"""Autotuner: analytic strategy ranking vs exhaustive measured sweeps.

ISSUE 2 acceptance: the autotuner picks the traffic-model-optimal strategy
for SpMV/BFS/GSANA on at least two scenario shapes each, cross-checked by
running *every* candidate in the grid through the engine and comparing the
chosen strategy's measured traffic against the sweep minimum.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Comm,
    Layout,
    MigratoryStrategy,
    bucketize,
    cost_model_for,
    generate_alignment_pair,
    partition_ell,
    pick_grid,
)
from repro.engine import (
    BFSInputs,
    GSANAInputs,
    PlanCache,
    SpMVInputs,
    autotune,
    candidate_grid,
    choose_strategy,
    rank_strategies,
    run,
)
from repro.sparse import (
    edges_to_csr,
    erdos_renyi_edges,
    laplacian_2d,
    partition_graph,
    rmat_edges,
    skewed_matrix,
)


def _spmv_inputs(kind: str) -> SpMVInputs:
    if kind == "laplacian":
        a = laplacian_2d(10)
        n = 100
    else:
        a = skewed_matrix(400, 6, 48, seed=1)
        n = 400
    lens = np.diff(np.asarray(a.indptr))
    x = jnp.asarray(np.random.default_rng(0).standard_normal(n).astype(np.float32))
    return SpMVInputs(partition_ell(a, 8, k=int(lens.max())), x)


def _bfs_inputs(kind: str) -> BFSInputs:
    scale = 8
    n = 1 << scale
    edges = (
        erdos_renyi_edges(scale, 6, seed=7) if kind == "er" else rmat_edges(scale, 6, seed=7)
    )
    return BFSInputs(partition_graph(edges_to_csr(edges, n), 8), 0)


def _gsana_inputs(n: int) -> GSANAInputs:
    vs1, vs2, pi = generate_alignment_pair(n, seed=3)
    grid = pick_grid(n, 32)
    cap = max(bucketize(vs1, grid).cap, bucketize(vs2, grid).cap)
    return GSANAInputs(
        vs1, vs2, bucketize(vs1, grid, cap=cap), bucketize(vs2, grid, cap=cap),
        ground_truth=pi,
    )


SCENARIOS = [
    ("spmv", "laplacian"),
    ("spmv", "skewed"),
    ("bfs", "er"),
    ("bfs", "rmat"),
    ("gsana", "n128"),
    ("gsana", "n192"),
]


def _inputs_for(op: str, case: str):
    if op == "spmv":
        return _spmv_inputs(case)
    if op == "bfs":
        return _bfs_inputs(case)
    return _gsana_inputs(128 if case == "n128" else 192)


@pytest.mark.parametrize("op,case", SCENARIOS)
def test_choose_strategy_matches_exhaustive_measured_sweep(op, case):
    """The analytic pick must achieve the minimum *measured* traffic over an
    exhaustive engine sweep of the full candidate grid."""
    inputs = _inputs_for(op, case)
    chosen = choose_strategy(op, inputs)
    cache = PlanCache()
    measured = {}
    for st in candidate_grid(op):
        _, rep = run(op, inputs, st, "local", iters=1, warmup=0, cache=cache)
        measured[st] = rep
    min_traffic = min(r.traffic.total_bytes for r in measured.values())
    assert chosen in measured
    assert measured[chosen].traffic.total_bytes == min_traffic


def test_spmv_picks_replication():
    """Paper §5.1: replicating x eliminates migrations on both shapes."""
    for case in ("laplacian", "skewed"):
        st = choose_strategy("spmv", _spmv_inputs(case))
        assert st.replicate_x is True


def test_bfs_picks_remote_write():
    """Paper §5.2: small write packets beat migrate's context ping-pong."""
    for case in ("er", "rmat"):
        st = choose_strategy("bfs", _bfs_inputs(case))
        assert st.comm == Comm.REMOTE_WRITE


def test_gsana_picks_hcb():
    """Paper §5.3: Hilbert placement co-locates buckets with their
    neighborhoods; among traffic ties the lower modeled makespan wins."""
    for n in (128, 192):
        inputs = _gsana_inputs(n)
        st = choose_strategy("gsana", inputs)
        assert st.layout == Layout.HCB
        model = cost_model_for("gsana", inputs)
        chosen = model(st)
        ties = [
            e for e in (model(c) for c in candidate_grid("gsana"))
            if e.traffic_bytes == chosen.traffic_bytes
        ]
        assert chosen.balance_penalty == min(e.balance_penalty for e in ties)


def test_rank_strategies_sorted_and_consistent():
    inputs = _spmv_inputs("laplacian")
    ranked = rank_strategies("spmv", inputs)
    keys = [e.rank_key() for e in ranked]
    assert keys == sorted(keys)
    assert ranked[0].strategy == choose_strategy("spmv", inputs)


def test_run_with_auto_strategy():
    inputs = _spmv_inputs("laplacian")
    _, rep = run("spmv", inputs, "auto", "local", cache=PlanCache())
    assert rep.strategy["replicate_x"] is True
    assert rep.traffic.migrations == 0
    with pytest.raises(ValueError, match="unknown strategy"):
        run("spmv", inputs, "fastest", "local")


def test_autotune_probes_warm_the_cache():
    """Probing the top-k compiles their plans, so the production run of the
    winner is a cache hit — the compile is amortized away."""
    inputs = _bfs_inputs("er")
    cache = PlanCache()
    tuned = autotune("bfs", inputs, "local", probe_top_k=2, cache=cache)
    probed = [c for c in tuned.candidates if c.probe is not None]
    assert len(probed) == 2
    assert all(not c.probe.cache_hit for c in probed)
    _, rep = run("bfs", inputs, tuned.best, "local", cache=cache)
    assert rep.cache_hit
    # the ranking table carries every candidate and marks the winner
    table = tuned.table()
    assert len(table) == len(candidate_grid("bfs"))
    assert sum(row["chosen"] for row in table) >= 1


def test_unknown_op_cost_model_raises():
    with pytest.raises(ValueError, match="no cost model"):
        cost_model_for("attention", None)
