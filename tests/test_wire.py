"""Wire codec (engine/wire.py): the stable serialization shared by the
cluster protocol and the dedup content hash (DESIGN.md §1h).

Pins the two properties everything downstream rests on:

- **bit-exact round trips** — arrays come back with the same dtype, shape,
  and raw bytes (base64 of the C-order buffer, no float repr loss); enums
  come back as enum members (the str-mixin Comm/Layout/Scheme must not
  flatten to bare strings); dataclasses rebuild through the ``repro.*``-only
  class allowlist.
- **canonical bytes** — ``canonical_bytes`` is deterministic across dict
  insertion order and process boundaries, so "same computation" hashes the
  same everywhere. A Request deduped in-process and the same Request routed
  to a worker share one identity: ``_content_hash`` over the original and
  over a wire round trip agree.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Comm, Layout, MigratoryStrategy, Scheme, partition_ell
from repro.engine import (
    BFSInputs,
    MoEDispatchInputs,
    Request,
    SpMVInputs,
    WireError,
    canonical_bytes,
    decode_value,
    encode_value,
    run,
)
from repro.engine.service import _content_hash
from repro.sparse import edges_to_csr, erdos_renyi_edges, laplacian_2d, partition_graph


def _roundtrip(value):
    return decode_value(json.loads(json.dumps(encode_value(value))))


# -- scalar / container round trips -------------------------------------------


@pytest.mark.parametrize("value", [
    None, True, False, 0, -7, 3.25, "text", "",
    (1, 2, 3), [1.5, None, "x"], {"a": 1, "b": (2, 3)},
    {"nested": {"t": (1, [2, {"deep": True}])}},
])
def test_json_values_roundtrip(value):
    assert _roundtrip(value) == value


def test_tuple_list_distinction_survives():
    assert _roundtrip((1, 2)) == (1, 2)
    assert isinstance(_roundtrip((1, 2)), tuple)
    assert isinstance(_roundtrip([1, 2]), list)
    assert isinstance(_roundtrip(((1,), [2])), tuple)


def test_nan_and_inf_roundtrip():
    out = _roundtrip([float("inf"), float("-inf")])
    assert out == [float("inf"), float("-inf")]
    assert np.isnan(_roundtrip(float("nan")))


# -- arrays: dtype/shape/bit-exactness ----------------------------------------


@pytest.mark.parametrize("dtype", ["float32", "float64", "int32", "int64", "bool"])
def test_ndarray_roundtrip_preserves_dtype_and_bits(dtype):
    rng = np.random.default_rng(3)
    arr = (rng.standard_normal((5, 7)) * 100).astype(dtype)
    back = _roundtrip(arr)
    assert isinstance(back, np.ndarray)
    assert back.dtype == arr.dtype
    assert back.shape == arr.shape
    assert back.tobytes() == arr.tobytes()  # bit-exact, not approx


def test_jax_array_roundtrips_as_numpy():
    x = jnp.asarray(np.arange(12, dtype=np.float32).reshape(3, 4))
    back = _roundtrip(x)
    assert isinstance(back, np.ndarray)
    assert back.dtype == np.float32
    assert np.array_equal(back, np.asarray(x))


def test_noncontiguous_array_encodes_c_order():
    arr = np.arange(24, dtype=np.int32).reshape(4, 6).T  # F-order view
    back = _roundtrip(arr)
    assert np.array_equal(back, arr)


def test_zero_dim_and_empty_arrays():
    assert _roundtrip(np.float32(2.5)) == np.float32(2.5)
    back = _roundtrip(np.empty((0, 3), dtype=np.int64))
    assert back.shape == (0, 3) and back.dtype == np.int64


def test_object_dtype_refused():
    with pytest.raises(WireError, match="object-dtype"):
        encode_value(np.array([object()], dtype=object))


# -- enums and dataclasses ----------------------------------------------------


@pytest.mark.parametrize("member", [
    Comm.MIGRATE, Comm.REMOTE_WRITE, Layout.HCB, Scheme.PAIR,
])
def test_str_mixin_enums_roundtrip_as_members(member):
    back = _roundtrip(member)
    assert back is member  # the member, not its bare string value
    # and the encoding is tagged, not a bare scalar (str-Enum trap)
    assert isinstance(encode_value(member), dict)


def test_strategy_dataclass_roundtrip():
    st = MigratoryStrategy(
        comm=Comm.MIGRATE, replicate_x=False, layout=Layout.BLK,
        scheme=Scheme.ALL, grain=64,
    )
    back = _roundtrip(st)
    assert back == st
    assert back.cache_key() == st.cache_key()
    assert isinstance(back.comm, Comm)


def test_non_repro_class_refused_on_decode():
    payload = {
        "__wire__": "dc",
        "cls": "subprocess:Popen",
        "fields": {"args": ["true"]},
    }
    with pytest.raises(WireError, match="only repro"):
        decode_value(payload)


def test_repr_fallback_hashes_but_refuses_decode():
    class Opaque:
        pass

    encoded = encode_value(Opaque())
    assert encoded["__wire__"] == "repr"  # hash identity still works
    canonical_bytes(Opaque())  # and canonicalizes without raising
    with pytest.raises(WireError, match="hash-only"):
        decode_value(encoded)


def test_unknown_tag_refused():
    with pytest.raises(WireError, match="unknown wire tag"):
        decode_value({"__wire__": "no-such-tag"})


# -- canonical bytes ----------------------------------------------------------


def test_canonical_bytes_insertion_order_independent():
    a = {"x": 1, "y": (2, 3), "z": np.arange(3)}
    b = {"z": np.arange(3), "y": (2, 3), "x": 1}
    assert canonical_bytes(a) == canonical_bytes(b)


def test_canonical_bytes_distinguishes_values_and_dtypes():
    assert canonical_bytes(np.float32(1)) != canonical_bytes(np.float64(1))
    assert canonical_bytes((1, 2)) != canonical_bytes([1, 2])
    assert canonical_bytes({"a": 1}) != canonical_bytes({"a": 2})


# -- Request wire form --------------------------------------------------------


def _mixed_requests():
    rng = np.random.default_rng(0)
    a = partition_ell(laplacian_2d(8), 4)
    x = jnp.asarray(rng.standard_normal(64).astype(np.float32))
    g = partition_graph(edges_to_csr(erdos_renyi_edges(6, 4, seed=1), 64), 4)
    moe = MoEDispatchInputs(
        x=jnp.asarray(rng.standard_normal((16, 8)).astype(np.float32)),
        router=jnp.asarray(rng.standard_normal((8, 4)).astype(np.float32)),
        nodelets=2,
    )
    return [
        Request("spmv", SpMVInputs(a, x), MigratoryStrategy(), "local"),
        Request("bfs", BFSInputs(g, 0)),
        Request("moe_dispatch", moe, qos=2.0, timeout=30.0),
    ]


@pytest.mark.parametrize("idx", [0, 1, 2])
def test_request_roundtrip_and_execution_parity(idx):
    request = _mixed_requests()[idx]
    payload = request.to_wire()
    # the wire form is honest JSON: survives a dumps/loads boundary
    rebuilt = Request.from_wire(json.loads(json.dumps(payload)))
    assert rebuilt.qos == request.qos and rebuilt.timeout == request.timeout
    y0, _ = run(request, iters=1, warmup=0)
    y1, _ = run(rebuilt, iters=1, warmup=0)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))


def test_request_wire_version_checked():
    payload = _mixed_requests()[0].to_wire()
    payload["v"] = 999
    with pytest.raises(WireError, match="version"):
        Request.from_wire(payload)


def test_request_op_instance_travels_by_name():
    from repro.engine import SpMVOp

    req = _mixed_requests()[0]
    payload = Request(SpMVOp(), req.inputs).to_wire()
    assert payload["op"] == "spmv"


def test_request_unregistered_substrate_refused():
    from repro.engine import Substrate

    class Rogue(Substrate):
        name = "never-registered"

    req = _mixed_requests()[0]
    with pytest.raises(WireError, match="registered substrate"):
        Request(req.op, req.inputs, substrate=Rogue()).to_wire()


def test_dedup_hash_shared_with_wire_identity():
    """The dedup content hash and the wire form agree on request identity:
    a request that crossed the wire hashes identically to the original."""
    request = _mixed_requests()[0]
    rebuilt = Request.from_wire(json.loads(json.dumps(request.to_wire())))
    h0 = _content_hash(request.op, request.inputs, request.strategy, "local")
    h1 = _content_hash(rebuilt.op, rebuilt.inputs, rebuilt.strategy, "local")
    assert h0 == h1
    # and different inputs hash differently
    other = _mixed_requests()[1]
    h2 = _content_hash(other.op, other.inputs, other.strategy, "local")
    assert h2 != h0


# -- segment / blobref modes (protocol v2 data plane) --------------------------


def test_segment_mode_emits_ndref_and_roundtrips_bit_identically():
    from repro.engine import SegmentTable

    table = SegmentTable()
    arr = np.arange(24, dtype=np.int64).reshape(4, 6)
    encoded = encode_value({"a": arr, "k": 3}, segments=table)
    assert len(table) == 1 and table.nbytes() == arr.nbytes
    # the envelope carries no tensor bytes, only the ref
    flat = json.dumps(encoded)
    assert "ndref" in flat and "data" not in flat
    # decode path: the protocol layer attaches the raw buffer
    from repro.cluster.protocol import attach_segments

    parsed = json.loads(flat)
    attach_segments(parsed, [bytes(s) for s in table.segments])
    out = decode_value(parsed)
    np.testing.assert_array_equal(out["a"], arr)
    assert out["a"].dtype == arr.dtype and out["k"] == 3


def test_segment_decode_returns_writable_copies():
    """v1 'nd' parity: an ndref decodes to a fresh writable array, not a
    read-only view pinning the frame buffer."""
    from repro.engine import SegmentTable

    from repro.cluster.protocol import attach_segments

    table = SegmentTable()
    encoded = encode_value(np.arange(8, dtype=np.float32), segments=table)
    parsed = json.loads(json.dumps(encoded))
    attach_segments(parsed, [bytes(s) for s in table.segments])
    out = decode_value(parsed)
    assert out.flags.writeable and out.flags.owndata
    out[0] = -1.0  # downstream in-place mutation keeps working


def test_unattached_ndref_is_refused():
    from repro.engine import SegmentTable

    encoded = encode_value(np.ones(3), segments=SegmentTable())
    with pytest.raises(WireError, match="not attached"):
        decode_value(json.loads(json.dumps(encoded)))


def test_blob_sink_emits_blobref_and_resolver_decodes():
    from repro.engine import SegmentTable, collect_blob_digests, content_digest

    big = np.arange(64, dtype=np.float32)
    small = np.ones(2, dtype=np.float32)
    store = {}

    def sink(original, arr):
        if arr.nbytes < 64:
            return None
        digest = content_digest(arr)
        store[digest] = arr
        return digest

    table = SegmentTable()
    encoded = encode_value((big, small), segments=table, blob_sink=sink)
    assert len(store) == 1  # only the big array was claimed
    assert len(table) == 1  # the small one rides as a segment
    assert collect_blob_digests(encoded) == list(store)
    from repro.cluster.protocol import attach_segments

    attach_segments(encoded, [bytes(s) for s in table.segments])
    out = decode_value(encoded, blob_resolver=store.__getitem__)
    np.testing.assert_array_equal(out[0], big)
    np.testing.assert_array_equal(out[1], small)
    with pytest.raises(WireError, match="blob store"):
        decode_value(encoded, blob_resolver=None)


def test_canonical_bytes_ignore_transport_encoding():
    """Dedup identity must not depend on how a value crossed the wire."""
    from repro.engine import SegmentTable, content_digest

    a = partition_ell(laplacian_2d(6), 2)
    x = jnp.asarray(np.arange(36, dtype=np.float32))
    value = SpMVInputs(a, x)
    baseline = canonical_bytes(value)
    # encoding the same value in segment/blob modes leaves identity alone
    encode_value(value, segments=SegmentTable())
    encode_value(value, blob_sink=lambda o, arr: content_digest(arr))
    assert canonical_bytes(value) == baseline
    # and a segment-mode wire round trip reproduces the same canonical bytes
    from repro.cluster.protocol import attach_segments

    table = SegmentTable()
    encoded = json.loads(json.dumps(encode_value(value, segments=table)))
    attach_segments(encoded, [bytes(s) for s in table.segments])
    assert canonical_bytes(decode_value(encoded)) == baseline


def test_request_to_wire_threads_segments_and_blobs():
    from repro.engine import SegmentTable, collect_blob_digests, content_digest

    a = partition_ell(laplacian_2d(6), 2)
    x = jnp.asarray(np.arange(36, dtype=np.float32))
    request = Request("spmv", SpMVInputs(a, x), strategy=None)
    blobs = {}

    def sink(original, arr):
        if arr.nbytes < 128:
            return None
        digest = content_digest(arr)
        blobs[digest] = arr
        return digest

    table = SegmentTable()
    payload = request.to_wire(segments=table, blob_sink=sink)
    digests = collect_blob_digests(payload)
    assert digests and set(digests) == set(blobs)
    from repro.cluster.protocol import attach_segments

    parsed = json.loads(json.dumps(payload))
    attach_segments(parsed, [bytes(s) for s in table.segments])
    rebuilt = Request.from_wire(parsed, blob_resolver=blobs.__getitem__)
    oracle, _ = run(request, iters=1, warmup=0)
    got, _ = run(rebuilt, iters=1, warmup=0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(oracle))
