"""Engine: MigratoryOp/Substrate/RunReport — substrate parity, traffic
accounting parity with the legacy per-algorithm functions, and the report
schema."""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Comm,
    Layout,
    MigratoryStrategy,
    Scheme,
    bfs_traffic,
    bucketize,
    ceil_div,
    effective_bandwidth,
    gather_result,
    generate_alignment_pair,
    partition_ell,
    pick_grid,
    plan_stats,
    layout_hcb,
    round_up,
    spmv_traffic,
)
from repro.engine import (
    BFSInputs,
    BFSOp,
    GSANAInputs,
    GSANAOp,
    OpNotSupportedError,
    PallasSubstrate,
    RunReport,
    SpMVInputs,
    SpMVOp,
    get_substrate,
    list_substrates,
    register_substrate,
    run,
)
from repro.sparse import (
    edges_to_csr,
    erdos_renyi_edges,
    laplacian_2d,
    partition_graph,
    spmv_csr_ref,
)


# -- shared small problems -----------------------------------------------------


@pytest.fixture(scope="module")
def spmv_problem():
    a = laplacian_2d(12)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(144).astype(np.float32))
    return a, SpMVInputs(partition_ell(a, 8), x)


@pytest.fixture(scope="module")
def bfs_problem():
    g = edges_to_csr(erdos_renyi_edges(8, 6, seed=2), 256)
    return BFSInputs(partition_graph(g, 8), 3)


@pytest.fixture(scope="module")
def gsana_problem():
    vs1, vs2, pi = generate_alignment_pair(384, seed=11)
    grid = pick_grid(384, 32)
    cap = max(bucketize(vs1, grid).cap, bucketize(vs2, grid).cap)
    return GSANAInputs(
        vs1, vs2, bucketize(vs1, grid, cap=cap), bucketize(vs2, grid, cap=cap),
        ground_truth=pi,
    )


# -- util ----------------------------------------------------------------------


def test_ceil_div_round_up():
    assert ceil_div(0, 4) == 0
    assert ceil_div(1, 4) == 1
    assert ceil_div(8, 4) == 2
    assert ceil_div(9, 4) == 3
    assert round_up(0, 8) == 0
    assert round_up(1, 8) == 8
    assert round_up(16, 8) == 16
    # the quadruple-negation expression it replaced in partition_ell
    for n, p, pad in [(144, 8, 1), (37, 8, 4), (1000, 64, 8), (5, 3, 2)]:
        assert round_up(ceil_div(n, p), pad) == -(-(-(-n // p)) // pad) * pad


# -- engine.run on the local substrate -----------------------------------------


@pytest.mark.parametrize("replicate", [True, False])
def test_spmv_local_matches_ref_and_legacy_traffic(spmv_problem, replicate):
    a, inputs = spmv_problem
    st = MigratoryStrategy(replicate_x=replicate)
    y, report = run(SpMVOp(), inputs, st, "local")
    np.testing.assert_allclose(
        np.asarray(gather_result(y, 144)), np.asarray(spmv_csr_ref(a, inputs.x)),
        atol=1e-4,
    )
    legacy = spmv_traffic(inputs.a, st)
    assert report.traffic.migrations == legacy.migrations
    assert report.traffic.remote_writes == legacy.remote_writes
    # effective bandwidth consistent with the legacy formula at this timing
    assert report.effective_gbps * 1e9 == pytest.approx(
        effective_bandwidth(inputs.a, 144, report.seconds), rel=1e-6
    )


@pytest.mark.parametrize("comm", [Comm.MIGRATE, Comm.REMOTE_WRITE])
def test_bfs_local_matches_legacy_traffic(bfs_problem, comm):
    st = MigratoryStrategy(comm=comm)
    parents, report = run(BFSOp(), bfs_problem, st, "local")
    legacy = bfs_traffic(bfs_problem.g, bfs_problem.root, st)
    assert report.traffic.migrations == legacy.traffic.migrations
    assert report.traffic.remote_writes == legacy.traffic.remote_writes
    assert report.metrics["rounds"] == legacy.rounds
    assert report.metrics["edges_traversed"] == legacy.edges_traversed
    assert report.metrics["reached"] == int((np.asarray(parents) >= 0).sum())


def test_gsana_local_matches_legacy_plan_stats(gsana_problem):
    st = MigratoryStrategy(layout=Layout.HCB, scheme=Scheme.PAIR)
    (cand, score), report = run(GSANAOp(), gsana_problem, st, "local")
    assert report.metrics["recall_at_k"] > 0.9
    i = gsana_problem
    legacy = plan_stats(
        i.vs1, i.vs2, i.b1, i.b2, layout_hcb(i.b1, i.b2, i.nodelets),
        Scheme.PAIR, i.nodelets, threads_per_nodelet=i.threads_per_nodelet,
    )
    assert report.traffic.migrations == legacy.traffic.migrations
    assert report.metrics["model_makespan"] == legacy.makespan
    assert report.metrics["total_comparisons"] == legacy.total_comparisons


def test_run_by_op_name(spmv_problem):
    _, inputs = spmv_problem
    y, report = run("spmv", inputs, MigratoryStrategy(), "local")
    assert report.op == "spmv" and report.substrate == "local"


# -- pallas substrate ----------------------------------------------------------


def test_spmv_pallas_matches_local(spmv_problem):
    a, inputs = spmv_problem
    st = MigratoryStrategy()
    y_local, _ = run(SpMVOp(), inputs, st, "local")
    y_pallas, report = run(SpMVOp(), inputs, st, "pallas")
    np.testing.assert_allclose(
        np.asarray(y_local), np.asarray(y_pallas), atol=1e-4
    )
    assert report.substrate == "pallas"


def test_gsana_pallas_matches_local(gsana_problem):
    st = MigratoryStrategy(scheme=Scheme.PAIR)
    (c_l, s_l), _ = run(GSANAOp(), gsana_problem, st, "local")
    (c_p, s_p), _ = run(GSANAOp(), gsana_problem, st, "pallas")
    fin = np.isfinite(np.asarray(s_l))
    np.testing.assert_allclose(
        np.asarray(s_l)[fin], np.asarray(s_p)[fin], atol=1e-5
    )


def test_bfs_pallas_matches_local(bfs_problem):
    """("bfs", "pallas") resolves now and its parent tree is bit-identical
    to the local oracle (integer min-scatter is deterministic)."""
    assert PallasSubstrate().supports("bfs")
    assert PallasSubstrate().supports("spmv")
    with pytest.raises(OpNotSupportedError):
        PallasSubstrate().kernel("moe_dispatch")
    p_local, _ = run(BFSOp(), bfs_problem, MigratoryStrategy(), "local")
    p_pallas, report = run(BFSOp(), bfs_problem, MigratoryStrategy(), "pallas")
    np.testing.assert_array_equal(np.asarray(p_local), np.asarray(p_pallas))
    assert report.substrate == "pallas"


# -- registry + report schema --------------------------------------------------


def test_substrate_registry():
    assert {"local", "mesh", "pallas"} <= set(list_substrates())
    with pytest.raises(ValueError):
        get_substrate("no-such-substrate")
    from repro.engine.substrate import _REGISTRY

    register_substrate("local2", type(get_substrate("local")))
    try:
        assert "local2" in list_substrates()
    finally:
        _REGISTRY.pop("local2", None)


def test_report_schema_roundtrip(bfs_problem):
    _, report = run(BFSOp(), bfs_problem, MigratoryStrategy(), "local")
    d = json.loads(report.to_json())
    for key in (
        "op", "substrate", "seconds", "us_per_call", "migrations",
        "remote_writes", "traffic_bytes", "bytes_moved", "effective_gbps",
        "strategy_comm", "strategy_replicate_x", "strategy_layout",
        "strategy_scheme", "mteps", "rounds", "cache_hit", "compile_seconds",
    ):
        assert key in d, key
    assert d["op"] == "bfs"
    assert d["strategy_comm"] == "remote_write"
    assert isinstance(report, RunReport)


def test_benchmark_rows_use_unified_schema(spmv_problem):
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.util import emit_report

    _, inputs = spmv_problem
    _, report = run(SpMVOp(), inputs, MigratoryStrategy(), "local")
    row = emit_report("bench_x", "case_y", report, extra_key=1)
    assert row["bench"] == "bench_x" and row["case"] == "case_y"
    assert row["op"] == "spmv" and row["extra_key"] == 1
    assert "effective_gbps" in row and "migrations" in row


# -- local vs mesh parity (subprocess, 8 forced host devices) ------------------

PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax.numpy as jnp
from repro.core import Comm, MigratoryStrategy, Scheme, bucketize, \
    generate_alignment_pair, partition_ell, pick_grid
from repro.engine import (BFSInputs, BFSOp, GSANAInputs, GSANAOp, SpMVInputs,
                          SpMVOp, run)
from repro.sparse import edges_to_csr, erdos_renyi_edges, laplacian_2d, \
    partition_graph

a = laplacian_2d(16)
x = jnp.asarray(np.random.default_rng(0).standard_normal(256).astype(np.float32))
si = SpMVInputs(partition_ell(a, 8), x)
g = edges_to_csr(erdos_renyi_edges(9, 8, seed=1), 512)
bi = BFSInputs(partition_graph(g, 8), 3)
vs1, vs2, pi = generate_alignment_pair(384, seed=11)
grid = pick_grid(384, 32)
cap = max(bucketize(vs1, grid).cap, bucketize(vs2, grid).cap)
gi = GSANAInputs(vs1, vs2, bucketize(vs1, grid, cap=cap),
                 bucketize(vs2, grid, cap=cap))

# all four (replicate_x, comm) strategy combinations, all three ops
for replicate in (True, False):
    for comm in (Comm.MIGRATE, Comm.REMOTE_WRITE):
        st = MigratoryStrategy(replicate_x=replicate, comm=comm)
        yl, rl = run(SpMVOp(), si, st, "local")
        ym, rm = run(SpMVOp(), si, st, "mesh")
        assert np.array_equal(np.asarray(yl), np.asarray(ym)), ("spmv", replicate, comm)
        assert rl.traffic.migrations == rm.traffic.migrations

        pl, _ = run(BFSOp(), bi, st, "local")
        pm, _ = run(BFSOp(), bi, st, "mesh")
        assert np.array_equal(np.asarray(pl), np.asarray(pm)), ("bfs", replicate, comm)

for scheme in (Scheme.ALL, Scheme.PAIR):
    st = MigratoryStrategy(scheme=scheme)
    (cl, sl), _ = run(GSANAOp(), gi, st, "local")
    (cm, sm), _ = run(GSANAOp(), gi, st, "mesh")
    assert np.array_equal(np.asarray(cl), np.asarray(cm)), ("gsana cand", scheme)
    assert np.array_equal(np.asarray(sl), np.asarray(sm)), ("gsana score", scheme)
print("ENGINE-PARITY-OK")
"""


@pytest.mark.slow
def test_local_mesh_parity_subprocess():
    """ISSUE acceptance: local and mesh substrates produce bit-identical
    results for SpMV/BFS/GSANA across the strategy grid."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", PARITY_SCRIPT], env=env, capture_output=True,
        text=True, timeout=900,
    )
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr}"
    assert "ENGINE-PARITY-OK" in r.stdout
