"""Core GSANA: S3 layout strategy — scheme equivalence, recall, layout effects."""
import numpy as np
import pytest

from repro.core import (
    Scheme, bucketize, compute_similarity, generate_alignment_pair,
    gsana_effective_bw, hilbert_order_of_buckets, layout_blk, layout_hcb,
    neighbor_buckets, pick_grid, plan_stats, recall_at_k, xy_to_d, d_to_xy,
)


@pytest.fixture(scope="module")
def problem():
    vs1, vs2, pi = generate_alignment_pair(384, seed=11)
    grid = pick_grid(384, 32)
    cap = max(bucketize(vs1, grid).cap, bucketize(vs2, grid).cap)
    b1 = bucketize(vs1, grid, cap=cap)
    b2 = bucketize(vs2, grid, cap=cap)
    return vs1, vs2, b1, b2, pi


def test_hilbert_curve_bijection():
    order = 4
    d = np.arange(256)
    x, y = d_to_xy(order, d)
    assert (xy_to_d(order, x, y) == d).all()
    # consecutive points are grid neighbors (the locality property)
    dx, dy = np.abs(np.diff(x)), np.abs(np.diff(y))
    assert ((dx + dy) == 1).all()


def test_neighbor_buckets_window():
    nb = neighbor_buckets(4)
    assert nb.shape == (16, 9)
    assert (nb[5] >= 0).all()  # interior bucket has 9 neighbors
    assert (nb[0] >= 0).sum() == 4  # corner has 4


def test_all_equals_pair(problem):
    """Paper §3.3.1: ALL and PAIR compute the same similarity top-k."""
    vs1, vs2, b1, b2, pi = problem
    cA, sA = compute_similarity(vs1, vs2, b1, b2, k=4, scheme=Scheme.ALL)
    cP, sP = compute_similarity(vs1, vs2, b1, b2, k=4, scheme=Scheme.PAIR)
    sa = np.where(np.isfinite(np.asarray(sA)), np.asarray(sA), -1.0)
    sp = np.where(np.isfinite(np.asarray(sP)), np.asarray(sP), -1.0)
    assert np.allclose(sa, sp, atol=1e-5)


def test_alignment_recall(problem):
    """The aligner finds ground-truth partners (paper: GSANA achieves high
    recall with reduced problem space)."""
    vs1, vs2, b1, b2, pi = problem
    cand, _ = compute_similarity(vs1, vs2, b1, b2, k=4)
    assert recall_at_k(cand, pi) > 0.9


def test_hcb_reduces_migrations(problem):
    """Paper Fig. 11: HCB cuts thread migrations vs BLK (10-36% time gain)."""
    vs1, vs2, b1, b2, _ = problem
    p = 8
    pl_blk = layout_blk(b1, b2, vs1.n, vs2.n, p)
    pl_hcb = layout_hcb(b1, b2, p)
    st_blk = plan_stats(vs1, vs2, b1, b2, pl_blk, Scheme.PAIR, p)
    st_hcb = plan_stats(vs1, vs2, b1, b2, pl_hcb, Scheme.PAIR, p)
    assert st_hcb.traffic.migrations < st_blk.traffic.migrations
    assert st_hcb.total_comparisons == st_blk.total_comparisons


def test_pair_improves_balance(problem):
    """Paper §5.3: PAIR's finer granularity gives better modeled speedup."""
    vs1, vs2, b1, b2, _ = problem
    p = 8
    pl = layout_blk(b1, b2, vs1.n, vs2.n, p)
    st_all = plan_stats(vs1, vs2, b1, b2, pl, Scheme.ALL, p, threads_per_nodelet=32)
    st_pair = plan_stats(vs1, vs2, b1, b2, pl, Scheme.PAIR, p, threads_per_nodelet=32)
    assert st_pair.speedup_model >= st_all.speedup_model


def test_effective_bw_positive(problem):
    vs1, vs2, b1, b2, _ = problem
    bw = gsana_effective_bw(vs1, vs2, b1, b2, seconds=1.0)
    assert bw > 0


def test_hilbert_rank_is_permutation():
    r = hilbert_order_of_buckets(8)
    assert sorted(r.tolist()) == list(range(64))
