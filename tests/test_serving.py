"""Serving correctness: prefill + decode_step must reproduce the
teacher-forced forward logits at the same position, for every family."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import reduced_config
from repro.models import Ctx, api

CASES = [
    ("qwen2-7b", 2e-4),
    ("glm4-9b", 2e-4),          # partial rope
    ("mixtral-8x22b", 8e-2),    # MoE: capacity drops differ prefill vs decode
    ("rwkv6-3b", 2e-4),
    ("whisper-small", 2e-4),
    ("zamba2-2.7b", 2e-4),
    ("phi-3-vision-4.2b", 2e-4),
]


def _setup(arch):
    cfg = reduced_config(arch)
    ctx = Ctx(cfg=cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    b, s = 2, 24
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (b, cfg.encoder_frames, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(key, (b, cfg.num_patches, cfg.d_model), jnp.float32)
    return cfg, ctx, params, toks, batch


@pytest.mark.parametrize("arch,tol", CASES)
def test_decode_matches_forward(arch, tol):
    cfg, ctx, params, toks, batch = _setup(arch)
    b, s = toks.shape
    lg, st = api.prefill(ctx, params, toks[:, : s - 1], max_len=s + 8, batch=batch)
    lg2, st2 = api.decode_step(ctx, params, toks[:, s - 1 : s], st)
    m = api.module_for(cfg)
    if cfg.family == "encdec":
        ref = m.forward(ctx, params, toks, batch["frames"])[:, s - 1]
    elif cfg.family == "vlm":
        ref = m.forward(ctx, params, toks, batch["patches"])[:, cfg.num_patches + s - 1]
    else:
        ref = m.forward(ctx, params, toks)[:, s - 1]
    err = float(jnp.abs(lg2[:, 0] - ref).max())
    assert err < tol, f"{arch}: decode/forward mismatch {err}"


@pytest.mark.parametrize("arch", ["llama3.2-3b", "rwkv6-3b", "zamba2-2.7b"])
def test_multi_step_decode_stable(arch):
    """Greedy-decode 8 tokens; logits stay finite, cache length advances."""
    cfg, ctx, params, toks, batch = _setup(arch)
    b, s = toks.shape
    lg, st = api.prefill(ctx, params, toks, max_len=s + 16, batch=batch)
    tok = jnp.argmax(lg[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for _ in range(8):
        lg, st = api.decode_step(ctx, params, tok, st)
        assert not bool(jnp.isnan(lg).any())
        tok = jnp.argmax(lg[:, -1], axis=-1)[:, None].astype(jnp.int32)
    if hasattr(st, "length"):
        assert int(st.length) == s + 8


def test_prefill_logits_match_forward_tail():
    cfg, ctx, params, toks, batch = _setup("llama3.2-3b")
    lg, _ = api.prefill(ctx, params, toks, max_len=64, batch=batch)
    m = api.module_for(cfg)
    ref = m.forward(ctx, params, toks)[:, -1:]
    assert float(jnp.abs(lg - ref).max()) < 2e-4
