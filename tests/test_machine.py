"""Calibration plane: machine file lifecycle, alpha-beta fits, and the
predicted-seconds contract (DESIGN.md §1f).

ISSUE 6 acceptance: with a calibrated machine file the autotuner ranks in
predicted wall seconds and RunReports carry the model-honesty columns; with
no machine file every ranking and report is bit-identical to the
traffic-unit behavior. The rank-correlation tests check the prediction
*ordering* against exhaustive measured engine sweeps (Spearman on the
sweep's reported traffic, the same cross-check lens test_autotune.py uses —
wall seconds on the single-device local oracle are noise for
execution-inert strategy axes)."""
import json
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bucketize, generate_alignment_pair, partition_ell, pick_grid
from repro.engine import (
    BFSInputs,
    GSANAInputs,
    PlanCache,
    ProbeStore,
    SpMVInputs,
    autotune,
    candidate_grid,
    rank_strategies,
    run,
)
from repro.machine import (
    DEFAULT_PROFILE,
    AlphaBeta,
    MachineProfile,
    Peaks,
    PerformanceModel,
    SubstrateProfile,
    default_machine,
    fit_alpha_beta,
    load_machine,
    machine_fingerprint,
    reset_default_machine_cache,
)
from repro.sparse import (
    edges_to_csr,
    erdos_renyi_edges,
    laplacian_2d,
    partition_graph,
    rmat_edges,
    skewed_matrix,
)


def _calibrated_profile(fingerprint=None) -> MachineProfile:
    """A synthetic calibrated profile (no measurement): plausible sustained
    rates, fingerprinted to this topology unless told otherwise."""
    sub = SubstrateProfile(
        stream_bw=10e9,
        dispatch_overhead=20e-6,
        collectives={
            "all_gather": AlphaBeta(alpha=50e-6, beta=1.0 / 5e9),
            "all_to_all": AlphaBeta(alpha=50e-6, beta=1.0 / 5e9),
            "psum": AlphaBeta(alpha=50e-6, beta=1.0 / 5e9),
        },
        source="measured",
    )
    return MachineProfile(
        fingerprint=fingerprint if fingerprint is not None else machine_fingerprint(),
        peaks=Peaks(flops=1e12, hbm_bw=10e9, ici_bw=5e9),
        substrates={"local": sub, "mesh": sub, "pallas": sub},
        host_parallel_capacity=1.8,
        calibrated=True,
        created="2026-08-09T00:00:00",
    )


@pytest.fixture
def calibrated_machine(tmp_path, monkeypatch):
    """A calibrated machine file installed as the process default."""
    path = tmp_path / "machine.json"
    _calibrated_profile().save(path)
    monkeypatch.setenv("REPRO_MACHINE_PATH", str(path))
    reset_default_machine_cache()
    yield path
    reset_default_machine_cache()


# -- machine file lifecycle ----------------------------------------------------


def test_machine_file_roundtrip(tmp_path):
    profile = _calibrated_profile()
    path = profile.save(tmp_path / "machine.json")
    loaded = load_machine(path)
    assert loaded is not None
    assert loaded.calibrated
    assert loaded.fingerprint == profile.fingerprint
    assert loaded.peaks == profile.peaks
    assert loaded.substrate("local").collective("all_gather") == AlphaBeta(
        alpha=50e-6, beta=1.0 / 5e9
    )
    assert loaded.host_parallel_capacity == pytest.approx(1.8)


def test_absent_machine_file_is_silent_none(tmp_path):
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert load_machine(tmp_path / "never_written.json") is None


@pytest.mark.parametrize("payload", [
    '{"peaks": {',                 # truncated
    '{"peaks": null}',             # wrong shape
    '{}',                          # missing peaks entirely
    'null',                        # not an object
])
def test_corrupt_machine_file_warns_and_falls_back(tmp_path, payload):
    path = tmp_path / "machine.json"
    path.write_text(payload)
    with pytest.warns(RuntimeWarning, match="corrupt machine file"):
        assert load_machine(path) is None


def test_newer_schema_machine_file_warns(tmp_path):
    blob = _calibrated_profile().to_dict()
    blob["version"] = 999
    path = tmp_path / "machine.json"
    path.write_text(json.dumps(blob))
    with pytest.warns(RuntimeWarning, match="schema v999"):
        assert load_machine(path) is None


def test_stale_fingerprint_rejected_unless_allowed(tmp_path):
    foreign = dict(machine_fingerprint(), device_count=424242)
    path = _calibrated_profile(fingerprint=foreign).save(tmp_path / "machine.json")
    with pytest.warns(RuntimeWarning, match="different topology"):
        assert load_machine(path) is None
    assert load_machine(path, allow_stale=True) is not None


def test_default_profile_is_uncalibrated_with_roofline_peaks():
    # the session fixture points REPRO_MACHINE_PATH at a nonexistent file
    profile = default_machine()
    assert profile.calibrated is False
    assert profile.stale() is False  # the bundled default claims no topology
    # the bundled peaks are the roofline's former hardcoded constants
    assert DEFAULT_PROFILE.peaks == Peaks(flops=197e12, hbm_bw=819e9, ici_bw=50e9)
    # unknown substrate degrades to the local profile, never raises
    assert profile.substrate("tpu-pod") == profile.substrate("local")


def test_default_machine_cache_tracks_mtime(tmp_path, monkeypatch):
    path = tmp_path / "machine.json"
    monkeypatch.setenv("REPRO_MACHINE_PATH", str(path))
    reset_default_machine_cache()
    assert default_machine().calibrated is False
    _calibrated_profile().save(path)
    assert default_machine().calibrated is True  # picked up without a reset
    reset_default_machine_cache()


# -- alpha-beta fitting --------------------------------------------------------


def test_fit_alpha_beta_recovers_synthetic_model():
    alpha, beta = 2e-4, 1.0 / 5e9
    sizes = [1e4, 1e5, 1e6, 1e7]
    fit = fit_alpha_beta(sizes, [alpha + beta * n for n in sizes])
    assert fit.alpha == pytest.approx(alpha, rel=1e-6)
    assert fit.beta == pytest.approx(beta, rel=1e-6)
    assert fit.seconds(1e6, launches=2.0) == pytest.approx(2 * alpha + beta * 1e6)


def test_fit_alpha_beta_clamps_noise_nonnegative():
    # constant timings (pure latency): beta degenerates but never negative
    fit = fit_alpha_beta([1e3, 1e4, 1e5], [1e-4, 1e-4, 1e-4])
    assert fit.alpha >= 0.0 and fit.beta >= 0.0
    # decreasing timings (timer noise): bandwidth-only refit, still nonneg
    fit = fit_alpha_beta([1e3, 1e6], [5e-4, 1e-4])
    assert fit.alpha >= 0.0 and fit.beta >= 0.0
    with pytest.raises(ValueError):
        fit_alpha_beta([], [])


# -- predicted-seconds vs exhaustive measured sweeps ---------------------------


def _spmv_inputs(kind: str) -> SpMVInputs:
    if kind == "laplacian":
        a, n = laplacian_2d(10), 100
    else:
        a, n = skewed_matrix(400, 6, 48, seed=1), 400
    lens = np.diff(np.asarray(a.indptr))
    x = jnp.asarray(np.random.default_rng(0).standard_normal(n).astype(np.float32))
    return SpMVInputs(partition_ell(a, 8, k=int(lens.max())), x)


def _bfs_inputs(kind: str) -> BFSInputs:
    scale = 8
    edges = (
        erdos_renyi_edges(scale, 6, seed=7) if kind == "er"
        else rmat_edges(scale, 6, seed=7)
    )
    return BFSInputs(partition_graph(edges_to_csr(edges, 1 << scale), 8), 0)


def _gsana_inputs(n: int) -> GSANAInputs:
    vs1, vs2, pi = generate_alignment_pair(n, seed=3)
    grid = pick_grid(n, 32)
    cap = max(bucketize(vs1, grid).cap, bucketize(vs2, grid).cap)
    return GSANAInputs(
        vs1, vs2, bucketize(vs1, grid, cap=cap), bucketize(vs2, grid, cap=cap),
        ground_truth=pi,
    )


def _spearman(a, b) -> float:
    """Spearman rank correlation with average ranks for ties."""

    def ranks(xs):
        order = np.argsort(xs, kind="stable")
        r = np.empty(len(xs))
        i = 0
        while i < len(xs):
            j = i
            while j + 1 < len(xs) and xs[order[j + 1]] == xs[order[i]]:
                j += 1
            r[order[i : j + 1]] = (i + j) / 2.0
            i = j + 1
        return r
    ra, rb = ranks(np.asarray(a, float)), ranks(np.asarray(b, float))
    da, db = ra - ra.mean(), rb - rb.mean()
    denom = np.sqrt((da**2).sum() * (db**2).sum())
    if denom == 0:  # all ties on a side: orderings cannot disagree
        return 1.0
    return float((da * db).sum() / denom)


SCENARIOS = [
    ("spmv", "laplacian"),
    ("spmv", "skewed"),
    ("bfs", "er"),
    ("bfs", "rmat"),
    ("gsana", "n128"),
    ("gsana", "n192"),
]


def _inputs_for(op: str, case: str):
    if op == "spmv":
        return _spmv_inputs(case)
    if op == "bfs":
        return _bfs_inputs(case)
    return _gsana_inputs(128 if case == "n128" else 192)


@pytest.mark.parametrize("op,case", SCENARIOS)
def test_predicted_seconds_rank_correlates_with_measured_sweep(op, case):
    """The prediction's *ordering* must agree (Spearman >= 0.7) with an
    exhaustive engine sweep's measured traffic on the local substrate."""
    inputs = _inputs_for(op, case)
    model = PerformanceModel(_calibrated_profile())
    ranked = rank_strategies(op, inputs, machine=_calibrated_profile())
    assert all(e.predicted_seconds is not None for e in ranked)
    # predicted seconds are sorted best-first by construction
    preds = [e.predicted_seconds for e in ranked]
    assert preds == sorted(preds)

    cache = PlanCache()
    by_strategy = {}
    for st in candidate_grid(op):
        _, rep = run(op, inputs, st, "local", iters=1, warmup=0, cache=cache)
        by_strategy[st] = rep.traffic.total_bytes
    measured = [by_strategy[e.strategy] for e in ranked]
    rho = _spearman(preds, measured)
    assert rho >= 0.7, f"Spearman {rho:.3f} for {op}/{case}: {list(zip(preds, measured))}"
    # the model-optimal pick also achieves the sweep's measured minimum
    assert by_strategy[ranked[0].strategy] == min(measured)
    # prediction parts are finite, nonnegative, and sum to the total
    parts = model.predict_parts(ranked[0], "local")
    assert all(v >= 0.0 for v in parts.values())
    assert sum(parts.values()) == pytest.approx(ranked[0].predicted_seconds)


# -- calibrated engine behavior ------------------------------------------------


def test_calibrated_auto_ranks_in_predicted_seconds(calibrated_machine):
    inputs = _spmv_inputs("laplacian")
    tuned = autotune("spmv", inputs, "local")
    assert tuned.ranked_by == "predicted_seconds"
    assert all(c.predicted_seconds is not None for c in tuned.candidates)
    assert "predicted_seconds" in tuned.table()[0]
    _, rep = run("spmv", inputs, "auto", "local", cache=PlanCache())
    assert rep.strategy["replicate_x"] is True  # same pick, now in seconds
    assert rep.predicted_seconds is not None and rep.predicted_seconds > 0
    assert rep.model_error == pytest.approx(rep.predicted_seconds / rep.seconds)
    row = rep.to_dict()
    assert row["predicted_seconds"] == rep.predicted_seconds
    assert row["model_error"] == rep.model_error


def test_uncalibrated_fallback_is_bit_identical():
    # session fixture: no machine file -> the traffic-unit contract
    inputs = _bfs_inputs("er")
    ranked = rank_strategies("bfs", inputs)
    assert all(e.predicted_seconds is None for e in ranked)
    keys = [e.rank_key() for e in ranked]
    assert keys == sorted(keys)  # pure traffic-unit ordering
    tuned = autotune("bfs", inputs, "local")
    assert tuned.ranked_by == "traffic_bytes"
    assert "predicted_seconds" not in tuned.table()[0]
    _, rep = run("bfs", inputs, "auto", "local", cache=PlanCache())
    assert rep.predicted_seconds is None and rep.model_error is None
    row = rep.to_dict()
    assert "predicted_seconds" not in row and "model_error" not in row


# -- probe store fingerprinting ------------------------------------------------

KEY = ("spmv", ("local",), ("remote_write", True, "hcb", "pair", None), (), "sig")


def test_probe_store_ignores_and_prunes_foreign_fingerprints(tmp_path):
    from repro.machine import fingerprint_key

    path = tmp_path / "probes.json"
    foreign = fingerprint_key(dict(machine_fingerprint(), device_count=424242))
    path.write_text(json.dumps({
        "version": 2,
        "probes": {
            ProbeStore.encode_key(KEY): {"seconds": 0.25, "machine": foreign},
            "legacy-v1-entry": 0.125,  # schema v1: no provenance
        },
    }))
    store = ProbeStore(path)
    assert len(store) == 2  # loaded, but...
    assert store.get(KEY) is None  # ...foreign entries read as absent
    assert store.stale == 1
    store.record(KEY, 0.5)  # re-measured here
    store.save()
    assert store.pruned == 1  # the legacy v1 entry; KEY was overwritten
    saved = json.loads(path.read_text())
    assert saved["version"] == 2
    assert list(saved["probes"]) == [ProbeStore.encode_key(KEY)]
    fresh = ProbeStore(path)
    assert fresh.get(KEY) == 0.5  # same machine: served
    assert fresh.reused == 1


def test_probe_store_roundtrip_carries_this_machine(tmp_path):
    path = tmp_path / "probes.json"
    store = ProbeStore(path)
    store.record(KEY, 0.125)
    store.save()
    entry = next(iter(json.loads(path.read_text())["probes"].values()))
    assert entry["seconds"] == 0.125
    assert entry["machine"] == json.dumps(
        machine_fingerprint(), sort_keys=True, default=str
    )


# -- op cost-model memory declarations (§1f calibration contract) --------------


def test_moe_dispatch_declares_memory_class_and_model_consumes_it():
    """ISSUE 9 satellite pin: ``moe_dispatch``'s cost model declares its
    per-launch working set (``memory_bytes_per_launch`` + stream access
    class), and ``PerformanceModel.predict_parts`` charges exactly that —
    launches x bytes at STREAM rate — not the generic bytes_moved/gather
    fallback. Guards the declaration from silently regressing to the
    fallback (a 4x rate error on the synthetic profile)."""
    import dataclasses

    from repro.engine import MoEDispatchInputs, rank_strategies

    rng = np.random.default_rng(0)
    inputs = MoEDispatchInputs(
        x=jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32)),
        router=jnp.asarray(rng.standard_normal((16, 8)).astype(np.float32)),
        nodelets=4,
    )
    profile = _calibrated_profile()
    model = PerformanceModel(profile)
    ranked = rank_strategies("moe_dispatch", inputs, machine=profile)
    assert ranked
    local = profile.substrate("local")
    assert local.access_bw("stream") != local.access_bw("gather")
    for est in ranked:
        detail = est.detail
        assert detail["memory_access"] == "stream"
        assert detail["memory_bytes_per_launch"] > 0
        assert "collective_launches" in detail

        parts = model.predict_parts(est, "local")
        launches = max(1.0, float(detail["collective_launches"]))
        expected = (
            launches * float(detail["memory_bytes_per_launch"])
            / local.access_bw("stream")
        )
        assert parts["memory"] == pytest.approx(expected)

        # strip the declaration: the model must fall back to charging
        # bytes_moved at gather rate, which predicts a different memory term
        stripped = dataclasses.replace(est, detail={
            k: v for k, v in detail.items()
            if k not in ("memory_bytes_per_launch", "memory_access")
        })
        fallback = model.predict_parts(
            stripped, "local",
            bytes_moved=float(detail["memory_bytes_per_launch"]),
        )
        assert fallback["memory"] == pytest.approx(
            float(detail["memory_bytes_per_launch"]) / local.access_bw("gather")
        )
        assert fallback["memory"] != pytest.approx(parts["memory"])
