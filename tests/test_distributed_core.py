"""Multi-device shard_map paths for the core algorithms.

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main test process keeps seeing 1 device (per the dry-run isolation
rule). Marked slow-ish; one subprocess covers all assertions.
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.sparse import *
from repro.core import *
from repro.launch.mesh import make_nodelet_mesh

mesh = make_nodelet_mesh(8)
a = laplacian_2d(16)
x = jnp.asarray(np.random.default_rng(0).standard_normal(256).astype(np.float32))
pe = partition_ell(a, 8)
ref = spmv_csr_ref(a, x)
y1 = gather_result(spmv(pe, x, MigratoryStrategy(replicate_x=True), mesh=mesh), 256)
y2 = gather_result(spmv(pe, stripe_vector(x, 8), MigratoryStrategy(replicate_x=False), mesh=mesh), 256)
assert abs(np.asarray(y1) - np.asarray(ref)).max() < 1e-4, "replicated spmv"
assert abs(np.asarray(y2) - np.asarray(ref)).max() < 1e-4, "striped spmv"

g = edges_to_csr(erdos_renyi_edges(9, 8, seed=1), 512)
pg = partition_graph(g, 8)
p_ref = np.asarray(bfs(pg, 3))
for comm in (Comm.REMOTE_WRITE, Comm.MIGRATE):
    p_d = np.asarray(bfs(pg, 3, MigratoryStrategy(comm=comm), mesh=mesh))
    assert validate_parents(pg, 3, p_d), comm
    assert (((p_d >= 0) == (p_ref >= 0)).all()), comm

# collective structure: push must use all-to-all, pull must use all-gather
from jax.sharding import PartitionSpec as P
import re
def hlo_for(comm):
    from repro.core.bfs import _bfs_distributed
    import repro.core.bfs as bfsmod
    adj = jnp.transpose(pg.adj, (1, 0, 2)).reshape(-1, pg.k)
    def run(adj):
        return bfsmod._bfs_distributed(pg, 3, MigratoryStrategy(comm=comm), mesh, "nodelet", 64)
    return jax.jit(lambda: _bfs_distributed(pg, 3, MigratoryStrategy(comm=comm), mesh, "nodelet", 64)).lower().compile().as_text()
push_hlo = hlo_for(Comm.REMOTE_WRITE)
pull_hlo = hlo_for(Comm.MIGRATE)
assert "all-to-all" in push_hlo, "push should lower to all-to-all"
assert "all-gather" in pull_hlo, "pull should lower to all-gather"
print("DISTRIBUTED-CORE-OK")
"""


@pytest.mark.slow
def test_distributed_core_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True,
        timeout=900,
    )
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr}"
    assert "DISTRIBUTED-CORE-OK" in r.stdout
