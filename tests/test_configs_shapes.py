"""Config/shape registry invariants + divisibility constraints the production
mesh relies on."""
import pytest

from repro.configs import ARCHS, SHAPES, applicable, cells, get_config, reduced_config


def test_registry_complete():
    assert len(ARCHS) == 10
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert len(cells(ARCHS)) == 40


def test_long_500k_applicability():
    runs = [a for a in ARCHS if applicable(get_config(a), SHAPES["long_500k"])[0]]
    # SSM, hybrid, and SWA archs only (DESIGN.md §7)
    assert sorted(runs) == ["mixtral-8x22b", "rwkv6-3b", "zamba2-2.7b"]


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_flattened_projection_dims_divide_model_axis(arch):
    """TP sharding requires flattened head/ffn/vocab dims divisible by 16
    (whisper's vocab is the one documented exception -> replicated)."""
    cfg = get_config(arch)
    ms = 16
    assert (cfg.num_heads * cfg.hd) % ms == 0, "q projection"
    assert (cfg.num_kv_heads * cfg.hd) % ms == 0, "kv projection"
    assert cfg.d_model % ms == 0, "fsdp dim"
    if cfg.is_moe:
        assert (cfg.moe_d_ff or cfg.d_ff) % ms == 0
    else:
        assert cfg.d_ff % ms == 0
    if arch != "whisper-small":
        assert cfg.vocab_size % ms == 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_configs_are_small(arch):
    cfg = reduced_config(arch)
    assert cfg.num_layers <= 4
    assert cfg.d_model <= 256
    assert cfg.vocab_size <= 1024
    assert cfg.family == get_config(arch).family


def test_global_batch_divides_mesh():
    for s in SHAPES.values():
        if s.kind == "train":
            assert s.global_batch % 32 == 0  # pod x data
        # decode_32k batch 128 over data 16 ok; long_500k batch 1 replicated
