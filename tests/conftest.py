"""Shared test fixtures.

The calibration plane changes autotuner behavior when a calibrated
``experiments/machine.json`` exists (DESIGN.md §1f). The tier-1 suite pins
the *uncalibrated* contract — strategy picks in the paper's traffic units —
so every test session points the machine file at a path that does not
exist; tests that exercise calibrated behavior (tests/test_machine.py)
repoint it per-test via monkeypatch + ``reset_default_machine_cache``.
"""
import os

import pytest


@pytest.fixture(scope="session", autouse=True)
def _isolated_machine_file(tmp_path_factory):
    path = tmp_path_factory.mktemp("machine") / "machine.json"  # never written
    old = os.environ.get("REPRO_MACHINE_PATH")
    os.environ["REPRO_MACHINE_PATH"] = str(path)
    from repro.machine.machine import reset_default_machine_cache

    reset_default_machine_cache()
    yield
    if old is None:
        os.environ.pop("REPRO_MACHINE_PATH", None)
    else:
        os.environ["REPRO_MACHINE_PATH"] = old
    reset_default_machine_cache()
