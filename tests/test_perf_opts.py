"""Beyond-paper perf optimizations must be numerically exact (EXPERIMENTS.md
§Perf): padded-head TP equals the unsharded baseline."""
import os
import subprocess
import sys

import pytest

PAD_HEADS = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, dataclasses
from repro.configs import reduced_config
from repro.models import api, Ctx
from repro.models.sharding import make_rules

cfg = dataclasses.replace(
    reduced_config("llama3.2-3b"), num_heads=6, num_kv_heads=2, head_dim=16,
    d_model=96, d_ff=192,
)
from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
rules = make_rules(mesh, num_heads=6, num_kv_heads=2, vocab_size=cfg.vocab_size)
assert rules.heads4d is None  # 6 % 4 != 0 -> baseline replicates attention
params = api.init_params(cfg, jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)
m = api.module_for(cfg)
ctx_base = Ctx(cfg=cfg, mesh=mesh, rules=rules)
ctx_pad = Ctx(cfg=dataclasses.replace(cfg, tp_pad_heads=True), mesh=mesh, rules=rules)
with mesh:
    ref = jax.jit(lambda p, t: m.forward(ctx_base, p, t))(params, toks)
    pad = jax.jit(lambda p, t: m.forward(ctx_pad, p, t))(params, toks)
err = float(jnp.abs(ref - pad).max())
assert err < 1e-4, err
print("PAD-HEADS-EXACT-OK", err)
"""


@pytest.mark.slow
def test_padded_head_tp_exact_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", PAD_HEADS], env=env, capture_output=True,
        text=True, timeout=900,
    )
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr}"
    assert "PAD-HEADS-EXACT-OK" in r.stdout


def test_pad_heads_inactive_on_single_device():
    """Without a model axis the padded path must not engage (semantics oracle
    stays the plain one)."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import reduced_config
    from repro.models import Ctx, api

    cfg = dataclasses.replace(reduced_config("llama3.2-3b"), tp_pad_heads=True)
    ctx = Ctx(cfg=cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    m = api.module_for(cfg)
    base = m.forward(Ctx(cfg=reduced_config("llama3.2-3b")), params, toks)
    padded = m.forward(ctx, params, toks)
    assert float(jnp.abs(base - padded).max()) == 0.0
