"""Sparse substrate: formats, generators, conversions."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.sparse import (
    CSR, ELL, edges_to_csr, ell_from_csr, erdos_renyi_edges, laplacian_2d,
    partition_graph, rmat_edges, skewed_matrix, spmv_csr_ref, spmv_ell_ref,
    split_long_rows,
)


def test_laplacian_structure():
    a = laplacian_2d(8)
    assert a.shape == (64, 64)
    d = np.asarray(a.to_dense())
    assert np.allclose(d, d.T)
    assert (np.diag(d) == 4).all()
    # interior rows have 5 nonzeros (pentadiagonal)
    lens = np.diff(np.asarray(a.indptr))
    assert lens.max() == 5 and lens.min() == 3


def test_csr_dense_roundtrip():
    rng = np.random.default_rng(0)
    d = (rng.random((13, 17)) < 0.2) * rng.standard_normal((13, 17)).astype(np.float32)
    a = CSR.from_dense(d)
    assert np.allclose(np.asarray(a.to_dense()), d)


def test_ell_matches_csr():
    a = laplacian_2d(6)
    e = ell_from_csr(a)
    x = jnp.arange(36, dtype=jnp.float32)
    assert np.allclose(np.asarray(spmv_ell_ref(e, x)), np.asarray(spmv_csr_ref(a, x)))


def test_split_long_rows():
    rng = np.random.default_rng(1)
    d = np.zeros((10, 40), np.float32)
    d[3, :37] = rng.standard_normal(37)  # hub row
    d[5, :4] = 1.0
    a = CSR.from_dense(d)
    s, owner = split_long_rows(a, k=8)
    x = jnp.asarray(rng.standard_normal(40).astype(np.float32))
    y_sub = spmv_csr_ref(s, x)
    y = np.zeros(10, np.float32)
    np.add.at(y, owner, np.asarray(y_sub))
    assert np.allclose(y, np.asarray(spmv_csr_ref(a, x)), atol=1e-5)


def test_generators_shapes():
    e = erdos_renyi_edges(8, 4, seed=0)
    assert e.shape == (4 * 256, 2) and e.max() < 256
    r = rmat_edges(8, 4, seed=0)
    assert r.shape == (4 * 256, 2) and r.max() < 256
    # RMAT should be more skewed than ER
    g_er = edges_to_csr(e, 256)
    g_rm = edges_to_csr(r, 256)
    er_max = np.diff(np.asarray(g_er.indptr)).max()
    rm_max = np.diff(np.asarray(g_rm.indptr)).max()
    assert rm_max > er_max


def test_skewed_matrix_signature():
    m = skewed_matrix(3000, 8.0, 600, seed=0)
    lens = np.diff(np.asarray(m.indptr))
    assert lens.max() >= 400  # hubs present (dedup can shave a bit)
    assert 2.0 < lens.mean() < 24.0


def test_partition_graph_roundtrip():
    g = edges_to_csr(erdos_renyi_edges(7, 4, seed=2), 128)
    pg = partition_graph(g, 8)
    # every edge present exactly once at its owner
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)
    adj = np.asarray(pg.adj)
    for v in range(128):
        nbrs = sorted(indices[indptr[v]:indptr[v + 1]].tolist())
        row = adj[v % 8, v // 8]
        assert sorted(row[row >= 0].tolist()) == nbrs


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 40),
    density=st.floats(0.05, 0.6),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_csr_spmv_matches_dense(n, density, seed):
    rng = np.random.default_rng(seed)
    d = (rng.random((n, n)) < density) * rng.standard_normal((n, n)).astype(np.float32)
    a = CSR.from_dense(d)
    x = rng.standard_normal(n).astype(np.float32)
    assert np.allclose(np.asarray(spmv_csr_ref(a, jnp.asarray(x))), d @ x, atol=1e-4)
