"""Kernel registry: op x substrate completeness matrix, capabilities
introspection, OpSpec-driven dispatch, and legacy-shim delegation.

ISSUE 4 acceptance: every ``(op, substrate)`` pair either resolves a kernel
(with local/mesh bit-identical parity, pinned in the subprocess test below
for the new ``moe_dispatch`` op; engine parity for the original three lives
in test_engine.py) or raises ``OpNotSupportedError`` cleanly — including
``moe_dispatch``, which registers without touching any Substrate subclass.
"""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Comm, MigratoryStrategy, cost_model_for, partition_ell
from repro.engine import (
    OPS,
    KernelRegistry,
    MoEDispatchInputs,
    OpNotSupportedError,
    OpSpec,
    SpMVInputs,
    capabilities,
    candidate_grid,
    default_registry,
    get_substrate,
    list_substrates,
    run,
)
from repro.sparse import laplacian_2d

ALL_OPS = ("spmv", "bfs", "gsana", "moe_dispatch")
ALL_SUBSTRATES = ("local", "mesh", "pallas")


# -- completeness matrix -------------------------------------------------------


@pytest.mark.parametrize("op_name", ALL_OPS)
@pytest.mark.parametrize("sub_name", ALL_SUBSTRATES)
def test_every_pair_resolves_or_raises_cleanly(op_name, sub_name):
    """The matrix: kernel lookup either yields a callable or raises
    OpNotSupportedError — never KeyError, never AttributeError."""
    sub = get_substrate(sub_name)
    if capabilities()[op_name][sub_name]:
        kern = sub.kernel(op_name)
        assert callable(kern)
        assert sub.supports(op_name)
    else:
        assert not sub.supports(op_name)
        with pytest.raises(OpNotSupportedError):
            sub.kernel(op_name)


def test_capabilities_table_shape():
    """Rows = every registered op, columns = every registered substrate; the
    known support facts hold (pallas runs spmv/bfs/gsana but not moe).
    Compared over the three core substrates — importing ``repro.cluster``
    anywhere in the session legitimately adds a ``cluster`` column (its
    cells mirror the workers' kind, ``local`` when no cluster is active)."""
    table = capabilities()
    assert set(ALL_OPS) <= set(table)
    for op_name, row in table.items():
        assert set(row) == set(list_substrates())

    def core(op_name):
        return {k: table[op_name][k] for k in ("local", "mesh", "pallas")}

    assert core("spmv") == {"local": True, "mesh": True, "pallas": True}
    assert core("bfs") == {"local": True, "mesh": True, "pallas": True}
    assert core("moe_dispatch") == {"local": True, "mesh": True, "pallas": False}
    if "cluster" in list_substrates():
        assert table["spmv"]["cluster"] is True  # workers serve local kernels


def test_capabilities_agrees_with_kernel_table():
    """The exact drift check CI runs (one implementation, not a test-local
    copy): no unservable op, no unreachable kernel, table == resolution."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.capabilities_check import check

    assert check() == []


# -- OpSpec-driven dispatch ----------------------------------------------------


def test_ops_view_is_live_and_registry_backed():
    """The legacy OPS mapping reflects the registry, including ops
    registered after the engine was imported (moe_dispatch)."""
    assert set(ALL_OPS) <= set(OPS)
    assert OPS["spmv"]().name == "spmv"
    assert OPS["moe_dispatch"]().name == "moe_dispatch"
    assert "no_such_op" not in OPS
    with pytest.raises(KeyError):
        OPS["no_such_op"]


def test_unknown_op_and_duplicate_registration():
    with pytest.raises(ValueError, match="unknown op"):
        run("hyetograph", None, None, "local")
    reg = KernelRegistry()
    reg.register_kernel("x", "local", lambda sub: None)
    with pytest.raises(ValueError, match="already registered"):
        reg.register_kernel("x", "local", lambda sub: None)
    reg.register_kernel("x", "local", lambda sub: 42, replace=True)
    assert reg.resolve_kernel("x", "local")(None) == 42
    spec = OpSpec(name="x", factory=object)
    reg.register_op(spec)
    with pytest.raises(ValueError, match="already registered"):
        reg.register_op(spec)


def test_opspec_grid_drives_autotuner():
    """candidate_grid comes from the registered OpSpec: SpMV sweeps grains,
    BFS/GSANA use the default cross product, moe_dispatch varies only S2."""
    assert len(candidate_grid("spmv")) == 2 * 2 * 2 * 2 * 4
    assert len(candidate_grid("bfs")) == 2 * 2 * 2 * 2
    moe = candidate_grid("moe_dispatch")
    assert len(moe) == 2
    assert {st.comm for st in moe} == {Comm.MIGRATE, Comm.REMOTE_WRITE}


def test_opspec_grid_is_substrate_aware():
    """Targeting the grid at pallas widens the kernel-tuning axis to the
    Pallas block_rows candidates; other substrates (and None) see the
    substrate-blind grid; zero-arg grid callables still work."""
    from repro.engine import PALLAS_BLOCK_CANDIDATES

    spmv_p = candidate_grid("spmv", "pallas")
    assert len(spmv_p) == 2 * 2 * 2 * 2 * len(PALLAS_BLOCK_CANDIDATES)
    assert {st.grain for st in spmv_p} == set(PALLAS_BLOCK_CANDIDATES)
    bfs_p = candidate_grid("bfs", "pallas")
    assert {st.grain for st in bfs_p} == set(PALLAS_BLOCK_CANDIDATES)
    # substrate-blind spellings agree, instance or name alike
    assert candidate_grid("spmv", "local") == candidate_grid("spmv")
    assert candidate_grid("bfs", get_substrate("mesh")) == candidate_grid("bfs")
    # a zero-arg grid registered by an out-of-tree op is called as before
    # (kernel registered too so the drift check never sees an unservable op)
    reg = default_registry()
    spec = OpSpec(name="zero_arg_grid_op", factory=object, grid=lambda: [MigratoryStrategy()])
    reg.register_op(spec, replace=True)
    reg.register_kernel("zero_arg_grid_op", "local", lambda sub: None, replace=True)
    assert candidate_grid("zero_arg_grid_op", "pallas") == [MigratoryStrategy()]


def test_opspec_cost_model_registered_into_core():
    """Registering an OpSpec with a cost_model makes core.cost serve it —
    moe_dispatch is autotunable through the same lookup as the paper ops."""
    rng = np.random.default_rng(0)
    inputs = MoEDispatchInputs(
        x=jnp.asarray(rng.standard_normal((64, 16)).astype(np.float32)),
        router=jnp.asarray(rng.standard_normal((16, 16)).astype(np.float32)),
    )
    model = cost_model_for("moe_dispatch", inputs)
    est = model(MigratoryStrategy())
    assert est.traffic_bytes >= 0
    assert "dispatch_mode" in est.detail


# -- legacy shims (removed with the Request redesign) --------------------------


def test_legacy_method_shims_are_gone():
    """The pre-registry per-op methods (``substrate.spmv(...)``) were
    deleted — kernels resolve only through the registry, and a missing
    registration is a typed capability error."""
    sub = get_substrate("local")
    for legacy in ("spmv", "bfs", "gsana"):
        assert not hasattr(sub, legacy), f"legacy shim {legacy} resurfaced"
    # the registry path still serves the op
    a = laplacian_2d(8)
    x = jnp.asarray(np.random.default_rng(3).standard_normal(64).astype(np.float32))
    inputs = SpMVInputs(partition_ell(a, 8), x)
    y_kern = sub.kernel("spmv")(inputs.a, x, strategy=MigratoryStrategy())
    assert np.asarray(y_kern).size == x.size
    with pytest.raises(OpNotSupportedError):
        get_substrate("pallas").kernel("moe_dispatch")


# -- moe_dispatch local/mesh parity (subprocess, 8 forced host devices) --------

MOE_PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax.numpy as jnp
from repro.core import Comm, MigratoryStrategy
from repro.engine import MoEDispatchInputs, run

rng = np.random.default_rng(1)
# divisible (ep modes) and non-divisible (tp fallback) expert/nodelet shapes
for (T, D, E, P) in [(128, 32, 16, 8), (256, 48, 8, 4), (120, 16, 6, 4)]:
    mi = MoEDispatchInputs(
        x=jnp.asarray(rng.standard_normal((T, D)).astype(np.float32)),
        router=jnp.asarray(rng.standard_normal((D, E)).astype(np.float32)),
        nodelets=P)
    for comm in (Comm.MIGRATE, Comm.REMOTE_WRITE):
        st = MigratoryStrategy(comm=comm)
        yl, rl = run("moe_dispatch", mi, st, "local")
        ym, rm = run("moe_dispatch", mi, st, "mesh")
        assert np.array_equal(np.asarray(yl), np.asarray(ym)), (T, E, P, comm)
        assert rl.traffic.total_bytes == rm.traffic.total_bytes
        assert rl.metrics["dispatch_mode"] == rm.metrics["dispatch_mode"]
print("MOE-PARITY-OK")
"""


@pytest.mark.slow
def test_moe_local_mesh_parity_subprocess():
    """ISSUE 4 acceptance: the fourth op's local and mesh kernels are
    bit-identical across push/pull/tp modes."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", MOE_PARITY_SCRIPT], env=env, capture_output=True,
        text=True, timeout=900,
    )
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr}"
    assert "MOE-PARITY-OK" in r.stdout


def test_renamed_subclass_inherits_parent_kernels():
    """A subclass that only renames itself keeps its parent's kernels (the
    pre-registry subclassing contract): substrate_kind walks the MRO to the
    nearest class with registered kernels; explicit kind= still wins."""
    from repro.engine import LocalSubstrate

    class FastLocal(LocalSubstrate):
        name = "fast_local"

    sub = FastLocal()
    assert sub.substrate_kind == "local"
    assert sub.supports("spmv") and sub.supports("moe_dispatch")
    assert callable(sub.kernel("bfs"))

    class PinnedKind(LocalSubstrate):
        name = "pinned"
        kind = "pallas"

    assert PinnedKind().substrate_kind == "pallas"
    assert not PinnedKind().supports("moe_dispatch")  # pallas has no moe kernel
    assert PinnedKind().supports("bfs")  # ("bfs", "pallas") registered
