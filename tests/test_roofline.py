"""Roofline HLO parser: trip-count scaling, dot FLOPs, collective ring costs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.roofline import HloModule, analyze, model_flops
from repro.configs import get_config


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_dot_flops_exact():
    co = _compile(
        lambda a, b: a @ b,
        jax.ShapeDtypeStruct((32, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 48), jnp.float32),
    )
    mod = HloModule(co.as_text())
    assert mod.flops() == 2 * 32 * 48 * 64


def test_scan_trip_count_scaling():
    """XLA cost analysis counts scan bodies once; the parser must multiply."""
    def f(x, ws):
        def layer(c, w):
            return jnp.tanh(c @ w), None
        x, _ = jax.lax.scan(layer, x, ws)
        return x.sum()

    l = 6
    co = _compile(
        f,
        jax.ShapeDtypeStruct((8, 32), jnp.float32),
        jax.ShapeDtypeStruct((l, 32, 32), jnp.float32),
    )
    mod = HloModule(co.as_text())
    assert mod.flops() == l * 2 * 8 * 32 * 32
    # and the raw XLA number is indeed body-once (the bug we correct);
    # cost_analysis() returns a per-device list on older jax
    ca = co.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    assert ca["flops"] < mod.flops()


def test_nested_scan_trip_counts():
    def f(x, ws):
        def outer(c, w):
            def inner(ci, wi):
                return ci @ wi, None
            c, _ = jax.lax.scan(inner, c, w)
            return c, None
        x, _ = jax.lax.scan(outer, x, ws)
        return x.sum()

    co = _compile(
        f,
        jax.ShapeDtypeStruct((4, 16), jnp.float32),
        jax.ShapeDtypeStruct((3, 5, 16, 16), jnp.float32),
    )
    mod = HloModule(co.as_text())
    assert mod.flops() == 3 * 5 * 2 * 4 * 16 * 16


def test_collective_ring_costs():
    """Synthetic HLO text: each collective kind gets its ring multiplier."""
    txt = """
HloModule test

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %ag = f32[4096]{0} all-gather(%p0), replica_groups=[2,4]<=[8], dimensions={0}
  %ar = f32[1024]{0} all-reduce(%p0), replica_groups=[2,4]<=[8], to_apply=%add
  %rs = f32[256]{0} reduce-scatter(%p0), replica_groups=[2,4]<=[8], to_apply=%add
  %a2a = f32[1024]{0} all-to-all(%p0), replica_groups=[2,4]<=[8]
  %cp = f32[1024]{0} collective-permute(%p0), source_target_pairs={{0,1}}
  ROOT %out = f32[1024]{0} add(%ar, %p0)
}
"""
    mod = HloModule(txt)
    recs = {r.kind: r for r in mod.collectives()}
    b = 1024 * 4
    assert recs["all-gather"].group_size == 4
    assert recs["all-gather"].wire_bytes == 3 * b
    assert recs["all-reduce"].wire_bytes == pytest.approx(2 * 3 / 4 * b)
    assert recs["reduce-scatter"].wire_bytes == pytest.approx(3 / 4 * b)
    assert recs["all-to-all"].wire_bytes == pytest.approx(3 / 4 * b)
    assert recs["collective-permute"].wire_bytes == b


def test_analyze_terms_and_dominance():
    co = _compile(
        lambda a, b: (a @ b).sum(),
        jax.ShapeDtypeStruct((256, 256), jnp.bfloat16),
        jax.ShapeDtypeStruct((256, 256), jnp.bfloat16),
    )
    rep = analyze(co.as_text())
    assert rep.flops == 2 * 256**3
    assert rep.t_compute > 0 and rep.t_memory > 0
    assert rep.dominant in ("compute", "memory", "collective")
    assert rep.bytes_collective == 0


def test_model_flops_formulas():
    cfg = get_config("llama3.2-3b")
    n = cfg.param_count
    tr = model_flops(cfg, "train", 4096, 256)
    assert tr > 6 * n * 4096 * 256  # attention term on top
    pf = model_flops(cfg, "prefill", 32768, 32)
    de = model_flops(cfg, "decode", 32768, 128)
    assert pf > de
    moe = get_config("mixtral-8x22b")
    assert moe.active_param_count < moe.param_count / 3


def test_bytes_nonzero_and_fusion_skipped():
    co = _compile(lambda x: jnp.tanh(x) * 2 + 1, jax.ShapeDtypeStruct((4096,), jnp.float32))
    mod = HloModule(co.as_text())
    b = mod.bytes_hbm()
    assert 0 < b < 4096 * 4 * 20  # bounded: fused internals not double-counted
