"""MoE layer: routing invariants, capacity behavior, dispatch-mode
equivalence on a multi-device submesh (subprocess)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.layers import Ctx
from repro.models.moe import (
    _capacity, _local_combine, _local_dispatch, _positions_in_expert,
    _route, moe_params, moe_sublayer,
)


def _cfg(e=4, k=2, cap=2.0):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=64, num_heads=2,
        num_kv_heads=2, d_ff=128, vocab_size=64, num_experts=e,
        experts_per_token=k, moe_d_ff=32, capacity_factor=cap,
        dtype="float32", remat=False,
    )


def test_positions_in_expert():
    ef = jnp.asarray([2, 0, 2, 1, 2, 0], dtype=jnp.int32)
    pos = np.asarray(_positions_in_expert(ef, 3))
    np.testing.assert_array_equal(pos, [0, 0, 1, 0, 2, 1])


def test_route_gates_normalized():
    cfg = _cfg()
    xt = jax.random.normal(jax.random.PRNGKey(0), (16, 64))
    router = jax.random.normal(jax.random.PRNGKey(1), (64, 4))
    gates, experts = _route(cfg, xt, router)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert int(experts.max()) < 4
    # top-k distinct experts per token
    assert all(len(set(r.tolist())) == 2 for r in np.asarray(experts))


def test_dispatch_combine_roundtrip_identity_experts():
    """With identity expert FFNs, dispatch+combine must reproduce the input
    (for tokens under capacity)."""
    cfg = _cfg(cap=8.0)  # ample capacity: nothing dropped
    t, d = 12, 64
    xt = jax.random.normal(jax.random.PRNGKey(0), (t, d))
    gates = jnp.full((t, 2), 0.5)
    experts = jnp.stack(
        [jnp.arange(t, dtype=jnp.int32) % 4, (jnp.arange(t, dtype=jnp.int32) + 1) % 4],
        axis=1,
    )
    cap = _capacity(cfg, t, 4)
    buf, ef, pos, keep = _local_dispatch(cfg, xt, gates, experts, cap)
    assert bool(keep.all())
    out = _local_combine(cfg, buf, gates, ef, pos, keep, t, d)  # identity "FFN"
    np.testing.assert_allclose(np.asarray(out), np.asarray(xt), rtol=1e-5)


def test_capacity_drops_overflow():
    cfg = _cfg(cap=0.25)
    t = 32
    xt = jax.random.normal(jax.random.PRNGKey(0), (t, 64))
    gates = jnp.full((t, 2), 0.5)
    experts = jnp.zeros((t, 2), jnp.int32)  # everyone wants expert 0
    cap = _capacity(cfg, t, 4)
    _, _, _, keep = _local_dispatch(cfg, xt, gates, experts, cap)
    assert int(keep.sum()) == cap  # exactly capacity kept, rest dropped


def test_single_device_moe_forward():
    cfg = _cfg()
    ctx = Ctx(cfg=cfg)
    p = moe_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 64))
    out = moe_sublayer(ctx, p, x)
    assert out.shape == x.shape and not bool(jnp.isnan(out).any())


DISPATCH_EQUIV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.models.config import ModelConfig
from repro.models.layers import Ctx
from repro.models.moe import moe_params, moe_sublayer
from repro.models.sharding import make_rules
from repro.compat import make_mesh

cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=64, num_heads=2,
                  num_kv_heads=2, d_ff=128, vocab_size=64, num_experts=8,
                  experts_per_token=2, moe_d_ff=32, capacity_factor=8.0,
                  dtype="float32", remat=False)
mesh = make_mesh((4, 2), ("data", "model"))
rules = make_rules(mesh, num_experts=8, num_heads=2, num_kv_heads=2)
ctx = Ctx(cfg=cfg, mesh=mesh, rules=rules)
ctx1 = Ctx(cfg=cfg)
p = moe_params(cfg, jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64))
ref = moe_sublayer(ctx1, p, x)
for mode in ("ep_push", "ep_pull", "tp"):
    with mesh:
        out = jax.jit(lambda p, x: moe_sublayer(ctx, p, x, dispatch=mode))(p, x)
    err = float(jnp.abs(out - ref).max())
    assert err < 1e-3, f"{mode}: {err}"
    print(f"{mode} err={err:.2e}")
print("MOE-DISPATCH-EQUIV-OK")
"""


@pytest.mark.slow
def test_dispatch_modes_equivalent_subprocess():
    """All three distributed dispatch strategies equal the single-device
    semantics (ample capacity so no drops)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", DISPATCH_EQUIV], env=env, capture_output=True,
        text=True, timeout=900,
    )
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr}"
    assert "MOE-DISPATCH-EQUIV-OK" in r.stdout
