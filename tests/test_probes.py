"""ProbeStore: persistence roundtrip and the corrupt-store regression.

ISSUE 4 satellite: a corrupt/truncated ``experiments/autotune_probes.json``
must degrade to an empty store with a warning, never crash the autotuner.
"""
import json
import warnings

import pytest

from repro.engine import ProbeStore

KEY = ("spmv", ("local",), ("remote_write", True, "hcb", "pair", None), (), "sig")


def test_roundtrip(tmp_path):
    path = tmp_path / "probes.json"
    store = ProbeStore(path)
    assert store.get(KEY) is None
    store.record(KEY, 0.125)
    store.save()
    fresh = ProbeStore(path)
    assert fresh.get(KEY) == 0.125
    assert fresh.reused == 1
    assert len(fresh) == 1


def test_missing_file_is_silent(tmp_path):
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # any warning fails the test
        store = ProbeStore(tmp_path / "never_written.json")
        assert len(store) == 0


@pytest.mark.parametrize("payload", [
    '{"probes": {',                      # truncated mid-write
    '{"probes": {"k": {}}}',             # value of a non-castable type
    '{"probes": {"k": null}}',           # null seconds
    '{"probes": [1, 2]}',                # wrong container shape
    'null',                              # not an object at all
    '\x00\x01binary-garbage',            # not JSON
])
def test_corrupt_store_degrades_to_empty_with_warning(tmp_path, payload):
    """Regression: every corruption shape loads as an empty store and warns
    (previously ``float(dict)``/``float(None)`` raised TypeError)."""
    path = tmp_path / "probes.json"
    path.write_text(payload)
    store = ProbeStore(path)
    with pytest.warns(RuntimeWarning, match="corrupt probe store"):
        assert len(store) == 0
    # the degraded store still records and saves over the corrupt file
    store.record(KEY, 0.5)
    store.save()
    assert json.loads(path.read_text())["probes"]
    assert ProbeStore(path).get(KEY) == 0.5


def test_non_utf8_store_degrades_to_empty_with_warning(tmp_path):
    """Regression: read as bytes, so non-UTF-8 garbage is 'corrupt', not an
    uncaught UnicodeDecodeError."""
    path = tmp_path / "probes.json"
    path.write_bytes(b"\xff\xfe\x00garbage")
    store = ProbeStore(path)
    with pytest.warns(RuntimeWarning, match="corrupt probe store"):
        assert len(store) == 0
