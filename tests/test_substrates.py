"""Substrate tests: data pipeline determinism, optimizer, checkpointing
(atomic/async/keep-k), fault-tolerant supervisor, elastic re-meshing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.data import DataConfig, SyntheticTokens
from repro.optim import AdamWConfig, apply_updates, global_norm, init, schedule
from repro.optim.adamw import compress_decompress
from repro.runtime import (
    SupervisorConfig, plan_remesh, run_supervised, straggler_report,
)


# -- data ----------------------------------------------------------------------


def test_pipeline_deterministic_and_host_sharded():
    cfg = DataConfig(vocab_size=1000, seq_len=128, global_batch=8)
    full = SyntheticTokens(cfg)
    h0 = SyntheticTokens(cfg, host_id=0, num_hosts=2)
    h1 = SyntheticTokens(cfg, host_id=1, num_hosts=2)
    g = full.batch(3)
    assert g.shape == (8, 129)
    np.testing.assert_array_equal(np.concatenate([h0.batch(3), h1.batch(3)]), g)
    np.testing.assert_array_equal(full.batch(3), g)  # replayable
    assert not np.array_equal(full.batch(3), full.batch(4))
    assert g.max() < 1000 and g.min() >= 0


def test_pipeline_has_learnable_structure():
    cfg = DataConfig(vocab_size=256, seq_len=4096, global_batch=2)
    b = SyntheticTokens(cfg).batch(0)
    # bigram (x*31+7)%255+1 appears more often than chance
    t = b[:, :-1].reshape(-1)
    n = b[:, 1:].reshape(-1)
    hits = (n == (t * 31 + 7) % 255 + 1).mean()
    assert hits > 0.2


# -- optimizer -----------------------------------------------------------------


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, total_steps=200, warmup_steps=0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = init(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw of w^2
        params, state, _ = apply_updates(params, state, grads, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) < 0.2
    assert float(schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0, abs=0.02)
    assert float(schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1, abs=0.02)


def test_grad_clip():
    cfg = AdamWConfig(lr=0.0, clip_norm=1.0, total_steps=10)
    params = {"w": jnp.zeros(3)}
    state = init(params, cfg)
    _, _, m = apply_updates(params, state, {"w": jnp.full(3, 100.0)}, cfg)
    assert float(m["grad_norm"]) > 100


def test_ef_compression_residual_correction():
    """Error feedback: the running sum of decompressed grads tracks the true
    sum (bias-free compression)."""
    rng = np.random.default_rng(0)
    residual = jnp.zeros(64)
    total_true = np.zeros(64)
    total_sent = np.zeros(64)
    for i in range(50):
        g = jnp.asarray(rng.standard_normal(64).astype(np.float32))
        sent, residual = compress_decompress(g, residual)
        total_true += np.asarray(g)
        total_sent += np.asarray(sent)
    assert np.abs(total_true - total_sent).max() < 0.5  # bounded by one quantum


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-3, 1e3))
def test_property_int8_quantization_bounded_error(seed, scale):
    rng = np.random.default_rng(seed)
    g = jnp.asarray((rng.standard_normal(128) * scale).astype(np.float32))
    sent, res = compress_decompress(g, jnp.zeros(128))
    # residual = exactly what was not sent
    np.testing.assert_allclose(np.asarray(sent + res), np.asarray(g), rtol=1e-5, atol=1e-5 * scale)
    assert float(jnp.abs(res).max()) <= float(jnp.abs(g).max()) / 127 * 1.01


# -- checkpointing -------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3), "b": {"c": jnp.int32(7)}}
    save(tmp_path, 5, tree)
    assert latest_step(tmp_path) == 5
    out = restore(tmp_path, 5, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert int(out["b"]["c"]) == 7


def test_checkpoint_keep_k_and_atomicity(tmp_path):
    tree = {"w": jnp.zeros(4)}
    for s in (1, 2, 3, 4, 5):
        save(tmp_path, s, tree, keep=2)
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [4, 5]
    # a stale tmp dir must not count as a checkpoint
    (tmp_path / "step_9.tmp").mkdir()
    assert latest_step(tmp_path) == 5


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(tmp_path, keep=3)
    for s in (10, 20):
        ck.save(s, {"w": jnp.full(8, float(s))})
    ck.wait()
    assert latest_step(tmp_path) == 20
    out = restore(tmp_path, 20, {"w": jnp.zeros(8)})
    assert float(out["w"][0]) == 20.0


# -- supervisor: checkpoint/restart fault tolerance ----------------------------


def _toy_build():
    params = {"w": jnp.zeros(4)}
    opt = {"step": jnp.int32(0)}

    def step_fn(params, opt_state, batch):
        w = params["w"] + batch["x"].mean()
        return {"w": w}, {"step": opt_state["step"] + 1}, {"loss": w.sum()}

    return params, opt, step_fn


def test_supervisor_recovers_from_failure(tmp_path):
    cfg = SupervisorConfig(
        ckpt_dir=str(tmp_path), ckpt_every=5, total_steps=20, max_restarts=2
    )
    calls = []

    def data_for_step(step):
        calls.append(step)
        return {"x": jnp.full(4, 1.0)}

    res = run_supervised(
        cfg, build=_toy_build, data_for_step=data_for_step, fail_at=12
    )
    assert res.restarts == 1
    assert res.final_step == 19
    # steps replayed from the last checkpoint (10), not from zero
    assert 11 in calls and calls.count(0) == 1
    # final state reflects exactly 20 effective steps
    out = restore(tmp_path, 19, ({"w": jnp.zeros(4)}, {"step": jnp.int32(0)}))
    assert float(out[0]["w"][0]) == pytest.approx(20.0)


def test_supervisor_no_failure(tmp_path):
    cfg = SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=50, total_steps=7)
    res = run_supervised(
        cfg, build=_toy_build, data_for_step=lambda s: {"x": jnp.ones(4)}
    )
    assert res.restarts == 0 and res.final_step == 6


def test_straggler_report():
    r = straggler_report([1.0] * 10 + [5.0])
    assert r["stragglers"] == 1 and r["worst_ratio"] == pytest.approx(5.0)


# -- elastic -------------------------------------------------------------------


def test_elastic_plan():
    p = plan_remesh(n_healthy=400, model_axis=16, global_batch=256, prev_data_axis=16)
    assert p.model_axis == 16
    assert p.data_axis == 16  # 400 // 16 = 25 -> 16 (pow2)
    p2 = plan_remesh(n_healthy=200, model_axis=16, global_batch=256, prev_data_axis=16)
    assert p2.data_axis == 8
    assert p2.per_device_batch_factor == 2.0
    assert p2.microbatches >= 2
    with pytest.raises(ValueError):
        plan_remesh(n_healthy=8, model_axis=16, global_batch=256, prev_data_axis=16)
