"""Batched EngineService: submission-order responses, per-batch compile
amortization, aggregate throughput stats.

ISSUE 2 acceptance: batched results are bit-identical to sequential
``engine.run`` calls.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Comm, MigratoryStrategy, Scheme, bucketize, \
    generate_alignment_pair, partition_ell, pick_grid
from repro.engine import (
    BFSInputs,
    EngineService,
    GSANAInputs,
    PlanCache,
    SpMVInputs,
    run,
)
from repro.sparse import edges_to_csr, erdos_renyi_edges, laplacian_2d, partition_graph


@pytest.fixture(scope="module")
def spmv_inputs():
    a = laplacian_2d(12)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(144).astype(np.float32))
    return SpMVInputs(partition_ell(a, 8), x)


@pytest.fixture(scope="module")
def bfs_inputs():
    g = edges_to_csr(erdos_renyi_edges(8, 6, seed=2), 256)
    return BFSInputs(partition_graph(g, 8), 3)


@pytest.fixture(scope="module")
def gsana_inputs():
    vs1, vs2, pi = generate_alignment_pair(192, seed=11)
    grid = pick_grid(192, 32)
    cap = max(bucketize(vs1, grid).cap, bucketize(vs2, grid).cap)
    return GSANAInputs(
        vs1, vs2, bucketize(vs1, grid, cap=cap), bucketize(vs2, grid, cap=cap),
    )


def test_batched_results_bit_identical_to_sequential(spmv_inputs, bfs_inputs, gsana_inputs):
    """The acceptance parity: batching changes when executors compile, never
    what they compute."""
    requests = [
        ("spmv", spmv_inputs, MigratoryStrategy()),
        ("spmv", spmv_inputs, MigratoryStrategy(replicate_x=False)),
        ("bfs", bfs_inputs, MigratoryStrategy(comm=Comm.MIGRATE)),
        ("bfs", bfs_inputs, MigratoryStrategy(comm=Comm.REMOTE_WRITE)),
        ("gsana", gsana_inputs, MigratoryStrategy(scheme=Scheme.PAIR)),
    ]
    svc = EngineService()
    tickets = [svc.submit(op, inp, st) for op, inp, st in requests]
    responses = svc.drain()
    assert [r.ticket for r in responses] == tickets
    for (op, inp, st), resp in zip(requests, responses):
        seq_result, _ = run(op, inp, st, "local", iters=1, warmup=0, cache=PlanCache())
        got, want = resp.result, seq_result
        if isinstance(want, tuple):
            for g, w in zip(got, want):
                np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        else:
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_same_key_batch_compiles_once(spmv_inputs):
    svc = EngineService()
    for _ in range(4):
        svc.submit("spmv", spmv_inputs)
    a2 = laplacian_2d(8)
    x2 = jnp.asarray(np.random.default_rng(1).standard_normal(64).astype(np.float32))
    svc.submit("spmv", SpMVInputs(partition_ell(a2, 8), x2))  # second signature
    responses = svc.drain()
    assert len(responses) == 5
    stats = svc.stats()
    assert stats.compiles == 2  # one per distinct plan key
    assert stats.cache_hits == 3
    assert stats.batches == 2
    assert stats.amortization == pytest.approx(2.5)
    hits = [r.report.cache_hit for r in responses[:4]]
    assert hits == [False, True, True, True]


def test_second_drain_serves_from_warm_cache(spmv_inputs):
    svc = EngineService()
    svc.submit("spmv", spmv_inputs)
    svc.drain()
    svc.submit("spmv", spmv_inputs)
    (resp,) = svc.drain()
    assert resp.report.cache_hit
    assert svc.stats().drains == 2


def test_empty_drain_and_queue_len(spmv_inputs):
    svc = EngineService()
    assert svc.drain() == []
    svc.submit("spmv", spmv_inputs)
    assert len(svc) == 1
    svc.drain()
    assert len(svc) == 0


def test_autotune_mode_picks_model_optimal(spmv_inputs):
    svc = EngineService(autotune=True)
    svc.submit("spmv", spmv_inputs)  # no strategy given -> "auto"
    (resp,) = svc.drain()
    assert resp.report.strategy["replicate_x"] is True
    assert resp.report.traffic.migrations == 0


def test_shared_cache_pools_compiles(spmv_inputs):
    shared = PlanCache()
    run("spmv", spmv_inputs, None, "local", iters=1, warmup=0, cache=shared)
    svc = EngineService(cache=shared)
    svc.submit("spmv", spmv_inputs)
    (resp,) = svc.drain()
    assert resp.report.cache_hit  # compiled outside the service, reused inside


def test_throughput_report_schema(spmv_inputs):
    svc = EngineService()
    svc.submit("spmv", spmv_inputs)
    svc.drain()
    report = svc.throughput_report()
    for key in (
        "requests", "batches", "drains", "cache_hits", "compiles",
        "compile_seconds", "run_seconds", "wall_seconds", "busy_seconds",
        "queue_depth_hwm", "rejected", "cancelled", "errors",
        "overlap_seconds", "overlap_ratio",
        "requests_per_second", "amortization", "cache",
    ):
        assert key in report, key
    assert report["requests"] == 1
    assert report["cache"]["entries"] == 1


def test_drain_mode_wall_equals_busy(spmv_inputs):
    """Batch drains are single-stream: the drain wall is fully busy and no
    compile/execute overlap is possible."""
    svc = EngineService()
    svc.submit("spmv", spmv_inputs)
    svc.submit("spmv", spmv_inputs)
    svc.drain()
    stats = svc.stats()
    assert stats.wall_seconds > 0
    assert stats.busy_seconds == pytest.approx(stats.wall_seconds)
    assert stats.overlap_seconds == 0.0
    assert stats.overlap_ratio == 0.0
