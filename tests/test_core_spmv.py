"""Core SpMV: S1 replication strategy — correctness across strategies/grains."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (
    MigratoryStrategy, effective_bandwidth, gather_result, partition_ell, spmv,
    spmv_traffic, stripe_vector, unstripe_vector,
)
from repro.sparse import CSR, laplacian_2d, skewed_matrix, spmv_csr_ref


@pytest.mark.parametrize("replicate", [True, False])
@pytest.mark.parametrize("grain", [1, 4, 16, None])
def test_spmv_strategies_match_ref(replicate, grain):
    a = laplacian_2d(12)  # 144x144
    n = 144
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    pe = partition_ell(a, 8)
    st_ = MigratoryStrategy(replicate_x=replicate, grain=grain)
    xin = x if replicate else stripe_vector(x, 8)
    y = gather_result(spmv(pe, xin, st_), n)
    assert np.allclose(np.asarray(y), np.asarray(spmv_csr_ref(a, x)), atol=1e-4)


def test_replication_eliminates_migrations():
    """Paper §5.1: replication removes per-element cross-nodelet reads."""
    a = laplacian_2d(16)
    pe = partition_ell(a, 8)
    t_rep = spmv_traffic(pe, MigratoryStrategy(replicate_x=True))
    t_str = spmv_traffic(pe, MigratoryStrategy(replicate_x=False))
    assert t_rep.migrations == 0
    assert t_str.migrations > 0


def test_striped_vector_roundtrip():
    x = jnp.arange(37, dtype=jnp.float32)
    xs = stripe_vector(x, 8)
    assert xs.shape == (8, 5)
    assert np.allclose(np.asarray(unstripe_vector(xs, 37)), np.asarray(x))


def test_skewed_matrix_spmv():
    """High-max-degree (Table 3 pathology) still computes correctly."""
    a = skewed_matrix(400, 6.0, 120, seed=3)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(400).astype(np.float32))
    pe = partition_ell(a, 8)
    y = gather_result(spmv(pe, x, MigratoryStrategy()), 400)
    assert np.allclose(np.asarray(y), np.asarray(spmv_csr_ref(a, x)), atol=1e-3)


def test_effective_bandwidth_formula():
    a = laplacian_2d(8)
    pe = partition_ell(a, 4)
    bw = effective_bandwidth(pe, 64, seconds=1.0)
    # nnz*(4+4) + (64+64)*4 bytes
    assert bw == a.nnz * 8 + 128 * 4


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(8, 64),
    p=st.sampled_from([2, 4, 8]),
    density=st.floats(0.05, 0.5),
    replicate=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_spmv_invariant_to_strategy(n, p, density, replicate, seed):
    """Invariant: the strategy changes communication, never the result."""
    rng = np.random.default_rng(seed)
    d = (rng.random((n, n)) < density) * rng.standard_normal((n, n)).astype(np.float32)
    a = CSR.from_dense(d)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    pe = partition_ell(a, p)
    st_ = MigratoryStrategy(replicate_x=replicate, grain=rng.integers(1, 8))
    xin = x if replicate else stripe_vector(x, p)
    y = gather_result(spmv(pe, xin, st_), n)
    assert np.allclose(np.asarray(y), d @ np.asarray(x), atol=1e-3)
