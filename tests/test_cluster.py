"""Cluster plane (repro/cluster/): multi-process serving, substrate, failover.

ISSUE 9 acceptance, as tests:

- a 2-worker localhost cluster serves a mixed SpMV/BFS/MoE-dispatch stream
  **bit-identically** to in-process ``engine.run`` — the request-level wire
  path (``Coordinator.submit``), with requests actually distributed across
  both worker processes;
- ``EngineService(substrate="cluster")`` drives the PR-5 executor pool over
  process-spanning placement slots (the kernel-level path), same parity;
- SIGKILLing one worker mid-load leaves **every future terminated** and the
  retried results bit-identical (ops are pure, so replaying an in-flight
  request on a survivor is safe), with the death visible in the stats
  (failovers/retries) and the topology fingerprint (plan-cache keys must
  not alias across memberships).

The launcher/backends and the autoscaler signal (``resize_signal``) are
pinned with process-free unit tests at the bottom — they must not cost a
cluster launch to check a pod manifest or a threshold comparison.
"""
import json
import signal
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import partition_ell
from repro.engine import (
    BFSInputs,
    EngineService,
    MoEDispatchInputs,
    Request,
    ServiceStats,
    SpMVInputs,
    get_substrate,
    run,
)
from repro.sparse import edges_to_csr, erdos_renyi_edges, laplacian_2d, partition_graph


def _mixed_requests(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    spmv_pool = []
    for size in (8, 12):
        a = partition_ell(laplacian_2d(size), 4)
        x = jnp.asarray(rng.standard_normal(size * size).astype(np.float32))
        spmv_pool.append(SpMVInputs(a, x))
    g = partition_graph(edges_to_csr(erdos_renyi_edges(6, 4, seed=seed), 64), 4)
    moe = MoEDispatchInputs(
        x=jnp.asarray(rng.standard_normal((16, 8)).astype(np.float32)),
        router=jnp.asarray(rng.standard_normal((8, 4)).astype(np.float32)),
        nodelets=2,
    )
    requests = []
    for i in range(n):
        if i % 4 == 2:
            requests.append(Request("bfs", BFSInputs(g, 0)))
        elif i % 4 == 3:
            requests.append(Request("moe_dispatch", moe))
        else:
            requests.append(Request("spmv", spmv_pool[i % 2]))
    return requests


def _assert_bit_identical(a, b):
    import jax

    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# -- live 2-worker cluster (module-scoped: one launch pays for all) -----------


@pytest.fixture(scope="module")
def cluster():
    from repro.cluster import launch_cluster

    with launch_cluster(n_workers=2, service_workers=1) as c:
        yield c


def test_submit_parity_and_distribution(cluster):
    requests = _mixed_requests(12)
    futures = [cluster.submit(r) for r in requests]
    responses = [f.result(timeout=300) for f in futures]
    for request, response in zip(requests, responses):
        oracle, _ = run(request, iters=1, warmup=0)
        _assert_bit_identical(response.result, oracle)
        assert response.report is not None
    stats = cluster.stats()
    served = {w["worker_id"]: w["served"] for w in stats["workers"]}
    assert sum(served.values()) >= len(requests)
    assert sum(1 for n in served.values() if n > 0) == 2, served
    assert stats["n_healthy"] == 2
    assert stats["retries"] == 0 and stats["failovers"] == 0


def test_sticky_placement_pins_same_signature_to_one_worker(cluster):
    requests = _mixed_requests(8)
    spmv_like = [r for r in requests if r.op == "spmv"][:4]
    responses = [cluster.submit(r).result(timeout=300) for r in spmv_like]
    by_signature = {}
    for request, response in zip(spmv_like, responses):
        key = id(request.inputs.a)  # two pooled signatures alternate
        by_signature.setdefault(key, set()).add(response.worker_id)
    for workers in by_signature.values():
        assert len(workers) == 1  # a signature never bounces between workers


def test_remote_errors_propagate_and_are_not_retried(cluster):
    bad = Request("spmv", _mixed_requests(4)[2].inputs)  # BFS inputs to spmv
    before = cluster.stats()["retries"]
    from repro.cluster import RemoteOpError

    with pytest.raises(RemoteOpError):
        cluster.submit(bad).result(timeout=300)
    assert cluster.stats()["retries"] == before  # deterministic: no retry
    assert cluster.stats()["n_healthy"] == 2  # and no worker was condemned


def test_cluster_substrate_spans_processes(cluster):
    sub = get_substrate("cluster")
    assert sub.placement_slots() == 2
    fp = sub.cache_fingerprint()
    assert fp[0] == "cluster"
    generation, members = fp[1]
    assert len(members) == 2  # topology is part of every plan-cache key
    assert sub.jit_plans is False  # socket I/O must stay out of jax.jit


def test_engine_service_pool_over_cluster_substrate(cluster):
    requests = _mixed_requests(8)
    svc = EngineService(substrate="cluster", workers=2).start()
    try:
        futures = [
            svc.submit(Request(r.op, r.inputs, r.strategy, "cluster"))
            for r in requests
        ]
        responses = [f.result(timeout=300) for f in futures]
    finally:
        svc.stop()
    assert len(responses) == len(requests)
    for request, response in zip(requests, responses):
        oracle, _ = run(request, iters=1, warmup=0)
        _assert_bit_identical(response.result, oracle)
    assert cluster.stats()["kernel_calls"] > 0  # genuinely crossed processes
    stats = svc.stats()
    assert stats.workers == 2
    assert stats.resize_signal() in ("grow", "hold", "shrink")


# -- failover (own cluster: this one loses a worker) --------------------------


def test_sigkill_failover_terminates_every_future_with_parity():
    from repro.cluster import launch_cluster

    with launch_cluster(
        n_workers=2, service_workers=1, activate=False,
        heartbeat_interval=0.2, heartbeat_timeout=3.0,
    ) as cluster:
        fp_before = cluster.coordinator.topology_fingerprint()
        requests = _mixed_requests(12, seed=1)
        futures = [cluster.submit(r) for r in requests]
        victim = cluster.coordinator.healthy_workers()[0].worker_id
        cluster.kill_worker(victim, sig=signal.SIGKILL)
        responses = [f.result(timeout=300) for f in futures]  # all terminate
        for request, response in zip(requests, responses):
            oracle, _ = run(request, iters=1, warmup=0)
            _assert_bit_identical(response.result, oracle)
        stats = cluster.stats()
        assert stats["failovers"] == 1
        assert stats["n_healthy"] == 1
        dead = [w for w in stats["workers"] if w["worker_id"] == victim]
        assert dead and dead[0]["state"] == "dead"
        # survivors absorbed the victim's load; membership re-fingerprints
        # so no plan-cache entry aliases across the two topologies
        assert cluster.coordinator.topology_fingerprint() != fp_before
        survivor_served = sum(
            w["served"] for w in stats["workers"] if w["worker_id"] != victim
        )
        assert survivor_served > 0


# -- launcher backends and supervisor (no processes needed) -------------------


def test_k8s_backend_emits_pod_spec_but_does_not_schedule():
    from repro.cluster import K8sBackend, WorkerSpec

    spec = WorkerSpec(
        worker_id=3, connect=("10.0.0.7", 4242), substrate="local", token="tok",
    )
    backend = K8sBackend(image="repro-serving:v1", namespace="serving")
    pod = backend.pod_spec(spec)
    assert pod["kind"] == "Pod"
    assert pod["metadata"]["name"] == "repro-worker-3"
    assert pod["metadata"]["namespace"] == "serving"
    container = pod["spec"]["containers"][0]
    assert container["image"] == "repro-serving:v1"
    assert container["command"] == spec.argv()
    assert "--connect" in container["command"]
    assert "10.0.0.7:4242" in container["command"]
    assert {"name": "REPRO_CLUSTER_TOKEN", "value": "tok"} in container["env"]
    json.dumps(pod)  # manifest must be plain-JSON appliable
    with pytest.raises(NotImplementedError):
        backend.start(spec)


def test_process_supervisor_restart_budget():
    from repro.runtime.supervisor import ProcessSupervisor

    class Fake:
        def __init__(self):
            self.returncode = None

    spawned = []

    def restart():
        handle = Fake()
        spawned.append(handle)
        return handle

    sup = ProcessSupervisor(max_restarts=1)
    first = Fake()
    sup.watch("w", first, alive=lambda h: h.returncode is None, restart=restart)
    assert sup.poll() == []  # alive: nothing to report
    first.returncode = -9
    (event,) = sup.poll()
    assert event.restarted and event.restarts == 1
    assert sup.handles()["w"] is spawned[0]
    spawned[0].returncode = 1
    (event,) = sup.poll()
    assert not event.restarted  # budget exhausted
    assert sup.poll() == []  # idempotent on a process already seen down


def test_worker_spec_argv_is_reproducible_entrypoint():
    from repro.cluster import WorkerSpec

    argv = WorkerSpec(worker_id=0, connect=("127.0.0.1", 9000)).argv()
    assert argv[1:3] == ["-m", "repro.cluster.worker"]
    assert "--worker-id" in argv and "0" in argv


# -- resize signal (autoscaler trigger; pure threshold logic) -----------------


def _stats(occupancy, wall=10.0):
    return ServiceStats(
        requests=8, wall_seconds=wall, workers=len(occupancy),
        worker_occupancy=list(occupancy),
        occupancy_hwm=max(occupancy, default=0.0),
    )


def test_resize_signal_grow_on_saturated_pool():
    assert _stats([0.9, 0.8]).resize_signal() == "grow"
    assert _stats([0.75, 0.75]).resize_signal() == "grow"  # mean at threshold


def test_resize_signal_shrink_on_idle_pool():
    assert _stats([0.1, 0.2]).resize_signal() == "shrink"
    # a single worker never shrinks below itself
    assert _stats([0.05]).resize_signal() == "hold"


def test_resize_signal_hold_between_thresholds_and_on_empty():
    assert _stats([0.5, 0.4]).resize_signal() == "hold"
    # one busy worker keeps the pool: max occupancy above shrink line
    assert _stats([0.9, 0.05]).resize_signal() == "hold"
    assert _stats([]).resize_signal() == "hold"
    assert _stats([0.9], wall=0.0).resize_signal() == "hold"


def test_resize_signal_custom_thresholds_and_to_dict():
    stats = _stats([0.6, 0.6])
    assert stats.resize_signal(grow_above=0.5) == "grow"
    assert _stats([0.3, 0.3]).resize_signal(shrink_below=0.35) == "shrink"
    row = stats.to_dict()
    assert row["resize_signal"] == "hold"
    assert row["occupancy_hwm"] == 0.6
    assert row["worker_occupancy"] == [0.6, 0.6]
