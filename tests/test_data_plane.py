"""Zero-copy cluster data plane (PR 10): v2 framing, blob store, pipelining.

Three layers, pinned separately and then together:

- **protocol v2** (socketpair units) — envelope + out-of-band segments
  round-trip bit-identically; a mid-frame EOF *or* ``OSError`` raises
  ``ProtocolError("truncated frame...")`` instead of masquerading as a
  clean disconnect (the PR-9 ``_recv_exact`` bug); a v1-framed peer is
  refused with a version-mismatch error at the first frame; oversized
  frames raise :class:`FrameTooLarge` naming ``REPRO_MAX_FRAME_BYTES``.
- **blob store** (process-free units) — digest-verified admission (a
  corrupt shipment is refused, never stored — and the caller's own array
  is never frozen), byte-budgeted LRU eviction, ``ensure``'s
  miss-negotiation wait (woken by ``put``, failed fast — but only once —
  by ``mark_gone``).
- **coordinator units** (socketpair, no processes) — ``blob_gone`` drops
  the per-worker belief digest so later submits re-ship; writable arrays
  re-hash on every submit (no stale id()-keyed digests); the pipelined
  writer flushes an isolated submit immediately and only lingers
  ``flush_window`` on a queued burst.
- **cluster integration** (live workers) — a tiny worker-side budget
  forces evictions and the ``need_blob`` re-fetch path while results stay
  bit-identical; SIGKILL failover re-ships pinned blobs to the survivor
  and retried results stay bit-identical; a submit burst coalesces into
  ``submit_many`` frames; wire/blob counters land in the coordinator rows
  and the worker's ``ServiceStats.to_dict()``.
"""
import json
import signal
import socket
import struct
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster.blobs import (
    BlobDigestMismatch,
    BlobError,
    BlobMissing,
    BlobStore,
    blob_digest,
)
from repro.cluster.protocol import (
    Channel,
    FrameTooLarge,
    ProtocolError,
    _recv_exact,
    max_frame_bytes,
)
from repro.core import partition_ell
from repro.engine import Request, SpMVInputs, run
from repro.sparse import laplacian_2d


def _assert_bit_identical(a, b):
    import jax

    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# -- protocol v2 framing (socketpair, no processes) ---------------------------


@pytest.fixture()
def channel_pair():
    left, right = socket.socketpair()
    a, b = Channel(left), Channel(right)
    yield a, b
    a.close()
    b.close()


def test_envelope_and_segments_roundtrip(channel_pair):
    a, b = channel_pair
    payload = np.arange(1000, dtype=np.float64).tobytes()
    a.send(
        {"kind": "submit", "x": {"__wire__": "ndref", "seg": 0}},
        [payload],
    )
    message = b.recv()
    assert message["kind"] == "submit"
    assert bytes(message["x"]["data"]) == payload  # attached in place
    assert a.bytes_sent == b.bytes_received > len(payload)
    assert a.frames_sent == b.frames_received == 1


def test_multi_segment_frame_attaches_by_index(channel_pair):
    a, b = channel_pair
    segs = [bytes([i]) * (i + 1) for i in range(5)]
    refs = [{"__wire__": "ndref", "seg": i} for i in range(5)]
    a.send({"kind": "submit", "items": refs}, segs)
    message = b.recv()
    for i, node in enumerate(message["items"]):
        assert bytes(node["data"]) == segs[i]


def test_clean_eof_between_frames_returns_none(channel_pair):
    a, b = channel_pair
    a.send({"kind": "ping"})
    assert b.recv()["kind"] == "ping"
    a.close()
    assert b.recv() is None


def test_truncated_frame_raises_not_eof(channel_pair):
    """EOF after partial bytes must raise, not look like a disconnect."""
    a, b = channel_pair
    a._sock.sendall(b"\x02\x00")  # two bytes of a 13-byte prefix, then gone
    a.close()
    with pytest.raises(ProtocolError, match="truncated frame"):
        b.recv()


def test_truncated_envelope_raises(channel_pair):
    a, b = channel_pair
    header = struct.pack(">BIQ", 2, 0, 1000)  # promises 1000 envelope bytes
    a._sock.sendall(header + b'{"kind":')  # ...delivers 8
    a.close()
    with pytest.raises(ProtocolError, match="truncated frame"):
        b.recv()


def test_oserror_mid_frame_raises_truncated_frame():
    """The PR-9 bug: an OSError under a partial read returned None (clean
    EOF). It must raise — failover treats a torn frame differently."""
    left, right = socket.socketpair()
    try:
        left.sendall(b"\x02\x00\x00")  # partial prefix...
        deadline = time.monotonic() + 5.0

        def reset_soon():
            # SO_LINGER(0) makes close() send RST: the reader gets
            # ECONNRESET (an OSError), not an orderly EOF
            left.setsockopt(
                socket.SOL_SOCKET, socket.SO_LINGER,
                struct.pack("ii", 1, 0),
            )
            left.close()

        timer = threading.Timer(0.05, reset_soon)
        timer.start()
        with pytest.raises(ProtocolError, match="truncated frame"):
            right.settimeout(deadline - time.monotonic())
            _recv_exact(right, 13, at_boundary=False)
        timer.join()
    finally:
        right.close()


def test_v1_peer_is_refused_with_version_mismatch(channel_pair):
    a, b = channel_pair
    # a v1 frame: bare 8-byte big-endian length + JSON. Its first byte is
    # 0x00, which the v2 reader reads as "protocol version 0".
    body = json.dumps({"kind": "hello"}).encode()
    a._sock.sendall(struct.pack(">Q", len(body)) + body)
    with pytest.raises(ProtocolError, match="version mismatch"):
        b.recv()


def test_frame_cap_is_env_overridable(channel_pair, monkeypatch):
    a, b = channel_pair
    assert max_frame_bytes() == 1 << 30  # the new 1 GiB default
    monkeypatch.setenv("REPRO_MAX_FRAME_BYTES", "64")
    assert max_frame_bytes() == 64
    with pytest.raises(FrameTooLarge, match="REPRO_MAX_FRAME_BYTES"):
        a.send({"kind": "submit"}, [b"x" * 128])
    # receive side enforces the cap too (corrupt/hostile headers)
    monkeypatch.delenv("REPRO_MAX_FRAME_BYTES")
    a.send({"kind": "submit", "pad": "y" * 128})
    monkeypatch.setenv("REPRO_MAX_FRAME_BYTES", "64")
    with pytest.raises(FrameTooLarge, match="REPRO_MAX_FRAME_BYTES"):
        b.recv()


def test_concurrent_sends_interleave_whole_frames(channel_pair):
    a, b = channel_pair
    n_threads, per_thread = 4, 25
    seg = bytes(range(256))

    def sender(t):
        for i in range(per_thread):
            a.send(
                {"kind": "submit", "t": t, "i": i,
                 "x": {"__wire__": "ndref", "seg": 0}},
                [seg],
            )

    threads = [
        threading.Thread(target=sender, args=(t,)) for t in range(n_threads)
    ]
    for th in threads:
        th.start()
    got = [b.recv() for _ in range(n_threads * per_thread)]
    for th in threads:
        th.join()
    assert all(bytes(m["x"]["data"]) == seg for m in got)
    seen = {(m["t"], m["i"]) for m in got}
    assert len(seen) == n_threads * per_thread  # no torn/duplicated frames


# -- blob store (process-free) ------------------------------------------------


def _blob(fill, kib=1):
    return np.full(kib * 256, fill, dtype=np.float32)  # kib KiB per blob


def test_put_verifies_digest_and_refuses_corruption():
    store = BlobStore(budget_bytes=1 << 20)
    arr = _blob(1.0)
    digest = blob_digest(arr)
    store.put(digest, arr)
    np.testing.assert_array_equal(store.resolve(digest), arr)
    with pytest.raises(BlobDigestMismatch, match="refusing"):
        store.put(digest, _blob(2.0))  # claimed digest, different bytes
    assert store.stats()["blobs"] == 1  # the corrupt shipment never landed


def test_resolve_miss_raises_and_counts():
    store = BlobStore(budget_bytes=1 << 20)
    with pytest.raises(BlobMissing):
        store.resolve("no-such-digest")
    arr = _blob(3.0)
    store.put(blob_digest(arr), arr)
    store.resolve(blob_digest(arr))
    assert store.stats()["hits"] == 1


def test_lru_eviction_at_byte_budget():
    store = BlobStore(budget_bytes=3 * 1024)  # room for three 1 KiB blobs
    blobs = [_blob(float(i)) for i in range(4)]
    digests = [blob_digest(b) for b in blobs]
    for digest, arr in zip(digests[:3], blobs[:3]):
        store.put(digest, arr)
    store.get(digests[0])  # touch: 0 is now MRU, 1 is LRU
    store.put(digests[3], blobs[3])
    assert store.missing(digests) == [digests[1]]  # LRU went, touched stayed
    assert store.stats()["evictions"] == 1
    assert store.stats()["bytes_stored"] <= 3 * 1024


def test_single_over_budget_blob_is_admitted_alone():
    store = BlobStore(budget_bytes=1024)
    small = _blob(1.0)
    store.put(blob_digest(small), small)
    huge = _blob(2.0, kib=8)
    store.put(blob_digest(huge), huge)  # evicts everything else, stays
    assert blob_digest(huge) in store
    assert blob_digest(small) not in store


def test_ensure_requests_missing_once_and_wakes_on_put():
    store = BlobStore(budget_bytes=1 << 20)
    arr = _blob(7.0)
    digest = blob_digest(arr)
    asked = []

    def request_missing(missing):
        asked.append(list(missing))
        threading.Timer(0.05, lambda: store.put(digest, arr)).start()

    store.ensure([digest], request_missing, timeout=10.0)
    assert asked == [[digest]]
    assert store.stats()["misses"] == 1
    store.ensure([digest], request_missing, timeout=10.0)  # present: no ask
    assert asked == [[digest]]


def test_ensure_fails_fast_on_blob_gone_and_times_out_otherwise():
    store = BlobStore(budget_bytes=1 << 20)

    def mark(missing):
        threading.Timer(0.05, lambda: store.mark_gone(missing[0])).start()

    with pytest.raises(BlobError, match="gone"):
        store.ensure(["dead-digest"], mark, timeout=10.0)
    with pytest.raises(BlobError, match="timed out"):
        store.ensure(["slow-digest"], lambda missing: None, timeout=0.1)


def test_stored_blobs_are_read_only():
    store = BlobStore(budget_bytes=1 << 20)
    arr = _blob(4.0)
    stored = store.put(blob_digest(arr), arr)
    with pytest.raises(ValueError):
        stored[0] = 99.0  # a shared blob must never be mutated in place


def test_put_never_freezes_the_callers_array():
    """Admitting a C-contiguous owndata array (the coordinator sink path,
    ``verify=False``) must freeze a private view, not the caller's own
    object — in-place weight updates between submits must keep working."""
    store = BlobStore(budget_bytes=1 << 20)
    arr = _blob(5.0)
    stored = store.put(blob_digest(arr), arr, verify=False)
    assert arr.flags.writeable, "put() froze the caller's own array"
    arr[0] = 99.0  # must not raise "assignment destination is read-only"
    with pytest.raises(ValueError):
        stored[1] = 1.0  # ...while the stored entry stays read-only


def test_blob_gone_tombstone_is_transient():
    """``blob_gone`` fails the waits that saw it and is then forgotten —
    a later submit re-pins the blob coordinator-side, so a later ensure()
    must be allowed to re-ask instead of failing instantly forever."""
    store = BlobStore(budget_bytes=1 << 20)
    arr = _blob(6.0)
    digest = blob_digest(arr)

    def mark(missing):
        threading.Timer(0.02, lambda: store.mark_gone(digest)).start()

    with pytest.raises(BlobError, match="gone"):
        store.ensure([digest], mark, timeout=10.0)

    def ship(missing):
        threading.Timer(0.02, lambda: store.put(digest, arr)).start()

    store.ensure([digest], ship, timeout=10.0)  # no stale tombstone
    np.testing.assert_array_equal(store.resolve(digest), arr)


# -- coordinator units (socketpair, no processes) -----------------------------


@pytest.fixture()
def coordinator_worker():
    from repro.cluster.coordinator import Coordinator, WorkerHandle

    left, right = socket.socketpair()
    right.settimeout(10.0)
    coordinator = Coordinator(flush_window=1.0)
    worker = WorkerHandle(1, Channel(left), {"pid": 0})
    coordinator._workers[1] = worker
    peer = Channel(right)
    yield coordinator, worker, peer
    worker.send_queue.put(None)
    worker.channel.close()
    peer.close()


def test_blob_gone_forgets_the_coordinator_belief(coordinator_worker):
    """Answering ``blob_gone`` must drop the digest from the worker's
    belief set, so the next submit referencing it re-ships the bytes
    instead of trusting a pin the coordinator just failed to honor."""
    coordinator, worker, peer = coordinator_worker
    worker.blob_digests.add("deadbeef")
    coordinator._on_message(
        worker, {"kind": "need_blob", "digests": ["deadbeef"]}
    )
    assert peer.recv() == {"kind": "blob_gone", "digest": "deadbeef"}
    assert "deadbeef" not in worker.blob_digests


def test_writable_arrays_rehash_on_resubmit():
    """A writable array mutated in place and resubmitted must ship its
    *new* bytes: the id()-keyed digest memo only covers read-only buffers
    (frozen numpy / immutable jax arrays)."""
    from repro.cluster.coordinator import Coordinator
    from repro.engine.wire import content_digest

    coordinator = Coordinator()
    arr = np.arange(512, dtype=np.float64)
    first = coordinator._array_digest(arr, arr)
    arr[0] = -1.0
    second = coordinator._array_digest(arr, arr)
    assert first != second and second == content_digest(arr)
    assert id(arr) not in coordinator._digest_cache
    frozen = np.arange(512, dtype=np.float64)
    frozen.setflags(write=False)
    assert (
        coordinator._array_digest(frozen, frozen)
        == coordinator._array_digest(frozen, frozen)
    )
    assert id(frozen) in coordinator._digest_cache


def test_isolated_submit_flushes_without_window_latency(coordinator_worker):
    """An isolated submit must go out immediately — the 1 s flush window
    only lingers when a burst is already queued."""
    coordinator, worker, peer = coordinator_worker
    writer = threading.Thread(
        target=coordinator._writer_loop, args=(worker,), daemon=True
    )
    writer.start()
    start = time.monotonic()
    worker.send_queue.put(({"kind": "submit", "ticket": 1}, []))
    message = peer.recv()
    elapsed = time.monotonic() - start
    assert message["kind"] == "submit" and message["ticket"] == 1
    assert elapsed < 0.5, f"isolated submit waited {elapsed:.3f}s on the window"


def test_queued_burst_still_coalesces_into_submit_many(coordinator_worker):
    coordinator, worker, peer = coordinator_worker
    coordinator.flush_window = 0.01
    for ticket in range(3):  # queued before the writer even starts
        worker.send_queue.put(({"kind": "submit", "ticket": ticket}, []))
    writer = threading.Thread(
        target=coordinator._writer_loop, args=(worker,), daemon=True
    )
    writer.start()
    message = peer.recv()
    assert message["kind"] == "submit_many"
    assert [item["ticket"] for item in message["items"]] == [0, 1, 2]
    deadline = time.monotonic() + 5.0  # counter lands just after the send
    while coordinator._submits_coalesced < 3 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert coordinator._submits_coalesced == 3


# -- cluster integration (live workers) ---------------------------------------


def _large_requests(n, grids=(48,), seed=3):
    """Requests sharing the ``grids``' large operands round-robin, with a
    fresh small vector each — the blobref traffic shape. grid=48 puts
    cols/vals (~45 KiB each) above the test-time 16 KiB blob threshold.
    Distinct grid sizes give genuinely distinct blob digests — identical
    laplacians would dedup to one blob pair under content addressing."""
    rng = np.random.default_rng(seed)
    mats = [partition_ell(laplacian_2d(g), 8) for g in grids]
    return [
        Request(
            "spmv",
            SpMVInputs(
                mats[i % len(grids)],
                jnp.asarray(
                    rng.standard_normal(
                        grids[i % len(grids)] ** 2
                    ).astype(np.float32)
                ),
            ),
        )
        for i in range(n)
    ]


@pytest.fixture(scope="module")
def dp_cluster(tmp_path_factory):
    """One 2-worker cluster for the data-plane tests: a deliberately tiny
    worker-side blob budget (holds any single matrix's cols/vals pair but
    never two pairs, inherited via the environment) and a low blob
    threshold so eviction + need_blob actually happen at test sizes."""
    import os

    from repro.cluster import launch_cluster

    os.environ["REPRO_BLOB_BUDGET_BYTES"] = str(160 * 1024)
    try:
        with launch_cluster(
            n_workers=2, service_workers=1, activate=False,
            blob_min_bytes=16 * 1024, flush_window=0.01,
        ) as c:
            yield c
    finally:
        os.environ.pop("REPRO_BLOB_BUDGET_BYTES", None)


def test_blobs_ship_once_then_serve_by_reference(dp_cluster):
    requests = _large_requests(6)
    before = dp_cluster.stats()
    responses = [
        f.result(timeout=300)
        for f in [dp_cluster.submit(r) for r in requests]
    ]
    for request, response in zip(requests, responses):
        oracle, _ = run(request, iters=1, warmup=0)
        _assert_bit_identical(response.result, oracle)
    stats = dp_cluster.stats()
    # the shared operand's two arrays shipped at most once per worker...
    assert stats["blob_misses"] - before["blob_misses"] <= 2 * 2
    # ...and later submits referenced them by digest
    assert stats["blob_hits"] - before["blob_hits"] > 0


def test_eviction_triggers_need_blob_refetch_with_parity(dp_cluster):
    # 3 distinct matrices x 2 blobs x 45-61 KiB ≈ 320 KiB of distinct
    # blobs vs a 160 KiB worker budget (one pair fits, two never do):
    # serving the stream *requires* eviction, and revisiting an evicted
    # matrix *requires* a need_blob re-fetch. Sequential submits keep the
    # evict/re-fetch cycle deterministic (no mid-decode eviction races).
    requests = _large_requests(12, grids=(48, 52, 56), seed=5)
    responses = [dp_cluster.submit(r).result(timeout=300) for r in requests]
    for request, response in zip(requests, responses):
        oracle, _ = run(request, iters=1, warmup=0)
        _assert_bit_identical(response.result, oracle)
    worker_rows = [
        dp_cluster.coordinator.worker_stats(w["worker_id"])
        for w in dp_cluster.stats()["workers"] if w["state"] == "healthy"
    ]
    evictions = sum(r["blob_store"]["evictions"] for r in worker_rows)
    refetches = sum(r["blob_misses"] for r in worker_rows)
    assert evictions > 0, "budget never forced an eviction"
    assert refetches > 0, "no worker ever re-fetched via need_blob"


def test_submit_burst_coalesces_into_submit_many(dp_cluster):
    before = dp_cluster.stats()
    requests = _large_requests(8, seed=9)
    responses = [
        f.result(timeout=300)
        for f in [dp_cluster.submit(r) for r in requests]
    ]
    assert len(responses) == len(requests)
    stats = dp_cluster.stats()
    assert stats["submits_coalesced"] > before["submits_coalesced"], (
        "a same-worker burst under flush_window never produced submit_many"
    )
    for request, response in zip(requests, responses):
        oracle, _ = run(request, iters=1, warmup=0)
        _assert_bit_identical(response.result, oracle)


def test_wire_counters_reach_coordinator_rows_and_service_stats(dp_cluster):
    dp_cluster.submit(_large_requests(1)[0]).result(timeout=300)
    stats = dp_cluster.stats()
    assert stats["wire_bytes_sent"] > 0 and stats["wire_bytes_received"] > 0
    for row in stats["workers"]:
        for key in ("bytes_sent", "bytes_received", "blob_hits",
                    "blob_misses", "frames_sent", "frames_received"):
            assert key in row, key
    worker_row = dp_cluster.coordinator.worker_stats(
        stats["workers"][0]["worker_id"]
    )
    # the worker merges transport + blob-store counters into its
    # ServiceStats.to_dict() row (ISSUE 10 observability satellite)
    assert worker_row["wire_bytes_sent"] > 0
    assert worker_row["wire_bytes_received"] > 0
    assert "blob_hits" in worker_row and "blob_misses" in worker_row
    assert worker_row["blob_store"]["blobs"] >= 0


def test_sigkill_failover_reships_blobs_and_stays_bit_identical():
    from repro.cluster import launch_cluster

    with launch_cluster(
        n_workers=2, service_workers=1, activate=False,
        heartbeat_interval=0.2, heartbeat_timeout=3.0,
        blob_min_bytes=16 * 1024,
    ) as cluster:
        requests = _large_requests(10, seed=11)
        # warm the pinned worker (and its blob belief set), then kill it
        # with a burst in flight: retries must re-ship the pinned blobs to
        # the survivor before replaying
        first = cluster.submit(requests[0]).result(timeout=300)
        victim = first.worker_id
        futures = [cluster.submit(r) for r in requests[1:]]
        cluster.kill_worker(victim, sig=signal.SIGKILL)
        responses = [f.result(timeout=300) for f in futures]
        for request, response in zip(requests[1:], responses):
            oracle, _ = run(request, iters=1, warmup=0)
            _assert_bit_identical(response.result, oracle)
        stats = cluster.stats()
        assert stats["failovers"] == 1 and stats["n_healthy"] == 1
        survivor = [
            w for w in stats["workers"]
            if w["worker_id"] != victim and w["state"] == "healthy"
        ]
        assert survivor and survivor[0]["served"] > 0
        # the survivor holds the re-shipped blobs (belief set non-empty)
        assert survivor[0]["blobs_shipped"] > 0


def test_service_stats_has_data_plane_fields_in_process():
    from repro.engine import ServiceStats

    row = ServiceStats().to_dict()
    for key in ("wire_bytes_sent", "wire_bytes_received", "blob_hits",
                "blob_misses"):
        assert row[key] == 0  # present, zero when no cluster is involved
