"""Async EngineService: worker-loop parity under concurrent submission,
admission control, QoS scheduling, lifecycle, and the wall/busy/overlap
stats schema.

ISSUE 3 acceptance: concurrent submissions across all 3 ops return
bit-identical results to sequential ``engine.run`` regardless of submission
order; bounded queues reject deterministically; shutdown with pending work
is clean.
"""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Comm, MigratoryStrategy, Scheme, bucketize, \
    generate_alignment_pair, partition_ell, pick_grid
from repro.engine import (
    AdmissionError,
    BFSInputs,
    EngineService,
    GSANAInputs,
    PlanCache,
    ServiceFuture,
    ServiceStopped,
    SpMVInputs,
    run,
)
from repro.engine.service import ServiceRequest, _WorkItem
from repro.sparse import edges_to_csr, erdos_renyi_edges, laplacian_2d, partition_graph


@pytest.fixture(scope="module")
def spmv_inputs():
    a = laplacian_2d(12)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(144).astype(np.float32))
    return SpMVInputs(partition_ell(a, 8), x)


@pytest.fixture(scope="module")
def bfs_inputs():
    g = edges_to_csr(erdos_renyi_edges(8, 6, seed=2), 256)
    return BFSInputs(partition_graph(g, 8), 3)


@pytest.fixture(scope="module")
def gsana_inputs():
    vs1, vs2, pi = generate_alignment_pair(192, seed=11)
    grid = pick_grid(192, 32)
    cap = max(bucketize(vs1, grid).cap, bucketize(vs2, grid).cap)
    return GSANAInputs(
        vs1, vs2, bucketize(vs1, grid, cap=cap), bucketize(vs2, grid, cap=cap),
    )


def _signatures(spmv_inputs, bfs_inputs, gsana_inputs):
    """The mixed-op request signatures every async test rotates over."""
    return [
        ("spmv", spmv_inputs, MigratoryStrategy()),
        ("spmv", spmv_inputs, MigratoryStrategy(replicate_x=False)),
        ("bfs", bfs_inputs, MigratoryStrategy(comm=Comm.MIGRATE)),
        ("bfs", bfs_inputs, MigratoryStrategy(comm=Comm.REMOTE_WRITE)),
        ("gsana", gsana_inputs, MigratoryStrategy(scheme=Scheme.PAIR)),
    ]


def _assert_same_result(got, want):
    if isinstance(want, tuple):
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    else:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_concurrent_mixed_submissions_bit_identical(
    spmv_inputs, bfs_inputs, gsana_inputs
):
    """The acceptance parity: many threads submitting mixed SpMV/BFS/GSANA
    concurrently get bit-identical results to sequential engine.run, in any
    submission order."""
    signatures = _signatures(spmv_inputs, bfs_inputs, gsana_inputs)
    requests = [signatures[i % len(signatures)] for i in range(20)]
    svc = EngineService()
    svc.start()
    futures: dict[int, ServiceFuture] = {}

    def submitter(idx_chunk):
        for idx in idx_chunk:
            op, inputs, st = requests[idx]
            futures[idx] = svc.submit(op, inputs, st)

    # 4 threads, interleaved index chunks -> scrambled submission order
    threads = [
        threading.Thread(target=submitter, args=(range(t, len(requests), 4),))
        for t in range(4)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    responses = {idx: fut.result(timeout=600) for idx, fut in futures.items()}
    svc.stop()

    seq_cache = PlanCache()
    expected = {}
    for op, inputs, st in signatures:
        result, _ = run(op, inputs, st, "local", iters=1, warmup=0, cache=seq_cache)
        expected[(op, id(inputs), st)] = result
    for idx, (op, inputs, st) in enumerate(requests):
        _assert_same_result(responses[idx].result, expected[(op, id(inputs), st)])

    stats = svc.stats()
    assert stats.requests == len(requests)
    assert stats.compiles == len(signatures)  # one compile per plan key
    assert stats.cache_hits == len(requests) - len(signatures)
    assert stats.errors == 0 and stats.rejected == 0


def test_futures_resolve_and_len_drops(spmv_inputs):
    svc = EngineService()
    svc.start()
    fut = svc.submit("spmv", spmv_inputs)
    assert isinstance(fut, ServiceFuture)
    resp = fut.result(timeout=300)
    assert fut.done() and fut.exception() is None
    assert resp.ticket == fut.ticket
    svc.flush(timeout=60)
    assert len(svc) == 0
    svc.stop()


def test_admission_reject_bounded_queue(spmv_inputs):
    """Deterministic rejection: batch mode never consumes, so the third
    submit must bounce off the depth-2 queue."""
    svc = EngineService(max_queue_depth=2, admission="reject")
    svc.submit("spmv", spmv_inputs)
    svc.submit("spmv", spmv_inputs)
    with pytest.raises(AdmissionError, match="reject"):
        svc.submit("spmv", spmv_inputs)
    stats = svc.stats()
    assert stats.rejected == 1
    assert stats.queue_depth_hwm == 2
    assert len(svc.drain()) == 2


def test_admission_block_without_worker_raises(spmv_inputs):
    """'block' with no worker would deadlock, so it degrades to a
    rejection that tells the caller to start()."""
    svc = EngineService(max_queue_depth=1, admission="block")
    svc.submit("spmv", spmv_inputs)
    with pytest.raises(AdmissionError, match="start"):
        svc.submit("spmv", spmv_inputs)
    svc.drain()


def test_admission_block_backpressure_serves_everything(spmv_inputs):
    """With a running worker, 'block' applies backpressure instead of
    dropping: every submission eventually lands."""
    svc = EngineService(max_queue_depth=1, admission="block")
    svc.start()
    futures = [svc.submit("spmv", spmv_inputs) for _ in range(6)]
    responses = [f.result(timeout=300) for f in futures]
    svc.stop()
    stats = svc.stats()
    assert len(responses) == 6
    assert stats.rejected == 0
    assert stats.queue_depth_hwm == 1  # the bound held


def test_stop_drains_pending_work(spmv_inputs, bfs_inputs):
    """Clean shutdown with pending work: stop(drain=True) serves everything
    already admitted before the workers exit."""
    svc = EngineService(batch_window=0.2)
    svc.start()
    futures = [
        svc.submit(*(("bfs", bfs_inputs) if i % 3 == 2 else ("spmv", spmv_inputs)))
        for i in range(9)
    ]
    svc.stop()  # drain=True default; returns only after the queue is served
    assert all(f.done() for f in futures)
    assert all(f.exception() is None for f in futures)
    assert svc.stats().requests == 9
    with pytest.raises(ServiceStopped):
        svc.submit("spmv", spmv_inputs)


def test_stop_nodrain_cancels_queued(spmv_inputs):
    """stop(drain=False) rejects still-queued futures with ServiceStopped
    instead of hanging them."""
    svc = EngineService(batch_window=0.5)  # worker sleeps before snapshotting
    svc.start()
    futures = [svc.submit("spmv", spmv_inputs) for _ in range(6)]
    svc.stop(drain=False)  # cancels while the worker is still in its window
    assert all(f.done() for f in futures)
    cancelled = [f for f in futures if isinstance(f.exception(), ServiceStopped)]
    assert len(cancelled) == svc.stats().cancelled
    assert len(cancelled) >= 1
    with pytest.raises(ServiceStopped):
        cancelled[0].result(timeout=1)


def test_restart_after_stop(spmv_inputs):
    svc = EngineService()
    svc.start()
    svc.submit("spmv", spmv_inputs).result(timeout=300)
    svc.stop()
    svc.start()  # restartable
    resp = svc.submit("spmv", spmv_inputs).result(timeout=300)
    assert resp.report.cache_hit  # same service cache across restarts
    svc.stop()


def test_drain_is_batch_mode_only(spmv_inputs):
    svc = EngineService()
    svc.start()
    with pytest.raises(RuntimeError, match="batch-mode"):
        svc.drain()
    svc.stop()


def test_start_with_pending_batch_requests_raises(spmv_inputs):
    svc = EngineService()
    svc.submit("spmv", spmv_inputs)
    with pytest.raises(RuntimeError, match="drain"):
        svc.start()
    svc.drain()


def test_bad_knobs_fail_at_construction():
    """Misconfiguration must raise in the constructor, not inside the
    worker thread where it would strand futures."""
    with pytest.raises(ValueError):
        EngineService(qos={"bfs": "high"})
    with pytest.raises(ValueError, match="admission"):
        EngineService(admission="drop")


def test_qos_orders_groups(spmv_inputs, bfs_inputs):
    """Higher QoS weight schedules a later-submitted group first; arrival
    order breaks ties."""
    svc = EngineService(qos={"bfs": 2.0})
    items = [
        _WorkItem(
            ServiceRequest(t, op, inputs, MigratoryStrategy(), "local"),
            ServiceFuture(t),
        )
        for t, (op, inputs) in enumerate(
            [("spmv", spmv_inputs), ("bfs", bfs_inputs), ("spmv", spmv_inputs)]
        )
    ]
    groups = svc._plan_groups(items)
    assert [g[0].op.name for g in groups] == ["bfs", "spmv"]
    assert [item.request.ticket for item in groups[1]] == [0, 2]


def test_worker_stats_wall_busy_overlap_schema(spmv_inputs, bfs_inputs):
    """wall_seconds is meaningful in worker mode (admission -> completion
    window), busy_seconds is the union of stage spans inside it, and the
    to_dict schema carries every documented field."""
    svc = EngineService(batch_window=0.05)
    svc.start()
    futures = [
        svc.submit(*(("bfs", bfs_inputs) if i % 2 else ("spmv", spmv_inputs)))
        for i in range(8)
    ]
    for f in futures:
        f.result(timeout=300)
    svc.stop()
    stats = svc.stats()
    assert stats.wall_seconds > 0
    assert 0 < stats.busy_seconds <= stats.wall_seconds + 1e-6
    assert stats.overlap_seconds >= 0.0
    assert stats.overlap_ratio >= 0.0
    row = stats.to_dict()
    for key in (
        "requests", "batches", "drains", "cache_hits", "compiles",
        "compile_seconds", "run_seconds", "wall_seconds", "busy_seconds",
        "queue_depth_hwm", "rejected", "cancelled", "errors",
        "overlap_seconds", "overlap_ratio", "requests_per_second",
        "amortization",
    ):
        assert key in row, key


def test_request_error_resolves_future_not_pipeline(spmv_inputs):
    """A bad request rejects its own future; the pipeline keeps serving."""
    svc = EngineService()
    svc.start()
    bad = svc.submit("no-such-op", spmv_inputs)
    good = svc.submit("spmv", spmv_inputs)
    with pytest.raises(ValueError, match="unknown op"):
        bad.result(timeout=300)
    assert good.result(timeout=300).report.op == "spmv"
    svc.stop()
    assert svc.stats().errors == 1


# -- latency percentiles + value-keyed dedup (ISSUE 4 satellites) --------------


def test_latency_percentile_schema_and_ordering(spmv_inputs, bfs_inputs):
    """Per-request queue-wait and service-time percentiles are measured in
    worker mode, ordered (p50 <= p95 <= p99), and present in to_dict."""
    svc = EngineService(batch_window=0.02)
    svc.start()
    futures = [
        svc.submit(*(("bfs", bfs_inputs) if i % 2 else ("spmv", spmv_inputs)))
        for i in range(8)
    ]
    for f in futures:
        f.result(timeout=300)
    svc.stop()
    stats = svc.stats()
    assert 0.0 <= stats.queue_wait_p50 <= stats.queue_wait_p95 <= stats.queue_wait_p99
    assert 0.0 < stats.service_p50 <= stats.service_p95 <= stats.service_p99
    # the batch window forces every request to wait for the snapshot
    assert stats.queue_wait_p50 > 0.0
    row = stats.to_dict()
    for key in (
        "queue_wait_p50", "queue_wait_p95", "queue_wait_p99",
        "service_p50", "service_p95", "service_p99", "dedup_hits",
    ):
        assert key in row, key


def test_percentiles_measured_in_batch_mode_too(spmv_inputs):
    svc = EngineService()
    svc.submit("spmv", spmv_inputs)
    svc.submit("spmv", spmv_inputs)
    svc.drain()
    stats = svc.stats()
    assert stats.service_p50 > 0.0
    assert stats.queue_wait_p50 >= 0.0


def test_dedup_serves_worker_repeats_without_reexecution(spmv_inputs, bfs_inputs):
    """Identical input values -> the response cache answers instead of the
    pipeline; different values/ops still execute; results stay bit-identical
    to sequential engine.run."""
    want_spmv, _ = run("spmv", spmv_inputs, MigratoryStrategy(), "local")
    svc = EngineService(cache=PlanCache(), dedup=True)
    svc.start()
    try:
        first = svc.submit("spmv", spmv_inputs).result(timeout=300)
        repeats = [svc.submit("spmv", spmv_inputs) for _ in range(5)]
        other = svc.submit("bfs", bfs_inputs)
        responses = [f.result(timeout=300) for f in repeats]
        other.result(timeout=300)
    finally:
        svc.stop()
    stats = svc.stats()
    assert stats.dedup_hits == 5  # every repeat after the completed first
    assert stats.requests == 7
    for resp in [first, *responses]:
        _assert_same_result(resp.result, want_spmv)
    # distinct tickets even when served from the dedup store
    assert len({r.ticket for r in [first, *responses]}) == 6


def test_dedup_in_batch_drain_and_strategy_distinguishes(spmv_inputs):
    """Batch drains dedup within and across drains; a different strategy is
    a different value key (it changes the computation)."""
    svc = EngineService(cache=PlanCache(), dedup=True)
    for _ in range(3):
        svc.submit("spmv", spmv_inputs)
    svc.submit("spmv", spmv_inputs, MigratoryStrategy(replicate_x=False))
    responses = svc.drain()
    assert len(responses) == 4
    assert svc.stats().dedup_hits == 2  # repeats 2 and 3 of the default-strategy run
    svc.submit("spmv", spmv_inputs)
    svc.drain()
    assert svc.stats().dedup_hits == 3  # served across drains too


def test_dedup_disabled_by_default(spmv_inputs):
    svc = EngineService(cache=PlanCache())
    for _ in range(3):
        svc.submit("spmv", spmv_inputs)
    svc.drain()
    assert svc.stats().dedup_hits == 0


def test_inflight_coalescing_attaches_waiters(spmv_inputs):
    """ISSUE 5 satellite: concurrent identical requests coalesce onto the
    *pending* primary's future instead of waiting for it to complete —
    counted in both dedup_hits and the dedup_coalesced breakdown, with
    distinct tickets and the primary's exact result."""
    want, _ = run("spmv", spmv_inputs, MigratoryStrategy(), "local")
    # the batch window holds the primary in the queue long enough for the
    # duplicates to arrive while it is demonstrably still in flight
    svc = EngineService(cache=PlanCache(), dedup=True, batch_window=0.25)
    svc.start()
    try:
        primary = svc.submit("spmv", spmv_inputs)
        dups = [svc.submit("spmv", spmv_inputs) for _ in range(4)]
        assert not primary.done()  # still inside the batch window
        responses = [f.result(timeout=300) for f in [primary, *dups]]
    finally:
        svc.stop()
    stats = svc.stats()
    assert stats.dedup_coalesced == 4
    assert stats.dedup_hits == 4  # all in-flight; none waited for completion
    assert stats.requests == 5
    assert stats.compiles + stats.cache_hits == 1  # the primary executed once
    for resp in responses:
        _assert_same_result(resp.result, want)
    assert len({r.ticket for r in responses}) == 5
    report = responses[0].report
    assert all(r.report is report for r in responses[1:])  # shared execution


def test_coalesced_waiters_fail_with_their_primary(spmv_inputs):
    """A waiter asked for the same computation as its primary: if the
    primary fails, the waiters fail with the same exception (never hang)."""
    svc = EngineService(cache=PlanCache(), dedup=True, batch_window=0.25)
    svc.start()
    try:
        primary = svc.submit("spmv", "not-spmv-inputs")
        dups = [svc.submit("spmv", "not-spmv-inputs") for _ in range(3)]
        excs = [f.exception(timeout=300) for f in [primary, *dups]]
    finally:
        svc.stop()
    assert all(e is not None for e in excs)
    assert all(type(e) is type(excs[0]) for e in excs)
    assert svc.stats().errors == 4


def test_stop_nodrain_terminates_every_future(spmv_inputs, bfs_inputs):
    """ISSUE 5 satellite (regression): stop(drain=False) racing mid-flight
    groups across the pool must leave every submitted future terminated —
    resolved, errored, or cancelled with ServiceStopped; never stranded.
    Repeated at several stop points to catch scheduler/worker races."""
    for delay in (0.0, 0.02, 0.08):
        svc = EngineService(
            cache=PlanCache(), workers=4, dedup=True, batch_window=0.05
        )
        svc.start()
        futures = [
            svc.submit(*(("bfs", bfs_inputs) if i % 3 == 2 else ("spmv", spmv_inputs)))
            for i in range(24)
        ]
        if delay:
            threading.Event().wait(delay)
        svc.stop(drain=False)
        undone = [f for f in futures if not f.done()]
        assert not undone, f"stranded futures at delay={delay}: {undone}"
        stats = svc.stats()
        served = sum(1 for f in futures if f.exception() is None)
        cancelled = sum(
            1 for f in futures if isinstance(f.exception(), ServiceStopped)
        )
        assert served + cancelled == len(futures)
        assert stats.cancelled >= cancelled  # waiters may add to the count
        assert len(svc) == 0  # no phantom in-flight accounting survives stop


def test_dedup_hash_distinguishes_large_array_values(spmv_inputs):
    """Regression: op input containers are unregistered-pytree dataclasses,
    and a repr-based hash truncates large arrays — two inputs differing in
    one interior element must NOT collide."""
    import jax.numpy as jnp
    from repro.engine import MoEDispatchInputs
    from repro.engine.service import _content_hash

    rng = np.random.default_rng(0)
    x = rng.standard_normal((256, 8)).astype(np.float32)
    router = rng.standard_normal((8, 16)).astype(np.float32)
    a = MoEDispatchInputs(x=jnp.asarray(x), router=jnp.asarray(router))
    x2 = x.copy()
    x2[100, 3] += 5.0  # deep inside the repr-elided region
    b = MoEDispatchInputs(x=jnp.asarray(x2), router=jnp.asarray(router))
    ha = _content_hash("moe_dispatch", a, None, "local")
    hb = _content_hash("moe_dispatch", b, None, "local")
    assert ha != hb
    assert ha == _content_hash("moe_dispatch", a, None, "local")  # stable
    # and end-to-end: the dedup service executes both, bitwise-distinct
    svc = EngineService(cache=PlanCache(), dedup=True)
    svc.submit("moe_dispatch", a)
    svc.submit("moe_dispatch", b)
    ra, rb = svc.drain()
    assert svc.stats().dedup_hits == 0
    assert not np.array_equal(np.asarray(ra.result), np.asarray(rb.result))
