"""End-to-end MoE decode serving (ISSUE 8): real expert FFNs behind
``moe_dispatch`` transport, the ``moe_decode`` op through the engine, and
``DecodeServer``'s continuous batching — every route bit-identical to the
single-process oracle across all three dispatch modes and a staggered
join/leave schedule. Mesh parity for the decode step runs in a subprocess
with 8 forced host devices (``@pytest.mark.slow``), mirroring the
``moe_dispatch`` parity test in test_registry.py.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Comm, MigratoryStrategy
from repro.engine import (
    DecodeServer,
    EngineService,
    MoEDecodeInputs,
    MoEDispatchInputs,
    PlanCache,
    Request,
    moe_decode_reference,
    moe_decode_traffic,
    run,
)
from repro.models.config import ModelConfig
from repro.models.moe import expert_ffn, moe_params
from repro.models.transformer import moe_decode_params

EP_PULL = MigratoryStrategy(comm=Comm.MIGRATE)
EP_PUSH = MigratoryStrategy(comm=Comm.REMOTE_WRITE)

# (label, strategy, nodelets): serve-moe has 8 experts, so nodelets=4 gives
# the two expert-parallel modes and nodelets=1 the tp replication fallback
MODES = (("ep_pull", EP_PULL, 4), ("ep_push", EP_PUSH, 4), ("tp", None, 1))


@pytest.fixture(scope="module")
def cfg():
    return get_config("serve-moe")


@pytest.fixture(scope="module")
def params(cfg):
    return moe_decode_params(cfg, jax.random.PRNGKey(0))


# -- expert FFNs ride the dispatch transport -----------------------------------


def test_dispatch_applies_expert_ffn_identically_across_modes():
    """With expert weights attached, all three transports compute the same
    expert outputs at no-drop capacity — the FFN runs where the tokens land,
    and where they land never changes what they compute."""
    mcfg = ModelConfig(
        name="t", family="moe", num_layers=1, d_model=16, num_heads=1,
        num_kv_heads=1, d_ff=32, vocab_size=64, num_experts=8,
        experts_per_token=2, moe_d_ff=24, dtype="float32", remat=False,
    )
    mp = moe_params(mcfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))
    common = dict(
        x=x, router=mp["router"], w_gate=mp["w_gate"], w_up=mp["w_up"],
        w_down=mp["w_down"], experts_per_token=2, capacity_factor=8.0,
    )
    outs = {}
    for label, st, nod in MODES:
        inputs = MoEDispatchInputs(nodelets=nod, **common)
        y, rep = run(
            Request("moe_dispatch", inputs, st, "local"),
            iters=1, warmup=0, cache=PlanCache(),
        )
        assert rep.metrics["expert_ffn"] is True
        outs[label] = np.asarray(y)
        # the FFN actually ran: identity transport would return gated x
        assert not np.allclose(outs[label], 0.0)
    np.testing.assert_array_equal(outs["ep_pull"], outs["tp"])
    np.testing.assert_array_equal(outs["ep_push"], outs["tp"])


def test_expert_ffn_wrapper_keeps_zero_rows_zero():
    """The public wrapper the engine shares with the LM layer: padded
    capacity slots (zero rows) must stay exactly zero through the SwiGLU."""
    mcfg_params = moe_params(
        ModelConfig(
            name="t2", family="moe", num_layers=1, d_model=8, num_heads=1,
            num_kv_heads=1, d_ff=16, vocab_size=32, num_experts=4,
            experts_per_token=2, moe_d_ff=12, dtype="float32", remat=False,
        ),
        jax.random.PRNGKey(3),
    )
    ffn = {k: mcfg_params[k] for k in ("w_gate", "w_up", "w_down")}
    xs = jnp.zeros((4, 3, 8), jnp.float32)
    np.testing.assert_array_equal(np.asarray(expert_ffn(ffn, xs)), 0.0)


def test_dispatch_rejects_partial_expert_weights():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((16, 8)).astype(np.float32))
    router = jnp.asarray(rng.standard_normal((8, 4)).astype(np.float32))
    w = jnp.zeros((4, 8, 12), jnp.float32)
    inputs = MoEDispatchInputs(x=x, router=router, w_gate=w)  # missing up/down
    with pytest.raises(ValueError, match="all-or-none"):
        run(Request("moe_dispatch", inputs), iters=1, warmup=0, cache=PlanCache())


# -- moe_decode through the engine ---------------------------------------------


def _decode_inputs(cfg, params, batch=8, seq=16, seed=0, nodelets=4):
    rng = np.random.default_rng(seed)
    d = cfg.d_model
    return MoEDecodeInputs(
        params=params,
        tokens=jnp.asarray(rng.integers(1, cfg.vocab_size, batch), jnp.int32),
        k_cache=jnp.zeros((batch, seq, d), jnp.float32),
        v_cache=jnp.zeros((batch, seq, d), jnp.float32),
        positions=jnp.zeros((batch,), jnp.int32),
        nodelets=nodelets,
        experts_per_token=cfg.experts_per_token,
        capacity_factor=cfg.capacity_factor,
    )


@pytest.mark.parametrize("label,strategy,nodelets", MODES)
def test_moe_decode_engine_matches_oracle(cfg, params, label, strategy, nodelets):
    """The acceptance parity at the op level: one decode step served through
    the engine is bit-identical to the direct single-process reference."""
    inputs = _decode_inputs(cfg, params, nodelets=nodelets)
    out, rep = run(
        Request("moe_decode", inputs, strategy, "local"),
        iters=1, warmup=0, cache=PlanCache(),
    )
    ref = moe_decode_reference(inputs, strategy)
    assert rep.metrics["dispatch_mode"] == label
    for got, want in zip(out, ref):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    traffic = moe_decode_traffic(inputs, strategy)
    if label == "tp":
        assert traffic.total_bytes == 0
    else:
        assert traffic.collective_bytes > 0


def test_moe_decode_rejects_bad_batch_or_params(cfg, params):
    inputs = _decode_inputs(cfg, params, batch=6, nodelets=4)  # 6 % 4 != 0
    with pytest.raises(ValueError, match="nodelets"):
        run(Request("moe_decode", inputs), iters=1, warmup=0, cache=PlanCache())
    short = {k: v for k, v in params.items() if k != "lm_head"}
    bad = _decode_inputs(cfg, dict(short, **{}), batch=8, nodelets=4)
    with pytest.raises(ValueError, match="lm_head"):
        run(Request("moe_decode", bad), iters=1, warmup=0, cache=PlanCache())


# -- DecodeServer continuous batching ------------------------------------------


def _drive(server, prompts, schedule):
    """Feed prompts per the (step_at_add,) schedule — sequences join while
    others are mid-decode, finish at different steps, and free slots refill
    from the waiting queue (continuous batching)."""
    for (prompt, max_new), step_now in zip(prompts, schedule):
        server.add(prompt, max_new_tokens=max_new)
        for _ in range(step_now):
            server.step()
    server.run_until_drained()
    return dict(server.results)


@pytest.mark.parametrize("label,strategy,nodelets", MODES)
def test_served_decode_bit_identical_to_oracle(cfg, params, label, strategy, nodelets):
    """ISSUE 8 acceptance: continuous-batched decode through EngineService
    (batch AND worker modes) emits exactly the oracle's tokens under a
    join/leave schedule, for every dispatch mode."""
    rng = np.random.default_rng(7)
    prompts = [
        (rng.integers(1, cfg.vocab_size, size=int(n)).tolist(), int(m))
        for n, m in zip(rng.integers(2, 6, size=6), (3, 5, 2, 4, 3, 2))
    ]
    schedule = (0, 1, 0, 2, 0, 1)  # joins interleaved with decode steps
    mk = dict(capacity=4, max_len=16, nodelets=nodelets, strategy=strategy)

    oracle = _drive(
        DecodeServer(cfg, params, oracle=True, **mk), prompts, schedule
    )
    assert sorted(oracle) == list(range(len(prompts)))  # ids are add-order
    assert all(len(oracle[i]) == m for i, (_, m) in enumerate(prompts))

    direct = _drive(DecodeServer(cfg, params, **mk), prompts, schedule)
    assert direct == oracle

    batch_svc = EngineService(cache=PlanCache())
    batched = _drive(
        DecodeServer(cfg, params, service=batch_svc, **mk), prompts, schedule
    )
    assert batched == oracle

    worker_svc = EngineService(cache=PlanCache(), slo_target_seconds=600.0)
    worker_svc.start()
    try:
        worked = _drive(
            DecodeServer(cfg, params, service=worker_svc, **mk), prompts, schedule
        )
    finally:
        worker_svc.stop()
    assert worked == oracle
    stats = worker_svc.stats()
    assert stats.slo_checked > 0 and stats.slo_violations == 0
    assert stats.total_p99 > 0.0


def test_decode_server_admission_and_retirement(cfg, params):
    """Waiting sequences admit FIFO as slots retire; results appear exactly
    once per sequence with the declared number of generated tokens."""
    server = DecodeServer(cfg, params, capacity=2, max_len=16, nodelets=1,
                          oracle=True)
    ids = [server.add([5, 6], max_new_tokens=2) for _ in range(4)]
    assert len(server._waiting) == 2  # capacity 2: last two queue
    server.run_until_drained()
    assert sorted(server.results) == sorted(ids)
    assert all(len(toks) == 2 for toks in server.results.values())
    with pytest.raises(ValueError):
        server.add([], max_new_tokens=1)
    with pytest.raises(ValueError):
        server.add([1] * 20, max_new_tokens=1)  # prompt + new > max_len


# -- local/mesh decode parity (subprocess, 8 forced host devices) --------------

DECODE_PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.core import Comm, MigratoryStrategy
from repro.engine import MoEDecodeInputs, PlanCache, Request, run
from repro.models.transformer import moe_decode_params

cfg = get_config("serve-moe")
params = moe_decode_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(1)
B, S, D = 8, 16, cfg.d_model
for P in (4, 8):
    inputs = MoEDecodeInputs(
        params=params,
        tokens=jnp.asarray(rng.integers(1, cfg.vocab_size, B), jnp.int32),
        k_cache=jnp.asarray(rng.standard_normal((B, S, D)).astype(np.float32)),
        v_cache=jnp.asarray(rng.standard_normal((B, S, D)).astype(np.float32)),
        positions=jnp.asarray(rng.integers(0, S - 1, B), jnp.int32),
        nodelets=P,
        experts_per_token=cfg.experts_per_token,
        capacity_factor=cfg.capacity_factor,
    )
    for comm in (Comm.MIGRATE, Comm.REMOTE_WRITE):
        st = MigratoryStrategy(comm=comm)
        yl, rl = run(Request("moe_decode", inputs, st, "local"),
                     iters=1, warmup=0, cache=PlanCache())
        ym, rm = run(Request("moe_decode", inputs, st, "mesh"),
                     iters=1, warmup=0, cache=PlanCache())
        for a, b in zip(yl, ym):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (P, comm)
        assert rl.metrics["dispatch_mode"] == rm.metrics["dispatch_mode"]
        assert rl.traffic.total_bytes == rm.traffic.total_bytes
print("DECODE-PARITY-OK")
"""


@pytest.mark.slow
def test_decode_local_mesh_parity_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", DECODE_PARITY_SCRIPT], env=env,
        capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr}"
    assert "DECODE-PARITY-OK" in r.stdout
