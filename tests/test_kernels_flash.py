"""Pallas flash-attention kernel vs pure-jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_reference


def _rand_qkv(rng, b, hq, hkv, sq, skv, d, dtype=np.float32):
    q = jnp.asarray(rng.standard_normal((b, hq, sq, d)).astype(dtype))
    k = jnp.asarray(rng.standard_normal((b, hkv, skv, d)).astype(dtype))
    v = jnp.asarray(rng.standard_normal((b, hkv, skv, d)).astype(dtype))
    return q, k, v


@pytest.mark.parametrize("b,hq,hkv,sq,skv,d,causal,window", [
    (2, 4, 2, 64, 64, 32, True, None),    # GQA causal
    (1, 8, 8, 128, 128, 64, True, None),  # MHA
    (1, 8, 1, 64, 64, 32, True, None),    # MQA
    (1, 4, 4, 64, 192, 32, True, None),   # q tail of longer kv (chunked decode)
    (2, 4, 2, 96, 96, 32, True, 48),      # sliding window (Mixtral SWA)
    (1, 2, 1, 64, 64, 32, False, None),   # non-causal (encoder / cross-attn)
    (1, 2, 2, 100, 100, 32, True, None),  # non-block-multiple seq (padding)
])
def test_flash_matches_ref(b, hq, hkv, sq, skv, d, causal, window):
    rng = np.random.default_rng(b * sq + skv)
    q, k, v = _rand_qkv(rng, b, hq, hkv, sq, skv, d)
    o_k = flash_attention(q, k, v, causal=causal, window=window, block_q=32, block_k=32)
    o_r = attention_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), rtol=1e-4, atol=1e-5)


def test_flash_block_size_invariance():
    rng = np.random.default_rng(7)
    q, k, v = _rand_qkv(rng, 1, 4, 2, 128, 128, 32)
    outs = [
        np.asarray(flash_attention(q, k, v, block_q=bq, block_k=bk))
        for bq, bk in [(32, 32), (64, 32), (32, 64), (128, 128)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-6)


def test_flash_bf16():
    rng = np.random.default_rng(3)
    q, k, v = _rand_qkv(rng, 1, 4, 2, 64, 64, 64)
    o_k = flash_attention(
        q.astype(jnp.bfloat16), k.astype(jnp.bfloat16), v.astype(jnp.bfloat16),
        block_q=32, block_k=32,
    )
    o_r = attention_reference(q, k, v)
    np.testing.assert_allclose(
        np.asarray(o_k.astype(jnp.float32)), np.asarray(o_r), rtol=0.1, atol=0.05
    )


def test_flash_window_equals_full_when_wide():
    """A window >= seq must equal full causal attention."""
    rng = np.random.default_rng(5)
    q, k, v = _rand_qkv(rng, 1, 2, 2, 64, 64, 32)
    o_w = flash_attention(q, k, v, window=64, block_q=32, block_k=32)
    o_f = flash_attention(q, k, v, window=None, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(o_w), np.asarray(o_f), rtol=1e-6)


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 2),
    hkv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 2, 4]),
    sq=st.sampled_from([16, 48, 64]),
    extra_kv=st.sampled_from([0, 16, 64]),
    d=st.sampled_from([16, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_flash(b, hkv, group, sq, extra_kv, d, causal, seed):
    rng = np.random.default_rng(seed)
    q, k, v = _rand_qkv(rng, b, hkv * group, hkv, sq, sq + extra_kv, d)
    o_k = flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
    o_r = attention_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), rtol=1e-4, atol=1e-4)
