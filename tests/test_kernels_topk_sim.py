"""Pallas fused similarity+top-k kernel vs oracle and vs the core GSANA path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (
    Scheme, bucketize, compute_similarity, generate_alignment_pair,
    neighbor_buckets, pick_grid, recall_at_k,
)
from repro.core.gsana import similarity_block
from repro.kernels.topk_sim.kernel import topk_sim_pallas
from repro.kernels.topk_sim.ref import topk_sim_reference
from repro.kernels.topk_sim.ops import pack_features, topk_sim_pairs


def _problem(n=256, seed=4):
    vs1, vs2, pi = generate_alignment_pair(n, seed=seed)
    grid = pick_grid(n, 32)
    cap = max(bucketize(vs1, grid).cap, bucketize(vs2, grid).cap)
    return vs1, vs2, bucketize(vs1, grid, cap=cap), bucketize(vs2, grid, cap=cap), pi


def test_kernel_matches_ref():
    vs1, vs2, b1, b2, _ = _problem()
    nb = neighbor_buckets(b2.grid)
    g2 = b2.grid * b2.grid
    pb2 = jnp.asarray(np.repeat(np.arange(g2), 9))
    pb1 = jnp.asarray(nb.reshape(-1))
    s_k, u_k = topk_sim_pairs(vs1, vs2, b1, b2, pb2, pb1, k=4, use_kernel=True)
    s_r, u_r = topk_sim_pairs(vs1, vs2, b1, b2, pb2, pb1, k=4, use_kernel=False)
    sk, sr = np.asarray(s_k), np.asarray(s_r)
    assert (np.isfinite(sk) == np.isfinite(sr)).all()
    np.testing.assert_allclose(
        np.where(np.isfinite(sk), sk, 0), np.where(np.isfinite(sr), sr, 0), atol=1e-5
    )


def test_kernel_matches_core_similarity():
    """The packed-feature kernel must agree with the sorted-array core path."""
    vs1, vs2, b1, b2, _ = _problem()
    nb = neighbor_buckets(b2.grid)
    bid2 = b2.grid + 1  # an interior bucket
    for j in range(9):
        bid1 = int(nb[bid2, j])
        if bid1 < 0:
            continue
        s_core = similarity_block(vs2, vs1, b2.vid[bid2], b1.vid[bid1])
        sc, _ = jax.lax.top_k(s_core, 4)
        s_k, _ = topk_sim_pairs(
            vs1, vs2, b1, b2, jnp.asarray([bid2]), jnp.asarray([bid1]), k=4
        )
        a, b = np.asarray(sc), np.asarray(s_k[0])
        m = np.isfinite(a)
        assert (m == np.isfinite(b)).all()
        np.testing.assert_allclose(a[m], b[m], atol=1e-5)


def test_end_to_end_recall_with_kernel():
    vs1, vs2, b1, b2, pi = _problem(n=384, seed=9)
    nb = neighbor_buckets(b2.grid)
    g2 = b2.grid * b2.grid
    pb2 = jnp.asarray(np.repeat(np.arange(g2), 9))
    pb1 = jnp.asarray(nb.reshape(-1))
    scores, u_ids = topk_sim_pairs(vs1, vs2, b1, b2, pb2, pb1, k=4)
    # merge per-bucket (9 pairs each) and scatter to vertices
    k = 4
    cap2 = b2.cap
    sc = np.asarray(scores).reshape(g2, 9, cap2, k).transpose(0, 2, 1, 3).reshape(g2, cap2, 9 * k)
    ui = np.asarray(u_ids).reshape(g2, 9, cap2, k).transpose(0, 2, 1, 3).reshape(g2, cap2, 9 * k)
    top = np.argsort(-sc, axis=-1)[..., :k]
    cand_b = np.take_along_axis(ui, top, axis=-1)
    vid = np.asarray(b2.vid).reshape(-1)
    cand = np.zeros((vs2.n, k), dtype=np.int64)
    ok = vid >= 0
    cand[vid[ok]] = cand_b.reshape(-1, k)[ok]
    assert recall_at_k(jnp.asarray(cand), pi) > 0.9


@settings(max_examples=10, deadline=None)
@given(
    a=st.sampled_from([4, 8, 16]),
    b=st.sampled_from([4, 8, 16]),
    p=st.integers(1, 6),
    k=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_kernel_vs_ref_random_features(a, b, p, k, seed):
    rng = np.random.default_rng(seed)
    t1 = t2 = 8
    t3 = 16
    f = 5 + t1 + t2 + t3
    fv = jnp.asarray(np.abs(rng.standard_normal((p, a, f))).astype(np.float32))
    fu = jnp.asarray(np.abs(rng.standard_normal((p, b, f))).astype(np.float32))
    mv = jnp.asarray((rng.random((p, a)) > 0.2).astype(np.float32))
    mu = jnp.asarray((rng.random((p, b)) > 0.2).astype(np.float32))
    s_k, i_k = topk_sim_pallas(fv, fu, mv, mu, t1=t1, t2=t2, t3=t3, k=k)
    s_r, i_r = topk_sim_reference(fv, fu, mv, mu, t1=t1, t2=t2, t3=t3, k=k)
    sk, sr = np.asarray(s_k), np.asarray(s_r)
    assert (np.isfinite(sk) == np.isfinite(sr)).all()
    np.testing.assert_allclose(
        np.where(np.isfinite(sk), sk, 0), np.where(np.isfinite(sr), sr, 0), atol=1e-5
    )
