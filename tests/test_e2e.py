"""End-to-end integration: supervised training improves loss; serving decodes;
the train driver recovers from an injected failure."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.data import DataConfig, SyntheticTokens
from repro.models import Ctx, api
from repro.optim import AdamWConfig
from repro.runtime import SupervisorConfig, run_supervised


def test_training_reduces_loss(tmp_path):
    cfg = reduced_config("llama3.2-3b")
    ctx = Ctx(cfg=cfg)
    opt_cfg = AdamWConfig(lr=3e-3, total_steps=30, warmup_steps=3)
    data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=4))

    def build():
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        opt = api.init_opt(cfg, params, opt_cfg)
        fn = jax.jit(
            lambda p, o, b: api.train_step(ctx, p, o, b, opt_cfg),
            donate_argnums=(0, 1),
        )
        return params, opt, fn

    sup = SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=10, total_steps=30)
    res = run_supervised(sup, build=build, data_for_step=data.jax_batch)
    assert res.restarts == 0
    first = np.mean(res.losses[:3])
    last = np.mean(res.losses[-3:])
    assert last < first - 0.3, f"loss did not improve: {first} -> {last}"


def test_training_with_failure_recovers_and_matches(tmp_path):
    """The restarted run must land exactly where the unfailed run lands
    (deterministic pipeline + checkpoint replay)."""
    cfg = reduced_config("llama3.2-3b")
    ctx = Ctx(cfg=cfg)
    opt_cfg = AdamWConfig(lr=1e-3, total_steps=14, warmup_steps=2)
    data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=2))

    def build():
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        opt = api.init_opt(cfg, params, opt_cfg)
        fn = jax.jit(lambda p, o, b: api.train_step(ctx, p, o, b, opt_cfg))
        return params, opt, fn

    sup_a = SupervisorConfig(ckpt_dir=str(tmp_path / "a"), ckpt_every=5, total_steps=14)
    res_a = run_supervised(sup_a, build=build, data_for_step=data.jax_batch)
    sup_b = SupervisorConfig(ckpt_dir=str(tmp_path / "b"), ckpt_every=5, total_steps=14)
    res_b = run_supervised(
        sup_b, build=build, data_for_step=data.jax_batch, fail_at=8
    )
    assert res_b.restarts == 1
    # identical trailing losses (recovery replays the exact stream)
    np.testing.assert_allclose(res_a.losses[-3:], res_b.losses[-3:], rtol=1e-4)


def test_serve_generates(tmp_path):
    cfg = reduced_config("qwen2-7b")
    ctx = Ctx(cfg=cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 1, cfg.vocab_size)
    logits, st = api.prefill(ctx, params, prompts, max_len=32, batch={})
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    outs = []
    for _ in range(6):
        logits, st = api.decode_step(ctx, params, tok, st)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        outs.append(tok)
    gen = jnp.concatenate(outs, axis=1)
    assert gen.shape == (2, 6)
    assert not bool(jnp.isnan(logits).any())
    assert int(st.length) == 16 + 6
