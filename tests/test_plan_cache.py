"""Compiled-plan cache + the plan -> compile -> execute pipeline.

ISSUE 2 acceptance: a repeat ``engine.run`` with identical inputs reports
``cache_hit=True`` and lower ``seconds`` than the cold call; RunReport
separates compile from steady state; op metrics cannot shadow schema
columns.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MigratoryStrategy, TrafficStats, partition_ell
from repro.engine import (
    BFSInputs,
    LocalSubstrate,
    MeshSubstrate,
    PallasSubstrate,
    PlanCache,
    RunReport,
    SpMVInputs,
    SpMVOp,
    build_plan,
    compile_plan,
    default_cache,
    execute,
    run,
)
from repro.sparse import (
    edges_to_csr,
    erdos_renyi_edges,
    laplacian_2d,
    partition_graph,
    spmv_csr_ref,
)


@pytest.fixture(scope="module")
def spmv_problem():
    a = laplacian_2d(12)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(144).astype(np.float32))
    return a, SpMVInputs(partition_ell(a, 8), x)


@pytest.fixture(scope="module")
def bfs_problem():
    g = edges_to_csr(erdos_renyi_edges(8, 6, seed=2), 256)
    return BFSInputs(partition_graph(g, 8), 3)


# -- the acceptance property ---------------------------------------------------


def test_repeat_run_hits_cache_and_is_faster(spmv_problem):
    """Cold call compiles (timed in ``seconds`` with warmup=0); the repeat
    reuses the jitted executor and must be strictly faster."""
    _, inputs = spmv_problem
    cache = PlanCache()
    y1, r1 = run("spmv", inputs, None, "local", iters=1, warmup=0, cache=cache)
    y2, r2 = run("spmv", inputs, None, "local", iters=1, warmup=0, cache=cache)
    assert not r1.cache_hit and r1.compile_seconds > 0
    assert r2.cache_hit and r2.compile_seconds == 0.0
    assert r2.seconds < r1.seconds
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_steady_state_defaults_split_compile(spmv_problem):
    """CI-smoke defaults (iters=3, warmup=1): the compiling call lands in
    warmup, so ``seconds`` is steady state and much smaller than compile."""
    _, inputs = spmv_problem
    cache = PlanCache()
    _, rep = run("spmv", inputs, None, "local", cache=cache)
    assert not rep.cache_hit
    assert rep.compile_seconds > rep.seconds


# -- key semantics -------------------------------------------------------------


def test_same_shapes_different_values_share_executor(spmv_problem):
    """The cache key is shape/dtype-based: value-different inputs reuse the
    executor and still compute *their own* result."""
    a, inputs = spmv_problem
    x2 = jnp.asarray(np.random.default_rng(9).standard_normal(144).astype(np.float32))
    inputs2 = SpMVInputs(inputs.a, x2)
    cache = PlanCache()
    run("spmv", inputs, None, "local", iters=1, warmup=0, cache=cache)
    y2, r2 = run("spmv", inputs2, None, "local", iters=1, warmup=0, cache=cache)
    assert r2.cache_hit
    from repro.core import gather_result

    np.testing.assert_allclose(
        np.asarray(gather_result(y2, 144)), np.asarray(spmv_csr_ref(a, x2)), atol=1e-4
    )


def test_strategy_and_shape_changes_miss(spmv_problem):
    _, inputs = spmv_problem
    cache = PlanCache()
    run("spmv", inputs, MigratoryStrategy(grain=16), "local", iters=1, warmup=0, cache=cache)
    # different grain -> different strategy key -> miss
    _, r2 = run("spmv", inputs, MigratoryStrategy(grain=64), "local", iters=1, warmup=0, cache=cache)
    assert not r2.cache_hit
    # different shape -> miss
    a2 = laplacian_2d(8)
    x2 = jnp.asarray(np.random.default_rng(1).standard_normal(64).astype(np.float32))
    _, r3 = run(
        "spmv", SpMVInputs(partition_ell(a2, 8), x2),
        MigratoryStrategy(grain=16), "local", iters=1, warmup=0, cache=cache,
    )
    assert not r3.cache_hit
    assert cache.stats()["misses"] == 3 and cache.stats()["hits"] == 0


def test_bfs_root_is_static_in_key(bfs_problem):
    cache = PlanCache()
    run("bfs", bfs_problem, None, "local", iters=1, warmup=0, cache=cache)
    other_root = dataclasses.replace(bfs_problem, root=5)
    _, r2 = run("bfs", other_root, None, "local", iters=1, warmup=0, cache=cache)
    assert not r2.cache_hit  # the executor closes over the root
    _, r3 = run("bfs", other_root, None, "local", iters=1, warmup=0, cache=cache)
    assert r3.cache_hit


def test_substrate_fingerprints_distinguish_backends():
    assert LocalSubstrate().cache_fingerprint() == LocalSubstrate().cache_fingerprint()
    assert PallasSubstrate(True).cache_fingerprint() != PallasSubstrate(False).cache_fingerprint()
    assert LocalSubstrate().cache_fingerprint() != MeshSubstrate().cache_fingerprint()


# -- pipeline stages -----------------------------------------------------------


def test_pipeline_stages_compose(spmv_problem):
    _, inputs = spmv_problem
    cache = PlanCache()
    plan = build_plan("spmv", inputs, None, "local")
    assert plan.key is not None
    compiled = compile_plan(plan, cache)
    assert not compiled.cache_hit
    result, seconds, compile_seconds = execute(compiled, iters=1, warmup=0, cache=cache)
    assert compile_seconds > 0 and seconds > 0
    # a second compile of an equal plan reuses the now-warm entry
    compiled2 = compile_plan(build_plan("spmv", inputs, None, "local"), cache)
    assert compiled2.cache_hit
    assert compiled2.executor is compiled.executor


def test_plan_run_method_matches_executor(spmv_problem):
    _, inputs = spmv_problem
    plan = build_plan("spmv", inputs, None, "local")
    np.testing.assert_array_equal(
        np.asarray(plan.run()), np.asarray(plan.executor(*plan.args))
    )


def test_uncacheable_plan_bypasses_cache(spmv_problem):
    _, inputs = spmv_problem
    cache = PlanCache()
    plan = build_plan("spmv", inputs, None, "local")
    plan.key = None
    for _ in range(2):
        compiled = cache.get(plan)
        assert not compiled.cache_hit
    assert cache.stats()["uncacheable"] == 2
    assert len(cache) == 0


def test_cache_stats_clear_and_eviction(spmv_problem):
    _, inputs = spmv_problem
    cache = PlanCache(max_entries=1)
    run("spmv", inputs, MigratoryStrategy(grain=4), "local", iters=1, warmup=0, cache=cache)
    run("spmv", inputs, MigratoryStrategy(grain=8), "local", iters=1, warmup=0, cache=cache)
    assert len(cache) == 1  # LRU evicted the first entry
    # the evicted plan compiles again
    _, r = run("spmv", inputs, MigratoryStrategy(grain=4), "local", iters=1, warmup=0, cache=cache)
    assert not r.cache_hit
    cache.clear()
    assert len(cache) == 0 and cache.stats()["hits"] == 0


def test_default_cache_is_process_wide(spmv_problem):
    _, inputs = spmv_problem
    default_cache().clear()
    run("spmv", inputs, None, "local", iters=1, warmup=0)
    _, r2 = run("spmv", inputs, None, "local", iters=1, warmup=0)
    assert r2.cache_hit
    default_cache().clear()


# -- report schema -------------------------------------------------------------


def test_report_has_cache_columns(spmv_problem):
    _, inputs = spmv_problem
    _, rep = run("spmv", inputs, None, "local", cache=PlanCache())
    d = rep.to_dict()
    assert "cache_hit" in d and "compile_seconds" in d


def test_op_metric_shadowing_schema_column_raises():
    rep = RunReport.from_parts(
        op="spmv", strategy=MigratoryStrategy(), substrate="local",
        seconds=1.0, traffic=TrafficStats(), bytes_moved=8,
        metrics={"seconds": 2.0},
    )
    with pytest.raises(ValueError, match="collide"):
        rep.to_dict()


# -- compile-stage jit + placement pinning (ISSUE 5) ---------------------------


def test_keyed_plans_compile_to_jitted_executors(spmv_problem):
    """The compile stage wraps keyed executors in jax.jit (one fused
    executable per plan key) with results bit-identical to the plan's own
    eager executor; keyless and jit=False plans stay eager."""
    _, inputs = spmv_problem
    cache = PlanCache()
    plan = build_plan("spmv", inputs, None, "local")
    compiled = cache.get(plan)
    assert compiled.executor is not plan.executor  # wrapped
    np.testing.assert_array_equal(
        np.asarray(compiled()), np.asarray(plan.executor(*plan.args))
    )
    eager_plan = build_plan("spmv", inputs, None, "local")
    eager_plan.jit = False
    cache2 = PlanCache()
    assert cache2.get(eager_plan).executor is eager_plan.executor
    keyless = build_plan("spmv", inputs, None, "local")
    keyless.key = None
    assert cache.get(keyless).executor is keyless.executor


def test_cache_slot_pinning_first_wins(spmv_problem):
    _, inputs = spmv_problem
    cache = PlanCache()
    plan = build_plan("spmv", inputs, None, "local")
    assert cache.slot_of(plan.key) is None
    assert not cache.is_warm(plan.key)
    compiled = cache.get(plan, slot=2)
    cache.note_compiled(compiled, 0.1)
    assert cache.slot_of(plan.key) == 2
    assert cache.is_warm(plan.key)
    # a steal resolves from another slot but never moves the pin
    cache.get(build_plan("spmv", inputs, None, "local"), slot=0)
    assert cache.slot_of(plan.key) == 2
    assert cache.stats()["pinned"] == 1
    assert cache.slot_of(None) is None and not cache.is_warm(None)


def test_pin_key_alias_survives_without_entry(spmv_problem):
    """Mesh placement stores compiled entries under slot-variant keys; the
    base key's pin lives in the alias table so affinity survives a fresh
    service over a shared cache."""
    _, inputs = spmv_problem
    cache = PlanCache()
    plan = build_plan("spmv", inputs, None, "local")
    cache.pin_key(plan.key, 3)
    assert cache.slot_of(plan.key) == 3
    cache.pin_key(plan.key, 1)  # first pin wins
    assert cache.slot_of(plan.key) == 3
    cache.pin_key(None, 0)  # keyless: no-op
    cache.clear()
    assert cache.slot_of(plan.key) is None
