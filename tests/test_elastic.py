"""runtime/elastic.py::plan_remesh — dedicated coverage (ISSUE 2 satellite):
power-of-two data-axis shrink, the model-axis-too-big error, and the
microbatch (gradient-accumulation) fallback when activations outgrow HBM.
"""
import pytest

from repro.runtime.elastic import ElasticPlan, plan_remesh


def test_data_axis_shrinks_to_power_of_two():
    # 400 healthy / 16-way model axis: 25 data slots -> largest pow2 is 16
    p = plan_remesh(n_healthy=400, model_axis=16, global_batch=256, prev_data_axis=16)
    assert (p.data_axis, p.model_axis) == (16, 16)
    assert p.per_device_batch_factor == 1.0
    assert p.microbatches == 1
    # 6 healthy / 2-way model: 3 data slots -> pow2 shrink to 2
    p = plan_remesh(n_healthy=6, model_axis=2, global_batch=256, prev_data_axis=4)
    assert p.data_axis == 2
    assert p.per_device_batch_factor == 2.0


@pytest.mark.parametrize("n_healthy,model_axis", [(8, 16), (1, 2), (15, 16)])
def test_model_axis_too_big_raises(n_healthy, model_axis):
    """The model axis is sacred (TP state layout): fewer devices than the
    model axis cannot be remeshed."""
    with pytest.raises(ValueError, match="cannot preserve model axis"):
        plan_remesh(n_healthy, model_axis, global_batch=256, prev_data_axis=model_axis)


def test_exact_model_axis_survivors_is_valid():
    # exactly model_axis devices left: a 1-wide data axis, all batch on it
    p = plan_remesh(n_healthy=16, model_axis=16, global_batch=256, prev_data_axis=8)
    assert p.data_axis == 1
    assert p.per_device_batch_factor == 8.0
    assert p.microbatches == 8  # 8/8 = 1.0 <= 1/0.8 headroom


def test_microbatch_fallback_keeps_global_batch():
    """Shrinking data 16 -> 8 doubles per-device batch; with 0.8 HBM
    headroom that exceeds budget, so microbatching splits it."""
    p = plan_remesh(n_healthy=200, model_axis=16, global_batch=256, prev_data_axis=16)
    assert p.data_axis == 8
    assert p.per_device_batch_factor == 2.0
    # factor/micro must fit inside 1/headroom = 1.25
    assert p.microbatches == 2
    assert p.per_device_batch_factor / p.microbatches <= 1.25


def test_headroom_controls_microbatching():
    # full headroom (1.0): any growth must be fully microbatched away
    p = plan_remesh(
        n_healthy=8, model_axis=2, global_batch=64, prev_data_axis=16,
        hbm_headroom_frac=1.0,
    )
    assert p.data_axis == 4
    assert p.per_device_batch_factor == 4.0
    assert p.microbatches == 4
    # generous headroom: no microbatching needed for the same shrink
    p2 = plan_remesh(
        n_healthy=8, model_axis=2, global_batch=64, prev_data_axis=16,
        hbm_headroom_frac=0.2,
    )
    assert p2.microbatches == 1


def test_growth_is_also_planned():
    """More survivors than before (recovery): data axis grows, per-device
    batch shrinks below 1 — never microbatched."""
    p = plan_remesh(n_healthy=64, model_axis=2, global_batch=256, prev_data_axis=8)
    assert p.data_axis == 32
    assert p.per_device_batch_factor == 0.25
    assert p.microbatches == 1
    assert isinstance(p, ElasticPlan)
