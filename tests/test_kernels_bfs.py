"""Pallas BFS frontier expansion vs the dense-scatter oracle: one-round
bit-parity across block sizes (including non-multiple row counts) and full
traversals bit-identical to ``bfs_local`` — integer min-scatter is
deterministic, so parity is exact, not tolerance-pinned."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import MigratoryStrategy, bfs_local
from repro.kernels.bfs import bfs_expand, bfs_expand_pallas, bfs_expand_reference, bfs_pallas
from repro.kernels.bfs.ref import UNVISITED
from repro.sparse import edges_to_csr, erdos_renyi_edges, partition_graph


def _rand_round(rng, n, k, frontier_frac=0.3):
    """A random padded adjacency (slot -1 = padding) and boolean frontier."""
    adj = rng.integers(-1, n, size=(n, k)).astype(np.int32)
    frontier = (rng.random(n) < frontier_frac).astype(bool)
    return jnp.asarray(adj), jnp.asarray(frontier)


@pytest.mark.parametrize("n,k,block_rows", [
    (64, 4, 16),
    (100, 6, 32),     # rows not a multiple of block_rows (padding path)
    (256, 1, 256),    # K=1, single program
    (37, 8, 64),      # block larger than rows (clamp path)
    (96, 5, 1),       # one row per program
])
def test_bfs_expand_matches_reference(n, k, block_rows):
    rng = np.random.default_rng(n * k + block_rows)
    adj, frontier = _rand_round(rng, n, k)
    got = bfs_expand_pallas(adj, frontier, block_rows=block_rows)
    want = bfs_expand_reference(adj, frontier)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bfs_expand_block_invariance():
    """DESIGN.md §2a: block_rows changes the launch grid and the partial
    merge order, never the min-merged result."""
    rng = np.random.default_rng(7)
    adj, frontier = _rand_round(rng, 200, 6)
    outs = [
        np.asarray(bfs_expand_pallas(adj, frontier, block_rows=b))
        for b in (1, 13, 64, 200, 4096)
    ]
    for out in outs[1:]:
        np.testing.assert_array_equal(out, outs[0])


def test_bfs_expand_edge_frontiers():
    """Empty frontier proposes nothing; full frontier proposes the min
    source for every destination with an in-edge."""
    rng = np.random.default_rng(11)
    adj, _ = _rand_round(rng, 50, 4)
    empty = bfs_expand_pallas(adj, jnp.zeros(50, dtype=bool), block_rows=16)
    assert bool(jnp.all(empty == UNVISITED))
    full = bfs_expand_pallas(adj, jnp.ones(50, dtype=bool), block_rows=16)
    np.testing.assert_array_equal(
        np.asarray(full),
        np.asarray(bfs_expand_reference(adj, jnp.ones(50, dtype=bool))),
    )


def test_bfs_expand_use_kernel_toggle():
    """``bfs_expand(use_kernel=False)`` is the reference path, and both
    arms agree bit-for-bit."""
    rng = np.random.default_rng(3)
    adj, frontier = _rand_round(rng, 80, 5)
    np.testing.assert_array_equal(
        np.asarray(bfs_expand(adj, frontier, block_rows=32, use_kernel=True)),
        np.asarray(bfs_expand(adj, frontier, use_kernel=False)),
    )


@pytest.mark.parametrize("root", [0, 3, 200])
@pytest.mark.parametrize("block_rows", [None, 13, 64, 512])
def test_bfs_pallas_traversal_matches_local(root, block_rows):
    """Full traversal: the Pallas round loop reproduces the local oracle's
    parent tree exactly, for every block size and root."""
    g = partition_graph(edges_to_csr(erdos_renyi_edges(8, 6, seed=5), 256), 8)
    want = np.asarray(bfs_local(g, root))
    got = np.asarray(bfs_pallas(g, root, MigratoryStrategy(), block_rows=block_rows))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(2, 120),
    k=st.integers(1, 10),
    block_rows=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_bfs_expand(n, k, block_rows, seed):
    rng = np.random.default_rng(seed)
    adj, frontier = _rand_round(rng, n, k)
    got = bfs_expand_pallas(adj, frontier, block_rows=block_rows)
    want = bfs_expand_reference(adj, frontier)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
