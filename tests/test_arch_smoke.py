"""Per-arch smoke tests (deliverable f): REDUCED same-family configs, one
forward + one train step on CPU, asserting output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced_config
from repro.models import Ctx, api
from repro.optim import AdamWConfig

OPT = AdamWConfig(total_steps=10, warmup_steps=2)


def _batch(cfg, b=2, s=32, key=jax.random.PRNGKey(1)):
    batch = {"tokens": jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (b, cfg.encoder_frames, cfg.d_model), jnp.float32
        )
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (b, cfg.num_patches, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_forward_and_train_step(arch):
    cfg = reduced_config(arch)
    ctx = Ctx(cfg=cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 32
    batch = _batch(cfg, b, s)

    m = api.module_for(cfg)
    if cfg.family == "encdec":
        logits = m.forward(ctx, params, batch["tokens"][:, :-1], batch["frames"])
        assert logits.shape == (b, s, cfg.vocab_size)
    elif cfg.family == "vlm":
        logits = m.forward(ctx, params, batch["tokens"][:, :-1], batch["patches"])
        assert logits.shape == (b, s + cfg.num_patches, cfg.vocab_size)
    else:
        logits = m.forward(ctx, params, batch["tokens"][:, :-1])
        assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"

    opt = api.init_opt(cfg, params, OPT)
    # snapshot a fingerprint first: train_step donates params (production
    # memory behavior), so the old tree is dead after the call
    before = float(
        sum(jnp.abs(x.astype(jnp.float32)).sum() for x in jax.tree.leaves(params))
    )
    p2, o2, metrics = api.train_step(ctx, params, opt, batch, OPT)
    assert not bool(jnp.isnan(metrics["loss"])), f"{arch}: NaN loss"
    assert float(metrics["grad_norm"]) > 0
    after = float(
        sum(jnp.abs(x.astype(jnp.float32)).sum() for x in jax.tree.leaves(p2))
    )
    assert before != after, f"{arch}: train step was a no-op"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_full_config_is_exact(arch):
    """The full (non-reduced) configs must match the assignment table."""
    from repro.configs import get_config

    cfg = get_config(arch)
    table = {
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
    }[arch]
    got = (
        cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
        cfg.d_ff, cfg.vocab_size,
    )
    assert got == table, f"{arch}: {got} != {table}"
    if arch == "moonshot-v1-16b-a3b":
        assert (cfg.num_experts, cfg.experts_per_token) == (64, 6)
    if arch == "mixtral-8x22b":
        assert (cfg.num_experts, cfg.experts_per_token) == (8, 2)
        assert cfg.sliding_window == 4096
    if arch == "zamba2-2.7b":
        assert cfg.ssm_state == 64 and cfg.shared_attn_period > 0
    if arch == "qwen2-7b":
        assert cfg.qkv_bias
    if arch == "glm4-9b":
        assert cfg.rope_fraction == 0.5
