"""The execution plane (ISSUE 5 tentpole): a pool of N executor workers fed
by one scheduler/compile stage, with substrate-aware placement and work
stealing.

Pinned here:

- **pool stress**: W ∈ {1, 2, 4} workers x mixed ops x steal-inducing skewed
  group sizes, bit-identical to sequential ``engine.run`` every time;
- **QoS ordering per worker**: each worker starts its groups in
  non-increasing priority order within every scheduler snapshot;
- **stats schema**: ``queue_depth_hwm`` plus the merged per-worker
  busy/steal/occupancy columns in one ``to_dict``;
- **placement**: plan-key groups pin to the slot that compiled them
  (cache-level pinning), mesh substrates carve per-slot device windows with
  bit-identical results (subprocess, 8 forced host devices).
"""
import os
import subprocess
import sys
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Comm, MigratoryStrategy, Scheme, bucketize, \
    generate_alignment_pair, partition_ell, pick_grid
from repro.engine import (
    EngineService,
    BFSInputs,
    GSANAInputs,
    LocalSubstrate,
    MeshSubstrate,
    PallasSubstrate,
    PlanCache,
    SpMVInputs,
    placement_table,
    run,
)
from repro.sparse import edges_to_csr, erdos_renyi_edges, laplacian_2d, partition_graph


@pytest.fixture(scope="module")
def spmv_inputs():
    a = laplacian_2d(12)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(144).astype(np.float32))
    return SpMVInputs(partition_ell(a, 8), x)


@pytest.fixture(scope="module")
def bfs_inputs():
    g = edges_to_csr(erdos_renyi_edges(8, 6, seed=2), 256)
    return BFSInputs(partition_graph(g, 8), 3)


@pytest.fixture(scope="module")
def gsana_inputs():
    vs1, vs2, pi = generate_alignment_pair(192, seed=11)
    grid = pick_grid(192, 32)
    cap = max(bucketize(vs1, grid).cap, bucketize(vs2, grid).cap)
    return GSANAInputs(
        vs1, vs2, bucketize(vs1, grid, cap=cap), bucketize(vs2, grid, cap=cap),
    )


def _signatures(spmv_inputs, bfs_inputs, gsana_inputs):
    return [
        ("spmv", spmv_inputs, MigratoryStrategy()),
        ("spmv", spmv_inputs, MigratoryStrategy(replicate_x=False)),
        ("bfs", bfs_inputs, MigratoryStrategy(comm=Comm.MIGRATE)),
        ("bfs", bfs_inputs, MigratoryStrategy(comm=Comm.REMOTE_WRITE)),
        ("gsana", gsana_inputs, MigratoryStrategy(scheme=Scheme.PAIR)),
    ]


def _assert_same_result(got, want):
    if isinstance(want, tuple):
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    else:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("workers", [1, 2, 4])
def test_pool_stress_bit_identical_parity(
    workers, spmv_inputs, bfs_inputs, gsana_inputs
):
    """The acceptance stress: mixed ops, skewed group sizes (one dominant
    plan key so idle workers must steal), concurrent submitters — results
    bit-identical to sequential engine.run at every pool width."""
    signatures = _signatures(spmv_inputs, bfs_inputs, gsana_inputs)
    # skew: signature 0 dominates (steal-inducing), the rest trickle
    requests = [signatures[0]] * 18 + [
        signatures[i % len(signatures)] for i in range(12)
    ]
    svc = EngineService(cache=PlanCache(), workers=workers)
    svc.start()
    futures = {}

    def submitter(idx_chunk):
        for idx in idx_chunk:
            op, inputs, st = requests[idx]
            futures[idx] = svc.submit(op, inputs, st)

    threads = [
        threading.Thread(target=submitter, args=(range(t, len(requests), 3),))
        for t in range(3)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    responses = {idx: fut.result(timeout=600) for idx, fut in futures.items()}
    svc.stop()

    seq_cache = PlanCache()
    expected = {}
    for op, inputs, st in signatures:
        result, _ = run(op, inputs, st, "local", iters=1, warmup=0, cache=seq_cache)
        expected[(op, id(inputs), st)] = result
    for idx, (op, inputs, st) in enumerate(requests):
        _assert_same_result(responses[idx].result, expected[(op, id(inputs), st)])

    stats = svc.stats()
    assert stats.requests == len(requests)
    assert stats.errors == 0 and stats.rejected == 0
    assert stats.workers == workers
    assert stats.compiles == len(signatures)  # one compile per plan key
    assert sum(stats.worker_requests) + stats.compiles == len(requests)


def test_pool_spreads_load_and_steals(spmv_inputs, bfs_inputs):
    """Skewed group sizes on a spread-policy (local) substrate: more than
    one worker ends up serving requests, and the idle ones stole work."""
    svc = EngineService(cache=PlanCache(), workers=4)
    svc.start()
    # warm both keys so the whole burst is executor-pool work
    svc.submit("spmv", spmv_inputs).result(timeout=300)
    svc.submit("bfs", bfs_inputs).result(timeout=300)
    svc.flush(timeout=60)
    # one dominant group (40 members) + a trickle: stealers must split it
    futures = [svc.submit("spmv", spmv_inputs) for _ in range(40)]
    futures += [svc.submit("bfs", bfs_inputs) for _ in range(4)]
    for f in futures:
        f.result(timeout=600)
    svc.stop()
    stats = svc.stats()
    assert stats.workers == 4
    assert stats.steals >= 1
    assert sum(1 for r in stats.worker_requests if r > 0) >= 2
    assert sum(stats.worker_steals) == stats.steals


def test_per_worker_qos_ordering(spmv_inputs, bfs_inputs):
    """Within each worker, groups start in non-increasing QoS-priority
    order inside every scheduler snapshot (the plane's ordering contract:
    ordering, not preemption)."""
    svc = EngineService(
        cache=PlanCache(), workers=2, qos={"bfs": 2.0}, batch_window=0.15
    )
    svc.start()
    # warm first so the measured burst skips compile-stage reordering noise
    svc.submit("spmv", spmv_inputs).result(timeout=300)
    svc.submit("bfs", bfs_inputs).result(timeout=300)
    svc.flush(timeout=60)
    trace_start = len(svc._exec_trace)
    futures = [svc.submit("spmv", spmv_inputs) for _ in range(6)]
    futures += [svc.submit("bfs", bfs_inputs) for _ in range(6)]
    for f in futures:
        f.result(timeout=600)
    svc.stop()
    trace = list(svc._exec_trace)[trace_start:]
    assert trace, "executed groups must be traced"
    by_worker: dict[int, list[float]] = {}
    for worker, first_ticket, qos, stolen in trace:
        # stolen groups arrive opportunistically (tail of a busy peer) and
        # are exempt from the victim's ordering; the worker's OWN dispatch
        # sequence is the contract under test
        if not stolen:
            by_worker.setdefault(worker, []).append(qos)
    assert by_worker, "at least one worker must have served its own queue"
    for worker, own in by_worker.items():
        assert own == sorted(own, reverse=True), (
            f"worker {worker} started groups out of QoS order: {own}"
        )


def test_pool_stats_schema_and_occupancy(spmv_inputs, bfs_inputs):
    svc = EngineService(cache=PlanCache(), workers=2, batch_window=0.02)
    svc.start()
    futures = [
        svc.submit(*(("bfs", bfs_inputs) if i % 2 else ("spmv", spmv_inputs)))
        for i in range(10)
    ]
    for f in futures:
        f.result(timeout=600)
    svc.stop()
    stats = svc.stats()
    assert stats.queue_depth_hwm >= 1
    assert stats.workers == 2
    assert len(stats.worker_busy_seconds) == 2
    assert len(stats.worker_requests) == 2
    assert len(stats.worker_steals) == 2
    assert len(stats.worker_occupancy) == 2
    assert all(0.0 <= occ <= 1.0 + 1e-6 for occ in stats.worker_occupancy)
    assert sum(stats.worker_busy_seconds) >= max(stats.worker_busy_seconds)
    row = stats.to_dict()
    for key in (
        "queue_depth_hwm", "workers", "steals", "worker_busy_seconds",
        "worker_requests", "worker_steals", "worker_occupancy",
        "dedup_coalesced",
    ):
        assert key in row, key


def test_placement_pins_plan_key_to_compiling_slot(spmv_inputs):
    """The cache remembers which slot compiled a key; later groups with the
    same key route back to it (a steal never moves the pin)."""
    cache = PlanCache()
    svc = EngineService(cache=cache, workers=4)
    svc.start()
    svc.submit("spmv", spmv_inputs).result(timeout=300)
    for _ in range(3):
        svc.submit("spmv", spmv_inputs).result(timeout=300)
    svc.stop()
    assert cache.stats()["pinned"] >= 1
    key = next(iter(cache._entries))
    pinned = cache.slot_of(key)
    assert pinned is not None and 0 <= pinned < 4
    assert cache.is_warm(key)


def test_workers_auto_sizes_from_substrate():
    svc = EngineService(workers="auto")
    n = svc._resolve_workers()
    assert 1 <= n <= 8
    assert n == min(8, LocalSubstrate().placement_slots())
    with pytest.raises(ValueError, match="workers"):
        EngineService(workers=0)
    with pytest.raises(ValueError, match="workers"):
        EngineService(workers="many")


def test_placement_table_shape():
    table = placement_table()
    for name in ("local", "mesh", "pallas"):
        assert name in table
        row = table[name]
        assert row["policy"] in ("spread", "affinity")
        assert row["slots"] >= 1
    assert table["mesh"]["policy"] == "affinity"
    assert table["local"]["policy"] == "spread"


def test_placement_variants_local_and_explicit_mesh_are_self():
    local = LocalSubstrate()
    assert local.placement_variant(1, 4) is local
    assert PallasSubstrate().placement_slots() >= 1
    # an explicit mesh is a committed channel set: never carved
    sub = MeshSubstrate()
    assert sub.placement_variant(0, 1) is sub


# -- mesh device windows: per-slot carving, bit-identical (subprocess) ---------

WINDOW_PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.core import Comm, MigratoryStrategy, partition_ell
from repro.engine import EngineService, MeshSubstrate, PlanCache, SpMVInputs, run
from repro.sparse import laplacian_2d

sub = MeshSubstrate()
assert sub.placement_slots() == 8
variants = [sub.placement_variant(s, 4) for s in range(4)]
windows = [v.device_window for v in variants]
assert all(len(w) == 2 for w in windows)
flat = [d for w in windows for d in w]
assert len(set(flat)) == 8, f"windows must be disjoint: {windows}"
assert all(v.cache_fingerprint() != sub.cache_fingerprint() for v in variants)

rng = np.random.default_rng(0)
a = laplacian_2d(16)
x = jnp.asarray(rng.standard_normal(256).astype(np.float32))
inputs = SpMVInputs(partition_ell(a, 2), x)
want, _ = run("spmv", inputs, MigratoryStrategy(), "local", iters=1, warmup=0)
for v in variants:
    got, rep = run("spmv", inputs, MigratoryStrategy(), v, iters=1, warmup=0)
    assert rep.substrate == "mesh"
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

# and through the pooled service: mesh placement routes to device windows
cache = PlanCache()
svc = EngineService(cache=cache, substrate="mesh", workers=4)
svc.start()
futs = [svc.submit("spmv", inputs, MigratoryStrategy()) for _ in range(8)]
futs += [svc.submit("spmv", inputs, MigratoryStrategy(replicate_x=False))
         for _ in range(8)]
resps = [f.result(timeout=600) for f in futs]
svc.stop()
st = svc.stats()
assert st.errors == 0
assert st.steals == 0  # affinity policy: mesh groups are never stolen
for r in resps[:8]:
    np.testing.assert_array_equal(np.asarray(r.result), np.asarray(want))
assert cache.stats()["pinned"] >= 1
print("WINDOW-PARITY-OK")
"""


def test_mesh_device_windows_bit_identical_subprocess():
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", WINDOW_PARITY_SCRIPT],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0 and "WINDOW-PARITY-OK" in proc.stdout, (
        f"rc={proc.returncode}\nstdout={proc.stdout}\nstderr={proc.stderr}"
    )
