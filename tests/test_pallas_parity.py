"""Pallas fast-path parity + block-size autotune axis (ISSUE 7).

Engine-level parity across the strategy x block-size grid: ``("spmv",
"pallas")`` tolerance-pinned (float accumulation order differs per block),
``("bfs", "pallas")`` bit-identical (integer min-scatter). Plus the CSR
stripe variant on skewed rows, the backend-aware interpret default, and
calibrated predicted-seconds ranking over the Pallas grain axis."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Comm, MigratoryStrategy, partition_ell
from repro.engine import (
    PALLAS_BLOCK_CANDIDATES,
    BFSInputs,
    BFSOp,
    SpMVInputs,
    SpMVOp,
    candidate_grid,
    rank_strategies,
    run,
)
from repro.kernels.runtime import default_interpret, resolve_interpret
from repro.kernels.spmv.ops import STRIPE_WASTE_THRESHOLD, spmv
from repro.kernels.spmv.ref import spmv_ell_reference
from repro.kernels.spmv.stripe import build_stripe_plan, spmv_ell_stripes
from repro.machine.machine import DEFAULT_PROFILE
from repro.sparse import (
    edges_to_csr,
    ell_from_csr,
    erdos_renyi_edges,
    laplacian_2d,
    partition_graph,
    skewed_matrix,
    spmv_csr_ref,
)


@pytest.fixture(scope="module")
def spmv_problem():
    a = laplacian_2d(12)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(144).astype(np.float32))
    return SpMVInputs(partition_ell(a, 8), x)


@pytest.fixture(scope="module")
def bfs_problem():
    g = edges_to_csr(erdos_renyi_edges(8, 6, seed=2), 256)
    return BFSInputs(partition_graph(g, 8), 3)


# -- engine parity across the strategy x block-size grid -----------------------


@pytest.mark.parametrize("grain", PALLAS_BLOCK_CANDIDATES)
@pytest.mark.parametrize("comm", [Comm.MIGRATE, Comm.REMOTE_WRITE])
def test_spmv_pallas_parity_across_grid(spmv_problem, grain, comm):
    st = MigratoryStrategy(comm=comm, grain=grain)
    y_local, _ = run(SpMVOp(), spmv_problem, st, "local")
    y_pallas, report = run(SpMVOp(), spmv_problem, st, "pallas")
    np.testing.assert_allclose(
        np.asarray(y_local), np.asarray(y_pallas), rtol=1e-5, atol=1e-5
    )
    assert report.substrate == "pallas"


@pytest.mark.parametrize("grain", PALLAS_BLOCK_CANDIDATES)
def test_bfs_pallas_parity_across_grid(bfs_problem, grain):
    st = MigratoryStrategy(grain=grain)
    p_local, _ = run(BFSOp(), bfs_problem, st, "local")
    p_pallas, _ = run(BFSOp(), bfs_problem, st, "pallas")
    np.testing.assert_array_equal(np.asarray(p_local), np.asarray(p_pallas))


# -- CSR stripe variant on skewed rows -----------------------------------------


@pytest.fixture(scope="module")
def skewed_ell():
    a = skewed_matrix(512, avg_deg=4.0, max_deg=128, seed=9)
    e = ell_from_csr(a)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(512).astype(np.float32))
    return a, e, x


def test_stripe_plan_shapes(skewed_ell):
    _, e, _ = skewed_ell
    plan = build_stripe_plan(e.cols, block_rows=64)
    assert plan.n_rows == e.cols.shape[0] and plan.k_full == e.cols.shape[1]
    covered = sorted(r for b in plan.buckets for r in np.asarray(b.rows).tolist())
    assert covered == list(range(plan.n_rows))  # every row in exactly one stripe
    for b in plan.buckets:
        # stripe widths are powers of two, capped at the full ELL width
        assert b.k == plan.k_full or b.k & max(0, b.k - 1) == 0
    # skewed rows leave the dense ELL mostly padding -> stripes shed it
    assert plan.waste_ratio >= STRIPE_WASTE_THRESHOLD
    assert plan.padded_slots < e.cols.shape[0] * e.cols.shape[1]


def test_stripe_spmv_matches_reference(skewed_ell):
    a, e, x = skewed_ell
    want = np.asarray(spmv_csr_ref(a, x))
    for block_rows in (32, 64, 200):
        got = np.asarray(spmv_ell_stripes(e.cols, e.vals, x, block_rows=block_rows))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_spmv_variant_auto_picks_stripes_when_skewed(skewed_ell):
    a, e, x = skewed_ell
    want = np.asarray(spmv_csr_ref(a, x))
    got = np.asarray(spmv(e.cols, e.vals, x, grain=64, variant="auto"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    # uniform rows stay on the dense ELL kernel; both variants agree there
    u = partition_ell(laplacian_2d(8), 1)
    xu = jnp.asarray(np.random.default_rng(2).standard_normal(64).astype(np.float32))
    assert build_stripe_plan(u.cols[0], block_rows=16).waste_ratio < STRIPE_WASTE_THRESHOLD
    np.testing.assert_allclose(
        np.asarray(spmv(u.cols[0], u.vals[0], xu, grain=16, variant="auto")),
        np.asarray(spmv_ell_reference(u.cols[0], u.vals[0], xu)),
        rtol=1e-5, atol=1e-5,
    )
    with pytest.raises(ValueError, match="variant"):
        spmv(e.cols, e.vals, x, variant="csr5")


# -- backend-aware interpret default -------------------------------------------


def test_default_interpret_is_backend_aware():
    assert default_interpret("tpu") is False
    assert default_interpret("gpu") is False
    assert default_interpret("cpu") is True
    assert resolve_interpret(True) is True and resolve_interpret(False) is False
    # None resolves from the live backend; on the CPU test host that is
    # interpret mode, and PallasSubstrate bakes the resolved value in
    assert resolve_interpret(None) == default_interpret(jax.default_backend())
    from repro.engine import PallasSubstrate

    assert PallasSubstrate().interpret == default_interpret(jax.default_backend())
    assert PallasSubstrate(interpret=False).interpret is False


# -- calibrated predicted-seconds ranking over the grain axis ------------------


def test_calibrated_ranking_orders_pallas_block_sizes(spmv_problem, bfs_problem):
    """With a calibrated machine file the autotuner ranks the Pallas grid
    in predicted seconds, and every block-size candidate gets its own
    prediction (the substrate-targeted working set varies with grain)."""
    profile = dataclasses.replace(DEFAULT_PROFILE, calibrated=True)
    for op, inputs in ((SpMVOp(), spmv_problem), (BFSOp(), bfs_problem)):
        grid = candidate_grid(op.name, "pallas")
        assert {st.grain for st in grid} == set(PALLAS_BLOCK_CANDIDATES)
        ranked = rank_strategies(op, inputs, grid, substrate="pallas", machine=profile)
        secs = [e.predicted_seconds for e in ranked]
        assert all(s is not None and s > 0 for s in secs)
        assert secs == sorted(secs)
        # the grain axis is visible to the model: per-launch working sets
        # differ across block sizes, so predictions are not all ties
        by_grain = {
            e.strategy.grain: e.detail["substrate_memory"]["pallas"]["bytes_per_launch"]
            for e in ranked
        }
        assert len(set(by_grain.values())) > 1
        # uncalibrated stays bit-identical to traffic-unit ranking
        plain = rank_strategies(op, inputs, grid, substrate="pallas")
        assert all(e.predicted_seconds is None for e in plain)
