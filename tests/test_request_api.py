"""The unified ``Request`` entry shape (ISSUE 8): one dataclass drives both
``engine.run`` and ``EngineService.submit`` (batch and worker modes alike),
the legacy kwargs spellings survive as thin deprecated wrappers that warn
and produce identical results, per-request ``qos`` overrides the service's
per-op weight table, and per-request ``timeout`` sheds expired work with a
typed ``ServiceTimeout`` counted in the stats.
"""
import time
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MigratoryStrategy, partition_ell
from repro.engine import (
    EngineService,
    PlanCache,
    Request,
    ServiceTimeout,
    SpMVInputs,
    run,
)
from repro.sparse import laplacian_2d


@pytest.fixture(scope="module")
def spmv_inputs():
    a = laplacian_2d(12)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(144).astype(np.float32))
    return SpMVInputs(partition_ell(a, 8), x)


# -- Request construction and validation ---------------------------------------


def test_request_validates_qos_and_timeout(spmv_inputs):
    Request("spmv", spmv_inputs, qos=2.0, timeout=1.0)  # fine
    with pytest.raises(ValueError, match="qos"):
        Request("spmv", spmv_inputs, qos=0.0)
    with pytest.raises(ValueError, match="qos"):
        Request("spmv", spmv_inputs, qos=-1.0)
    with pytest.raises(ValueError, match="timeout"):
        Request("spmv", spmv_inputs, timeout=-0.5)


def test_request_mixed_with_positional_args_is_a_type_error(spmv_inputs):
    """Passing a Request AND the legacy positional fields is ambiguous —
    rejected loudly rather than silently preferring one side."""
    req = Request("spmv", spmv_inputs)
    with pytest.raises(TypeError):
        run(req, spmv_inputs)
    svc = EngineService()
    with pytest.raises(TypeError):
        svc.submit(req, spmv_inputs)


# -- engine.run equivalence ----------------------------------------------------


def test_run_kwargs_form_warns_and_matches_request_form(spmv_inputs):
    st = MigratoryStrategy()
    y_req, rep_req = run(
        Request("spmv", spmv_inputs, st, "local"),
        iters=1, warmup=0, cache=PlanCache(),
    )
    with pytest.warns(DeprecationWarning, match="Request"):
        y_kw, rep_kw = run(
            "spmv", spmv_inputs, st, "local", iters=1, warmup=0, cache=PlanCache(),
        )
    np.testing.assert_array_equal(np.asarray(y_req), np.asarray(y_kw))
    assert rep_req.traffic.total_bytes == rep_kw.traffic.total_bytes
    assert rep_req.substrate == rep_kw.substrate == "local"


def test_run_request_form_does_not_warn(spmv_inputs):
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        run(Request("spmv", spmv_inputs), iters=1, warmup=0, cache=PlanCache())


# -- EngineService.submit equivalence ------------------------------------------


def test_submit_kwargs_form_warns_and_matches_request_form_batch(spmv_inputs):
    st = MigratoryStrategy(replicate_x=False)
    svc = EngineService(cache=PlanCache())
    t1 = svc.submit(Request("spmv", spmv_inputs, st))
    with pytest.warns(DeprecationWarning, match="Request"):
        t2 = svc.submit("spmv", spmv_inputs, st)
    responses = {r.ticket: r for r in svc.drain()}
    np.testing.assert_array_equal(
        np.asarray(responses[t1].result), np.asarray(responses[t2].result)
    )


def test_submit_request_form_worker_loop(spmv_inputs):
    svc = EngineService(cache=PlanCache())
    svc.start()
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            fut = svc.submit(Request("spmv", spmv_inputs))
            resp = fut.result(timeout=600)
    finally:
        svc.stop()
    seq, _ = run(
        Request("spmv", spmv_inputs), iters=1, warmup=0, cache=PlanCache(),
    )
    np.testing.assert_array_equal(np.asarray(resp.result), np.asarray(seq))


# -- per-request qos and timeout -----------------------------------------------


def test_per_request_qos_splits_scheduling_groups(spmv_inputs):
    """Per-request qos is part of the scheduling-group identity: identical
    requests share one batch, but a boosted duplicate forms its own group
    (so its weight orders it independently) while results stay identical."""
    same = EngineService(cache=PlanCache())
    same.submit(Request("spmv", spmv_inputs))
    same.submit(Request("spmv", spmv_inputs))
    r_same = same.drain()
    assert same.stats().batches == 1  # one signature, one group

    split = EngineService(cache=PlanCache())
    split.submit(Request("spmv", spmv_inputs))
    split.submit(Request("spmv", spmv_inputs, qos=100.0))
    r_split = split.drain()
    assert split.stats().batches == 2  # qos=100 group scheduled separately
    for a, b in ((r_same[0], r_same[1]), (r_split[0], r_split[1])):
        np.testing.assert_array_equal(np.asarray(a.result), np.asarray(b.result))


def test_per_request_timeout_sheds_expired_work(spmv_inputs):
    """A request whose deadline passed before execution is rejected with
    ServiceTimeout and counted in stats.timed_out, never silently served."""
    svc = EngineService(cache=PlanCache(), batch_window=0.3)
    svc.start()
    try:
        fut = svc.submit(Request("spmv", spmv_inputs, timeout=0.01))
        time.sleep(0.1)  # let the deadline lapse inside the batch window
        with pytest.raises(ServiceTimeout):
            fut.result(timeout=600)
        # the service keeps serving: an undeadlined request still completes
        ok = svc.submit(Request("spmv", spmv_inputs)).result(timeout=600)
        assert ok.result is not None
    finally:
        svc.stop()
    stats = svc.stats()
    assert stats.timed_out == 1


# -- SLO accounting ------------------------------------------------------------


def test_slo_stats_accounting(spmv_inputs):
    """With a declared slo_target_seconds every completed request is checked:
    a generous target shows full attainment, an impossible one shows zero,
    and the end-to-end (queue-wait + service) percentiles are populated."""
    svc = EngineService(cache=PlanCache(), slo_target_seconds=600.0)
    svc.start()
    try:
        futs = [svc.submit(Request("spmv", spmv_inputs)) for _ in range(4)]
        for f in futs:
            f.result(timeout=600)
    finally:
        svc.stop()
    stats = svc.stats()
    assert stats.slo_target_seconds == 600.0
    assert stats.slo_checked == 4
    assert stats.slo_violations == 0
    assert stats.slo_attainment == 1.0
    assert stats.total_p99 >= stats.total_p50 > 0.0
    # end-to-end latency can never be under the pure service time
    assert stats.total_p99 >= stats.service_p50
    d = stats.to_dict()
    for key in ("slo_checked", "slo_violations", "slo_attainment",
                "total_p50", "total_p95", "total_p99", "timed_out"):
        assert key in d

    tight = EngineService(cache=PlanCache(), slo_target_seconds=1e-12)
    tight.start()
    try:
        tight.submit(Request("spmv", spmv_inputs)).result(timeout=600)
    finally:
        tight.stop()
    tstats = tight.stats()
    assert tstats.slo_checked == 1
    assert tstats.slo_violations == 1
    assert tstats.slo_attainment == 0.0


def test_no_slo_target_means_no_slo_accounting(spmv_inputs):
    svc = EngineService(cache=PlanCache())
    svc.submit(Request("spmv", spmv_inputs))
    svc.drain()
    stats = svc.stats()
    assert stats.slo_target_seconds is None
    assert stats.slo_checked == 0
    assert stats.slo_attainment is None
