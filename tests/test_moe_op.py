"""MoE dispatch as the fourth MigratoryOp.

ISSUE 4 acceptance: ``moe_dispatch`` registers without modifying any
Substrate subclass; ``EngineService.submit("moe_dispatch", ...,
strategy="auto")`` returns results bit-identical to calling
``dispatch_from_strategy`` directly (the :func:`moe_dispatch_reference`
oracle); and the autotuner's chosen mode matches an exhaustive measured
sweep on >= 2 (batch, experts, mesh) scenarios.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Comm, MigratoryStrategy
from repro.engine import (
    EngineService,
    MoEDispatchInputs,
    MoEDispatchOp,
    OpNotSupportedError,
    PlanCache,
    candidate_grid,
    choose_strategy,
    get_substrate,
    moe_dispatch_reference,
    run,
)
from repro.models.moe import dispatch_from_strategy


def _inputs(T: int, D: int, E: int, P: int, seed: int = 7) -> MoEDispatchInputs:
    rng = np.random.default_rng(seed)
    return MoEDispatchInputs(
        x=jnp.asarray(rng.standard_normal((T, D)).astype(np.float32)),
        router=jnp.asarray(rng.standard_normal((D, E)).astype(np.float32)),
        nodelets=P,
    )


# (tokens, d_model, experts, nodelets): two ep-capable scenarios with
# different batch/expert/mesh shapes + one tp-fallback scenario
SCENARIOS = [
    ("t128_e16_p8", (128, 32, 16, 8)),
    ("t256_e8_p4", (256, 24, 8, 4)),
    ("t120_e6_p4_tp", (120, 16, 6, 4)),
]


@pytest.mark.parametrize("name,shape", SCENARIOS)
def test_choose_strategy_matches_exhaustive_measured_sweep(name, shape):
    """ISSUE 4 acceptance: the analytic pick achieves the minimum *measured*
    traffic over an exhaustive engine sweep of the moe candidate grid, and
    the chosen dispatch mode equals the sweep winner's mode."""
    inputs = _inputs(*shape)
    chosen = choose_strategy("moe_dispatch", inputs)
    cache = PlanCache()
    measured = {}
    for st in candidate_grid("moe_dispatch"):
        _, rep = run("moe_dispatch", inputs, st, "local", iters=1, warmup=0, cache=cache)
        measured[st] = rep
    min_traffic = min(r.traffic.total_bytes for r in measured.values())
    assert chosen in measured
    assert measured[chosen].traffic.total_bytes == min_traffic
    chosen_mode = dispatch_from_strategy(
        chosen, num_experts=inputs.num_experts, data_axis=inputs.nodelets
    )
    best_modes = {
        r.metrics["dispatch_mode"]
        for r in measured.values()
        if r.traffic.total_bytes == min_traffic
    }
    assert chosen_mode in best_modes


def test_push_beats_pull_when_divisible():
    """Paper §5.2 at LM scale: all_to_all packets (remote write) move less
    than all_gathering every token to every owner (migrate) — so auto picks
    REMOTE_WRITE -> ep_push whenever expert parallelism is available."""
    inputs = _inputs(128, 32, 16, 8)
    st = choose_strategy("moe_dispatch", inputs)
    assert st.comm == Comm.REMOTE_WRITE
    assert dispatch_from_strategy(st, num_experts=16, data_axis=8) == "ep_push"


def test_mode_mapping_and_metrics():
    """The engine's mode metric is exactly dispatch_from_strategy's answer,
    and tp fallback reports zero modeled traffic (node-local dispatch)."""
    inputs = _inputs(128, 32, 16, 8)
    for comm, want in ((Comm.MIGRATE, "ep_pull"), (Comm.REMOTE_WRITE, "ep_push")):
        st = MigratoryStrategy(comm=comm)
        _, rep = run("moe_dispatch", inputs, st, "local", cache=PlanCache())
        assert rep.metrics["dispatch_mode"] == want
        assert rep.metrics["dispatch_mode"] == dispatch_from_strategy(
            st, num_experts=16, data_axis=8
        )
        assert rep.traffic.total_bytes > 0
    tp_inputs = _inputs(120, 16, 6, 4)
    _, rep = run("moe_dispatch", tp_inputs, MigratoryStrategy(), "local", cache=PlanCache())
    assert rep.metrics["dispatch_mode"] == "tp"
    assert rep.traffic.total_bytes == 0
    assert 0.0 <= rep.metrics["drop_fraction"] < 1.0


def test_served_through_async_service_bit_identical():
    """ISSUE 4 acceptance: EngineService.submit("moe_dispatch", ...,
    strategy="auto") == the direct dispatch_from_strategy path, bitwise."""
    inputs = _inputs(128, 32, 16, 8)
    direct = moe_dispatch_reference(inputs, choose_strategy("moe_dispatch", inputs))
    svc = EngineService(cache=PlanCache())
    svc.start()
    try:
        futures = [svc.submit("moe_dispatch", inputs, "auto") for _ in range(4)]
        responses = [f.result(timeout=600) for f in futures]
    finally:
        svc.stop()
    for resp in responses:
        assert resp.report.op == "moe_dispatch"
        np.testing.assert_array_equal(np.asarray(resp.result), np.asarray(direct))
    # and the batched drain path agrees too
    batch_svc = EngineService(cache=PlanCache())
    batch_svc.submit("moe_dispatch", inputs, "auto")
    (resp,) = batch_svc.drain()
    np.testing.assert_array_equal(np.asarray(resp.result), np.asarray(direct))


def test_moe_dispatch_unsupported_on_pallas_and_bad_shapes():
    inputs = _inputs(128, 32, 16, 8)
    with pytest.raises(OpNotSupportedError):
        run("moe_dispatch", inputs, None, "pallas")
    with pytest.raises(ValueError, match="nodelets"):
        MoEDispatchOp().plan(
            _inputs(130, 32, 16, 8), MigratoryStrategy(), get_substrate("local")
        )


def test_plan_cache_reuses_moe_executor():
    """Same shapes + strategy + substrate -> plan-cache hit; different comm
    (a different dispatch mode) -> distinct entry."""
    inputs = _inputs(128, 32, 16, 8)
    cache = PlanCache()
    _, r1 = run("moe_dispatch", inputs, MigratoryStrategy(), "local", cache=cache)
    _, r2 = run("moe_dispatch", inputs, MigratoryStrategy(), "local", cache=cache)
    assert not r1.cache_hit and r2.cache_hit
    _, r3 = run(
        "moe_dispatch", inputs, MigratoryStrategy(comm=Comm.MIGRATE), "local",
        cache=cache,
    )
    assert not r3.cache_hit
    assert len(cache) == 2


def test_mesh_kernel_rejects_mismatched_explicit_mesh():
    """An explicit substrate mesh narrower than inputs.nodelets must raise,
    not silently shard mis-sized capacity buffers."""
    from repro.engine import MeshSubstrate
    from repro.launch.mesh import make_nodelet_mesh

    inputs = _inputs(128, 32, 16, 8)  # nodelets=8
    sub = MeshSubstrate(mesh=make_nodelet_mesh(1))  # 1-device explicit mesh
    with pytest.raises(OpNotSupportedError, match="8-way"):
        run("moe_dispatch", inputs, MigratoryStrategy(), sub, cache=PlanCache())
