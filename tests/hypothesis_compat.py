"""Optional-hypothesis shim (install the ``test`` extra for property tests).

``hypothesis`` is a test-extra dependency (``pip install .[test]``), not a
runtime one. Importing it unguarded makes the whole suite fail to collect on
a bare install, so test modules import ``given``/``settings``/``st`` from
here instead: with hypothesis present this is a pass-through; without it the
property tests are collected as skips (the rest of each module still runs).
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAS_HYPOTHESIS = True
except ModuleNotFoundError:  # test extra not installed
    HAS_HYPOTHESIS = False

    _SKIP = pytest.mark.skip(
        reason="hypothesis not installed (pip install '.[test]')"
    )

    def given(*_args, **_kwargs):  # noqa: D103 - mirrors hypothesis.given
        def decorate(fn):
            # drop the property arguments: the test body never runs
            def skipped():
                pass  # pragma: no cover

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return _SKIP(skipped)

        return decorate

    def settings(*_args, **_kwargs):  # noqa: D103 - mirrors hypothesis.settings
        return lambda fn: fn

    class _StrategyStub:
        """Stands in for ``hypothesis.strategies`` at decoration time."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()


def require_hypothesis():
    """``pytest.importorskip``-style guard for tests that call hypothesis
    APIs imperatively (rather than through the decorators above)."""
    return pytest.importorskip("hypothesis")
