"""Pallas SpMV kernel vs pure-jnp oracle: shape/dtype/grain sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels.spmv.ops import spmv
from repro.kernels.spmv.ref import spmv_ell_reference
from repro.core import MigratoryStrategy, partition_ell
from repro.sparse import laplacian_2d, spmv_csr_ref


def _rand_ell(rng, r, k, n, dtype):
    cols = rng.integers(-1, n, size=(r, k)).astype(np.int32)
    vals = np.where(cols >= 0, rng.standard_normal((r, k)), 0).astype(dtype)
    x = rng.standard_normal(n).astype(dtype)
    return jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize("r,k,n,grain", [
    (64, 5, 64, 16),
    (100, 7, 128, 32),   # rows not a multiple of grain (padding path)
    (256, 1, 32, 256),   # K=1
    (8, 16, 1024, 4),    # wide rows, small grain
])
def test_spmv_kernel_matches_ref(dtype, r, k, n, grain):
    rng = np.random.default_rng(r * k + n)
    cols, vals, x = _rand_ell(rng, r, k, n, dtype)
    y_k = spmv(cols, vals, x, grain=grain)
    y_r = spmv_ell_reference(cols, vals, x)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=1e-5, atol=1e-5)


def test_spmv_kernel_bf16():
    rng = np.random.default_rng(0)
    cols, vals, x = _rand_ell(rng, 64, 4, 64, np.float32)
    y_k = spmv(cols, vals.astype(jnp.bfloat16), x.astype(jnp.bfloat16), grain=16)
    y_r = spmv_ell_reference(cols, vals, x)
    np.testing.assert_allclose(
        np.asarray(y_k.astype(jnp.float32)), np.asarray(y_r), rtol=0.1, atol=0.1
    )


def test_spmv_kernel_grain_invariance():
    """Paper Fig. 4: grain changes scheduling, never the result."""
    rng = np.random.default_rng(1)
    cols, vals, x = _rand_ell(rng, 96, 6, 96, np.float32)
    ys = [np.asarray(spmv(cols, vals, x, grain=g)) for g in (1, 2, 16, 96, 512)]
    for y in ys[1:]:
        np.testing.assert_allclose(y, ys[0], rtol=1e-6)


def test_spmv_kernel_vs_csr_pipeline():
    """End-to-end: CSR -> partitioned ELL planes -> kernel == CSR oracle."""
    a = laplacian_2d(10)
    pe = partition_ell(a, 4)
    n = 100
    x = jnp.asarray(np.random.default_rng(2).standard_normal(n).astype(np.float32))
    ref = np.asarray(spmv_csr_ref(a, x))
    for p in range(4):
        y = np.asarray(spmv(pe.cols[p], pe.vals[p], x, grain=8))
        rows = np.arange(p, n, 4)
        np.testing.assert_allclose(y[: len(rows)], ref[rows], rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    r=st.integers(1, 80),
    k=st.integers(1, 12),
    n=st.integers(4, 200),
    grain=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_spmv_kernel(r, k, n, grain, seed):
    rng = np.random.default_rng(seed)
    cols, vals, x = _rand_ell(rng, r, k, n, np.float32)
    y_k = spmv(cols, vals, x, grain=grain)
    y_r = spmv_ell_reference(cols, vals, x)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=1e-4, atol=1e-4)
