"""Core BFS: S2 remote-write strategy — correctness + traffic ordering."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (
    Comm, MigratoryStrategy, bfs, bfs_effective_bandwidth, bfs_traffic, teps,
    validate_parents,
)
from repro.sparse import edges_to_csr, erdos_renyi_edges, partition_graph, rmat_edges


def _ref_bfs_levels(adj_csr, root):
    """Plain numpy BFS levels oracle."""
    indptr = np.asarray(adj_csr.indptr)
    indices = np.asarray(adj_csr.indices)
    n = adj_csr.n_rows
    level = np.full(n, -1)
    level[root] = 0
    frontier = [root]
    l = 0
    while frontier:
        nxt = []
        for u in frontier:
            for v in indices[indptr[u]:indptr[u + 1]]:
                if level[v] < 0:
                    level[v] = l + 1
                    nxt.append(v)
        frontier = nxt
        l += 1
    return level


@pytest.mark.parametrize("gen,scale", [("er", 8), ("rmat", 8)])
def test_bfs_matches_reference_reachability(gen, scale):
    n = 1 << scale
    edges = erdos_renyi_edges(scale, 8, seed=0) if gen == "er" else rmat_edges(scale, 8, seed=0)
    g = edges_to_csr(edges, n)
    pg = partition_graph(g, 8)
    parents = np.asarray(bfs(pg, 0))
    ref_level = _ref_bfs_levels(g, 0)
    assert ((parents >= 0) == (ref_level >= 0)).all()
    assert validate_parents(pg, 0, parents)


def test_bfs_parent_levels_are_minimal():
    """Level-synchronous min-merge must produce shortest-path levels."""
    n = 256
    g = edges_to_csr(erdos_renyi_edges(8, 4, seed=5), n)
    pg = partition_graph(g, 4)
    parents = np.asarray(bfs(pg, 7))
    ref_level = _ref_bfs_levels(g, 7)
    # derive level from parent chain
    for v in range(n):
        if parents[v] < 0 or v == 7:
            continue
        lv, u = 0, v
        while u != 7 and lv <= n:
            u = parents[u]
            lv += 1
        assert lv == ref_level[v], f"vertex {v}: {lv} != {ref_level[v]}"


def test_remote_write_traffic_beats_migrate():
    """Paper Fig. 7: put packets are far cheaper than thread migrations."""
    g = edges_to_csr(erdos_renyi_edges(10, 16, seed=1), 1024)
    pg = partition_graph(g, 8)
    t_mig = bfs_traffic(pg, 0, MigratoryStrategy(comm=Comm.MIGRATE))
    t_rw = bfs_traffic(pg, 0, MigratoryStrategy(comm=Comm.REMOTE_WRITE))
    assert t_rw.traffic.total_bytes < t_mig.traffic.total_bytes / 5
    assert t_mig.rounds == t_rw.rounds
    assert t_mig.edges_traversed == t_rw.edges_traversed


def test_metrics():
    assert teps(100, 2.0) == 50.0
    assert bfs_effective_bandwidth(10, 1.0) == 16 * 1024 * 16


@settings(max_examples=15, deadline=None)
@given(
    scale=st.integers(5, 8),
    ef=st.integers(2, 8),
    p=st.sampled_from([2, 4, 8]),
    root_seed=st.integers(0, 10**6),
)
def test_property_bfs_tree_valid(scale, ef, p, root_seed):
    """Invariant: any produced parent array is a valid BFS tree with full
    reachable coverage, regardless of partitioning."""
    n = 1 << scale
    g = edges_to_csr(erdos_renyi_edges(scale, ef, seed=root_seed % 17), n)
    pg = partition_graph(g, p)
    root = root_seed % n
    parents = np.asarray(bfs(pg, root))
    assert validate_parents(pg, root, parents)
    ref = _ref_bfs_levels(g, root)
    assert ((parents >= 0) == (ref >= 0)).all()
