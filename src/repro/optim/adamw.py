"""AdamW with decoupled weight decay, global-norm clipping, and optional
error-feedback int8 gradient compression for the cross-pod all-reduce.

The compression hook implements the standard EF-SGD trick: quantize the
gradient to int8 with a per-tensor scale, carry the quantization residual in
the optimizer state, add it back next step. At 1000+ node scale the cross-pod
gradient reduction is the slowest collective (lowest-bandwidth links); 4x
smaller payloads move the collective roofline term down proportionally.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # () int32
    mu: Any  # pytree like params (f32)
    nu: Any  # pytree like params (f32)
    ef_residual: Any | None  # error-feedback residual (None if compression off)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    compress_grads: bool = False  # int8 EF compression (cross-pod trick)


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init(params: Any, cfg: AdamWConfig) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    ef = (
        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if cfg.compress_grads
        else None
    )
    nu = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=nu, ef_residual=ef)


def _quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(g: jax.Array, residual: jax.Array) -> tuple[jax.Array, jax.Array]:
    """EF int8 round-trip: returns (decompressed grad, new residual)."""
    g_ef = g + residual
    q, scale = _quantize_int8(g_ef)
    deq = q.astype(jnp.float32) * scale
    return deq, g_ef - deq


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(0, 1))
def apply_updates(
    params: Any, state: AdamWState, grads: Any, cfg: AdamWConfig
) -> tuple[Any, AdamWState, dict[str, jax.Array]]:
    """One AdamW step. Returns (params, state, metrics)."""
    step = state.step + 1
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    ef = state.ef_residual
    if cfg.compress_grads:
        out = jax.tree.map(compress_decompress, grads, ef)
        grads = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        ef = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))

    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda n, g: cfg.b2 * n + (1 - cfg.b2) * g * g, state.nu, grads)

    def upd(p, m, n):
        mhat = m / b1c
        nhat = n / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return (
        new_params,
        AdamWState(step=step, mu=mu, nu=nu, ef_residual=ef),
        {"grad_norm": gnorm, "lr": lr},
    )
