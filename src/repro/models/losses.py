"""Chunked cross-entropy: never materializes the full (B, S, V) logits.

The unembed + CE over a 100k+ vocab dominates training memory if done in one
shot (f32 logits + their backward). Chunking the sequence through a rematted
scan bounds the live logits to (B, chunk, V/model_shards) and recomputes them
in the backward pass — the standard production trick.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import Ctx

CE_CHUNK = 512


def chunked_cross_entropy(
    ctx: Ctx, x: jax.Array, lm_head: jax.Array, labels: jax.Array,
    chunk: int = CE_CHUNK,
) -> jax.Array:
    """x: (B, S, D) final-normed activations; labels: (B, S) (-1 = pad).

    Returns mean CE over non-pad positions.
    """
    b, s, d = x.shape
    c = min(chunk, s)
    s_pad = -(-s // c) * c
    if s_pad != s:
        x = jnp.pad(x, ((0, 0), (0, s_pad - s), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, s_pad - s)), constant_values=-1)
    nc = s_pad // c
    # gather the (possibly seq-sharded) stream once, then slice chunks on an
    # unsharded leading dim (scan-friendly under GSPMD)
    x = ctx.cs(x, "batch", None, None)
    xc = x.reshape(b, nc, c, d).transpose(1, 0, 2, 3)  # (nc, B, c, D)
    lc = labels.reshape(b, nc, c).transpose(1, 0, 2)

    def chunk_loss(carry, inp):
        xi, li = inp  # (B, c, D), (B, c)
        logits = jnp.einsum("bcd,dv->bcv", xi, lm_head).astype(jnp.float32)
        logits = ctx.cs(logits, "batch", None, "vocab")
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(li, 0)[..., None], axis=-1
        )[..., 0]
        mask = (li >= 0).astype(jnp.float32)
        tot, cnt = carry
        return (tot + jnp.sum((logz - gold) * mask), cnt + jnp.sum(mask)), None

    body = jax.checkpoint(chunk_loss, policy=jax.checkpoint_policies.nothing_saveable)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (xc, lc))
    return tot / jnp.maximum(cnt, 1.0)
