"""RWKV-6 "Finch": data-dependent-decay linear attention (attention-free).

TPU-native chunked formulation (DESIGN.md §2): the per-token recurrence
S_t = diag(w_t) S_{t-1} + k_t v_t^T is evaluated in chunks of ``ssm_chunk``
tokens — intra-chunk contributions via an MXU (c x c) matmul with decay
ratios exp(L_{t-1} - L_i) (f32, L = cumsum log w), inter-chunk via the carried
per-head state (M x M). A lax.scan over chunks replaces the Emu-style
per-element walk; decode uses the exact single-step recurrence.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Ctx, _dt, norm_params, rmsnorm

HEAD = 64  # rwkv6 head size M


class RWKVState(NamedTuple):
    s: jax.Array  # (L, B, H, M, M) wkv state
    tm_x: jax.Array  # (L, B, D) last input seen by time-mix (token shift)
    cm_x: jax.Array  # (L, B, D) last input seen by channel-mix


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    d, f, l = cfg.d_model, cfg.d_ff, cfg.num_layers
    dt = _dt(cfg)
    init = jax.nn.initializers.normal(0.02)
    ks = jax.random.split(key, 12)
    lora = 32
    return {
        "embed": init(ks[0], (cfg.vocab_size, d), dt),
        "blocks": {
            "ln1": norm_params(cfg, d, (l,)),
            "ln2": norm_params(cfg, d, (l,)),
            # time-mix
            "mu_r": jnp.full((l, d), 0.5, dt), "mu_k": jnp.full((l, d), 0.5, dt),
            "mu_v": jnp.full((l, d), 0.5, dt), "mu_w": jnp.full((l, d), 0.5, dt),
            "mu_g": jnp.full((l, d), 0.5, dt),
            "w_r": init(ks[1], (l, d, d), dt), "w_k": init(ks[2], (l, d, d), dt),
            "w_v": init(ks[3], (l, d, d), dt), "w_g": init(ks[4], (l, d, d), dt),
            "w_o": init(ks[5], (l, d, d), dt),
            "w_decay": jnp.full((l, d), -1.0, jnp.float32),  # base log-decay
            "w_lora_a": init(ks[6], (l, d, lora), dt),
            "w_lora_b": init(ks[7], (l, lora, d), jnp.float32),
            "u_bonus": jnp.zeros((l, d), jnp.float32),
            "ln_x": norm_params(cfg, d, (l,)),  # per-head group norm (rms)
            # channel-mix
            "cmu_k": jnp.full((l, d), 0.5, dt), "cmu_r": jnp.full((l, d), 0.5, dt),
            "cw_k": init(ks[8], (l, d, f), dt),
            "cw_v": init(ks[9], (l, f, d), dt),
            "cw_r": init(ks[10], (l, d, d), dt),
        },
        "final_norm": norm_params(cfg, d),
        "lm_head": init(ks[11], (d, cfg.vocab_size), dt),
    }


def param_specs(cfg: ModelConfig) -> dict:
    L = None
    vec = (L, "heads")  # (l, d) vectors shard with the head dim
    return {
        "embed": ("vocab", "fsdp"),
        "blocks": {
            "ln1": {"w": (L, None)}, "ln2": {"w": (L, None)},
            "mu_r": vec, "mu_k": vec, "mu_v": vec, "mu_w": vec, "mu_g": vec,
            "w_r": (L, "fsdp", "heads"), "w_k": (L, "fsdp", "heads"),
            "w_v": (L, "fsdp", "heads"), "w_g": (L, "fsdp", "heads"),
            "w_o": (L, "heads", "fsdp"),
            "w_decay": vec, "w_lora_a": (L, "fsdp", None), "w_lora_b": (L, None, "heads"),
            "u_bonus": vec, "ln_x": {"w": (L, None)},
            "cmu_k": vec, "cmu_r": vec,
            "cw_k": (L, "fsdp", "d_ff"), "cw_v": (L, "d_ff", "fsdp"),
            "cw_r": (L, "fsdp", "heads"),
        },
        "final_norm": {"w": (None,)},
        "lm_head": ("fsdp", "vocab"),
    }


def _shift(x: jax.Array, last: jax.Array | None) -> jax.Array:
    """Token shift: x_{t-1} stream; ``last`` carries across calls (decode)."""
    head = jnp.zeros_like(x[:, :1]) if last is None else last[:, None, :].astype(x.dtype)
    return jnp.concatenate([head, x[:, :-1]], axis=1)


def _time_mix_chunked(
    ctx: Ctx, p: dict, x: jax.Array, s0: jax.Array, tm_last: jax.Array | None
):
    """x: (B, S, D) -> (out (B, S, D), s_final (B, H, M, M), new_tm_last)."""
    cfg = ctx.cfg
    b, s, d = x.shape
    h = d // HEAD
    c = min(cfg.ssm_chunk, s)
    xs = _shift(x, tm_last)

    def mix(mu):
        return x * mu + xs * (1 - mu)

    r = jnp.einsum("bsd,de->bse", mix(p["mu_r"]), p["w_r"]).reshape(b, s, h, HEAD)
    k = jnp.einsum("bsd,de->bse", mix(p["mu_k"]), p["w_k"]).reshape(b, s, h, HEAD)
    v = jnp.einsum("bsd,de->bse", mix(p["mu_v"]), p["w_v"]).reshape(b, s, h, HEAD)
    g = jnp.einsum("bsd,de->bse", mix(p["mu_g"]), p["w_g"])
    # data-dependent decay (the "Finch" contribution): w = base + lora(x)
    wx = mix(p["mu_w"])
    w_log = p["w_decay"] + jnp.einsum(
        "bsd,dr,re->bse", wx.astype(jnp.float32), p["w_lora_a"].astype(jnp.float32),
        p["w_lora_b"],
    )
    log_w = -jnp.exp(w_log.reshape(b, s, h, HEAD))  # log decay in (-inf, 0)
    u = p["u_bonus"].reshape(h, HEAD)

    r = ctx.cs(r, "batch", "seq", "heads", None)
    k = ctx.cs(k, "batch", "seq", "heads", None)
    v = ctx.cs(v, "batch", "seq", "heads", None)

    # pad to a chunk multiple: k/v/r pads are zero (no contribution), decay
    # pads are zero in log space (state no-ops) so s_final stays exact.
    s_pad = -(-s // c) * c
    if s_pad != s:
        pad = ((0, 0), (0, s_pad - s), (0, 0), (0, 0))
        r = jnp.pad(r, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        log_w = jnp.pad(log_w, pad)
    nc = s_pad // c
    rc = r.reshape(b, nc, c, h, HEAD).astype(jnp.float32)
    kc = k.reshape(b, nc, c, h, HEAD).astype(jnp.float32)
    vc = v.reshape(b, nc, c, h, HEAD).astype(jnp.float32)
    lw = log_w.reshape(b, nc, c, h, HEAD)

    causal = jnp.tril(jnp.ones((c, c), bool), k=-1)  # strictly lower (i < t)

    def chunk_step(state, inp):
        rr, kk, vv, ll = inp  # (b, c, h, M)
        L_inc = jnp.cumsum(ll, axis=1)  # inclusive
        L_exc = L_inc - ll  # exclusive  (L_{t-1})
        q_dec = rr * jnp.exp(L_exc)  # (b,c,h,M)
        k_dec = kk * jnp.exp(-L_inc)
        A = jnp.einsum("bthm,bihm->bhti", q_dec, k_dec)
        A = jnp.where(causal[None, None], A, 0.0)
        diag = jnp.einsum("bthm,hm,bthm->bht", rr, u, kk)
        o = jnp.einsum("bhti,bihm->bthm", A, vv)
        o += jnp.einsum("bht,bthm->bthm", diag, vv)
        o += jnp.einsum("bthm,bhmn->bthn", q_dec, state)
        # state update
        decay_all = jnp.exp(L_inc[:, -1])  # (b,h,M)
        k_tail = kk * jnp.exp(L_inc[:, -1][:, None] - L_inc)  # (b,c,h,M)
        state = state * decay_all[..., None] + jnp.einsum("bthm,bthn->bhmn", k_tail, vv)
        return state, o.astype(x.dtype)

    inp = (
        rc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
        vc.transpose(1, 0, 2, 3, 4), lw.transpose(1, 0, 2, 3, 4),
    )
    # remat: the (c x c) decay matrix A is recomputed in backward
    step_fn = jax.checkpoint(
        chunk_step, policy=jax.checkpoint_policies.nothing_saveable
    )
    s_final, o = jax.lax.scan(step_fn, s0.astype(jnp.float32), inp)
    o = o.astype(jnp.float32).transpose(1, 0, 2, 3, 4).reshape(b, s_pad, d)[:, :s]
    # per-head group norm + gate + output proj
    o = rmsnorm(o.reshape(b, s, h, HEAD), jnp.ones(HEAD, jnp.float32), cfg.norm_eps)
    o = (o.reshape(b, s, d) * p["ln_x"]["w"]).astype(x.dtype)
    o = o * jax.nn.silu(g)
    out = jnp.einsum("bsd,de->bse", o, p["w_o"])
    return ctx.cs(out, "batch", "residual_seq", None), s_final, x[:, -1, :]


def _time_mix_step(ctx: Ctx, p: dict, x1: jax.Array, s0, tm_last):
    """Exact single-token recurrence (decode). x1: (B, D)."""
    cfg = ctx.cfg
    b, d = x1.shape
    h = d // HEAD
    xs = tm_last.astype(x1.dtype)

    def mix(mu):
        return x1 * mu + xs * (1 - mu)

    r = (mix(p["mu_r"]) @ p["w_r"]).reshape(b, h, HEAD).astype(jnp.float32)
    k = (mix(p["mu_k"]) @ p["w_k"]).reshape(b, h, HEAD).astype(jnp.float32)
    v = (mix(p["mu_v"]) @ p["w_v"]).reshape(b, h, HEAD).astype(jnp.float32)
    g = mix(p["mu_g"]) @ p["w_g"]
    wx = mix(p["mu_w"])
    w_log = p["w_decay"] + (wx.astype(jnp.float32) @ p["w_lora_a"].astype(jnp.float32)) @ p["w_lora_b"]
    w = jnp.exp(-jnp.exp(w_log.reshape(b, h, HEAD)))
    u = p["u_bonus"].reshape(h, HEAD)
    s0 = s0.astype(jnp.float32)
    kv = jnp.einsum("bhm,bhn->bhmn", k, v)
    o = jnp.einsum("bhm,bhmn->bhn", r, s0 + u[None, :, :, None] * kv)
    s_new = s0 * w[..., None] + kv
    o = rmsnorm(o, jnp.ones(HEAD, jnp.float32), cfg.norm_eps)
    o = (o.reshape(b, d) * p["ln_x"]["w"]).astype(x1.dtype)
    o = o * jax.nn.silu(g)
    return o @ p["w_o"], s_new, x1


def _channel_mix(ctx: Ctx, p: dict, x: jax.Array, cm_last: jax.Array | None):
    xs = _shift(x, cm_last) if x.ndim == 3 else cm_last.astype(x.dtype)
    xk = x * p["cmu_k"] + xs * (1 - p["cmu_k"])
    xr = x * p["cmu_r"] + xs * (1 - p["cmu_r"])
    k = jnp.square(jax.nn.relu(xk @ p["cw_k"]))
    k = ctx.cs(k, "batch", "seq", "d_ff") if x.ndim == 3 else k
    out = (k @ p["cw_v"]) * jax.nn.sigmoid(xr @ p["cw_r"])
    last = x[:, -1, :] if x.ndim == 3 else x
    return out, last


def _block(ctx: Ctx, p: dict, x: jax.Array, state: tuple | None):
    """One rwkv block over a full sequence (training/prefill)."""
    s0, tm_last, cm_last = state
    h, s_new, tm_new = _time_mix_chunked(
        ctx, p, rmsnorm(x, p["ln1"]["w"], ctx.cfg.norm_eps), s0, tm_last
    )
    x = x + h
    xn = rmsnorm(x, p["ln2"]["w"], ctx.cfg.norm_eps)
    h2, cm_new = _channel_mix(ctx, p, xn, cm_last)
    return x + h2, (s_new, tm_new, cm_new)


def forward(ctx: Ctx, params: dict, tokens: jax.Array, extra_embeds=None) -> jax.Array:
    cfg = ctx.cfg
    b, s = tokens.shape
    d = cfg.d_model
    h = d // HEAD
    x = jnp.take(params["embed"], tokens, axis=0)
    x = ctx.cs(x, "batch", "residual_seq", None)
    s0 = jnp.zeros((b, h, HEAD, HEAD), jnp.float32)

    def body(carry, pl):
        y, _ = _block(ctx, pl, carry, (s0, None, None))
        return y, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["blocks"])
    x = rmsnorm(x, params["final_norm"]["w"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return ctx.cs(logits, "batch", "seq", "vocab")


def loss_fn(ctx: Ctx, params: dict, batch: dict) -> jax.Array:
    from .losses import chunked_cross_entropy

    cfg = ctx.cfg
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    b = inputs.shape[0]
    h = cfg.d_model // HEAD
    x = jnp.take(params["embed"], inputs, axis=0)
    x = ctx.cs(x, "batch", "residual_seq", None)
    s0 = jnp.zeros((b, h, HEAD, HEAD), jnp.float32)

    def body(carry, pl):
        y, _ = _block(ctx, pl, carry, (s0, None, None))
        return y, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["blocks"])
    x = rmsnorm(x, params["final_norm"]["w"], cfg.norm_eps)
    return chunked_cross_entropy(ctx, x, params["lm_head"], labels)


def init_state(cfg: ModelConfig, batch: int) -> RWKVState:
    h = cfg.d_model // HEAD
    return RWKVState(
        s=jnp.zeros((cfg.num_layers, batch, h, HEAD, HEAD), jnp.float32),
        tm_x=jnp.zeros((cfg.num_layers, batch, cfg.d_model), jnp.float32),
        cm_x=jnp.zeros((cfg.num_layers, batch, cfg.d_model), jnp.float32),
    )


def state_specs(cfg: ModelConfig) -> RWKVState:
    return RWKVState(
        s=(None, "batch", "heads4d", None, None),
        tm_x=(None, "batch", None),
        cm_x=(None, "batch", None),
    )


def prefill(ctx: Ctx, params: dict, tokens: jax.Array, max_len: int = 0):
    """Absorb the prompt into recurrent state (the 'KV cache' of an SSM)."""
    cfg = ctx.cfg
    b, s = tokens.shape
    h = cfg.d_model // HEAD
    x = jnp.take(params["embed"], tokens, axis=0)
    x = ctx.cs(x, "batch", "seq", None)
    s0 = jnp.zeros((b, h, HEAD, HEAD), jnp.float32)

    def body(carry, pl):
        y, st = _block(ctx, pl, carry, (s0, None, None))
        return y, st

    x, (ss, tms, cms) = jax.lax.scan(body, x, params["blocks"])
    x = rmsnorm(x, params["final_norm"]["w"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x[:, -1:, :], params["lm_head"])
    return logits, RWKVState(s=ss, tm_x=tms, cm_x=cms)


def decode_step(ctx: Ctx, params: dict, token: jax.Array, state: RWKVState):
    """(B, 1) token -> (B, 1, V) logits. O(1) per token: the 500k-context
    cell runs through this path (state already encodes the context)."""
    cfg = ctx.cfg
    x = jnp.take(params["embed"], token[:, 0], axis=0)  # (B, D)

    def body(carry, scanned):
        pl, s0, tm, cm = scanned
        xn = rmsnorm(carry, pl["ln1"]["w"], cfg.norm_eps)
        h, s_new, tm_new = _time_mix_step(ctx, pl, xn, s0, tm)
        y = carry + h
        yn = rmsnorm(y, pl["ln2"]["w"], cfg.norm_eps)
        h2, cm_new = _channel_mix(ctx, pl, yn, cm)
        return y + h2, (s_new, tm_new, cm_new)

    x, (ss, tms, cms) = jax.lax.scan(body, x, (params["blocks"], state.s, state.tm_x, state.cm_x))
    x = rmsnorm(x, params["final_norm"]["w"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x, params["lm_head"])[:, None, :]
    return logits, RWKVState(s=ss, tm_x=tms, cm_x=cms)
