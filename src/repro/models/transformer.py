"""Unified decoder-only LM: dense (qwen2/llama/mistral/glm/phi3v) and MoE
(mixtral/moonshot) families, with scanned layer stacks, KV-cache serving, and
mesh-aware sharding. The VLM variant prepends stub patch embeddings."""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (
    Ctx, _dt, attn_params, attn_sublayer, mlp_params, mlp_sublayer, norm,
    norm_params,
)
from .moe import moe_params, moe_sublayer


class KVCaches(NamedTuple):
    k: jax.Array  # (L, B, Smax, Hkv, Dh)
    v: jax.Array
    length: jax.Array  # () int32 valid prefix


# -- params --------------------------------------------------------------------


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, 8)
    l = cfg.num_layers
    init = jax.nn.initializers.normal(0.02)
    p: dict[str, Any] = {
        "embed": init(ks[0], (cfg.vocab_size, cfg.d_model), _dt(cfg)),
        "blocks": {
            "ln1": norm_params(cfg, cfg.d_model, (l,)),
            "ln2": norm_params(cfg, cfg.d_model, (l,)),
            "attn": attn_params(cfg, ks[1], stack=(l,)),
        },
        "final_norm": norm_params(cfg, cfg.d_model),
        "lm_head": init(ks[2], (cfg.d_model, cfg.vocab_size), _dt(cfg)),
    }
    if cfg.is_moe:
        p["blocks"]["moe"] = moe_params(cfg, ks[3], stack=(l,))
    else:
        p["blocks"]["mlp"] = mlp_params(cfg, ks[3], stack=(l,))
    return p


def param_specs(cfg: ModelConfig) -> dict:
    """Logical-axis PartitionSpecs mirroring init_params' tree.

    fsdp shards the d_model dim of weights over "data"; heads/d_ff/vocab
    shard over "model"; MoE experts over "data" (EP) + F over "model" (TP)
    when divisible, else F over "model" only (the tp fallback).
    """
    L = None  # layer-stack dim never sharded

    def nrm():
        base = {"w": (L, None)}
        if cfg.norm == "layernorm":
            base["b"] = (L, None)
        return base

    attn = {
        "wq": (L, "fsdp", "heads"),
        "wk": (L, "fsdp", "heads"),
        "wv": (L, "fsdp", "heads"),
        "wo": (L, "heads", "fsdp"),
    }
    if cfg.qkv_bias:
        attn.update({"bq": (L, "heads"), "bk": (L, "heads"), "bv": (L, "heads")})
    p: dict[str, Any] = {
        "embed": ("vocab", "fsdp"),
        "blocks": {"ln1": nrm(), "ln2": nrm(), "attn": attn},
        "final_norm": {"w": (None,)} if cfg.norm != "layernorm" else {"w": (None,), "b": (None,)},
        "lm_head": ("fsdp", "vocab"),
    }
    if cfg.is_moe:
        p["blocks"]["moe"] = {
            "router": (L, None, None),
            "w_gate": (L, "experts", "expert_inner", "moe_d_ff"),
            "w_up": (L, "experts", "expert_inner", "moe_d_ff"),
            "w_down": (L, "experts", "moe_d_ff", "expert_inner"),
        }
    else:
        p["blocks"]["mlp"] = {
            "w_gate": (L, "fsdp", "d_ff"),
            "w_up": (L, "fsdp", "d_ff"),
            "w_down": (L, "d_ff", "fsdp"),
        }
    return p


# -- forward -------------------------------------------------------------------


def _block(ctx: Ctx, p: dict, x: jax.Array, *, pos_offset=0, cache=None, cache_len=None):
    h, new_cache = attn_sublayer(
        ctx, p["attn"], norm(ctx, p["ln1"], x),
        pos_offset=pos_offset, cache=cache, cache_len=cache_len,
    )
    x = x + h
    if "moe" in p:
        h2 = moe_sublayer(ctx, p["moe"], norm(ctx, p["ln2"], x))
    else:
        h2 = mlp_sublayer(ctx, p["mlp"], norm(ctx, p["ln2"], x))
    x = x + h2
    return ctx.cs(x, "batch", "residual_seq", None), new_cache


def _embed(ctx: Ctx, params: dict, tokens: jax.Array, extra_embeds: jax.Array | None):
    """Token (+optional patch-prefix) embedding. S1 surface: the activation
    stream is replicated over "model" while the table stays vocab-sharded."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if extra_embeds is not None:  # vlm: prepend stub patch embeddings
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    return ctx.cs(x, "batch", "residual_seq", None)


def _unembed(ctx: Ctx, params: dict, x: jax.Array) -> jax.Array:
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return ctx.cs(logits, "batch", "seq", "vocab")


def forward(
    ctx: Ctx, params: dict, tokens: jax.Array, extra_embeds: jax.Array | None = None
) -> jax.Array:
    """Training/scoring forward: (B, S) tokens -> (B, S[+Np], V) logits."""
    return _unembed(ctx, params, backbone(ctx, params, tokens, extra_embeds))


def backbone(ctx: Ctx, params: dict, tokens: jax.Array, extra_embeds=None) -> jax.Array:
    """Embed + scanned blocks + final norm (no unembed)."""
    cfg = ctx.cfg
    x = _embed(ctx, params, tokens, extra_embeds)

    def body(carry, pl):
        y, _ = _block(ctx, pl, carry)
        return y, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["blocks"])
    return norm(ctx, params["final_norm"], x)


def loss_fn(ctx: Ctx, params: dict, batch: dict) -> jax.Array:
    from .losses import chunked_cross_entropy

    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    x = backbone(ctx, params, inputs, batch.get("patches"))
    if "patches" in batch:  # loss only on the token positions
        x = x[:, batch["patches"].shape[1]:]
    return chunked_cross_entropy(ctx, x, params["lm_head"], labels)


# -- serving -------------------------------------------------------------------


def moe_decode_params(cfg: ModelConfig, key: jax.Array) -> dict:
    """Flat single-block MoE decode-serving params for the engine's
    ``moe_decode`` op (engine/decode_op.py): one single-head attention
    sublayer (head dim = d_model), one MoE sublayer in the
    :func:`repro.models.moe.moe_params` layout, rmsnorms at ones. Use a
    float32 config (``serve-moe`` in configs/) when served output must be
    bit-comparable to the single-process oracle."""
    d = cfg.d_model
    dt = _dt(cfg)
    init = jax.nn.initializers.normal(0.02)
    ks = jax.random.split(key, 7)
    moe = moe_params(cfg, ks[0])
    return {
        "embed": init(ks[1], (cfg.vocab_size, d), dt),
        "ln1": jnp.ones((d,), dt),
        "ln2": jnp.ones((d,), dt),
        "ln_f": jnp.ones((d,), dt),
        "wq": init(ks[2], (d, d), dt),
        "wk": init(ks[3], (d, d), dt),
        "wv": init(ks[4], (d, d), dt),
        "wo": init(ks[5], (d, d), dt),
        "router": moe["router"],
        "w_gate": moe["w_gate"],
        "w_up": moe["w_up"],
        "w_down": moe["w_down"],
        "lm_head": init(ks[6], (d, cfg.vocab_size), dt),
    }


def init_caches(cfg: ModelConfig, batch: int, max_len: int) -> KVCaches:
    shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.hd)
    return KVCaches(
        k=jnp.zeros(shape, _dt(cfg)),
        v=jnp.zeros(shape, _dt(cfg)),
        length=jnp.zeros((), jnp.int32),
    )


def cache_specs(cfg: ModelConfig) -> KVCaches:
    """Logical PartitionSpecs for KV caches (kv_seq shards for long-context)."""
    spec = (None, "batch", "kv_seq", "kv_heads4d", None)
    return KVCaches(k=spec, v=spec, length=())


def prefill(
    ctx: Ctx, params: dict, tokens: jax.Array, max_len: int,
    extra_embeds: jax.Array | None = None,
) -> tuple[jax.Array, KVCaches]:
    """Run the prompt, build KV caches sized max_len. Returns (last-token
    logits, caches)."""
    cfg = ctx.cfg
    x = _embed(ctx, params, tokens, extra_embeds)
    s = x.shape[1]

    def body(carry, pl):
        y, (k, v) = _block(ctx, pl, carry)
        return y, (k, v)

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
    x = norm(ctx, params["final_norm"], x)
    logits = _unembed(ctx, params, x[:, -1:, :])
    b = tokens.shape[0]
    caches = init_caches(cfg, b, max(max_len, s))  # vlm: patches extend s
    caches = KVCaches(
        k=jax.lax.dynamic_update_slice(caches.k, ks.astype(caches.k.dtype), (0, 0, 0, 0, 0)),
        v=jax.lax.dynamic_update_slice(caches.v, vs.astype(caches.v.dtype), (0, 0, 0, 0, 0)),
        length=jnp.asarray(s, jnp.int32),
    )
    return logits, caches


def decode_step(
    ctx: Ctx, params: dict, token: jax.Array, caches: KVCaches
) -> tuple[jax.Array, KVCaches]:
    """One serve step: (B, 1) token -> (B, 1, V) logits, caches advanced."""
    cfg = ctx.cfg
    x = _embed(ctx, params, token, None)
    ln = caches.length

    def body(carry, scanned):
        pl, ck, cv = scanned
        y, (nk, nv) = _block(ctx, pl, carry, pos_offset=ln, cache=(ck, cv), cache_len=ln)
        return y, (nk, nv)

    x, (nks, nvs) = jax.lax.scan(body, x, (params["blocks"], caches.k, caches.v))
    x = norm(ctx, params["final_norm"], x)
    logits = _unembed(ctx, params, x)
    return logits, KVCaches(k=nks, v=nvs, length=ln + token.shape[1])
