"""Mixture-of-Experts with migratory-strategy dispatch (DESIGN.md §4).

The token->expert routing problem IS the Emu's irregular-access problem: a
token needs to reach the shard owning its expert's weights. Three dispatch
modes realize the paper's strategies on the TPU mesh's "model" axis:

- ``ep_push``  (S2 remote-write, Alg. 2 analogue): each shard bins its local
  tokens by destination expert-owner shard and pushes them with a single
  ``all_to_all`` (the remote-write packet stream); owners compute their
  experts and push results back with the inverse ``all_to_all``. Requires
  num_experts % model_axis == 0 (moonshot: 64 % 16).
- ``ep_pull``  (S2 migrate, Alg. 1 analogue): every expert-owner shard pulls
  ALL tokens with an ``all_gather`` over the model axis, computes its local
  experts on the full token set, and the combine reduces with ``psum_scatter``.
  Communication grows with the full token volume — the migrating-threads
  baseline.
- ``tp``      (S1-flavored fallback for any expert count, e.g. mixtral's 8
  experts on a 16-way axis): every shard holds an F-slice of EVERY expert
  (replication of the expert *set*, sharding of the FFN dim); dispatch stays
  node-local (pure local scatter) and the only communication is the TP
  all-reduce of the combined output, exactly like a dense TP MLP.

All modes use capacity-factor token dropping (static shapes; the overflow
counter mirrors the paper's SpMV grain/hotspot discussion — §5.1 load
imbalance) and are implemented in ``shard_map`` so the collectives are
explicit and auditable in the dry-run HLO (roofline §collective term).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..core.strategies import Comm, MigratoryStrategy
from ..core.util import round_up
from .config import ModelConfig
from .layers import Ctx, _dt


def dispatch_from_strategy(
    strategy: MigratoryStrategy | None, *, num_experts: int, data_axis: int
) -> str | None:
    """Map a paper strategy onto an MoE dispatch mode (the engine's
    strategy-to-substrate idea applied to token routing, DESIGN.md §4):
    S2 remote_write -> ep_push (all_to_all packets), S2 migrate -> ep_pull
    (all_gather the token set), and the S1-flavored ``tp`` replication
    fallback whenever expert parallelism cannot divide the data axis."""
    if strategy is None:
        return None
    if data_axis > 1 and num_experts % data_axis == 0:
        return "ep_pull" if strategy.comm == Comm.MIGRATE else "ep_push"
    return "tp"


def moe_params(cfg: ModelConfig, key, stack: tuple[int, ...] = ()) -> dict:
    d, e = cfg.d_model, cfg.num_experts
    f = cfg.moe_d_ff or cfg.d_ff
    dt = _dt(cfg)
    init = jax.nn.initializers.normal(0.02)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": init(k1, (*stack, d, e), jnp.float32),
        "w_gate": init(k2, (*stack, e, d, f), dt),
        "w_up": init(k3, (*stack, e, d, f), dt),
        "w_down": init(k4, (*stack, e, f, d), dt),
    }


def _route(cfg: ModelConfig, xt: jax.Array, router: jax.Array):
    """Token routing: top-k softmax gates. xt: (T, D) -> gates/experts (T, k)."""
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates.astype(xt.dtype), experts.astype(jnp.int32)


def _positions_in_expert(experts_flat: jax.Array, num_experts: int) -> jax.Array:
    """Rank of each routed slot within its expert (stable order). O(T·E) free
    of sorts: cumulative one-hot counts."""
    oh = jax.nn.one_hot(experts_flat, num_experts, dtype=jnp.int32)  # (Tk, E)
    ranks = jnp.cumsum(oh, axis=0) - oh  # occurrences before this slot
    return jnp.sum(ranks * oh, axis=1)  # (Tk,)


def _expert_ffn(cfg: ModelConfig, p: dict, xs: jax.Array) -> jax.Array:
    """xs: (E_local, C, D) -> (E_local, C, D) through each expert's SwiGLU."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", xs, p["w_up"])
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def expert_ffn(params: dict, xs: jax.Array) -> jax.Array:
    """Public expert-stack entry: each expert's SwiGLU over its capacity
    buffer. ``params`` holds ``w_gate``/``w_up`` (E, D, F) and ``w_down``
    (E, F, D) — the :func:`moe_params` layout; ``xs`` is (E, C, D). This is
    the exact math the engine's ``moe_dispatch`` op applies at the owner
    stage, so engine-served experts and the LM stack share one definition.
    Zero rows map to zero rows (no biases) — padded capacity slots stay
    inert through the FFN."""
    return _expert_ffn(None, params, xs)


def _local_dispatch(cfg: ModelConfig, xt, gates, experts, capacity):
    """Scatter local tokens into per-expert buffers (drop past capacity).

    Returns (buffers (E, C, D), slot_expert (T,k), slot_pos (T,k), kept mask).
    """
    t, d = xt.shape
    k = cfg.experts_per_token
    ef = experts.reshape(-1)
    pos = _positions_in_expert(ef, cfg.num_experts)
    keep = pos < capacity
    xk = jnp.repeat(xt, k, axis=0)  # (T*k, D)
    buf = jnp.zeros((cfg.num_experts, capacity, d), xt.dtype)
    buf = buf.at[jnp.where(keep, ef, 0), jnp.where(keep, pos, 0)].add(
        jnp.where(keep[:, None], xk, 0), mode="drop"
    )
    return buf, ef, pos, keep


def _local_combine(cfg, out_buf, gates, ef, pos, keep, t, d):
    """Gather per-expert outputs back to token order, weighted by gates."""
    k = cfg.experts_per_token
    vals = out_buf[jnp.where(keep, ef, 0), jnp.where(keep, pos, 0)]  # (T*k, D)
    vals = jnp.where(keep[:, None], vals, 0)
    return jnp.sum((vals * gates.reshape(-1)[:, None]).reshape(t, k, d), axis=1)


def moe_sublayer(
    ctx: Ctx,
    p: dict,
    x: jax.Array,
    *,
    dispatch: str | None = None,
    strategy: MigratoryStrategy | None = None,
) -> jax.Array:
    """x: (B, S, D) -> (B, S, D). Dispatch mode: explicit ``dispatch`` wins,
    else derived from ``strategy`` (engine-style), else the config/default
    (the default REMOTE_WRITE strategy, i.e. ep_push where divisible)."""
    cfg = ctx.cfg
    b, s, d = x.shape
    mesh = ctx.mesh
    ms = mesh.shape.get("model", 1) if mesh is not None else 1
    ds = mesh.shape.get("data", 1) if mesh is not None else 1
    if dispatch is None:
        dispatch = dispatch_from_strategy(
            strategy, num_experts=cfg.num_experts, data_axis=ds
        )
    if dispatch is None:
        dispatch = cfg.moe_dispatch
    if dispatch is None:
        dispatch = dispatch_from_strategy(
            MigratoryStrategy(), num_experts=cfg.num_experts, data_axis=ds
        )
    if mesh is None or ms == 1:
        # single-shard semantics path (smoke tests)
        xt = x.reshape(b * s, d)
        gates, experts = _route(cfg, xt, p["router"])
        cap = _capacity(cfg, b * s, cfg.num_experts)
        buf, ef, pos, keep = _local_dispatch(cfg, xt, gates, experts, cap)
        out = _expert_ffn(cfg, p, buf)
        return _local_combine(cfg, out, gates, ef, pos, keep, b * s, d).reshape(b, s, d)

    batch_axes = ctx.rules.batch if ctx.rules else ("data",)
    if dispatch == "tp":
        return _moe_tp(ctx, p, x, batch_axes)
    if dispatch == "ep_push":
        return _moe_ep(ctx, p, x, batch_axes, push=True)
    if dispatch == "ep_pull":
        return _moe_ep(ctx, p, x, batch_axes, push=False)
    raise ValueError(f"unknown dispatch {dispatch}")


def _capacity(cfg: ModelConfig, tokens: int, experts: int) -> int:
    c = int(cfg.capacity_factor * tokens * cfg.experts_per_token / experts)
    return max(8, round_up(c, 8))


def _moe_tp(ctx: Ctx, p: dict, x: jax.Array, batch_axes) -> jax.Array:
    """Every shard: all experts, F-sliced. Local dispatch + one TP all-reduce."""
    cfg = ctx.cfg
    mesh = ctx.mesh
    b, s, d = x.shape
    tl = (b // _axis_size(mesh, batch_axes)) * s  # local tokens

    tc = min(8192, tl)  # token chunk: bounds dispatch buffers (grain size)

    def body(xb, router, wg, wu, wd):
        bl, sl, _ = xb.shape
        t = bl * sl
        xt = xb.reshape(t, d)
        tcc = min(tc, t)
        cap_c = _capacity(cfg, tcc, cfg.num_experts)

        def chunk_fn(xc):
            gates, experts = _route(cfg, xc, router)
            buf, ef, pos, keep = _local_dispatch(cfg, xc, gates, experts, cap_c)
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
            h = h * jnp.einsum("ecd,edf->ecf", buf, wu)
            out_p = jnp.einsum("ecf,efd->ecd", h, wd)  # partial over F slices
            out_p = jax.lax.psum(out_p, "model")  # TP reduce (dense-MLP-like)
            return _local_combine(cfg, out_p, gates, ef, pos, keep, xc.shape[0], d)

        if t > tcc:
            nck = t // tcc
            chunk_fn = jax.checkpoint(
                chunk_fn, policy=jax.checkpoint_policies.nothing_saveable
            )
            out = jax.lax.map(chunk_fn, xt.reshape(nck, tcc, d)).reshape(t, d)
        else:
            out = chunk_fn(xt)
        return out.reshape(bl, sl, d)

    return shard_map(
        body,
        mesh,
        in_specs=(
            P(batch_axes, None, None),
            P(),  # router replicated
            P(None, None, "model"),  # w_gate: F sliced
            P(None, None, "model"),
            P(None, "model", None),  # w_down: F sliced on input dim
        ),
        out_specs=P(batch_axes, None, None),
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def _moe_ep(ctx: Ctx, p: dict, x: jax.Array, batch_axes, *, push: bool) -> jax.Array:
    """Expert parallelism along "data" (the axis that shards tokens), with TP
    over "model" inside each expert (F-sliced). Hierarchical across pods:
    experts are replicated per pod, dispatch stays within a pod.

    push (S2 remote-write): bin local tokens by destination expert-owner,
      one all_to_all over "data" there, one back; TP psum folded into token
      space after the return trip.
    pull (S2 migrate): every owner all_gathers ALL tokens over "data",
      computes its experts on the full set, results return via psum_scatter.
    """
    cfg = ctx.cfg
    mesh = ctx.mesh
    ds = mesh.shape["data"]
    ms = mesh.shape.get("model", 1)
    e_local = cfg.num_experts // ds
    b, s, d = x.shape
    k = cfg.experts_per_token

    def body(xb, router, wg, wu, wd):
        bl, sl, _ = xb.shape
        t_full = bl * sl
        xt = xb.reshape(t_full, d)
        # tokens are replicated along "model": slice so each model shard
        # dispatches a distinct 1/ms of them (all_gather back at the end) —
        # cuts dispatch buffers and a2a traffic by ms (DeepSpeed-MoE "dual").
        # Skipped when the local token count is too small to split (decode).
        model_slice = ms > 1 and t_full % ms == 0 and t_full >= ms
        if model_slice:
            t = t_full // ms
            mi = jax.lax.axis_index("model")
            xt = jax.lax.dynamic_slice(xt, (mi * t, jnp.int32(0)), (t, d))
        else:
            t = t_full
        gates, experts = _route(cfg, xt, router)  # (t, k)
        ef = experts.reshape(-1)  # (t*k,)
        owner = ef // e_local  # destination "data" shard
        ffn = {"w_gate": wg, "w_up": wu, "w_down": wd}
        if push:
            # --- remote-write: bin by owner, push with all_to_all ----------
            cap_pair = _capacity(cfg, t, ds)  # slots per (src->dst) pair
            pos = _positions_in_expert(owner, ds)  # rank within owner bin
            keep = pos < cap_pair
            xk = jnp.repeat(xt, k, axis=0)
            ow = jnp.where(keep, owner, 0)
            ps = jnp.where(keep, pos, 0)
            send = jnp.zeros((ds, cap_pair, d), xt.dtype)
            send = send.at[ow, ps].add(jnp.where(keep[:, None], xk, 0), mode="drop")
            send_e = jnp.full((ds, cap_pair), -1, jnp.int32)
            send_e = send_e.at[ow, ps].max(jnp.where(keep, ef, -1), mode="drop")
            recv = jax.lax.all_to_all(send, "data", 0, 0, tiled=False)
            recv_e = jax.lax.all_to_all(send_e, "data", 0, 0, tiled=False)
            # recv: (ds, cap_pair, d) tokens destined to my local experts
            shard = jax.lax.axis_index("data")
            rf = (recv_e - shard * e_local).reshape(-1)
            rf = jnp.where(recv_e.reshape(-1) >= 0, rf, e_local)
            cap_e = _capacity(cfg, t * ds, cfg.num_experts)
            rpos = _positions_in_expert(rf, e_local + 1)
            rkeep = (rf < e_local) & (rpos < cap_e)
            buf = jnp.zeros((e_local, cap_e, d), xt.dtype)
            rx = recv.reshape(-1, d)
            buf = buf.at[jnp.where(rkeep, rf, 0), jnp.where(rkeep, rpos, 0)].add(
                jnp.where(rkeep[:, None], rx, 0), mode="drop"
            )
            out_buf = _expert_ffn(cfg, ffn, buf)  # full-F experts (no TP psum)
            out_slots = out_buf[jnp.where(rkeep, rf, 0), jnp.where(rkeep, rpos, 0)]
            out_slots = jnp.where(rkeep[:, None], out_slots, 0).reshape(ds, cap_pair, d)
            back = jax.lax.all_to_all(out_slots, "data", 0, 0, tiled=False)
            vals = back[ow, ps]
            vals = jnp.where(keep[:, None], vals, 0)
            out = jnp.sum((vals * gates.reshape(-1)[:, None]).reshape(t, k, d), axis=1)
        else:
            # --- migrate: pull every token to every owner -------------------
            xg = jax.lax.all_gather(xt, "data", tiled=True)  # (t*ds, d)
            gg = jax.lax.all_gather(gates.reshape(-1), "data", tiled=True)
            eg = jax.lax.all_gather(ef, "data", tiled=True)  # (t*k*ds,)
            shard = jax.lax.axis_index("data")
            mine = (eg // e_local) == shard
            le = jnp.where(mine, eg - shard * e_local, e_local)
            cap_e = _capacity(cfg, t * ds, cfg.num_experts)
            pos = _positions_in_expert(le, e_local + 1)
            keep = mine & (pos < cap_e)
            xkg = jnp.repeat(xg, k, axis=0)
            buf = jnp.zeros((e_local, cap_e, d), xt.dtype)
            buf = buf.at[jnp.where(keep, le, 0), jnp.where(keep, pos, 0)].add(
                jnp.where(keep[:, None], xkg, 0), mode="drop"
            )
            out_buf = _expert_ffn(cfg, ffn, buf)
            vals = out_buf[jnp.where(keep, le, 0), jnp.where(keep, pos, 0)]
            vals = jnp.where(keep[:, None], vals, 0) * gg[:, None]
            contrib = vals.reshape(ds, t, k, d).sum(2)  # (ds, t, d) per source
            out = jax.lax.psum_scatter(contrib, "data", scatter_dimension=0, tiled=False)
        if model_slice:
            # collect the per-model-shard token slices back together
            out = jax.lax.all_gather(out, "model", tiled=True)
        return out.reshape(bl, sl, d)

    return shard_map(
        body,
        mesh,
        in_specs=(
            P(batch_axes, None, None),
            P(),
            P("data", None, None),  # E over data (EP), full-F experts
            P("data", None, None),
            P("data", None, None),
        ),
        out_specs=P(batch_axes, None, None),
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def _axis_size(mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes or ():
        n *= mesh.shape.get(a, 1)
    return n
