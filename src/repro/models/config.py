"""Architecture config: one dataclass covers all 10 assigned families."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None

    # attention variants
    qkv_bias: bool = False  # qwen2
    rope_theta: float = 1_000_000.0
    rope_fraction: float = 1.0  # glm4/phi3 partial rotary
    sliding_window: int | None = None  # mixtral SWA
    norm_eps: float = 1e-5
    act: str = "swiglu"  # swiglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    pos_emb: str = "rope"  # rope | sinusoidal (whisper)

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int | None = None
    capacity_factor: float = 1.25
    moe_dispatch: str | None = None  # ep_push | ep_pull | tp | None=auto

    # SSM / RWKV
    ssm_state: int = 0  # mamba2 state size N / rwkv head size
    ssm_heads: int = 0
    ssm_chunk: int = 256  # chunked-scan block for training shapes

    # hybrid (zamba2): one shared attention block applied every period layers
    shared_attn_period: int = 0

    # enc-dec (whisper): encoder backbone + stub frame frontend
    encoder_layers: int = 0
    encoder_frames: int = 0  # precomputed frame embeddings (stub conv frontend)

    # vlm (phi3v): stub patch embeddings prepended to the token stream
    num_patches: int = 0

    dtype: str = "bfloat16"
    remat: bool = True  # activation checkpointing for train_step
    # TP optimization for head counts that do not divide the model axis
    # (llama 24, qwen2 28, whisper 12 on a 16-way axis): repeat KV to MHA,
    # zero-pad heads to the next multiple, shard. Numerically exact (padded
    # heads contribute zero); costs kv-activation replication. See
    # EXPERIMENTS.md §Perf (beyond-paper optimization).
    tp_pad_heads: bool = False
    # attention backend: "reference" (jnp, CPU-lowerable) or "flash"
    # (Pallas kernel; interpret=True on CPU, native on TPU)
    attn_impl: str = "reference"

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def param_count(self) -> int:
        """Non-embedding parameter count (for MODEL_FLOPS accounting)."""
        d, hd = self.d_model, self.hd
        if self.family == "ssm":  # rwkv6
            per_layer = (
                4 * d * d  # r,k,v,g (time-mix)
                + d * d  # output
                + 2 * d * self.d_ff // 2 + self.d_ff // 2 * 0  # placeholder
                + d * self.d_ff + self.d_ff * d + d * d  # channel-mix k,v,r
            )
            return self.num_layers * per_layer
        att = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) + (self.num_heads * hd) * d
        if self.is_moe:
            fe = self.moe_d_ff or self.d_ff
            ffn = self.num_experts * 3 * d * fe + d * self.num_experts
        else:
            n_mats = 3 if self.act == "swiglu" else 2
            ffn = n_mats * d * self.d_ff
        layers = self.num_layers * (att + ffn)
        if self.family == "encdec":
            layers += self.encoder_layers * (att + ffn) + self.num_layers * att  # cross-attn
        if self.family == "hybrid" and self.shared_attn_period:
            layers += att  # the single shared attention block
        return layers

    @property
    def active_param_count(self) -> int:
        """Active (per-token) params — MoE uses experts_per_token of num_experts."""
        if not self.is_moe:
            return self.param_count
        d = self.d_model
        hd = self.hd
        att = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) + (self.num_heads * hd) * d
        fe = self.moe_d_ff or self.d_ff
        ffn = self.experts_per_token * 3 * d * fe + d * self.num_experts
        return self.num_layers * (att + ffn)
