"""Zamba2 hybrid: Mamba-2 backbone + ONE shared attention block applied at a
fixed cadence (paper-S1 made literal: the shared block is read-hot replicated
state reused at every application point, while each point keeps its own KV
cache). 54 layers / period 6 -> 9 shared-attention applications."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (
    Ctx, _dt, attn_params, attn_sublayer, mlp_params, mlp_sublayer, norm,
    norm_params,
)
from .mamba2 import MambaLayerState, mamba_param_specs, mamba_params, mamba_sublayer


class ZambaCaches(NamedTuple):
    mamba_h: jax.Array  # (L, B, H, N, P)
    mamba_conv: jax.Array  # (L, B, W-1, Dconv)
    attn_k: jax.Array  # (A, B, Smax, Hkv, Dh) one per application point
    attn_v: jax.Array
    length: jax.Array


def _groups(cfg: ModelConfig) -> tuple[int, int]:
    period = cfg.shared_attn_period or cfg.num_layers
    assert cfg.num_layers % period == 0
    return cfg.num_layers // period, period  # (n_groups, per_group)


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    g, per = _groups(cfg)
    l = cfg.num_layers
    dt = _dt(cfg)
    init = jax.nn.initializers.normal(0.02)
    ks = jax.random.split(key, 6)
    return {
        "embed": init(ks[0], (cfg.vocab_size, cfg.d_model), dt),
        "blocks": {
            "ln": norm_params(cfg, cfg.d_model, (l,)),
            "mamba": mamba_params(cfg, ks[1], stack=(l,)),
        },
        "shared_attn": {  # ONE block, reused at every application point (S1)
            "ln1": norm_params(cfg, cfg.d_model),
            "ln2": norm_params(cfg, cfg.d_model),
            "attn": attn_params(cfg, ks[2]),
            "mlp": mlp_params(cfg, ks[3]),
        },
        "final_norm": norm_params(cfg, cfg.d_model),
        "lm_head": init(ks[4], (cfg.d_model, cfg.vocab_size), dt),
    }


def param_specs(cfg: ModelConfig) -> dict:
    attn = {
        "wq": ("fsdp", "heads"), "wk": ("fsdp", "heads"),
        "wv": ("fsdp", "heads"), "wo": ("heads", "fsdp"),
    }
    return {
        "embed": ("vocab", "fsdp"),
        "blocks": {"ln": {"w": (None, None)}, "mamba": mamba_param_specs()},
        "shared_attn": {
            "ln1": {"w": (None,)}, "ln2": {"w": (None,)},
            "attn": attn,
            "mlp": {
                "w_gate": ("fsdp", "d_ff"), "w_up": ("fsdp", "d_ff"),
                "w_down": ("d_ff", "fsdp"),
            },
        },
        "final_norm": {"w": (None,)},
        "lm_head": ("fsdp", "vocab"),
    }


def _shared_attn_block(ctx, p, x, *, pos_offset=0, cache=None, cache_len=None):
    h, new_cache = attn_sublayer(
        ctx, p["attn"], norm(ctx, p["ln1"], x),
        pos_offset=pos_offset, cache=cache, cache_len=cache_len,
    )
    x = x + h
    x = x + mlp_sublayer(ctx, p["mlp"], norm(ctx, p["ln2"], x))
    return x, new_cache


def _backbone(ctx: Ctx, params: dict, x: jax.Array, caches: ZambaCaches | None):
    """Shared forward core: groups of scanned mamba layers + shared attn."""
    cfg = ctx.cfg
    g, per = _groups(cfg)
    b = x.shape[0]
    length = caches.length if caches is not None else None
    new_h, new_conv, new_k, new_v = [], [], [], []

    def group_blocks(gi):
        return jax.tree.map(lambda a: a[gi * per : (gi + 1) * per], params["blocks"])

    for gi in range(g):
        blocks = group_blocks(gi)

        def body(carry, scanned):
            if caches is None:
                pl, = scanned
                st = None
            else:
                pl, hst, cst = scanned
                st = MambaLayerState(h=hst, conv=cst)
            xn = norm(ctx, pl["ln"], carry)
            out, new_st = mamba_sublayer(ctx, pl["mamba"], xn, st)
            return carry + out, (new_st.h, new_st.conv)

        if cfg.remat and caches is None:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        xs = (
            (blocks,)
            if caches is None
            else (blocks, caches.mamba_h[gi * per : (gi + 1) * per],
                  caches.mamba_conv[gi * per : (gi + 1) * per])
        )
        x, (hs, convs) = jax.lax.scan(body, x, xs)
        new_h.append(hs)
        new_conv.append(convs)
        if caches is None:
            x, (k, v) = _shared_attn_block(ctx, params["shared_attn"], x)
        else:
            x, (k, v) = _shared_attn_block(
                ctx, params["shared_attn"], x, pos_offset=length,
                cache=(caches.attn_k[gi], caches.attn_v[gi]), cache_len=length,
            )
        new_k.append(k)
        new_v.append(v)
    aux = (
        jnp.concatenate(new_h), jnp.concatenate(new_conv),
        jnp.stack(new_k), jnp.stack(new_v),
    )
    return x, aux


def forward(ctx: Ctx, params: dict, tokens: jax.Array, extra_embeds=None) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    x = ctx.cs(x, "batch", "residual_seq", None)
    x, _ = _backbone(ctx, params, x, None)
    x = norm(ctx, params["final_norm"], x)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return ctx.cs(logits, "batch", "seq", "vocab")


def loss_fn(ctx: Ctx, params: dict, batch: dict) -> jax.Array:
    from .losses import chunked_cross_entropy

    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    x = jnp.take(params["embed"], inputs, axis=0)
    x = ctx.cs(x, "batch", "residual_seq", None)
    x, _ = _backbone(ctx, params, x, None)
    x = norm(ctx, params["final_norm"], x)
    return chunked_cross_entropy(ctx, x, params["lm_head"], labels)


def init_caches(cfg: ModelConfig, batch: int, max_len: int) -> ZambaCaches:
    from .mamba2 import CONV_W, P_HEAD

    g, _ = _groups(cfg)
    di = 2 * cfg.d_model
    h = di // P_HEAD
    return ZambaCaches(
        mamba_h=jnp.zeros((cfg.num_layers, batch, h, cfg.ssm_state, P_HEAD), jnp.float32),
        mamba_conv=jnp.zeros(
            (cfg.num_layers, batch, CONV_W - 1, di + 2 * cfg.ssm_state), _dt(cfg)
        ),
        attn_k=jnp.zeros((g, batch, max_len, cfg.num_kv_heads, cfg.hd), _dt(cfg)),
        attn_v=jnp.zeros((g, batch, max_len, cfg.num_kv_heads, cfg.hd), _dt(cfg)),
        length=jnp.zeros((), jnp.int32),
    )


def cache_specs(cfg: ModelConfig) -> ZambaCaches:
    return ZambaCaches(
        mamba_h=(None, "batch", "heads4d", None, None),
        mamba_conv=(None, "batch", None, "heads"),
        attn_k=(None, "batch", "kv_seq", "kv_heads4d", None),
        attn_v=(None, "batch", "kv_seq", "kv_heads4d", None),
        length=(),
    )


def prefill(ctx: Ctx, params: dict, tokens: jax.Array, max_len: int):
    cfg = ctx.cfg
    b, s = tokens.shape
    caches0 = init_caches(cfg, b, max_len)
    x = jnp.take(params["embed"], tokens, axis=0)
    x = ctx.cs(x, "batch", "residual_seq", None)
    x, (hs, convs, ks, vs) = _backbone(ctx, params, x, None)
    x = norm(ctx, params["final_norm"], x)
    logits = jnp.einsum("bsd,dv->bsv", x[:, -1:], params["lm_head"])
    caches = ZambaCaches(
        mamba_h=hs, mamba_conv=convs,
        attn_k=jax.lax.dynamic_update_slice(
            caches0.attn_k, ks.astype(caches0.attn_k.dtype), (0, 0, 0, 0, 0)
        ),
        attn_v=jax.lax.dynamic_update_slice(
            caches0.attn_v, vs.astype(caches0.attn_v.dtype), (0, 0, 0, 0, 0)
        ),
        length=jnp.asarray(s, jnp.int32),
    )
    return logits, caches


def decode_step(ctx: Ctx, params: dict, token: jax.Array, caches: ZambaCaches):
    x = jnp.take(params["embed"], token, axis=0)  # (B, 1, D)
    x, (hs, convs, ks, vs) = _backbone(ctx, params, x, caches)
    x = norm(ctx, params["final_norm"], x)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return logits, ZambaCaches(
        mamba_h=hs, mamba_conv=convs, attn_k=ks, attn_v=vs,
        length=caches.length + token.shape[1],
    )
