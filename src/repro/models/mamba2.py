"""Mamba-2 (SSD) block: chunked selective-state-space scan.

Same chunked machinery as rwkv6 but with a scalar per-head decay
a_t = exp(-softplus(dt_t) * exp(A_log)): state (N x P) per head,
h_t = a_t h_{t-1} + dt_t * B_t x_t^T,  y_t = C_t^T h_t + D x_t.
Includes the depthwise causal conv frontend and SiLU gating of Mamba-2.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Ctx, _dt, rmsnorm

P_HEAD = 64  # head dim (P) of the inner stream
CONV_W = 4


class MambaLayerState(NamedTuple):
    h: jax.Array  # (B, H, N, P) ssm state
    conv: jax.Array  # (B, CONV_W - 1, D_conv) conv tail


def mamba_params(cfg: ModelConfig, key, stack: tuple[int, ...] = ()) -> dict:
    d = cfg.d_model
    di = 2 * d  # inner dim
    n = cfg.ssm_state
    h = di // P_HEAD
    dconv = di + 2 * n  # x + B + C stream through the conv
    dt = _dt(cfg)
    init = jax.nn.initializers.normal(0.02)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": init(ks[0], (*stack, d, di + dconv + h), dt),  # z, xBC, dt
        "conv_w": init(ks[1], (*stack, CONV_W, dconv), dt),
        "conv_b": jnp.zeros((*stack, dconv), dt),
        "a_log": jnp.zeros((*stack, h), jnp.float32),
        "d_skip": jnp.ones((*stack, h), jnp.float32),
        "dt_bias": jnp.zeros((*stack, h), jnp.float32),
        "out_norm": jnp.ones((*stack, di), dt),
        "out_proj": init(ks[2], (*stack, di, d), dt),
    }


def mamba_param_specs() -> dict:
    L = None
    return {
        "in_proj": (L, "fsdp", "heads"),
        "conv_w": (L, None, "heads"),
        "conv_b": (L, "heads"),
        "a_log": (L, "heads"),
        "d_skip": (L, "heads"),
        "dt_bias": (L, "heads"),
        "out_norm": (L, "heads"),
        "out_proj": (L, "heads", "fsdp"),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, tail: jax.Array | None):
    """Depthwise causal conv, width CONV_W. x: (B, S, C). Returns (y, new_tail)."""
    bsz, s, c = x.shape
    head = (
        jnp.zeros((bsz, CONV_W - 1, c), x.dtype) if tail is None else tail.astype(x.dtype)
    )
    xp = jnp.concatenate([head, x], axis=1)  # (B, S + W - 1, C)
    y = sum(xp[:, i : i + s] * w[i] for i in range(CONV_W)) + b
    return jax.nn.silu(y), xp[:, -(CONV_W - 1) :]


def mamba_sublayer(
    ctx: Ctx, p: dict, x: jax.Array, state: MambaLayerState | None = None
) -> tuple[jax.Array, MambaLayerState]:
    """x: (B, S, D) -> (out, final state). Chunked scan over S."""
    cfg = ctx.cfg
    bsz, s, d = x.shape
    di = 2 * d
    n = cfg.ssm_state
    h = di // P_HEAD
    dconv = di + 2 * n
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc, dt_raw = jnp.split(proj, [di, di + dconv], axis=-1)
    xbc, conv_tail = _causal_conv(
        xbc, p["conv_w"], p["conv_b"], None if state is None else state.conv
    )
    xi, b_in, c_in = jnp.split(xbc, [di, di + n], axis=-1)
    xi = ctx.cs(xi, "batch", "seq", "heads")
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    log_a = -dt * jnp.exp(p["a_log"])  # (B,S,H) scalar decay per head

    xh_raw = xi.reshape(bsz, s, h, P_HEAD).astype(jnp.float32)
    xh = xh_raw * dt[..., None]  # fold dt into the input
    bmat = b_in.astype(jnp.float32)  # (B,S,N) shared across heads (G=1)
    cmat = c_in.astype(jnp.float32)

    c = min(cfg.ssm_chunk, s)
    s_pad = -(-s // c) * c
    if s_pad != s:
        pad3 = ((0, 0), (0, s_pad - s), (0, 0))
        xh = jnp.pad(xh, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, pad3)
        cmat = jnp.pad(cmat, pad3)
        log_a = jnp.pad(log_a, pad3)
    nc = s_pad // c
    xc = xh.reshape(bsz, nc, c, h, P_HEAD).transpose(1, 0, 2, 3, 4)
    bc = bmat.reshape(bsz, nc, c, n).transpose(1, 0, 2, 3)
    cc = cmat.reshape(bsz, nc, c, n).transpose(1, 0, 2, 3)
    lc = log_a.reshape(bsz, nc, c, h).transpose(1, 0, 2, 3)

    causal_incl = jnp.tril(jnp.ones((c, c), bool))  # i <= t

    def chunk_step(hstate, inp):  # hstate: (B, H, N, P)
        xx, bb, ccm, ll = inp
        L_inc = jnp.cumsum(ll, axis=1)  # (B,c,H) inclusive
        # intra: y_t = sum_{i<=t} exp(L_t - L_i) * (C_t . B_i) x_i
        ratio = L_inc[:, :, None, :] - L_inc[:, None, :, :]  # (B,t,i,H)
        ratio = jnp.where(causal_incl[None, :, :, None], jnp.exp(ratio), 0.0)
        cb = jnp.einsum("btn,bin->bti", ccm, bb)
        y = jnp.einsum("bti,btih,bihp->bthp", cb, ratio, xx)
        # inter: y_t += exp(L_t) * C_t . h_0
        y += jnp.einsum("btn,bth,bhnp->bthp", ccm, jnp.exp(L_inc), hstate)
        # state: h_new = exp(L_last) h_0 + sum_i exp(L_last - L_i) B_i x_i^T
        last = L_inc[:, -1]  # (B,H)
        w_tail = jnp.exp(last[:, None] - L_inc)  # (B,c,H)
        h_new = hstate * jnp.exp(last)[:, :, None, None] + jnp.einsum(
            "bin,bih,bihp->bhnp", bb, w_tail, xx
        )
        return h_new, y.astype(x.dtype)

    h0 = (
        jnp.zeros((bsz, h, n, P_HEAD), jnp.float32)
        if state is None
        else state.h.astype(jnp.float32)
    )
    # remat: the (c x c) decay-ratio tensor is recomputed in backward
    step_fn = jax.checkpoint(
        chunk_step, policy=jax.checkpoint_policies.nothing_saveable
    )
    h_final, y = jax.lax.scan(step_fn, h0, (xc, bc, cc, lc))
    y = y.astype(jnp.float32)
    y = y.transpose(1, 0, 2, 3, 4).reshape(bsz, s_pad, h, P_HEAD)[:, :s]
    y = y + xh_raw * p["d_skip"][None, None, :, None]  # D skip connection
    y = y.reshape(bsz, s, di)
    y = rmsnorm(y.astype(x.dtype), p["out_norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return ctx.cs(out, "batch", "residual_seq", None), MambaLayerState(h=h_final, conv=conv_tail)
