"""Family-dispatching model API used by the launcher, dry-run, and examples.

Every family exposes: init_params, loss_fn, forward, prefill, decode_step,
param_specs, and (for decoders) cache/state constructors + specs. The API
here adds train_step (loss + grad + AdamW) and abstract (ShapeDtypeStruct)
variants for the dry-run.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..optim import AdamWConfig, AdamWState, apply_updates
from ..optim import init as adamw_init
from .config import ModelConfig
from .layers import Ctx, _dt
from . import rwkv6, transformer, whisper, zamba2

_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": rwkv6,
    "hybrid": zamba2,
    "encdec": whisper,
}


def module_for(cfg: ModelConfig):
    return _FAMILY[cfg.family]


def init_params(cfg: ModelConfig, key: jax.Array) -> Any:
    return module_for(cfg).init_params(cfg, key)


def abstract_params(cfg: ModelConfig) -> Any:
    """Param ShapeDtypeStructs without allocating (dry-run)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def param_specs(cfg: ModelConfig) -> Any:
    return module_for(cfg).param_specs(cfg)


def loss_fn(ctx: Ctx, params: Any, batch: dict) -> jax.Array:
    return module_for(ctx.cfg).loss_fn(ctx, params, batch)


def train_step(
    ctx: Ctx, params: Any, opt_state: AdamWState, batch: dict, opt_cfg: AdamWConfig,
    microbatches: int = 1,
):
    """One optimizer step; with microbatches > 1, gradients are accumulated
    over a scan of microbatches (activation memory / m)."""
    if microbatches <= 1:
        loss, grads = jax.value_and_grad(lambda p: loss_fn(ctx, p, batch))(params)
    else:
        m = microbatches

        def split(leaf):
            b = leaf.shape[0]
            assert b % m == 0, f"batch {b} not divisible by microbatches {m}"
            return leaf.reshape(m, b // m, *leaf.shape[1:])

        mbatch = jax.tree.map(split, batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(acc, mb):
            loss_acc, grad_acc = acc
            l, g = jax.value_and_grad(lambda p: loss_fn(ctx, p, mb))(params)
            grad_acc = jax.tree.map(
                lambda a, x: a + x.astype(jnp.float32) / m, grad_acc, g
            )
            return (loss_acc + l / m, grad_acc), None

        (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0), zeros), mbatch)
    params, opt_state, metrics = apply_updates(params, opt_state, grads, opt_cfg)
    metrics["loss"] = loss
    return params, opt_state, metrics


def init_opt(cfg: ModelConfig, params: Any, opt_cfg: AdamWConfig) -> AdamWState:
    return adamw_init(params, opt_cfg)


# -- serving -------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int):
    m = module_for(cfg)
    if cfg.family == "ssm":
        return m.init_state(cfg, batch)
    return m.init_caches(cfg, batch, max_len)


def decode_state_specs(cfg: ModelConfig):
    m = module_for(cfg)
    if cfg.family == "ssm":
        return m.state_specs(cfg)
    return m.cache_specs(cfg)


def prefill(ctx: Ctx, params: Any, tokens: jax.Array, max_len: int, batch: dict | None = None):
    m = module_for(ctx.cfg)
    if ctx.cfg.family == "encdec":
        return m.prefill(ctx, params, tokens, max_len, batch["frames"])
    if ctx.cfg.family == "vlm":
        return m.prefill(ctx, params, tokens, max_len, extra_embeds=batch["patches"])
    return m.prefill(ctx, params, tokens, max_len)


def decode_step(ctx: Ctx, params: Any, token: jax.Array, state):
    return module_for(ctx.cfg).decode_step(ctx, params, token, state)


# -- input specs (ShapeDtypeStructs for every model input) ----------------------


def input_specs(cfg: ModelConfig, kind: str, seq_len: int, global_batch: int) -> dict:
    """Abstract inputs for a (shape-kind x arch) cell.

    train:   full batch dict for train_step (tokens + modality stubs)
    prefill: prompt batch for prefill
    decode:  one new token + the decode state sized to seq_len
    """
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    b, s = global_batch, seq_len
    if kind == "train":
        if cfg.family == "encdec":
            return {
                "tokens": jax.ShapeDtypeStruct((b, s + 1), i32),
                "frames": jax.ShapeDtypeStruct((b, cfg.encoder_frames, cfg.d_model), dt),
            }
        if cfg.family == "vlm":
            s_tok = s - cfg.num_patches
            return {
                "tokens": jax.ShapeDtypeStruct((b, s_tok + 1), i32),
                "patches": jax.ShapeDtypeStruct((b, cfg.num_patches, cfg.d_model), dt),
            }
        return {"tokens": jax.ShapeDtypeStruct((b, s + 1), i32)}
    if kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "encdec":
            out["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_frames, cfg.d_model), dt)
        if cfg.family == "vlm":
            out["patches"] = jax.ShapeDtypeStruct((b, cfg.num_patches, cfg.d_model), dt)
        return out
    if kind == "decode":
        state = jax.eval_shape(lambda: init_decode_state(cfg, b, s))
        return {"token": jax.ShapeDtypeStruct((b, 1), i32), "state": state}
    raise ValueError(f"unknown shape kind {kind}")
