"""Whisper-small backbone: encoder-decoder transformer.

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, frames, D); the encoder runs bidirectional
self-attention over them, the decoder runs causal self-attention + cross
attention. LayerNorm + GELU + sinusoidal positions (no RoPE), as in the
original architecture.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (
    Ctx, _dt, attn_params, attn_sublayer, mlp_params, mlp_sublayer, norm,
    norm_params, sinusoidal,
)


class WhisperCaches(NamedTuple):
    self_k: jax.Array  # (L, B, Smax, Hkv, Dh)
    self_v: jax.Array
    cross_k: jax.Array  # (L, B, F, Hkv, Dh) — precomputed at prefill
    cross_v: jax.Array
    length: jax.Array


def _enc_dec_blocks(cfg: ModelConfig, key, l: int, cross: bool) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "ln1": norm_params(cfg, cfg.d_model, (l,)),
        "ln2": norm_params(cfg, cfg.d_model, (l,)),
        "attn": attn_params(cfg, ks[0], stack=(l,)),
        "mlp": mlp_params(cfg, ks[1], stack=(l,)),
    }
    if cross:
        p["ln_x"] = norm_params(cfg, cfg.d_model, (l,))
        p["xattn"] = attn_params(cfg, ks[2], stack=(l,))
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, 4)
    init = jax.nn.initializers.normal(0.02)
    return {
        "embed": init(ks[0], (cfg.vocab_size, cfg.d_model), _dt(cfg)),
        "enc_blocks": _enc_dec_blocks(cfg, ks[1], cfg.encoder_layers, cross=False),
        "enc_norm": norm_params(cfg, cfg.d_model),
        "dec_blocks": _enc_dec_blocks(cfg, ks[2], cfg.num_layers, cross=True),
        "final_norm": norm_params(cfg, cfg.d_model),
        "lm_head": init(ks[3], (cfg.d_model, cfg.vocab_size), _dt(cfg)),
    }


def param_specs(cfg: ModelConfig) -> dict:
    L = None

    def nrm():
        return {"w": (L, None), "b": (L, None)}

    attn = {
        "wq": (L, "fsdp", "heads"), "wk": (L, "fsdp", "heads"),
        "wv": (L, "fsdp", "heads"), "wo": (L, "heads", "fsdp"),
    }
    mlp = {"w_up": (L, "fsdp", "d_ff"), "w_down": (L, "d_ff", "fsdp")}
    enc = {"ln1": nrm(), "ln2": nrm(), "attn": dict(attn), "mlp": dict(mlp)}
    dec = {
        "ln1": nrm(), "ln2": nrm(), "ln_x": nrm(),
        "attn": dict(attn), "xattn": dict(attn), "mlp": dict(mlp),
    }
    fn = {"w": (None,), "b": (None,)}
    return {
        "embed": ("vocab", "fsdp"),
        "enc_blocks": enc, "enc_norm": dict(fn),
        "dec_blocks": dec,
        "final_norm": dict(fn),
        "lm_head": ("fsdp", "vocab"),
    }


def encode(ctx: Ctx, params: dict, frames: jax.Array) -> jax.Array:
    """frames: (B, F, D) stub embeddings -> encoder states."""
    cfg = ctx.cfg
    x = frames.astype(_dt(cfg)) + sinusoidal(frames.shape[1], cfg.d_model, _dt(cfg))
    x = ctx.cs(x, "batch", "residual_seq", None)

    def body(carry, pl):
        h, _ = attn_sublayer(
            ctx, pl["attn"], norm(ctx, pl["ln1"], carry), causal=False, use_rope=False
        )
        y = carry + h
        y = y + mlp_sublayer(ctx, pl["mlp"], norm(ctx, pl["ln2"], y))
        return y, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return norm(ctx, params["enc_norm"], x)


def _dec_block(ctx, pl, x, enc):
    """Decoder block for training/prefill (fresh cross-attn against enc)."""
    h, new_cache = attn_sublayer(
        ctx, pl["attn"], norm(ctx, pl["ln1"], x), use_rope=False
    )
    x = x + h
    h, xkv = attn_sublayer(
        ctx, pl["xattn"], norm(ctx, pl["ln_x"], x), xkv=enc, use_rope=False
    )
    x = x + h
    x = x + mlp_sublayer(ctx, pl["mlp"], norm(ctx, pl["ln2"], x))
    return x, new_cache, xkv


def decode_tokens(ctx: Ctx, params: dict, tokens: jax.Array, enc: jax.Array) -> jax.Array:
    """Teacher-forced decoder pass (training)."""
    cfg = ctx.cfg
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + sinusoidal(tokens.shape[1], cfg.d_model, x.dtype)
    x = ctx.cs(x, "batch", "residual_seq", None)

    def body(carry, pl):
        y, _, _ = _dec_block(ctx, pl, carry, enc)
        return y, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = norm(ctx, params["final_norm"], x)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return ctx.cs(logits, "batch", "seq", "vocab")


def forward(ctx: Ctx, params: dict, tokens: jax.Array, frames: jax.Array) -> jax.Array:
    return decode_tokens(ctx, params, tokens, encode(ctx, params, frames))


def loss_fn(ctx: Ctx, params: dict, batch: dict) -> jax.Array:
    from .losses import chunked_cross_entropy

    cfg = ctx.cfg
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    enc = encode(ctx, params, batch["frames"])
    x = jnp.take(params["embed"], inputs, axis=0)
    x = x + sinusoidal(inputs.shape[1], cfg.d_model, x.dtype)
    x = ctx.cs(x, "batch", "residual_seq", None)

    def body(carry, pl):
        y, _, _ = _dec_block(ctx, pl, carry, enc)
        return y, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = norm(ctx, params["final_norm"], x)
    return chunked_cross_entropy(ctx, x, params["lm_head"], labels)


def init_caches(cfg: ModelConfig, batch: int, max_len: int) -> WhisperCaches:
    l = cfg.num_layers
    shape = (l, batch, max_len, cfg.num_kv_heads, cfg.hd)
    xshape = (l, batch, cfg.encoder_frames, cfg.num_kv_heads, cfg.hd)
    dt = _dt(cfg)
    return WhisperCaches(
        self_k=jnp.zeros(shape, dt), self_v=jnp.zeros(shape, dt),
        cross_k=jnp.zeros(xshape, dt), cross_v=jnp.zeros(xshape, dt),
        length=jnp.zeros((), jnp.int32),
    )


def cache_specs(cfg: ModelConfig) -> WhisperCaches:
    s = (None, "batch", "kv_seq", "kv_heads4d", None)
    x = (None, "batch", None, "kv_heads4d", None)
    return WhisperCaches(self_k=s, self_v=s, cross_k=x, cross_v=x, length=())


def prefill(
    ctx: Ctx, params: dict, tokens: jax.Array, max_len: int, frames: jax.Array
):
    """Encode audio + run the decoder prompt; build self- and cross-KV caches."""
    cfg = ctx.cfg
    enc = encode(ctx, params, frames)
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + sinusoidal(s, cfg.d_model, x.dtype)
    x = ctx.cs(x, "batch", "residual_seq", None)

    def body(carry, pl):
        y, (k, v), xkv = _dec_block(ctx, pl, carry, enc)
        return y, (k, v, xkv[0], xkv[1])

    x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, params["dec_blocks"])
    x = norm(ctx, params["final_norm"], x)
    logits = jnp.einsum("bsd,dv->bsv", x[:, -1:], params["lm_head"])
    caches0 = init_caches(cfg, b, max_len)
    return logits, WhisperCaches(
        self_k=jax.lax.dynamic_update_slice(
            caches0.self_k, ks.astype(caches0.self_k.dtype), (0, 0, 0, 0, 0)
        ),
        self_v=jax.lax.dynamic_update_slice(
            caches0.self_v, vs.astype(caches0.self_v.dtype), (0, 0, 0, 0, 0)
        ),
        cross_k=xks.astype(caches0.cross_k.dtype),
        cross_v=xvs.astype(caches0.cross_v.dtype),
        length=jnp.asarray(s, jnp.int32),
    )


def decode_step(ctx: Ctx, params: dict, token: jax.Array, caches: WhisperCaches):
    """One decoder step against cached self-KV and precomputed cross-KV."""
    cfg = ctx.cfg
    b = token.shape[0]
    ln = caches.length
    x = jnp.take(params["embed"], token, axis=0)
    pos = sinusoidal(65536, cfg.d_model, x.dtype)
    x = x + jax.lax.dynamic_slice(pos, (ln, 0), (1, cfg.d_model))[None]

    def body(carry, scanned):
        pl, ck, cv, xk, xv = scanned
        h, (nk, nv) = attn_sublayer(
            ctx, pl["attn"], norm(ctx, pl["ln1"], carry),
            cache=(ck, cv), cache_len=ln, use_rope=False,
        )
        y = carry + h
        # cross-attention against the full precomputed encoder K/V
        h, _ = _cross_from_cache(ctx, pl["xattn"], norm(ctx, pl["ln_x"], y), xk, xv)
        y = y + h
        y = y + mlp_sublayer(ctx, pl["mlp"], norm(ctx, pl["ln2"], y))
        return y, (nk, nv)

    x, (nks, nvs) = jax.lax.scan(
        body, x, (params["dec_blocks"], caches.self_k, caches.self_v,
                  caches.cross_k, caches.cross_v),
    )
    x = norm(ctx, params["final_norm"], x)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return logits, WhisperCaches(
        self_k=nks, self_v=nvs, cross_k=caches.cross_k, cross_v=caches.cross_v,
        length=ln + token.shape[1],
    )


def _cross_from_cache(ctx, p, x, xk, xv):
    """Cross-attn where K/V are cached: only the q/o projections run."""
    cfg = ctx.cfg
    b, s, d = x.shape
    hd, hq = cfg.hd, cfg.num_heads
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, s, hq, hd)
    from .layers import _attend

    o = _attend(ctx, q, xk, xv, causal=False, window=None)
    o = o.reshape(b, s, hq * hd)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"]), None
