"""Logical-axis sharding rules mapped onto the production mesh.

Model code annotates arrays with *logical* axes; the rules translate them to
mesh axes. The paper's strategies surface here (DESIGN.md §4): ``replicate``
(S1) vs sharded layouts for read-hot operands, and push- vs pull-style
constraint placement for MoE dispatch (S2).
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = tuple[str, ...] | str | None


@dataclasses.dataclass(frozen=True)
class Rules:
    """logical axis -> mesh axes."""

    batch: MeshAxes = ("data",)
    seq: MeshAxes = None
    residual_seq: MeshAxes = None  # Megatron-SP: residual stream seq-sharded
    kv_seq: MeshAxes = None  # set to ("data",) for long-context decode
    heads: MeshAxes = "model"  # flattened H*hd projections (always divisible)
    heads4d: MeshAxes = "model"  # explicit head dim of 4-D activations
    kv_heads4d: MeshAxes = "model"  # explicit kv-head dim (replicate if uneven)
    heads_pad: MeshAxes = "model"  # padded-head dim (always divisible)
    d_model: MeshAxes = None
    fsdp: MeshAxes = "data"  # weight-shard axis (d_model dim of weights)
    d_ff: MeshAxes = "model"
    vocab: MeshAxes = "model"
    experts: MeshAxes = "model"  # expert dim of MoE weights (EP storage)
    expert_inner: MeshAxes = None  # d_model dim of expert weights (FSDP when no EP)
    moe_d_ff: MeshAxes = None  # F dim of expert weights ("model" in tp mode)
    replicated: MeshAxes = None

    def spec(self, *axes: str | None) -> P:
        out = []
        for a in axes:
            if a is None:
                out.append(None)
            else:
                out.append(getattr(self, a))
        return P(*out)


def make_rules(
    mesh: Mesh,
    *,
    num_experts: int = 0,
    num_heads: int = 0,
    num_kv_heads: int = 0,
    vocab_size: int = 0,
    long_context: bool = False,
    seq_shard: bool = False,
) -> Rules:
    """Production rules for the (pod?, data, model) mesh.

    - batch spans (pod, data): DP across pods, DP+FSDP within.
    - 4-D head dims shard over "model" only when divisible (e.g. qwen2's 28
      q-heads / 4 kv-heads do NOT divide 16 — heads stay unsharded and the
      baseline pays an attention-region gather, a documented hillclimb
      target); flattened H*hd projection dims always divide and always shard.
    - experts shard over "data" for EP dispatch (handled inside moe.py's
      shard_map); the "experts" rule here covers the weight STORAGE layout:
      sharded when divisible, else replicated-expert/F-sliced (tp mode).
    - long-context decode (batch=1) shards the KV sequence over "data"
      (sequence parallelism) since there is no batch to shard.
    """
    axes = mesh.axis_names
    batch = ("pod", "data") if "pod" in axes else ("data",)
    fsdp = ("pod", "data") if "pod" in axes else ("data",)  # hierarchical FSDP
    ms = mesh.shape["model"] if "model" in mesh.shape else 1
    ds = mesh.shape["data"] if "data" in mesh.shape else 1
    ep = bool(num_experts) and num_experts % ds == 0
    kv_head_model = bool(num_kv_heads) and num_kv_heads % ms == 0
    return Rules(
        batch=batch,
        fsdp=fsdp,
        # Megatron sequence parallelism: the residual stream (and hence the
        # per-layer scan-carry checkpoints) live seq-sharded over "model";
        # XLA inserts the AG/RS pair at each layer boundary. Cuts stored
        # activations by the model-axis factor — required to fit train/prefill
        # shapes in HBM. Off for decode (seq 1).
        residual_seq=("model",) if seq_shard else None,
        # KV caches shard their sequence dim over "model" when the kv-head dim
        # cannot take it (kv-head counts rarely divide a 16-way axis; a 32k
        # cache must not replicate), plus "data" for long-context (batch=1).
        kv_seq=_kv_seq_axes(long_context, kv_head_model),
        heads4d="model" if (num_heads and num_heads % ms == 0) else None,
        kv_heads4d="model" if kv_head_model else None,
        # MoE weight storage: EP shards experts over "data" with full-F
        # experts; the tp fallback (expert count not divisible, e.g. mixtral
        # 8 on 16) keeps experts unsharded but FSDPs d_model and TPs F.
        experts="data" if ep else None,
        expert_inner="model" if ep else "data",
        moe_d_ff=None if ep else "model",
        # whisper's 51865 vocab does not divide the model axis: replicate
        vocab="model" if (not vocab_size or vocab_size % ms == 0) else None,
    )


def _kv_seq_axes(long_context: bool, kv_head_model: bool):
    axes = (("data",) if long_context else ()) + (
        () if kv_head_model else ("model",)
    )
    return axes or None


def constrain(x: jax.Array, mesh: Mesh | None, rules: Rules | None, *axes: str | None):
    if mesh is None or rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, rules.spec(*axes)))


def named(mesh: Mesh, rules: Rules, *axes: str | None) -> NamedSharding:
    return NamedSharding(mesh, rules.spec(*axes))
