"""Shared transformer layers: norms, RoPE, GQA attention (train/prefill/
decode), MLP. All functions are mesh-optional: with a (mesh, rules) context
they add sharding constraints, without they run plainly on one device."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .sharding import Rules, constrain


@dataclasses.dataclass(frozen=True)
class Ctx:
    cfg: ModelConfig
    mesh: Any = None
    rules: Rules | None = None

    def cs(self, x, *axes):
        return constrain(x, self.mesh, self.rules, *axes)


# -- norms ---------------------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    x32 = x.astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * r).astype(x.dtype) * w


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w + b


def norm(ctx: Ctx, p: dict, x: jax.Array) -> jax.Array:
    if ctx.cfg.norm == "layernorm":
        return layernorm(x, p["w"], p["b"], ctx.cfg.norm_eps)
    return rmsnorm(x, p["w"], ctx.cfg.norm_eps)


def norm_params(cfg: ModelConfig, d: int, stack: tuple[int, ...] = ()) -> dict:
    shape = (*stack, d)
    p = {"w": jnp.ones(shape, _dt(cfg))}
    if cfg.norm == "layernorm":
        p["b"] = jnp.zeros(shape, _dt(cfg))
    return p


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# -- positions -----------------------------------------------------------------


def rope(x: jax.Array, pos: jax.Array, theta: float, fraction: float) -> jax.Array:
    """x: (B, S, H, Dh); pos: (S,) or (B, S) absolute positions."""
    dh = x.shape[-1]
    rot = int(dh * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    half = rot // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    if pos.ndim == 1:
        ang = pos.astype(jnp.float32)[None, :, None] * freqs[None, None, :]  # (1,S,half)
    else:
        ang = pos.astype(jnp.float32)[:, :, None] * freqs[None, None, :]  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1 = x[..., :half]
    x2 = x[..., half:rot]
    rest = x[..., rot:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos, rest], axis=-1)


def sinusoidal(seq: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div[: (d + 1) // 2]))
    return pe.astype(dtype)


# -- attention -----------------------------------------------------------------


_SCORE_BYTE_BUDGET = 1 << 28  # per-device cap on the materialized score tile


def _attend_dense(q, k, v, *, causal, window, scale, q_offset, sq_total, kv_valid_len):
    """One (B, cq, Hq, Dh) x (B, Skv, Hkv, Dh) attention tile, jnp reference."""
    b, cq, hq, dh = q.shape
    skv = k.shape[1]
    hkv = k.shape[2]
    group = hq // hkv
    kr = jnp.repeat(k, group, axis=2)
    vr = jnp.repeat(v, group, axis=2)
    s = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)
    ) * scale
    if kv_valid_len is not None:
        q_pos = q_offset + jnp.arange(cq)[:, None] + (kv_valid_len - sq_total)
    else:
        q_pos = q_offset + jnp.arange(cq)[:, None] + (skv - sq_total)
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((cq, skv), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32)).astype(q.dtype)


def _attend(
    ctx: Ctx,
    q: jax.Array,  # (B, Sq, Hq, Dh)
    k: jax.Array,  # (B, Skv, Hkv, Dh)
    v: jax.Array,
    *,
    causal: bool,
    window: int | None,
    kv_valid_len: jax.Array | None = None,  # dynamic kv length (decode)
) -> jax.Array:
    """Attention dispatch: flash kernel (static masks), dense jnp, or
    q-chunked jnp (lax.map over query blocks — flash-shaped memory footprint
    with pure-jnp lowering for the CPU dry-run)."""
    cfg = ctx.cfg
    b, sq, hq, dh = q.shape
    skv = k.shape[1]
    if cfg.attn_impl == "flash" and kv_valid_len is None:
        from ..kernels.flash_attention.ops import flash_attention

        o = flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal, window=window,
        )
        return o.transpose(0, 2, 1, 3)
    scale = dh ** -0.5
    # per-device score bytes (account for batch/head sharding)
    shards = 1
    if ctx.mesh is not None and ctx.rules is not None:
        for a in (ctx.rules.batch or ()):
            shards *= ctx.mesh.shape.get(a, 1)
        ms = ctx.mesh.shape.get("model", 1)
        if ctx.rules.heads4d or hq % ms == 0:  # incl. the padded-head path
            shards *= ms
    score_bytes = b * hq * sq * skv * 4 // shards
    if kv_valid_len is not None or score_bytes <= _SCORE_BYTE_BUDGET or sq <= 128:
        return _attend_dense(
            q, k, v, causal=causal, window=window, scale=scale,
            q_offset=0, sq_total=sq, kv_valid_len=kv_valid_len,
        )
    # chunk queries so each tile fits the budget
    cq = sq
    while cq > 128 and (b * hq * cq * skv * 4 // shards) > _SCORE_BYTE_BUDGET:
        cq //= 2
    while sq % cq:
        cq //= 2
    nc = sq // cq
    qc = q.reshape(b, nc, cq, hq, dh).transpose(1, 0, 2, 3, 4)
    offsets = jnp.arange(nc, dtype=jnp.int32) * cq

    def tile(args):
        qi, off = args
        return _attend_dense(
            qi, k, v, causal=causal, window=window, scale=scale,
            q_offset=off, sq_total=sq, kv_valid_len=None,
        )

    # remat each tile: backward recomputes the score block instead of saving
    # the softmax residuals of every chunk (flash-attention-like memory)
    tile = jax.checkpoint(tile, policy=jax.checkpoint_policies.nothing_saveable)
    o = jax.lax.map(tile, (qc, offsets))
    return o.transpose(1, 0, 2, 3, 4).reshape(b, sq, hq, dh)


def attn_params(cfg: ModelConfig, key, d: int | None = None, stack: tuple[int, ...] = ()) -> dict:
    d = d or cfg.d_model
    hd, hq, hkv = cfg.hd, cfg.num_heads, cfg.num_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    init = jax.nn.initializers.normal(0.02)
    dt = _dt(cfg)
    p = {
        "wq": init(k1, (*stack, d, hq * hd), dt),
        "wk": init(k2, (*stack, d, hkv * hd), dt),
        "wv": init(k3, (*stack, d, hkv * hd), dt),
        "wo": init(k4, (*stack, hq * hd, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((*stack, hq * hd), dt)
        p["bk"] = jnp.zeros((*stack, hkv * hd), dt)
        p["bv"] = jnp.zeros((*stack, hkv * hd), dt)
    return p


def attn_sublayer(
    ctx: Ctx,
    p: dict,
    x: jax.Array,  # (B, S, D)
    *,
    pos_offset: jax.Array | int = 0,
    cache: tuple[jax.Array, jax.Array] | None = None,  # (B, Smax, Hkv, Dh) x2
    cache_len: jax.Array | None = None,  # valid entries in cache before this call
    xkv: jax.Array | None = None,  # cross-attention source (B, Skv, D)
    causal: bool = True,
    use_rope: bool = True,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array] | None]:
    """Attention sublayer. Returns (out, updated cache or computed (k, v)).

    - self-attn train/prefill: cache=None, returns freshly computed (k, v)
    - decode: cache + cache_len given; x is the new token(s)
    - cross-attn: xkv given (keys/values from xkv, no causal mask, no cache)
    """
    cfg = ctx.cfg
    b, s, d = x.shape
    hd, hq, hkv = cfg.hd, cfg.num_heads, cfg.num_kv_heads
    src = xkv if xkv is not None else x
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", src, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", src, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, x.shape[1], hq, hd)
    k = k.reshape(b, src.shape[1], hkv, hd)
    v = v.reshape(b, src.shape[1], hkv, hd)
    q = ctx.cs(q, "batch", "seq", "heads4d", None)
    k = ctx.cs(k, "batch", "seq", "kv_heads4d", None)
    v = ctx.cs(v, "batch", "seq", "kv_heads4d", None)

    if use_rope and cfg.pos_emb == "rope" and xkv is None:
        qpos = jnp.arange(x.shape[1]) + pos_offset
        kpos = jnp.arange(src.shape[1]) + (0 if cache is not None else pos_offset)
        q = rope(q, qpos, cfg.rope_theta, cfg.rope_fraction)
        k = rope(k, kpos if cache is None else qpos, cfg.rope_theta, cfg.rope_fraction)

    if cache is not None:
        ck, cv = cache
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_len, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_len, 0, 0))
        o = _attend(
            ctx, q, ck, cv, causal=causal, window=cfg.sliding_window,
            kv_valid_len=cache_len + x.shape[1],
        )
        new_cache = (ck, cv)
    else:
        ms = ctx.mesh.shape.get("model", 1) if ctx.mesh is not None else 1
        if (
            cfg.tp_pad_heads and ms > 1 and hq % ms != 0
            and (ctx.rules is None or ctx.rules.heads4d is None)
        ):
            # padded-head TP: repeat KV to MHA (group mapping preserved),
            # zero-pad heads to the next model-axis multiple, shard the
            # head dim. Exact: padded heads attend over zero K/V and their
            # output slice is dropped before the wo projection.
            hq_pad = -(-hq // ms) * ms
            kr = jnp.repeat(k, hq // hkv, axis=2)
            vr = jnp.repeat(v, hq // hkv, axis=2)
            pad = ((0, 0), (0, 0), (0, hq_pad - hq), (0, 0))
            qp = ctx.cs(jnp.pad(q, pad), "batch", "seq", "heads_pad", None)
            kp = ctx.cs(jnp.pad(kr, pad), "batch", "seq", "heads_pad", None)
            vp = ctx.cs(jnp.pad(vr, pad), "batch", "seq", "heads_pad", None)
            o = _attend(
                ctx, qp, kp, vp, causal=causal and xkv is None,
                window=cfg.sliding_window if xkv is None else None,
            )[:, :, :hq, :]
        else:
            o = _attend(
                ctx, q, k, v, causal=causal and xkv is None,
                window=cfg.sliding_window if xkv is None else None,
            )
        new_cache = (k, v)
    o = o.reshape(b, x.shape[1], hq * hd)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"])
    return ctx.cs(out, "batch", "residual_seq", None), new_cache


# -- MLP -----------------------------------------------------------------------


def mlp_params(cfg: ModelConfig, key, d_ff: int | None = None, stack: tuple[int, ...] = ()) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = _dt(cfg)
    init = jax.nn.initializers.normal(0.02)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {
            "w_gate": init(k1, (*stack, d, f), dt),
            "w_up": init(k2, (*stack, d, f), dt),
            "w_down": init(k3, (*stack, f, d), dt),
        }
    return {"w_up": init(k1, (*stack, d, f), dt), "w_down": init(k2, (*stack, f, d), dt)}


def mlp_sublayer(ctx: Ctx, p: dict, x: jax.Array) -> jax.Array:
    if ctx.cfg.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
        h = h * jnp.einsum("bsd,df->bsf", x, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_up"]))
    h = ctx.cs(h, "batch", "seq", "d_ff")
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    return ctx.cs(out, "batch", "residual_seq", None)
