from .config import ModelConfig
from .layers import Ctx
from . import api
