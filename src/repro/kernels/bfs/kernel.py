"""Pallas kernel: one BFS frontier-expansion round, blocked + aggregated.

Paper mapping (§3.2, Alg. 2): the remote-write BFS has every frontier
vertex *push* a proposed parent at its neighbors; the migratory-hardware
win is aggregating those writes instead of issuing them one by one. Here
a grid program owns a ``block_rows`` stripe of the adjacency (the grain),
gathers its stripe's neighbor lists from VMEM, and scatter-mins all of its
proposals into one private partial — the per-block aggregation — before
merging that partial into the shared next-frontier array. The output block
index map is constant (every program revisits the same (N,) block), so the
merge is the classic TPU revisiting-accumulator pattern: program 0
initializes, later programs ``min`` into it, exactly the deterministic
min-merge the repo's BFS variants all share (DESIGN.md §10).

``UNVISITED`` (int32 max) is the merge identity. Frontier arrives as an
int32 0/1 mask (TPU block loads prefer lane-width dtypes over bool).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.util import round_up
from ..runtime import resolve_interpret
from .ref import UNVISITED


def _bfs_expand_kernel(adj_ref, frontier_ref, out_ref, *, block_rows: int):
    i = pl.program_id(0)
    adj = adj_ref[...]  # (block_rows, K) int32 neighbor ids
    fr = frontier_ref[...]  # (block_rows,) int32 0/1
    n_out = out_ref.shape[0]
    # global source ids for this stripe (2D iota: TPU-safe)
    src = i * block_rows + jax.lax.broadcasted_iota(jnp.int32, adj.shape, 0)
    valid = (fr != 0)[:, None] & (adj >= 0)
    dst = jnp.where(valid, adj, 0)
    prop = jnp.where(valid, src, UNVISITED)
    # per-block aggregation: all of this stripe's remote writes collapse
    # into one private partial before touching the shared array
    partial = (
        jnp.full((n_out,), UNVISITED, dtype=jnp.int32)
        .at[dst.reshape(-1)]
        .min(prop.reshape(-1), mode="drop")
    )

    @pl.when(i == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(i > 0)
    def _merge():
        out_ref[...] = jnp.minimum(out_ref[...], partial)


@functools.partial(jax.jit, static_argnames=("n_out", "block_rows", "interpret"))
def _bfs_expand_call(adj, frontier, *, n_out: int, block_rows: int, interpret: bool):
    """The raw pallas_call: rows already a multiple of ``block_rows``."""
    r, k = adj.shape
    grid = (r // block_rows,)
    return pl.pallas_call(
        functools.partial(_bfs_expand_kernel, block_rows=block_rows),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        # every program maps to the same output block: the revisiting
        # accumulator the per-block partials min-merge into
        out_specs=pl.BlockSpec((n_out,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_out,), jnp.int32),
        interpret=interpret,
    )(adj, frontier)


def bfs_expand_pallas(
    adj: jax.Array,
    frontier: jax.Array,
    *,
    block_rows: int = 256,
    interpret: "bool | None" = None,
) -> jax.Array:
    """One expansion round. adj: (N, K) int32 (-1 padding); frontier: (N,)
    int32/bool mask. Returns the (N,) proposed-parent array (UNVISITED
    where nothing proposed) — bit-identical to the reference oracle.

    Any N works: the row stripe padding (masked rows, frontier 0) is
    internal, mirroring the SpMV kernel's contract.
    """
    n, k = adj.shape
    block = max(1, min(block_rows, n))
    r_pad = round_up(n, block)
    frontier = frontier.astype(jnp.int32)
    if r_pad != n:
        adj = jnp.pad(adj, ((0, r_pad - n), (0, 0)), constant_values=-1)
        frontier = jnp.pad(frontier, (0, r_pad - n))
    return _bfs_expand_call(
        adj,
        frontier,
        n_out=n,
        block_rows=block,
        interpret=resolve_interpret(interpret),
    )
