"""Reference oracle for one BFS frontier-expansion round.

Semantically identical to ``repro.core.bfs._expand_dense`` (the local
substrate's round body): every frontier vertex proposes itself as parent
for each neighbor via a dense min-scatter; UNVISITED slots are the merge
identity. Integer min-merge makes the round — and therefore the whole
parent tree — deterministic, which is what lets the kernel tests demand
bit-identical output rather than a tolerance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

UNVISITED = jnp.iinfo(jnp.int32).max  # same sentinel as repro.core.bfs


def bfs_expand_reference(adj: jax.Array, frontier: jax.Array) -> jax.Array:
    """One expansion round: (N, K) adjacency + (N,) frontier mask -> (N,)
    proposed-parent array (UNVISITED where nothing proposed)."""
    n, k = adj.shape
    src = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, k))
    valid = (frontier != 0)[:, None] & (adj >= 0)
    dst = jnp.where(valid, adj, 0)
    prop = jnp.where(valid, src, UNVISITED)
    return jnp.full((n,), UNVISITED, dtype=jnp.int32).at[dst.reshape(-1)].min(
        prop.reshape(-1), mode="drop"
    )
