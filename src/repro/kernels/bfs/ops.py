"""Public BFS kernel ops: the round wrapper and the full level-synchronous
loop the ``("bfs", "pallas")`` engine kernel dispatches to.

The loop is ``repro.core.bfs._bfs_local`` with the expansion round swapped
for the Pallas kernel; the min-merge is deterministic integer arithmetic,
so the parent tree is bit-identical to the local oracle for every strategy
and block size — the parity the tests pin. Both S2 comm strategies share
the kernel (the per-block aggregation *is* the remote-write realization;
the migrate variant computes the same tree, as on the local substrate) —
the strategy's contribution here is the grain axis: ``block_rows``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ...core.bfs import UNVISITED, _adj_global, _finalize_parents
from ...core.strategies import MigratoryStrategy
from ...sparse.graph import PartitionedGraph
from ..runtime import resolve_interpret
from .kernel import bfs_expand_pallas
from .ref import bfs_expand_reference


def bfs_expand(
    adj: jax.Array,
    frontier: jax.Array,
    *,
    block_rows: int = 256,
    use_kernel: bool = True,
    interpret: "bool | None" = None,
) -> jax.Array:
    """One frontier-expansion round, kernel or reference oracle."""
    if not use_kernel:
        return bfs_expand_reference(adj, frontier)
    return bfs_expand_pallas(
        adj, frontier, block_rows=block_rows, interpret=interpret
    )


@partial(jax.jit, static_argnames=("max_rounds", "block_rows", "interpret"))
def _bfs_pallas_loop(
    adj: jax.Array, root: jax.Array, max_rounds: int, block_rows: int, interpret: bool
) -> jax.Array:
    n = adj.shape[0]
    parents0 = jnp.full((n,), UNVISITED, dtype=jnp.int32).at[root].set(root)
    frontier0 = jnp.zeros((n,), dtype=bool).at[root].set(True)

    def cond(state):
        _, frontier, it = state
        return jnp.logical_and(frontier.any(), it < max_rounds)

    def body(state):
        parents, frontier, it = state
        nP = bfs_expand_pallas(
            adj, frontier, block_rows=block_rows, interpret=interpret
        )
        newly = (parents == UNVISITED) & (nP != UNVISITED)
        parents = jnp.where(newly, nP, parents)
        return parents, newly, it + 1

    parents, _, _ = jax.lax.while_loop(cond, body, (parents0, frontier0, 0))
    return parents


def bfs_pallas(
    g: PartitionedGraph,
    root: int,
    strategy: "MigratoryStrategy | None" = None,
    max_rounds: "int | None" = None,
    *,
    block_rows: "int | None" = None,
    interpret: "bool | None" = None,
) -> jax.Array:
    """Full BFS through the Pallas round kernel. (n_vertices,) int32
    parents, -1 unreached — bit-identical to ``bfs_local``.

    ``block_rows`` (explicit) beats the strategy's grain axis beats the
    dynamic-grain default; the engine's autotuner sweeps it via
    ``MigratoryStrategy.grain``.
    """
    adj = _adj_global(g)
    n = adj.shape[0]
    max_rounds = max_rounds or n
    if block_rows is None:
        st = strategy or MigratoryStrategy()
        block_rows = st.dynamic_grain(n)
    block = max(1, min(int(block_rows), n))
    parents = _bfs_pallas_loop(
        adj, jnp.int32(root), max_rounds, block, resolve_interpret(interpret)
    )
    return _finalize_parents(g, parents)
