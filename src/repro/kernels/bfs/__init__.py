"""Pallas BFS frontier expansion (DESIGN.md §2a): one level-synchronous
round as a blocked adjacency gather + per-block scatter-min accumulation —
the paper's remote-write aggregation realized as grid-program partials."""
from .kernel import bfs_expand_pallas
from .ops import bfs_expand, bfs_pallas
from .ref import bfs_expand_reference

__all__ = ["bfs_expand", "bfs_expand_pallas", "bfs_expand_reference", "bfs_pallas"]
