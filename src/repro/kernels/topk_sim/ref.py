"""Pure-jnp oracle for the fused similarity+top-k kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import NEG, _sim_from_feats


def topk_sim_reference(
    feat_v: jax.Array, feat_u: jax.Array, mask_v: jax.Array, mask_u: jax.Array,
    *, t1: int, t2: int, t3: int, k: int = 4,
):
    """Batched (vmap over pairs) similarity + lax.top_k."""

    def one(fv, fu, mv, mu):
        s = _sim_from_feats(fv, fu, t1, t2, t3)
        valid = (mv > 0)[:, None] & (mu > 0)[None, :]
        s = jnp.where(valid, s, NEG)
        sc, ix = jax.lax.top_k(s, k)
        return sc, ix.astype(jnp.int32)

    return jax.vmap(one)(feat_v, feat_u, mask_v, mask_u)
