"""Public op: pack VertexSet metadata into dense feature planes and run the
fused similarity+top-k kernel over a PAIR task list."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.gsana_data import Buckets, VertexSet
from .kernel import topk_sim_pallas
from .ref import topk_sim_reference


def _hist_f32(a: jax.Array, vocab: int) -> jax.Array:
    oh = jax.nn.one_hot(jnp.where(a >= 0, a, vocab), vocab + 1, dtype=jnp.float32)
    return oh.sum(axis=-2)[..., :vocab]


def pack_features(vs: VertexSet, vocab: tuple[int, int, int]) -> jax.Array:
    """(n, F) dense feature plane: scalars + the three metadata histograms."""
    t1, t2, t3 = vocab
    return jnp.concatenate(
        [
            vs.deg.astype(jnp.float32)[:, None],
            vs.vtype.astype(jnp.float32)[:, None],
            (vs.ntypes >= 0).sum(-1).astype(jnp.float32)[:, None],
            (vs.etypes >= 0).sum(-1).astype(jnp.float32)[:, None],
            (vs.attrs >= 0).sum(-1).astype(jnp.float32)[:, None],
            _hist_f32(vs.ntypes, t1),
            _hist_f32(vs.etypes, t2),
            _hist_f32(vs.attrs, t3),
        ],
        axis=1,
    )


def topk_sim_pairs(
    vs1: VertexSet,
    vs2: VertexSet,
    b1: Buckets,
    b2: Buckets,
    pair_b2: jax.Array,  # (P,) QT2 bucket id per task
    pair_b1: jax.Array,  # (P,) QT1 bucket id per task (-1 = inactive task)
    *,
    vocab: tuple[int, int, int] = (16, 16, 64),
    k: int = 4,
    use_kernel: bool = True,
    interpret: bool = True,
):
    """Run all PAIR tasks. Returns (scores (P, cap2, k), u_ids (P, cap2, k))."""
    t1, t2, t3 = vocab
    f1 = pack_features(vs1, vocab)
    f2 = pack_features(vs2, vocab)
    v_idx = b2.vid[pair_b2]  # (P, cap2)
    u_idx = jnp.where(pair_b1[:, None] >= 0, b1.vid[jnp.maximum(pair_b1, 0)], -1)
    fv = f2[jnp.maximum(v_idx, 0)]
    fu = f1[jnp.maximum(u_idx, 0)]
    mv = (v_idx >= 0).astype(jnp.float32)
    mu = (u_idx >= 0).astype(jnp.float32)
    fn = topk_sim_pallas if use_kernel else topk_sim_reference
    kwargs = dict(t1=t1, t2=t2, t3=t3, k=k)
    if use_kernel:
        kwargs["interpret"] = interpret
    scores, local_ix = fn(fv, fu, mv, mu, **kwargs)
    u_ids = jax.vmap(lambda u, ix: u[ix])(u_idx, local_ix)  # (P, cap2, k)
    u_ids = jnp.where(jnp.isfinite(scores), u_ids, -1)
    return scores, u_ids
