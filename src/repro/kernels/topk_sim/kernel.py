"""Pallas TPU kernel: fused GSANA bucket-pair similarity + top-k (S3/PAIR).

One grid program = one ⟨B, B'⟩ PAIR task (paper Alg. 5). The irregular
per-vertex metadata (sorted type/attribute arrays) is packed OUTSIDE the
kernel into dense feature planes (histograms + scalars, see ops.py) so the
kernel streams two MXU/VPU-aligned tiles:

    feat_v (A, F), feat_u (B, F)  ->  scores (A, k), idx (A, k)

computing all five σ metrics (Δ, τ, τ_V, τ_E, C_V) as elementwise/reduction
ops on the feature planes, then maintaining the paper's "priority list with
top k elements" entirely in VMEM via k unrolled max-and-mask selection passes
— no global memory traffic for the priority queues.

Feature plane layout (F = 5 + T1 + T2 + T3, padded):
    [0] deg, [1] vtype, [2] |ntypes|, [3] |etypes|, [4] |attrs|,
    [5:5+T1] ntypes hist, [5+T1:5+T1+T2] etypes hist, [...:+T3] attrs hist.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = float("-inf")


def _sim_from_feats(fv, fu, t1: int, t2: int, t3: int):
    """(A, F) x (B, F) -> (A, B) σ scores (valid-slot masking done by caller)."""
    deg_v, deg_u = fv[:, 0], fu[:, 0]
    s_deg = 1.0 / (1.0 + jnp.abs(deg_v[:, None] - deg_u[None, :]))
    s_typ = (fv[:, 1][:, None] == fu[:, 1][None, :]).astype(jnp.float32)

    def ov(lo, width, nslot):
        hv = fv[:, lo : lo + width]
        hu = fu[:, lo : lo + width]
        inter = jnp.minimum(hv[:, None, :], hu[None, :, :]).sum(-1)
        denom = jnp.maximum(jnp.maximum(fv[:, nslot][:, None], fu[:, nslot][None, :]), 1.0)
        return inter / denom

    o = 5
    s_nt = ov(o, t1, 2)
    s_et = ov(o + t1, t2, 3)
    s_at = ov(o + t1 + t2, t3, 4)
    return 0.2 * (s_deg + s_typ + s_nt + s_et + s_at)


def _topk_sim_kernel(
    fv_ref, fu_ref, mv_ref, mu_ref, score_ref, idx_ref, *, t1, t2, t3, k
):
    fv = fv_ref[0]  # (A, F)
    fu = fu_ref[0]  # (B, F)
    mv = mv_ref[0]  # (A,) validity
    mu = mu_ref[0]  # (B,)
    s = _sim_from_feats(fv, fu, t1, t2, t3)
    valid = (mv > 0)[:, None] & (mu > 0)[None, :]
    s = jnp.where(valid, s, NEG)
    a, b = s.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (a, b), 1)
    # k unrolled selection passes: running top-k priority list in VMEM
    for j in range(k):
        m = jnp.max(s, axis=1)
        arg = jnp.argmax(s, axis=1).astype(jnp.int32)
        score_ref[0, :, j] = m
        idx_ref[0, :, j] = arg
        s = jnp.where(cols == arg[:, None], NEG, s)


@functools.partial(jax.jit, static_argnames=("t1", "t2", "t3", "k", "interpret"))
def topk_sim_pallas(
    feat_v: jax.Array,  # (P, A, F) f32
    feat_u: jax.Array,  # (P, B, F) f32
    mask_v: jax.Array,  # (P, A) f32 1/0
    mask_u: jax.Array,  # (P, B) f32 1/0
    *,
    t1: int,
    t2: int,
    t3: int,
    k: int = 4,
    interpret: bool = True,
):
    p, a, f = feat_v.shape
    _, b, _ = feat_u.shape
    kernel = functools.partial(_topk_sim_kernel, t1=t1, t2=t2, t3=t3, k=k)
    return pl.pallas_call(
        kernel,
        grid=(p,),
        in_specs=[
            pl.BlockSpec((1, a, f), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, b, f), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, a), lambda i: (i, 0)),
            pl.BlockSpec((1, b), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, a, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, a, k), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, a, k), jnp.float32),
            jax.ShapeDtypeStruct((p, a, k), jnp.int32),
        ],
        interpret=interpret,
    )(feat_v, feat_u, mask_v, mask_u)
