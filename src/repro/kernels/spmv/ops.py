"""Jit'd public wrapper for the SpMV kernel: pads rows to the grain and
dispatches kernel vs reference."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.util import round_up
from .kernel import spmv_ell_pallas
from .ref import spmv_ell_reference


def spmv(
    cols: jax.Array,
    vals: jax.Array,
    x: jax.Array,
    *,
    grain: int = 256,
    use_kernel: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """y = A @ x for padded-ELL A. Handles row padding to the grain.

    ``grain`` = rows per program (the paper's grain size, Fig. 4).
    """
    r, k = cols.shape
    if not use_kernel:
        return spmv_ell_reference(cols, vals, x)
    g = max(1, min(grain, r))
    r_pad = round_up(r, g)
    if r_pad != r:
        cols = jnp.pad(cols, ((0, r_pad - r), (0, 0)), constant_values=-1)
        vals = jnp.pad(vals, ((0, r_pad - r), (0, 0)))
    y = spmv_ell_pallas(cols, vals, x, block_rows=g, interpret=interpret)
    return y[:r]
