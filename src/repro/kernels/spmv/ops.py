"""Jit'd public wrapper for the SpMV kernels: dispatches reference vs
blocked-ELL vs CSR-stripe variants; padding and interpret policy live in
the kernels themselves."""
from __future__ import annotations

import jax

from .kernel import spmv_ell_pallas
from .ref import spmv_ell_reference
from .stripe import StripePlan, build_stripe_plan, spmv_ell_stripes

#: dense-ELL padding overhead at which the auto variant flips to stripes:
#: below this the blocked kernel's single launch wins, above it a skewed
#: matrix is mostly executing padding
STRIPE_WASTE_THRESHOLD = 2.0


def spmv(
    cols: jax.Array,
    vals: jax.Array,
    x: jax.Array,
    *,
    grain: int = 256,
    use_kernel: bool = True,
    interpret: "bool | None" = None,
    variant: str = "ell",
    stripe_plan: "StripePlan | None" = None,
) -> jax.Array:
    """y = A @ x for padded-ELL A.

    ``grain`` = rows per program (the paper's grain size, Fig. 4).
    ``variant``: ``"ell"`` (blocked, one launch), ``"stripe"`` (sliced-ELL
    per-stripe widths for skewed rows; needs concrete ``cols`` or a
    prebuilt ``stripe_plan``), or ``"auto"`` (stripe when the dense-ELL
    padding waste exceeds ``STRIPE_WASTE_THRESHOLD``; needs concrete
    ``cols``). ``interpret=None`` resolves from the backend.
    """
    r, k = cols.shape
    if not use_kernel:
        return spmv_ell_reference(cols, vals, x)
    g = max(1, min(grain, r))
    if variant == "auto":
        plan = stripe_plan if stripe_plan is not None else build_stripe_plan(cols, g)
        variant = "stripe" if plan.waste_ratio >= STRIPE_WASTE_THRESHOLD else "ell"
        stripe_plan = plan
    if variant == "stripe":
        return spmv_ell_stripes(
            cols, vals, x, block_rows=g, interpret=interpret, plan=stripe_plan
        )
    if variant != "ell":
        raise ValueError(f"unknown spmv variant {variant!r}: ell | stripe | auto")
    return spmv_ell_pallas(cols, vals, x, block_rows=g, interpret=interpret)
