"""Pure-jnp oracle for the blocked-ELL SpMV kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spmv_ell_reference(cols: jax.Array, vals: jax.Array, x: jax.Array) -> jax.Array:
    """y[r] = sum_k vals[r,k] * x[cols[r,k]] over valid (col >= 0) slots."""
    mask = cols >= 0
    xg = jnp.take(x, jnp.maximum(cols, 0), axis=0)
    return jnp.sum(jnp.where(mask, vals * xg, jnp.zeros_like(vals)), axis=1)
