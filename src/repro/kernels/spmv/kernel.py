"""Pallas TPU kernel: blocked-ELL SpMV with replicated x in VMEM (S1).

TPU adaptation of the paper's SpMV (DESIGN.md §2): rows are padded to ELL
tiles so each grid program streams a (block_rows, K) tile of column indices
and values through VMEM; the dense vector ``x`` is *replicated into every
program's VMEM* — the Pallas realization of the paper's winning replication
strategy (§5.1). ``block_rows`` is the paper's grain size (rows per thread ->
rows per program).

The gather ``x[cols]`` is the irregular access; on TPU it executes as a VMEM
vector gather (VPU), with padding slots (col = -1) masked to zero.

Row counts need not divide ``block_rows``: the kernel pads the planes with
masked rows (col = -1) internally and slices the result, so callers hand it
arbitrary matrices. ``interpret=None`` resolves from the backend
(:mod:`repro.kernels.runtime`): native lowering on TPU/GPU, interpret
elsewhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.util import round_up
from ..runtime import resolve_interpret


def _spmv_ell_kernel(cols_ref, vals_ref, x_ref, y_ref):
    cols = cols_ref[...]  # (block_rows, K) int32
    vals = vals_ref[...]  # (block_rows, K)
    x = x_ref[...]  # (N,) replicated in VMEM
    mask = cols >= 0
    xg = jnp.take(x, jnp.maximum(cols, 0).reshape(-1), axis=0).reshape(cols.shape)
    y_ref[...] = jnp.sum(jnp.where(mask, vals * xg, jnp.zeros_like(vals)), axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _spmv_ell_call(cols, vals, x, *, block_rows: int, interpret: bool):
    """The raw pallas_call: rows already a multiple of ``block_rows``."""
    r, k = cols.shape
    n = x.shape[0]
    grid = (r // block_rows,)
    return pl.pallas_call(
        _spmv_ell_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),  # x: whole vector, every program (S1)
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), vals.dtype),
        interpret=interpret,
    )(cols, vals, x)


def spmv_ell_pallas(
    cols: jax.Array,
    vals: jax.Array,
    x: jax.Array,
    *,
    block_rows: int = 256,
    interpret: "bool | None" = None,
) -> jax.Array:
    """y = A @ x for ELL planes. cols/vals: (R, K); x: (N,).

    Any R works: rows are padded to the next ``block_rows`` multiple with
    masked slots and the padding is sliced back off. ``interpret=None``
    picks interpret mode off-TPU/GPU, native lowering on them.
    """
    r, k = cols.shape
    block = max(1, min(block_rows, r))
    r_pad = round_up(r, block)
    if r_pad != r:
        cols = jnp.pad(cols, ((0, r_pad - r), (0, 0)), constant_values=-1)
        vals = jnp.pad(vals, ((0, r_pad - r), (0, 0)))
    y = _spmv_ell_call(
        cols, vals, x, block_rows=block, interpret=resolve_interpret(interpret)
    )
    return y[:r] if r_pad != r else y
