"""CSR-stripe SpMV: sliced-ELL over row stripes for skewed degree mixes.

The blocked-ELL kernel pads every row to the *global* max degree K — a
power-law matrix with one hub row executes its padding everywhere (the
"Impact of Traditional Sparse Optimizations on a Migratory Thread
Architecture" hierarchical-striping observation, PAPERS.md). This variant
keeps the CSR row structure at stripe granularity instead: rows are cut
into stripes of ``block_rows``, each stripe is padded only to *its own*
max width (rounded to a power of two so shapes bucket), and stripes of
equal width share one blocked-ELL ``pallas_call``. A skewed matrix then
pays Σ_stripe rows·K_stripe instead of R·K_global — the hub's width stays
confined to the hub's stripe.

The stripe decomposition depends on the *values* of ``cols`` (degrees),
so it is built eagerly from a concrete matrix (:func:`build_stripe_plan`,
one numpy pass) and carried as static structure; :func:`spmv_ell_stripes`
is then jit-compatible with the plan closed over — the engine pins a plan
to its matrix the same way it pins shapes.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ...core.util import ceil_div
from .kernel import spmv_ell_pallas


@dataclasses.dataclass(frozen=True)
class StripeBucket:
    """Stripes of equal padded width: one pallas_call per bucket."""

    k: int  # padded width every row in this bucket is sliced to
    rows: np.ndarray  # global row ids, concatenated stripe ranges (int32)


@dataclasses.dataclass(frozen=True)
class StripePlan:
    """Static stripe decomposition of one concrete matrix."""

    block_rows: int
    n_rows: int
    k_full: int
    buckets: tuple[StripeBucket, ...]

    @property
    def padded_slots(self) -> int:
        """Σ rows·K_stripe — the slots the striped kernels execute."""
        return sum(b.k * len(b.rows) for b in self.buckets)

    @property
    def waste_ratio(self) -> float:
        """Dense-ELL slots / striped slots: how much padding striping
        avoids (1.0 = none; hub-skewed matrices reach 5-50x)."""
        return (self.n_rows * self.k_full) / max(1, self.padded_slots)


def _row_widths(cols: np.ndarray) -> np.ndarray:
    """Per-row ELL width = last valid slot + 1 (0 for empty rows). Robust
    to non-left-packed planes."""
    valid = cols >= 0
    any_valid = valid.any(axis=1)
    last = cols.shape[1] - np.argmax(valid[:, ::-1], axis=1)
    return np.where(any_valid, last, 0).astype(np.int64)


def _pow2_at_least(v: int) -> int:
    return 1 << max(0, int(v - 1).bit_length())


def build_stripe_plan(cols, block_rows: int = 256) -> StripePlan:
    """One numpy pass over concrete ``cols``: stripe widths, power-of-two
    bucketing, row-id concatenation per bucket."""
    c = np.asarray(cols)
    if c.dtype == object:  # np.asarray on a tracer yields an object scalar
        raise TypeError(
            "build_stripe_plan needs a concrete cols array (the stripe "
            "decomposition is data-dependent); build the plan eagerly and "
            "pass it to spmv_ell_stripes(plan=...) under jit"
        )
    r, k = c.shape
    block = max(1, min(block_rows, r))
    widths = _row_widths(c)
    n_stripes = ceil_div(r, block)
    by_k: dict[int, list[np.ndarray]] = {}
    for s in range(n_stripes):
        lo, hi = s * block, min((s + 1) * block, r)
        w = int(widths[lo:hi].max(initial=0))
        k_s = min(k, _pow2_at_least(w)) if w > 0 else 0
        by_k.setdefault(k_s, []).append(np.arange(lo, hi, dtype=np.int32))
    buckets = tuple(
        StripeBucket(k=k_s, rows=np.concatenate(ranges))
        for k_s, ranges in sorted(by_k.items())
    )
    return StripePlan(block_rows=block, n_rows=r, k_full=k, buckets=buckets)


def spmv_ell_stripes(
    cols: jax.Array,
    vals: jax.Array,
    x: jax.Array,
    *,
    block_rows: int = 256,
    interpret: "bool | None" = None,
    plan: "StripePlan | None" = None,
) -> jax.Array:
    """y = A @ x through per-width stripe buckets (one blocked-ELL
    pallas_call each). Without ``plan``, ``cols`` must be concrete."""
    if plan is None:
        plan = build_stripe_plan(cols, block_rows)
    r, k = cols.shape
    if (r, k) != (plan.n_rows, plan.k_full):
        raise ValueError(
            f"stripe plan built for shape {(plan.n_rows, plan.k_full)}, "
            f"got {(r, k)}"
        )
    y = jnp.zeros((r,), dtype=vals.dtype)
    for bucket in plan.buckets:
        if bucket.k == 0:
            continue  # all-empty stripes: y stays 0
        rows = jnp.asarray(bucket.rows)
        y_b = spmv_ell_pallas(
            cols[rows, : bucket.k],
            vals[rows, : bucket.k],
            x,
            block_rows=plan.block_rows,
            interpret=interpret,
        )
        y = y.at[rows].set(y_b)
    return y
