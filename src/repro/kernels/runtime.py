"""Shared kernel-runtime policy: when do Pallas kernels interpret?

Every Pallas kernel in this package takes ``interpret: bool | None``. None
(the default everywhere) means "decide from the backend": compile natively
on accelerators that can lower Mosaic/Triton (TPU, GPU), interpret on
everything else (CPU CI, the common case for this repo's tests). An
explicit bool always wins — tests pin ``interpret=True`` for determinism,
TPU runs may force ``interpret=False`` to fail loudly if lowering breaks.

The resolved value is part of the engine's compiled-plan cache key
(``PallasSubstrate.cache_fingerprint``), so resolution must be stable for
the life of the process — ``default_interpret`` caches the backend probe.
"""
from __future__ import annotations

import functools

# backends whose Pallas lowering is native; everything else interprets
_COMPILED_BACKENDS = ("tpu", "gpu", "cuda", "rocm")


@functools.lru_cache(maxsize=None)
def default_interpret(backend: "str | None" = None) -> bool:
    """True when Pallas kernels should run in interpret mode here."""
    if backend is None:
        import jax

        backend = jax.default_backend()
    return backend not in _COMPILED_BACKENDS


def resolve_interpret(interpret: "bool | None") -> bool:
    """The per-call resolution every kernel wrapper funnels through."""
    if interpret is None:
        return default_interpret()
    return bool(interpret)
