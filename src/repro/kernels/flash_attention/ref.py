"""Pure-jnp oracle for flash attention (f32 math, GQA, causal, window)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_reference(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,  # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq)[:, None] + (skv - sq)
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32)).astype(q.dtype)
