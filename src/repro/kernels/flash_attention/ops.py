"""Public flash-attention op: (B, H, S, D) API, folding + padding + dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import flash_attention_folded
from .ref import attention_reference


def flash_attention(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,  # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    use_kernel: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Tiled attention; pads sequence dims to block multiples internally."""
    if not use_kernel:
        return attention_reference(q, k, v, causal=causal, window=window, scale=scale)
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    bq = min(block_q, max(8, sq))
    bk = min(block_k, max(8, skv))
    sq_p = -(-sq // bq) * bq
    skv_p = -(-skv // bk) * bk
    qf = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0))).reshape(b * hq, sq_p, d)
    kf = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0))).reshape(b * hkv, skv_p, d)
    vf = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0))).reshape(b * hkv, skv_p, d)
    o = flash_attention_folded(
        qf, kf, vf, q_len=sq, kv_len=skv, causal=causal, window=window,
        scale=scale, block_q=bq, block_k=bk, interpret=interpret,
    )
    return o.reshape(b, hq, sq_p, d)[:, :, :sq, :]
