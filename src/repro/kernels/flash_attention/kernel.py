"""Pallas TPU kernel: tiled online-softmax (flash) attention.

Grid = (batch*q_heads, q_blocks, k_blocks); the innermost k dimension
accumulates into VMEM scratch (m, l, acc) with the standard online-softmax
rescaling, writing the output tile once on the last k block. GQA is handled
in the BlockSpec index maps (q head -> shared kv head), causal and
sliding-window (Mixtral SWA) masks are applied in-kernel.

VMEM working set per program: q (bq, D) + k,v (bk, D) + acc (bq, D) + the
(bq, bk) score tile — all MXU-aligned for bq, bk, D multiples of 128 (D=64
also allowed; the MXU pads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _flash_kernel(
    q_ref, k_ref, v_ref,  # in
    o_ref,  # out
    m_scr, l_scr, acc_scr,  # scratch
    *,
    scale: float,
    causal: bool,
    window: int | None,
    q_len: int,
    kv_len: int,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]  # (bq, D)
    k = k_ref[0]  # (bk, D)
    v = v_ref[0]  # (bk, D)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = (q_pos < q_len) & (k_pos < kv_len)
    if causal:
        # align query positions to the END of the kv sequence (prefill: q_len
        # == kv_len; chunked decode: q is the tail of the kv stream)
        mask &= (q_pos + (kv_len - q_len)) >= k_pos
    if window is not None:
        mask &= (q_pos + (kv_len - q_len)) - k_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]  # (bq, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
    p = jnp.exp(s - m_safe)  # rows fully masked -> exp(-inf - 0) = 0
    alpha = jnp.where(jnp.isneginf(m_prev), 0.0, jnp.exp(m_prev - m_safe))
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l_safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "q_len", "kv_len", "causal", "window", "scale", "block_q", "block_k",
        "interpret",
    ),
)
def flash_attention_folded(
    q: jax.Array,  # (BHq, Sq, D) — batch and q-heads folded
    k: jax.Array,  # (BHkv, Skv, D)
    v: jax.Array,  # (BHkv, Skv, D)
    *,
    q_len: int | None = None,
    kv_len: int | None = None,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    bhq, sq, d = q.shape
    bhkv, skv, _ = k.shape
    assert bhq % bhkv == 0, "q heads must be a multiple of kv heads"
    group = bhq // bhkv
    q_len = q_len or sq
    kv_len = kv_len or skv
    assert sq % block_q == 0 and skv % block_k == 0
    scale = scale if scale is not None else d ** -0.5
    grid = (bhq, sq // block_q, skv // block_k)
    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window,
        q_len=q_len, kv_len=kv_len, block_q=block_q, block_k=block_k,
        num_k_blocks=skv // block_k,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bhq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
