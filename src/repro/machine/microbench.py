"""STREAM-like microbenchmark suite -> machine file (DESIGN.md §1f).

The "Microbenchmark Characterization of the Emu Chick" methodology
(arXiv:1809.07696) applied to whatever this process runs on: measure what
the machine *sustains* — not what the datasheet promises — and write it
down so the cost models can speak seconds.

    python -m repro.machine.microbench --quick          # CI calibration
    python -m repro.machine.microbench --out path.json  # pinned location

Per registered substrate: sustained memory bandwidth in three access
classes (a jitted triad, a random-index gather, a random-index scatter —
the latter two are the paper's irregular-access measurement and differ
from the triad by 20-50x on XLA-CPU), per-call dispatch overhead (the
jit-call floor every prediction owes), and — when the host exposes >1 device — per-collective
alpha-beta models over the nodelet mesh axis (all_gather / all_to_all /
psum at several message sizes, least-squares fit to ``t = α + β·bytes``).
Plus one matmul peak-FLOPs probe and the host parallel-capacity probe the
serve suite pioneered. Single-device hosts get mesh collective terms
*derived* from local numbers (marked ``source="derived"``) instead of
silently keeping defaults.
"""
from __future__ import annotations

import argparse
import time
from typing import Callable, Iterable

import numpy as np

from .machine import (
    AlphaBeta,
    MachineProfile,
    Peaks,
    SubstrateProfile,
    default_machine_path,
    machine_fingerprint,
)

# message/buffer sizes (bytes) per mode; quick keeps CI calibration seconds
STREAM_SIZES = {"quick": (1 << 20, 4 << 20), "full": (4 << 20, 16 << 20, 64 << 20)}
COLLECTIVE_SIZES = {
    "quick": (16 << 10, 256 << 10, 1 << 20),
    "full": (16 << 10, 256 << 10, 4 << 20, 16 << 20),
}


def _median_seconds(fn: Callable[[], object], iters: int, warmup: int = 1) -> float:
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def fit_alpha_beta(
    nbytes: Iterable[float], seconds: Iterable[float]
) -> AlphaBeta:
    """Least-squares fit of ``t = alpha + beta * n`` with both terms clamped
    nonnegative (noisy small-message timings can produce a negative
    intercept; a negative latency or bandwidth is never meaningful)."""
    n = np.asarray(list(nbytes), dtype=np.float64)
    t = np.asarray(list(seconds), dtype=np.float64)
    if n.size == 0:
        raise ValueError("fit_alpha_beta needs at least one sample")
    if n.size == 1:
        return AlphaBeta(alpha=0.0, beta=float(t[0] / max(n[0], 1.0)))
    coeffs, *_ = np.linalg.lstsq(np.stack([np.ones_like(n), n], axis=1), t, rcond=None)
    alpha, beta = float(coeffs[0]), float(coeffs[1])
    if beta < 0:  # degenerate (timings not increasing): bandwidth-only refit
        beta = float(t.sum() / max(n.sum(), 1.0))
        alpha = 0.0
    return AlphaBeta(alpha=max(0.0, alpha), beta=max(0.0, beta))


def measure_stream_bw(sizes: "tuple[int, ...]", iters: int = 3) -> float:
    """Sustained bytes/s of a jitted scale-add triad (reads one array,
    writes one: 2 touched bytes per element-byte), max over buffer sizes —
    the STREAM number the memory term of every prediction divides by."""
    import jax
    import jax.numpy as jnp

    kernel = jax.jit(lambda x: x * 1.000001 + 0.5)
    best = 0.0
    for size in sizes:
        x = jnp.arange(size // 4, dtype=jnp.float32)
        sec = _median_seconds(lambda x=x: kernel(x), iters=iters)
        best = max(best, 2.0 * size / max(sec, 1e-9))
    return best


def _random_access_bw(kernel, sizes: "tuple[int, ...]", iters: int) -> float:
    """Shared harness for the random-access probes: run ``kernel(x, idx)``
    over random int32 indices at each size, charge 12 bytes per element
    (4B index read + 4B random data touch + 4B result), keep the best."""
    import jax.numpy as jnp

    rng = np.random.default_rng(7)
    best = 0.0
    for size in sizes:
        n = max(1, size // 12)
        x = jnp.arange(n, dtype=jnp.float32)
        idx = jnp.asarray(rng.integers(0, n, size=n).astype(np.int32))
        sec = _median_seconds(lambda x=x, idx=idx: kernel(x, idx), iters=iters)
        best = max(best, 12.0 * n / max(sec, 1e-9))
    return best


def measure_gather_bw(sizes: "tuple[int, ...]", iters: int = 3) -> float:
    """Sustained bytes/s of a jitted random-index *gather* (``x[idx]``) —
    the irregular-read analogue of the triad. SpMV-style kernels (random
    reads, sequential writes) see this rate."""
    import jax

    return _random_access_bw(jax.jit(lambda x, idx: x[idx] + 1.0), sizes, iters)


def measure_scatter_bw(sizes: "tuple[int, ...]", iters: int = 3) -> float:
    """Sustained bytes/s of a jitted random-index *scatter*
    (``x.at[idx].add``) — what frontier expansion and remote-write
    lowering actually execute. On XLA-CPU this is serialized and lands
    20-50x below the triad; charging scatter-bound sweeps at STREAM is
    precisely the unit-level model bug the band gate exists to catch."""
    import jax

    return _random_access_bw(
        jax.jit(lambda x, idx: x.at[idx].add(1.0)), sizes, iters
    )


def measure_dispatch_overhead(iters: int = 30) -> float:
    """Seconds per warm jitted call on a tiny operand — the per-call floor
    (trace-cache lookup + dispatch + sync) that dominates small problems."""
    import jax
    import jax.numpy as jnp

    kernel = jax.jit(lambda x: x + 1.0)
    x = jnp.zeros((8,), jnp.float32)
    return _median_seconds(lambda: kernel(x), iters=iters, warmup=3)


def measure_matmul_flops(n: int = 512, iters: int = 3) -> float:
    """Sustained FLOP/s of one jitted f32 matmul — the calibrated stand-in
    for the roofline's peak-FLOPs constant."""
    import jax
    import jax.numpy as jnp

    a = jnp.ones((n, n), jnp.float32)
    kernel = jax.jit(lambda a: a @ a)
    sec = _median_seconds(lambda: kernel(a), iters=iters)
    return 2.0 * n**3 / max(sec, 1e-9)


def measure_collectives(
    sizes: "tuple[int, ...]",
    kinds: "tuple[str, ...]" = ("all_gather", "all_to_all", "psum"),
    axis_name: str = "nodelet",
    iters: int = 3,
) -> dict[str, AlphaBeta]:
    """Alpha-beta models per collective over a 1-D mesh of every host
    device. Empty dict on single-device hosts (nothing to wire-measure)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from ..compat import shard_map
    from ..launch.mesh import make_nodelet_mesh

    n_dev = len(jax.devices())
    if n_dev < 2:
        return {}
    mesh = make_nodelet_mesh(n_dev)

    def body(kind):
        def f(x):
            if kind == "all_gather":
                return jax.lax.all_gather(x, axis_name, tiled=True)
            if kind == "all_to_all":
                return jax.lax.all_to_all(
                    x.reshape(n_dev, -1), axis_name, 0, 0, tiled=False
                )
            return jax.lax.psum(x, axis_name)

        return f

    out: dict[str, AlphaBeta] = {}
    for kind in kinds:
        f = jax.jit(
            shard_map(
                body(kind), mesh, in_specs=P(axis_name), out_specs=(
                    P() if kind == "psum" else P(axis_name)
                ),
            )
        )
        samples = []
        for size in sizes:
            elems = max(n_dev * n_dev, size // 4 // n_dev * n_dev)
            x = jnp.arange(elems, dtype=jnp.float32)
            sec = _median_seconds(lambda x=x: f(x), iters=iters)
            samples.append((elems * 4, sec))
        out[kind] = fit_alpha_beta(*zip(*samples))
    return out


def measure_host_parallel_capacity(quick: bool = True) -> float:
    """How much the host scales two concurrent GIL-releasing workers vs one
    (2.0 = perfect). The executor pool's speedup ceiling; recorded so a
    sub-linear pool reading on a throttled host stays interpretable."""
    import threading

    n = 192 if quick else 384
    reps = 6 if quick else 12
    a = np.random.default_rng(0).standard_normal((n, n))

    def work():
        for _ in range(reps):
            a @ a  # numpy dot releases the GIL

    def timed(k: int) -> float:
        threads = [threading.Thread(target=work) for _ in range(k)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        return time.perf_counter() - t0

    timed(1)  # warm the BLAS pool
    one, two = timed(1), timed(2)
    return max(1.0, 2.0 * one / max(two, 1e-9))


def calibrate(*, quick: bool = True, mesh_dispatch_iters: int = 5) -> MachineProfile:
    """Run the full suite and assemble a calibrated, fingerprinted
    :class:`MachineProfile` for this process's topology. Does not save —
    callers decide the path (:meth:`MachineProfile.save`)."""
    import jax

    mode = "quick" if quick else "full"
    stream = measure_stream_bw(STREAM_SIZES[mode])
    gather = measure_gather_bw(STREAM_SIZES[mode])
    scatter = measure_scatter_bw(STREAM_SIZES[mode])
    dispatch = measure_dispatch_overhead()
    flops = measure_matmul_flops(n=384 if quick else 1024)
    collectives = measure_collectives(COLLECTIVE_SIZES[mode])
    capacity = measure_host_parallel_capacity(quick=quick)

    local = SubstrateProfile(
        stream_bw=stream, dispatch_overhead=dispatch, collectives={},
        source="measured", gather_bw=gather, scatter_bw=scatter,
    )
    if collectives:
        # mesh dispatch overhead: one warm shard_map'd no-op collective call
        # at the smallest size is already folded into the alpha terms; take
        # the all_gather alpha as the per-call floor
        mesh_dispatch = max(dispatch, collectives["all_gather"].alpha)
        mesh = SubstrateProfile(
            stream_bw=stream, dispatch_overhead=mesh_dispatch,
            collectives=collectives, source="measured",
            gather_bw=gather, scatter_bw=scatter,
        )
        ici = max(1.0 / max(ab.beta, 1e-18) for ab in collectives.values())
    else:
        # single-device host: the mesh substrate would refuse multi-nodelet
        # plans anyway; derive wire terms from the memory system so
        # predictions stay finite and honest about their provenance
        mesh = SubstrateProfile(
            stream_bw=stream, dispatch_overhead=dispatch,
            collectives={
                k: AlphaBeta(alpha=dispatch, beta=2.0 / stream)
                for k in ("all_gather", "all_to_all", "psum")
            },
            source="derived", gather_bw=gather, scatter_bw=scatter,
        )
        ici = stream / 2.0
    profile = MachineProfile(
        fingerprint=machine_fingerprint(),
        peaks=Peaks(flops=flops, hbm_bw=stream, ici_bw=ici),
        substrates={"local": local, "mesh": mesh, "pallas": local},
        host_parallel_capacity=capacity,
        calibrated=True,
        quick=quick,
        created=time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    )
    del jax, mesh_dispatch_iters
    return profile


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true", help="CI-fast sizes")
    ap.add_argument("--full", action="store_true", help="large-buffer sizes")
    ap.add_argument("--out", default=None, help="machine file path "
                    "(default: experiments/machine.json)")
    args = ap.parse_args(argv)
    profile = calibrate(quick=not args.full)
    path = profile.save(args.out if args.out else default_machine_path())
    local = profile.substrate("local")
    mesh = profile.substrate("mesh")
    print(f"# machine file -> {path}")
    print(f"# fingerprint: {profile.fingerprint}")
    print(
        f"# local: stream {local.stream_bw / 1e9:.2f} GB/s, "
        f"gather {local.access_bw('gather') / 1e9:.2f} GB/s, "
        f"scatter {local.access_bw('scatter') / 1e9:.3f} GB/s, "
        f"dispatch {local.dispatch_overhead * 1e6:.1f} us; "
        f"peak {profile.peaks.flops / 1e9:.1f} GFLOP/s; "
        f"host capacity {profile.host_parallel_capacity:.2f}x"
    )
    for kind, ab in sorted(mesh.collectives.items()):
        print(
            f"# mesh {kind} ({mesh.source}): alpha {ab.alpha * 1e6:.1f} us, "
            f"beta {1.0 / max(ab.beta, 1e-18) / 1e9:.2f} GB/s"
        )


if __name__ == "__main__":
    main()
