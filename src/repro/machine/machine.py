"""The machine file: a versioned, fingerprinted record of what this host
actually sustains (DESIGN.md §1f).

The paper's Emu analysis only became credible once the Chick was
characterized with microbenchmarks (arXiv:1809.07696: STREAM-like
bandwidth, migration latency); this module is that characterization for
whatever hardware the engine runs on. ``microbench.calibrate()`` writes a
:class:`MachineProfile` to ``experiments/machine.json``; the perf model
(:mod:`~repro.machine.perfmodel`) combines it with the per-op traffic
models to predict wall seconds, and the autotuner ranks in those seconds
when a *calibrated* profile is present.

Three guarantees:

- **works uncalibrated** — :data:`DEFAULT_PROFILE` bundles conservative
  numbers (the roofline's former hardcoded TPU-v5e peaks plus CPU-ish
  substrate terms), so every consumer has a profile; only *ranking* and
  RunReport honesty columns require a calibrated file;
- **staleness is detected** — the file carries a topology fingerprint
  (:func:`machine_fingerprint`: backend, device count/kinds, host cores);
  :func:`load_machine` refuses (with a warning) a profile recorded on a
  different topology, e.g. an 8-forced-device subprocess reading a
  1-device calibration;
- **one dtype-width table** — :data:`DTYPE_BYTES` is the shared
  definition the roofline HLO parser and the microbenchmarks both read
  (previously duplicated in ``launch/roofline.py``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import warnings
from pathlib import Path
from typing import Any

SCHEMA_VERSION = 1

DEFAULT_MACHINE_PATH = (
    Path(__file__).resolve().parents[3] / "experiments" / "machine.json"
)

# dtype -> bytes per element. Shared by the roofline HLO parser (XLA type
# names) and the microbenchmark suite; keep XLA's short spellings.
DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}


def machine_fingerprint() -> dict[str, Any]:
    """Topology identity a calibration is valid for: jax backend, device
    count and kinds, host core count. Forcing host devices (the mesh CI
    jobs' ``--xla_force_host_platform_device_count=8``) changes it, so a
    subprocess with a different device topology never silently reuses the
    parent's calibration."""
    import jax

    devices = jax.devices()
    return {
        "backend": jax.default_backend(),
        "device_count": len(devices),
        "device_kinds": sorted({d.device_kind for d in devices}),
        "cpu_count": os.cpu_count() or 1,
    }


def fingerprint_key(fp: "dict[str, Any] | None") -> "str | None":
    """Stable string encoding of a fingerprint (what ProbeStore entries
    carry); None stays None (unknown provenance == always stale)."""
    if fp is None:
        return None
    return json.dumps(fp, sort_keys=True, default=str)


@dataclasses.dataclass(frozen=True)
class AlphaBeta:
    """The classic collective cost model: ``seconds(n) = alpha + beta*n``
    — per-launch latency plus per-byte inverse bandwidth."""

    alpha: float  # seconds per launch
    beta: float  # seconds per byte

    def seconds(self, nbytes: float, launches: float = 1.0) -> float:
        return launches * self.alpha + self.beta * float(nbytes)

    def to_dict(self) -> dict[str, float]:
        return {"alpha": self.alpha, "beta": self.beta}

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "AlphaBeta":
        return cls(alpha=float(d["alpha"]), beta=float(d["beta"]))


@dataclasses.dataclass(frozen=True)
class Peaks:
    """Roofline peaks (launch/roofline.py reads these instead of its old
    module constants)."""

    flops: float  # FLOP/s per chip
    hbm_bw: float  # bytes/s per chip
    ici_bw: float  # bytes/s per link

    def to_dict(self) -> dict[str, float]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "Peaks":
        return cls(
            flops=float(d["flops"]), hbm_bw=float(d["hbm_bw"]),
            ici_bw=float(d["ici_bw"]),
        )


@dataclasses.dataclass(frozen=True)
class SubstrateProfile:
    """What one substrate sustains: STREAM bandwidth, per-call dispatch
    overhead, and an alpha-beta model per collective class.

    ``collectives`` keys are the engine's traffic classes — ``all_gather``
    (S2 migrate / pull), ``all_to_all`` (S2 remote-write / push), ``psum``
    (reductions). ``source`` records how the numbers were obtained
    (``measured`` | ``derived`` | ``default``).

    ``gather_bw`` / ``scatter_bw`` are the random-access bandwidths — the
    paper's central measurement: irregular access sustains a fraction of
    STREAM, and the two directions differ wildly (XLA-CPU scatter is
    serialized, ~20-50x below gather). Cost models declare which class
    their memory sweep belongs to; :meth:`access_bw` maps the class to a
    rate, falling back to conservative STREAM fractions for old files and
    the bundled default."""

    stream_bw: float  # sustained bytes/s, sequential (STREAM triad)
    dispatch_overhead: float  # seconds per jitted call
    collectives: dict[str, AlphaBeta]
    source: str = "default"
    gather_bw: "float | None" = None  # bytes/s, random reads (x[idx])
    scatter_bw: "float | None" = None  # bytes/s, random writes (x.at[idx])

    def access_bw(self, access: str = "gather") -> float:
        """Bytes/s for one memory-access class: ``stream`` (sequential
        sweeps — dense histograms, ELL row walks), ``gather`` (random
        reads), ``scatter`` (random read-modify-writes — frontier
        expansion, remote-write lowering). Unmeasured classes fall back to
        STREAM/4 (gather) and STREAM/16 (scatter)."""
        if access == "stream":
            return self.stream_bw
        if access == "scatter":
            if self.scatter_bw is not None and self.scatter_bw > 0:
                return self.scatter_bw
            return self.stream_bw / 16.0
        if self.gather_bw is not None and self.gather_bw > 0:
            return self.gather_bw
        return self.stream_bw / 4.0

    def collective(self, kind: str) -> AlphaBeta:
        """The alpha-beta model for one collective class, falling back to a
        stream-derived model (one dispatch of latency, stream-rate bytes)."""
        ab = self.collectives.get(kind)
        if ab is not None:
            return ab
        return AlphaBeta(alpha=self.dispatch_overhead, beta=1.0 / self.stream_bw)

    def to_dict(self) -> dict[str, Any]:
        return {
            "stream_bw": self.stream_bw,
            "dispatch_overhead": self.dispatch_overhead,
            "collectives": {k: v.to_dict() for k, v in self.collectives.items()},
            "source": self.source,
            "gather_bw": self.gather_bw,
            "scatter_bw": self.scatter_bw,
        }

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "SubstrateProfile":
        gather = d.get("gather_bw")
        scatter = d.get("scatter_bw")
        return cls(
            stream_bw=float(d["stream_bw"]),
            dispatch_overhead=float(d["dispatch_overhead"]),
            collectives={
                str(k): AlphaBeta.from_dict(v)
                for k, v in dict(d.get("collectives", {})).items()
            },
            source=str(d.get("source", "default")),
            gather_bw=float(gather) if gather is not None else None,
            scatter_bw=float(scatter) if scatter is not None else None,
        )


@dataclasses.dataclass(frozen=True)
class MachineProfile:
    """One machine file: fingerprinted topology + per-substrate sustained
    rates + roofline peaks + host parallel capacity.

    ``calibrated=False`` marks the bundled default (and any profile whose
    numbers were not measured on this topology); the autotuner only ranks
    in predicted seconds when ``calibrated`` is true."""

    fingerprint: "dict[str, Any] | None"
    peaks: Peaks
    substrates: dict[str, SubstrateProfile]
    host_parallel_capacity: float = 1.0
    calibrated: bool = False
    quick: bool = False
    created: str = ""
    version: int = SCHEMA_VERSION

    def substrate(self, name: str) -> SubstrateProfile:
        """Profile for a substrate name, falling back to ``local`` and then
        to any profile present — prediction never fails on an unknown
        backend, it just degrades to host-side numbers."""
        prof = self.substrates.get(name)
        if prof is not None:
            return prof
        prof = self.substrates.get("local")
        if prof is not None:
            return prof
        return next(iter(self.substrates.values()))

    def stale(self, fp: "dict[str, Any] | None" = None) -> bool:
        """True when this profile was calibrated on a different topology
        than ``fp`` (default: the current one). The bundled default
        (``fingerprint=None``) is never stale — it claims no topology."""
        if self.fingerprint is None:
            return False
        current = fp if fp is not None else machine_fingerprint()
        return fingerprint_key(self.fingerprint) != fingerprint_key(current)

    def to_dict(self) -> dict[str, Any]:
        return {
            "version": self.version,
            "created": self.created,
            "fingerprint": self.fingerprint,
            "calibrated": self.calibrated,
            "quick": self.quick,
            "host_parallel_capacity": self.host_parallel_capacity,
            "peaks": self.peaks.to_dict(),
            "substrates": {k: v.to_dict() for k, v in self.substrates.items()},
        }

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "MachineProfile":
        return cls(
            version=int(d.get("version", SCHEMA_VERSION)),
            created=str(d.get("created", "")),
            fingerprint=d.get("fingerprint"),
            calibrated=bool(d.get("calibrated", False)),
            quick=bool(d.get("quick", False)),
            host_parallel_capacity=float(d.get("host_parallel_capacity", 1.0)),
            peaks=Peaks.from_dict(d["peaks"]),
            substrates={
                str(k): SubstrateProfile.from_dict(v)
                for k, v in dict(d.get("substrates", {})).items()
            },
        )

    def save(self, path: "str | os.PathLike | None" = None) -> Path:
        """Atomic spill (tmp + rename), mirroring the ProbeStore policy."""
        out = Path(path) if path is not None else default_machine_path()
        out.parent.mkdir(parents=True, exist_ok=True)
        tmp = out.with_suffix(".json.tmp")
        tmp.write_text(json.dumps(self.to_dict(), indent=2, sort_keys=True))
        tmp.replace(out)
        return out


def _default_profile() -> MachineProfile:
    """The bundled conservative default: the roofline's former hardcoded
    TPU-v5e peaks (197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link) plus
    deliberately pessimistic CPU-host substrate terms. Everything works
    against it; nothing *ranks* by it."""
    local = SubstrateProfile(
        stream_bw=8e9,  # ~1 DDR channel — conservative for any host
        dispatch_overhead=50e-6,
        collectives={},  # derived from stream on demand
        source="default",
    )
    mesh = SubstrateProfile(
        stream_bw=8e9,
        dispatch_overhead=200e-6,  # shard_map dispatch is heavier
        collectives={
            "all_gather": AlphaBeta(alpha=100e-6, beta=1.0 / 4e9),
            "all_to_all": AlphaBeta(alpha=100e-6, beta=1.0 / 4e9),
            "psum": AlphaBeta(alpha=100e-6, beta=1.0 / 4e9),
        },
        source="default",
    )
    return MachineProfile(
        fingerprint=None,
        peaks=Peaks(flops=197e12, hbm_bw=819e9, ici_bw=50e9),
        substrates={"local": local, "mesh": mesh, "pallas": local},
        host_parallel_capacity=1.0,
        calibrated=False,
    )


DEFAULT_PROFILE = _default_profile()


def default_machine_path() -> Path:
    """``experiments/machine.json``; ``REPRO_MACHINE_PATH`` overrides."""
    return Path(os.environ.get("REPRO_MACHINE_PATH", str(DEFAULT_MACHINE_PATH)))


def load_machine(
    path: "str | os.PathLike | None" = None, *, allow_stale: bool = False
) -> "MachineProfile | None":
    """Load a machine file, or None when it is absent, unreadable, corrupt,
    from a newer schema, or (unless ``allow_stale``) calibrated on a
    different topology. Every non-absent rejection warns — a stale
    calibration silently ranking strategies is exactly the bug this
    detection exists for."""
    p = Path(path) if path is not None else default_machine_path()
    try:
        blob = p.read_bytes()
    except FileNotFoundError:
        return None
    except OSError as exc:
        warnings.warn(
            f"unreadable machine file at {p} ({exc!r}); using the bundled default",
            RuntimeWarning, stacklevel=2,
        )
        return None
    try:
        profile = MachineProfile.from_dict(json.loads(blob))
    except (ValueError, KeyError, TypeError, AttributeError) as exc:
        warnings.warn(
            f"corrupt machine file at {p} ({exc!r}); using the bundled default",
            RuntimeWarning, stacklevel=2,
        )
        return None
    if profile.version > SCHEMA_VERSION:
        warnings.warn(
            f"machine file at {p} has schema v{profile.version} > "
            f"supported v{SCHEMA_VERSION}; using the bundled default",
            RuntimeWarning, stacklevel=2,
        )
        return None
    if not allow_stale and profile.stale():
        warnings.warn(
            f"machine file at {p} was calibrated on a different topology "
            f"({profile.fingerprint} != {machine_fingerprint()}); "
            "re-run `python -m repro.machine.microbench` — using the bundled default",
            RuntimeWarning, stacklevel=2,
        )
        return None
    return profile


# -- cached default lookup -----------------------------------------------------
# engine.run consults the machine file on every call; cache the load keyed
# by (path, mtime) so the steady-state cost is one os.stat.

_cache_lock = threading.Lock()
_cached: "tuple[str, float | None, MachineProfile] | None" = None


def default_machine() -> MachineProfile:
    """The process-wide machine profile: the file at
    :func:`default_machine_path` when present and fresh, else
    :data:`DEFAULT_PROFILE` (``calibrated=False``). Reloads automatically
    when the file's mtime changes (``--calibrate`` mid-process works)."""
    global _cached
    path = default_machine_path()
    try:
        mtime: "float | None" = path.stat().st_mtime
    except OSError:
        mtime = None
    key = str(path)
    with _cache_lock:
        if _cached is not None and _cached[0] == key and _cached[1] == mtime:
            return _cached[2]
    profile = (load_machine(path) if mtime is not None else None) or DEFAULT_PROFILE
    with _cache_lock:
        _cached = (key, mtime, profile)
    return profile


def reset_default_machine_cache() -> None:
    """Drop the cached default profile (tests repoint ``REPRO_MACHINE_PATH``)."""
    global _cached
    with _cache_lock:
        _cached = None
