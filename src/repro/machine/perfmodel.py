"""PerformanceModel: machine file x traffic model -> predicted wall seconds.

The calibration plane's top layer (DESIGN.md §1f). The per-op cost models
(:mod:`repro.core.cost`) already know *how many bytes move in which class*
(migrations, remote-write packets, collective payloads); the machine file
(:mod:`repro.machine.machine`) knows *what a byte costs here*. This module
multiplies them:

    t(strategy) = dispatch_overhead                    # per-call floor
                + sweep_bytes / access_bw              # memory term
                + flops / peak_flops                   # compute term
                + launches * alpha(comm)               # collective latency
                + Sigma_class beta(class) * bytes(class)  # wire terms

    The memory term charges the cost model's declared per-launch working
    set (``detail["memory_bytes_per_launch"]``, padding included — skewed
    matrices execute their padding) at the substrate's rate for the
    declared access class (``detail["memory_access"]``: stream / gather /
    scatter). The class matters more than the byte count: sustained
    scatter is 20-50x below the triad on XLA-CPU, which is the source
    paper's central measurement transplanted to this backend.

where the migration bytes of a strategy are charged at the ``all_gather``
rate (S2 migrate lowers to a pull), remote-write bytes at the
``all_to_all`` rate (push), and explicit collective payloads at the
strategy's own comm-axis rate. ``launches`` comes from the cost model's
``detail["collective_launches"]`` — BFS pays one dispatch per frontier
round, which is exactly what makes migrate-vs-write latency-bound on
high-diameter graphs.

Predictions are *attached*, never substituted: ranking by them is the
autotuner's decision and only happens against a ``calibrated`` profile, so
an uncalibrated process stays bit-identical to traffic-unit ranking.
"""
from __future__ import annotations

import collections
import dataclasses
import weakref
from typing import Any, Callable, Iterable, Sequence

from ..core.cost import CostEstimate, cost_model_for
from ..core.strategies import Comm, MigratoryStrategy, TrafficStats
from .machine import MachineProfile, default_machine

# S2 axis -> collective class: migrate lowers to a pull (all_gather),
# remote write to a push (all_to_all). Mirrors the substrate kernels.
COMM_CLASS = {Comm.MIGRATE: "all_gather", Comm.REMOTE_WRITE: "all_to_all"}


class PerformanceModel:
    """Predicts wall seconds per (op, strategy, substrate) from a machine
    profile. Construct with an explicit profile or let it pick up the
    process-wide :func:`~repro.machine.machine.default_machine`."""

    def __init__(self, profile: "MachineProfile | None" = None):
        self.profile = profile if profile is not None else default_machine()

    @property
    def calibrated(self) -> bool:
        return self.profile.calibrated

    def predict_parts(
        self,
        estimate: CostEstimate,
        substrate: str = "local",
        *,
        bytes_moved: float = 0.0,
        flops: float = 0.0,
    ) -> dict[str, float]:
        """The prediction's additive terms, for report honesty and tests."""
        sub = self.profile.substrate(substrate)
        traffic = estimate.traffic
        comm = COMM_CLASS.get(estimate.strategy.comm, "all_gather")
        ab_comm = sub.collective(comm)
        launches = float(estimate.detail.get("collective_launches", 1))
        # memory term: the cost model's own per-launch sweep accounting
        # (e.g. BFS scatter-mins over the padded adjacency every round)
        # supersedes the generic useful-bytes count when present — it knows
        # the execution shape *and* the access class (stream / gather /
        # scatter, whose sustained rates differ by 20-50x on XLA-CPU);
        # ``bytes_moved`` charges one gather-rate pass otherwise. A
        # substrate-targeted declaration (``detail["substrate_memory"]``,
        # keyed by kind) beats both: the Pallas kernels' sweeps depend on
        # the grain axis (x replicated per program, per-block partials), so
        # this is the term that makes predictions rank block sizes.
        per_launch = estimate.detail.get("memory_bytes_per_launch")
        access = estimate.detail.get("memory_access", "gather")
        targeted = (estimate.detail.get("substrate_memory") or {}).get(substrate)
        if targeted is not None:
            per_launch = targeted.get("bytes_per_launch", per_launch)
            access = targeted.get("access", access)
        mem_bytes = (
            max(1.0, launches) * float(per_launch)
            if per_launch is not None
            else float(bytes_moved)
        )
        if traffic is None:
            # cost model predates the split: charge everything at the
            # comm-axis wire rate so prediction still works
            wire = ab_comm.beta * float(estimate.traffic_bytes)
        else:
            wire = (
                sub.collective("all_gather").beta * traffic.migration_bytes
                + sub.collective("all_to_all").beta * traffic.remote_write_bytes
                + ab_comm.beta * traffic.collective_bytes
            )
        return {
            "dispatch": sub.dispatch_overhead,
            "memory": mem_bytes / sub.access_bw(access),
            "compute": float(flops) / self.profile.peaks.flops,
            "collective_latency": launches * ab_comm.alpha,
            "wire": wire,
        }

    def predict_estimate(
        self,
        estimate: CostEstimate,
        substrate: str = "local",
        *,
        bytes_moved: float = 0.0,
        flops: float = 0.0,
    ) -> float:
        """Predicted wall seconds for one candidate."""
        return sum(
            self.predict_parts(
                estimate, substrate, bytes_moved=bytes_moved, flops=flops
            ).values()
        )

    def attach(
        self,
        estimates: Sequence[CostEstimate],
        substrate: str = "local",
        *,
        bytes_moved: float = 0.0,
    ) -> list[CostEstimate]:
        """Return copies of ``estimates`` with ``predicted_seconds`` filled.
        The shared ``bytes_moved`` term is a constant across candidates of
        one op, so it shifts predictions without reordering them."""
        return [
            dataclasses.replace(
                e,
                predicted_seconds=self.predict_estimate(
                    e, substrate, bytes_moved=bytes_moved
                ),
            )
            for e in estimates
        ]

    def predict_plan_seconds(self, op: Any, plan: Any) -> "float | None":
        """Predicted seconds for a concrete :class:`ExecutionPlan`, or None
        when the op has no cost model. Uses the op's own ``bytes_moved``
        accounting (already memoized per plan)."""
        try:
            estimator = _estimator_for(op.name, plan.inputs)
            estimate = estimator(plan.strategy)
            moved = float(op.bytes_moved(plan))
        except (ValueError, NotImplementedError):
            return None
        return self.predict_estimate(estimate, plan.substrate, bytes_moved=moved)


def maybe_predict_plan_seconds(op: Any, plan: Any) -> "float | None":
    """The runner's hook: a prediction for this plan when (and only when) a
    calibrated machine file is present, else None. The uncalibrated fast
    path is one cached profile lookup and a bool — RunReports stay
    bit-identical without a machine file."""
    profile = default_machine()
    if not profile.calibrated:
        return None
    return PerformanceModel(profile).predict_plan_seconds(op, plan)


# -- per-inputs estimator memo -------------------------------------------------
# cost_model_for does one full pass over the inputs (nnz ownership, BFS edge
# replay); autotune already amortizes that across its grid, but the runner
# predicts once per run_plan call, so memoize the estimator per concrete
# inputs object (weakref-validated identity, same policy as the ops-layer
# _derived_cached memo).

_ESTIMATOR_MEMO: "collections.OrderedDict[tuple, tuple]" = collections.OrderedDict()
_ESTIMATOR_MEMO_MAX = 64


def _estimator_for(
    op_name: str, inputs: Any
) -> Callable[[MigratoryStrategy], CostEstimate]:
    key = (op_name, id(inputs))
    hit = _ESTIMATOR_MEMO.get(key)
    if hit is not None and hit[0]() is inputs:
        _ESTIMATOR_MEMO.move_to_end(key)
        return hit[1]
    estimator = cost_model_for(op_name, inputs)
    try:
        ref: Callable[[], Any] = weakref.ref(inputs)
    except TypeError:  # inputs type without weakref support
        ref = lambda obj=inputs: obj  # noqa: E731 - tiny closure, same shape as weakref
    _ESTIMATOR_MEMO[key] = (ref, estimator)
    while len(_ESTIMATOR_MEMO) > _ESTIMATOR_MEMO_MAX:
        _ESTIMATOR_MEMO.popitem(last=False)
    return estimator
