"""Machine-model calibration plane (DESIGN.md §1f).

``microbench`` measures what this host sustains, ``machine`` persists it as
a versioned fingerprinted machine file, ``perfmodel`` turns per-op traffic
counts into predicted wall seconds. The autotuner ranks in predicted
seconds only against a *calibrated* profile; everything else works (and
stays bit-identical) against the bundled default.
"""
from .machine import (
    DEFAULT_PROFILE,
    DTYPE_BYTES,
    SCHEMA_VERSION,
    AlphaBeta,
    MachineProfile,
    Peaks,
    SubstrateProfile,
    default_machine,
    default_machine_path,
    fingerprint_key,
    load_machine,
    machine_fingerprint,
    reset_default_machine_cache,
)
from .perfmodel import COMM_CLASS, PerformanceModel, maybe_predict_plan_seconds


def __getattr__(name):
    # lazy: ``python -m repro.machine.microbench`` must not find the module
    # pre-imported by this package (runpy would warn), and importing the
    # engine should not pull the benchmark suite in eagerly
    if name in ("calibrate", "fit_alpha_beta"):
        from . import microbench

        return getattr(microbench, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "DEFAULT_PROFILE",
    "DTYPE_BYTES",
    "SCHEMA_VERSION",
    "AlphaBeta",
    "MachineProfile",
    "Peaks",
    "SubstrateProfile",
    "default_machine",
    "default_machine_path",
    "fingerprint_key",
    "load_machine",
    "machine_fingerprint",
    "reset_default_machine_cache",
    "calibrate",
    "fit_alpha_beta",
    "COMM_CLASS",
    "PerformanceModel",
    "maybe_predict_plan_seconds",
]
