"""Padded ELL / blocked-ELL formats.

The TPU re-think of the Emu's fine-grained jagged rows (DESIGN.md §2): the
Chick's NCDRAM is efficient at <64 B accesses, the TPU is not — so rows are
padded/blocked into MXU/VPU-aligned tiles. ``ELL`` is the dense-padded format
consumed by the Pallas SpMV kernel; padding slots carry ``col = -1`` and
``val = 0`` so they are arithmetic no-ops.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .csr import CSR


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ELL:
    """ELLPACK: (n_rows, k) column-index / value planes, row-major padded."""

    cols: jax.Array  # (n_rows, k) int32, -1 = padding
    vals: jax.Array  # (n_rows, k)
    shape: tuple[int, int]  # static logical shape

    def tree_flatten(self):
        return (self.cols, self.vals), self.shape

    @classmethod
    def tree_unflatten(cls, shape, leaves):
        return cls(*leaves, shape=shape)

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def k(self) -> int:
        return self.cols.shape[1]

    @property
    def nnz_padded(self) -> int:
        return self.cols.shape[0] * self.cols.shape[1]


def ell_from_csr(a: CSR, k: int | None = None, row_pad_to: int = 1) -> ELL:
    """Convert CSR -> padded ELL. ``k`` defaults to max row degree.

    ``row_pad_to`` pads the row count (for tile-aligned kernels).
    """
    indptr = np.asarray(a.indptr)
    indices = np.asarray(a.indices)
    data = np.asarray(a.data)
    n = a.n_rows
    lens = indptr[1:] - indptr[:-1]
    kmax = int(lens.max()) if n else 0
    if k is None:
        k = max(kmax, 1)
    if kmax > k:
        raise ValueError(f"k={k} < max row degree {kmax}; split rows first")
    n_pad = -(-n // row_pad_to) * row_pad_to
    cols = np.full((n_pad, k), -1, dtype=np.int32)
    vals = np.zeros((n_pad, k), dtype=data.dtype)
    for r in range(n):
        s, e = indptr[r], indptr[r + 1]
        cols[r, : e - s] = indices[s:e]
        vals[r, : e - s] = data[s:e]
    return ELL(cols=jnp.asarray(cols), vals=jnp.asarray(vals), shape=a.shape)


def spmv_ell_ref(a: ELL, x: jax.Array) -> jax.Array:
    """Reference ELL SpMV: masked gather + row-sum (pure jnp oracle)."""
    mask = a.cols >= 0
    xg = jnp.take(x, jnp.maximum(a.cols, 0), axis=0)
    y = jnp.sum(jnp.where(mask, a.vals * xg, 0), axis=1)
    return y[: a.n_rows]


def split_long_rows(a: CSR, k: int) -> tuple[CSR, np.ndarray]:
    """Split rows with degree > k into chains of sub-rows (vertex-delegate
    style mitigation for Table 3's high-max-degree pathology, §5.1).

    Returns the split CSR and an int32 map ``sub_row -> original_row`` so the
    caller can segment-sum sub-row results back together.
    """
    indptr = np.asarray(a.indptr)
    indices = np.asarray(a.indices)
    data = np.asarray(a.data)
    new_rows, owner = [], []
    for r in range(a.n_rows):
        s, e = int(indptr[r]), int(indptr[r + 1])
        if e - s <= k:
            new_rows.append((s, e))
            owner.append(r)
        else:
            for off in range(s, e, k):
                new_rows.append((off, min(off + k, e)))
                owner.append(r)
    nip = np.zeros(len(new_rows) + 1, dtype=np.int64)
    chunks_i, chunks_d = [], []
    for i, (s, e) in enumerate(new_rows):
        nip[i + 1] = nip[i] + (e - s)
        chunks_i.append(indices[s:e])
        chunks_d.append(data[s:e])
    out = CSR(
        indptr=jnp.asarray(nip, dtype=jnp.int32),
        indices=jnp.asarray(np.concatenate(chunks_i) if chunks_i else np.zeros(0, np.int32)),
        data=jnp.asarray(np.concatenate(chunks_d) if chunks_d else np.zeros(0, data.dtype)),
        shape=(len(new_rows), a.n_cols),
    )
    return out, np.asarray(owner, dtype=np.int32)
