"""Input generators matching the paper's experiment inputs (§4.2).

- ``laplacian_2d``: d=2, k=5 point stencil => n^2 x n^2 pentadiagonal
  Laplacian (SpMV synthetic input, Figs. 4-6).
- ``erdos_renyi`` / ``rmat``: Graph500-style balanced vs skewed graphs
  (BFS, Figs. 7-9), scale/edge-factor parameterization.
- ``skewed_matrix``: degree-distribution proxies for the Table 3 real-world
  matrices (offline container: SuiteSparse is unreachable, so we match the
  published Avg/Max-degree signatures instead).
"""
from __future__ import annotations

import numpy as np

from .csr import CSR


def laplacian_2d(n: int, dtype=np.float32) -> CSR:
    """5-point stencil Laplacian on an n x n grid -> (n^2, n^2) pentadiagonal."""
    N = n * n
    idx = np.arange(N)
    r, c = divmod(idx, n)
    rows = [idx]
    cols = [idx]
    vals = [np.full(N, 4.0, dtype=dtype)]
    for dr, dc in ((-1, 0), (1, 0), (0, -1), (0, 1)):
        rr, cc = r + dr, c + dc
        ok = (rr >= 0) & (rr < n) & (cc >= 0) & (cc < n)
        rows.append(idx[ok])
        cols.append((rr * n + cc)[ok])
        vals.append(np.full(ok.sum(), -1.0, dtype=dtype))
    return CSR.from_coo(np.concatenate(rows), np.concatenate(cols), np.concatenate(vals), (N, N))


def erdos_renyi_edges(scale: int, edge_factor: int = 16, seed: int = 0) -> np.ndarray:
    """Uniform-random (balanced) edge list, Graph500 sizing: 2^scale vertices,
    edge_factor * 2^scale undirected edges. Returns (m, 2) int64."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = edge_factor * n
    return rng.integers(0, n, size=(m, 2), dtype=np.int64)


def rmat_edges(
    scale: int,
    edge_factor: int = 16,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
) -> np.ndarray:
    """RMAT (Graph500 Kronecker) edge list with skewed degree distribution."""
    rng = np.random.default_rng(seed)
    n_bits = scale
    m = edge_factor * (1 << scale)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for _ in range(n_bits):
        u = rng.random(m)
        # quadrant probabilities a,b,c,d
        src_bit = u >= a + b
        dst_bit = ((u >= a) & (u < a + b)) | (u >= a + b + c)
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    return np.stack([src, dst], axis=1)


def edges_to_csr(edges: np.ndarray, n: int, symmetrize: bool = True, dtype=np.float32) -> CSR:
    """Edge list -> unweighted adjacency CSR (dedup, no self loops)."""
    e = edges
    if symmetrize:
        e = np.concatenate([e, e[:, ::-1]], axis=0)
    e = e[e[:, 0] != e[:, 1]]
    key = e[:, 0] * n + e[:, 1]
    key = np.unique(key)
    rows, cols = key // n, key % n
    return CSR.from_coo(rows, cols, np.ones(len(rows), dtype=dtype), (n, n))


# -- Table 3 proxies ---------------------------------------------------------
# (name, n_rows, approx nnz, avg_deg, max_deg) from the paper's Table 3; we
# generate matrices with matching row-degree signatures.
TABLE3_SIGNATURES = [
    ("mc2depi", 52_600, 4.0, 4),
    ("ecology1", 100_000, 5.0, 5),
    ("amazon03", 40_100, 8.0, 10),
    ("roadNet", 139_000, 2.76, 12),
    ("mac_econ", 20_600, 6.17, 44),
    ("cop20k_A", 12_100, 21.65, 81),
    ("watson_2", 35_200, 5.25, 93),
    ("poisson3", 8_600, 27.74, 145),
    ("gyro_k", 1_700, 58.82, 360),
    ("vsp_fina", 14_000, 7.90, 669),
    ("Stanford", 28_200, 8.20, 3860),
    ("ins2", 30_900, 8.89, 15470),
]
# NOTE: sizes are the paper's /10 (and max degree for the last two /10) so the
# whole Table 3 sweep runs in CPU-container minutes; degree *shape* (avg, max,
# skew) is what drives the paper's observed effect.


def skewed_matrix(n: int, avg_deg: float, max_deg: int, seed: int = 0, dtype=np.float32) -> CSR:
    """Matrix with given average and max row degree: lognormal-ish body plus a
    few max-degree hub rows (the Stanford/ins2 pathology)."""
    rng = np.random.default_rng(seed)
    if max_deg <= avg_deg * 2:
        lens = rng.poisson(avg_deg, size=n).clip(1, max_deg)
    else:
        sigma = 1.0
        mu = np.log(max(avg_deg, 1.01)) - sigma**2 / 2
        lens = np.exp(rng.normal(mu, sigma, size=n)).astype(np.int64).clip(1, max_deg)
        n_hubs = max(1, n // 2000)
        hubs = rng.choice(n, size=n_hubs, replace=False)
        lens[hubs] = max_deg
        # rescale body so the average lands near avg_deg
        body = np.setdiff1d(np.arange(n), hubs)
        target = avg_deg * n - n_hubs * max_deg
        if target > len(body):
            lens[body] = np.maximum(1, (lens[body] * target / lens[body].sum()).astype(np.int64))
    lens = np.minimum(lens, n)
    rows = np.repeat(np.arange(n), lens)
    cols = rng.integers(0, n, size=lens.sum())
    # dedupe within row
    key = np.unique(rows * n + cols)
    rows, cols = key // n, key % n
    vals = rng.standard_normal(len(rows)).astype(dtype)
    return CSR.from_coo(rows, cols, vals, (n, n))
