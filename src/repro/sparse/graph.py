"""STINGER-inspired partitioned graph (paper §3.2).

Vertices are striped across ``P`` logical nodelets exactly as on the Chick
(vertex ``v`` lives on nodelet ``v % P``); each vertex's adjacency stays with
its owner ("edge blocks from the local pool"). The TPU-blocked realization is
a padded (P, V_p, K) neighbor tensor — edge-block chains become contiguous
padded rows (DESIGN.md §2: regularize fine-grained structures into tiles).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .csr import CSR


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PartitionedGraph:
    """Graph striped over P logical nodelets.

    Global vertex id v  <->  (nodelet p = v % P, local slot l = v // P).
    """

    adj: jax.Array  # (P, V_p, K) int32 global neighbor ids, -1 = pad
    deg: jax.Array  # (P, V_p) int32 true degrees
    n_vertices: int  # static (<= P * V_p)

    def tree_flatten(self):
        return (self.adj, self.deg), self.n_vertices

    @classmethod
    def tree_unflatten(cls, n, leaves):
        return cls(*leaves, n_vertices=n)

    @property
    def P(self) -> int:
        return self.adj.shape[0]

    @property
    def v_per_nodelet(self) -> int:
        return self.adj.shape[1]

    @property
    def k(self) -> int:
        return self.adj.shape[2]

    @property
    def n_edges(self) -> int:
        return int(self.deg.sum())


def partition_graph(a: CSR, p: int, k: int | None = None) -> PartitionedGraph:
    """Stripe an adjacency CSR over ``p`` nodelets (v % p ownership)."""
    indptr = np.asarray(a.indptr)
    indices = np.asarray(a.indices)
    n = a.n_rows
    vp = -(-n // p)
    lens = indptr[1:] - indptr[:-1]
    kmax = int(lens.max()) if n else 1
    k = k or max(kmax, 1)
    if kmax > k:
        raise ValueError(f"max degree {kmax} > k={k}")
    adj = np.full((p, vp, k), -1, dtype=np.int32)
    deg = np.zeros((p, vp), dtype=np.int32)
    for v in range(n):
        s, e = indptr[v], indptr[v + 1]
        adj[v % p, v // p, : e - s] = indices[s:e]
        deg[v % p, v // p] = e - s
    return PartitionedGraph(adj=jnp.asarray(adj), deg=jnp.asarray(deg), n_vertices=n)


def owner_of(v: jax.Array, p: int) -> jax.Array:
    return v % p


def local_slot(v: jax.Array, p: int) -> jax.Array:
    return v // p


def global_id(p_idx: jax.Array, slot: jax.Array, p: int) -> jax.Array:
    return slot * p + p_idx
