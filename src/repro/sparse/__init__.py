from .csr import CSR, spmv_csr_ref
from .ell import ELL, ell_from_csr, spmv_ell_ref, split_long_rows
from .gen import (
    TABLE3_SIGNATURES,
    edges_to_csr,
    erdos_renyi_edges,
    laplacian_2d,
    rmat_edges,
    skewed_matrix,
)
from .graph import PartitionedGraph, global_id, local_slot, owner_of, partition_graph

__all__ = [
    "CSR", "ELL", "PartitionedGraph", "TABLE3_SIGNATURES",
    "edges_to_csr", "ell_from_csr", "erdos_renyi_edges", "global_id",
    "laplacian_2d", "local_slot", "owner_of", "partition_graph",
    "rmat_edges", "skewed_matrix", "spmv_csr_ref", "spmv_ell_ref",
    "split_long_rows",
]
