"""CSR sparse-matrix container (paper §3.1 Fig. 2 layout).

The Emu stores the row-offset array striped across nodelets and keeps each
row's nonzeros together on one nodelet (jagged ``col``/``V`` arrays). Here the
container is device-agnostic; the *partitioned* views used by the distributed
ops live in :mod:`repro.core.spmv`.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed sparse row matrix: three arrays + a static shape."""

    indptr: jax.Array  # (n_rows + 1,) int32
    indices: jax.Array  # (nnz,) int32 column ids
    data: jax.Array  # (nnz,) values
    shape: tuple[int, int]  # static

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        return (self.indptr, self.indices, self.data), self.shape

    @classmethod
    def tree_unflatten(cls, shape, leaves):
        return cls(*leaves, shape=shape)

    # -- basic properties ------------------------------------------------
    @property
    def nnz(self) -> int:
        return self.data.shape[0]

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    def row_lengths(self) -> jax.Array:
        return self.indptr[1:] - self.indptr[:-1]

    # -- conversions -------------------------------------------------------
    @classmethod
    def from_coo(cls, rows, cols, vals, shape) -> "CSR":
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        vals = np.asarray(vals)
        order = np.lexsort((cols, rows))
        rows, cols, vals = rows[order], cols[order], vals[order]
        indptr = np.zeros(shape[0] + 1, dtype=np.int64)
        np.add.at(indptr, rows + 1, 1)
        indptr = np.cumsum(indptr)
        return cls(
            indptr=jnp.asarray(indptr, dtype=jnp.int32),
            indices=jnp.asarray(cols, dtype=jnp.int32),
            data=jnp.asarray(vals),
            shape=tuple(int(s) for s in shape),
        )

    @classmethod
    def from_dense(cls, dense: np.ndarray) -> "CSR":
        dense = np.asarray(dense)
        rows, cols = np.nonzero(dense)
        return cls.from_coo(rows, cols, dense[rows, cols], dense.shape)

    def to_dense(self) -> jax.Array:
        row_of_nnz = jnp.searchsorted(
            self.indptr, jnp.arange(self.nnz, dtype=self.indptr.dtype), side="right"
        ) - 1
        out = jnp.zeros(self.shape, dtype=self.data.dtype)
        return out.at[row_of_nnz, self.indices].add(self.data)


@partial(jax.jit, static_argnames=())
def spmv_csr_ref(a: CSR, x: jax.Array) -> jax.Array:
    """Reference CSR SpMV (y = A @ x) via segment-sum. Oracle for all SpMV paths."""
    row_of_nnz = jnp.searchsorted(
        a.indptr, jnp.arange(a.nnz, dtype=a.indptr.dtype), side="right"
    ) - 1
    prod = a.data * jnp.take(x, a.indices, axis=0)
    return jax.ops.segment_sum(prod, row_of_nnz, num_segments=a.n_rows)
