"""Cluster worker process: one `EngineService` behind a socket (§1h).

Spawned by the launcher as ``python -m repro.cluster.worker --connect
HOST:PORT --worker-id K``, it dials back to the coordinator, sends a
``hello``, and serves the protocol until ``shutdown`` or EOF:

- ``submit`` — rebuild the :class:`~repro.engine.request.Request` from its
  wire form and run it through this process's own :class:`EngineService`
  worker loop. The worker therefore has everything the in-process serving
  plane has — plan cache with jitted executables, QoS, admission — which is
  what makes cluster results *structurally* bit-identical to
  ``engine.run``: the same pipeline executes, one process over.
- ``kernel_call`` — execute one substrate kernel on forwarded arguments
  (the :class:`~repro.cluster.substrate.ClusterSubstrate` fast path).
  Calls are wrapped in ``jax.jit`` with Python-scalar positional arguments
  pinned static — mirroring how the in-process plan cache closes over
  statics — and cached per value-independent signature, so repeated calls
  hit a warm executable. Kernels the tracer rejects fall back to eager,
  once, and stay pinned eager.
- ``submit_many`` — a coordinator-coalesced frame: each item is a full
  submit (ticket + request) sharing the frame's segment table; they fan
  out to the pool exactly as if they had arrived one frame each.
- ``put_blob`` / ``blob_gone`` — content-addressed data plane: shipped
  blobs land in a byte-budgeted LRU :class:`~repro.cluster.blobs.BlobStore`
  (digest-verified — corrupt shipments are refused); requests referencing
  a ``blobref`` this worker no longer holds block in ``ensure`` while a
  ``need_blob`` round trip re-fetches the bytes.
- ``ping`` — answered inline by the reader thread, *never* queued behind
  compute, so a busy worker still heartbeats and only a dead or truly hung
  process misses its deadline.

Log records from the ``repro`` logger tree are forwarded to the
coordinator as ``log`` messages (one line of a worker's warning shows up
in the coordinator's log, attributed to the worker).
"""
from __future__ import annotations

import argparse
import logging
import os
import socket
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any

from .protocol import Channel

log = logging.getLogger("repro.cluster.worker")


class _ForwardingLogHandler(logging.Handler):
    """Ships ``repro.*`` log records to the coordinator as ``log`` frames."""

    def __init__(self, channel: Channel, worker_id: int):
        super().__init__(level=logging.INFO)
        self._channel = channel
        self._worker_id = worker_id

    def emit(self, record: logging.LogRecord) -> None:
        if record.name.startswith("repro.cluster"):
            return  # don't forward our own transport chatter (loop risk)
        try:
            self._channel.send({
                "kind": "log",
                "worker_id": self._worker_id,
                "level": record.levelname,
                "logger": record.name,
                "msg": self.format(record),
            })
        except Exception:
            pass  # a dying channel must not take the service down


class _KernelCache:
    """Warm per-signature executables for forwarded kernel calls.

    Key: (op, value-independent argument signature, canonical kwargs).
    Python-scalar positional args are made ``static_argnums`` — the same
    constant-folding the in-process executor gets by closing over them —
    so e.g. a BFS ``root`` or gsana ``k`` compiles exactly as it would
    have locally. A kernel that refuses tracing runs eager and the key is
    pinned eager from then on.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._fns: dict[Any, Any] = {}

    def call(self, substrate: Any, op: str, args: tuple, kwargs: dict) -> Any:
        import jax

        from ..engine.api import args_signature
        from ..engine.wire import canonical_bytes

        key = (
            op,
            substrate.cache_fingerprint(),
            args_signature(args),
            canonical_bytes(kwargs),
        )
        with self._lock:
            fn = self._fns.get(key)
        if fn is not None:
            return fn(*args)
        kern = substrate.kernel(op)
        static = tuple(
            i
            for i, a in enumerate(args)
            if a is None or isinstance(a, (bool, int, float, str))
        )
        jitted = jax.jit(lambda *xs: kern(*xs, **kwargs), static_argnums=static)
        try:
            result = jitted(*args)
            chosen = jitted
        except Exception:
            # host-side work the tracer cannot see: run (and stay) eager
            def chosen(*xs):
                return kern(*xs, **kwargs)

            result = chosen(*args)
        with self._lock:
            self._fns[key] = chosen
        return result


def serve(
    connect: "tuple[str, int]",
    worker_id: int,
    *,
    substrate: str = "local",
    service_workers: int = 2,
    token: "str | None" = None,
) -> None:
    """Dial the coordinator and serve until ``shutdown`` or EOF."""
    from ..engine.request import Request
    from ..engine.service import EngineService
    from ..engine.substrate import get_substrate
    from ..engine.wire import (
        SegmentTable,
        collect_blob_digests,
        decode_value,
        encode_value,
    )
    from .blobs import BlobMissing, BlobStore

    token = token if token is not None else os.environ.get("REPRO_CLUSTER_TOKEN", "")
    sock = socket.create_connection(connect, timeout=30)
    sock.settimeout(None)
    channel = Channel(sock)
    handler = _ForwardingLogHandler(channel, worker_id)
    logging.getLogger("repro").addHandler(handler)

    service = EngineService(substrate=substrate, workers=service_workers)
    service.start()
    sub = get_substrate(substrate)
    kernels = _KernelCache()
    blob_store = BlobStore()
    pool = ThreadPoolExecutor(
        max_workers=max(2, service_workers), thread_name_prefix=f"w{worker_id}"
    )
    channel.send({
        "kind": "hello",
        "worker_id": worker_id,
        "pid": os.getpid(),
        "token": token,
        "substrate": substrate,
        "slots": sub.placement_slots(),
    })

    def request_blobs(missing: "list[str]") -> None:
        channel.send({"kind": "need_blob", "digests": missing})

    def decode_with_blobs(decode):
        """Run ``decode()`` with every referenced blob present, re-fetching
        via ``need_blob`` when the LRU evicted one between arrival and
        decode (bounded — a blob the coordinator cannot produce raises)."""
        for _attempt in range(3):
            try:
                return decode()
            except BlobMissing as exc:
                blob_store.ensure([exc.digest], request_blobs)
        return decode()

    def finish_submit(ticket: int, payload: dict) -> None:
        try:
            digests = collect_blob_digests(payload)
            if digests:
                blob_store.ensure(digests, request_blobs)
            request = decode_with_blobs(
                lambda: Request.from_wire(
                    payload, blob_resolver=blob_store.resolve
                )
            )
            response = service.submit(request).result()
            table = SegmentTable()
            channel.send({
                "kind": "result",
                "ticket": ticket,
                "result": encode_value(response.result, segments=table),
                "report": encode_value(response.report, segments=table),
            }, table.segments)
        except Exception as exc:  # noqa: BLE001 — every ticket must answer
            _send_error(ticket, exc)

    def finish_kernel(ticket: int, message: dict) -> None:
        try:
            digests = collect_blob_digests([message["args"], message["kwargs"]])
            if digests:
                blob_store.ensure(digests, request_blobs)
            args, kwargs = decode_with_blobs(
                lambda: (
                    decode_value(
                        message["args"], blob_resolver=blob_store.resolve
                    ),
                    decode_value(
                        message["kwargs"], blob_resolver=blob_store.resolve
                    ),
                )
            )
            result = kernels.call(sub, message["op"], tuple(args), kwargs)
            table = SegmentTable()
            channel.send({
                "kind": "result",
                "ticket": ticket,
                "result": encode_value(result, segments=table),
                "report": None,
            }, table.segments)
        except Exception as exc:  # noqa: BLE001
            _send_error(ticket, exc)

    def _send_error(ticket: int, exc: BaseException) -> None:
        try:
            channel.send({
                "kind": "error",
                "ticket": ticket,
                "etype": type(exc).__name__,
                "error": str(exc),
            })
        except Exception:
            pass

    try:
        while True:
            message = channel.recv()
            if message is None:
                break  # coordinator gone
            kind = message["kind"]
            if kind == "ping":
                channel.send({"kind": "pong", "inflight": len(service)})
            elif kind == "submit":
                pool.submit(finish_submit, message["ticket"], message["request"])
            elif kind == "submit_many":
                for item in message["items"]:
                    pool.submit(finish_submit, item["ticket"], item["request"])
            elif kind == "put_blob":
                # verify-then-store inline on the reader: the bytes must be
                # in the store before any frame referencing them decodes
                try:
                    blob_store.put(
                        message["digest"], decode_value(message["blob"])
                    )
                except Exception:
                    log.exception(
                        "worker %d: refused blob %s", worker_id,
                        message.get("digest"),
                    )
            elif kind == "blob_gone":
                blob_store.mark_gone(message["digest"])
            elif kind == "kernel_call":
                pool.submit(finish_kernel, message["ticket"], message)
            elif kind == "stats":
                stats = service.stats()
                stats.wire_bytes_sent = channel.bytes_sent
                stats.wire_bytes_received = channel.bytes_received
                store_stats = blob_store.stats()
                stats.blob_hits = store_stats["hits"]
                stats.blob_misses = store_stats["misses"]
                row = stats.to_dict()
                row["blob_store"] = store_stats
                channel.send({
                    "kind": "stats_reply",
                    "ticket": message["ticket"],
                    "stats": row,
                })
            elif kind == "shutdown":
                break
            else:
                log.warning("worker %d: unknown message kind %r", worker_id, kind)
    finally:
        pool.shutdown(wait=False)
        try:
            service.stop(drain=False)
        except Exception:
            pass
        logging.getLogger("repro").removeHandler(handler)
        channel.close()


def main(argv: "list[str] | None" = None) -> None:
    parser = argparse.ArgumentParser(description="repro cluster worker process")
    parser.add_argument("--connect", required=True, help="coordinator HOST:PORT")
    parser.add_argument("--worker-id", type=int, required=True)
    parser.add_argument("--substrate", default="local")
    parser.add_argument("--service-workers", type=int, default=2)
    args = parser.parse_args(argv)
    host, _, port = args.connect.rpartition(":")
    serve(
        (host, int(port)),
        args.worker_id,
        substrate=args.substrate,
        service_workers=args.service_workers,
    )


if __name__ == "__main__":
    main()
