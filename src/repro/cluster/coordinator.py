"""Cluster coordinator: admission, routing, health, and failover (§1h).

The coordinator owns the client-facing end of the control plane. It
listens on a localhost socket; workers dial in and say ``hello``; from
then on each worker is a :class:`WorkerHandle` with a reader thread, a
health state, and an in-flight table. Two submission paths share the
machinery:

- :meth:`Coordinator.submit` — a whole :class:`Request` crosses the wire
  and the worker's own ``EngineService`` serves it (the serving path;
  warm plan-cache executables live *in the worker*). Requests are routed
  by **placement key** (op name x input signature x strategy identity):
  the first request of a key pins it to the least-loaded live worker, and
  every later request with the same key — i.e. the same compiled
  executable — goes to the same process. That is the Emu discipline one
  level up: migrate the *request* to the process that owns the data
  (here: the warm executable), never migrate the executable.
- :meth:`Coordinator.kernel_call` — one substrate kernel invocation
  (the :class:`~repro.cluster.substrate.ClusterSubstrate` path), pinned
  to a worker by the substrate's placement variant.

**Health**: a monitor thread pings every worker each
``heartbeat_interval``; a worker whose last ``pong`` is older than
``heartbeat_timeout`` — or whose connection EOFs, the fast path for a
SIGKILLed process — is declared dead.

**Failover**: when a worker dies, its placement pins are dropped (keys
re-place on survivors on next submit — "slots redistributed") and every
in-flight request it held is retried **once** on a surviving worker. Safe
because ops are pure: re-running a request cannot double-apply anything.
A request whose retry also dies fails its future with
:class:`WorkerFailure` — every submitted future terminates, always.
Remote *computation* errors are not retried (they are deterministic); they
re-raise as :class:`RemoteOpError`.

**Data plane (protocol v2)**: every outgoing submit/kernel_call encodes
its arrays out-of-band — raw frame segments for small ones, and
content-addressed blobrefs for arrays at/above ``blob_min_bytes``.
Blob bytes ship to a given worker **once** (``put_blob``), tracked in the
per-worker ``blob_digests`` belief set; re-submits of the same tensor send
only its digest. Workers that evicted a blob ask for it back with
``need_blob``; failover re-ships an in-flight request's pinned blobs to
the survivor before replaying the request, so retries stay bit-identical.
Submits to the same worker are coalesced by a per-worker writer thread
into one ``submit_many`` frame under ``flush_window`` — continuous-batch
decode traffic pays one syscall + frame per flush, not per request.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import logging
import queue
import secrets
import socket
import threading
import time
import weakref
from typing import Any

from ..engine.api import args_signature
from ..engine.request import Request
from ..engine.wire import SegmentTable, content_digest, decode_value, encode_value
from .blobs import BlobStore, blob_min_bytes_default
from .protocol import Channel, ProtocolError

log = logging.getLogger("repro.cluster")


class ClusterError(RuntimeError):
    """The cluster cannot serve (no live workers / not listening / stopped)."""


class WorkerFailure(ClusterError):
    """The worker executing a request died, and so did its one retry."""


class RemoteOpError(RuntimeError):
    """The request itself raised on the worker (not a transport failure)."""

    def __init__(self, etype: str, message: str, worker_id: int):
        super().__init__(f"[worker {worker_id}] {etype}: {message}")
        self.etype = etype
        self.worker_id = worker_id


class WorkerState(str, enum.Enum):
    STARTING = "starting"
    HEALTHY = "healthy"
    DEAD = "dead"


@dataclasses.dataclass
class ClusterResponse:
    """What a resolved cluster future yields."""

    ticket: int
    result: Any
    report: Any  # RunReport for submit(); None for kernel calls
    worker_id: int
    retried: bool = False


class ClusterFuture:
    """Terminates exactly once: a response, a remote error, or failover
    exhaustion. Same blocking surface as ``ServiceFuture``."""

    def __init__(self, ticket: int):
        self.ticket = ticket
        self._done = threading.Event()
        self._response: "ClusterResponse | None" = None
        self._exception: "BaseException | None" = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: "float | None" = None) -> ClusterResponse:
        if not self._done.wait(timeout):
            raise TimeoutError(f"cluster request {self.ticket} still pending")
        if self._exception is not None:
            raise self._exception
        assert self._response is not None
        return self._response

    def exception(self, timeout: "float | None" = None) -> "BaseException | None":
        if not self._done.wait(timeout):
            raise TimeoutError(f"cluster request {self.ticket} still pending")
        return self._exception

    def _resolve(self, response: ClusterResponse) -> None:
        self._response = response
        self._done.set()

    def _fail(self, exc: BaseException) -> None:
        self._exception = exc
        self._done.set()


@dataclasses.dataclass
class _Inflight:
    ticket: int
    future: ClusterFuture
    #: resend template (everything but the ticket) — what failover replays
    message: "dict[str, Any]"
    decode_report: bool
    retried: bool = False
    #: the message's out-of-band payload buffers (ndref targets), replayed
    #: verbatim on failover so the retry is bit-identical
    segments: "list[Any]" = dataclasses.field(default_factory=list)
    #: digest -> array pins for every blobref the message references —
    #: strong refs, so failover can re-ship even past store eviction
    blobs: "dict[str, Any]" = dataclasses.field(default_factory=dict)


def _offset_ndrefs(node: Any, offset: int) -> Any:
    """A structural copy of an encoded message with every ndref's segment
    index shifted by ``offset`` — how per-submit segment tables concatenate
    into one ``submit_many`` frame. A copy, never in-place: the original is
    an in-flight entry's resend template."""
    if isinstance(node, dict):
        out = {k: _offset_ndrefs(v, offset) for k, v in node.items()}
        if out.get("__wire__") == "ndref" and isinstance(out.get("seg"), int):
            out["seg"] += offset
        return out
    if isinstance(node, list):
        return [_offset_ndrefs(v, offset) for v in node]
    return node


class WorkerHandle:
    """Coordinator-side view of one worker process."""

    def __init__(self, worker_id: int, channel: Channel, hello: dict):
        self.worker_id = worker_id
        self.channel = channel
        self.pid: "int | None" = hello.get("pid")
        self.substrate: str = hello.get("substrate", "local")
        self.slots: int = int(hello.get("slots", 1))
        self.state = WorkerState.HEALTHY
        self.last_pong = time.monotonic()
        self.served = 0
        self.inflight: "dict[int, _Inflight]" = {}
        self.reader: "threading.Thread | None" = None
        #: belief set: digests this worker has been shipped (may be stale —
        #: the worker LRU-evicts; ``need_blob`` repairs the divergence)
        self.blob_digests: "set[str]" = set()
        #: blobrefs sent without re-shipping bytes (the data-plane win) /
        #: shipments (first sends + need_blob re-sends)
        self.blob_hits = 0
        self.blob_misses = 0
        #: pipelined-submit writer: dispatch enqueues, the writer coalesces
        self.send_queue: "queue.Queue[Any]" = queue.Queue()
        self.writer: "threading.Thread | None" = None

    def describe(self) -> dict:
        return {
            "worker_id": self.worker_id,
            "pid": self.pid,
            "state": self.state.value,
            "substrate": self.substrate,
            "slots": self.slots,
            "served": self.served,
            "inflight": len(self.inflight),
            "blob_hits": self.blob_hits,
            "blob_misses": self.blob_misses,
            "blobs_shipped": len(self.blob_digests),
            **self.channel.wire_stats(),
        }


class Coordinator:
    def __init__(
        self,
        *,
        heartbeat_interval: float = 0.5,
        heartbeat_timeout: float = 5.0,
        max_inflight: int = 512,
        call_timeout: float = 300.0,
        token: "str | None" = None,
        flush_window: float = 0.002,
        blob_min_bytes: "int | None" = None,
        blob_budget_bytes: "int | None" = None,
    ):
        self.heartbeat_interval = heartbeat_interval
        self.heartbeat_timeout = heartbeat_timeout
        self.max_inflight = max_inflight
        self.call_timeout = call_timeout
        self.token = token if token is not None else secrets.token_hex(8)
        #: submit-coalescing window (seconds): when a worker's writer sees
        #: a *burst* — several submits already queued, or other submits
        #: still in flight on the worker — it lingers this long for
        #: stragglers before flushing everything as one ``submit_many``
        #: frame. An isolated submit with nothing else outstanding is
        #: flushed immediately — the window never taxes synchronous
        #: single-stream latency. 0 disables the linger (still coalesces
        #: whatever already queued up).
        self.flush_window = flush_window
        #: arrays at/above this many bytes become content-addressed blobs
        self.blob_min_bytes = (
            blob_min_bytes_default() if blob_min_bytes is None else int(blob_min_bytes)
        )
        #: re-ship source for ``need_blob``; in-flight pins cover the rest
        self._blob_store = BlobStore(budget_bytes=blob_budget_bytes)
        self._digest_lock = threading.Lock()
        self._digest_cache: "dict[int, tuple[Any, str]]" = {}
        self._lock = threading.RLock()
        self._space = threading.Condition(self._lock)  # admission: slot freed
        self._joined = threading.Condition(self._lock)  # wait_ready()
        self._workers: "dict[int, WorkerHandle]" = {}
        self._tickets = itertools.count(1)
        self._inflight_total = 0
        self._placement: "dict[Any, int]" = {}  # placement key -> worker_id
        self._generation = 0  # bumps on every join/death (topology identity)
        self._listener: "socket.socket | None" = None
        self._threads: "list[threading.Thread]" = []
        self._stopping = False
        # counters for stats()
        self._submitted = 0
        self._kernel_calls = 0
        self._retries = 0
        self._failovers = 0
        self._remote_errors = 0
        self._submit_frames = 0  # frames that carried >=1 submit
        self._submits_coalesced = 0  # submits that rode a submit_many

    # -- lifecycle -------------------------------------------------------------

    def listen(self, host: str = "127.0.0.1", port: int = 0) -> "tuple[str, int]":
        """Bind the control socket and start the accept + monitor threads.
        Returns the bound ``(host, port)`` workers should dial."""
        listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        listener.bind((host, port))
        listener.listen(64)
        self._listener = listener
        for target, name in ((self._accept_loop, "accept"), (self._monitor_loop, "monitor")):
            thread = threading.Thread(
                target=target, name=f"cluster-{name}", daemon=True
            )
            thread.start()
            self._threads.append(thread)
        return listener.getsockname()[:2]

    @property
    def address(self) -> "tuple[str, int]":
        if self._listener is None:
            raise ClusterError("coordinator is not listening (call listen())")
        return self._listener.getsockname()[:2]

    def wait_ready(self, n_workers: int, timeout: float = 120.0) -> None:
        """Block until ``n_workers`` workers are registered and healthy."""
        deadline = time.monotonic() + timeout
        with self._joined:
            while len(self.healthy_workers()) < n_workers:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise ClusterError(
                        f"only {len(self.healthy_workers())} of {n_workers} "
                        f"workers joined within {timeout:.0f}s"
                    )
                self._joined.wait(remaining)

    def shutdown(self) -> None:
        """Stop serving: tell workers to exit, fail leftover futures."""
        with self._lock:
            if self._stopping:
                return
            self._stopping = True
            workers = list(self._workers.values())
            self._space.notify_all()
        for worker in workers:
            worker.send_queue.put(None)  # stop the writer
            try:
                worker.channel.send({"kind": "shutdown"})
            except Exception:
                pass
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
        time.sleep(0.05)  # give shutdown frames a beat to flush
        for worker in workers:
            worker.channel.close()
            self._sweep_inflight(worker, ClusterError("cluster shut down"))

    # -- membership ------------------------------------------------------------

    def healthy_workers(self) -> "list[WorkerHandle]":
        with self._lock:
            return [
                w for w in self._workers.values() if w.state == WorkerState.HEALTHY
            ]

    def worker(self, worker_id: int) -> WorkerHandle:
        with self._lock:
            return self._workers[worker_id]

    def topology_fingerprint(self) -> tuple:
        """Hashable cluster-topology identity for plan-cache fingerprints:
        which workers exist, where, and the membership generation — plans
        compiled against one topology never serve another."""
        with self._lock:
            members = tuple(
                (w.worker_id, w.substrate, w.slots)
                for w in sorted(self._workers.values(), key=lambda w: w.worker_id)
                if w.state == WorkerState.HEALTHY
            )
            return (self._generation, members)

    def _accept_loop(self) -> None:
        assert self._listener is not None
        while not self._stopping:
            try:
                sock, _addr = self._listener.accept()
            except OSError:
                return  # listener closed — shutting down
            sock.settimeout(None)
            threading.Thread(
                target=self._register, args=(sock,), daemon=True
            ).start()

    def _register(self, sock: socket.socket) -> None:
        channel = Channel(sock)
        try:
            hello = channel.recv()
        except ProtocolError:
            channel.close()
            return
        if hello is None or hello.get("kind") != "hello":
            channel.close()
            return
        if self.token and hello.get("token") != self.token:
            log.warning("rejecting worker with bad token")
            channel.close()
            return
        worker = WorkerHandle(int(hello["worker_id"]), channel, hello)
        with self._joined:
            stale = self._workers.get(worker.worker_id)
            if stale is not None and stale.state != WorkerState.DEAD:
                log.warning(
                    "worker %d reconnected while marked %s; replacing",
                    worker.worker_id, stale.state.value,
                )
                stale.channel.close()
            self._workers[worker.worker_id] = worker
            self._generation += 1
            self._joined.notify_all()
        reader = threading.Thread(
            target=self._reader_loop,
            args=(worker,),
            name=f"cluster-reader-{worker.worker_id}",
            daemon=True,
        )
        worker.reader = reader
        reader.start()
        writer = threading.Thread(
            target=self._writer_loop,
            args=(worker,),
            name=f"cluster-writer-{worker.worker_id}",
            daemon=True,
        )
        worker.writer = writer
        writer.start()
        log.info(
            "worker %d joined (pid=%s, substrate=%s, slots=%d)",
            worker.worker_id, worker.pid, worker.substrate, worker.slots,
        )

    # -- submission ------------------------------------------------------------

    def _array_digest(self, original: Any, arr: Any) -> str:
        """Content digest of one array, memoized by the *original* object's
        identity — a decode server re-submitting the same expert-weight
        array (frozen numpy, or an immutable jax array) pays sha256 once,
        not per request. Only **read-only** buffers are memoized: a
        writable array can be mutated in place and resubmitted, and an
        id()-keyed digest would then silently ship the old bytes — those
        recompute every time. Weak refs keep the cache from pinning
        tensors; un-weakref-able inputs just recompute."""
        key = id(original)
        with self._digest_lock:
            entry = self._digest_cache.get(key)
            if entry is not None and entry[0]() is original:
                return entry[1]
        digest = content_digest(arr)
        if arr.flags.writeable:
            return digest
        try:
            ref = weakref.ref(
                original, lambda _r, k=key: self._digest_cache.pop(k, None)
            )
        except TypeError:
            return digest
        with self._digest_lock:
            self._digest_cache[key] = (ref, digest)
        return digest

    def _make_blob_sink(self, blobs: "dict[str, Any]"):
        """A ``blob_sink`` for :func:`encode_value`: arrays at/above the
        threshold become blobrefs, pinned in ``blobs`` and admitted to the
        coordinator's re-ship store."""

        def sink(original: Any, arr: Any) -> "str | None":
            if arr.nbytes < self.blob_min_bytes:
                return None
            digest = self._array_digest(original, arr)
            blobs[digest] = self._blob_store.put(digest, arr, verify=False)
            return digest

        return sink

    def submit(self, request: Request) -> ClusterFuture:
        """Serve one Request on the cluster; returns a future that always
        terminates (result, remote error, or :class:`WorkerFailure`)."""
        segments = SegmentTable()
        blobs: "dict[str, Any]" = {}
        # raises WireError before admission
        payload = request.to_wire(
            segments=segments, blob_sink=self._make_blob_sink(blobs)
        )
        op_name = payload["op"]
        strategy = request.strategy
        strategy_id = (
            strategy.cache_key() if hasattr(strategy, "cache_key") else strategy
        )
        placement_key = (op_name, strategy_id, args_signature((request.inputs,)))
        message = {"kind": "submit", "request": payload}
        with self._space:
            while (
                self._inflight_total >= self.max_inflight and not self._stopping
            ):
                self._space.wait(1.0)
            if self._stopping:
                raise ClusterError("coordinator is shut down")
            worker = self._place(placement_key)
            self._submitted += 1
        return self._dispatch(
            worker,
            message,
            decode_report=True,
            segments=segments.segments,
            blobs=blobs,
        )

    def kernel_call(
        self,
        op: str,
        args: tuple,
        kwargs: dict,
        *,
        worker_pin: "int | None" = None,
        timeout: "float | None" = None,
    ) -> Any:
        """Execute one substrate kernel on a worker (blocking). Pinned calls
        go to ``worker_pin`` while it is healthy; a death mid-call fails
        over exactly like a submit."""
        segments = SegmentTable()
        blobs: "dict[str, Any]" = {}
        sink = self._make_blob_sink(blobs)
        message = {
            "kind": "kernel_call",
            "op": op,
            "args": encode_value(tuple(args), segments=segments, blob_sink=sink),
            "kwargs": encode_value(
                dict(kwargs), segments=segments, blob_sink=sink
            ),
        }
        with self._lock:
            if self._stopping:
                raise ClusterError("coordinator is shut down")
            worker = None
            if worker_pin is not None:
                candidate = self._workers.get(worker_pin)
                if candidate is not None and candidate.state == WorkerState.HEALTHY:
                    worker = candidate
            if worker is None:
                worker = self._least_loaded()
            self._kernel_calls += 1
        future = self._dispatch(
            worker,
            message,
            decode_report=False,
            segments=segments.segments,
            blobs=blobs,
        )
        timeout = self.call_timeout if timeout is None else timeout
        try:
            response = future.result(timeout=timeout)
        except TimeoutError:
            # hung worker the heartbeat hasn't condemned yet (e.g. pings
            # answered but compute wedged): condemn it ourselves; failover
            # resubmits the call, so wait once more for the retry
            self._on_death(worker, f"kernel call exceeded {timeout:.0f}s")
            response = future.result(timeout=timeout)
        return response.result

    def _place(self, key: Any) -> WorkerHandle:
        """Sticky placement: first arrival of a key pins it to the
        least-loaded live worker; later arrivals follow the pin. Dead
        workers' pins were dropped at death, so their keys re-place here —
        the slot-redistribution half of failover."""
        pinned = self._placement.get(key)
        if pinned is not None:
            worker = self._workers.get(pinned)
            if worker is not None and worker.state == WorkerState.HEALTHY:
                return worker
        worker = self._least_loaded()
        self._placement[key] = worker.worker_id
        return worker

    def _least_loaded(self) -> WorkerHandle:
        healthy = [
            w for w in self._workers.values() if w.state == WorkerState.HEALTHY
        ]
        if not healthy:
            raise ClusterError("no healthy workers")
        pins: "dict[int, int]" = {w.worker_id: 0 for w in healthy}
        for wid in self._placement.values():
            if wid in pins:
                pins[wid] += 1
        return min(
            healthy, key=lambda w: (len(w.inflight), pins[w.worker_id], w.worker_id)
        )

    def _dispatch(
        self,
        worker: WorkerHandle,
        message: "dict[str, Any]",
        *,
        decode_report: bool,
        retried: bool = False,
        future: "ClusterFuture | None" = None,
        segments: "list[Any] | None" = None,
        blobs: "dict[str, Any] | None" = None,
    ) -> ClusterFuture:
        segments = [] if segments is None else segments
        blobs = {} if blobs is None else blobs
        with self._lock:
            if worker.state == WorkerState.DEAD:
                # died between placement and dispatch: reroute immediately
                # (raises ClusterError when no one is left)
                worker = self._least_loaded()
            ticket = next(self._tickets)
            if future is None:
                future = ClusterFuture(ticket)
            entry = _Inflight(
                ticket, future, message, decode_report, retried,
                segments=segments, blobs=blobs,
            )
            worker.inflight[ticket] = entry
            self._inflight_total += 1
            # decide blob shipments under the lock (belief set is shared
            # state); the actual sends happen outside it
            unshipped = [d for d in blobs if d not in worker.blob_digests]
            worker.blob_digests.update(unshipped)
            worker.blob_hits += len(blobs) - len(unshipped)
            worker.blob_misses += len(unshipped)
        try:
            for digest in unshipped:
                # direct send, so TCP ordering puts the bytes on the worker
                # before any frame that references the digest
                self._ship_blob(worker, digest, blobs[digest])
            if message.get("kind") == "submit":
                # the writer coalesces queued submits into submit_many
                worker.send_queue.put(({**message, "ticket": ticket}, segments))
            else:
                worker.channel.send({**message, "ticket": ticket}, segments)
        except Exception as exc:  # connection died between place and send
            self._on_death(worker, f"send failed: {exc}")
        return future

    def _ship_blob(self, worker: WorkerHandle, digest: str, array: Any) -> None:
        table = SegmentTable()
        encoded = encode_value(array, segments=table)
        worker.channel.send(
            {"kind": "put_blob", "digest": digest, "blob": encoded},
            table.segments,
        )

    def _writer_loop(self, worker: WorkerHandle) -> None:
        """Per-worker pipelined-submit writer: pick up one queued submit,
        drain whatever else already queued, and flush it all as a single
        frame — ``submit_many`` when more than one coalesced. The
        ``flush_window`` linger only happens when a burst is plausibly in
        progress — the drain found company, or the caller has *other*
        submits still in flight on this worker (a pipelined stream, so
        more is coming); a synchronous single-stream caller's isolated
        submit flushes immediately and pays no latency tax."""
        q = worker.send_queue
        while True:
            item = q.get()
            if item is None:
                return  # death or shutdown sentinel
            batch = [item]
            stop = False

            def drain() -> None:
                nonlocal stop
                while not stop:
                    try:
                        nxt = q.get_nowait()
                    except queue.Empty:
                        return
                    if nxt is None:
                        stop = True
                        return
                    batch.append(nxt)

            drain()
            # worker.inflight already holds the batch's own entries
            # (dispatch registers before enqueueing), so a strictly larger
            # inflight table means other submits are still outstanding
            if (
                self.flush_window > 0
                and not stop
                and (len(batch) > 1 or len(worker.inflight) > len(batch))
            ):
                time.sleep(self.flush_window)
                drain()
            try:
                self._send_batch(worker, batch)
            except Exception as exc:
                # _on_death retries everything in worker.inflight —
                # including the batch and anything still queued
                self._on_death(worker, f"send failed: {exc}")
                return
            if stop:
                return

    def _send_batch(self, worker: WorkerHandle, batch: "list[tuple]") -> None:
        if len(batch) == 1:
            message, segments = batch[0]
            worker.channel.send(message, segments)
            with self._lock:
                self._submit_frames += 1
            return
        items: "list[Any]" = []
        all_segments: "list[Any]" = []
        for message, segments in batch:
            items.append(_offset_ndrefs(message, len(all_segments)))
            all_segments.extend(segments)
        worker.channel.send(
            {"kind": "submit_many", "items": items}, all_segments
        )
        with self._lock:
            self._submit_frames += 1
            self._submits_coalesced += len(batch)

    # -- worker I/O ------------------------------------------------------------

    def _reader_loop(self, worker: WorkerHandle) -> None:
        while True:
            try:
                message = worker.channel.recv()
            except ProtocolError as exc:
                self._on_death(worker, f"protocol error: {exc}")
                return
            if message is None:
                if worker.state != WorkerState.DEAD and not self._stopping:
                    self._on_death(worker, "connection closed")
                return
            try:
                self._on_message(worker, message)
            except Exception:
                log.exception(
                    "error handling %r from worker %d",
                    message.get("kind"), worker.worker_id,
                )

    def _on_message(self, worker: WorkerHandle, message: dict) -> None:
        kind = message["kind"]
        if kind == "pong":
            worker.last_pong = time.monotonic()
            return
        if kind == "log":
            level = getattr(logging, message.get("level", "INFO"), logging.INFO)
            logging.getLogger(
                f"repro.cluster.w{worker.worker_id}.{message.get('logger', '?')}"
            ).log(level, "%s", message.get("msg", ""))
            return
        if kind in ("result", "error"):
            with self._space:
                entry = worker.inflight.pop(message["ticket"], None)
                if entry is not None:
                    self._inflight_total -= 1
                    self._space.notify_all()
            if entry is None:
                return  # already failed over; late answer is redundant
            if kind == "error":
                with self._lock:
                    self._remote_errors += 1
                entry.future._fail(
                    RemoteOpError(
                        message.get("etype", "Exception"),
                        message.get("error", ""),
                        worker.worker_id,
                    )
                )
                return
            worker.served += 1
            report = message.get("report")
            entry.future._resolve(
                ClusterResponse(
                    ticket=entry.ticket,
                    result=decode_value(message["result"]),
                    report=(
                        decode_value(report)
                        if entry.decode_report and report is not None
                        else None
                    ),
                    worker_id=worker.worker_id,
                    retried=entry.retried,
                )
            )
            return
        if kind == "stats_reply":
            with self._lock:
                entry = worker.inflight.pop(message["ticket"], None)
                self._inflight_total -= 1 if entry else 0
            if entry is not None:
                entry.future._resolve(
                    ClusterResponse(
                        entry.ticket, message.get("stats"), None, worker.worker_id
                    )
                )
            return
        if kind == "need_blob":
            # the worker evicted (or never had) these digests: re-ship from
            # the coordinator store, falling back to in-flight pins; answer
            # blob_gone for anything unproducible so the request fails fast
            # instead of hanging in BlobStore.ensure
            for digest in message.get("digests", ()):
                array = self._blob_store.get(digest)
                if array is None:
                    with self._lock:
                        for w in self._workers.values():
                            for entry in w.inflight.values():
                                if digest in entry.blobs:
                                    array = entry.blobs[digest]
                                    break
                            if array is not None:
                                break
                try:
                    if array is None:
                        log.warning(
                            "worker %d needs blob %s but it is gone",
                            worker.worker_id, digest,
                        )
                        # forget the belief too: the next submit that
                        # references this digest must re-ship the bytes,
                        # not trust a pin we just failed to honor
                        with self._lock:
                            worker.blob_digests.discard(digest)
                        worker.channel.send(
                            {"kind": "blob_gone", "digest": digest}
                        )
                        continue
                    with self._lock:
                        worker.blob_digests.add(digest)
                        worker.blob_misses += 1
                    self._ship_blob(worker, digest, array)
                except Exception as exc:
                    self._on_death(worker, f"blob re-ship failed: {exc}")
                    return
            return
        log.warning("unknown message kind %r from worker %d", kind, worker.worker_id)

    # -- health + failover -----------------------------------------------------

    def _monitor_loop(self) -> None:
        while not self._stopping:
            time.sleep(self.heartbeat_interval)
            if self._stopping:  # woke into a shutdown: channels are closing
                return
            now = time.monotonic()
            for worker in self.healthy_workers():
                if now - worker.last_pong > self.heartbeat_timeout:
                    self._on_death(
                        worker,
                        f"missed heartbeats for {now - worker.last_pong:.1f}s",
                    )
                    continue
                try:
                    worker.channel.send({"kind": "ping"})
                except Exception as exc:
                    self._on_death(worker, f"ping failed: {exc}")

    def _on_death(self, worker: WorkerHandle, reason: str) -> None:
        """Declare ``worker`` dead: drop its placement pins, retry its
        in-flight work once on survivors, fail what was already retried."""
        with self._joined:
            if worker.state == WorkerState.DEAD or self._stopping:
                return  # already handled, or a shutdown tearing channels down
            worker.state = WorkerState.DEAD
            self._generation += 1
            self._failovers += 1
            dropped = [
                key for key, wid in self._placement.items()
                if wid == worker.worker_id
            ]
            for key in dropped:
                del self._placement[key]
            orphans = list(worker.inflight.values())
            worker.inflight.clear()
            self._inflight_total -= len(orphans)
            self._space.notify_all()
            self._joined.notify_all()
        worker.send_queue.put(None)  # stop the writer
        log.warning(
            "worker %d is dead (%s): redistributing %d placement pins, "
            "retrying %d in-flight request(s)",
            worker.worker_id, reason, len(dropped), len(orphans),
        )
        worker.channel.close()
        for entry in orphans:
            if entry.retried:
                entry.future._fail(
                    WorkerFailure(
                        f"request {entry.ticket} lost worker "
                        f"{worker.worker_id} ({reason}) after one retry"
                    )
                )
                continue
            try:
                with self._lock:
                    survivor = self._least_loaded()
                    self._retries += 1
                # segments + blob pins travel with the retry: the survivor
                # gets the same bytes (put_blob first if it lacks any
                # digest), so the replay is bit-identical
                self._dispatch(
                    survivor,
                    entry.message,
                    decode_report=entry.decode_report,
                    retried=True,
                    future=entry.future,
                    segments=entry.segments,
                    blobs=entry.blobs,
                )
            except ClusterError as exc:
                entry.future._fail(
                    WorkerFailure(
                        f"request {entry.ticket} lost worker "
                        f"{worker.worker_id} ({reason}) and no healthy "
                        f"worker remains: {exc}"
                    )
                )

    def _sweep_inflight(self, worker: WorkerHandle, exc: BaseException) -> None:
        with self._lock:
            orphans = list(worker.inflight.values())
            worker.inflight.clear()
            self._inflight_total -= len(orphans)
        for entry in orphans:
            entry.future._fail(exc)

    # -- introspection ---------------------------------------------------------

    def worker_stats(self, worker_id: int, timeout: float = 30.0) -> dict:
        """The worker's own ``ServiceStats.to_dict()`` snapshot, fetched
        over the wire."""
        worker = self.worker(worker_id)
        future = self._dispatch(
            worker, {"kind": "stats"}, decode_report=False
        )
        return future.result(timeout=timeout).result

    def stats(self) -> "dict[str, Any]":
        """Control-plane counters + per-worker health, serve counts, and
        wire-traffic rows (bytes/frames/blob hit-miss per worker)."""
        with self._lock:
            workers = [w.describe() for w in self._workers.values()]
            served = sum(w.served for w in self._workers.values())
            return {
                "workers": workers,
                "n_workers": len(workers),
                "n_healthy": sum(
                    1 for w in workers if w["state"] == WorkerState.HEALTHY.value
                ),
                "generation": self._generation,
                "submitted": self._submitted,
                "kernel_calls": self._kernel_calls,
                "served": served,
                "inflight": self._inflight_total,
                "retries": self._retries,
                "failovers": self._failovers,
                "remote_errors": self._remote_errors,
                "placement_pins": len(self._placement),
                "wire_bytes_sent": sum(w["bytes_sent"] for w in workers),
                "wire_bytes_received": sum(
                    w["bytes_received"] for w in workers
                ),
                "blob_hits": sum(w["blob_hits"] for w in workers),
                "blob_misses": sum(w["blob_misses"] for w in workers),
                "blob_store": self._blob_store.stats(),
                "submit_frames": self._submit_frames,
                "submits_coalesced": self._submits_coalesced,
                "flush_window": self.flush_window,
            }
