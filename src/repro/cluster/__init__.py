"""Cluster plane: multi-process serving substrate, launcher, failover.

The serving plane, out of one process (DESIGN.md §1h):

    from repro.cluster import launch_cluster
    from repro.engine import Request

    with launch_cluster(n_workers=2) as cluster:
        fut = cluster.submit(Request("spmv", SpMVInputs(a, x)))
        resp = fut.result()            # served by a worker process
        # ... or drive the PR-5 pool across processes:
        svc = EngineService(substrate="cluster", workers="auto")

Pieces: a length-prefixed JSON protocol (:mod:`.protocol`), worker
processes each running their own ``EngineService`` (:mod:`.worker`), a
coordinator owning admission/routing/heartbeats/failover
(:mod:`.coordinator`), a ``"cluster"`` substrate whose placement slots
span processes (:mod:`.substrate`), and a launcher with pluggable
process backends (:mod:`.launch`). Importing this package registers the
substrate.
"""
from .coordinator import (
    ClusterError,
    ClusterFuture,
    ClusterResponse,
    Coordinator,
    RemoteOpError,
    WorkerFailure,
    WorkerState,
)
from .launch import (
    Cluster,
    K8sBackend,
    LaunchBackend,
    LocalProcessBackend,
    WorkerSpec,
    launch_cluster,
)
from .substrate import (
    ClusterSubstrate,
    activate_cluster,
    active_cluster,
    deactivate_cluster,
)

__all__ = [
    "Cluster",
    "ClusterError",
    "ClusterFuture",
    "ClusterResponse",
    "ClusterSubstrate",
    "Coordinator",
    "K8sBackend",
    "LaunchBackend",
    "LocalProcessBackend",
    "RemoteOpError",
    "WorkerFailure",
    "WorkerSpec",
    "WorkerState",
    "activate_cluster",
    "active_cluster",
    "deactivate_cluster",
    "launch_cluster",
]
