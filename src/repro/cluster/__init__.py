"""Cluster plane: multi-process serving substrate, launcher, failover.

The serving plane, out of one process (DESIGN.md §1h):

    from repro.cluster import launch_cluster
    from repro.engine import Request

    with launch_cluster(n_workers=2) as cluster:
        fut = cluster.submit(Request("spmv", SpMVInputs(a, x)))
        resp = fut.result()            # served by a worker process
        # ... or drive the PR-5 pool across processes:
        svc = EngineService(substrate="cluster", workers="auto")

Pieces: a binary-framed v2 protocol — JSON envelope + raw out-of-band
tensor segments (:mod:`.protocol`), a content-addressed blob store so
repeated large inputs ship once per worker (:mod:`.blobs`), worker
processes each running their own ``EngineService`` (:mod:`.worker`), a
coordinator owning admission/routing/heartbeats/failover plus the
data-plane writer that coalesces submits (:mod:`.coordinator`), a
``"cluster"`` substrate whose placement slots span processes
(:mod:`.substrate`), and a launcher with pluggable process backends
(:mod:`.launch`). Importing this package registers the substrate.
"""
from .blobs import (
    BlobDigestMismatch,
    BlobError,
    BlobMissing,
    BlobStore,
    blob_digest,
)
from .coordinator import (
    ClusterError,
    ClusterFuture,
    ClusterResponse,
    Coordinator,
    RemoteOpError,
    WorkerFailure,
    WorkerState,
)
from .launch import (
    Cluster,
    K8sBackend,
    LaunchBackend,
    LocalProcessBackend,
    WorkerSpec,
    launch_cluster,
)
from .substrate import (
    ClusterSubstrate,
    activate_cluster,
    active_cluster,
    deactivate_cluster,
)

__all__ = [
    "BlobDigestMismatch",
    "BlobError",
    "BlobMissing",
    "BlobStore",
    "Cluster",
    "ClusterError",
    "ClusterFuture",
    "ClusterResponse",
    "ClusterSubstrate",
    "Coordinator",
    "K8sBackend",
    "LaunchBackend",
    "LocalProcessBackend",
    "RemoteOpError",
    "WorkerFailure",
    "WorkerSpec",
    "WorkerState",
    "activate_cluster",
    "active_cluster",
    "blob_digest",
    "deactivate_cluster",
    "launch_cluster",
]
