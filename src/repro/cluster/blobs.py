"""Content-addressed blob store for the cluster data plane (DESIGN.md §1h).

The Emu discipline, applied to the wire: move the lightweight context
(the request envelope) to where the bulk data already lives, never the
bulk data itself. Large arrays are addressed by the sha256 of their
canonical wire bytes (:func:`repro.engine.wire.content_digest` — the same
identity the dedup cache hashes), shipped to a worker **once** as a
``put_blob`` frame, and referenced thereafter as
``{"__wire__": "blobref", "digest": ...}`` — steady-state serving moves
per-step deltas, not the expert weights / adjacency structures the worker
already holds.

Both ends hold a :class:`BlobStore`:

- the **worker's** store is the authoritative byte-budgeted LRU the
  decode path resolves blobrefs against. On a miss (evicted, or a
  coordinator's stale belief) the worker sends ``need_blob`` and blocks
  that request in :meth:`BlobStore.ensure` until the blob is re-shipped —
  or the coordinator answers ``blob_gone``, which tombstones the digest
  and fails the request instead of hanging it. The tombstone is
  *transient*: it fails the waits that saw it and is cleared, so a later
  submit (which re-pins the blob coordinator-side) can re-fetch it.
- the **coordinator's** store keeps recently-shipped blobs for
  ``need_blob`` re-fetches and failover re-shipping (in-flight requests
  additionally pin their blobs on the ``_Inflight`` entry, so a retry can
  always re-ship even past coordinator-side eviction).

Budgets/thresholds (env-overridable, read at store/coordinator creation):

- ``REPRO_BLOB_MIN_BYTES`` (default 64 KiB) — arrays below this ride the
  frame inline as ``ndref`` segments; blob bookkeeping only pays off when
  re-shipping would hurt.
- ``REPRO_BLOB_BUDGET_BYTES`` (default 256 MiB) — per-store LRU byte
  budget. A single blob larger than the budget is still admitted alone
  (refusing it would deadlock the request that needs it).
"""
from __future__ import annotations

import os
import threading
import time
from collections import OrderedDict
from typing import Any, Callable

import numpy as np

from ..engine.wire import content_digest

DEFAULT_BLOB_MIN_BYTES = 64 << 10
DEFAULT_BLOB_BUDGET_BYTES = 256 << 20


def _env_bytes(name: str, default: int) -> int:
    raw = os.environ.get(name)
    if not raw:
        return default
    try:
        return max(1, int(raw))
    except ValueError:
        return default


def blob_min_bytes_default() -> int:
    """Arrays at/above this many bytes become blobrefs (coordinator side)."""
    return _env_bytes("REPRO_BLOB_MIN_BYTES", DEFAULT_BLOB_MIN_BYTES)


def blob_budget_bytes_default() -> int:
    """Per-store LRU byte budget."""
    return _env_bytes("REPRO_BLOB_BUDGET_BYTES", DEFAULT_BLOB_BUDGET_BYTES)


def blob_digest(array: Any) -> str:
    """Content address of one array: :func:`content_digest` of its
    canonical wire form — dtype/shape-aware and bit-exact, so two arrays
    share a digest iff they are the same tensor."""
    arr = np.ascontiguousarray(np.asarray(array))
    return content_digest(arr)


class BlobError(RuntimeError):
    """A blob the data plane needs cannot be produced."""


class BlobDigestMismatch(BlobError):
    """A shipped blob's bytes do not hash to its claimed digest."""


class BlobMissing(BlobError):
    """A blobref resolved against a store that does not hold the digest."""

    def __init__(self, digest: str):
        super().__init__(f"blob {digest} is not in the store")
        self.digest = digest


class BlobStore:
    """Byte-budgeted LRU of content-addressed arrays, with waiter support.

    Thread-safe. ``put`` verifies the digest by default (a worker must
    refuse corrupt shipments — :class:`BlobDigestMismatch`), stores the
    array read-only, and wakes any :meth:`ensure` waiters. Eviction is
    LRU by last ``get``/``put`` touch, down to the byte budget.
    """

    def __init__(self, budget_bytes: "int | None" = None):
        self.budget_bytes = (
            blob_budget_bytes_default() if budget_bytes is None else int(budget_bytes)
        )
        self._cond = threading.Condition()
        self._entries: "OrderedDict[str, np.ndarray]" = OrderedDict()
        self._gone: "set[str]" = set()  # coordinator said blob_gone
        self.bytes_stored = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.inserted = 0

    def __contains__(self, digest: str) -> bool:
        with self._cond:
            return digest in self._entries

    def __len__(self) -> int:
        with self._cond:
            return len(self._entries)

    def get(self, digest: str) -> "np.ndarray | None":
        """The stored array (LRU-touched) or None. Does not count stats —
        use :meth:`resolve` on the decode path."""
        with self._cond:
            arr = self._entries.get(digest)
            if arr is not None:
                self._entries.move_to_end(digest)
            return arr

    def resolve(self, digest: str) -> np.ndarray:
        """Decode-path lookup: the array, or :class:`BlobMissing`."""
        with self._cond:
            arr = self._entries.get(digest)
            if arr is None:
                raise BlobMissing(digest)
            self._entries.move_to_end(digest)
            self.hits += 1
            return arr

    def put(self, digest: str, array: Any, *, verify: bool = True) -> np.ndarray:
        """Admit one blob; evict LRU entries past the byte budget. With
        ``verify`` (the worker-side default) the bytes must hash back to
        ``digest`` — a mismatched shipment is refused, never stored.

        The stored entry is always a *private* read-only array: the
        caller's own object is never frozen (a submitter must stay free to
        update weights in place between submits) and never stored directly
        (a read-only **view** aliases its buffer instead — zero-copy; a
        later drift between the caller's bytes and the digest is caught by
        the receiving end's ``verify``)."""
        arr = np.ascontiguousarray(np.asarray(array))
        if verify:
            actual = content_digest(arr)
            if actual != digest:
                raise BlobDigestMismatch(
                    f"blob claimed digest {digest} but its bytes hash to "
                    f"{actual}; refusing the shipment"
                )
        with self._cond:
            existing = self._entries.get(digest)
            if existing is not None:
                self._gone.discard(digest)
                self._entries.move_to_end(digest)
                return existing
        if not arr.flags.owndata:
            # e.g. a decode view: copying frees the whole frame buffer the
            # view would otherwise pin for the blob's store lifetime
            arr = arr.copy()
        elif arr is array:
            # the caller's own object — freeze a private view, not it
            arr = arr.view()
        arr.setflags(write=False)
        with self._cond:
            self._gone.discard(digest)
            if digest in self._entries:
                self._entries.move_to_end(digest)
                return self._entries[digest]
            self._entries[digest] = arr
            self.bytes_stored += arr.nbytes
            self.inserted += 1
            # a single over-budget blob stays (alone); everything else LRUs out
            while self.bytes_stored > self.budget_bytes and len(self._entries) > 1:
                old_digest, old = self._entries.popitem(last=False)
                self.bytes_stored -= old.nbytes
                self.evictions += 1
            self._cond.notify_all()
            return arr

    def mark_gone(self, digest: str) -> None:
        """The coordinator cannot produce this digest (``blob_gone``):
        tombstone it so :meth:`ensure` waiters fail instead of timing out."""
        with self._cond:
            self._gone.add(digest)
            self._cond.notify_all()

    def missing(self, digests: "list[str]") -> "list[str]":
        with self._cond:
            return [d for d in digests if d not in self._entries]

    def ensure(
        self,
        digests: "list[str]",
        request_missing: "Callable[[list[str]], None]",
        timeout: float = 60.0,
    ) -> None:
        """Block until every digest is present **simultaneously**. Missing
        digests are asked for via ``request_missing`` (the worker's
        ``need_blob`` send); arrival of ``put_blob``/``blob_gone`` frames
        wakes the wait. A digest that was present (or even one that just
        arrived) can be LRU-evicted by another ``put`` before the full set
        is satisfied — such digests are **re-requested**, so the wait
        converges whenever the budget can hold the whole set at once
        (needed blobs land MRU; eviction eats the cold tail). Raises
        :class:`BlobError` on a tombstoned digest or timeout."""
        deadline = time.monotonic() + timeout
        requested: "set[str]" = set()  # asked for and not yet arrived
        while True:
            with self._cond:
                gone = [d for d in digests if d in self._gone]
                if gone:
                    # fail *this* wait, but clear the tombstone: blob_gone
                    # is a statement about the coordinator's store at one
                    # moment — a later submit re-pins the blob there, so a
                    # later ensure() must be allowed to re-ask
                    self._gone.difference_update(gone)
                    raise BlobError(
                        f"blob(s) {gone} are gone at the coordinator and "
                        "cannot be re-fetched"
                    )
                still = [d for d in digests if d not in self._entries]
                if not still:
                    return
                # an arrived-then-evicted digest leaves `requested` here,
                # making it re-askable below
                requested &= set(still)
                to_ask = [d for d in still if d not in requested]
                if to_ask:
                    self.misses += len(to_ask)
                    requested.update(to_ask)
                else:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        raise BlobError(
                            f"timed out after {timeout:.0f}s waiting for "
                            f"blob(s) {still}"
                        )
                    self._cond.wait(remaining)
                    continue
            # outside the lock: request_missing sends on the wire, and the
            # reader thread that answers needs the lock to put()
            request_missing(to_ask)

    def stats(self) -> "dict[str, Any]":
        with self._cond:
            return {
                "blobs": len(self._entries),
                "bytes_stored": self.bytes_stored,
                "budget_bytes": self.budget_bytes,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "inserted": self.inserted,
            }
