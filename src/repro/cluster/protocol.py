"""Binary framing v2 for the cluster data plane (DESIGN.md §1h).

One frame = a fixed 13-byte prefix, a per-segment length table, a UTF-8
JSON **envelope**, and zero or more raw **payload segments** appended
verbatim:

    offset  size  field
    ------  ----  -----------------------------------------------
    0       1     protocol version (``PROTOCOL_VERSION`` = 2)
    1       4     u32 segment count
    5       8     u64 envelope length (bytes)
    13      8*n   u64 length of each segment
    ...           envelope (JSON object with a ``"kind"``)
    ...           segments, concatenated C-order buffers

The envelope is the *message*: a dict with a ``"kind"`` discriminator and
plain JSON fields; engine values inside it are pre-encoded with
:mod:`repro.engine.wire`. Tensor payloads do **not** ride the envelope:
in segment mode an array encodes as ``{"__wire__": "ndref", "seg": i,
"dtype", "shape"}`` and its raw buffer becomes segment ``i`` — no base64
(a flat ~33% tax in v1), and ``json.loads`` never parses tensor bytes.
:meth:`Channel.recv` re-attaches each segment to its ndref in place
(:func:`attach_segments`), so ``decode_value`` sees a buffer, not an
index. Content-addressed arrays cross as ``blobref`` envelopes with *no*
segment at all — see :mod:`repro.cluster.blobs`.

**v1 interop is refused, cleanly.** v1 framed with a bare 8-byte length
prefix, so the first byte a v1 peer sends is 0x00 (the high byte of any
sane length); a v2 reader sees version 0 ≠ 2 and raises
:class:`ProtocolError` naming the mismatch instead of misparsing. In the
other direction a v2 frame's leading 0x02 byte makes a v1 reader decode a
huge bogus length and trip its frame cap. Both sides fail fast at the
first frame — a mixed-version cluster cannot half-work.

Message kinds:

======================  =========  ==========================================
kind                    direction  fields
======================  =========  ==========================================
``hello``               w -> c     ``worker_id, pid, token, substrate, slots``
``pong``                w -> c     ``inflight`` (reply to ``ping``)
``result``              w -> c     ``ticket, result, report`` (wire-encoded)
``error``               w -> c     ``ticket, etype, error`` (repr strings)
``stats_reply``         w -> c     ``ticket, stats`` (plain dict)
``log``                 w -> c     ``level, logger, msg`` (forwarded record)
``need_blob``           w -> c     ``digests`` (blobref misses to re-ship)
``ping``                c -> w     (heartbeat; reader answers while busy)
``submit``              c -> w     ``ticket, request`` (``Request.to_wire()``)
``submit_many``         c -> w     ``items`` (coalesced submits, one frame)
``kernel_call``         c -> w     ``ticket, op, args, kwargs`` (wire-encoded)
``put_blob``            c -> w     ``digest, blob`` (+ one raw segment)
``blob_gone``           c -> w     ``digest`` (a need_blob that cannot be met)
``stats``               c -> w     ``ticket``
``shutdown``            c -> w     (drain and exit)
======================  =========  ==========================================
"""
from __future__ import annotations

import json
import os
import socket
import struct
import threading
from typing import Any, Iterable

PROTOCOL_VERSION = 2

_PREFIX = struct.Struct(">BIQ")  # version, segment count, envelope length
_SEGLEN = struct.Struct(">Q")

#: frame-size guard default: 1 GiB. Large enough for any real request or
#: blob shipment, small enough that a corrupt header cannot trigger a
#: giant allocation. Override with ``REPRO_MAX_FRAME_BYTES``.
DEFAULT_MAX_FRAME_BYTES = 1 << 30
#: segment-count sanity cap (a frame with more segments than this is junk)
MAX_FRAME_SEGMENTS = 1 << 16


def max_frame_bytes() -> int:
    """The active frame-size cap: ``REPRO_MAX_FRAME_BYTES`` or 1 GiB."""
    raw = os.environ.get("REPRO_MAX_FRAME_BYTES")
    if not raw:
        return DEFAULT_MAX_FRAME_BYTES
    try:
        return max(1, int(raw))
    except ValueError:
        return DEFAULT_MAX_FRAME_BYTES


class ProtocolError(RuntimeError):
    """A malformed frame (oversized, truncated, wrong version, or not a
    JSON message object)."""


class FrameTooLarge(ProtocolError):
    """A legitimate frame exceeded the configured cap. The message names
    the knob so the fix is one environment variable away."""

    def __init__(self, nbytes: int, cap: int):
        super().__init__(
            f"frame of {nbytes} bytes exceeds the {cap}-byte cap; raise "
            "REPRO_MAX_FRAME_BYTES if this payload is legitimate"
        )
        self.nbytes = nbytes
        self.cap = cap


def _recv_exact(
    sock: socket.socket, n: int, *, at_boundary: bool = False
) -> "bytes | None":
    """Read exactly ``n`` bytes. A clean EOF (zero bytes read) at a frame
    boundary returns None — the peer closed between frames. *Anything*
    else that cuts the read short — EOF after partial bytes, EOF mid-frame
    (``at_boundary=False``), or an ``OSError`` under the read — raises
    :class:`ProtocolError`: a torn frame must never masquerade as a
    graceful disconnect (failover treats them very differently)."""
    chunks: "list[bytes]" = []
    got = 0
    while got < n:
        try:
            chunk = sock.recv(min(n - got, 1 << 20))
        except OSError as exc:
            if got == 0 and at_boundary:
                return None  # peer reset between frames == EOF
            raise ProtocolError(
                f"truncated frame: socket error after {got} of {n} bytes "
                f"({exc})"
            ) from exc
        if not chunk:
            if got == 0 and at_boundary:
                return None
            raise ProtocolError(f"truncated frame: got {got} of {n} bytes")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def attach_segments(message: Any, segments: "list[bytes]") -> None:
    """Attach each raw segment to its ``ndref`` envelope node (in place,
    under ``"data"``) so :func:`repro.engine.wire.decode_value` reads the
    buffer directly — the decode path never sees a segment index."""
    if isinstance(message, dict):
        if message.get("__wire__") == "ndref" and "seg" in message:
            idx = message["seg"]
            if not isinstance(idx, int) or not 0 <= idx < len(segments):
                raise ProtocolError(
                    f"ndref segment index {idx!r} outside the frame's "
                    f"{len(segments)} segment(s)"
                )
            message["data"] = segments[idx]
            return
        for value in message.values():
            attach_segments(value, segments)
    elif isinstance(message, list):
        for value in message:
            attach_segments(value, segments)


class Channel:
    """A message channel over one connected socket.

    ``send`` is serialized by an internal lock (any thread may reply);
    ``recv`` is single-reader by convention (each side runs one reader
    thread). ``recv`` returns ``None`` on EOF — the peer is gone.

    Wire-traffic counters (``bytes_sent``/``bytes_received``/
    ``frames_sent``/``frames_received``) count everything including frame
    overhead; they feed the per-worker observability rows (§1h).
    """

    def __init__(self, sock: socket.socket):
        self._sock = sock
        self._send_lock = threading.Lock()
        self._closed = False
        self.bytes_sent = 0
        self.bytes_received = 0
        self.frames_sent = 0
        self.frames_received = 0

    def send(
        self, message: "dict[str, Any]", segments: "Iterable[Any]" = ()
    ) -> None:
        """Frame and send one message. ``segments`` are raw bytes-like
        payload buffers (what a :class:`~repro.engine.wire.SegmentTable`
        collected); they are written verbatim after the envelope — large
        tensors never pass through ``json.dumps`` or base64."""
        envelope = json.dumps(message, separators=(",", ":")).encode("utf-8")
        segs = list(segments)
        total = len(envelope) + sum(len(s) for s in segs)
        cap = max_frame_bytes()
        if total > cap:
            raise FrameTooLarge(total, cap)
        header = _PREFIX.pack(PROTOCOL_VERSION, len(segs), len(envelope))
        if segs:
            header += b"".join(_SEGLEN.pack(len(s)) for s in segs)
        with self._send_lock:
            # header + envelope in one write (small); big segments
            # straight from their buffers — no joining copy
            self._sock.sendall(header + envelope)
            for seg in segs:
                self._sock.sendall(seg)
            self.bytes_sent += len(header) + total
            self.frames_sent += 1

    def recv(self) -> "dict[str, Any] | None":
        prefix = _recv_exact(self._sock, _PREFIX.size, at_boundary=True)
        if prefix is None:
            return None
        version, n_segments, envelope_len = _PREFIX.unpack(prefix)
        if version != PROTOCOL_VERSION:
            raise ProtocolError(
                f"wire protocol version mismatch: peer sent v{version}, "
                f"this side speaks v{PROTOCOL_VERSION} (v1 JSON-frame peers "
                "must be upgraded — mixed-version clusters are refused)"
            )
        if n_segments > MAX_FRAME_SEGMENTS:
            raise ProtocolError(
                f"frame claims {n_segments} segments (cap {MAX_FRAME_SEGMENTS})"
            )
        received = _PREFIX.size
        seg_lens: "list[int]" = []
        if n_segments:
            raw = _recv_exact(self._sock, n_segments * _SEGLEN.size)
            received += len(raw)
            seg_lens = [
                _SEGLEN.unpack_from(raw, i * _SEGLEN.size)[0]
                for i in range(n_segments)
            ]
        total = envelope_len + sum(seg_lens)
        cap = max_frame_bytes()
        if total > cap:
            raise FrameTooLarge(total, cap)
        envelope = _recv_exact(self._sock, envelope_len)
        segments = [_recv_exact(self._sock, n) for n in seg_lens]
        received += total
        message = json.loads(envelope.decode("utf-8"))
        if not isinstance(message, dict) or "kind" not in message:
            raise ProtocolError("frame is not a message object with a 'kind'")
        if segments:
            attach_segments(message, segments)
        self.bytes_received += received
        self.frames_received += 1
        return message

    def wire_stats(self) -> "dict[str, int]":
        return {
            "bytes_sent": self.bytes_sent,
            "bytes_received": self.bytes_received,
            "frames_sent": self.frames_sent,
            "frames_received": self.frames_received,
        }

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass
