"""Length-prefixed JSON framing for the cluster control plane (DESIGN.md §1h).

One frame = an 8-byte big-endian length header + a UTF-8 JSON object. The
object is a *message*: a dict with a ``"kind"`` discriminator and plain
JSON fields; any field that carries engine values (request payloads, kernel
arguments, results, reports) is pre-encoded with
:mod:`repro.engine.wire` so arrays cross dtype/shape-exact. Keeping the
envelope plain JSON means a frame is greppable on the wire and the codec
for *values* lives in exactly one place.

Message kinds:

======================  =========  ==========================================
kind                    direction  fields
======================  =========  ==========================================
``hello``               w -> c     ``worker_id, pid, token, substrate, slots``
``pong``                w -> c     ``inflight`` (reply to ``ping``)
``result``              w -> c     ``ticket, result, report`` (wire-encoded)
``error``               w -> c     ``ticket, etype, error`` (repr strings)
``stats_reply``         w -> c     ``ticket, stats`` (plain dict)
``log``                 w -> c     ``level, logger, msg`` (forwarded record)
``ping``                c -> w     (heartbeat; reader answers while busy)
``submit``              c -> w     ``ticket, request`` (``Request.to_wire()``)
``kernel_call``         c -> w     ``ticket, op, args, kwargs`` (wire-encoded)
``stats``               c -> w     ``ticket``
``shutdown``            c -> w     (drain and exit)
======================  =========  ==========================================
"""
from __future__ import annotations

import json
import socket
import struct
import threading
from typing import Any

_HEADER = struct.Struct(">Q")
#: hard frame-size guard: a corrupt header must not trigger a giant alloc
MAX_FRAME_BYTES = 1 << 33


class ProtocolError(RuntimeError):
    """A malformed frame (oversized, truncated, or not a JSON object)."""


def _recv_exact(sock: socket.socket, n: int) -> "bytes | None":
    """Read exactly ``n`` bytes; None on a clean EOF at a frame boundary."""
    chunks: list[bytes] = []
    got = 0
    while got < n:
        try:
            chunk = sock.recv(min(n - got, 1 << 20))
        except OSError:
            return None  # peer reset / socket closed under us == EOF
        if not chunk:
            if got:
                raise ProtocolError(f"truncated frame: got {got} of {n} bytes")
            return None
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


class Channel:
    """A message channel over one connected socket.

    ``send`` is serialized by an internal lock (any thread may reply);
    ``recv`` is single-reader by convention (each side runs one reader
    thread). ``recv`` returns ``None`` on EOF — the peer is gone.
    """

    def __init__(self, sock: socket.socket):
        self._sock = sock
        self._send_lock = threading.Lock()
        self._closed = False

    def send(self, message: "dict[str, Any]") -> None:
        data = json.dumps(message, separators=(",", ":")).encode("utf-8")
        if len(data) > MAX_FRAME_BYTES:
            raise ProtocolError(f"frame of {len(data)} bytes exceeds the cap")
        with self._send_lock:
            self._sock.sendall(_HEADER.pack(len(data)) + data)

    def recv(self) -> "dict[str, Any] | None":
        header = _recv_exact(self._sock, _HEADER.size)
        if header is None:
            return None
        (length,) = _HEADER.unpack(header)
        if length > MAX_FRAME_BYTES:
            raise ProtocolError(f"frame of {length} bytes exceeds the cap")
        body = _recv_exact(self._sock, length)
        if body is None:
            return None
        message = json.loads(body.decode("utf-8"))
        if not isinstance(message, dict) or "kind" not in message:
            raise ProtocolError("frame is not a message object with a 'kind'")
        return message

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass
