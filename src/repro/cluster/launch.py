"""Cluster launcher: stand the worker pool up as processes (§1h).

``launch_cluster(n_workers)`` is the one-call path the CLI
(``launch/serve.py --cluster N``), the benchmark suite, and the tests
share: start a coordinator, spawn N localhost worker subprocesses through
a :class:`LaunchBackend`, wait for them to join, install the coordinator
as the active cluster (so ``substrate="cluster"`` resolves), and hand
back a :class:`Cluster` that cleans all of it up.

Backends are pluggable behind three methods (``start/alive/stop``):

- :class:`LocalProcessBackend` — ``subprocess.Popen`` on this host, with
  ``PYTHONPATH`` pointed at this checkout and the cluster auth token in
  the environment. What CI and the tests use.
- :class:`K8sBackend` — the deployment seam: :meth:`K8sBackend.pod_spec`
  emits the pod manifest a real scheduler would apply (same worker argv,
  token via env, coordinator address as the dial target); ``start``
  raises ``NotImplementedError`` until one is wired in. It exists so the
  worker contract (dial back, hello, heartbeat) is demonstrably
  scheduler-shaped, not subprocess-shaped.

Process exits are watched by the training plane's
:class:`~repro.runtime.supervisor.ProcessSupervisor` — ``restarts > 0``
respawns a crashed worker, which re-dials the coordinator and rejoins the
pool (membership generation bumps; plans re-fingerprint). The default is
0: request-level failover already guarantees liveness, so restarts are an
availability knob, not a correctness one.
"""
from __future__ import annotations

import dataclasses
import os
import subprocess
import sys
import threading
import time
from typing import Any

from ..runtime.supervisor import ProcessSupervisor
from .coordinator import ClusterError, Coordinator
from .substrate import activate_cluster, deactivate_cluster


@dataclasses.dataclass
class WorkerSpec:
    """Everything a backend needs to start one worker."""

    worker_id: int
    connect: "tuple[str, int]"  # coordinator (host, port) to dial
    substrate: str = "local"
    service_workers: int = 2
    token: str = ""

    def argv(self) -> "list[str]":
        return [
            sys.executable, "-m", "repro.cluster.worker",
            "--connect", f"{self.connect[0]}:{self.connect[1]}",
            "--worker-id", str(self.worker_id),
            "--substrate", self.substrate,
            "--service-workers", str(self.service_workers),
        ]


class LaunchBackend:
    """Where worker processes run. Implementations provide start/alive/stop."""

    def start(self, spec: WorkerSpec) -> Any:
        raise NotImplementedError

    def alive(self, handle: Any) -> bool:
        raise NotImplementedError

    def stop(self, handle: Any) -> None:
        raise NotImplementedError


class LocalProcessBackend(LaunchBackend):
    """Workers as localhost subprocesses of this interpreter."""

    def start(self, spec: WorkerSpec) -> subprocess.Popen:
        import repro

        src = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
        env = dict(os.environ)
        existing = env.get("PYTHONPATH")
        env["PYTHONPATH"] = src + (os.pathsep + existing if existing else "")
        env["REPRO_CLUSTER_TOKEN"] = spec.token
        return subprocess.Popen(spec.argv(), env=env)

    def alive(self, handle: subprocess.Popen) -> bool:
        return handle.poll() is None

    def stop(self, handle: subprocess.Popen) -> None:
        if handle.poll() is None:
            handle.terminate()
            try:
                handle.wait(timeout=10)
            except subprocess.TimeoutExpired:
                handle.kill()
                handle.wait(timeout=10)


class K8sBackend(LaunchBackend):
    """Pod-spec emitter stub: the shape a real scheduler slots into."""

    def __init__(self, image: str = "repro-serving:latest", namespace: str = "repro"):
        self.image = image
        self.namespace = namespace

    def pod_spec(self, spec: WorkerSpec) -> "dict[str, Any]":
        """The manifest ``kubectl apply`` would take for this worker."""
        return {
            "apiVersion": "v1",
            "kind": "Pod",
            "metadata": {
                "name": f"repro-worker-{spec.worker_id}",
                "namespace": self.namespace,
                "labels": {"app": "repro-cluster", "role": "worker"},
            },
            "spec": {
                "restartPolicy": "OnFailure",
                "containers": [{
                    "name": "worker",
                    "image": self.image,
                    "command": spec.argv(),
                    "env": [
                        {"name": "REPRO_CLUSTER_TOKEN", "value": spec.token},
                    ],
                }],
            },
        }

    def start(self, spec: WorkerSpec) -> Any:
        raise NotImplementedError(
            "K8sBackend emits pod specs (pod_spec()) but does not schedule; "
            "wire it to a cluster API or use LocalProcessBackend"
        )

    def alive(self, handle: Any) -> bool:  # pragma: no cover - stub
        raise NotImplementedError

    def stop(self, handle: Any) -> None:  # pragma: no cover - stub
        raise NotImplementedError


class Cluster:
    """A running cluster: coordinator + supervised worker processes."""

    def __init__(
        self,
        coordinator: Coordinator,
        backend: LaunchBackend,
        specs: "list[WorkerSpec]",
        supervisor: ProcessSupervisor,
        poll_interval: float = 0.5,
    ):
        self.coordinator = coordinator
        self.backend = backend
        self.specs = {spec.worker_id: spec for spec in specs}
        self.supervisor = supervisor
        self._stopping = False
        self._poller = threading.Thread(
            target=self._poll_loop, args=(poll_interval,),
            name="cluster-supervise", daemon=True,
        )
        self._poller.start()

    def _poll_loop(self, interval: float) -> None:
        while not self._stopping:
            time.sleep(interval)
            self.supervisor.poll()

    def worker_pid(self, worker_id: int) -> "int | None":
        handle = self.supervisor.handles().get(f"worker-{worker_id}")
        return getattr(handle, "pid", None)

    def kill_worker(self, worker_id: int, sig: "int | None" = None) -> None:
        """Hard-kill one worker process (failover tests / demos).
        ``sig=None`` uses SIGKILL."""
        import signal

        pid = self.worker_pid(worker_id)
        if pid is None:
            raise ClusterError(f"no process handle for worker {worker_id}")
        os.kill(pid, signal.SIGKILL if sig is None else sig)

    def submit(self, request: Any):
        return self.coordinator.submit(request)

    def stats(self) -> "dict[str, Any]":
        return self.coordinator.stats()

    def shutdown(self) -> None:
        if self._stopping:
            return
        self._stopping = True
        deactivate_cluster(self.coordinator)
        self.coordinator.shutdown()
        for handle in self.supervisor.handles().values():
            try:
                self.backend.stop(handle)
            except Exception:
                pass

    def __enter__(self) -> "Cluster":
        return self

    def __exit__(self, *exc_info: Any) -> None:
        self.shutdown()


def launch_cluster(
    n_workers: int = 2,
    *,
    substrate: str = "local",
    service_workers: int = 2,
    backend: "LaunchBackend | None" = None,
    heartbeat_interval: float = 0.5,
    heartbeat_timeout: float = 5.0,
    max_inflight: int = 512,
    restarts: int = 0,
    wait_timeout: float = 180.0,
    activate: bool = True,
    flush_window: float = 0.002,
    blob_min_bytes: "int | None" = None,
) -> Cluster:
    """Stand up a localhost cluster and return its :class:`Cluster` handle.

    ``activate=True`` (default) installs the coordinator as the process's
    active cluster so ``substrate="cluster"`` resolves everywhere.
    ``flush_window`` is the submit-coalescing window; ``blob_min_bytes``
    the content-addressing threshold (None = ``REPRO_BLOB_MIN_BYTES`` or
    its 64 KiB default). Workers read ``REPRO_BLOB_BUDGET_BYTES`` from
    their (inherited) environment for the blob-store byte budget.
    """
    if n_workers < 1:
        raise ValueError(f"n_workers must be >= 1, got {n_workers}")
    coordinator = Coordinator(
        heartbeat_interval=heartbeat_interval,
        heartbeat_timeout=heartbeat_timeout,
        max_inflight=max_inflight,
        flush_window=flush_window,
        blob_min_bytes=blob_min_bytes,
    )
    host, port = coordinator.listen()
    backend = backend if backend is not None else LocalProcessBackend()
    supervisor = ProcessSupervisor(max_restarts=restarts)
    specs = [
        WorkerSpec(
            worker_id=k,
            connect=(host, port),
            substrate=substrate,
            service_workers=service_workers,
            token=coordinator.token,
        )
        for k in range(n_workers)
    ]
    started: list = []
    try:
        for spec in specs:
            handle = backend.start(spec)
            started.append(handle)
            supervisor.watch(
                f"worker-{spec.worker_id}",
                handle,
                alive=backend.alive,
                restart=(lambda s=spec: backend.start(s)) if restarts else None,
            )
        coordinator.wait_ready(n_workers, timeout=wait_timeout)
    except Exception:
        coordinator.shutdown()
        for handle in started:
            try:
                backend.stop(handle)
            except Exception:
                pass
        raise
    if activate:
        activate_cluster(coordinator)
    return Cluster(coordinator, backend, specs, supervisor)
