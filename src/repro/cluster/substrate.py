"""`ClusterSubstrate`: worker processes as placement slots (§1h).

Registered as ``"cluster"`` via the ordinary
:func:`~repro.engine.substrate.register_substrate` hook, so the whole
PR-5 serving plane — plan-cache pinning, placement variants, QoS —
carries over *unchanged* at the process level:

- :meth:`placement_slots` spans the live worker processes, so
  ``EngineService(substrate="cluster", workers="auto")`` sizes its pool to
  the cluster;
- :meth:`placement_variant` pins pool slot *k* to one worker process
  (``worker_pin``), and :meth:`cache_fingerprint` embeds both the pin and
  the coordinator's topology fingerprint — a plan compiled against one
  membership generation never serves another (exactly how mesh device
  windows behave, one level up);
- :meth:`kernel` returns a **forwarder**: the kernel call (args + kwargs,
  wire-encoded) executes on the pinned worker, which runs the real kernel
  from its own registry against its own substrate. Capability is the
  *remote* kind's registry — the cluster supports what its workers
  support. Forwarded arguments ride the protocol-v2 data plane: raw frame
  segments for small arrays, content-addressed blobrefs for large ones —
  a repeatedly-forwarded adjacency structure crosses the wire once per
  worker, not once per call (the Emu move-the-context discipline, applied
  to the forwarder).

``placement_policy = "affinity"`` (the warm executable lives in one
process) and ``jit_plans = False`` (the forwarder does socket I/O;
tracing it into ``jax.jit`` would bake one reply in as a constant — the
planner keeps cluster plans eager; the *worker* side does the jitting).

The registry factory takes no arguments, so ``get_substrate("cluster")``
resolves through the **active cluster**: the coordinator installed by
:func:`activate_cluster` (done by ``launch_cluster``). Without one, a
clear error tells you to launch first.
"""
from __future__ import annotations

import threading
from typing import Any, Callable

from ..engine.api import OpNotSupportedError
from ..engine.registry import default_registry
from ..engine.substrate import Substrate, register_substrate
from .coordinator import ClusterError, Coordinator

_ACTIVE_LOCK = threading.Lock()
_ACTIVE: "Coordinator | None" = None


def activate_cluster(coordinator: Coordinator) -> None:
    """Install ``coordinator`` as what ``get_substrate("cluster")`` binds to."""
    global _ACTIVE
    with _ACTIVE_LOCK:
        _ACTIVE = coordinator


def deactivate_cluster(coordinator: "Coordinator | None" = None) -> None:
    """Uninstall the active cluster (no-op if ``coordinator`` is stale)."""
    global _ACTIVE
    with _ACTIVE_LOCK:
        if coordinator is None or _ACTIVE is coordinator:
            _ACTIVE = None


def active_cluster() -> Coordinator:
    with _ACTIVE_LOCK:
        if _ACTIVE is None:
            raise ClusterError(
                "no active cluster — launch one first "
                "(repro.cluster.launch_cluster(n_workers=...) or "
                "launch/serve.py --cluster N)"
            )
        return _ACTIVE


class ClusterSubstrate(Substrate):
    """Executes kernels on the cluster's worker processes."""

    name = "cluster"
    placement_policy = "affinity"
    jit_plans = False

    def __init__(
        self,
        coordinator: "Coordinator | None" = None,
        worker_pin: "int | None" = None,
    ):
        self._coordinator = coordinator
        self.worker_pin = worker_pin

    @property
    def coordinator(self) -> Coordinator:
        return self._coordinator if self._coordinator is not None else active_cluster()

    def remote_kind(self) -> str:
        """The kernel-registry kind calls resolve under *on the worker* —
        the workers' substrate name (homogeneous pools; the launcher
        enforces one substrate per cluster). Falls back to ``"local"``
        (the default worker substrate) when no cluster is active, so the
        capability/placement tables stay readable after a mere import —
        only *executing* a kernel requires a live coordinator."""
        try:
            workers = self.coordinator.healthy_workers()
        except ClusterError:
            return "local"
        return workers[0].substrate if workers else "local"

    @property
    def substrate_kind(self) -> str:
        # the cluster supports what its workers support: capability rows
        # and drift checks must agree with kernel()'s resolution
        return self.remote_kind()

    def supports(self, op_name: str) -> bool:
        return default_registry().has_kernel(op_name, self.remote_kind())

    def kernel(self, op_name: str) -> Callable:
        if not self.supports(op_name):
            raise OpNotSupportedError(
                f"op {op_name!r} has no kernel for the cluster's remote "
                f"kind {self.remote_kind()!r}"
            )
        pin = self.worker_pin

        def forward(*args: Any, **kwargs: Any) -> Any:
            # resolved per call, not at plan time: a plan may outlive a
            # coordinator, and an inactive cluster should fail with the
            # launch hint only when work actually needs a worker
            return self.coordinator.kernel_call(
                op_name, args, kwargs, worker_pin=pin
            )

        return forward

    def placement_slots(self) -> int:
        try:
            return max(1, len(self.coordinator.healthy_workers()))
        except ClusterError:
            return 1

    def placement_variant(self, slot: int, n_slots: int) -> "ClusterSubstrate":
        try:
            workers = sorted(
                w.worker_id for w in self.coordinator.healthy_workers()
            )
        except ClusterError:
            return self
        if not workers:
            return self
        return ClusterSubstrate(
            self._coordinator, worker_pin=workers[slot % len(workers)]
        )

    def cache_fingerprint(self) -> tuple:
        return (
            self.name,
            self.coordinator.topology_fingerprint(),
            self.worker_pin,
        )


register_substrate("cluster", ClusterSubstrate)
