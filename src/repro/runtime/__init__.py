from .elastic import ElasticPlan, make_elastic_mesh, plan_remesh
from .supervisor import Failure, RunResult, SupervisorConfig, run_supervised, straggler_report
