"""Elastic re-meshing: rebuild programs when the healthy device set shrinks.

Policy: the "model" axis is sacred (TP state layout); shrink the "data" axis
to the largest power-of-two that the survivors support, re-shard params via
host round-trip (restore path), and keep the GLOBAL batch constant by raising
per-device batch (preferred) or microbatching. The deterministic pipeline
makes the data stream independent of the mesh shape.
"""
from __future__ import annotations

import dataclasses

import jax


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    data_axis: int
    model_axis: int
    per_device_batch_factor: float  # vs the healthy-mesh configuration
    microbatches: int


def plan_remesh(
    n_healthy: int, model_axis: int, global_batch: int, prev_data_axis: int,
    hbm_headroom_frac: float = 0.8,
) -> ElasticPlan:
    """Choose the new mesh for ``n_healthy`` devices (model axis preserved)."""
    if n_healthy < model_axis:
        raise ValueError(
            f"cannot preserve model axis {model_axis} with {n_healthy} devices"
        )
    data = 1
    while data * 2 * model_axis <= n_healthy:
        data *= 2
    # keep global batch: per-device batch grows by prev/new
    factor = prev_data_axis / data
    # if activations no longer fit, fall back to gradient accumulation
    micro = 1
    while factor / micro > 1.0 / hbm_headroom_frac:
        micro *= 2
    return ElasticPlan(
        data_axis=data, model_axis=model_axis,
        per_device_batch_factor=factor, microbatches=micro,
    )


def make_elastic_mesh(plan: ElasticPlan) -> jax.sharding.Mesh:
    from ..compat import make_mesh

    return make_mesh((plan.data_axis, plan.model_axis), ("data", "model"))
