"""Fault-tolerant training supervisor: checkpoint/restart, simulated failure
injection, elastic re-meshing, straggler accounting.

At 1000+ nodes, MTBF is minutes-to-hours; the supervisor owns the loop:

  run -> [failure] -> restore latest checkpoint -> rebuild programs on the
  (possibly smaller) healthy mesh -> replay the deterministic data stream
  from the restored step -> continue.

The CPU container simulates failures by raising at a chosen step; elasticity
is exercised by rebuilding on a mesh with fewer "data" rows (the index-based
pipeline keeps the global batch identical, re-sharded over survivors).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable

import jax

from ..checkpoint import store

log = logging.getLogger("repro.supervisor")


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    max_restarts: int = 3
    total_steps: int = 200


@dataclasses.dataclass
class RunResult:
    final_step: int
    restarts: int
    losses: list
    step_times: list  # per-step wall time (straggler accounting)


class Failure(RuntimeError):
    """Injected node failure."""


def run_supervised(
    cfg: SupervisorConfig,
    *,
    build: Callable[[], tuple[Any, Any, Callable]],
    data_for_step: Callable[[int], dict],
    fail_at: int | None = None,
) -> RunResult:
    """Run the training loop under supervision.

    ``build()`` -> (params, opt_state, step_fn); called fresh after every
    restart (in production this re-acquires the healthy mesh).
    ``fail_at``: inject a Failure the first time that step is reached.
    """
    restarts = 0
    losses: list[float] = []
    times: list[float] = []
    failed_once = False
    while True:
        params, opt_state, step_fn = build()
        start = store.latest_step(cfg.ckpt_dir)
        step = 0
        if start is not None:
            params, opt_state = store.restore(
                cfg.ckpt_dir, start, (params, opt_state)
            )
            step = start + 1
            log.info("restored checkpoint step=%d", start)
        ckpt = store.AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep)
        try:
            while step < cfg.total_steps:
                if fail_at is not None and step == fail_at and not failed_once:
                    failed_once = True
                    raise Failure(f"injected failure at step {step}")
                t0 = time.perf_counter()
                batch = data_for_step(step)
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                times.append(time.perf_counter() - t0)
                losses.append(float(metrics["loss"]))
                if step % cfg.ckpt_every == 0 and step > 0:
                    ckpt.save(step, (params, opt_state))
                step += 1
            ckpt.save(cfg.total_steps - 1, (params, opt_state))
            ckpt.wait()
            return RunResult(
                final_step=step - 1, restarts=restarts, losses=losses,
                step_times=times,
            )
        except Failure as e:
            restarts += 1
            log.warning("failure: %s (restart %d)", e, restarts)
            ckpt.wait()
            if restarts > cfg.max_restarts:
                raise
        except Exception:
            ckpt.wait()
            raise


@dataclasses.dataclass
class ProcessEvent:
    """One supervision observation: a watched process exited."""

    name: str
    returncode: "int | None"
    restarted: bool
    restarts: int


class ProcessSupervisor:
    """The restart half of the supervisor, generalized to OS processes.

    :func:`run_supervised` supervises a training loop in-process; the
    cluster launcher (``repro.cluster.launch``) needs the same policy —
    bounded restarts, audible exits — over worker *subprocesses*. The
    supervisor stays transport-agnostic: ``watch()`` takes the process
    handle plus ``alive``/``restart`` callables (the launch backend's),
    and :meth:`poll` reports exits as :class:`ProcessEvent`\\ s, invoking
    ``restart`` while the per-process budget (``max_restarts``) lasts.
    ``max_restarts=0`` is pure exit detection — the cluster coordinator's
    failover handles the work; the supervisor handles the *process*.
    """

    def __init__(self, max_restarts: int = 0):
        self.max_restarts = max_restarts
        self._watched: dict[str, dict] = {}

    def watch(
        self,
        name: str,
        handle: Any,
        *,
        alive: Callable[[Any], bool],
        restart: "Callable[[], Any] | None" = None,
    ) -> None:
        self._watched[name] = {
            "handle": handle, "alive": alive, "restart": restart,
            "restarts": 0, "down": False,
        }

    def handles(self) -> "dict[str, Any]":
        return {name: w["handle"] for name, w in self._watched.items()}

    def poll(self) -> "list[ProcessEvent]":
        """Check every watched process once; restart the dead within
        budget. Idempotent on processes already seen down."""
        events: list[ProcessEvent] = []
        for name, w in self._watched.items():
            if w["down"] or w["alive"](w["handle"]):
                continue
            returncode = getattr(w["handle"], "returncode", None)
            can_restart = (
                w["restart"] is not None and w["restarts"] < self.max_restarts
            )
            if can_restart:
                w["restarts"] += 1
                w["handle"] = w["restart"]()
                log.warning(
                    "process %s exited (rc=%s); restarted (%d/%d)",
                    name, returncode, w["restarts"], self.max_restarts,
                )
            else:
                w["down"] = True
                log.warning(
                    "process %s exited (rc=%s); restart budget exhausted",
                    name, returncode,
                )
            events.append(
                ProcessEvent(name, returncode, can_restart, w["restarts"])
            )
        return events


def straggler_report(step_times: list, threshold: float = 1.5) -> dict:
    """Flag steps slower than threshold x median — the metric a straggler
    mitigation (re-balance/evict) loop watches."""
    if not step_times:
        return {"median": 0.0, "stragglers": 0, "worst_ratio": 0.0}
    s = sorted(step_times)
    med = s[len(s) // 2]
    worst = max(step_times) / max(med, 1e-9)
    count = sum(1 for t in step_times if t > threshold * med)
    return {"median": med, "stragglers": count, "worst_ratio": worst}
