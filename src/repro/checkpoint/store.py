"""Sharded, atomic, async checkpointing with keep-k retention.

Layout: <dir>/step_<N>/  with one .npz per pytree leaf-group and a manifest
(tree structure + shapes + dtypes). Writes go to step_<N>.tmp and are
atomically renamed after fsync — a crashed save can never shadow a good one.
``AsyncCheckpointer`` overlaps serialization with the next training steps
(device->host copy happens at save() call; disk IO on the thread).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> tuple[list[tuple[str, np.ndarray]], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    keyed = [(f"leaf{i}", np.asarray(x)) for i, x in enumerate(leaves)]
    return keyed, treedef


def save(directory: str | Path, step: int, tree: Any, keep: int = 3) -> Path:
    """Synchronous atomic save of a pytree at ``step``."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f"step_{step}.tmp"
    final = directory / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    keyed, treedef = _flatten(tree)
    arrays = {k: v for k, v in keyed}
    np.savez(tmp / "leaves.npz", **arrays)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "leaves": [
            {"key": k, "shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in keyed
        ],
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    # fsync the directory entries then atomically publish
    fd = os.open(tmp, os.O_RDONLY)
    os.fsync(fd)
    os.close(fd)
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    _retain(directory, keep)
    return final


def _retain(directory: Path, keep: int) -> None:
    steps = sorted(
        (int(p.name.split("_")[1]), p)
        for p in directory.glob("step_*")
        if not p.name.endswith(".tmp")
    )
    for _, p in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory: str | Path) -> int | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in directory.glob("step_*")
        if not p.name.endswith(".tmp") and (p / "manifest.json").exists()
    ]
    return max(steps) if steps else None


def restore(directory: str | Path, step: int, like: Any) -> Any:
    """Restore into the structure (and shardings, if any) of ``like``."""
    directory = Path(directory) / f"step_{step}"
    data = np.load(directory / "leaves.npz")
    leaves_like, treedef = jax.tree.flatten(like)
    out = []
    for i, ref in enumerate(leaves_like):
        arr = data[f"leaf{i}"]
        if hasattr(ref, "sharding") and ref.sharding is not None:
            out.append(jax.device_put(arr.astype(ref.dtype), ref.sharding))
        else:
            out.append(jax.numpy.asarray(arr, dtype=getattr(ref, "dtype", None)))
    return jax.tree.unflatten(treedef, out)


class AsyncCheckpointer:
    """Overlap checkpoint IO with training. One in-flight save at a time
    (back-pressure if the previous save has not finished)."""

    def __init__(self, directory: str | Path, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.saved_steps: list[int] = []

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # D2H now, IO later

        def _run():
            save(self.directory, step, host_tree, keep=self.keep)
            self.saved_steps.append(step)

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
