"""One MoE decode step as an engine op — the serving tentpole (DESIGN.md §1g).

``moe_decode`` runs a compact one-block MoE LM decode step for a
continuous batch of sequences: embed the current token of every batch
slot, one single-head attention sublayer over each slot's KV cache (each
slot carries its own ``positions`` write cursor, so sequences at different
depths share one step), then the MoE sublayer *through the engine's
``moe_dispatch`` machinery* — routing, capacity binning, the S2
collectives, and the real SwiGLU expert FFN (models/moe.py weights) — and
the lm_head. Everything outside the dispatch runs through the SAME two
compiled executables (``_decode_pre``/``_decode_post``) in the local and
mesh kernels; the dispatch is the shared per-shard helper stack of
engine/moe_op.py, so served decode is bit-identical to the single-process
:func:`moe_decode_reference` oracle in all three dispatch modes
(ep_push / ep_pull / tp) by construction.

Params come from :func:`repro.models.transformer.moe_decode_params`,
parameterized by a :class:`~repro.models.config.ModelConfig` (the
``serve-moe`` entry in configs/). The op returns
``(logits (B, V), new_k (B, S, D), new_v (B, S, D))`` — the caller (the
serving plane's :class:`~repro.engine.decode.DecodeServer`) threads the
caches back in on the next submit, which is exactly the "per-sequence KV
state carried across submits" contract continuous batching needs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core.cost import CostEstimate
from ..core.strategies import MigratoryStrategy, TrafficStats
from ..models.layers import rmsnorm
from ..models.moe import dispatch_from_strategy
from .api import ExecutionPlan, OpNotSupportedError, plan_key
from .moe_op import _dispatch_local, _dispatch_mesh, moe_dispatch_grid
from .registry import OpSpec, kernel, register_op
from .substrate import Substrate

_PARAM_KEYS = (
    "embed", "ln1", "ln2", "ln_f", "wq", "wk", "wv", "wo",
    "router", "w_gate", "w_up", "w_down", "lm_head",
)


@dataclasses.dataclass(frozen=True)
class MoEDecodeInputs:
    """One continuous-batched decode step. ``tokens``/``positions`` are
    (B,) int32 — the current token and KV write cursor of every batch slot
    (padded slots just decode garbage that the server ignores; they must be
    deterministic so the oracle replay stays bit-identical). ``k_cache``/
    ``v_cache`` are (B, S, D). ``nodelets`` is the expert-parallel width
    the dispatch maps onto; B must divide by it."""

    params: dict
    tokens: jax.Array
    k_cache: jax.Array
    v_cache: jax.Array
    positions: jax.Array
    nodelets: int = 1
    experts_per_token: int = 2
    capacity_factor: float = 1.25
    norm_eps: float = 1e-5

    @property
    def num_experts(self) -> int:
        return int(self.params["router"].shape[-1])


def derive_decode_mode(inputs: MoEDecodeInputs, strategy: MigratoryStrategy) -> str:
    """Same strategy -> dispatch-mode mapping as ``moe_dispatch``."""
    return dispatch_from_strategy(
        strategy, num_experts=inputs.num_experts, data_axis=inputs.nodelets
    )


# -- the decode math (dispatch-agnostic) ---------------------------------------
#
# Split into two jitted halves around the dispatch. Both kernels call the
# SAME compiled executables for everything outside the dispatch —
# bit-identity demands the same executable, not merely the same math: XLA
# is free to fuse and reassociate float reductions differently in each
# compile, and a whole-step jit on the mesh path was observed to drift the
# logits by 1 ulp at nodelets=8.


@functools.partial(jax.jit, static_argnames=("norm_eps",))
def _decode_pre(p, tokens, k_cache, v_cache, positions, *, norm_eps):
    """Embed -> attention over the cache -> residual + pre-MoE norm."""
    B, S, D = k_cache.shape
    x = jnp.take(p["embed"], tokens, axis=0)  # (B, D)
    h = rmsnorm(x, p["ln1"], norm_eps)
    q = h @ p["wq"]
    k_new = h @ p["wk"]
    v_new = h @ p["wv"]
    b = jnp.arange(B)
    k_cache = k_cache.at[b, positions].set(k_new.astype(k_cache.dtype))
    v_cache = v_cache.at[b, positions].set(v_new.astype(v_cache.dtype))
    s = jnp.einsum(
        "bd,bsd->bs", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * jax.lax.rsqrt(jnp.float32(D))
    mask = jnp.arange(S)[None, :] <= positions[:, None]
    s = jnp.where(mask, s, -jnp.inf)
    att = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    x = x + jnp.einsum("bs,bsd->bd", att, v_cache) @ p["wo"]
    h2 = rmsnorm(x, p["ln2"], norm_eps)
    return x, h2, k_cache, v_cache


@functools.partial(jax.jit, static_argnames=("norm_eps",))
def _decode_post(p, x, expert_out, *, norm_eps):
    """MoE residual -> final norm -> lm_head."""
    x = x + expert_out
    return rmsnorm(x, p["ln_f"], norm_eps) @ p["lm_head"]


def _decode_local(
    params, tokens, k_cache, v_cache, positions, *,
    mode, nodelets, experts_per_token, capacity_factor, norm_eps,
):
    x, h2, k_cache, v_cache = _decode_pre(
        params, tokens, k_cache, v_cache, positions, norm_eps=norm_eps
    )
    out = _dispatch_local(
        h2, params["router"], params["w_gate"], params["w_up"],
        params["w_down"], mode=mode, nodelets=nodelets,
        experts_per_token=experts_per_token, capacity_factor=capacity_factor,
    )
    return _decode_post(params, x, out, norm_eps=norm_eps), k_cache, v_cache


def _decode_mesh(
    params, tokens, k_cache, v_cache, positions, *,
    mode, nodelets, experts_per_token, capacity_factor, norm_eps,
    mesh, axis_name,
):
    x, h2, k_cache, v_cache = _decode_pre(
        params, tokens, k_cache, v_cache, positions, norm_eps=norm_eps
    )
    out = _dispatch_mesh(
        h2, params["router"], params["w_gate"], params["w_up"],
        params["w_down"], mode=mode, nodelets=nodelets,
        experts_per_token=experts_per_token, capacity_factor=capacity_factor,
        mesh=mesh, axis_name=axis_name,
    )
    # re-land the mesh-sharded dispatch output as a replicated local array:
    # a sharded operand would specialize a second _decode_post executable
    # whose fusion choices need not match the local kernel's bit-for-bit
    out = jnp.asarray(np.asarray(out))
    return _decode_post(params, x, out, norm_eps=norm_eps), k_cache, v_cache


# -- kernels -------------------------------------------------------------------


@kernel("moe_decode", "local")
def _moe_decode_local(
    sub: Substrate, params, tokens, k_cache, v_cache, positions, *,
    strategy, nodelets, experts_per_token, capacity_factor, norm_eps,
):
    mode = dispatch_from_strategy(
        strategy, num_experts=int(params["router"].shape[-1]), data_axis=nodelets
    )
    return _decode_local(
        params, tokens, k_cache, v_cache, positions, mode=mode,
        nodelets=nodelets, experts_per_token=experts_per_token,
        capacity_factor=capacity_factor, norm_eps=norm_eps,
    )


@kernel("moe_decode", "mesh")
def _moe_decode_mesh(
    sub, params, tokens, k_cache, v_cache, positions, *,
    strategy, nodelets, experts_per_token, capacity_factor, norm_eps,
):
    mode = dispatch_from_strategy(
        strategy, num_experts=int(params["router"].shape[-1]), data_axis=nodelets
    )
    mesh = sub.mesh_for(nodelets)
    axis_size = dict(mesh.shape).get(sub.axis_name)
    if axis_size != nodelets:
        raise OpNotSupportedError(
            f"moe_decode needs a {nodelets}-way {sub.axis_name!r} mesh axis "
            f"(inputs.nodelets), got {axis_size}"
        )
    return _decode_mesh(
        params, tokens, k_cache, v_cache, positions, mode=mode,
        nodelets=nodelets, experts_per_token=experts_per_token,
        capacity_factor=capacity_factor, norm_eps=norm_eps,
        mesh=mesh, axis_name=sub.axis_name,
    )


def moe_decode_reference(
    inputs: MoEDecodeInputs, strategy: MigratoryStrategy | None = None
) -> tuple:
    """The single-process ``model.apply`` oracle: the exact decode math with
    the local dispatch — what every served decode step must bit-match."""
    strategy = strategy if strategy is not None else MigratoryStrategy()
    return _decode_local(
        inputs.params, inputs.tokens, inputs.k_cache, inputs.v_cache,
        inputs.positions, mode=derive_decode_mode(inputs, strategy),
        nodelets=inputs.nodelets, experts_per_token=inputs.experts_per_token,
        capacity_factor=inputs.capacity_factor, norm_eps=inputs.norm_eps,
    )


# -- traffic model -------------------------------------------------------------


def moe_decode_traffic(
    inputs: MoEDecodeInputs, strategy: MigratoryStrategy
) -> TrafficStats:
    """Analytic dispatch traffic of one decode step (T = B tokens). Unlike
    ``moe_dispatch`` there is no host routing replay — the serving plane
    submits a fresh step every few milliseconds, so the model uses the
    uniform-routing expectation for push mode: of the T*k kept slots, a
    (P-1)/P fraction lands off-shard. Pull mode is exact (routing-free)."""
    P, k = inputs.nodelets, inputs.experts_per_token
    T = int(inputs.tokens.shape[0])
    D = int(inputs.k_cache.shape[-1])
    itemsize = jnp.dtype(inputs.k_cache.dtype).itemsize
    mode = derive_decode_mode(inputs, strategy)
    if mode == "tp":
        return TrafficStats(0, 0, 0)
    if mode == "ep_push":
        remote = int(T * k * (P - 1) / P)
        return TrafficStats(
            migrations=0,
            remote_writes=remote,
            collective_bytes=remote * (2 * D * itemsize + 4),
        )
    gather = T * (P - 1) * D * itemsize + T * k * (P - 1) * 4
    ret = T * k * (P - 1) * D * itemsize
    return TrafficStats(
        migrations=T * (P - 1), remote_writes=0, collective_bytes=gather + ret
    )


def moe_decode_cost_model(inputs: MoEDecodeInputs):
    """Autotuner factory: rank S2 modes by modeled dispatch traffic (the
    rest of the step is mode-invariant compute)."""
    T = int(inputs.tokens.shape[0])
    B, S, D = inputs.k_cache.shape
    itemsize = jnp.dtype(inputs.k_cache.dtype).itemsize
    # mode-invariant working set: both caches read + written, activations
    stage_bytes = 4 * int(B) * int(S) * int(D) * itemsize

    def estimate(st: MigratoryStrategy) -> CostEstimate:
        traffic = moe_decode_traffic(inputs, st)
        mode = derive_decode_mode(inputs, st)
        launches = {"tp": 0, "ep_push": 3, "ep_pull": 2}[mode]
        return CostEstimate(
            strategy=st,
            traffic_bytes=traffic.total_bytes,
            balance_penalty=0.0,
            detail={
                "dispatch_mode": mode,
                "migrations": traffic.migrations,
                "batch": T,
                "collective_launches": launches,
                "memory_bytes_per_launch": stage_bytes,
                "memory_access": "stream",
            },
            traffic=traffic,
        )

    return estimate


# -- the op --------------------------------------------------------------------


class MoEDecodeOp:
    """MigratoryOp adapter: one continuous-batched MoE decode step."""

    name = "moe_decode"

    def plan(
        self, inputs: MoEDecodeInputs, strategy: MigratoryStrategy,
        substrate: Substrate,
    ) -> ExecutionPlan:
        B = int(inputs.tokens.shape[0])
        if B % inputs.nodelets != 0:
            raise ValueError(
                f"moe_decode needs B % nodelets == 0, got B={B}, "
                f"nodelets={inputs.nodelets}"
            )
        missing = [k for k in _PARAM_KEYS if k not in inputs.params]
        if missing:
            raise ValueError(
                f"moe_decode params missing {missing}; build them with "
                "repro.models.transformer.moe_decode_params(cfg, key)"
            )
        kern = substrate.kernel(self.name)
        args = (
            inputs.params, inputs.tokens, inputs.k_cache, inputs.v_cache,
            inputs.positions,
        )
        statics = (
            inputs.nodelets, inputs.experts_per_token,
            inputs.capacity_factor, inputs.norm_eps,
        )
        nodelets, k, cf, eps = statics
        return ExecutionPlan(
            op=self.name,
            strategy=strategy,
            substrate=substrate.name,
            inputs=inputs,
            executor=lambda p, t, kc, vc, pos: kern(
                p, t, kc, vc, pos, strategy=strategy, nodelets=nodelets,
                experts_per_token=k, capacity_factor=cf, norm_eps=eps,
            ),
            args=args,
            meta={"mode": derive_decode_mode(inputs, strategy)},
            key=plan_key(self.name, substrate, strategy, args, static=statics),
            # the kernels jit their own pre/dispatch/post stages and share
            # the pre/post executables across substrates; a whole-executor
            # jit here would refuse (and re-fuse) differently per substrate,
            # breaking local/mesh bit-identity — and the mesh kernel's
            # host-side re-landing of the dispatch output can't be traced
            jit=False,
        )

    def traffic(self, plan: ExecutionPlan) -> TrafficStats:
        return moe_decode_traffic(plan.inputs, plan.strategy)

    def bytes_moved(self, plan: ExecutionPlan) -> int:
        """Useful bytes of one step: full param read + caches read/written
        + logits written."""
        i = plan.inputs
        B, S, D = i.k_cache.shape
        it = jnp.dtype(i.k_cache.dtype).itemsize
        params_bytes = sum(
            w.size * jnp.dtype(w.dtype).itemsize
            for w in jax.tree_util.tree_leaves(i.params)
        )
        V = int(i.params["lm_head"].shape[-1])
        return params_bytes + 4 * int(B) * int(S) * int(D) * it + int(B) * V * it

    def metrics(self, plan: ExecutionPlan, result: Any, seconds: float) -> dict[str, Any]:
        i = plan.inputs
        B, S, D = i.k_cache.shape
        return {
            "dispatch_mode": plan.meta["mode"],
            "experts": i.num_experts,
            "nodelets": i.nodelets,
            "batch": int(B),
            "cache_len": int(S),
            "tokens_per_second": int(B) / seconds if seconds > 0 else 0.0,
        }


register_op(OpSpec(
    name="moe_decode",
    factory=MoEDecodeOp,
    inputs_type=MoEDecodeInputs,
    cost_model=moe_decode_cost_model,
    grid=moe_dispatch_grid,
))
