"""The engine's plan -> compile -> execute pipeline behind ``engine.run``.

    result, report = run(SpMVOp(), SpMVInputs(a, x), strategy, substrate="mesh")

The stages are individually exposed (DESIGN.md §1):

- :func:`build_plan`  — bind op + inputs + strategy to a substrate executor
  (``strategy="auto"`` routes through the traffic-model autotuner).
- :func:`compile_plan` — resolve the executor through a
  :class:`~repro.engine.cache.PlanCache`; a hit reuses the jitted executor.
- :func:`execute` / :func:`run` — timed execution. Defaults
  (``iters=3, warmup=1``) report *steady-state* medians with compile cost
  split into ``RunReport.compile_seconds``; pass ``iters=1, warmup=0`` to
  time a single cold call (compile included in ``seconds`` on a cache miss).
"""
from __future__ import annotations

import time
from typing import Any

import jax

from ..core.strategies import MigratoryStrategy
from . import ops as _ops  # noqa: F401  (imports register the built-in OpSpecs)
from .api import ExecutionPlan, MigratoryOp, RunReport
from .cache import CompiledPlan, PlanCache, default_cache
from .registry import default_registry
from .request import Request, coerce_request
from .substrate import Substrate, get_substrate


def resolve_op(op: "MigratoryOp | str") -> MigratoryOp:
    """Name -> MigratoryOp via the registry's OpSpec; instances pass through."""
    if isinstance(op, str):
        return default_registry().op_spec(op).factory()
    return op


def resolve_strategy(
    op: MigratoryOp,
    inputs: Any,
    strategy: "MigratoryStrategy | str | None",
    substrate: "Substrate | str" = "local",
) -> MigratoryStrategy:
    """None -> paper defaults; ``"auto"`` -> autotuner pick (ranked in
    predicted seconds for ``substrate`` when a calibrated machine file is
    present, in traffic units otherwise)."""
    if strategy is None:
        return MigratoryStrategy()
    if isinstance(strategy, str):
        if strategy != "auto":
            raise ValueError(f"unknown strategy {strategy!r}; expected 'auto'")
        from .autotune import choose_strategy

        return choose_strategy(op, inputs, substrate)
    return strategy


def _bind_plan(
    op: MigratoryOp, inputs: Any, strategy: Any, sub: Substrate
) -> ExecutionPlan:
    """op.plan + the substrate's planning overrides: a substrate whose
    executors the tracer cannot see (``jit_plans=False``, e.g. cluster
    forwarding over sockets) forces the plan eager regardless of what the
    op declared."""
    plan = op.plan(inputs, resolve_strategy(op, inputs, strategy, sub), sub)
    if not sub.jit_plans:
        plan.jit = False
    return plan


def build_plan(
    op: "MigratoryOp | str",
    inputs: Any,
    strategy: "MigratoryStrategy | str | None" = None,
    substrate: "Substrate | str" = "local",
) -> ExecutionPlan:
    """Stage 1: plan. Resolve op/strategy/substrate and bind the inputs."""
    op = resolve_op(op)
    sub = get_substrate(substrate)
    return _bind_plan(op, inputs, strategy, sub)


def compile_plan(
    plan: ExecutionPlan,
    cache: PlanCache | None = None,
    *,
    slot: "int | None" = None,
) -> CompiledPlan:
    """Stage 2: compile. Resolve the plan's executor through the cache —
    for keyed plans the first resolution wraps it in ``jax.jit``, so the
    cached artifact is a fused executable. ``slot`` tags the entry with the
    executor-pool slot doing the resolving (placement pinning, §1b)."""
    return (default_cache() if cache is None else cache).get(plan, slot=slot)


def _timed_call(compiled: CompiledPlan, times: list[float]) -> Any:
    t0 = time.perf_counter()
    result = jax.block_until_ready(compiled())
    times.append(time.perf_counter() - t0)
    return result


def execute(
    compiled: "CompiledPlan | ExecutionPlan",
    *,
    iters: int = 3,
    warmup: int = 1,
    cache: PlanCache | None = None,
    slot: "int | None" = None,
) -> tuple[Any, float, float]:
    """Stage 3: execute. Returns ``(result, seconds, compile_seconds)``.

    ``seconds`` is the median of ``iters`` timed calls after ``warmup``
    unmeasured ones. On a cache miss the first call traces + compiles; it is
    recorded as ``compile_seconds`` and doubles as the first warmup call —
    or, with ``warmup=0``, lands inside the timed set so a single cold call
    is timed compile-inclusive (the pre-cache engine's behavior).
    """
    if isinstance(compiled, ExecutionPlan):
        compiled = compile_plan(compiled, cache, slot=slot)
    timed: list[float] = []
    compile_seconds = 0.0
    result = None
    n_warm = warmup
    if not compiled.cache_hit:
        first: list[float] = []
        result = _timed_call(compiled, first)
        compile_seconds = first[0]
        (default_cache() if cache is None else cache).note_compiled(compiled, compile_seconds)
        if warmup > 0:
            n_warm = warmup - 1  # the compiling call was the first warmup
        else:
            timed.append(compile_seconds)  # cold-timing mode
    for _ in range(n_warm):
        result = _timed_call(compiled, [])
    for _ in range(max(1, iters) - len(timed)):
        result = _timed_call(compiled, timed)
    timed.sort()
    return result, timed[len(timed) // 2], compile_seconds


def single_call(
    plan: ExecutionPlan,
    op: MigratoryOp,
    *,
    cache: PlanCache | None = None,
    slot: "int | None" = None,
) -> tuple[Any, RunReport]:
    """One timed call through the cache — the unit of work of the async
    service's pipeline stages (DESIGN.md §1d).

    On a *cold* plan this call is the **compile** stage: the single timed
    call traces + compiles, and the report carries
    ``cache_hit=False, seconds == compile_seconds``. On a *warm* plan it is
    the **execute** stage: a pure steady-state call with
    ``cache_hit=True, compile_seconds=0.0``. The split lets the service
    overlap the compile of one plan-key group with the execution of others
    while each request still runs exactly the call sequence the synchronous
    path would have run — parity is structural, not incidental.

    ``slot`` is the placement tag: the executor-pool worker making the call.
    A compiling call pins the cache entry to it; a stolen execution passes
    its own slot but the pin stays with the compiling worker (§1b).
    """
    return run_plan(plan, op, iters=1, warmup=0, cache=cache, slot=slot)


def run_plan(
    plan: ExecutionPlan,
    op: MigratoryOp,
    *,
    iters: int = 3,
    warmup: int = 1,
    cache: PlanCache | None = None,
    slot: "int | None" = None,
) -> tuple[Any, RunReport]:
    """Compile + execute an already-built plan and assemble its RunReport."""
    compiled = compile_plan(plan, cache, slot=slot)
    result, seconds, compile_seconds = execute(
        compiled, iters=iters, warmup=warmup, cache=cache
    )
    # model honesty columns (DESIGN.md §1f): only a *calibrated* machine
    # file produces predictions — without one the report is bit-identical
    # to the pre-calibration schema (the columns stay None and are omitted
    # from to_dict), and the lookup is one cached profile check
    from ..machine.perfmodel import maybe_predict_plan_seconds

    predicted = maybe_predict_plan_seconds(op, plan)
    report = RunReport.from_parts(
        op=op.name,
        strategy=plan.strategy,
        substrate=plan.substrate,
        seconds=seconds,
        traffic=op.traffic(plan),
        bytes_moved=op.bytes_moved(plan),
        metrics=op.metrics(plan, result, seconds),
        cache_hit=compiled.cache_hit,
        compile_seconds=compile_seconds,
        predicted_seconds=predicted,
    )
    return result, report


def run_request(
    request: Request,
    *,
    iters: int = 3,
    warmup: int = 1,
    cache: PlanCache | None = None,
) -> tuple[Any, RunReport]:
    """Execute one :class:`~repro.engine.request.Request`; return
    ``(result, RunReport)``. The non-deprecated core behind :func:`run` —
    ``request.qos``/``request.timeout`` are serving-plane fields and are
    ignored here (the caller is already blocking on this one request)."""
    op = resolve_op(request.op)
    sub = get_substrate(
        request.substrate if request.substrate is not None else "local"
    )
    plan = _bind_plan(op, request.inputs, request.strategy, sub)
    return run_plan(plan, op, iters=iters, warmup=warmup, cache=cache)


def run(
    op: "Request | MigratoryOp | str",
    inputs: Any = None,
    strategy: "MigratoryStrategy | str | None" = None,
    substrate: "Substrate | str | None" = None,
    *,
    iters: int = 3,
    warmup: int = 1,
    cache: PlanCache | None = None,
) -> tuple[Any, RunReport]:
    """Execute one request; return ``(result, RunReport)``.

    The entry shape is a :class:`~repro.engine.request.Request`:

        y, report = run(Request("spmv", SpMVInputs(a, x), "auto", "mesh"))

    ``op``: the Request — or, deprecated, a MigratoryOp instance/name with
    the fields spread as arguments (emits ``DeprecationWarning``; behavior
    is identical via :func:`run_request`).
    ``strategy``: a MigratoryStrategy, ``None`` (paper defaults), or
    ``"auto"`` (traffic-model autotuner, engine/autotune.py).
    ``substrate``: a Substrate instance or name ("local" | "mesh" | "pallas").
    ``iters``/``warmup``: the defaults time steady state (median of 3 after
    1 warmup) with compile split out; ``iters=1, warmup=0`` times one cold
    call, compile included on a cache miss.
    ``cache``: plan cache override (default: the process-wide cache).
    """
    request = coerce_request(op, inputs, strategy, substrate, entry="run")
    return run_request(request, iters=iters, warmup=warmup, cache=cache)
