"""The single entry point: ``engine.run(op, inputs, strategy, substrate)``.

    result, report = run(SpMVOp(), SpMVInputs(a, x), strategy, substrate="mesh")

One call plans the op onto a substrate, executes (optionally warmed and
repeated for stable timing), and returns the result together with a
:class:`~repro.engine.api.RunReport` unifying wall time, the paper's traffic
model, and effective bandwidth.
"""
from __future__ import annotations

import time
from typing import Any

import jax

from ..core.strategies import MigratoryStrategy
from .api import ExecutionPlan, MigratoryOp, RunReport
from .ops import OPS
from .substrate import Substrate, get_substrate


def resolve_op(op: "MigratoryOp | str") -> MigratoryOp:
    if isinstance(op, str):
        try:
            return OPS[op]()
        except KeyError:
            raise ValueError(f"unknown op {op!r}; known: {sorted(OPS)}") from None
    return op


def execute(plan: ExecutionPlan, *, iters: int = 1, warmup: int = 0):
    """Run a plan, returning (result, median wall seconds). With the default
    ``iters=1, warmup=0`` the single timed call includes compilation."""
    for _ in range(warmup):
        jax.block_until_ready(plan.run())
    times = []
    result = None
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        result = jax.block_until_ready(plan.run())
        times.append(time.perf_counter() - t0)
    times.sort()
    return result, times[len(times) // 2]


def run(
    op: "MigratoryOp | str",
    inputs: Any,
    strategy: MigratoryStrategy | None = None,
    substrate: "Substrate | str" = "local",
    *,
    iters: int = 1,
    warmup: int = 0,
) -> tuple[Any, RunReport]:
    """Execute ``op`` on ``substrate`` under ``strategy``; return
    ``(result, RunReport)``.

    ``op``: a MigratoryOp instance or name ("spmv" | "bfs" | "gsana").
    ``substrate``: a Substrate instance or name ("local" | "mesh" | "pallas").
    ``iters``/``warmup``: benchmark-style timing (median of ``iters`` after
    ``warmup`` unmeasured calls); the defaults time a single cold call.
    """
    op = resolve_op(op)
    sub = get_substrate(substrate)
    strategy = strategy or MigratoryStrategy()
    plan = op.plan(inputs, strategy, sub)
    result, seconds = execute(plan, iters=iters, warmup=warmup)
    report = RunReport.from_parts(
        op=op.name,
        strategy=strategy,
        substrate=sub.name,
        seconds=seconds,
        traffic=op.traffic(plan),
        bytes_moved=op.bytes_moved(plan),
        metrics=op.metrics(plan, result, seconds),
    )
    return result, report
