"""MigratoryOp adapters over the core algorithms (DESIGN.md §1, §1e).

Each adapter owns three things for its algorithm: how to bind inputs to a
substrate (``plan``), the paper's traffic model (``traffic``), and the
paper's useful-bytes accounting (``bytes_moved``), plus derived metrics
(MTEPS, recall, modeled makespan) for the RunReport. ``plan`` binds the
executor by *kernel lookup* (``substrate.kernel(self.name)``), so an
unsupported pair fails at plan time with
:class:`~repro.engine.api.OpNotSupportedError` — capability is registry
presence, not substrate subclassing.

Each op registers an :class:`~repro.engine.registry.OpSpec` (factory +
inputs type + cost-model factory + autotune grid) with the default
registry; the module-level ``OPS`` mapping is a live legacy view of it.
"""
from __future__ import annotations

import collections
import dataclasses
import weakref
from collections.abc import Mapping
from typing import Any, Callable

import jax
import numpy as np

from ..core.bfs import bfs_bytes_moved, bfs_traffic, teps
from ..core.gsana import (
    gsana_rw_bytes,
    layout_blk,
    layout_hcb,
    plan_stats,
    recall_at_k,
)
from ..core.gsana_data import Buckets, VertexSet
from ..core.spmv import (
    PartitionedELL,
    spmv_bytes_moved,
    spmv_traffic,
    stripe_vector,
)
from ..core.cost import bfs_cost_model, gsana_cost_model, spmv_cost_model
from ..core.strategies import Layout, MigratoryStrategy, TrafficStats, strategy_grid
from ..sparse.graph import PartitionedGraph
from .api import ExecutionPlan, plan_key
from .registry import OpSpec, default_registry, register_op
from .substrate import Substrate

# grain values worth distinguishing for row-grained ops (None = dynamic);
# SpMV's autotune grid sweeps them, the other ops' grids pin grain=None
GRAIN_CANDIDATES = (None, 16, 64, 256)

# the Pallas kernel-tuning axis: grain = block_rows (rows per grid program).
# Wider and coarser than the generic sweep — VMEM-tile-shaped candidates;
# tiny grains are never competitive once the per-program x/partial
# replication is charged (core/cost.py substrate_memory)
PALLAS_BLOCK_CANDIDATES = (None, 64, 128, 256, 512, 1024)


def _spmv_grid(substrate_kind: "str | None" = None) -> list[MigratoryStrategy]:
    grains = PALLAS_BLOCK_CANDIDATES if substrate_kind == "pallas" else GRAIN_CANDIDATES
    return strategy_grid(grains=grains)


def _bfs_grid(substrate_kind: "str | None" = None) -> list[MigratoryStrategy]:
    # default grid pins grain=None (the local/mesh kernels never read it);
    # on pallas the grain is block_rows of the frontier-expansion kernel
    if substrate_kind == "pallas":
        return strategy_grid(grains=PALLAS_BLOCK_CANDIDATES)
    return strategy_grid()


# Cross-plan memo for host-side derived stats (traffic replays, placement
# models, nnz scans). The serving path builds a fresh plan per request, so
# ``plan.meta`` caching alone reruns the O(edges)-ish numpy work — and its
# device->host transfers — for every served request of the same inputs,
# serializing the executor pool on the GIL. Keyed by inputs object identity
# + a static discriminator, validated with a weakref so a recycled id of a
# collected object can never alias (the moe_op replay-memo pattern).
_DERIVED_MEMO: "collections.OrderedDict[tuple, tuple]" = collections.OrderedDict()
_DERIVED_MEMO_MAX = 256


def _derived_cached(kind: str, anchor: Any, extra: Any, compute: Callable[[], Any]) -> Any:
    key = (kind, id(anchor), extra)
    hit = _DERIVED_MEMO.get(key)
    if hit is not None and hit[0]() is anchor:
        _DERIVED_MEMO.move_to_end(key)
        return hit[1]
    value = compute()
    try:
        _DERIVED_MEMO[key] = (weakref.ref(anchor), value)
    except TypeError:
        return value  # unweakrefable anchor: still correct, just uncached
    while len(_DERIVED_MEMO) > _DERIVED_MEMO_MAX:
        _DERIVED_MEMO.popitem(last=False)  # LRU: never drop the hot entries
    return value


# -- SpMV ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SpMVInputs:
    """``x`` is always the full (N,) vector; the engine stripes it when the
    strategy keeps it distributed (S1 off)."""

    a: PartitionedELL
    x: jax.Array


class SpMVOp:
    name = "spmv"

    def plan(self, inputs: SpMVInputs, strategy: MigratoryStrategy, substrate: Substrate):
        x = inputs.x if strategy.replicate_x else stripe_vector(inputs.x, inputs.a.P)
        args = (inputs.a, x)
        kern = substrate.kernel(self.name)
        return ExecutionPlan(
            op=self.name,
            strategy=strategy,
            substrate=substrate.name,
            inputs=inputs,
            executor=lambda a, xv: kern(a, xv, strategy=strategy),
            args=args,
            meta={"n_cols": inputs.a.shape[1], "n_rows": inputs.a.shape[0]},
            key=plan_key(self.name, substrate, strategy, args),
        )

    def traffic(self, plan: ExecutionPlan) -> TrafficStats:
        inputs, strategy = plan.inputs, plan.strategy
        return _derived_cached(
            "spmv_traffic", inputs, strategy.cache_key(),
            lambda: spmv_traffic(inputs.a, strategy),
        )

    def bytes_moved(self, plan: ExecutionPlan) -> int:
        inputs, n_cols = plan.inputs, plan.meta["n_cols"]
        return _derived_cached(
            "spmv_bytes", inputs, n_cols,
            lambda: spmv_bytes_moved(inputs.a, n_cols),
        )

    def metrics(self, plan: ExecutionPlan, result: Any, seconds: float) -> dict[str, Any]:
        return {
            "grain": plan.strategy.dynamic_grain(plan.inputs.a.rows_per_nodelet),
            "nodelets": plan.inputs.a.P,
        }


# -- BFS -----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BFSInputs:
    g: PartitionedGraph
    root: int
    max_rounds: int | None = None


class BFSOp:
    name = "bfs"

    def plan(self, inputs: BFSInputs, strategy: MigratoryStrategy, substrate: Substrate):
        args = (inputs.g,)
        # close over the scalars, not `inputs`: the plan cache keeps the
        # executor closure alive, and it must not pin the graph arrays
        root, max_rounds = inputs.root, inputs.max_rounds
        kern = substrate.kernel(self.name)
        return ExecutionPlan(
            op=self.name,
            strategy=strategy,
            substrate=substrate.name,
            inputs=inputs,
            executor=lambda g: kern(g, root, strategy=strategy, max_rounds=max_rounds),
            args=args,
            key=plan_key(
                self.name, substrate, strategy, args,
                static=(inputs.root, inputs.max_rounds),
            ),
        )

    def _stats(self, plan: ExecutionPlan):
        """The numpy traffic replay: O(edges), computed once per
        (inputs, root, strategy) and shared across every plan built for
        them (the serving path builds one plan per request)."""
        if "run_stats" not in plan.meta:
            inputs, strategy = plan.inputs, plan.strategy
            plan.meta["run_stats"] = _derived_cached(
                "bfs_replay", inputs, (inputs.root, strategy.cache_key()),
                lambda: bfs_traffic(inputs.g, inputs.root, strategy),
            )
        return plan.meta["run_stats"]

    def traffic(self, plan: ExecutionPlan) -> TrafficStats:
        return self._stats(plan).traffic

    def bytes_moved(self, plan: ExecutionPlan) -> int:
        return bfs_bytes_moved(self._stats(plan).edges_traversed)

    def metrics(self, plan: ExecutionPlan, result: Any, seconds: float) -> dict[str, Any]:
        stats = self._stats(plan)
        reached = int((np.asarray(result) >= 0).sum()) if result is not None else 0
        return {
            "rounds": stats.rounds,
            "edges_traversed": stats.edges_traversed,
            "mteps": teps(stats.edges_traversed, seconds) / 1e6,
            "reached": reached,
        }


# -- GSANA ---------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GSANAInputs:
    vs1: VertexSet
    vs2: VertexSet
    b1: Buckets
    b2: Buckets
    k: int = 4
    nodelets: int = 8
    threads_per_nodelet: int = 32
    migration_penalty: float = 0.3
    ground_truth: np.ndarray | None = None  # optional π for recall@k


class GSANAOp:
    name = "gsana"

    def plan(self, inputs: GSANAInputs, strategy: MigratoryStrategy, substrate: Substrate):
        args = (inputs.vs1, inputs.vs2, inputs.b1, inputs.b2)
        # close over the scalar k, not `inputs`: cached executors must not
        # pin the vertex-set/bucket arrays of the first-compiling request
        k = inputs.k
        kern = substrate.kernel(self.name)
        return ExecutionPlan(
            op=self.name,
            strategy=strategy,
            substrate=substrate.name,
            inputs=inputs,
            executor=lambda vs1, vs2, b1, b2: kern(
                vs1, vs2, b1, b2, k, strategy=strategy
            ),
            args=args,
            key=plan_key(
                self.name, substrate, strategy, args, static=(inputs.k,),
            ),
        )

    def _plan_stats(self, plan: ExecutionPlan):
        """S3 placement/traffic model for (layout x scheme), computed once
        per (inputs, layout, scheme) and shared across plans."""
        if "plan_stats" not in plan.meta:
            i = plan.inputs
            strategy = plan.strategy

            def compute():
                if strategy.layout == Layout.HCB:
                    placement = layout_hcb(i.b1, i.b2, i.nodelets)
                else:
                    placement = layout_blk(i.b1, i.b2, i.vs1.n, i.vs2.n, i.nodelets)
                return plan_stats(
                    i.vs1, i.vs2, i.b1, i.b2, placement, strategy.scheme,
                    i.nodelets, threads_per_nodelet=i.threads_per_nodelet,
                    migration_penalty=i.migration_penalty,
                )

            plan.meta["plan_stats"] = _derived_cached(
                "gsana_plan_stats", i,
                (strategy.layout.value, strategy.scheme.value), compute,
            )
        return plan.meta["plan_stats"]

    def traffic(self, plan: ExecutionPlan) -> TrafficStats:
        return self._plan_stats(plan).traffic

    def bytes_moved(self, plan: ExecutionPlan) -> int:
        i = plan.inputs
        return _derived_cached(
            "gsana_rw_bytes", i, None,
            lambda: gsana_rw_bytes(i.vs1, i.vs2, i.b1, i.b2),
        )

    def metrics(self, plan: ExecutionPlan, result: Any, seconds: float) -> dict[str, Any]:
        ps = self._plan_stats(plan)
        out = {
            "total_comparisons": ps.total_comparisons,
            "model_makespan": ps.makespan,
            "model_speedup": ps.speedup_model,
            "rw_words": ps.rw_total,
        }
        if plan.inputs.ground_truth is not None and result is not None:
            cand, _ = result
            out["recall_at_k"] = recall_at_k(cand, plan.inputs.ground_truth)
        return out


# -- registration --------------------------------------------------------------

register_op(OpSpec(
    name="spmv",
    factory=SpMVOp,
    inputs_type=SpMVInputs,
    cost_model=spmv_cost_model,
    grid=_spmv_grid,
))
register_op(OpSpec(
    name="bfs",
    factory=BFSOp,
    inputs_type=BFSInputs,
    cost_model=bfs_cost_model,
    grid=_bfs_grid,
))
register_op(OpSpec(
    name="gsana",
    factory=GSANAOp,
    inputs_type=GSANAInputs,
    cost_model=gsana_cost_model,
))


class _OpsView(Mapping):
    """Legacy ``OPS`` mapping, now a live read-only view of the registry:
    ``OPS["spmv"]`` yields the op factory, iteration yields registered op
    names (so later registrations — e.g. ``moe_dispatch`` — appear)."""

    def __getitem__(self, name: str):
        try:
            return default_registry().op_spec(name).factory
        except ValueError:
            raise KeyError(name) from None

    def __iter__(self):
        return iter(default_registry().ops())

    def __len__(self) -> int:
        return len(default_registry().ops())


OPS = _OpsView()


# op modules that self-register their OpSpecs/kernels: importing them here
# guarantees registration wherever the engine is entered (runner imports
# this module before resolving any op name)
from . import moe_op as _moe_op  # noqa: E402,F401
from . import decode_op as _decode_op  # noqa: E402,F401
