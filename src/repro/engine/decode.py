"""Continuous-batched MoE decode serving over the engine (DESIGN.md §1g).

:class:`DecodeServer` drives the ``moe_decode`` op as a serving loop with a
fixed batch capacity of B slots (static shapes -> one compile for the whole
session). Sequences join a free slot mid-session and leave when finished;
per-slot KV caches and position cursors are carried across submits, so each
:meth:`step` is one engine request for the *current* batch composition —
exactly the continuous-batching contract.

Prefill is served through the decode path: a sequence's prompt tokens are
fed one per step ("forced" tokens) before greedy argmax takes over. That
keeps every step a single uniform ``moe_decode`` submit, which is what
makes oracle parity checkable: an oracle-mode server fed the same
join/leave schedule replays bit-identical padded batches, so served tokens
must match token-for-token in every dispatch mode.

Execution routes per construction:

- ``service=EngineService(...)``: each step submits one
  :class:`~repro.engine.request.Request` (batch mode drains per step;
  worker mode blocks on the future) — the production path, exercising
  QoS/SLO accounting.
- ``service=None``: direct ``engine.run_request`` per step.
- ``oracle=True``: the single-process reference — the parity baseline.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core.strategies import MigratoryStrategy
from .decode_op import MoEDecodeInputs, moe_decode_reference
from .request import Request


@dataclasses.dataclass
class _Sequence:
    id: int
    slot: int
    first_token: int
    forced: list  # remaining prompt tokens to feed before sampling
    forced_idx: int
    max_new_tokens: int
    generated: list


class DecodeServer:
    """Serve greedy decode for concurrent sequences over one engine op.

    ``capacity`` is the fixed batch width B (must divide by ``nodelets``);
    ``max_len`` the per-slot KV length. ``add()`` joins a sequence (queued
    FIFO when all slots are busy), ``step()`` advances every active slot by
    one token, ``run_until_drained()`` loops until everything finished.
    Finished outputs land in ``results[seq_id]``.
    """

    def __init__(
        self,
        cfg,
        params: dict,
        *,
        capacity: int = 8,
        max_len: int = 32,
        nodelets: int = 1,
        strategy: "MigratoryStrategy | str | None" = None,
        substrate: Any = "local",
        service: Any = None,
        oracle: bool = False,
        qos: "float | None" = None,
        timeout: "float | None" = None,
    ) -> None:
        if capacity % nodelets != 0:
            raise ValueError(
                f"capacity must divide by nodelets, got {capacity} % {nodelets}"
            )
        if oracle and isinstance(strategy, str):
            raise ValueError(
                "oracle mode needs a concrete strategy (or None), not "
                f"{strategy!r} — the oracle has no autotuner"
            )
        self.cfg = cfg
        self.params = params
        self.capacity = capacity
        self.max_len = max_len
        self.nodelets = nodelets
        self.strategy = strategy
        self.substrate = substrate
        self.service = service
        self.oracle = oracle
        self.qos = qos
        self.timeout = timeout
        D = int(cfg.d_model)
        dt = jnp.dtype(cfg.dtype)
        self._k = jnp.zeros((capacity, max_len, D), dt)
        self._v = jnp.zeros((capacity, max_len, D), dt)
        # padded slots decode token 0 at position 0 deterministically
        self._tokens = np.zeros((capacity,), np.int32)
        self._positions = np.zeros((capacity,), np.int32)
        self._slots: "list[_Sequence | None]" = [None] * capacity
        self._waiting: deque = deque()
        self._next_id = 0
        self.results: dict[int, list[int]] = {}
        self.steps = 0

    # -- admission -------------------------------------------------------------

    def add(self, prompt: "list[int]", max_new_tokens: int = 8) -> int:
        """Join a sequence: first prompt token becomes the slot's current
        token, the rest are forced through the decode path. Returns seq id."""
        if not prompt:
            raise ValueError("prompt must be non-empty")
        if len(prompt) + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt+generation ({len(prompt)}+{max_new_tokens}) exceeds "
                f"max_len {self.max_len}"
            )
        seq = _Sequence(
            id=self._next_id, slot=-1, first_token=int(prompt[0]),
            forced=[int(t) for t in prompt[1:]], forced_idx=0,
            max_new_tokens=max_new_tokens, generated=[],
        )
        self._next_id += 1
        self._waiting.append(seq)
        self._admit()
        return seq.id

    def _admit(self) -> None:
        for slot in range(self.capacity):
            if not self._waiting:
                return
            if self._slots[slot] is None:
                seq = self._waiting.popleft()
                seq.slot = slot
                self._slots[slot] = seq
                self._tokens[slot] = seq.first_token
                self._positions[slot] = 0

    @property
    def active(self) -> int:
        return sum(s is not None for s in self._slots)

    @property
    def pending(self) -> int:
        return self.active + len(self._waiting)

    # -- the serving loop ------------------------------------------------------

    def step(self) -> "list[tuple[int, int]]":
        """One decode step for the whole batch. Returns the (seq_id, token)
        pairs *sampled* this step (prefill-forced steps emit nothing)."""
        if self.active == 0:
            return []
        inputs = MoEDecodeInputs(
            params=self.params,
            tokens=jnp.asarray(self._tokens),
            k_cache=self._k,
            v_cache=self._v,
            positions=jnp.asarray(self._positions),
            nodelets=self.nodelets,
            experts_per_token=self.cfg.experts_per_token,
            capacity_factor=self.cfg.capacity_factor,
            norm_eps=self.cfg.norm_eps,
        )
        logits, self._k, self._v = self._execute(inputs)
        logits = np.asarray(jax.device_get(logits))
        emitted: list[tuple[int, int]] = []
        for seq in [s for s in self._slots if s is not None]:
            slot = seq.slot
            self._positions[slot] += 1
            if seq.forced_idx < len(seq.forced):
                nxt = seq.forced[seq.forced_idx]
                seq.forced_idx += 1
            else:
                nxt = int(np.argmax(logits[slot]))
                seq.generated.append(nxt)
                emitted.append((seq.id, nxt))
            self._tokens[slot] = nxt
            done = len(seq.generated) >= seq.max_new_tokens
            if done or int(self._positions[slot]) >= self.max_len - 1:
                self._retire(seq)
        self._admit()
        self.steps += 1
        return emitted

    def run_until_drained(self, max_steps: int = 10_000) -> dict[int, list[int]]:
        steps = 0
        while self.pending > 0:
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError(f"decode did not drain in {max_steps} steps")
        return self.results

    def _retire(self, seq: _Sequence) -> None:
        self.results[seq.id] = seq.generated
        self._slots[seq.slot] = None
        self._tokens[seq.slot] = 0
        self._positions[seq.slot] = 0

    # -- execution routes ------------------------------------------------------

    def _execute(self, inputs: MoEDecodeInputs) -> tuple:
        if self.oracle:
            return moe_decode_reference(inputs, self.strategy)
        request = Request(
            "moe_decode", inputs, strategy=self.strategy,
            substrate=self.substrate, qos=self.qos, timeout=self.timeout,
        )
        if self.service is None:
            from .runner import run_request

            result, _ = run_request(request)
            return result
        out = self.service.submit(request)
        if isinstance(out, int):  # batch mode: ticket + drain
            for resp in self.service.drain():
                if resp.ticket == out:
                    return resp.result
            raise RuntimeError(f"drain lost ticket {out}")
        return out.result().result  # worker mode: future -> ServiceResponse
