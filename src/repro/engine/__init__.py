"""Unified MigratoryOp engine: one substrate-dispatched entry point for the
paper's three irregular algorithms, with built-in traffic & bandwidth
accounting (DESIGN.md §1).

    from repro.engine import run, SpMVOp, SpMVInputs
    y, report = run(SpMVOp(), SpMVInputs(a, x), strategy, substrate="mesh")
    print(report.to_json())

Ops implement :class:`MigratoryOp`; backends implement
:class:`Substrate` and register with :func:`register_substrate`.
"""
from .api import (
    ExecutionPlan,
    MigratoryOp,
    OpNotSupportedError,
    RunReport,
    strategy_dict,
)
from .ops import (
    OPS,
    BFSInputs,
    BFSOp,
    GSANAInputs,
    GSANAOp,
    SpMVInputs,
    SpMVOp,
)
from .runner import execute, resolve_op, run
from .substrate import (
    LocalSubstrate,
    MeshSubstrate,
    PallasSubstrate,
    Substrate,
    get_substrate,
    list_substrates,
    register_substrate,
    substrate_for_mesh,
)

__all__ = [
    "BFSInputs", "BFSOp", "ExecutionPlan", "GSANAInputs", "GSANAOp",
    "LocalSubstrate", "MeshSubstrate", "MigratoryOp", "OPS",
    "OpNotSupportedError", "PallasSubstrate", "RunReport", "SpMVInputs",
    "SpMVOp", "Substrate", "execute", "get_substrate", "list_substrates",
    "register_substrate", "resolve_op", "run", "strategy_dict",
    "substrate_for_mesh",
]
