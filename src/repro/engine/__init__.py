"""Unified MigratoryOp engine: one substrate-dispatched entry point for the
paper's three irregular algorithms, with built-in traffic & bandwidth
accounting and an explicit plan -> compile -> execute pipeline
(DESIGN.md §1).

    from repro.engine import Request, run, SpMVOp, SpMVInputs
    y, report = run(Request(SpMVOp(), SpMVInputs(a, x), strategy, "mesh"))
    y, report = run(Request("spmv", SpMVInputs(a, x), "auto"))  # autotuned
    print(report.to_json())   # seconds + traffic + cache_hit/compile_seconds

``Request`` is the one entry shape for ``run`` and ``EngineService.submit``
(per-request ``qos``/``timeout`` ride it); the legacy kwargs spellings
still work but emit :class:`DeprecationWarning` (DESIGN.md §1g).

Ops implement :class:`MigratoryOp`; backends implement :class:`Substrate`
and register with :func:`register_substrate`. Ops and substrates meet only
in the :class:`KernelRegistry` (:mod:`repro.engine.registry`): kernels are
``(op, substrate_kind)`` entries (``@kernel("spmv", "mesh")``), ops are
:class:`OpSpec` registrations, and :func:`capabilities` is the
introspection table of who runs what — ``moe_dispatch``
(:mod:`repro.engine.moe_op`) is the fourth op, registered without touching
any substrate class. Compiled executors are cached per
shape/strategy/substrate signature (:mod:`repro.engine.cache`); the
strategy grid is ranked analytically (:mod:`repro.engine.autotune`) with
measured probes persisted across sessions (:mod:`repro.engine.probes`);
serving goes through :class:`EngineService` (:mod:`repro.engine.service`) —
batched drain or the async worker loop with admission control, a value-keyed
response dedup cache, and an overlapped compile/execute pipeline.
"""
from .api import (
    ExecutionPlan,
    MigratoryOp,
    OpNotSupportedError,
    RunReport,
    args_signature,
    plan_key,
    strategy_dict,
)
from .autotune import (
    AutotuneResult,
    RankedCandidate,
    autotune,
    candidate_grid,
    choose_strategy,
    rank_strategies,
)
from .cache import CompiledPlan, PlanCache, default_cache
from .probes import ProbeStore, default_probe_store
from .ops import (
    OPS,
    GRAIN_CANDIDATES,
    PALLAS_BLOCK_CANDIDATES,
    BFSInputs,
    BFSOp,
    GSANAInputs,
    GSANAOp,
    SpMVInputs,
    SpMVOp,
)
from .registry import (
    KernelRegistry,
    OpSpec,
    capabilities,
    default_registry,
    kernel,
    placement_table,
    register_op,
)
from .moe_op import (
    MoEDispatchInputs,
    MoEDispatchOp,
    moe_dispatch_cost_model,
    moe_dispatch_grid,
    moe_dispatch_reference,
    moe_dispatch_traffic,
)
from .decode import DecodeServer
from .decode_op import (
    MoEDecodeInputs,
    MoEDecodeOp,
    moe_decode_cost_model,
    moe_decode_reference,
    moe_decode_traffic,
)
from .request import Request
from .wire import (
    WIRE_VERSION,
    SegmentTable,
    WireError,
    canonical_bytes,
    collect_blob_digests,
    content_digest,
    decode_value,
    encode_value,
)
from .runner import (
    build_plan,
    compile_plan,
    execute,
    resolve_op,
    resolve_strategy,
    run,
    run_plan,
    run_request,
    single_call,
)
from .service import (
    AdmissionError,
    EngineService,
    ServiceFuture,
    ServiceRequest,
    ServiceResponse,
    ServiceStats,
    ServiceStopped,
    ServiceTimeout,
)
from .substrate import (
    LocalSubstrate,
    MeshSubstrate,
    PallasSubstrate,
    Substrate,
    get_substrate,
    list_substrates,
    register_substrate,
    substrate_for_mesh,
)

__all__ = [
    "AdmissionError", "AutotuneResult", "BFSInputs", "BFSOp", "CompiledPlan",
    "DecodeServer",
    "EngineService", "ExecutionPlan", "GRAIN_CANDIDATES", "GSANAInputs",
    "GSANAOp", "KernelRegistry", "LocalSubstrate", "MeshSubstrate",
    "MigratoryOp", "MoEDecodeInputs", "MoEDecodeOp",
    "MoEDispatchInputs", "MoEDispatchOp", "OPS", "OpSpec",
    "OpNotSupportedError", "PALLAS_BLOCK_CANDIDATES", "PallasSubstrate",
    "PlanCache", "ProbeStore",
    "RankedCandidate", "Request",
    "RunReport", "SegmentTable", "ServiceFuture", "ServiceRequest",
    "ServiceResponse",
    "ServiceStats", "ServiceStopped", "ServiceTimeout",
    "SpMVInputs", "SpMVOp", "Substrate",
    "WIRE_VERSION", "WireError",
    "args_signature", "autotune", "build_plan", "candidate_grid",
    "canonical_bytes", "collect_blob_digests", "content_digest",
    "capabilities", "choose_strategy", "compile_plan", "decode_value",
    "default_cache",
    "default_probe_store", "default_registry", "encode_value", "execute",
    "get_substrate",
    "kernel", "list_substrates",
    "moe_decode_cost_model", "moe_decode_reference", "moe_decode_traffic",
    "moe_dispatch_cost_model",
    "moe_dispatch_grid", "moe_dispatch_reference", "moe_dispatch_traffic",
    "placement_table", "plan_key", "rank_strategies", "register_op",
    "register_substrate",
    "resolve_op", "resolve_strategy", "run", "run_plan", "run_request",
    "single_call",
    "strategy_dict", "substrate_for_mesh",
]
