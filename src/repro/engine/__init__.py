"""Unified MigratoryOp engine: one substrate-dispatched entry point for the
paper's three irregular algorithms, with built-in traffic & bandwidth
accounting and an explicit plan -> compile -> execute pipeline
(DESIGN.md §1).

    from repro.engine import run, SpMVOp, SpMVInputs
    y, report = run(SpMVOp(), SpMVInputs(a, x), strategy, substrate="mesh")
    y, report = run("spmv", SpMVInputs(a, x), "auto")   # autotuned strategy
    print(report.to_json())   # seconds + traffic + cache_hit/compile_seconds

Ops implement :class:`MigratoryOp`; backends implement :class:`Substrate`
and register with :func:`register_substrate`. Compiled executors are cached
per shape/strategy/substrate signature (:mod:`repro.engine.cache`); the
strategy grid is ranked analytically (:mod:`repro.engine.autotune`) with
measured probes persisted across sessions (:mod:`repro.engine.probes`);
serving goes through :class:`EngineService` (:mod:`repro.engine.service`) —
batched drain or the async worker loop with admission control and an
overlapped compile/execute pipeline.
"""
from .api import (
    ExecutionPlan,
    MigratoryOp,
    OpNotSupportedError,
    RunReport,
    args_signature,
    plan_key,
    strategy_dict,
)
from .autotune import (
    AutotuneResult,
    autotune,
    candidate_grid,
    choose_strategy,
    rank_strategies,
)
from .cache import CompiledPlan, PlanCache, default_cache
from .probes import ProbeStore, default_probe_store
from .ops import (
    OPS,
    BFSInputs,
    BFSOp,
    GSANAInputs,
    GSANAOp,
    SpMVInputs,
    SpMVOp,
)
from .runner import (
    build_plan,
    compile_plan,
    execute,
    resolve_op,
    resolve_strategy,
    run,
    run_plan,
    single_call,
)
from .service import (
    AdmissionError,
    EngineService,
    ServiceFuture,
    ServiceRequest,
    ServiceResponse,
    ServiceStats,
    ServiceStopped,
)
from .substrate import (
    LocalSubstrate,
    MeshSubstrate,
    PallasSubstrate,
    Substrate,
    get_substrate,
    list_substrates,
    register_substrate,
    substrate_for_mesh,
)

__all__ = [
    "AdmissionError", "AutotuneResult", "BFSInputs", "BFSOp", "CompiledPlan",
    "EngineService", "ExecutionPlan", "GSANAInputs", "GSANAOp",
    "LocalSubstrate", "MeshSubstrate", "MigratoryOp", "OPS",
    "OpNotSupportedError", "PallasSubstrate", "PlanCache", "ProbeStore",
    "RunReport", "ServiceFuture", "ServiceRequest", "ServiceResponse",
    "ServiceStats", "ServiceStopped", "SpMVInputs", "SpMVOp", "Substrate",
    "args_signature", "autotune", "build_plan", "candidate_grid",
    "choose_strategy", "compile_plan", "default_cache", "default_probe_store",
    "execute", "get_substrate", "list_substrates", "plan_key",
    "rank_strategies", "register_substrate", "resolve_op", "resolve_strategy",
    "run", "run_plan", "single_call", "strategy_dict", "substrate_for_mesh",
]
