"""Unified MigratoryOp engine: one substrate-dispatched entry point for the
paper's three irregular algorithms, with built-in traffic & bandwidth
accounting and an explicit plan -> compile -> execute pipeline
(DESIGN.md §1).

    from repro.engine import run, SpMVOp, SpMVInputs
    y, report = run(SpMVOp(), SpMVInputs(a, x), strategy, substrate="mesh")
    y, report = run("spmv", SpMVInputs(a, x), "auto")   # autotuned strategy
    print(report.to_json())   # seconds + traffic + cache_hit/compile_seconds

Ops implement :class:`MigratoryOp`; backends implement :class:`Substrate`
and register with :func:`register_substrate`. Compiled executors are cached
per shape/strategy/substrate signature (:mod:`repro.engine.cache`); the
strategy grid is ranked analytically (:mod:`repro.engine.autotune`); batched
serving goes through :class:`EngineService` (:mod:`repro.engine.service`).
"""
from .api import (
    ExecutionPlan,
    MigratoryOp,
    OpNotSupportedError,
    RunReport,
    args_signature,
    plan_key,
    strategy_dict,
)
from .autotune import (
    AutotuneResult,
    autotune,
    candidate_grid,
    choose_strategy,
    rank_strategies,
)
from .cache import CompiledPlan, PlanCache, default_cache
from .ops import (
    OPS,
    BFSInputs,
    BFSOp,
    GSANAInputs,
    GSANAOp,
    SpMVInputs,
    SpMVOp,
)
from .runner import (
    build_plan,
    compile_plan,
    execute,
    resolve_op,
    resolve_strategy,
    run,
    run_plan,
)
from .service import EngineService, ServiceResponse, ServiceStats
from .substrate import (
    LocalSubstrate,
    MeshSubstrate,
    PallasSubstrate,
    Substrate,
    get_substrate,
    list_substrates,
    register_substrate,
    substrate_for_mesh,
)

__all__ = [
    "AutotuneResult", "BFSInputs", "BFSOp", "CompiledPlan", "EngineService",
    "ExecutionPlan", "GSANAInputs", "GSANAOp", "LocalSubstrate",
    "MeshSubstrate", "MigratoryOp", "OPS", "OpNotSupportedError",
    "PallasSubstrate", "PlanCache", "RunReport", "ServiceResponse",
    "ServiceStats", "SpMVInputs", "SpMVOp", "Substrate", "args_signature",
    "autotune", "build_plan", "candidate_grid", "choose_strategy",
    "compile_plan", "default_cache", "execute", "get_substrate",
    "list_substrates", "plan_key", "rank_strategies", "register_substrate",
    "resolve_op", "resolve_strategy", "run", "run_plan", "strategy_dict",
    "substrate_for_mesh",
]
