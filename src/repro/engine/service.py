"""EngineService: the serving front-end over the plan/compile/execute
pipeline, in two modes (DESIGN.md §1d).

**Batch mode** (the default, PR 2's API): ``submit()`` returns an int ticket
and nothing runs until ``drain()`` executes everything, grouped by plan key
so each group compiles at most once.

    svc = EngineService()
    t = svc.submit("spmv", inputs)               # -> int ticket
    responses = svc.drain()                      # one compile per plan key

**Worker-loop mode** (the serving path): ``start()`` spawns a two-stage
pipeline — a *compile* thread that pops plan-key groups off the admission
queue, schedules them by QoS weight, and runs each group's first (possibly
compiling) call, feeding a bounded queue to an *execute* thread that serves
the group's remaining cache-hit calls. While the execute thread works
through group N, the compile thread is already tracing/compiling group N+1,
so compile and execute wall time overlap instead of adding — the
compile-N+1-while-executing-N structure of the migratory-thread model
(keep work in flight against memory; never serialize on data movement).

    svc = EngineService(max_queue_depth=256, admission="block",
                        qos={"bfs": 2.0})
    svc.start()
    fut = svc.submit("spmv", inputs)             # -> ServiceFuture, non-blocking
    resp = fut.result(timeout=60)                # ServiceResponse
    svc.stop()                                   # drains by default
    print(svc.stats().overlap_ratio)             # compile hidden under execute

Admission control: ``max_queue_depth`` bounds the request queue;
``admission="block"`` applies backpressure to submitters (requires a running
worker to make progress), ``admission="reject"`` raises
:class:`AdmissionError` immediately (counted in ``ServiceStats.rejected``).
``qos`` maps op names to scheduling weights — within each queue snapshot,
higher-weight groups run first (ordering, not preemption).

Results are **bit-identical** to sequential ``engine.run`` in both modes:
each request still executes the same cached-executor call the synchronous
path would have run; concurrency changes *when* plans compile, never what
they compute (``tests/test_service_async.py`` pins this under concurrent
mixed-op submission).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import queue as queue_mod
import threading
import time
from collections import deque
from typing import Any

import jax

import numpy as np

from ..core.strategies import MigratoryStrategy
from .api import RunReport
from .cache import PlanCache
from .runner import build_plan, resolve_op, single_call
from .substrate import Substrate, get_substrate

_STOP = object()  # execute-loop shutdown sentinel

# per-request latency samples kept for percentile estimation (newest wins;
# bounds memory for long-lived services, like the span folding below)
_LATENCY_WINDOW = 4096


def _percentile(ordered: "list[float]", q: float) -> float:
    """Nearest-rank percentile of an already-sorted sample list."""
    if not ordered:
        return 0.0
    idx = min(len(ordered) - 1, max(0, int(round(q * (len(ordered) - 1)))))
    return ordered[idx]


class AdmissionError(RuntimeError):
    """submit() refused: the queue is full under the 'reject' policy (or
    'block' with no worker running to ever free space)."""


class ServiceStopped(RuntimeError):
    """The service shut down: raised by submissions after stop() and by
    futures whose queued request was cancelled by stop(drain=False)."""


@dataclasses.dataclass(frozen=True)
class ServiceRequest:
    ticket: int
    op: Any
    inputs: Any
    strategy: "MigratoryStrategy | str | None"
    substrate: "Substrate | str"
    t_admit: float = 0.0  # perf_counter at admission (queue-wait percentiles)


@dataclasses.dataclass
class ServiceResponse:
    ticket: int
    result: Any
    report: RunReport


class ServiceFuture:
    """Handle for one worker-loop submission — what async ``submit`` returns.

    ``result(timeout)`` blocks until the request is served and returns its
    :class:`ServiceResponse`; it re-raises the request's exception if the
    run failed or the service dropped it (:class:`ServiceStopped`).
    """

    def __init__(self, ticket: int):
        self.ticket = ticket
        self._done = threading.Event()
        self._response: ServiceResponse | None = None
        self._exception: BaseException | None = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: "float | None" = None) -> ServiceResponse:
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.ticket} not served within {timeout}s")
        if self._exception is not None:
            raise self._exception
        return self._response

    def exception(self, timeout: "float | None" = None) -> "BaseException | None":
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.ticket} not served within {timeout}s")
        return self._exception

    def _resolve(self, response: ServiceResponse) -> None:
        self._response = response
        self._done.set()

    def _reject(self, exc: BaseException) -> None:
        self._exception = exc
        self._done.set()


@dataclasses.dataclass
class _WorkItem:
    """One admitted worker-loop request moving through the pipeline."""

    request: ServiceRequest
    future: ServiceFuture
    op: Any = None
    plan: Any = None
    dedup_key: "str | None" = None  # content hash when dedup is enabled


def _hash_value(h, value: Any) -> None:
    """Feed one input value into the content hash, by *bytes* for arrays.

    The op input containers (SpMVInputs, MoEDispatchInputs, ...) are plain
    frozen dataclasses, not registered pytree nodes — ``tree_flatten`` would
    return them as single leaves whose ``repr`` truncates large arrays, so
    dataclasses are recursed field-by-field explicitly and every array-like
    is hashed by its full buffer."""
    if hasattr(value, "shape") and hasattr(value, "dtype"):
        arr = np.asarray(value)
        h.update(repr((arr.shape, str(arr.dtype))).encode())
        h.update(arr.tobytes())
        return
    if dataclasses.is_dataclass(value) and not isinstance(value, type):
        h.update(type(value).__name__.encode())
        for field in dataclasses.fields(value):
            h.update(field.name.encode())
            _hash_value(h, getattr(value, field.name))
        return
    leaves, treedef = jax.tree_util.tree_flatten(value)
    if len(leaves) == 1 and leaves[0] is value:
        h.update(repr(value).encode())  # true scalar leaf (int, str, enum, ...)
        return
    h.update(repr(treedef).encode())
    for leaf in leaves:
        _hash_value(h, leaf)


def _content_hash(op: Any, inputs: Any, strategy: Any, substrate: Any) -> str:
    """Value-keyed identity of one request: op name x strategy identity x
    substrate fingerprint x the *bytes* of every input leaf. Two requests
    with equal hashes are the same computation — ops are pure — so the
    service may answer the second from the first's response."""
    h = hashlib.sha256()
    op_name = op if isinstance(op, str) else getattr(op, "name", repr(op))
    h.update(repr(op_name).encode())
    strat_id = (
        strategy.cache_key() if isinstance(strategy, MigratoryStrategy) else strategy
    )
    h.update(repr(strat_id).encode())
    h.update(repr(get_substrate(substrate).cache_fingerprint()).encode())
    _hash_value(h, inputs)
    return h.hexdigest()


def _union_seconds(spans: "list[tuple[float, float]]") -> float:
    """Total covered time of possibly-overlapping (t0, t1) spans."""
    total = 0.0
    cur_start = cur_end = None
    for t0, t1 in sorted(spans):
        if cur_end is None or t0 > cur_end:
            if cur_end is not None:
                total += cur_end - cur_start
            cur_start, cur_end = t0, t1
        else:
            cur_end = max(cur_end, t1)
    if cur_end is not None:
        total += cur_end - cur_start
    return total


def _intersection_seconds(
    a: "list[tuple[float, float]]", b: "list[tuple[float, float]]"
) -> float:
    """Total time spans from ``a`` and ``b`` ran simultaneously. Each list is
    internally non-overlapping (one pipeline thread produced each), so a
    two-pointer sweep is exact."""
    a, b = sorted(a), sorted(b)
    i = j = 0
    total = 0.0
    while i < len(a) and j < len(b):
        lo = max(a[i][0], b[j][0])
        hi = min(a[i][1], b[j][1])
        if hi > lo:
            total += hi - lo
        if a[i][1] < b[j][1]:
            i += 1
        else:
            j += 1
    return total


@dataclasses.dataclass
class ServiceStats:
    """Aggregate serving counters across the service's lifetime, both modes.

    Timing semantics (the ``to_dict()`` schema):

    - ``wall_seconds`` — observable serving window. Batch mode: summed
      ``drain()`` wall time (unchanged from PR 2). Worker mode: first
      admission -> latest completion, so idle time between bursts counts —
      it is the denominator of sustained ``requests_per_second``.
    - ``busy_seconds`` — time at least one pipeline stage was doing work
      (union of compile-stage and execute-stage spans; equals wall time in
      batch mode, where drain() is always busy). ``wall - busy`` is idle.
    - ``overlap_seconds`` — time the compile stage of one plan-key group ran
      simultaneously with the execute stage of another;
      ``overlap_ratio = overlap_seconds / total compile-stage seconds`` is
      the fraction of compile time hidden under execution (0 in batch mode).
    - ``queue_wait_p50/p95/p99`` — per-request admission -> run-start wait;
      ``service_p50/p95/p99`` — per-request run duration (ROADMAP "latency
      accounting"). Estimated over the most recent ``_LATENCY_WINDOW``
      executed requests; dedup-served requests wait for neither and are
      excluded.
    - ``dedup_hits`` — requests answered from the value-keyed response cache
      without executing (``dedup=True`` services only).
    """

    requests: int = 0
    batches: int = 0
    drains: int = 0
    cache_hits: int = 0
    compiles: int = 0
    compile_seconds: float = 0.0
    run_seconds: float = 0.0  # steady-state execution seconds (compile excluded)
    wall_seconds: float = 0.0  # serving window (see class docstring)
    busy_seconds: float = 0.0  # >=1 pipeline stage active (see class docstring)
    queue_depth_hwm: int = 0  # high-water mark of the admission queue
    rejected: int = 0  # admission-control rejections
    cancelled: int = 0  # queued requests dropped by stop(drain=False)
    errors: int = 0  # requests whose plan/execute raised
    overlap_seconds: float = 0.0
    overlap_ratio: float = 0.0
    dedup_hits: int = 0  # responses served from the value-keyed dedup cache
    queue_wait_p50: float = 0.0
    queue_wait_p95: float = 0.0
    queue_wait_p99: float = 0.0
    service_p50: float = 0.0
    service_p95: float = 0.0
    service_p99: float = 0.0

    @property
    def requests_per_second(self) -> float:
        return self.requests / self.wall_seconds if self.wall_seconds > 0 else 0.0

    @property
    def amortization(self) -> float:
        """Requests served per compile — the batching win."""
        return self.requests / self.compiles if self.compiles else float(self.requests)

    def to_dict(self) -> dict[str, Any]:
        return {
            "requests": self.requests,
            "batches": self.batches,
            "drains": self.drains,
            "cache_hits": self.cache_hits,
            "compiles": self.compiles,
            "compile_seconds": self.compile_seconds,
            "run_seconds": self.run_seconds,
            "wall_seconds": self.wall_seconds,
            "busy_seconds": self.busy_seconds,
            "queue_depth_hwm": self.queue_depth_hwm,
            "rejected": self.rejected,
            "cancelled": self.cancelled,
            "errors": self.errors,
            "overlap_seconds": self.overlap_seconds,
            "overlap_ratio": self.overlap_ratio,
            "dedup_hits": self.dedup_hits,
            "queue_wait_p50": self.queue_wait_p50,
            "queue_wait_p95": self.queue_wait_p95,
            "queue_wait_p99": self.queue_wait_p99,
            "service_p50": self.service_p50,
            "service_p95": self.service_p95,
            "service_p99": self.service_p99,
            "requests_per_second": self.requests_per_second,
            "amortization": self.amortization,
        }


class EngineService:
    """Serving front-end over the plan/compile/execute pipeline.

    Constructed services are in batch mode; ``start()`` switches to the
    worker loop (module docstring). Admission-control and QoS knobs apply to
    both modes; ``batch_window`` is the micro-batching window — after the
    first request of a burst arrives, the worker waits this long before
    snapshotting the queue so bursts group into fewer, larger plan-key
    groups; ``pipeline_depth`` bounds the compiled-group queue between the
    two stages (backpressure on the compile thread).

    ``dedup=True`` puts a value-keyed response cache in front of the
    pipeline: requests whose op + strategy + substrate + input *values*
    content-hash to an already-served request are answered from the stored
    response without planning or executing (``ServiceStats.dedup_hits``).
    Sound because ops are pure functions of their inputs; the replayed
    response carries the original execution's report. Off by default —
    hashing large input pytrees on every submit is not free.
    """

    def __init__(
        self,
        cache: PlanCache | None = None,
        substrate: "Substrate | str" = "local",
        autotune: bool = False,
        *,
        max_queue_depth: "int | None" = None,
        admission: str = "block",
        qos: "dict[str, float] | None" = None,
        batch_window: float = 0.0,
        pipeline_depth: int = 2,
        dedup: bool = False,
        dedup_max_entries: int = 256,
    ):
        if admission not in ("block", "reject"):
            raise ValueError(
                f"admission must be 'block' or 'reject', got {admission!r}"
            )
        self.cache = cache if cache is not None else PlanCache()
        self.default_substrate = substrate
        self.autotune = autotune
        self.max_queue_depth = max_queue_depth
        self.admission = admission
        # validate weights here: a bad value must fail the constructor, not
        # the scheduler inside the worker thread
        self.qos = {name: float(weight) for name, weight in (qos or {}).items()}
        self.batch_window = batch_window
        self.pipeline_depth = max(1, pipeline_depth)
        self.dedup = dedup
        self.dedup_max_entries = max(1, dedup_max_entries)
        # value-keyed response store: content hash -> served ServiceResponse
        self._dedup_store: "collections.OrderedDict[str, ServiceResponse]" = (
            collections.OrderedDict()
        )
        # per-request latency samples (bounded; see ServiceStats docstring)
        self._queue_waits: deque = deque(maxlen=_LATENCY_WINDOW)
        self._service_times: deque = deque(maxlen=_LATENCY_WINDOW)
        self._pending: list[ServiceRequest] = []
        self._next_ticket = 0
        self._stats = ServiceStats()
        # worker-loop state: one lock, three conditions on it
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)  # worker: items arrived
        self._space = threading.Condition(self._lock)  # submitters: space freed
        self._idle = threading.Condition(self._lock)  # flush(): all resolved
        self._queue: deque[_WorkItem] = deque()
        self._inflight = 0  # admitted worker requests not yet resolved
        self._running = False
        self._stopping = False
        self._threads: list[threading.Thread] = []
        self._exec_queue: queue_mod.Queue = queue_mod.Queue(maxsize=self.pipeline_depth)
        self._compile_spans: list[tuple[float, float]] = []
        self._exec_spans: list[tuple[float, float]] = []
        # long-run safety: spans periodically fold into these accumulators so
        # a service alive for millions of requests stays O(1) in memory
        self._overlap_acc = 0.0
        self._busy_acc = 0.0
        self._compile_busy_acc = 0.0
        self._drain_wall = 0.0
        self._t_first: "float | None" = None
        self._t_last: "float | None" = None

    def __len__(self) -> int:
        """Unserved requests: batch-pending plus worker-admitted in flight."""
        with self._lock:
            return len(self._pending) + self._inflight

    # -- admission -------------------------------------------------------------

    def qos_weight(self, op_name: str) -> float:
        return float(self.qos.get(op_name, 1.0))

    def _admit_locked(self) -> None:
        if self._stopping:
            raise ServiceStopped("service stopped; no new submissions")
        if self.max_queue_depth is None:
            return
        while (
            len(self._queue) if self._running else len(self._pending)
        ) >= self.max_queue_depth:
            if self.admission == "reject" or not self._running:
                self._stats.rejected += 1
                reason = (
                    "policy is 'reject'"
                    if self.admission == "reject"
                    else "'block' needs a running worker to free space; call start()"
                )
                raise AdmissionError(
                    f"queue full ({self.max_queue_depth} requests); {reason}"
                )
            self._space.wait(timeout=0.1)
            if self._stopping:
                raise ServiceStopped("service stopped while blocked on admission")

    def submit(
        self,
        op: Any,
        inputs: Any,
        strategy: "MigratoryStrategy | str | None" = None,
        substrate: "Substrate | str | None" = None,
    ) -> "int | ServiceFuture":
        """Enqueue one request. Batch mode returns its int ticket (serve via
        ``drain()``); worker-loop mode returns a :class:`ServiceFuture`.
        Full queues block or raise per the admission policy. With
        ``dedup=True``, a worker-mode request whose content hash matches an
        already-served response resolves immediately — it never enters the
        queue (batch mode dedups inside ``drain()``)."""
        if strategy is None and self.autotune:
            strategy = "auto"
        sub = substrate if substrate is not None else self.default_substrate
        dkey = None
        # batch mode hashes inside drain() instead — a submit-time hash could
        # never serve a hit there (responses only exist once drain runs)
        if self.dedup and self._running:
            dkey = _content_hash(op, inputs, strategy, sub)  # outside the lock
            with self._lock:
                hit = self._dedup_store.get(dkey)
                if hit is not None and self._running and not self._stopping:
                    self._dedup_store.move_to_end(dkey)
                    ticket = self._next_ticket
                    self._next_ticket += 1
                    self._stats.requests += 1
                    self._stats.dedup_hits += 1
                    future = ServiceFuture(ticket)
                    future._resolve(
                        ServiceResponse(ticket, hit.result, hit.report)
                    )
                    return future
        with self._lock:
            self._admit_locked()
            ticket = self._next_ticket
            self._next_ticket += 1
            req = ServiceRequest(
                ticket=ticket,
                op=op,
                inputs=inputs,
                strategy=strategy,
                substrate=sub,
                t_admit=time.perf_counter(),
            )
            if self._running:
                future = ServiceFuture(ticket)
                self._queue.append(_WorkItem(req, future, dedup_key=dkey))
                self._inflight += 1
                if self._t_first is None:
                    self._t_first = time.perf_counter()
                self._stats.queue_depth_hwm = max(
                    self._stats.queue_depth_hwm, len(self._queue)
                )
                self._work.notify()
                return future
            self._pending.append(req)
            self._stats.queue_depth_hwm = max(
                self._stats.queue_depth_hwm, len(self._pending)
            )
            return ticket

    # -- lifecycle -------------------------------------------------------------

    def start(self) -> "EngineService":
        """Spawn the worker loop; subsequent ``submit()`` calls return
        futures. Restartable after ``stop()``."""
        with self._lock:
            if self._running:
                raise RuntimeError("service already started")
            if self._pending:
                raise RuntimeError(
                    "drain() pending batch-mode requests before start()"
                )
            self._running = True
            self._stopping = False
            self._exec_queue = queue_mod.Queue(maxsize=self.pipeline_depth)
            self._threads = [
                threading.Thread(
                    target=self._worker_loop, name="engine-service-compile", daemon=True
                ),
                threading.Thread(
                    target=self._execute_loop, name="engine-service-execute", daemon=True
                ),
            ]
            threads = list(self._threads)
        for t in threads:
            t.start()
        return self

    def stop(self, drain: bool = True, timeout: "float | None" = None) -> None:
        """Graceful shutdown. ``drain=True`` serves everything already
        admitted first; ``drain=False`` cancels still-queued requests (their
        futures raise :class:`ServiceStopped`; groups already in the
        pipeline complete). Idempotent; ``start()`` again to restart. If
        ``timeout`` expires with workers still running, raises TimeoutError
        and leaves the service in the stopping state — call ``stop()``
        again; it never marks a still-running service as stopped."""
        with self._lock:
            if not self._running:
                return
            self._stopping = True
            if not drain:
                while self._queue:
                    item = self._queue.popleft()
                    item.future._reject(
                        ServiceStopped("service stopped before this request ran")
                    )
                    self._inflight -= 1
                    self._stats.cancelled += 1
                self._idle.notify_all()
            self._work.notify_all()
            self._space.notify_all()
            threads = list(self._threads)
        for t in threads:
            t.join(timeout)
        alive = [t.name for t in threads if t.is_alive()]
        if alive:
            # a later start() must not spawn a second pipeline racing this one
            raise TimeoutError(
                f"stop() timed out with worker thread(s) still running: {alive}; "
                "call stop() again"
            )
        with self._lock:
            self._running = False
            self._threads = []
            # _stopping stays True: submit() after stop raises ServiceStopped
            # until start() is called again.

    def __enter__(self) -> "EngineService":
        return self.start()

    def __exit__(self, *exc_info) -> None:
        self.stop()

    def flush(self, timeout: "float | None" = None) -> None:
        """Block until every admitted worker-loop request has resolved."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            while self._queue or self._inflight:
                if deadline is not None and time.monotonic() >= deadline:
                    raise TimeoutError("flush timed out with work still in flight")
                self._idle.wait(timeout=0.1)

    # -- the pipeline ----------------------------------------------------------

    def _worker_loop(self) -> None:
        """Stage-1 thread: snapshot the queue, schedule plan-key groups by
        QoS, run each group's compile call, feed the execute stage."""
        try:
            while True:
                with self._lock:
                    while not self._queue and not self._stopping:
                        self._work.wait(timeout=0.1)
                    if not self._queue:
                        if self._stopping:
                            break
                        continue
                if self.batch_window > 0:
                    time.sleep(self.batch_window)  # let the burst accumulate
                with self._lock:
                    snapshot = list(self._queue)
                    self._queue.clear()
                    self._space.notify_all()
                try:
                    dispatched: set[int] = set()
                    for group in self._plan_groups(snapshot):
                        self._compile_group(group)
                        self._exec_queue.put(group)  # bounded: backpressure
                        dispatched.update(id(item) for item in group)
                except Exception as exc:
                    # defensive: a scheduler bug must not strand futures —
                    # reject the snapshot's undispatched requests (the
                    # execute stage owns the dispatched ones) and keep going
                    for item in snapshot:
                        if id(item) not in dispatched and not item.future.done():
                            self._finish_error(item, exc)
        finally:
            self._exec_queue.put(_STOP)

    def _execute_loop(self) -> None:
        """Stage-2 thread: serve each group's remaining (cache-hit) calls
        while the compile thread works on the next group."""
        while True:
            group = self._exec_queue.get()
            if group is _STOP:
                return
            rest = group[1:]
            if not rest:
                continue
            t0 = time.perf_counter()
            for item in rest:
                self._run_item(item)
            t1 = time.perf_counter()
            with self._lock:
                self._exec_spans.append((t0, t1))
                self._note_span_end_locked(t1)
                self._maybe_fold_spans_locked()

    def _plan_groups(self, items: "list[_WorkItem]") -> "list[list[_WorkItem]]":
        """The scheduler: bind every request's plan, group by compiled-plan
        key, order groups by QoS weight (higher first) then arrival."""
        groups: dict[Any, list[_WorkItem]] = {}
        auto_memo: dict[tuple, Any] = {}
        for item in items:
            req = item.request
            try:
                op = resolve_op(req.op)
                strategy = req.strategy
                if isinstance(strategy, str) and strategy == "auto":
                    memo_key = (op.name, id(req.inputs))
                    if memo_key not in auto_memo:
                        from .autotune import choose_strategy

                        auto_memo[memo_key] = choose_strategy(op, req.inputs)
                    strategy = auto_memo[memo_key]
                plan = build_plan(op, req.inputs, strategy, req.substrate)
            except Exception as exc:  # plan failures resolve that future only
                self._finish_error(item, exc)
                continue
            item.op, item.plan = op, plan
            gkey = plan.key if plan.key is not None else ("__unkeyed__", req.ticket)
            groups.setdefault(gkey, []).append(item)
        return sorted(
            groups.values(),
            key=lambda g: (-self.qos_weight(g[0].op.name), g[0].request.ticket),
        )

    def _compile_group(self, group: "list[_WorkItem]") -> None:
        """Pipeline compile stage: the group's first request runs its
        (possibly compiling) call; the group's later members are cache hits
        by construction and run in the execute stage."""
        t0 = time.perf_counter()
        self._run_item(group[0])
        t1 = time.perf_counter()
        with self._lock:
            self._compile_spans.append((t0, t1))
            self._note_span_end_locked(t1)
            self._stats.batches += 1
            self._maybe_fold_spans_locked()

    def _note_span_end_locked(self, t1: float) -> None:
        """Extend the wall window to the span end: _run_item stamped _t_last
        before the span closed, and busy (span union) must stay <= wall."""
        if self._t_last is None or t1 > self._t_last:
            self._t_last = t1

    _SPAN_FOLD_THRESHOLD = 8192

    def _maybe_fold_spans_locked(self) -> None:
        """Fold recorded spans into scalar accumulators once the buffers grow
        large, bounding memory and stats() cost for long-lived services (at
        the cost of ignoring overlap straddling a fold boundary — one group
        out of thousands)."""
        if len(self._compile_spans) + len(self._exec_spans) <= self._SPAN_FOLD_THRESHOLD:
            return
        self._overlap_acc += _intersection_seconds(self._compile_spans, self._exec_spans)
        self._busy_acc += _union_seconds(self._compile_spans + self._exec_spans)
        self._compile_busy_acc += sum(t1 - t0 for t0, t1 in self._compile_spans)
        self._compile_spans.clear()
        self._exec_spans.clear()

    def _run_item(self, item: _WorkItem) -> None:
        t0 = time.perf_counter()
        if item.dedup_key is not None and self._try_serve_dedup(item):
            return
        try:
            result, report = single_call(item.plan, item.op, cache=self.cache)
        except Exception as exc:
            self._finish_error(item, exc)
            return
        t1 = time.perf_counter()
        response = ServiceResponse(item.request.ticket, result, report)
        item.future._resolve(response)
        with self._lock:
            if item.dedup_key is not None:
                self._dedup_store[item.dedup_key] = response
                self._dedup_store.move_to_end(item.dedup_key)
                while len(self._dedup_store) > self.dedup_max_entries:
                    self._dedup_store.popitem(last=False)
            if item.request.t_admit:
                self._queue_waits.append(max(0.0, t0 - item.request.t_admit))
            self._service_times.append(t1 - t0)
            self._account_locked(report)
            self._finish_locked()

    def _try_serve_dedup(self, item: _WorkItem) -> bool:
        """Late dedup check (drain loop / pipeline stages): answer from the
        response store if an identical request completed since admission.
        Returns True when the item was served."""
        with self._lock:
            hit = self._dedup_store.get(item.dedup_key)
            if hit is None:
                return False
            self._dedup_store.move_to_end(item.dedup_key)
            self._stats.requests += 1
            self._stats.dedup_hits += 1
            item.future._resolve(
                ServiceResponse(item.request.ticket, hit.result, hit.report)
            )
            self._finish_locked()
            return True

    def _finish_error(self, item: _WorkItem, exc: BaseException) -> None:
        item.future._reject(exc)
        with self._lock:
            self._stats.errors += 1
            self._finish_locked()

    def _finish_locked(self) -> None:
        self._inflight -= 1
        self._t_last = time.perf_counter()
        self._idle.notify_all()

    def _account_locked(self, report: RunReport) -> None:
        self._stats.requests += 1
        self._stats.cache_hits += int(report.cache_hit)
        self._stats.compiles += int(not report.cache_hit)
        self._stats.compile_seconds += report.compile_seconds
        # a cold request's single timed call IS the compile call;
        # count only its steady-state remainder as run time
        self._stats.run_seconds += report.seconds - report.compile_seconds

    # -- batch mode ------------------------------------------------------------

    def drain(self) -> "list[ServiceResponse]":
        """Batch mode: run every pending request, batching same-plan-key
        requests so each batch compiles at most once. Responses in
        submission order. In worker-loop mode use the futures (or
        ``flush()``) instead."""
        with self._lock:
            if self._running:
                raise RuntimeError(
                    "drain() is the batch-mode API; the worker loop is running — "
                    "use the futures returned by submit(), or flush()"
                )
            pending, self._pending = self._pending, []
        if not pending:
            return []
        t_wall = time.perf_counter()
        items = [
            _WorkItem(
                req,
                ServiceFuture(req.ticket),
                dedup_key=(
                    _content_hash(req.op, req.inputs, req.strategy, req.substrate)
                    if self.dedup
                    else None
                ),
            )
            for req in pending
        ]
        with self._lock:
            self._inflight += len(items)  # balanced by _finish_locked per item
        try:
            groups = self._plan_groups(items)
            # fail fast, like the pre-worker-loop drain: a plan that would
            # not bind raises before any group spends compile/execute time
            bad = next(
                (i for i in items if i.future._exception is not None), None
            )
            if bad is not None:
                raise bad.future._exception
            responses: list[ServiceResponse] = []
            for group in groups:
                with self._lock:
                    self._stats.batches += 1
                for item in group:
                    self._run_item(item)
                    if item.future._exception is not None:
                        raise item.future._exception
                    responses.append(item.future._response)
        finally:
            with self._lock:
                # items skipped by a fail-fast raise never reached
                # _finish_locked; balance their admission count
                for item in items:
                    if not item.future.done():
                        self._inflight -= 1
                self._stats.drains += 1
                self._drain_wall += time.perf_counter() - t_wall
        responses.sort(key=lambda r: r.ticket)
        return responses

    # -- reporting -------------------------------------------------------------

    def stats(self) -> ServiceStats:
        """A snapshot of the aggregate counters with the timing/overlap
        fields recomputed from the recorded stage spans (see
        :class:`ServiceStats` for semantics). Each call returns a fresh
        object — safe to keep for before/after comparisons."""
        with self._lock:
            worker_wall = (
                self._t_last - self._t_first
                if self._t_first is not None and self._t_last is not None
                else 0.0
            )
            overlap_seconds = self._overlap_acc + _intersection_seconds(
                self._compile_spans, self._exec_spans
            )
            compile_busy = self._compile_busy_acc + sum(
                t1 - t0 for t0, t1 in self._compile_spans
            )
            waits = list(self._queue_waits)  # copy only; sort off-lock —
            services = list(self._service_times)  # submit()/pipeline contend here
            snapshot = dataclasses.replace(
                self._stats,
                wall_seconds=self._drain_wall + max(0.0, worker_wall),
                busy_seconds=(
                    self._drain_wall
                    + self._busy_acc
                    + _union_seconds(self._compile_spans + self._exec_spans)
                ),
                overlap_seconds=overlap_seconds,
                overlap_ratio=(
                    overlap_seconds / compile_busy if compile_busy > 0 else 0.0
                ),
            )
        waits.sort()
        services.sort()
        snapshot.queue_wait_p50 = _percentile(waits, 0.50)
        snapshot.queue_wait_p95 = _percentile(waits, 0.95)
        snapshot.queue_wait_p99 = _percentile(waits, 0.99)
        snapshot.service_p50 = _percentile(services, 0.50)
        snapshot.service_p95 = _percentile(services, 0.95)
        snapshot.service_p99 = _percentile(services, 0.99)
        return snapshot

    def throughput_report(self) -> dict[str, Any]:
        """Aggregate record: service counters + plan-cache health."""
        return {**self.stats().to_dict(), "cache": self.cache.stats()}
