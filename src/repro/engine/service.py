"""Batched EngineService: a submit/drain request queue over the engine
pipeline — the first concrete step toward the production-serving north star
(ROADMAP).

    svc = EngineService()
    t1 = svc.submit("spmv", inputs_a)            # enqueue, nothing runs
    t2 = svc.submit("spmv", inputs_b)            # same shapes -> same plan key
    responses = svc.drain()                      # one compile, two executions
    print(svc.stats().to_dict())                 # aggregate throughput record

``drain()`` builds every pending request's :class:`ExecutionPlan`, groups
requests by compiled-plan cache key, and runs each group back-to-back so a
batch of same-signature requests pays for at most one compile (the first
request traces + compiles; the rest are cache hits). Results are
bit-identical to sequential ``engine.run`` calls — batching changes *when*
executors compile, never what they compute (the service parity test pins
this). Responses come back in submission order.

The service owns a private :class:`PlanCache` by default so its hit-rate
statistics reflect its own traffic; pass a shared cache to pool compiled
executors with other engine users.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

from ..core.strategies import MigratoryStrategy
from .api import RunReport
from .cache import PlanCache
from .runner import build_plan, resolve_op, run_plan
from .substrate import Substrate


@dataclasses.dataclass(frozen=True)
class ServiceRequest:
    ticket: int
    op: Any
    inputs: Any
    strategy: "MigratoryStrategy | str | None"
    substrate: "Substrate | str"


@dataclasses.dataclass
class ServiceResponse:
    ticket: int
    result: Any
    report: RunReport


@dataclasses.dataclass
class ServiceStats:
    """Aggregate throughput accounting across every drain so far."""

    requests: int = 0
    batches: int = 0
    drains: int = 0
    cache_hits: int = 0
    compiles: int = 0
    compile_seconds: float = 0.0
    run_seconds: float = 0.0  # steady-state execution seconds (compile excluded)
    wall_seconds: float = 0.0  # end-to-end drain wall time

    @property
    def requests_per_second(self) -> float:
        return self.requests / self.wall_seconds if self.wall_seconds > 0 else 0.0

    @property
    def amortization(self) -> float:
        """Requests served per compile — the batching win."""
        return self.requests / self.compiles if self.compiles else float(self.requests)

    def to_dict(self) -> dict[str, Any]:
        return {
            "requests": self.requests,
            "batches": self.batches,
            "drains": self.drains,
            "cache_hits": self.cache_hits,
            "compiles": self.compiles,
            "compile_seconds": self.compile_seconds,
            "run_seconds": self.run_seconds,
            "wall_seconds": self.wall_seconds,
            "requests_per_second": self.requests_per_second,
            "amortization": self.amortization,
        }


class EngineService:
    """Synchronous batched front-end over the plan/compile/execute pipeline."""

    def __init__(
        self,
        cache: PlanCache | None = None,
        substrate: "Substrate | str" = "local",
        autotune: bool = False,
    ):
        self.cache = cache if cache is not None else PlanCache()
        self.default_substrate = substrate
        self.autotune = autotune
        self._pending: list[ServiceRequest] = []
        self._next_ticket = 0
        self._stats = ServiceStats()

    def __len__(self) -> int:
        return len(self._pending)

    def submit(
        self,
        op: Any,
        inputs: Any,
        strategy: "MigratoryStrategy | str | None" = None,
        substrate: "Substrate | str | None" = None,
    ) -> int:
        """Enqueue one request; returns its ticket (the drain-response id)."""
        ticket = self._next_ticket
        self._next_ticket += 1
        if strategy is None and self.autotune:
            strategy = "auto"
        self._pending.append(
            ServiceRequest(
                ticket=ticket,
                op=op,
                inputs=inputs,
                strategy=strategy,
                substrate=substrate if substrate is not None else self.default_substrate,
            )
        )
        return ticket

    def drain(self) -> list[ServiceResponse]:
        """Run every pending request, batching same-plan-key requests so each
        batch compiles at most once. Responses in submission order."""
        pending, self._pending = self._pending, []
        if not pending:
            return []
        t_wall = time.perf_counter()
        # stage 1 for every request: build plans, group by cache key
        built = []
        groups: dict[Any, list[int]] = {}
        # "auto" memo: requests sharing the exact same inputs object resolve
        # the cost model once (strategy choice is value-dependent, so the
        # memo is keyed on object identity, valid for this drain's lifetime)
        auto_memo: dict[tuple, Any] = {}
        for i, req in enumerate(pending):
            op = resolve_op(req.op)
            strategy = req.strategy
            if isinstance(strategy, str) and strategy == "auto":
                memo_key = (op.name, id(req.inputs))
                if memo_key not in auto_memo:
                    from .autotune import choose_strategy

                    auto_memo[memo_key] = choose_strategy(op, req.inputs)
                strategy = auto_memo[memo_key]
            plan = build_plan(op, req.inputs, strategy, req.substrate)
            built.append((req, op, plan))
            # keyless plans get singleton groups (ticket-unique key)
            gkey = plan.key if plan.key is not None else ("__unkeyed__", req.ticket)
            groups.setdefault(gkey, []).append(i)
        # stages 2+3 per group: first request compiles, the rest reuse
        responses: list[ServiceResponse] = []
        for members in groups.values():
            for i in members:
                req, op, plan = built[i]
                result, report = run_plan(
                    plan, op, iters=1, warmup=0, cache=self.cache
                )
                responses.append(ServiceResponse(req.ticket, result, report))
                self._stats.requests += 1
                self._stats.cache_hits += int(report.cache_hit)
                self._stats.compiles += int(not report.cache_hit)
                self._stats.compile_seconds += report.compile_seconds
                # a cold request's single timed call IS the compile call;
                # count only its steady-state remainder as run time
                self._stats.run_seconds += report.seconds - report.compile_seconds
        self._stats.batches += len(groups)
        self._stats.drains += 1
        self._stats.wall_seconds += time.perf_counter() - t_wall
        responses.sort(key=lambda r: r.ticket)
        return responses

    def stats(self) -> ServiceStats:
        return self._stats

    def throughput_report(self) -> dict[str, Any]:
        """Aggregate record: service counters + plan-cache health."""
        return {**self._stats.to_dict(), "cache": self.cache.stats()}
