"""EngineService: the serving front-end over the plan/compile/execute
pipeline, in two modes (DESIGN.md §1d).

**Batch mode** (the default, PR 2's API): ``submit()`` returns an int ticket
and nothing runs until ``drain()`` executes everything, grouped by plan key
so each group compiles at most once.

    svc = EngineService()
    t = svc.submit(Request("spmv", inputs))      # -> int ticket
    responses = svc.drain()                      # one compile per plan key

**Worker-loop mode** (the serving path): ``start()`` spawns an *execution
plane* — one scheduler/compile thread feeding a pool of N executor workers:

- the **scheduler** pops plan-key groups off the admission queue, orders
  them by QoS weight, places each group on a pool slot (substrate-aware:
  per-device affinity on mesh, round-robin on local), runs the group's
  first (possibly compiling) call, and hands warm work to the slot's queue;
- each **executor worker** serves its queue of cache-hit calls in QoS
  order; an idle worker steals queued (or straggling) groups from the
  busiest peer — but only on "spread" substrates, never from a device-pinned
  mesh slot.

While worker ``k`` executes group N, the scheduler is already compiling
group N+1 and the other workers are executing other groups — the
keep-contexts-in-flight structure of the migratory-thread model: independent
memory-side work proceeds on every channel at once, and compile time hides
under execution instead of adding to it.

    svc = EngineService(workers=4, max_queue_depth=256, qos={"bfs": 2.0})
    svc.start()
    fut = svc.submit(Request("spmv", inputs))    # -> ServiceFuture, non-blocking
    resp = fut.result(timeout=60)                # ServiceResponse
    svc.stop()                                   # drains by default
    print(svc.stats().worker_occupancy)          # per-worker utilization

Admission control: ``max_queue_depth`` bounds the request queue;
``admission="block"`` applies backpressure to submitters (requires a running
worker to make progress), ``admission="reject"`` raises
:class:`AdmissionError` immediately (counted in ``ServiceStats.rejected``).
``qos`` maps op names to scheduling weights — within each queue snapshot,
higher-weight groups run first, and the per-slot queues preserve that order
within every worker (ordering, not preemption).

Results are **bit-identical** to sequential ``engine.run`` in both modes
and at any pool width: each request still executes the same cached-executor
call the synchronous path would have run; concurrency changes *when* plans
compile and *where* warm calls run, never what they compute
(``tests/test_service_async.py`` and ``tests/test_service_pool.py`` pin
this under concurrent mixed-op load for W ∈ {1, 2, 4}).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import threading
import time
from collections import deque
from typing import Any

from ..core.strategies import MigratoryStrategy
from .api import RunReport
from .cache import PlanCache
from .request import Request, coerce_request
from .runner import build_plan, resolve_op, single_call
from .substrate import Substrate, get_substrate

# per-request latency samples kept for percentile estimation (newest wins;
# bounds memory for long-lived services, like the span folding below)
_LATENCY_WINDOW = 4096

# workers="auto" resolves to min(this, substrate.placement_slots())
_AUTO_WORKER_CAP = 8

# placement memory (base plan key -> slot) is LRU-bounded; evicting a pin
# only costs a re-placement, never correctness
_PIN_TABLE_MAX = 4096


def _percentile(ordered: "list[float]", q: float) -> float:
    """Nearest-rank percentile of an already-sorted sample list."""
    if not ordered:
        return 0.0
    idx = min(len(ordered) - 1, max(0, int(round(q * (len(ordered) - 1)))))
    return ordered[idx]


class AdmissionError(RuntimeError):
    """submit() refused: the queue is full under the 'reject' policy (or
    'block' with no worker running to ever free space)."""


class ServiceStopped(RuntimeError):
    """The service shut down: raised by submissions after stop() and by
    futures whose queued request was cancelled by stop(drain=False)."""


class ServiceTimeout(RuntimeError):
    """A request's per-request deadline (``Request.timeout``) passed while
    it was still queued: the service shed it instead of running it (counted
    in ``ServiceStats.timed_out``)."""


@dataclasses.dataclass(frozen=True)
class ServiceRequest:
    ticket: int
    op: Any
    inputs: Any
    strategy: "MigratoryStrategy | str | None"
    substrate: "Substrate | str"
    t_admit: float = 0.0  # perf_counter at admission (queue-wait percentiles)
    qos: "float | None" = None  # per-request weight override (Request.qos)
    timeout: "float | None" = None  # deadline seconds from admission


@dataclasses.dataclass
class ServiceResponse:
    ticket: int
    result: Any
    report: RunReport


class ServiceFuture:
    """Handle for one worker-loop submission — what async ``submit`` returns.

    ``result(timeout)`` blocks until the request is served and returns its
    :class:`ServiceResponse`; it re-raises the request's exception if the
    run failed or the service dropped it (:class:`ServiceStopped`).
    """

    def __init__(self, ticket: int):
        self.ticket = ticket
        self._done = threading.Event()
        self._response: ServiceResponse | None = None
        self._exception: BaseException | None = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: "float | None" = None) -> ServiceResponse:
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.ticket} not served within {timeout}s")
        if self._exception is not None:
            raise self._exception
        return self._response

    def exception(self, timeout: "float | None" = None) -> "BaseException | None":
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.ticket} not served within {timeout}s")
        return self._exception

    def _resolve(self, response: ServiceResponse) -> None:
        self._response = response
        self._done.set()

    def _reject(self, exc: BaseException) -> None:
        self._exception = exc
        self._done.set()


@dataclasses.dataclass
class _WorkItem:
    """One admitted worker-loop request moving through the pipeline.

    ``waiters`` are in-flight-coalesced duplicates: (ticket, future) pairs
    of value-identical requests that attached to this item instead of
    queueing. They resolve (or fail, or cancel) with it, atomically."""

    request: ServiceRequest
    future: ServiceFuture
    op: Any = None
    plan: Any = None
    dedup_key: "str | None" = None  # content hash when dedup is enabled
    waiters: "list[tuple[int, ServiceFuture]]" = dataclasses.field(
        default_factory=list
    )


@dataclasses.dataclass
class _Group:
    """One plan-key group placed on a pool slot — the scheduling unit of the
    execution plane. ``items`` is consumed head-first by the owning worker;
    stealers split from the tail, so arrival order survives on the owner."""

    key: Any
    qos: float
    first_ticket: int
    slot: int = 0
    stealable: bool = True
    stolen: bool = False  # arrived at its worker via a steal, not dispatch
    items: "deque[_WorkItem]" = dataclasses.field(default_factory=deque)


def _content_hash(op: Any, inputs: Any, strategy: Any, substrate: Any) -> str:
    """Value-keyed identity of one request: op name x strategy identity x
    substrate fingerprint x the *bytes* of every input leaf. Two requests
    with equal hashes are the same computation — ops are pure — so the
    service may answer the second from the first's response.

    Built on the engine's stable wire encoding
    (:func:`~repro.engine.wire.canonical_bytes`, DESIGN.md §1h), the same
    bytes a :class:`~repro.engine.request.Request` serializes to for the
    cluster protocol — so "identical computation" means exactly one thing
    whether a duplicate is answered in-process or routed to a worker, and
    the hash is stable across processes."""
    from .wire import canonical_bytes

    h = hashlib.sha256()
    op_name = op if isinstance(op, str) else getattr(op, "name", repr(op))
    strat_id = (
        strategy.cache_key() if isinstance(strategy, MigratoryStrategy) else strategy
    )
    h.update(canonical_bytes((op_name, strat_id, inputs)))
    h.update(repr(get_substrate(substrate).cache_fingerprint()).encode())
    return h.hexdigest()


def _union_seconds(spans: "list[tuple[float, float]]") -> float:
    """Total covered time of possibly-overlapping (t0, t1) spans."""
    total = 0.0
    cur_start = cur_end = None
    for t0, t1 in sorted(spans):
        if cur_end is None or t0 > cur_end:
            if cur_end is not None:
                total += cur_end - cur_start
            cur_start, cur_end = t0, t1
        else:
            cur_end = max(cur_end, t1)
    if cur_end is not None:
        total += cur_end - cur_start
    return total


def _merge_spans(
    spans: "list[tuple[float, float]]",
) -> "list[tuple[float, float]]":
    """Union of spans as a sorted, non-overlapping span list (the executor
    pool's N workers overlap each other; merging first keeps the two-pointer
    intersection below exact)."""
    merged: list[tuple[float, float]] = []
    for t0, t1 in sorted(spans):
        if merged and t0 <= merged[-1][1]:
            if t1 > merged[-1][1]:
                merged[-1] = (merged[-1][0], t1)
        else:
            merged.append((t0, t1))
    return merged


def _intersection_seconds(
    a: "list[tuple[float, float]]", b: "list[tuple[float, float]]"
) -> float:
    """Total time spans from ``a`` and ``b`` ran simultaneously. Each list
    must be internally non-overlapping (``a``: the single scheduler thread;
    ``b``: pre-merged via :func:`_merge_spans`), so a two-pointer sweep is
    exact."""
    a, b = sorted(a), sorted(b)
    i = j = 0
    total = 0.0
    while i < len(a) and j < len(b):
        lo = max(a[i][0], b[j][0])
        hi = min(a[i][1], b[j][1])
        if hi > lo:
            total += hi - lo
        if a[i][1] < b[j][1]:
            i += 1
        else:
            j += 1
    return total


@dataclasses.dataclass
class ServiceStats:
    """Aggregate serving counters across the service's lifetime, both modes.

    Timing semantics (the ``to_dict()`` schema):

    - ``wall_seconds`` — observable serving window. Batch mode: summed
      ``drain()`` wall time (unchanged from PR 2). Worker mode: first
      admission -> latest completion, so idle time between bursts counts —
      it is the denominator of sustained ``requests_per_second``.
    - ``busy_seconds`` — time at least one pipeline stage was doing work
      (union of compile-stage and all executor-worker spans; equals wall
      time in batch mode, where drain() is always busy). ``wall - busy`` is
      idle.
    - ``overlap_seconds`` — time the compile stage of one plan-key group ran
      simultaneously with any worker executing another;
      ``overlap_ratio = overlap_seconds / total compile-stage seconds`` is
      the fraction of compile time hidden under execution (0 in batch mode).
    - ``queue_wait_p50/p95/p99`` — per-request admission -> run-start wait;
      ``service_p50/p95/p99`` — per-request run duration (ROADMAP "latency
      accounting"); ``total_p50/p95/p99`` — admission -> completion, the
      end-to-end latency a client observes (queue wait + service time).
      Estimated over the most recent ``_LATENCY_WINDOW`` executed requests;
      dedup-served requests wait for neither and are excluded.
    - SLO accounting (``slo_target_seconds`` on the constructor): every
      executed request's *total* latency is checked against the declared
      target — ``slo_checked``/``slo_violations`` count them cumulatively
      and ``slo_attainment`` is the within-target fraction. ``timed_out``
      counts requests shed at their per-request ``Request.timeout``
      deadline instead of running (their futures raise
      :class:`ServiceTimeout`; they are neither errors nor SLO samples).
    - ``dedup_hits`` — requests answered from the value-keyed response cache
      without executing (``dedup=True`` services only). ``dedup_coalesced``
      is the in-flight subset: duplicates that attached to a *pending*
      identical request's future instead of waiting for it to complete
      first (so ``dedup_hits - dedup_coalesced`` answered post-completion).
    - ``workers``/``steals`` and the ``worker_*`` columns — the execution
      plane: pool width, total stolen groups, and per-worker busy seconds /
      executed requests / steals / occupancy (busy ÷ serving window). One
      ``to_dict()`` row carries the merged view so bench artifacts stay a
      single record per run.
    """

    requests: int = 0
    batches: int = 0
    drains: int = 0
    cache_hits: int = 0
    compiles: int = 0
    compile_seconds: float = 0.0
    run_seconds: float = 0.0  # steady-state execution seconds (compile excluded)
    wall_seconds: float = 0.0  # serving window (see class docstring)
    busy_seconds: float = 0.0  # >=1 pipeline stage active (see class docstring)
    queue_depth_hwm: int = 0  # high-water mark of the admission queue
    rejected: int = 0  # admission-control rejections
    cancelled: int = 0  # queued requests dropped by stop(drain=False)
    errors: int = 0  # requests whose plan/execute raised
    overlap_seconds: float = 0.0
    overlap_ratio: float = 0.0
    dedup_hits: int = 0  # responses served from the value-keyed dedup cache
    dedup_coalesced: int = 0  # ... of which attached to an in-flight primary
    workers: int = 1  # executor-pool width (1 = the pre-pool pipeline)
    steals: int = 0  # groups (or group tails) migrated to an idle worker
    timed_out: int = 0  # requests shed at their per-request deadline
    queue_wait_p50: float = 0.0
    queue_wait_p95: float = 0.0
    queue_wait_p99: float = 0.0
    service_p50: float = 0.0
    service_p95: float = 0.0
    service_p99: float = 0.0
    total_p50: float = 0.0  # admission -> completion (queue wait + service)
    total_p95: float = 0.0
    total_p99: float = 0.0
    slo_target_seconds: "float | None" = None
    slo_checked: int = 0  # executed requests measured against the target
    slo_violations: int = 0  # ... of which exceeded it
    worker_busy_seconds: "list[float]" = dataclasses.field(default_factory=list)
    worker_requests: "list[int]" = dataclasses.field(default_factory=list)
    worker_steals: "list[int]" = dataclasses.field(default_factory=list)
    worker_occupancy: "list[float]" = dataclasses.field(default_factory=list)
    #: peak per-worker occupancy observed across stats() snapshots — the
    #: monotone high-water mark an autoscaler compares against its grow
    #: threshold even if the pool has since gone idle
    occupancy_hwm: float = 0.0
    #: cluster data-plane counters (DESIGN.md §1h). Zero in-process; the
    #: cluster coordinator/worker planes merge real wire traffic and
    #: content-addressed blob-store activity into their stats rows.
    wire_bytes_sent: int = 0
    wire_bytes_received: int = 0
    blob_hits: int = 0  # blobrefs resolved from a local blob store
    blob_misses: int = 0  # blobrefs that needed a need_blob re-fetch

    @property
    def requests_per_second(self) -> float:
        return self.requests / self.wall_seconds if self.wall_seconds > 0 else 0.0

    @property
    def amortization(self) -> float:
        """Requests served per compile — the batching win."""
        return self.requests / self.compiles if self.compiles else float(self.requests)

    @property
    def slo_attainment(self) -> "float | None":
        """Fraction of SLO-checked requests whose total latency met the
        declared target; None when no target was declared (or nothing ran)."""
        if self.slo_target_seconds is None or self.slo_checked == 0:
            return None
        return 1.0 - self.slo_violations / self.slo_checked

    def resize_signal(
        self, *, grow_above: float = 0.75, shrink_below: float = 0.25
    ) -> str:
        """``"grow" | "hold" | "shrink"`` from per-worker occupancy — the
        elastic-pool resize trigger (ROADMAP) a cluster autoscaler drives.

        - **grow**: mean occupancy at/above ``grow_above`` — every extra
          worker would have found work; so would an extra process.
        - **shrink**: more than one worker and even the *busiest* sits
          at/below ``shrink_below`` — the pool would fit in fewer workers
          with headroom to spare.
        - **hold**: everything in between, or nothing observed yet.

        Computed on this snapshot's occupancy columns (busy ÷ serving
        window); :attr:`occupancy_hwm` carries the historical peak for
        autoscalers that want hysteresis against a recent burst."""
        occ = self.worker_occupancy
        if not occ or self.wall_seconds <= 0.0:
            return "hold"
        mean = sum(occ) / len(occ)
        if mean >= grow_above:
            return "grow"
        if len(occ) > 1 and max(occ) <= shrink_below:
            return "shrink"
        return "hold"

    def to_dict(self) -> dict[str, Any]:
        return {
            "requests": self.requests,
            "batches": self.batches,
            "drains": self.drains,
            "cache_hits": self.cache_hits,
            "compiles": self.compiles,
            "compile_seconds": self.compile_seconds,
            "run_seconds": self.run_seconds,
            "wall_seconds": self.wall_seconds,
            "busy_seconds": self.busy_seconds,
            "queue_depth_hwm": self.queue_depth_hwm,
            "rejected": self.rejected,
            "cancelled": self.cancelled,
            "errors": self.errors,
            "overlap_seconds": self.overlap_seconds,
            "overlap_ratio": self.overlap_ratio,
            "dedup_hits": self.dedup_hits,
            "dedup_coalesced": self.dedup_coalesced,
            "workers": self.workers,
            "steals": self.steals,
            "timed_out": self.timed_out,
            "queue_wait_p50": self.queue_wait_p50,
            "queue_wait_p95": self.queue_wait_p95,
            "queue_wait_p99": self.queue_wait_p99,
            "service_p50": self.service_p50,
            "service_p95": self.service_p95,
            "service_p99": self.service_p99,
            "total_p50": self.total_p50,
            "total_p95": self.total_p95,
            "total_p99": self.total_p99,
            "slo_target_seconds": self.slo_target_seconds,
            "slo_checked": self.slo_checked,
            "slo_violations": self.slo_violations,
            "slo_attainment": self.slo_attainment,
            "worker_busy_seconds": self.worker_busy_seconds,
            "worker_requests": self.worker_requests,
            "worker_steals": self.worker_steals,
            "worker_occupancy": self.worker_occupancy,
            "occupancy_hwm": self.occupancy_hwm,
            "wire_bytes_sent": self.wire_bytes_sent,
            "wire_bytes_received": self.wire_bytes_received,
            "blob_hits": self.blob_hits,
            "blob_misses": self.blob_misses,
            "resize_signal": self.resize_signal(),
            "requests_per_second": self.requests_per_second,
            "amortization": self.amortization,
        }


class EngineService:
    """Serving front-end over the plan/compile/execute pipeline.

    Constructed services are in batch mode; ``start()`` switches to the
    worker loop (module docstring). ``workers`` sets the executor-pool
    width: an int, or ``"auto"`` to size from the default substrate's
    ``placement_slots()`` (capped at 8). Admission-control and QoS knobs
    apply to both modes; ``batch_window`` is the micro-batching window —
    after the first request of a burst arrives, the scheduler waits this
    long before snapshotting the queue so bursts group into fewer, larger
    plan-key groups; ``pipeline_depth`` scales the plane's dispatch budget
    — at most ``pipeline_depth * workers`` groups queued across the pool
    (a shared budget, not a per-worker cap: the scheduler never blocks on
    one hot slot while others starve) — as backpressure on the scheduler.

    ``dedup=True`` puts a value-keyed response cache in front of the
    pipeline: requests whose op + strategy + substrate + input *values*
    content-hash to an already-served request are answered from the stored
    response without planning or executing, and concurrent identical
    requests coalesce onto the pending request's future
    (``ServiceStats.dedup_hits`` / ``dedup_coalesced``). Sound because ops
    are pure functions of their inputs; the replayed response carries the
    original execution's report. Off by default — hashing large input
    pytrees on every submit is not free.
    """

    def __init__(
        self,
        cache: PlanCache | None = None,
        substrate: "Substrate | str" = "local",
        autotune: bool = False,
        *,
        workers: "int | str" = 1,
        max_queue_depth: "int | None" = None,
        admission: str = "block",
        qos: "dict[str, float] | None" = None,
        batch_window: float = 0.0,
        pipeline_depth: int = 2,
        dedup: bool = False,
        dedup_max_entries: int = 256,
        slo_target_seconds: "float | None" = None,
    ):
        if admission not in ("block", "reject"):
            raise ValueError(
                f"admission must be 'block' or 'reject', got {admission!r}"
            )
        if isinstance(workers, str):
            if workers != "auto":
                raise ValueError(f"workers must be an int >= 1 or 'auto', got {workers!r}")
        elif int(workers) < 1:
            raise ValueError(f"workers must be an int >= 1 or 'auto', got {workers!r}")
        self.cache = cache if cache is not None else PlanCache()
        self.default_substrate = substrate
        self.autotune = autotune
        self.workers = workers
        self.max_queue_depth = max_queue_depth
        self.admission = admission
        # validate weights here: a bad value must fail the constructor, not
        # the scheduler inside the worker thread
        self.qos = {name: float(weight) for name, weight in (qos or {}).items()}
        self.batch_window = batch_window
        self.pipeline_depth = max(1, pipeline_depth)
        self.dedup = dedup
        self.dedup_max_entries = max(1, dedup_max_entries)
        if slo_target_seconds is not None and float(slo_target_seconds) <= 0:
            raise ValueError(
                f"slo_target_seconds must be > 0, got {slo_target_seconds!r}"
            )
        self.slo_target_seconds = slo_target_seconds
        # value-keyed response store: content hash -> served ServiceResponse
        self._dedup_store: "collections.OrderedDict[str, ServiceResponse]" = (
            collections.OrderedDict()
        )
        # content hash -> the in-flight primary item coalesced waiters attach to
        self._dedup_pending: "dict[str, _WorkItem]" = {}
        # per-request latency samples (bounded; see ServiceStats docstring)
        self._queue_waits: deque = deque(maxlen=_LATENCY_WINDOW)
        self._service_times: deque = deque(maxlen=_LATENCY_WINDOW)
        self._total_latencies: deque = deque(maxlen=_LATENCY_WINDOW)
        self._pending: list[ServiceRequest] = []
        self._next_ticket = 0
        self._stats = ServiceStats()
        # worker-loop state: one lock, five conditions on it
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)  # scheduler: items arrived
        self._space = threading.Condition(self._lock)  # submitters: space freed
        self._idle = threading.Condition(self._lock)  # flush(): all resolved
        self._pool_work = threading.Condition(self._lock)  # workers: groups queued
        self._pool_space = threading.Condition(self._lock)  # scheduler: slot freed
        self._queue: deque[_WorkItem] = deque()
        self._inflight = 0  # admitted worker requests not yet resolved
        self._running = False
        self._stopping = False
        self._sched_done = False  # scheduler exited; workers may drain + exit
        self._cancel_queued = False  # stop(drain=False): cancel undispatched work
        self._threads: list[threading.Thread] = []
        # the execution plane: per-worker group queues + in-progress groups
        self._n_workers = 1
        self._pool_queues: "list[list[_Group]]" = []
        self._pool_current: "list[_Group | None]" = []
        self._worker_spans: "list[list[tuple[float, float]]]" = []
        self._worker_busy: list[float] = []
        self._worker_reqs: list[int] = []
        self._worker_steal_counts: list[int] = []
        # placement memory: base plan key -> slot (scheduler thread only)
        self._pins: "collections.OrderedDict[Any, int]" = collections.OrderedDict()
        self._rr_next = 0
        # every not-yet-done worker-mode future, for the shutdown sweep that
        # guarantees no submitted future is ever stranded
        self._live: "dict[int, ServiceFuture]" = {}
        # (worker, first_ticket, qos) per executed group — bounded debug
        # trace the pool tests assert per-worker QoS ordering against
        self._exec_trace: deque = deque(maxlen=4096)
        self._compile_spans: list[tuple[float, float]] = []
        # long-run safety: spans periodically fold into these accumulators so
        # a service alive for millions of requests stays O(1) in memory
        self._overlap_acc = 0.0
        self._busy_acc = 0.0
        self._compile_busy_acc = 0.0
        self._drain_wall = 0.0
        self._t_first: "float | None" = None
        self._t_last: "float | None" = None
        self._occ_hwm = 0.0  # peak per-worker occupancy across snapshots

    def __len__(self) -> int:
        """Unserved requests: batch-pending plus worker-admitted in flight."""
        with self._lock:
            return len(self._pending) + self._inflight

    # -- admission -------------------------------------------------------------

    def qos_weight(self, op_name: str) -> float:
        return float(self.qos.get(op_name, 1.0))

    def _effective_qos(self, item: _WorkItem) -> float:
        """Per-request ``Request.qos`` override, else the per-op table."""
        q = item.request.qos
        return float(q) if q is not None else self.qos_weight(item.op.name)

    def _resolve_workers(self) -> int:
        if isinstance(self.workers, int):
            return max(1, self.workers)
        sub = get_substrate(self.default_substrate)
        return max(1, min(_AUTO_WORKER_CAP, sub.placement_slots()))

    def _admit_locked(self) -> None:
        if self._stopping:
            raise ServiceStopped("service stopped; no new submissions")
        if self.max_queue_depth is None:
            return
        while (
            len(self._queue) if self._running else len(self._pending)
        ) >= self.max_queue_depth:
            if self.admission == "reject" or not self._running:
                self._stats.rejected += 1
                reason = (
                    "policy is 'reject'"
                    if self.admission == "reject"
                    else "'block' needs a running worker to free space; call start()"
                )
                raise AdmissionError(
                    f"queue full ({self.max_queue_depth} requests); {reason}"
                )
            self._space.wait(timeout=0.1)
            if self._stopping:
                raise ServiceStopped("service stopped while blocked on admission")

    def submit(
        self,
        op: "Request | Any",
        inputs: Any = None,
        strategy: "MigratoryStrategy | str | None" = None,
        substrate: "Substrate | str | None" = None,
    ) -> "int | ServiceFuture":
        """Enqueue one :class:`~repro.engine.request.Request`. Batch mode
        returns its int ticket (serve via ``drain()``); worker-loop mode
        returns a :class:`ServiceFuture`. The deprecated kwargs form
        (``submit(op, inputs, ...)``) still works with a
        ``DeprecationWarning``. ``Request.qos`` overrides the service's
        per-op weight for this request's group; ``Request.timeout`` is a
        deadline from admission — still-queued past it, the request is shed
        (:class:`ServiceTimeout`). Full queues block or raise per the
        admission policy. With ``dedup=True``, a worker-mode request whose
        content hash matches an already-*served* response resolves
        immediately, and one matching a *pending* identical request
        coalesces onto its future — neither enters the queue (batch mode
        dedups inside ``drain()``)."""
        request = coerce_request(op, inputs, strategy, substrate, entry="submit")
        op, inputs, strategy = request.op, request.inputs, request.strategy
        if strategy is None and self.autotune:
            strategy = "auto"
        sub = (
            request.substrate
            if request.substrate is not None
            else self.default_substrate
        )
        dkey = None
        # batch mode hashes inside drain() instead — a submit-time hash could
        # never serve a hit there (responses only exist once drain runs)
        if self.dedup and self._running:
            dkey = _content_hash(op, inputs, strategy, sub)  # outside the lock
        with self._lock:
            if dkey is not None and self._running and not self._stopping:
                served = self._dedup_submit_locked(dkey)
                if served is not None:
                    return served
            self._admit_locked()
            if dkey is not None and self._running:
                # _admit_locked may have blocked; the answer (or a pending
                # primary) may have appeared while we waited
                served = self._dedup_submit_locked(dkey)
                if served is not None:
                    return served
            ticket = self._next_ticket
            self._next_ticket += 1
            req = ServiceRequest(
                ticket=ticket,
                op=op,
                inputs=request.inputs,
                strategy=strategy,
                substrate=sub,
                t_admit=time.perf_counter(),
                qos=request.qos,
                timeout=request.timeout,
            )
            if self._running:
                future = ServiceFuture(ticket)
                item = _WorkItem(req, future, dedup_key=dkey)
                if dkey is not None:
                    self._dedup_pending[dkey] = item
                self._queue.append(item)
                self._live[ticket] = future
                self._inflight += 1
                if self._t_first is None:
                    self._t_first = time.perf_counter()
                self._stats.queue_depth_hwm = max(
                    self._stats.queue_depth_hwm, len(self._queue)
                )
                self._work.notify()
                return future
            self._pending.append(req)
            self._stats.queue_depth_hwm = max(
                self._stats.queue_depth_hwm, len(self._pending)
            )
            return ticket

    def _dedup_submit_locked(self, dkey: str) -> "ServiceFuture | None":
        """Submit-time dedup: serve from the response store, or coalesce
        onto a pending identical request. None = no hit, enqueue normally."""
        hit = self._dedup_store.get(dkey)
        if hit is not None:
            self._dedup_store.move_to_end(dkey)
            ticket = self._next_ticket
            self._next_ticket += 1
            self._stats.requests += 1
            self._stats.dedup_hits += 1
            future = ServiceFuture(ticket)
            future._resolve(ServiceResponse(ticket, hit.result, hit.report))
            return future
        prim = self._dedup_pending.get(dkey)
        if prim is not None:
            if prim.future.done():
                # primary finished between resolving its future and its
                # locked bookkeeping; serve from its response if it has one
                resp = prim.future._response
                if resp is None:
                    return None  # primary failed: caller becomes a new primary
                ticket = self._next_ticket
                self._next_ticket += 1
                self._stats.requests += 1
                self._stats.dedup_hits += 1
                future = ServiceFuture(ticket)
                future._resolve(ServiceResponse(ticket, resp.result, resp.report))
                return future
            ticket = self._next_ticket
            self._next_ticket += 1
            future = ServiceFuture(ticket)
            prim.waiters.append((ticket, future))
            self._live[ticket] = future
            return future
        return None

    # -- lifecycle -------------------------------------------------------------

    def start(self) -> "EngineService":
        """Spawn the execution plane (scheduler + executor pool); subsequent
        ``submit()`` calls return futures. Restartable after ``stop()``."""
        with self._lock:
            if self._running:
                raise RuntimeError("service already started")
            if self._pending:
                raise RuntimeError(
                    "drain() pending batch-mode requests before start()"
                )
            self._running = True
            self._stopping = False
            self._sched_done = False
            self._cancel_queued = False
            self._n_workers = self._resolve_workers()
            n = self._n_workers
            self._pool_queues = [[] for _ in range(n)]
            self._pool_current = [None] * n
            while len(self._worker_spans) < n:
                self._worker_spans.append([])
                self._worker_busy.append(0.0)
                self._worker_reqs.append(0)
                self._worker_steal_counts.append(0)
            self._threads = [
                threading.Thread(
                    target=self._scheduler_loop,
                    name="engine-service-scheduler",
                    daemon=True,
                )
            ] + [
                threading.Thread(
                    target=self._worker_loop,
                    args=(w,),
                    name=f"engine-service-exec-{w}",
                    daemon=True,
                )
                for w in range(n)
            ]
            threads = list(self._threads)
        for t in threads:
            t.start()
        return self

    def stop(self, drain: bool = True, timeout: "float | None" = None) -> None:
        """Graceful shutdown. ``drain=True`` serves everything already
        admitted first; ``drain=False`` cancels still-queued requests — in
        the admission queue, in every worker's group queue, *and* in the
        scheduler's not-yet-compiled snapshot — along with their coalesced
        waiters (the futures raise :class:`ServiceStopped`; groups already
        compiled or handed to a worker complete).
        After the pool joins, a final sweep rejects any future that somehow
        survived, so every submitted future terminates. Idempotent;
        ``start()`` again to restart. If ``timeout`` expires with workers
        still running, raises TimeoutError and leaves the service in the
        stopping state — call ``stop()`` again; it never marks a
        still-running service as stopped."""
        with self._lock:
            if not self._running:
                return
            self._stopping = True
            if not drain:
                self._cancel_queued = True
                while self._queue:
                    self._cancel_item_locked(self._queue.popleft())
                for q in self._pool_queues:
                    for group in q:
                        while group.items:
                            self._cancel_item_locked(group.items.popleft())
                    q.clear()
                self._idle.notify_all()
                self._pool_space.notify_all()
            self._work.notify_all()
            self._space.notify_all()
            self._pool_work.notify_all()
            threads = list(self._threads)
        for t in threads:
            t.join(timeout)
        alive = [t.name for t in threads if t.is_alive()]
        if alive:
            # a later start() must not spawn a second pipeline racing this one
            raise TimeoutError(
                f"stop() timed out with worker thread(s) still running: {alive}; "
                "call stop() again"
            )
        with self._lock:
            self._running = False
            self._threads = []
            # regression net (the stop/mid-flight race): with the plane shut
            # down, any future neither resolved nor cancelled is stranded
            # forever — reject it now so every submitted future terminates
            leaked = [f for f in self._live.values() if not f.done()]
            for fut in leaked:
                fut._reject(
                    ServiceStopped("service stopped with this request unresolved")
                )
                self._stats.cancelled += 1
            self._live.clear()
            self._dedup_pending.clear()
            if leaked:
                self._inflight = 0
                self._idle.notify_all()
            # _stopping stays True: submit() after stop raises ServiceStopped
            # until start() is called again.

    def __enter__(self) -> "EngineService":
        return self.start()

    def __exit__(self, *exc_info) -> None:
        self.stop()

    def flush(self, timeout: "float | None" = None) -> None:
        """Block until every admitted worker-loop request has resolved."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            while self._queue or self._inflight:
                if deadline is not None and time.monotonic() >= deadline:
                    raise TimeoutError("flush timed out with work still in flight")
                self._idle.wait(timeout=0.1)

    # -- the execution plane ---------------------------------------------------

    def _scheduler_loop(self) -> None:
        """The plane's single compile stage: snapshot the queue, schedule
        plan-key groups by QoS, place each on a pool slot, run cold groups'
        compiling call, feed warm work to the executor workers."""
        try:
            while True:
                with self._lock:
                    while not self._queue and not self._stopping:
                        self._work.wait(timeout=0.1)
                    if not self._queue:
                        if self._stopping:
                            break
                        continue
                if self.batch_window > 0:
                    time.sleep(self.batch_window)  # let the burst accumulate
                with self._lock:
                    snapshot = [item for item in self._queue]
                    self._queue.clear()
                    self._space.notify_all()
                try:
                    dispatched: set[int] = set()
                    for items in self._plan_groups(snapshot):
                        with self._lock:
                            # stop(drain=False) after the snapshot was taken:
                            # honor it — groups not yet compiled or handed to
                            # a worker cancel like still-queued requests do
                            if self._cancel_queued:
                                for item in items:
                                    if not item.future.done():
                                        self._cancel_item_locked(item)
                                        dispatched.add(id(item))
                                self._idle.notify_all()
                                continue
                        group = self._place_group(items)
                        if group is None:
                            continue
                        with self._lock:
                            self._stats.batches += 1
                        if not self.cache.is_warm(group.key):
                            first = group.items.popleft()
                            dispatched.add(id(first))
                            self._compile_item(first, group.slot)
                        if group.items:
                            dispatched.update(id(it) for it in group.items)
                            self._dispatch_group(group)
                except Exception as exc:
                    # defensive: a scheduler bug must not strand futures —
                    # reject the snapshot's undispatched requests (the
                    # executor pool owns the dispatched ones) and keep going
                    for item in snapshot:
                        if id(item) not in dispatched and not item.future.done():
                            self._finish_error(item, exc)
        finally:
            with self._lock:
                self._sched_done = True
                self._pool_work.notify_all()

    def _place_group(self, items: "list[_WorkItem]") -> "_Group | None":
        """Substrate-aware placement: resolve the group's slot (pinned key >
        cache pin > round-robin) and, when the substrate carves per-slot
        variants (mesh device windows), rebuild the members' plans against
        the slot's variant so their compiled executables are keyed to it."""
        if not items:
            return None
        first = items[0]
        base_sub = get_substrate(first.request.substrate)
        bkey = (
            first.plan.key
            if first.plan.key is not None
            else ("__unkeyed__", first.request.ticket)
        )
        n = self._n_workers
        slot = 0
        affinity = base_sub.placement_policy == "affinity"
        if n > 1:
            if affinity:
                # sticky: a key re-routes to the slot that compiled it, so
                # its device-window executable never migrates
                slot = self._pins.get(bkey)
                if slot is None:
                    slot = self.cache.slot_of(first.plan.key)
                if slot is None:
                    slot = self._rr_next % n
                    self._rr_next += 1
                slot %= n
                self._pins[bkey] = slot
                self._pins.move_to_end(bkey)
                while len(self._pins) > _PIN_TABLE_MAX:
                    self._pins.popitem(last=False)
                # also pin the base key in the cache: the compiled entry
                # lives under the slot-variant key, so without this alias a
                # fresh service (or an evicted _pins entry) would re-place
                # the key and recompile it against a different window
                self.cache.pin_key(first.plan.key, slot)
            else:
                # spread: plain round-robin; stealing rebalances the rest
                slot = self._rr_next % n
                self._rr_next += 1
            variant = base_sub.placement_variant(slot, n)
            if variant is not base_sub:
                # one rebuild per group: members share an identity plan
                try:
                    plan = build_plan(
                        first.op, first.request.inputs, first.plan.strategy, variant
                    )
                except Exception as exc:  # placement failures reject the group
                    for item in items:
                        self._finish_error(item, exc)
                    return None
                for item in items:
                    item.plan = plan
        return _Group(
            key=items[0].plan.key,
            qos=self._effective_qos(items[0]),
            first_ticket=items[0].request.ticket,
            slot=slot,
            stealable=not affinity,
            items=deque(items),
        )

    def _dispatch_group(self, group: _Group) -> None:
        """Hand a (now warm) group to its slot's queue, QoS-ordered. The
        plane holds at most ``pipeline_depth * workers`` queued groups in
        total (backpressure on the scheduler; a shared budget so dispatch
        to idle slots never blocks behind one hot slot's queue)."""
        with self._lock:
            while (
                sum(len(q) for q in self._pool_queues)
                >= self.pipeline_depth * self._n_workers
            ):
                self._pool_space.wait(timeout=0.1)
            q = self._pool_queues[group.slot]
            rank = (-group.qos, group.first_ticket)
            idx = len(q)
            for i, queued in enumerate(q):
                if (-queued.qos, queued.first_ticket) > rank:
                    idx = i
                    break
            q.insert(idx, group)
            self._pool_work.notify_all()

    def _worker_loop(self, w: int) -> None:
        """Executor worker ``w``: serve own queue in QoS order; steal from
        the busiest peer when idle (spread-policy groups only)."""
        while True:
            with self._lock:
                group = self._pop_group_locked(w)
                if group is None:
                    if self._sched_done and not any(self._pool_queues):
                        break
                    self._pool_work.wait(timeout=0.05)
                    continue
                self._pool_current[w] = group
                self._exec_trace.append(
                    (w, group.first_ticket, group.qos, group.stolen)
                )
                self._pool_space.notify_all()
            t0 = time.perf_counter()
            served = 0
            while True:
                with self._lock:
                    if not group.items:
                        break
                    item = group.items.popleft()
                self._run_item(item, slot=w)
                served += 1
            t1 = time.perf_counter()
            with self._lock:
                self._pool_current[w] = None
                if served:
                    self._worker_spans[w].append((t0, t1))
                    self._worker_busy[w] += t1 - t0
                    self._worker_reqs[w] += served
                    self._note_span_end_locked(t1)
                    self._maybe_fold_spans_locked()

    def _pop_group_locked(self, w: int) -> "_Group | None":
        """Own queue head, else steal. Stealing prefers whole queued groups
        from the most-loaded peer (tail = lowest priority, so the victim's
        QoS order is undisturbed); failing that, it splits the tail half of
        the largest in-progress stealable group (straggler relief)."""
        q = self._pool_queues[w]
        if q:
            return q.pop(0)
        if self._n_workers <= 1:
            return None
        victim, loaded = None, 0
        for v, vq in enumerate(self._pool_queues):
            if v == w:
                continue
            n_stealable = sum(1 for g in vq if g.stealable)
            if n_stealable > loaded:
                victim, loaded = v, n_stealable
        if victim is not None:
            vq = self._pool_queues[victim]
            for i in range(len(vq) - 1, -1, -1):
                if vq[i].stealable:
                    group = vq.pop(i)
                    group.slot = w
                    group.stolen = True
                    self._note_steal_locked(w)
                    return group
        # no queued group to take: split a straggler's remaining tail
        best = None
        for v, cur in enumerate(self._pool_current):
            if v == w or cur is None or not cur.stealable:
                continue
            if len(cur.items) >= 2 and (
                best is None or len(cur.items) > len(best.items)
            ):
                best = cur
        if best is not None:
            stolen: deque[_WorkItem] = deque()
            for _ in range(len(best.items) // 2):
                stolen.appendleft(best.items.pop())
            self._note_steal_locked(w)
            return _Group(
                key=best.key,
                qos=best.qos,
                first_ticket=best.first_ticket,
                slot=w,
                stealable=True,
                stolen=True,
                items=stolen,
            )
        return None

    def _note_steal_locked(self, w: int) -> None:
        self._stats.steals += 1
        self._worker_steal_counts[w] += 1

    def _plan_groups(self, items: "list[_WorkItem]") -> "list[list[_WorkItem]]":
        """The scheduler: group requests by identity (op x inputs object x
        strategy x substrate), bind **one plan per group** shared by every
        member — plans are pure functions of their bound args, so members of
        an identity group run the same plan — and order groups by QoS weight
        (higher first) then arrival. Building per group, not per request,
        keeps the scheduler's serial planning cost off the pool's critical
        path (two same-shape groups still share one compile via the cache).
        """
        groups: dict[Any, list[_WorkItem]] = {}
        order: list[Any] = []
        for item in items:
            req = item.request
            try:
                item.op = resolve_op(req.op)
            except Exception as exc:  # resolve failures reject that future only
                self._finish_error(item, exc)
                continue
            strategy = req.strategy
            strat_id = (
                strategy.cache_key()
                if isinstance(strategy, MigratoryStrategy)
                else strategy
            )
            sub = req.substrate
            gkey = (
                item.op.name,
                id(req.inputs),
                strat_id,
                sub if isinstance(sub, str) else id(sub),
                req.qos,  # a per-request weight makes its own group (ordering)
            )
            if gkey not in groups:
                order.append(gkey)
            groups.setdefault(gkey, []).append(item)
        out: list[list[_WorkItem]] = []
        for gkey in order:
            members = groups[gkey]
            first = members[0]
            req = first.request
            try:
                strategy = req.strategy
                if isinstance(strategy, str) and strategy == "auto":
                    from .autotune import choose_strategy

                    strategy = choose_strategy(first.op, req.inputs, req.substrate)
                plan = build_plan(first.op, req.inputs, strategy, req.substrate)
            except Exception as exc:  # plan failures reject the identity group
                for member in members:
                    self._finish_error(member, exc)
                continue
            for member in members:
                member.op, member.plan = first.op, plan
            out.append(members)
        return sorted(
            out,
            key=lambda g: (-self._effective_qos(g[0]), g[0].request.ticket),
        )

    def _compile_item(self, item: _WorkItem, slot: int) -> None:
        """Plane compile stage: a cold group's first request runs its
        (possibly compiling) call on the scheduler thread — pinning the
        entry to ``slot`` — while the pool executes other groups; the
        group's later members are cache hits by construction."""
        t0 = time.perf_counter()
        self._run_item(item, slot=slot)
        t1 = time.perf_counter()
        with self._lock:
            self._compile_spans.append((t0, t1))
            self._note_span_end_locked(t1)
            self._maybe_fold_spans_locked()

    def _note_span_end_locked(self, t1: float) -> None:
        """Extend the wall window to the span end: _run_item stamped _t_last
        before the span closed, and busy (span union) must stay <= wall."""
        if self._t_last is None or t1 > self._t_last:
            self._t_last = t1

    _SPAN_FOLD_THRESHOLD = 8192

    def _maybe_fold_spans_locked(self) -> None:
        """Fold recorded spans into scalar accumulators once the buffers grow
        large, bounding memory and stats() cost for long-lived services (at
        the cost of ignoring overlap straddling a fold boundary — one group
        out of thousands)."""
        n_spans = len(self._compile_spans) + sum(
            len(spans) for spans in self._worker_spans
        )
        if n_spans <= self._SPAN_FOLD_THRESHOLD:
            return
        all_exec = [s for spans in self._worker_spans for s in spans]
        self._overlap_acc += _intersection_seconds(
            self._compile_spans, _merge_spans(all_exec)
        )
        self._busy_acc += _union_seconds(self._compile_spans + all_exec)
        self._compile_busy_acc += sum(t1 - t0 for t0, t1 in self._compile_spans)
        self._compile_spans.clear()
        for spans in self._worker_spans:
            spans.clear()

    def _run_item(self, item: _WorkItem, slot: "int | None" = None) -> None:
        t0 = time.perf_counter()
        if item.dedup_key is not None and self._try_serve_dedup(item):
            return
        if self._shed_if_expired(item, t0):
            return
        try:
            result, report = single_call(
                item.plan, item.op, cache=self.cache, slot=slot
            )
        except Exception as exc:
            self._finish_error(item, exc)
            return
        t1 = time.perf_counter()
        response = ServiceResponse(item.request.ticket, result, report)
        item.future._resolve(response)
        with self._lock:
            self._live.pop(item.request.ticket, None)
            if item.dedup_key is not None:
                self._dedup_store[item.dedup_key] = response
                self._dedup_store.move_to_end(item.dedup_key)
                while len(self._dedup_store) > self.dedup_max_entries:
                    self._dedup_store.popitem(last=False)
                if self._dedup_pending.get(item.dedup_key) is item:
                    del self._dedup_pending[item.dedup_key]
            self._resolve_waiters_locked(item, response)
            if item.request.t_admit:
                self._queue_waits.append(max(0.0, t0 - item.request.t_admit))
                total = max(0.0, t1 - item.request.t_admit)
                self._total_latencies.append(total)
                if self.slo_target_seconds is not None:
                    self._stats.slo_checked += 1
                    if total > self.slo_target_seconds:
                        self._stats.slo_violations += 1
            self._service_times.append(t1 - t0)
            self._account_locked(report)
            self._finish_locked()

    def _resolve_waiters_locked(
        self, item: _WorkItem, response: ServiceResponse
    ) -> None:
        """Answer every coalesced duplicate with the primary's response
        (fresh ticket, shared result/report) — the in-flight dedup hit."""
        for ticket, fut in item.waiters:
            fut._resolve(ServiceResponse(ticket, response.result, response.report))
            self._live.pop(ticket, None)
            self._stats.requests += 1
            self._stats.dedup_hits += 1
            self._stats.dedup_coalesced += 1
        item.waiters.clear()

    def _try_serve_dedup(self, item: _WorkItem) -> bool:
        """Late dedup check (drain loop / pipeline stages): answer from the
        response store if an identical request completed since admission.
        Returns True when the item was served."""
        with self._lock:
            hit = self._dedup_store.get(item.dedup_key)
            if hit is None:
                return False
            self._dedup_store.move_to_end(item.dedup_key)
            self._stats.requests += 1
            self._stats.dedup_hits += 1
            response = ServiceResponse(item.request.ticket, hit.result, hit.report)
            item.future._resolve(response)
            self._live.pop(item.request.ticket, None)
            if self._dedup_pending.get(item.dedup_key) is item:
                del self._dedup_pending[item.dedup_key]
            self._resolve_waiters_locked(item, response)
            self._finish_locked()
            return True

    def _shed_if_expired(self, item: _WorkItem, now: float) -> bool:
        """Deadline shedding: a request whose ``Request.timeout`` elapsed
        while it sat in the queue is dropped instead of run — its future
        (and any coalesced waiters') raises :class:`ServiceTimeout`, counted
        in ``ServiceStats.timed_out`` (not ``errors``, not an SLO sample).
        Returns True when the item was shed."""
        timeout = item.request.timeout
        if timeout is None or not item.request.t_admit:
            return False
        waited = now - item.request.t_admit
        if waited <= timeout:
            return False
        exc = ServiceTimeout(
            f"request {item.request.ticket} shed: queued {waited:.3f}s past "
            f"its {timeout:.3f}s deadline"
        )
        item.future._reject(exc)
        with self._lock:
            self._live.pop(item.request.ticket, None)
            if (
                item.dedup_key is not None
                and self._dedup_pending.get(item.dedup_key) is item
            ):
                del self._dedup_pending[item.dedup_key]
            for ticket, fut in item.waiters:
                fut._reject(exc)
                self._live.pop(ticket, None)
                self._stats.timed_out += 1
            item.waiters.clear()
            self._stats.timed_out += 1
            self._finish_locked()
        return True

    def _finish_error(self, item: _WorkItem, exc: BaseException) -> None:
        item.future._reject(exc)
        with self._lock:
            self._live.pop(item.request.ticket, None)
            if (
                item.dedup_key is not None
                and self._dedup_pending.get(item.dedup_key) is item
            ):
                del self._dedup_pending[item.dedup_key]
            # coalesced duplicates asked for the same computation: it failed
            for ticket, fut in item.waiters:
                fut._reject(exc)
                self._live.pop(ticket, None)
                self._stats.errors += 1
            item.waiters.clear()
            self._stats.errors += 1
            self._finish_locked()

    def _cancel_item_locked(self, item: _WorkItem) -> None:
        """Reject a still-queued item (and its coalesced waiters) with
        ServiceStopped — the stop(drain=False) path."""
        item.future._reject(
            ServiceStopped("service stopped before this request ran")
        )
        self._live.pop(item.request.ticket, None)
        if (
            item.dedup_key is not None
            and self._dedup_pending.get(item.dedup_key) is item
        ):
            del self._dedup_pending[item.dedup_key]
        for ticket, fut in item.waiters:
            fut._reject(
                ServiceStopped("service stopped before the coalesced primary ran")
            )
            self._live.pop(ticket, None)
            self._stats.cancelled += 1
        item.waiters.clear()
        self._inflight -= 1
        self._stats.cancelled += 1

    def _finish_locked(self) -> None:
        self._inflight -= 1
        self._t_last = time.perf_counter()
        self._idle.notify_all()

    def _account_locked(self, report: RunReport) -> None:
        self._stats.requests += 1
        self._stats.cache_hits += int(report.cache_hit)
        self._stats.compiles += int(not report.cache_hit)
        self._stats.compile_seconds += report.compile_seconds
        # a cold request's single timed call IS the compile call;
        # count only its steady-state remainder as run time
        self._stats.run_seconds += report.seconds - report.compile_seconds

    # -- batch mode ------------------------------------------------------------

    def drain(self) -> "list[ServiceResponse]":
        """Batch mode: run every pending request, batching same-plan-key
        requests so each batch compiles at most once. Responses in
        submission order. In worker-loop mode use the futures (or
        ``flush()``) instead."""
        with self._lock:
            if self._running:
                raise RuntimeError(
                    "drain() is the batch-mode API; the worker loop is running — "
                    "use the futures returned by submit(), or flush()"
                )
            pending, self._pending = self._pending, []
        if not pending:
            return []
        t_wall = time.perf_counter()
        items = [
            _WorkItem(
                req,
                ServiceFuture(req.ticket),
                dedup_key=(
                    _content_hash(req.op, req.inputs, req.strategy, req.substrate)
                    if self.dedup
                    else None
                ),
            )
            for req in pending
        ]
        with self._lock:
            self._inflight += len(items)  # balanced by _finish_locked per item
        try:
            groups = self._plan_groups(items)
            # fail fast, like the pre-worker-loop drain: a plan that would
            # not bind raises before any group spends compile/execute time
            bad = next(
                (i for i in items if i.future._exception is not None), None
            )
            if bad is not None:
                raise bad.future._exception
            responses: list[ServiceResponse] = []
            for group in groups:
                with self._lock:
                    self._stats.batches += 1
                for item in group:
                    self._run_item(item)
                    if item.future._exception is not None:
                        raise item.future._exception
                    responses.append(item.future._response)
        finally:
            with self._lock:
                # items skipped by a fail-fast raise never reached
                # _finish_locked; balance their admission count
                for item in items:
                    if not item.future.done():
                        self._inflight -= 1
                self._stats.drains += 1
                self._drain_wall += time.perf_counter() - t_wall
        responses.sort(key=lambda r: r.ticket)
        return responses

    # -- reporting -------------------------------------------------------------

    def stats(self) -> ServiceStats:
        """A snapshot of the aggregate counters with the timing/overlap
        fields recomputed from the recorded stage spans and the per-worker
        columns attached (see :class:`ServiceStats` for semantics). Each
        call returns a fresh object — safe to keep for before/after
        comparisons."""
        with self._lock:
            worker_wall = (
                self._t_last - self._t_first
                if self._t_first is not None and self._t_last is not None
                else 0.0
            )
            all_exec = [s for spans in self._worker_spans for s in spans]
            overlap_seconds = self._overlap_acc + _intersection_seconds(
                self._compile_spans, _merge_spans(all_exec)
            )
            compile_busy = self._compile_busy_acc + sum(
                t1 - t0 for t0, t1 in self._compile_spans
            )
            waits = list(self._queue_waits)  # copy only; sort off-lock —
            services = list(self._service_times)  # submit()/pipeline contend here
            totals = list(self._total_latencies)
            # report every slot ever used, not just the current width: a
            # restart with a narrower pool must not drop accumulated
            # per-worker counters (sum(worker_steals) == steals always)
            busy = list(self._worker_busy)
            reqs = list(self._worker_reqs)
            steals = list(self._worker_steal_counts)
            window = max(0.0, worker_wall)
            occupancy = [b / window if window > 0 else 0.0 for b in busy]
            if occupancy:
                self._occ_hwm = max(self._occ_hwm, max(occupancy))
            snapshot = dataclasses.replace(
                self._stats,
                wall_seconds=self._drain_wall + window,
                busy_seconds=(
                    self._drain_wall
                    + self._busy_acc
                    + _union_seconds(self._compile_spans + all_exec)
                ),
                overlap_seconds=overlap_seconds,
                overlap_ratio=(
                    overlap_seconds / compile_busy if compile_busy > 0 else 0.0
                ),
                workers=self._n_workers,
                worker_busy_seconds=busy,
                worker_requests=reqs,
                worker_steals=steals,
                worker_occupancy=occupancy,
                occupancy_hwm=self._occ_hwm,
                slo_target_seconds=self.slo_target_seconds,
            )
        waits.sort()
        services.sort()
        snapshot.queue_wait_p50 = _percentile(waits, 0.50)
        snapshot.queue_wait_p95 = _percentile(waits, 0.95)
        snapshot.queue_wait_p99 = _percentile(waits, 0.99)
        snapshot.service_p50 = _percentile(services, 0.50)
        snapshot.service_p95 = _percentile(services, 0.95)
        snapshot.service_p99 = _percentile(services, 0.99)
        totals.sort()
        snapshot.total_p50 = _percentile(totals, 0.50)
        snapshot.total_p95 = _percentile(totals, 0.95)
        snapshot.total_p99 = _percentile(totals, 0.99)
        return snapshot

    def throughput_report(self) -> dict[str, Any]:
        """Aggregate record: service counters + plan-cache health."""
        return {**self.stats().to_dict(), "cache": self.cache.stats()}
