"""Stable wire serialization for engine values (DESIGN.md §1h).

One canonical, JSON-compatible encoding shared by two consumers that must
agree on request identity:

- the **cluster protocol** (:mod:`repro.cluster.protocol`): a ``Request``
  crosses a process boundary as ``Request.to_wire()`` and is rebuilt with
  ``Request.from_wire()`` — dtype/shape-preserving, bit-exact array round
  trips;
- the **dedup content hash** (:func:`~repro.engine.service._content_hash`):
  the sha256 of :func:`canonical_bytes` over the same encoding, so "two
  requests are the same computation" means exactly "they serialize to the
  same wire bytes" — a request deduped in-process and a request routed to a
  worker share one identity.

Encoding rules (``encode_value``):

- JSON scalars (``None``/bool/int/float/str) pass through.
- Array-likes (anything with ``shape``+``dtype``) have three wire forms:

  * inline ``{"__wire__": "nd", "dtype", "shape", "data"}`` with ``data``
    the base64 of the C-order buffer — the *canonical* form, what
    :func:`canonical_bytes` always emits (dedup identity is pinned to it);
  * out-of-band ``{"__wire__": "ndref", "seg", "dtype", "shape"}`` when a
    :class:`SegmentTable` is passed — the raw C-order buffer is appended
    verbatim as frame segment ``seg`` instead of being base64-inflated
    into the JSON envelope (protocol v2's zero-copy data plane);
  * content-addressed ``{"__wire__": "blobref", "digest", "dtype",
    "shape"}`` when a ``blob_sink`` claims the array — the bytes do not
    travel with the envelope at all; the receiver resolves the digest
    against its blob store (``blob_resolver`` on decode).

  Decoding returns a NumPy array — the kernels convert lazily, and NumPy
  preserves dtypes (e.g. int64) that an eager ``jnp.asarray`` would
  downcast under default x64 settings.
- Dataclasses become ``{"__wire__": "dc", "cls": "module:qualname",
  "fields": {...}}``. Decoding imports the class, **restricted to
  ``repro.*`` modules** — the wire format never instantiates arbitrary
  types.
- Enums (``{"__wire__": "enum"}``) and tuples (``{"__wire__": "tuple"}``)
  are tagged so they survive JSON's list/str flattening; dicts are tagged
  with sorted items so plain mappings can't collide with wire tags and the
  canonical bytes are order-independent.
- Anything else falls back to ``{"__wire__": "repr"}`` — good enough to
  *hash* (dedup identity keeps working for exotic inputs) but refused by
  ``decode_value`` (a cluster cannot rebuild a value from its repr).

``canonical_bytes`` is ``json.dumps(encode_value(v), sort_keys=True)``
encoded UTF-8: deterministic across processes and Python hash seeds, and
**never** in segment or blobref form — the dedup identity of a value is
the same whether it crossed the wire as base64, a raw segment, or a blob.
"""
from __future__ import annotations

import base64
import dataclasses
import enum
import hashlib
import importlib
import json
from typing import Any, Callable

import numpy as np

WIRE_VERSION = 1

_TAG = "__wire__"
_ALLOWED_MODULE_PREFIX = "repro."


class WireError(ValueError):
    """A value cannot be encoded for, or decoded from, the wire."""


class SegmentTable:
    """Out-of-band payload collector for protocol v2 frames.

    Passed to :func:`encode_value` as ``segments=``: every array's raw
    C-order buffer lands here (as a zero-copy byte view when possible) and
    the envelope carries only an ``ndref`` with the segment index. The
    collected :attr:`segments` list rides the frame after the JSON
    envelope — see :meth:`repro.cluster.protocol.Channel.send`.
    """

    def __init__(self):
        self.segments: "list[Any]" = []  # bytes-like: memoryview | bytes

    def add(self, buf: Any) -> int:
        self.segments.append(buf)
        return len(self.segments) - 1

    def nbytes(self) -> int:
        return sum(len(s) for s in self.segments)

    def __len__(self) -> int:
        return len(self.segments)


def _byte_view(arr: np.ndarray) -> Any:
    """A flat byte view of a C-contiguous array (no copy when the buffer
    protocol allows it; ``tobytes`` fallback otherwise)."""
    try:
        return memoryview(arr).cast("B")
    except (TypeError, ValueError):
        return arr.tobytes()


def _class_path(cls: type) -> str:
    return f"{cls.__module__}:{cls.__qualname__}"


def _resolve_class(path: str) -> type:
    module, _, qualname = path.partition(":")
    if not (module.startswith(_ALLOWED_MODULE_PREFIX) or module == "repro"):
        raise WireError(
            f"refusing to resolve wire class {path!r}: only repro.* types "
            "may cross the wire"
        )
    obj: Any = importlib.import_module(module)
    for part in qualname.split("."):
        obj = getattr(obj, part)
    if not isinstance(obj, type):
        raise WireError(f"wire class path {path!r} is not a class")
    return obj


def encode_value(
    value: Any,
    *,
    segments: "SegmentTable | None" = None,
    blob_sink: "Callable[[Any, np.ndarray], str | None] | None" = None,
) -> Any:
    """Encode ``value`` into the JSON-compatible wire form (module doc).

    ``segments`` switches arrays to out-of-band ``ndref`` form (raw buffer
    appended to the table, no base64). ``blob_sink(original, contiguous)``
    is consulted first for every array: returning a digest string emits a
    ``blobref`` (the bytes travel separately, at most once per receiver);
    returning ``None`` falls through to the segment/inline path. Neither
    affects :func:`canonical_bytes`, which always encodes inline.
    """
    if isinstance(value, enum.Enum):
        # before the scalar pass-through: str/int-mixin enums (Comm, Layout,
        # Scheme) must round-trip as enum members, not bare scalars
        return {
            _TAG: "enum",
            "cls": _class_path(type(value)),
            "value": encode_value(value.value),
        }
    if value is None or isinstance(value, (bool, int, str)):
        return value
    if isinstance(value, float):
        return value  # json round-trips NaN/Infinity via its literals
    if hasattr(value, "shape") and hasattr(value, "dtype"):
        arr = np.ascontiguousarray(np.asarray(value))
        if arr.dtype == object:
            raise WireError("object-dtype arrays cannot cross the wire")
        if blob_sink is not None:
            digest = blob_sink(value, arr)
            if digest is not None:
                return {
                    _TAG: "blobref",
                    "digest": digest,
                    "dtype": str(arr.dtype),
                    "shape": list(arr.shape),
                }
        if segments is not None:
            return {
                _TAG: "ndref",
                "seg": segments.add(_byte_view(arr)),
                "dtype": str(arr.dtype),
                "shape": list(arr.shape),
            }
        return {
            _TAG: "nd",
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "data": base64.b64encode(arr.tobytes()).decode("ascii"),
        }
    if dataclasses.is_dataclass(value) and not isinstance(value, type):
        return {
            _TAG: "dc",
            "cls": _class_path(type(value)),
            "fields": {
                f.name: encode_value(
                    getattr(value, f.name), segments=segments, blob_sink=blob_sink
                )
                for f in dataclasses.fields(value)
            },
        }
    if isinstance(value, tuple):
        return {
            _TAG: "tuple",
            "items": [
                encode_value(v, segments=segments, blob_sink=blob_sink)
                for v in value
            ],
        }
    if isinstance(value, list):
        return {
            _TAG: "list",
            "items": [
                encode_value(v, segments=segments, blob_sink=blob_sink)
                for v in value
            ],
        }
    if isinstance(value, dict):
        items = [
            [
                encode_value(k),
                encode_value(v, segments=segments, blob_sink=blob_sink),
            ]
            for k, v in value.items()
        ]
        items.sort(key=lambda kv: json.dumps(kv[0], sort_keys=True, default=str))
        return {_TAG: "dict", "items": items}
    # hash-only fallback: identity for dedup, but not reconstructable
    return {_TAG: "repr", "repr": repr(value), "cls": _class_path(type(value))}


def decode_value(
    value: Any,
    *,
    blob_resolver: "Callable[[str], np.ndarray] | None" = None,
) -> Any:
    """Rebuild a value from its wire form. Raises :class:`WireError` for
    hash-only (``repr``) payloads and non-``repro.*`` classes.

    ``ndref`` values decode from the raw segment buffer the protocol layer
    attached under ``"data"`` (see
    :func:`repro.cluster.protocol.attach_segments`); an unattached ndref is
    refused. Both ``nd`` and ``ndref`` decode to a fresh *writable* array.
    ``blobref`` values resolve their digest through ``blob_resolver`` (the
    receiver's blob store); without one they are refused — a blobref is
    meaningless outside a blob-aware peer. Resolved blobs are the store's
    shared entries and therefore **read-only** — copy before mutating.
    """
    if value is None or isinstance(value, (bool, int, float, str)):
        return value
    if isinstance(value, list):  # bare lists never appear, but be lenient
        return [decode_value(v, blob_resolver=blob_resolver) for v in value]
    if not isinstance(value, dict):
        raise WireError(f"unexpected wire value of type {type(value).__name__}")
    tag = value.get(_TAG)
    if tag == "nd":
        raw = base64.b64decode(value["data"])
        arr = np.frombuffer(raw, dtype=np.dtype(value["dtype"]))
        return arr.reshape(tuple(value["shape"])).copy()
    if tag == "ndref":
        raw = value.get("data")
        if raw is None:
            raise WireError(
                f"ndref segment {value.get('seg')!r} was not attached — "
                "ndref values only decode inside a protocol v2 frame"
            )
        # .copy() for parity with the v1 "nd" path: decoded arrays are
        # writable, owndata, and don't pin the whole frame buffer alive
        arr = np.frombuffer(raw, dtype=np.dtype(value["dtype"]))
        return arr.reshape(tuple(value["shape"])).copy()
    if tag == "blobref":
        if blob_resolver is None:
            raise WireError(
                f"blobref {value.get('digest')!r} cannot be decoded without "
                "a blob store (pass blob_resolver=)"
            )
        return blob_resolver(value["digest"])
    if tag == "enum":
        cls = _resolve_class(value["cls"])
        return cls(decode_value(value["value"]))
    if tag == "dc":
        cls = _resolve_class(value["cls"])
        fields = {
            k: decode_value(v, blob_resolver=blob_resolver)
            for k, v in value["fields"].items()
        }
        return cls(**fields)
    if tag == "tuple":
        return tuple(
            decode_value(v, blob_resolver=blob_resolver) for v in value["items"]
        )
    if tag == "list":
        return [
            decode_value(v, blob_resolver=blob_resolver) for v in value["items"]
        ]
    if tag == "dict":
        return {
            decode_value(k): decode_value(v, blob_resolver=blob_resolver)
            for k, v in value["items"]
        }
    if tag == "repr":
        raise WireError(
            f"value of type {value.get('cls')!r} was encoded hash-only "
            "(repr fallback) and cannot be decoded"
        )
    raise WireError(f"unknown wire tag {tag!r}")


def collect_blob_digests(encoded: Any) -> "list[str]":
    """Every ``blobref`` digest reachable in an *encoded* wire structure,
    in first-appearance order (deduplicated). The receiver pre-scans a
    frame with this to fetch missing blobs in one ``need_blob`` round trip
    instead of failing mid-decode."""
    out: "list[str]" = []
    seen: "set[str]" = set()

    def walk(obj: Any) -> None:
        if isinstance(obj, dict):
            if obj.get(_TAG) == "blobref":
                digest = obj.get("digest")
                if digest not in seen:
                    seen.add(digest)
                    out.append(digest)
                return
            for v in obj.values():
                walk(v)
        elif isinstance(obj, list):
            for v in obj:
                walk(v)

    walk(encoded)
    return out


def canonical_bytes(value: Any) -> bytes:
    """Deterministic byte encoding of ``value`` — the dedup-hash payload.
    Stable across processes and Python hash seeds: sorted keys, no
    whitespace, UTF-8, and always the inline (base64) array form — never
    segment- or blob-relative, so identity does not depend on transport."""
    return json.dumps(
        encode_value(value), sort_keys=True, separators=(",", ":")
    ).encode("utf-8")


def content_digest(value: Any) -> str:
    """sha256 hex digest of :func:`canonical_bytes` — the one
    content-addressed identity in the system. The dedup cache hashes whole
    requests with it (via ``_content_hash``); the cluster's blob store
    addresses individual arrays with it (DESIGN.md §1h)."""
    return hashlib.sha256(canonical_bytes(value)).hexdigest()


def dumps(value: Any) -> bytes:
    """Wire bytes for a protocol message body (canonical form, so equal
    values produce equal frames)."""
    return canonical_bytes(value)


def loads(data: bytes) -> Any:
    return decode_value(json.loads(data.decode("utf-8")))
