"""Stable wire serialization for engine values (DESIGN.md §1h).

One canonical, JSON-compatible encoding shared by two consumers that must
agree on request identity:

- the **cluster protocol** (:mod:`repro.cluster.protocol`): a ``Request``
  crosses a process boundary as ``Request.to_wire()`` and is rebuilt with
  ``Request.from_wire()`` — dtype/shape-preserving, bit-exact array round
  trips (raw buffer in base64, no float repr loss);
- the **dedup content hash** (:func:`~repro.engine.service._content_hash`):
  the sha256 of :func:`canonical_bytes` over the same encoding, so "two
  requests are the same computation" means exactly "they serialize to the
  same wire bytes" — a request deduped in-process and a request routed to a
  worker share one identity.

Encoding rules (``encode_value``):

- JSON scalars (``None``/bool/int/float/str) pass through.
- Array-likes (anything with ``shape``+``dtype``) become
  ``{"__wire__": "nd", "dtype", "shape", "data"}`` with ``data`` the
  base64 of the C-order buffer. Decoding returns a NumPy array — the
  kernels convert lazily, and NumPy preserves dtypes (e.g. int64) that an
  eager ``jnp.asarray`` would downcast under default x64 settings.
- Dataclasses become ``{"__wire__": "dc", "cls": "module:qualname",
  "fields": {...}}``. Decoding imports the class, **restricted to
  ``repro.*`` modules** — the wire format never instantiates arbitrary
  types.
- Enums (``{"__wire__": "enum"}``) and tuples (``{"__wire__": "tuple"}``)
  are tagged so they survive JSON's list/str flattening; dicts are tagged
  with sorted items so plain mappings can't collide with wire tags and the
  canonical bytes are order-independent.
- Anything else falls back to ``{"__wire__": "repr"}`` — good enough to
  *hash* (dedup identity keeps working for exotic inputs) but refused by
  ``decode_value`` (a cluster cannot rebuild a value from its repr).

``canonical_bytes`` is ``json.dumps(encode_value(v), sort_keys=True)``
encoded UTF-8: deterministic across processes and Python hash seeds.
"""
from __future__ import annotations

import base64
import dataclasses
import enum
import importlib
import json
from typing import Any

import numpy as np

WIRE_VERSION = 1

_TAG = "__wire__"
_ALLOWED_MODULE_PREFIX = "repro."


class WireError(ValueError):
    """A value cannot be encoded for, or decoded from, the wire."""


def _class_path(cls: type) -> str:
    return f"{cls.__module__}:{cls.__qualname__}"


def _resolve_class(path: str) -> type:
    module, _, qualname = path.partition(":")
    if not (module.startswith(_ALLOWED_MODULE_PREFIX) or module == "repro"):
        raise WireError(
            f"refusing to resolve wire class {path!r}: only repro.* types "
            "may cross the wire"
        )
    obj: Any = importlib.import_module(module)
    for part in qualname.split("."):
        obj = getattr(obj, part)
    if not isinstance(obj, type):
        raise WireError(f"wire class path {path!r} is not a class")
    return obj


def encode_value(value: Any) -> Any:
    """Encode ``value`` into the JSON-compatible wire form (module doc)."""
    if isinstance(value, enum.Enum):
        # before the scalar pass-through: str/int-mixin enums (Comm, Layout,
        # Scheme) must round-trip as enum members, not bare scalars
        return {
            _TAG: "enum",
            "cls": _class_path(type(value)),
            "value": encode_value(value.value),
        }
    if value is None or isinstance(value, (bool, int, str)):
        return value
    if isinstance(value, float):
        return value  # json round-trips NaN/Infinity via its literals
    if hasattr(value, "shape") and hasattr(value, "dtype"):
        arr = np.ascontiguousarray(np.asarray(value))
        if arr.dtype == object:
            raise WireError("object-dtype arrays cannot cross the wire")
        return {
            _TAG: "nd",
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "data": base64.b64encode(arr.tobytes()).decode("ascii"),
        }
    if dataclasses.is_dataclass(value) and not isinstance(value, type):
        return {
            _TAG: "dc",
            "cls": _class_path(type(value)),
            "fields": {
                f.name: encode_value(getattr(value, f.name))
                for f in dataclasses.fields(value)
            },
        }
    if isinstance(value, tuple):
        return {_TAG: "tuple", "items": [encode_value(v) for v in value]}
    if isinstance(value, list):
        return {_TAG: "list", "items": [encode_value(v) for v in value]}
    if isinstance(value, dict):
        items = [
            [encode_value(k), encode_value(v)] for k, v in value.items()
        ]
        items.sort(key=lambda kv: json.dumps(kv[0], sort_keys=True, default=str))
        return {_TAG: "dict", "items": items}
    # hash-only fallback: identity for dedup, but not reconstructable
    return {_TAG: "repr", "repr": repr(value), "cls": _class_path(type(value))}


def decode_value(value: Any) -> Any:
    """Rebuild a value from its wire form. Raises :class:`WireError` for
    hash-only (``repr``) payloads and non-``repro.*`` classes."""
    if value is None or isinstance(value, (bool, int, float, str)):
        return value
    if isinstance(value, list):  # bare lists never appear, but be lenient
        return [decode_value(v) for v in value]
    if not isinstance(value, dict):
        raise WireError(f"unexpected wire value of type {type(value).__name__}")
    tag = value.get(_TAG)
    if tag == "nd":
        raw = base64.b64decode(value["data"])
        arr = np.frombuffer(raw, dtype=np.dtype(value["dtype"]))
        return arr.reshape(tuple(value["shape"])).copy()
    if tag == "enum":
        cls = _resolve_class(value["cls"])
        return cls(decode_value(value["value"]))
    if tag == "dc":
        cls = _resolve_class(value["cls"])
        fields = {k: decode_value(v) for k, v in value["fields"].items()}
        return cls(**fields)
    if tag == "tuple":
        return tuple(decode_value(v) for v in value["items"])
    if tag == "list":
        return [decode_value(v) for v in value["items"]]
    if tag == "dict":
        return {decode_value(k): decode_value(v) for k, v in value["items"]}
    if tag == "repr":
        raise WireError(
            f"value of type {value.get('cls')!r} was encoded hash-only "
            "(repr fallback) and cannot be decoded"
        )
    raise WireError(f"unknown wire tag {tag!r}")


def canonical_bytes(value: Any) -> bytes:
    """Deterministic byte encoding of ``value`` — the dedup-hash payload.
    Stable across processes: sorted keys, no whitespace, UTF-8."""
    return json.dumps(
        encode_value(value), sort_keys=True, separators=(",", ":")
    ).encode("utf-8")


def dumps(value: Any) -> bytes:
    """Wire bytes for a protocol message body (canonical form, so equal
    values produce equal frames)."""
    return canonical_bytes(value)


def loads(data: bytes) -> Any:
    return decode_value(json.loads(data.decode("utf-8")))
