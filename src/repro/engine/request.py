"""The unified request shape of the engine's public surface (DESIGN.md §1g).

Across PRs 1–5 the engine grew three inconsistent call shapes —
``engine.run(op, inputs, strategy, substrate)`` kwargs,
``EngineService.submit(op, inputs, ...)`` kwargs, and the legacy
``Substrate.spmv(...)``-style per-op methods. :class:`Request` collapses
them into one entry value:

    req = Request("spmv", SpMVInputs(a, x), strategy="auto", substrate="mesh")
    y, report = engine.run(req)             # one-shot
    fut = service.submit(req)               # batch ticket or async future

``engine.run`` and ``EngineService.submit`` accept a Request identically in
batch, async, and pooled modes. The old positional/kwargs forms still work
as thin wrappers that emit :class:`DeprecationWarning`; the per-op substrate
methods are gone (resolve kernels via ``substrate.kernel(op_name)``).

Serving-only fields ride along:

- ``qos``: per-request scheduling weight. Overrides the service's per-op
  ``qos`` table for this request's plan-key group (higher runs first).
- ``timeout``: per-request deadline in seconds from admission. A request
  still queued when its deadline passes is shed instead of run — its future
  raises :class:`~repro.engine.service.ServiceTimeout` and the shed is
  counted in ``ServiceStats.timed_out``. ``engine.run`` ignores ``timeout``
  (the caller is already blocking on the one request).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any

from ..core.strategies import MigratoryStrategy


@dataclasses.dataclass(frozen=True)
class Request:
    """One unit of engine work: what to run, on what, under which strategy,
    plus the serving QoS/deadline envelope."""

    op: Any
    inputs: Any
    strategy: "MigratoryStrategy | str | None" = None
    substrate: Any = None  # Substrate | str | None (None = callee default)
    qos: "float | None" = None
    timeout: "float | None" = None

    def __post_init__(self):
        if self.qos is not None and float(self.qos) <= 0:
            raise ValueError(f"qos must be > 0, got {self.qos!r}")
        if self.timeout is not None and float(self.timeout) < 0:
            raise ValueError(f"timeout must be >= 0, got {self.timeout!r}")

    def to_wire(self, *, segments=None, blob_sink=None) -> dict:
        """The stable wire form of this request (DESIGN.md §1h): a JSON-
        compatible dict with dtype/shape-preserving array encoding, shared
        by the cluster protocol and the dedup content hash. ``op`` travels
        by name and ``substrate`` by registered name — the receiving
        process resolves both through its own registries, so a Request
        round-trips between processes with different object identities but
        identical computation.

        ``segments`` (a :class:`~repro.engine.wire.SegmentTable`) and
        ``blob_sink`` opt input arrays out of inline base64 and into
        out-of-band frame segments / content-addressed blobrefs — the
        protocol-v2 data plane. With neither, the encoding is the fully
        inline v1-compatible form."""
        from .wire import WIRE_VERSION, WireError, encode_value

        op = self.op
        if not isinstance(op, str):
            op = getattr(op, "name", None)
            if not isinstance(op, str):
                raise WireError(
                    f"op {self.op!r} has no registry name; pass the op by "
                    "name for wire transport"
                )
        substrate = self.substrate
        if substrate is not None and not isinstance(substrate, str):
            from .substrate import Substrate, list_substrates

            if not isinstance(substrate, Substrate) or (
                substrate.name not in list_substrates()
            ):
                raise WireError(
                    f"substrate {substrate!r} is not a registered substrate "
                    "name; only registered substrates cross the wire"
                )
            substrate = substrate.name
        return {
            "v": WIRE_VERSION,
            "op": op,
            "inputs": encode_value(
                self.inputs, segments=segments, blob_sink=blob_sink
            ),
            "strategy": encode_value(self.strategy),
            "substrate": substrate,
            "qos": None if self.qos is None else float(self.qos),
            "timeout": None if self.timeout is None else float(self.timeout),
        }

    @classmethod
    def from_wire(cls, payload: dict, *, blob_resolver=None) -> "Request":
        """Rebuild a Request from :meth:`to_wire` output. ``blob_resolver``
        (digest -> array) resolves any ``blobref`` nodes — required when the
        sender encoded with a ``blob_sink``."""
        from .wire import WIRE_VERSION, WireError, decode_value

        version = payload.get("v")
        if version != WIRE_VERSION:
            raise WireError(
                f"wire version mismatch: got {version!r}, expected {WIRE_VERSION}"
            )
        return cls(
            op=payload["op"],
            inputs=decode_value(payload["inputs"], blob_resolver=blob_resolver),
            strategy=decode_value(payload["strategy"]),
            substrate=payload.get("substrate"),
            qos=payload.get("qos"),
            timeout=payload.get("timeout"),
        )


def warn_kwargs_form(entry: str) -> None:
    """One deprecation warning for a legacy kwargs call, attributed to the
    user's call site (4 frames up: caller -> entry -> coerce -> here)."""
    warnings.warn(
        f"{entry}(op, inputs, ...) kwargs form is deprecated; pass a "
        f"repro.engine.Request instead: {entry}(Request(op, inputs, ...))",
        DeprecationWarning,
        stacklevel=4,
    )


def coerce_request(
    op: Any,
    inputs: Any = None,
    strategy: "MigratoryStrategy | str | None" = None,
    substrate: Any = None,
    *,
    entry: str,
) -> Request:
    """Normalize an entry-point call to a :class:`Request`.

    A Request passed as ``op`` is returned as-is (mixing it with kwargs is
    an error — the Request is the whole call); anything else is the legacy
    kwargs form, wrapped with a :class:`DeprecationWarning`.
    """
    if isinstance(op, Request):
        if inputs is not None or strategy is not None or substrate is not None:
            raise TypeError(
                f"{entry}(Request, ...) takes no extra inputs/strategy/"
                "substrate arguments — put them on the Request"
            )
        return op
    warn_kwargs_form(entry)
    return Request(op=op, inputs=inputs, strategy=strategy, substrate=substrate)
