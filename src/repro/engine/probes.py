"""Measured-probe persistence for the autotuner (ROADMAP "measured-probe
persistence") — the on-disk sibling of the in-process compiled-plan cache.

``autotune(..., probe_top_k=k)`` executes the leading candidates to let
measured seconds override the traffic model. Those measurements are pure
re-derivable state, so a :class:`ProbeStore` spills them as
``(plan key -> median measured seconds)`` JSON at
``experiments/autotune_probes.json`` and reloads them lazily on first use:
a repeat session (or a repeat scenario within one session) skips the probe
execution entirely and reuses the stored timing. CI uploads the file as an
artifact next to the autotune ranking table.

Plan keys are exactly the compiled-plan cache keys
(:func:`~repro.engine.api.plan_key`): op x substrate fingerprint x strategy
x static scalars x argument shape/dtype signature — everything a probe
timing depends on besides the machine itself. Keys are stored as their
``repr`` (they are tuples of primitives and strings, so the repr is stable
across sessions). Stored probes can misjudge across *machines*; the
autotuner's ``override_margin`` guard applies to them the same way it does
to noisy fresh probes.
"""
from __future__ import annotations

import json
import os
import threading
import warnings
from pathlib import Path

DEFAULT_PROBES_PATH = (
    Path(__file__).resolve().parents[3] / "experiments" / "autotune_probes.json"
)
_SCHEMA_VERSION = 1


class ProbeStore:
    """Persistent ``(plan key -> measured seconds)`` map, loaded lazily and
    spilled atomically. Thread-safe; read-only filesystems degrade to an
    in-memory store (save() becomes a no-op)."""

    def __init__(self, path: "str | os.PathLike"):
        self.path = Path(path)
        self._lock = threading.RLock()
        self._data: "dict[str, float] | None" = None
        self.reused = 0  # probes served from the store this session
        self.recorded = 0  # fresh measurements added this session

    @staticmethod
    def encode_key(key: tuple) -> str:
        return repr(key)

    def _load_locked(self) -> "dict[str, float]":
        if self._data is None:
            try:
                blob = self.path.read_bytes()
            except FileNotFoundError:  # absent store: normal first session
                self._data = {}
                return self._data
            except OSError as exc:  # exists but unreadable: say so
                warnings.warn(
                    f"unreadable probe store at {self.path} ({exc!r}); "
                    "starting with an empty store",
                    RuntimeWarning,
                    stacklevel=3,
                )
                self._data = {}
                return self._data
            try:
                # bytes in: json.loads does the decode, so non-UTF-8 garbage
                # lands in the corrupt handler below instead of raising here
                raw = json.loads(blob)
                self._data = {
                    str(k): float(v) for k, v in raw.get("probes", {}).items()
                }
            except (ValueError, AttributeError, TypeError) as exc:
                # corrupt/truncated store (killed run, disk-full spill, hand
                # edit): probes are rederivable, so degrade to empty — but
                # loudly, the file will be overwritten on the next save()
                warnings.warn(
                    f"corrupt probe store at {self.path} ({exc!r}); "
                    "starting with an empty store",
                    RuntimeWarning,
                    stacklevel=3,
                )
                self._data = {}
        return self._data

    def __len__(self) -> int:
        with self._lock:
            return len(self._load_locked())

    def get(self, key: "tuple | None") -> "float | None":
        """Stored seconds for a plan key, or None (uncacheable/unseen)."""
        if key is None:
            return None
        with self._lock:
            seconds = self._load_locked().get(self.encode_key(key))
            if seconds is not None:
                self.reused += 1
            return seconds

    def record(self, key: "tuple | None", seconds: float) -> None:
        if key is None:
            return
        with self._lock:
            self._load_locked()[self.encode_key(key)] = float(seconds)
            self.recorded += 1

    def save(self) -> None:
        """Atomic spill (tmp file + rename); silently skipped where the
        experiments directory is not writable."""
        with self._lock:
            payload = {"version": _SCHEMA_VERSION, "probes": dict(self._load_locked())}
        try:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            tmp = self.path.with_suffix(".json.tmp")
            tmp.write_text(json.dumps(payload, indent=2, sort_keys=True))
            tmp.replace(self.path)
        except OSError:
            pass


_default_store: "ProbeStore | None" = None
_default_store_lock = threading.Lock()


def default_probe_store() -> ProbeStore:
    """The process-wide store at ``experiments/autotune_probes.json``
    (``REPRO_PROBES_PATH`` overrides the location)."""
    global _default_store
    with _default_store_lock:
        if _default_store is None:
            path = os.environ.get("REPRO_PROBES_PATH", str(DEFAULT_PROBES_PATH))
            _default_store = ProbeStore(path)
        return _default_store
