"""Measured-probe persistence for the autotuner (ROADMAP "measured-probe
persistence") — the on-disk sibling of the in-process compiled-plan cache.

``autotune(..., probe_top_k=k)`` executes the leading candidates to let
measured seconds override the traffic model. Those measurements are pure
re-derivable state, so a :class:`ProbeStore` spills them as
``(plan key -> {seconds, machine})`` JSON at
``experiments/autotune_probes.json`` and reloads them lazily on first use:
a repeat session (or a repeat scenario within one session) skips the probe
execution entirely and reuses the stored timing. CI uploads the file as an
artifact next to the autotune ranking table.

Plan keys are exactly the compiled-plan cache keys
(:func:`~repro.engine.api.plan_key`): op x substrate fingerprint x strategy
x static scalars x argument shape/dtype signature — everything a probe
timing depends on besides the machine itself. Keys are stored as their
``repr`` (they are tuples of primitives and strings, so the repr is stable
across sessions). The machine itself is covered by the calibration plane:
each entry carries the :func:`~repro.machine.machine.machine_fingerprint`
it was measured under (schema v2), ``get`` ignores entries from a different
topology, and ``save`` prunes them — a probe measured on an 8-device forced
host never silently ranks strategies on a 1-device one. Schema-v1 entries
(bare floats, no fingerprint) are treated as unknown provenance: always
stale, pruned on the next save.
"""
from __future__ import annotations

import json
import os
import threading
import warnings
from pathlib import Path

DEFAULT_PROBES_PATH = (
    Path(__file__).resolve().parents[3] / "experiments" / "autotune_probes.json"
)
_SCHEMA_VERSION = 2


class ProbeStore:
    """Persistent ``(plan key -> measured seconds)`` map, loaded lazily and
    spilled atomically. Thread-safe; read-only filesystems degrade to an
    in-memory store (save() becomes a no-op). Entries are fingerprinted to
    the machine topology they were measured on; foreign entries read as
    absent and are pruned on save."""

    def __init__(self, path: "str | os.PathLike"):
        self.path = Path(path)
        self._lock = threading.RLock()
        # key -> (seconds, fingerprint-key-or-None)
        self._data: "dict[str, tuple[float, str | None]] | None" = None
        self._machine: "str | None | bool" = False  # False = not yet computed
        self.reused = 0  # probes served from the store this session
        self.recorded = 0  # fresh measurements added this session
        self.stale = 0  # lookups rejected for a foreign fingerprint
        self.pruned = 0  # foreign entries dropped by the last save()

    @staticmethod
    def encode_key(key: tuple) -> str:
        return repr(key)

    def _machine_key(self) -> "str | None":
        """This process's topology fingerprint, computed once per store
        (importing lazily keeps ProbeStore usable without jax warmup)."""
        if self._machine is False:
            from ..machine.machine import fingerprint_key, machine_fingerprint

            try:
                self._machine = fingerprint_key(machine_fingerprint())
            except Exception:  # no backend at all: no provenance to claim
                self._machine = None
        return self._machine

    def _load_locked(self) -> "dict[str, tuple[float, str | None]]":
        if self._data is None:
            try:
                blob = self.path.read_bytes()
            except FileNotFoundError:  # absent store: normal first session
                self._data = {}
                return self._data
            except OSError as exc:  # exists but unreadable: say so
                warnings.warn(
                    f"unreadable probe store at {self.path} ({exc!r}); "
                    "starting with an empty store",
                    RuntimeWarning,
                    stacklevel=3,
                )
                self._data = {}
                return self._data
            try:
                # bytes in: json.loads does the decode, so non-UTF-8 garbage
                # lands in the corrupt handler below instead of raising here
                raw = json.loads(blob)
                self._data = {
                    str(k): self._parse_value(v)
                    for k, v in raw.get("probes", {}).items()
                }
            except (ValueError, AttributeError, TypeError, KeyError) as exc:
                # corrupt/truncated store (killed run, disk-full spill, hand
                # edit): probes are rederivable, so degrade to empty — but
                # loudly, the file will be overwritten on the next save()
                warnings.warn(
                    f"corrupt probe store at {self.path} ({exc!r}); "
                    "starting with an empty store",
                    RuntimeWarning,
                    stacklevel=3,
                )
                self._data = {}
        return self._data

    @staticmethod
    def _parse_value(v) -> "tuple[float, str | None]":
        """v2 ``{"seconds": s, "machine": fp}`` or v1 bare seconds (which
        carry no provenance -> fingerprint None -> always stale)."""
        if isinstance(v, dict):
            fp = v.get("machine")
            return (float(v["seconds"]), fp if isinstance(fp, str) else None)
        return (float(v), None)

    def __len__(self) -> int:
        with self._lock:
            return len(self._load_locked())

    def get(self, key: "tuple | None") -> "float | None":
        """Stored seconds for a plan key measured on *this* topology, or
        None (uncacheable / unseen / recorded on a different machine)."""
        if key is None:
            return None
        with self._lock:
            hit = self._load_locked().get(self.encode_key(key))
            if hit is None:
                return None
            seconds, fp = hit
            if fp is None or fp != self._machine_key():
                self.stale += 1
                return None
            self.reused += 1
            return seconds

    def record(self, key: "tuple | None", seconds: float) -> None:
        if key is None:
            return
        with self._lock:
            self._load_locked()[self.encode_key(key)] = (
                float(seconds), self._machine_key(),
            )
            self.recorded += 1

    def save(self) -> None:
        """Atomic spill (tmp file + rename) of the entries valid for this
        topology — foreign and provenance-less (v1) entries are pruned.
        Silently skipped where the experiments directory is not writable."""
        with self._lock:
            mine = self._machine_key()
            data = self._load_locked()
            kept = {
                k: {"seconds": s, "machine": fp}
                for k, (s, fp) in data.items()
                if fp is not None and fp == mine
            }
            self.pruned = len(data) - len(kept)
            payload = {"version": _SCHEMA_VERSION, "probes": kept}
        try:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            tmp = self.path.with_suffix(".json.tmp")
            tmp.write_text(json.dumps(payload, indent=2, sort_keys=True))
            tmp.replace(self.path)
        except OSError:
            pass


_default_store: "ProbeStore | None" = None
_default_store_lock = threading.Lock()


def default_probe_store() -> ProbeStore:
    """The process-wide store at ``experiments/autotune_probes.json``
    (``REPRO_PROBES_PATH`` overrides the location)."""
    global _default_store
    with _default_store_lock:
        if _default_store is None:
            path = os.environ.get("REPRO_PROBES_PATH", str(DEFAULT_PROBES_PATH))
            _default_store = ProbeStore(path)
        return _default_store
