"""Strategy autotuner: rank the S1 x S2 x S3 x grain grid with the paper's
traffic model, optionally confirm the top-k with measured probes.

The paper's central claim is that picking the *right* strategy is what makes
irregular algorithms fast on migratory hardware — and the right choice is
workload-dependent (Rolinger & Krieger, 1812.05955). The autotuner makes
that choice a systematized engine feature instead of a caller obligation:

    strategy = choose_strategy("spmv", inputs)            # analytic, no execution
    result, report = run(Request("spmv", inputs, "auto")) # same thing, inline

    tuned = autotune("bfs", inputs, probe_top_k=3)      # + measured probes
    best = tuned.best                                    # probes warm the plan
    rows = tuned.table()                                 # cache for the real run

Ranking is analytic (core/cost.py): with no machine file the primary key is
the modeled traffic in bytes — identical to what a measured sweep's
RunReports would carry — tie-broken by the per-op balance model. With a
*calibrated* machine file (DESIGN.md §1f) the same estimates are converted
to predicted wall seconds by the
:class:`~repro.machine.perfmodel.PerformanceModel` and ranked in those,
with the traffic key demoted to tie-break; ``AutotuneResult.ranked_by``
records which key ordered the table. Precedence is probe > model > traffic
units: ``probe_top_k`` executes the leading candidates through the
compiled-plan cache (so the eventual production run of the winner is a
cache hit) and a decisively faster probe overrides either analytic
ranking. Pass a :class:`~repro.engine.probes.ProbeStore` to persist
measured probe seconds to ``experiments/autotune_probes.json`` — repeat
sessions on the same machine fingerprint reuse the stored timing instead
of re-probing.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any

from ..core.cost import CostEstimate, cost_model_for
from ..core.strategies import MigratoryStrategy, strategy_grid
from ..machine.machine import MachineProfile, default_machine
from ..machine.perfmodel import PerformanceModel
from .api import ExecutionPlan, RunReport, strategy_dict
from .cache import PlanCache
from .ops import GRAIN_CANDIDATES  # noqa: F401  (legacy re-export; lives with the OpSpecs)
from .probes import ProbeStore
from .registry import default_registry
from .runner import build_plan, resolve_op, run
from .substrate import Substrate


def candidate_grid(
    op_name: str, substrate: "Substrate | str | None" = None
) -> list[MigratoryStrategy]:
    """The autotuner's search space for one op: the op's registered
    ``OpSpec.grid`` (e.g. SpMV populates the grain axis, ``moe_dispatch``
    varies only S2), else the default S1 x S2 x S3 cross product.

    ``substrate`` targets the grid at a backend: grid callables that accept
    an argument receive the substrate *kind* and may widen a kernel-tuning
    axis for it (SpMV/BFS enumerate Pallas ``block_rows``); zero-arg grids
    are called as before, so the substrate-blind contract is unchanged."""
    spec = default_registry().op_spec(op_name)
    if spec.grid is None:
        return strategy_grid()
    kind = None
    if substrate is not None:
        from .substrate import get_substrate

        kind = get_substrate(substrate).substrate_kind
    if inspect.signature(spec.grid).parameters:
        return spec.grid(kind)
    return spec.grid()


@dataclasses.dataclass
class RankedCandidate:
    """One grid point: its analytic estimate + optional measured probe.
    ``probe_persisted`` marks a probe whose seconds came from the
    :class:`~repro.engine.probes.ProbeStore` instead of a fresh run."""

    rank: int
    estimate: CostEstimate
    probe: RunReport | None = None
    probe_persisted: bool = False

    @property
    def predicted_seconds(self) -> "float | None":
        """Modeled wall seconds (calibrated machine file only, else None)."""
        return self.estimate.predicted_seconds

    def to_row(self) -> dict[str, Any]:
        row = {
            "rank": self.rank,
            **{f"strategy_{k}": v for k, v in strategy_dict(self.estimate.strategy).items()},
            "traffic_bytes": self.estimate.traffic_bytes,
            "balance_penalty": self.estimate.balance_penalty,
            **self.estimate.detail,
        }
        if self.predicted_seconds is not None:
            row["predicted_seconds"] = self.predicted_seconds
        if self.probe is not None:
            row["probe_seconds"] = self.probe.seconds
            row["probe_compile_seconds"] = self.probe.compile_seconds
            row["probe_cache_hit"] = self.probe.cache_hit
            row["probe_persisted"] = self.probe_persisted
        return row


@dataclasses.dataclass
class AutotuneResult:
    op: str
    substrate: str
    best: MigratoryStrategy
    candidates: list[RankedCandidate]
    ranked_by: str = "traffic_bytes"  # or "predicted_seconds" when calibrated

    def table(self) -> list[dict[str, Any]]:
        """The ranking table (JSON rows) — the CI artifact."""
        return [
            {"op": self.op, "substrate": self.substrate,
             "chosen": c.estimate.strategy == self.best, **c.to_row()}
            for c in self.candidates
        ]


def _substrate_name(substrate: "Substrate | str") -> str:
    return substrate.name if isinstance(substrate, Substrate) else str(substrate)


def rank_strategies(
    op,
    inputs,
    candidates: "list[MigratoryStrategy] | None" = None,
    *,
    substrate: "Substrate | str" = "local",
    machine: "MachineProfile | None" = None,
) -> list[CostEstimate]:
    """Analytically rank candidate strategies for ``op`` on ``inputs``
    (best first). No execution, no compilation — shapes and static
    structure only.

    With a calibrated machine profile (``machine`` when given, else the
    process-wide :func:`~repro.machine.machine.default_machine`), each
    estimate gains ``predicted_seconds`` for ``substrate`` and the sort key
    becomes (predicted seconds, traffic key); uncalibrated, estimates are
    untouched and the ordering is bit-identical to the traffic units."""
    op = resolve_op(op)
    model = cost_model_for(op.name, inputs)
    cands = candidates if candidates is not None else candidate_grid(op.name, substrate)
    estimates = [model(st) for st in cands]
    profile = machine if machine is not None else default_machine()
    if profile.calibrated:
        estimates = PerformanceModel(profile).attach(
            estimates, _substrate_name(substrate)
        )
        return sorted(estimates, key=lambda e: (e.predicted_seconds, *e.rank_key()))
    return sorted(estimates, key=lambda e: e.rank_key())


def choose_strategy(
    op, inputs, substrate: "Substrate | str" = "local",
    machine: "MachineProfile | None" = None,
) -> MigratoryStrategy:
    """The model-optimal strategy — what ``strategy="auto"`` runs. Ranked
    in predicted seconds when a calibrated machine file is present, in the
    paper's traffic units otherwise."""
    return rank_strategies(op, inputs, substrate=substrate, machine=machine)[0].strategy


def _persisted_probe_report(op, plan: ExecutionPlan, seconds: float) -> RunReport:
    """A RunReport standing in for a probe served from the persisted store:
    measured seconds from a prior session, analytic traffic from the plan.
    No execution happened, so the plan cache was not warmed —
    ``cache_hit=False`` stays truthful; ``probe_persisted`` in the ranking
    row carries the provenance."""
    return RunReport.from_parts(
        op=op.name,
        strategy=plan.strategy,
        substrate=plan.substrate,
        seconds=seconds,
        traffic=op.traffic(plan),
        bytes_moved=op.bytes_moved(plan),
        metrics={},
        cache_hit=False,
        compile_seconds=0.0,
    )


def autotune(
    op,
    inputs,
    substrate: "Substrate | str" = "local",
    *,
    probe_top_k: int = 0,
    iters: int = 3,
    warmup: int = 1,
    cache: PlanCache | None = None,
    override_margin: float = 0.2,
    probe_store: "ProbeStore | None" = None,
    machine: "MachineProfile | None" = None,
) -> AutotuneResult:
    """Rank the grid; optionally execute the top ``probe_top_k`` candidates
    through the plan cache and let measured seconds pick among them.

    A probe overrides the traffic-model pick only when it is decisively
    faster (by ``override_margin``): on substrates where a strategy axis is
    execution-inert (e.g. S2 on the single-device local oracle) probe
    timings are pure noise, and the model's choice stands. Probes compile
    each probed candidate's plan, so the subsequent production run of
    ``result.best`` is a cache hit.

    With a ``probe_store``, candidates whose plan key already has a stored
    measurement *from this machine fingerprint* skip execution and reuse
    the persisted seconds (those candidates do *not* warm the plan cache);
    entries recorded on a different topology read as absent and are pruned
    when the store is spilled to disk before returning.
    """
    op = resolve_op(op)
    profile = machine if machine is not None else default_machine()
    estimates = rank_strategies(op, inputs, substrate=substrate, machine=profile)
    candidates = [RankedCandidate(rank=i + 1, estimate=e) for i, e in enumerate(estimates)]
    best = candidates[0].estimate.strategy
    if probe_top_k > 0:
        # probe only cost-distinct candidates: grid points whose estimates tie
        # exactly differ in axes the op never reads, so one probe covers them.
        # The substrate-targeted working set (and predicted seconds, when
        # calibrated) join the signature so block-size variants that tie in
        # traffic units — the whole Pallas grain axis does — still get their
        # own probes: the target substrate's kernel *does* read that axis.
        from .substrate import get_substrate

        kind = get_substrate(substrate).substrate_kind
        probed: list[RankedCandidate] = []
        seen_costs: set[tuple] = set()
        for cand in candidates:
            targeted = (cand.estimate.detail.get("substrate_memory") or {}).get(kind)
            cost_sig = (
                cand.estimate.traffic_bytes,
                cand.estimate.balance_penalty,
                cand.estimate.predicted_seconds,
                targeted.get("bytes_per_launch") if targeted else None,
            )
            if cost_sig in seen_costs:
                continue
            seen_costs.add(cost_sig)
            plan = build_plan(op, inputs, cand.estimate.strategy, substrate)
            stored = probe_store.get(plan.key) if probe_store is not None else None
            if stored is not None:
                cand.probe = _persisted_probe_report(op, plan, stored)
                cand.probe_persisted = True
            else:
                _, report = run(
                    op, inputs, cand.estimate.strategy, substrate,
                    iters=iters, warmup=warmup, cache=cache,
                )
                cand.probe = report
                if probe_store is not None:
                    probe_store.record(plan.key, report.seconds)
            probed.append(cand)
            if len(probed) >= probe_top_k:
                break
        fastest = min(probed, key=lambda c: c.probe.seconds)
        model_pick = probed[0]  # rank 1 is always probed first
        if fastest.probe.seconds < model_pick.probe.seconds * (1.0 - override_margin):
            best = fastest.estimate.strategy
        if probe_store is not None:
            probe_store.save()
    return AutotuneResult(
        op=op.name,
        substrate=_substrate_name(substrate),
        best=best,
        candidates=candidates,
        ranked_by="predicted_seconds" if profile.calibrated else "traffic_bytes",
    )
