"""The op x substrate kernel registry (DESIGN.md §1e).

The paper's thesis is that migratory-thread programming is a *family* of
strategies for irregular algorithms, not a fixed menu of three. The engine
therefore keeps ops and substrates decoupled: an op contributes an
:class:`OpSpec` (how to build it, how to rank strategies for it), a backend
contributes kernels — concrete ``(op_name, substrate_kind)`` entry points —
and the registry is the only place the two meet. Adding a workload never
edits a substrate class; adding a backend never edits an op:

    from repro.engine.registry import OpSpec, kernel, register_op

    @kernel("moe_dispatch", "local")
    def _moe_local(substrate, x, router, *, strategy, **statics): ...

    @kernel("moe_dispatch", "mesh")
    def _moe_mesh(substrate, x, router, *, strategy, **statics): ...

    register_op(OpSpec(name="moe_dispatch", factory=MoEDispatchOp,
                       inputs_type=MoEDispatchInputs,
                       cost_model=moe_dispatch_cost_model,
                       grid=moe_dispatch_grid))

``Substrate.kernel(op_name)`` resolves through :func:`resolve_kernel`;
absence *is* the capability signal — it raises
:class:`~repro.engine.api.OpNotSupportedError`, so "does this backend run
this op" is a registry lookup, not a method override. The
:func:`capabilities` table is the introspection view CI diff-checks against
the registered kernels (``benchmarks/capabilities_check.py``).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable

from .api import OpNotSupportedError

# A kernel is a plain function: (substrate, *args, **statics) -> result.
# The substrate instance arrives first so kernels can use backend handles
# (mesh_for(), interpret flags) without subclassing anything.
Kernel = Callable[..., Any]


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """Everything the engine needs to serve one op, minus the kernels.

    ``factory`` builds the :class:`~repro.engine.api.MigratoryOp` adapter
    (plan/traffic/bytes_moved/metrics). ``inputs_type`` is the op's input
    dataclass (documentation + introspection). ``cost_model`` is the
    autotuner's analytic factory ``inputs -> (strategy -> CostEstimate)``;
    registering a spec installs it into ``core.cost`` so
    ``cost_model_for(name, inputs)`` serves every registered op from one
    lookup. ``grid`` yields the op's autotune candidate strategies (None:
    the default S1 x S2 x S3 cross product); a grid callable that accepts
    an argument is called with the target *substrate kind* (or None), so
    an op can widen a kernel-tuning axis per backend — SpMV/BFS enumerate
    Pallas ``block_rows`` candidates only when tuning for ``"pallas"``,
    while zero-arg grids stay substrate-blind
    (:func:`~repro.engine.autotune.candidate_grid` adapts the call).
    """

    name: str
    factory: Callable[[], Any]
    inputs_type: "type | None" = None
    cost_model: "Callable[[Any], Any] | None" = None
    grid: "Callable[..., list] | None" = None


class KernelRegistry:
    """Thread-safe ``(op_name, substrate_kind) -> kernel`` table plus the
    op-spec table. One default instance serves the process; tests may build
    private registries."""

    def __init__(self):
        self._lock = threading.RLock()
        self._specs: dict[str, OpSpec] = {}
        self._kernels: dict[tuple[str, str], Kernel] = {}

    # -- ops -------------------------------------------------------------------

    def register_op(self, spec: OpSpec, *, replace: bool = False) -> OpSpec:
        with self._lock:
            if spec.name in self._specs and not replace:
                raise ValueError(f"op {spec.name!r} already registered")
            self._specs[spec.name] = spec
        if spec.cost_model is not None:
            from ..core.cost import register_cost_model

            register_cost_model(spec.name, spec.cost_model)
        return spec

    def op_spec(self, name: str) -> OpSpec:
        with self._lock:
            try:
                return self._specs[name]
            except KeyError:
                raise ValueError(
                    f"unknown op {name!r}; known: {sorted(self._specs)}"
                ) from None

    def ops(self) -> list[str]:
        with self._lock:
            return sorted(self._specs)

    # -- kernels ---------------------------------------------------------------

    def register_kernel(
        self, op_name: str, substrate_kind: str, fn: Kernel, *, replace: bool = False
    ) -> Kernel:
        key = (op_name, substrate_kind)
        with self._lock:
            if key in self._kernels and not replace:
                raise ValueError(f"kernel {key} already registered")
            self._kernels[key] = fn
        return fn

    def resolve_kernel(self, op_name: str, substrate_kind: str) -> Kernel:
        """The dispatch point: missing entry == unsupported capability."""
        with self._lock:
            fn = self._kernels.get((op_name, substrate_kind))
        if fn is None:
            raise OpNotSupportedError(
                f"no kernel registered for op {op_name!r} on substrate "
                f"{substrate_kind!r} (registered kernels for this op: "
                f"{[k for o, k in self.kernels() if o == op_name]})"
            )
        return fn

    def has_kernel(self, op_name: str, substrate_kind: str) -> bool:
        with self._lock:
            return (op_name, substrate_kind) in self._kernels

    def kernels(self) -> list[tuple[str, str]]:
        with self._lock:
            return sorted(self._kernels)

    def kernel_kinds(self) -> list[str]:
        """Every substrate kind any kernel was registered under."""
        with self._lock:
            return sorted({kind for _, kind in self._kernels})


_DEFAULT_REGISTRY = KernelRegistry()


def default_registry() -> KernelRegistry:
    """The process-wide registry every engine entry point dispatches through."""
    return _DEFAULT_REGISTRY


def register_op(spec: OpSpec, *, replace: bool = False) -> OpSpec:
    return _DEFAULT_REGISTRY.register_op(spec, replace=replace)


def kernel(op_name: str, substrate_kind: str, *, replace: bool = False):
    """Decorator: ``@kernel("spmv", "mesh")`` registers the function as the
    mesh backend's SpMV entry point in the default registry."""

    def deco(fn: Kernel) -> Kernel:
        return _DEFAULT_REGISTRY.register_kernel(
            op_name, substrate_kind, fn, replace=replace
        )

    return deco


def capabilities() -> dict[str, dict[str, bool]]:
    """The op x substrate capability table: for every registered op, which
    registered substrates resolve a kernel for it.

    Columns are the *substrate registry's* names (``list_substrates()``),
    resolved through a real instance's ``substrate_kind`` — so the table
    reflects what ``engine.run(op, ..., substrate=name)`` would actually
    dispatch, and CI can diff it against the raw kernel table to catch
    kernels registered under kinds no substrate serves.
    """
    from .substrate import get_substrate, list_substrates

    reg = _DEFAULT_REGISTRY
    table: dict[str, dict[str, bool]] = {}
    kinds = {name: get_substrate(name).substrate_kind for name in list_substrates()}
    for op_name in reg.ops():
        table[op_name] = {
            name: reg.has_kernel(op_name, kind) for name, kind in kinds.items()
        }
    return table


def placement_table() -> dict[str, dict[str, Any]]:
    """The substrate placement view the executor pool routes by: for every
    registered substrate, its kernel-lookup kind, its placement policy
    (``"affinity"`` = plan-key groups pin to one slot, never stolen;
    ``"spread"`` = round-robin + work stealing), and how many independent
    execution slots it can drive on this host (``placement_slots()`` —
    device count on mesh, core count on local/pallas). The
    :class:`~repro.engine.service.EngineService` sizes ``workers="auto"``
    pools from this and benchmark artifacts record it, so a throughput
    number is always interpretable against the channels that produced it.
    """
    from .substrate import get_substrate, list_substrates

    table: dict[str, dict[str, Any]] = {}
    for name in list_substrates():
        sub = get_substrate(name)
        table[name] = {
            "kind": sub.substrate_kind,
            "policy": sub.placement_policy,
            "slots": sub.placement_slots(),
        }
    return table
