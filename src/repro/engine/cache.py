"""Compiled-plan cache: jit an executor once per plan key, reuse it for every
later plan with the same shape/dtype/strategy/substrate signature
(DESIGN.md §1b).

The engine's plan -> compile -> execute pipeline looks executors up here.
A *miss* wraps the plan's executor in ``jax.jit`` (unless the plan opted out
with ``jit=False``) and marks the entry pending; the runner times the
executor's first call (trace + XLA compile + first run on this signature)
and records it via :meth:`PlanCache.note_compiled`. A *hit* hands back the
already-warm executable, so the call skips tracing entirely and the run's
``RunReport`` carries ``cache_hit=True, compile_seconds=0.0`` — benchmarks
and the :class:`~repro.engine.service.EngineService` use this to separate
compile cost from steady-state throughput. Jitting here (rather than in
each kernel) is what makes the compile stage *compile*: before it, mesh
substrate plans executed ``shard_map`` op-by-op on every call, costing
seconds per request; the cached executable runs the same program fused.

Caching an executor closure is sound because :func:`~repro.engine.api.plan_key`
pins everything the closure captures: the op, the substrate fingerprint
(mesh identity / device window / interpret flag included), every strategy
axis, the op's static scalars, and the argument pytree signature. Only
array *values* vary across reuses — exactly what the executors are
polymorphic over.

**Placement pinning** (the executor pool, DESIGN.md §1d): entries remember
the pool slot that first compiled them (``CacheEntry.slot``). The service's
scheduler routes a plan-key group to its pinned slot so a compiled
executable keeps serving from the worker that owns it — a work-steal
*executes* a warm entry from another worker (the executable is shared
process memory) but never re-pins it, so the next group with that key still
routes home and the cache is not thrashed by migration.

The cache is thread-safe: the scheduler resolves plans while N executor
workers serve cache hits concurrently, so every entry-table access is taken
under one lock. Executor *calls* happen outside the lock — only the
bookkeeping is serialized.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any, Callable

import jax

from .api import ExecutionPlan


@dataclasses.dataclass
class CacheEntry:
    """One cached executor + its compile accounting."""

    executor: Callable[..., Any]
    compiled: bool = False  # first call completed (jax traced + compiled)
    compile_seconds: float = 0.0
    hits: int = 0
    slot: int | None = None  # executor-pool placement pin (None = unpinned)


@dataclasses.dataclass
class CompiledPlan:
    """A plan resolved through the cache, ready to execute.

    ``cache_hit`` is True iff an executor that already completed its first
    (compiling) call was reused — the run will be pure steady state.
    """

    plan: ExecutionPlan
    executor: Callable[..., Any]
    cache_hit: bool
    entry: CacheEntry | None

    def __call__(self) -> Any:
        return self.executor(*self.plan.args)


class PlanCache:
    """LRU cache of compiled executors keyed by ``ExecutionPlan.key``."""

    # placement pins for keys whose *entries* live under a different key
    # (a mesh group's base key aliases its slot-variant compiled key);
    # bounded separately from the entry table
    _PIN_ALIAS_MAX = 4096

    def __init__(self, max_entries: int = 256):
        self.max_entries = max_entries
        self._entries: collections.OrderedDict[tuple, CacheEntry] = collections.OrderedDict()
        self._key_pins: collections.OrderedDict[tuple, int] = collections.OrderedDict()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.uncacheable = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __bool__(self) -> bool:
        return True  # an empty cache is still a cache, not a None stand-in

    def get(self, plan: ExecutionPlan, *, slot: "int | None" = None) -> CompiledPlan:
        """Resolve a plan's executor. Keyless plans bypass the cache (and
        stay eager — a jit wrapper with no reuse only adds tracing cost).
        ``slot`` tags the entry with the executor-pool slot on first
        resolution; later resolutions never move the pin."""
        with self._lock:
            if plan.key is None:
                self.uncacheable += 1
                return CompiledPlan(plan, plan.executor, cache_hit=False, entry=None)
            entry = self._entries.get(plan.key)
            if entry is not None:
                self._entries.move_to_end(plan.key)
                if slot is not None and entry.slot is None:
                    entry.slot = slot  # adopt: e.g. batch-compiled, pool-served
                if entry.compiled:
                    entry.hits += 1
                    self.hits += 1
                    return CompiledPlan(plan, entry.executor, cache_hit=True, entry=entry)
                # entry exists but its first call never ran: still a cold path
                self.misses += 1
                return CompiledPlan(plan, entry.executor, cache_hit=False, entry=entry)
            executor = jax.jit(plan.executor) if plan.jit else plan.executor
            entry = CacheEntry(executor=executor, slot=slot)
            self._entries[plan.key] = entry
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
            self.misses += 1
            return CompiledPlan(plan, entry.executor, cache_hit=False, entry=entry)

    def note_compiled(self, compiled: CompiledPlan, seconds: float) -> None:
        """Record the timed first call of a miss (trace + compile + run)."""
        with self._lock:
            if compiled.entry is not None and not compiled.entry.compiled:
                compiled.entry.compiled = True
                compiled.entry.compile_seconds = seconds

    def is_warm(self, key: "tuple | None") -> bool:
        """True iff ``key`` resolves to an executor whose compiling call
        already completed — the pool scheduler's bypass test (warm groups go
        straight to their worker; only cold groups visit the compile stage)."""
        if key is None:
            return False
        with self._lock:
            entry = self._entries.get(key)
            return entry is not None and entry.compiled

    def pin_key(self, key: "tuple | None", slot: int) -> None:
        """Pin a *key* to a slot without requiring an entry under it. The
        pool's placement uses this for base plan keys whose compiled entry
        is stored under a slot-variant key (device windows change the
        fingerprint), so affinity survives the service's own pin table —
        e.g. across services sharing one cache. First pin wins."""
        if key is None:
            return
        with self._lock:
            if key not in self._key_pins:
                self._key_pins[key] = slot
                while len(self._key_pins) > self._PIN_ALIAS_MAX:
                    self._key_pins.popitem(last=False)

    def slot_of(self, key: "tuple | None") -> "int | None":
        """The executor-pool slot pinned at first compile (None = unpinned).
        Falls back to the :meth:`pin_key` alias table for keys whose entry
        lives under a variant key."""
        if key is None:
            return None
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None and entry.slot is not None:
                return entry.slot
            return self._key_pins.get(key)

    def stats(self) -> dict[str, Any]:
        """Aggregate counters — the benchmark/CI cache health record."""
        with self._lock:
            lookups = self.hits + self.misses
            return {
                "entries": len(self._entries),
                "hits": self.hits,
                "misses": self.misses,
                "uncacheable": self.uncacheable,
                "hit_rate": self.hits / lookups if lookups else 0.0,
                "compile_seconds_total": sum(
                    e.compile_seconds for e in self._entries.values()
                ),
                "pinned": sum(
                    1 for e in self._entries.values() if e.slot is not None
                ),
            }

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._key_pins.clear()
            self.hits = self.misses = self.uncacheable = 0


_DEFAULT_CACHE = PlanCache()


def default_cache() -> PlanCache:
    """The process-wide cache ``engine.run`` uses when none is passed."""
    return _DEFAULT_CACHE
