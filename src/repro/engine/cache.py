"""Compiled-plan cache: jit an executor once per plan key, reuse it for every
later plan with the same shape/dtype/strategy/substrate signature
(DESIGN.md §1b).

The engine's plan -> compile -> execute pipeline looks executors up here.
A *miss* hands back the plan's own executor and marks the entry pending; the
runner times the executor's first call (trace + compile + first run on this
signature) and records it via :meth:`PlanCache.note_compiled`. A *hit* hands
back the already-warm executor, so the call skips tracing entirely and the
run's ``RunReport`` carries ``cache_hit=True, compile_seconds=0.0`` —
benchmarks and the :class:`~repro.engine.service.EngineService` use this to
separate compile cost from steady-state throughput.

Caching an executor closure is sound because :func:`~repro.engine.api.plan_key`
pins everything the closure captures: the op, the substrate fingerprint
(mesh identity / interpret flag included), every strategy axis, the op's
static scalars, and the argument pytree signature. Only array *values* vary
across reuses — exactly what the executors are polymorphic over.

The cache is thread-safe: the async :class:`~repro.engine.service.EngineService`
resolves plans from its compile thread while its execute thread serves cache
hits, so every entry-table access is taken under one lock. Executor *calls*
happen outside the lock — only the bookkeeping is serialized.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any, Callable

from .api import ExecutionPlan


@dataclasses.dataclass
class CacheEntry:
    """One cached executor + its compile accounting."""

    executor: Callable[..., Any]
    compiled: bool = False  # first call completed (jax traced + compiled)
    compile_seconds: float = 0.0
    hits: int = 0


@dataclasses.dataclass
class CompiledPlan:
    """A plan resolved through the cache, ready to execute.

    ``cache_hit`` is True iff an executor that already completed its first
    (compiling) call was reused — the run will be pure steady state.
    """

    plan: ExecutionPlan
    executor: Callable[..., Any]
    cache_hit: bool
    entry: CacheEntry | None

    def __call__(self) -> Any:
        return self.executor(*self.plan.args)


class PlanCache:
    """LRU cache of compiled executors keyed by ``ExecutionPlan.key``."""

    def __init__(self, max_entries: int = 256):
        self.max_entries = max_entries
        self._entries: collections.OrderedDict[tuple, CacheEntry] = collections.OrderedDict()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.uncacheable = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __bool__(self) -> bool:
        return True  # an empty cache is still a cache, not a None stand-in

    def get(self, plan: ExecutionPlan) -> CompiledPlan:
        """Resolve a plan's executor. Keyless plans bypass the cache."""
        with self._lock:
            if plan.key is None:
                self.uncacheable += 1
                return CompiledPlan(plan, plan.executor, cache_hit=False, entry=None)
            entry = self._entries.get(plan.key)
            if entry is not None:
                self._entries.move_to_end(plan.key)
                if entry.compiled:
                    entry.hits += 1
                    self.hits += 1
                    return CompiledPlan(plan, entry.executor, cache_hit=True, entry=entry)
                # entry exists but its first call never ran: still a cold path
                self.misses += 1
                return CompiledPlan(plan, entry.executor, cache_hit=False, entry=entry)
            entry = CacheEntry(executor=plan.executor)
            self._entries[plan.key] = entry
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
            self.misses += 1
            return CompiledPlan(plan, entry.executor, cache_hit=False, entry=entry)

    def note_compiled(self, compiled: CompiledPlan, seconds: float) -> None:
        """Record the timed first call of a miss (trace + compile + run)."""
        with self._lock:
            if compiled.entry is not None and not compiled.entry.compiled:
                compiled.entry.compiled = True
                compiled.entry.compile_seconds = seconds

    def stats(self) -> dict[str, Any]:
        """Aggregate counters — the benchmark/CI cache health record."""
        with self._lock:
            lookups = self.hits + self.misses
            return {
                "entries": len(self._entries),
                "hits": self.hits,
                "misses": self.misses,
                "uncacheable": self.uncacheable,
                "hit_rate": self.hits / lookups if lookups else 0.0,
                "compile_seconds_total": sum(
                    e.compile_seconds for e in self._entries.values()
                ),
            }

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.hits = self.misses = self.uncacheable = 0


_DEFAULT_CACHE = PlanCache()


def default_cache() -> PlanCache:
    """The process-wide cache ``engine.run`` uses when none is passed."""
    return _DEFAULT_CACHE
