"""MoE token dispatch as the engine's fourth MigratoryOp (DESIGN.md §1e, §4).

Token -> expert routing IS the paper's irregular-access problem: a token
must reach the nodelet owning its expert, and the S2 axis decides how —
``remote_write`` pushes binned tokens with all_to_all packets (Alg. 2),
``migrate`` pulls the whole token set to every owner with an all_gather
(Alg. 1), and the S1-flavored ``tp`` fallback replicates the expert set so
dispatch stays node-local. The mode derivation is exactly
:func:`repro.models.moe.dispatch_from_strategy` — the same mapping the LM
stack uses — so the engine's autotuner ranks real MoE deployment choices.

This file is the registry's proof of decoupling: it registers
``moe_dispatch`` kernels for the ``local`` and ``mesh`` substrate kinds and
an :class:`~repro.engine.registry.OpSpec` (with a roofline collective-bytes
cost model) **without editing any existing Substrate subclass** — pallas
simply has no entry, so ``OpNotSupportedError`` falls out of the registry.

The op executes the dispatch *transport* (routing, capacity binning, the
collectives, and the gate-weighted combine) and, when the inputs carry
expert weights (``w_gate``/``w_up``/``w_down`` in the
:func:`repro.models.moe.moe_params` layout), the real SwiGLU expert FFN at
the owner stage — the same :func:`repro.models.moe.expert_ffn` math the LM
stack runs, applied to the capacity buffers between commit and gather-back.
Without weights the experts are identity and the op degenerates to the
pure transport it was through PR 7. Local and mesh kernels are
bit-identical either way: per-shard math is shared helper code, the
exchanges are pure permutations, expert weights shard over E exactly as
shard_map would slice them, and the pull-mode return trip uses a psum in
which every slot has exactly one nonzero contributor (float-exact by
construction).
"""
from __future__ import annotations

import dataclasses
import functools
import weakref
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core.cost import CostEstimate
from ..core.strategies import (
    CONTEXT_BYTES,
    Layout,
    MigratoryStrategy,
    Scheme,
    TrafficStats,
    strategy_grid,
)
from ..core.util import round_up
from ..models.moe import _positions_in_expert, dispatch_from_strategy, expert_ffn
from .api import ExecutionPlan, OpNotSupportedError, plan_key
from .registry import OpSpec, kernel, register_op
from .substrate import Substrate


@dataclasses.dataclass(frozen=True)
class MoEDispatchInputs:
    """One dispatch problem: ``x`` (T, D) token activations, ``router``
    (D, E) routing weights. ``nodelets`` is the expert-parallel width the
    strategy maps onto (the Chick's nodelet count); ep modes additionally
    need ``E % nodelets == 0`` — otherwise every strategy degrades to the
    ``tp`` replication fallback, exactly like the LM stack."""

    x: jax.Array
    router: jax.Array
    nodelets: int = 8
    experts_per_token: int = 2
    capacity_factor: float = 1.25
    # optional expert weights (moe_params layout): present -> the op runs
    # the real SwiGLU FFN at the owner stage; absent -> identity experts
    w_gate: "jax.Array | None" = None  # (E, D, F)
    w_up: "jax.Array | None" = None  # (E, D, F)
    w_down: "jax.Array | None" = None  # (E, F, D)

    @property
    def num_experts(self) -> int:
        return int(self.router.shape[-1])

    @property
    def has_experts(self) -> bool:
        return self.w_gate is not None

    @property
    def ffn_args(self) -> tuple:
        """The traced weight args, in kernel order — () when identity."""
        if not self.has_experts:
            return ()
        return (self.w_gate, self.w_up, self.w_down)

    def validate_experts(self) -> None:
        ws = (self.w_gate, self.w_up, self.w_down)
        present = [w is not None for w in ws]
        if not any(present):
            return
        if not all(present):
            raise ValueError(
                "moe_dispatch expert weights are all-or-none: pass "
                "w_gate, w_up and w_down together"
            )
        E, D = self.num_experts, int(self.x.shape[-1])
        F = int(self.w_gate.shape[-1])
        want = {"w_gate": (E, D, F), "w_up": (E, D, F), "w_down": (E, F, D)}
        for name, shape in want.items():
            got = tuple(getattr(self, name).shape)
            if got != shape:
                raise ValueError(
                    f"moe_dispatch {name} must have shape {shape} "
                    f"(moe_params layout), got {got}"
                )


def _cap(capacity_factor: float, expected_slots: float) -> int:
    """Static buffer capacity: expected slot count x factor, 8-aligned."""
    return max(8, round_up(int(capacity_factor * expected_slots), 8))


def derive_mode(inputs: MoEDispatchInputs, strategy: MigratoryStrategy) -> str:
    """The strategy -> dispatch-mode mapping, shared with models/moe.py."""
    return dispatch_from_strategy(
        strategy, num_experts=inputs.num_experts, data_axis=inputs.nodelets
    )


# -- shared per-shard pieces (identical code on both substrates) ---------------


def _route_shard(x_s: jax.Array, router: jax.Array, *, k: int):
    """x_s: (t, D) -> normalized top-k gates (t, k) in x.dtype, experts (t, k)."""
    logits = jnp.einsum(
        "td,de->te", x_s.astype(jnp.float32), router.astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates.astype(x_s.dtype), experts.astype(jnp.int32)


def _tp_shard(x_s, router, ffn=None, *, k, num_experts, cap):
    """S1 fallback: all experts resident, dispatch is a node-local scatter
    into (E, cap, D) buffers, the (optional) expert FFN, and a gate-weighted
    gather back."""
    t, d = x_s.shape
    gates, experts = _route_shard(x_s, router, k=k)
    ef = experts.reshape(-1)
    pos = _positions_in_expert(ef, num_experts)
    keep = pos < cap
    xk = jnp.repeat(x_s, k, axis=0)
    buf = jnp.zeros((num_experts, cap, d), x_s.dtype)
    buf = buf.at[jnp.where(keep, ef, 0), jnp.where(keep, pos, 0)].add(
        jnp.where(keep[:, None], xk, 0), mode="drop"
    )
    if ffn is not None:
        buf = expert_ffn(ffn, buf)
    vals = buf[jnp.where(keep, ef, 0), jnp.where(keep, pos, 0)]
    vals = jnp.where(keep[:, None], vals, 0)
    return jnp.sum((vals * gates.reshape(-1)[:, None]).reshape(t, k, d), axis=1)


def _push_pre(x_s, router, *, k, P, e_local, cap_pair):
    """Sender side of ep_push: bin local slots by destination owner into the
    (P_dst, cap_pair, D) send buffer (+ expert-id plane, -1 pad)."""
    gates, experts = _route_shard(x_s, router, k=k)
    ef = experts.reshape(-1)
    owner = ef // e_local
    pos = _positions_in_expert(owner, P)
    keep = pos < cap_pair
    xk = jnp.repeat(x_s, k, axis=0)
    ow = jnp.where(keep, owner, 0)
    ps = jnp.where(keep, pos, 0)
    send = jnp.zeros((P, cap_pair, x_s.shape[1]), x_s.dtype)
    send = send.at[ow, ps].add(jnp.where(keep[:, None], xk, 0), mode="drop")
    send_e = jnp.full((P, cap_pair), -1, jnp.int32)
    send_e = send_e.at[ow, ps].max(jnp.where(keep, ef, -1), mode="drop")
    return send, send_e, gates, ow, ps, keep


def _push_owner(recv, recv_e, shard_id, ffn=None, *, e_local, cap_e):
    """Owner side of ep_push: commit received slots into per-local-expert
    buffers (second capacity stage), run the experts (identity when ``ffn``
    is None, the owner's SwiGLU shard otherwise), and hand the slot values
    back in the received (P_src, cap_pair) layout."""
    p_src, cap_pair, d = recv.shape
    rf = (recv_e - shard_id * e_local).reshape(-1)
    rf = jnp.where(recv_e.reshape(-1) >= 0, rf, e_local)  # e_local = pad bin
    rpos = _positions_in_expert(rf, e_local + 1)
    rkeep = (rf < e_local) & (rpos < cap_e)
    rx = recv.reshape(-1, d)
    buf = jnp.zeros((e_local, cap_e, d), recv.dtype)
    buf = buf.at[jnp.where(rkeep, rf, 0), jnp.where(rkeep, rpos, 0)].add(
        jnp.where(rkeep[:, None], rx, 0), mode="drop"
    )
    if ffn is not None:
        buf = expert_ffn(ffn, buf)
    out = buf[jnp.where(rkeep, rf, 0), jnp.where(rkeep, rpos, 0)]
    out = jnp.where(rkeep[:, None], out, 0)
    return out.reshape(p_src, cap_pair, d)


def _push_post(back, gates, ow, ps, keep, *, t, k):
    """Sender-side combine: read each slot's returned value, weight by gate."""
    vals = back[ow, ps]
    vals = jnp.where(keep[:, None], vals, 0)
    return jnp.sum((vals * gates.reshape(-1)[:, None]).reshape(t, k, -1), axis=1)


def _pull_owner(x_full, eg, shard_id, ffn=None, *, k, e_local, cap_e):
    """Owner side of ep_pull: the full gathered slot stream, committed into
    my experts' buffers (then through my expert shard when ``ffn`` is set);
    returns per-slot values, nonzero only for slots I own AND kept (<= one
    nonzero contributor per slot across owners)."""
    mine = (eg // e_local) == shard_id
    le = jnp.where(mine, eg - shard_id * e_local, e_local)
    pos = _positions_in_expert(le, e_local + 1)
    keep = mine & (pos < cap_e)
    xkg = jnp.repeat(x_full, k, axis=0)  # (T*k, D) global slot stream
    buf = jnp.zeros((e_local, cap_e, x_full.shape[1]), x_full.dtype)
    buf = buf.at[jnp.where(keep, le, 0), jnp.where(keep, pos, 0)].add(
        jnp.where(keep[:, None], xkg, 0), mode="drop"
    )
    if ffn is not None:
        buf = expert_ffn(ffn, buf)
    out = buf[jnp.where(keep, le, 0), jnp.where(keep, pos, 0)]
    return jnp.where(keep[:, None], out, 0)  # (T*k, D)


def _pull_combine(vals_local, gates, x_s, *, t, k):
    del x_s  # combine consumes only returned slot values (post-capacity)
    vals = vals_local * gates.reshape(-1)[:, None]
    return jnp.sum(vals.reshape(t, k, -1), axis=1)


# -- local kernel: vmap emulation over the nodelet axis ------------------------


def _ffn_dict(ws: tuple) -> "dict | None":
    """(w_gate, w_up, w_down) kernel args -> expert_ffn params (or None)."""
    if not ws:
        return None
    g, u, d = ws
    return {"w_gate": g, "w_up": u, "w_down": d}


def _ffn_shards(ffn: "dict | None", P: int) -> "dict | None":
    """Slice replicated (E, ...) expert weights into the per-owner blocks
    shard_map's ``P_(axis)`` in_spec would hand each shard — leading axis P,
    so the local vmap emulation sees exactly the mesh shard's weights."""
    if ffn is None:
        return None
    return {k: w.reshape(P, w.shape[0] // P, *w.shape[1:]) for k, w in ffn.items()}


@functools.partial(
    jax.jit,
    static_argnames=("mode", "nodelets", "experts_per_token", "capacity_factor"),
)
def _dispatch_local(
    x, router, w_gate=None, w_up=None, w_down=None, *,
    mode, nodelets, experts_per_token, capacity_factor,
):
    P, k = nodelets, experts_per_token
    T, D = x.shape
    E = router.shape[-1]
    t = T // P
    xs = x.reshape(P, t, D)
    ffn = None if w_gate is None else {"w_gate": w_gate, "w_up": w_up, "w_down": w_down}
    if mode == "tp":
        cap = _cap(capacity_factor, t * k / E)
        # tp replicates the whole expert set per shard: weights ride in the
        # closure, broadcast across the vmapped nodelet axis
        body = functools.partial(_tp_shard, ffn=ffn, k=k, num_experts=E, cap=cap)
        return jax.vmap(body, in_axes=(0, None))(xs, router).reshape(T, D)
    e_local = E // P
    cap_e = _cap(capacity_factor, T * k / E)
    ffn_s = _ffn_shards(ffn, P)  # ep modes: weights shard over E
    if mode == "ep_push":
        cap_pair = _cap(capacity_factor, t * k / P)
        pre = functools.partial(_push_pre, k=k, P=P, e_local=e_local, cap_pair=cap_pair)
        send, send_e, gates, ow, ps, keep = jax.vmap(pre, in_axes=(0, None))(xs, router)
        recv = jnp.swapaxes(send, 0, 1)  # the all_to_all, as a transpose
        recv_e = jnp.swapaxes(send_e, 0, 1)
        owner = functools.partial(_push_owner, e_local=e_local, cap_e=cap_e)
        out = jax.vmap(owner)(recv, recv_e, jnp.arange(P), ffn_s)
        back = jnp.swapaxes(out, 0, 1)  # the return all_to_all
        post = functools.partial(_push_post, t=t, k=k)
        return jax.vmap(post)(back, gates, ow, ps, keep).reshape(T, D)
    if mode == "ep_pull":
        route = functools.partial(_route_shard, k=k)
        gates, experts = jax.vmap(route, in_axes=(0, None))(xs, router)
        eg = experts.reshape(-1)  # global slot stream, stripe-major
        owner = functools.partial(_pull_owner, k=k, e_local=e_local, cap_e=cap_e)
        contrib = jax.vmap(owner, in_axes=(None, None, 0, 0))(
            x, eg, jnp.arange(P), ffn_s
        )
        vals_all = contrib.sum(0)  # exact: <= 1 nonzero contributor per slot
        vals = vals_all.reshape(P, t * k, D)
        comb = functools.partial(_pull_combine, t=t, k=k)
        return jax.vmap(comb)(vals, gates, xs).reshape(T, D)
    raise ValueError(f"unknown dispatch mode {mode!r}")


# -- mesh kernel: the same per-shard pieces under shard_map --------------------


def _dispatch_mesh(
    x, router, w_gate=None, w_up=None, w_down=None, *,
    mode, nodelets, experts_per_token, capacity_factor, mesh, axis_name,
):
    from jax.sharding import PartitionSpec as P_

    from ..compat import shard_map

    P, k = nodelets, experts_per_token
    T, D = x.shape
    E = router.shape[-1]
    t = T // P
    ffn_args = () if w_gate is None else (w_gate, w_up, w_down)
    if mode == "tp":
        cap = _cap(capacity_factor, t * k / E)
        w_spec = P_()  # tp: full expert set resident on every shard

        def body(x_s, router, *ws):
            return _tp_shard(
                x_s, router, _ffn_dict(ws), k=k, num_experts=E, cap=cap
            )

    elif mode == "ep_push":
        e_local = E // P
        cap_e = _cap(capacity_factor, T * k / E)
        cap_pair = _cap(capacity_factor, t * k / P)
        w_spec = P_(axis_name)  # ep: each owner holds its E/P expert block

        def body(x_s, router, *ws):
            send, send_e, gates, ow, ps, keep = _push_pre(
                x_s, router, k=k, P=P, e_local=e_local, cap_pair=cap_pair
            )
            recv = jax.lax.all_to_all(send, axis_name, 0, 0, tiled=False)
            recv_e = jax.lax.all_to_all(send_e, axis_name, 0, 0, tiled=False)
            shard = jax.lax.axis_index(axis_name)
            out = _push_owner(
                recv, recv_e, shard, _ffn_dict(ws), e_local=e_local, cap_e=cap_e
            )
            back = jax.lax.all_to_all(out, axis_name, 0, 0, tiled=False)
            return _push_post(back, gates, ow, ps, keep, t=t, k=k)

    elif mode == "ep_pull":
        e_local = E // P
        cap_e = _cap(capacity_factor, T * k / E)
        w_spec = P_(axis_name)

        def body(x_s, router, *ws):
            gates, experts = _route_shard(x_s, router, k=k)
            ef = experts.reshape(-1)
            x_full = jax.lax.all_gather(x_s, axis_name, tiled=True)  # (T, D)
            eg = jax.lax.all_gather(ef, axis_name, tiled=True)  # (T*k,)
            shard = jax.lax.axis_index(axis_name)
            contrib = _pull_owner(
                x_full, eg, shard, _ffn_dict(ws), k=k, e_local=e_local, cap_e=cap_e
            )
            # return trip: each slot has exactly one nonzero contributor, so
            # the float psum is exact and order-free
            vals_all = jax.lax.psum(contrib, axis_name)
            vals = jax.lax.dynamic_slice(
                vals_all, (shard * t * k, jnp.int32(0)), (t * k, D)
            )
            return _pull_combine(vals, gates, x_s, t=t, k=k)

    else:
        raise ValueError(f"unknown dispatch mode {mode!r}")

    f = shard_map(
        body, mesh,
        in_specs=(P_(axis_name), P_()) + (w_spec,) * len(ffn_args),
        out_specs=P_(axis_name),
    )
    return f(x, router, *ffn_args)


# -- kernels: the registry's proof (no Substrate subclass edited) --------------


@kernel("moe_dispatch", "local")
def _moe_dispatch_local(
    sub: Substrate, x, router, *ws, strategy, nodelets, experts_per_token,
    capacity_factor,
):
    mode = dispatch_from_strategy(
        strategy, num_experts=int(router.shape[-1]), data_axis=nodelets
    )
    return _dispatch_local(
        x, router, *ws, mode=mode, nodelets=nodelets,
        experts_per_token=experts_per_token, capacity_factor=capacity_factor,
    )


@kernel("moe_dispatch", "mesh")
def _moe_dispatch_mesh(
    sub, x, router, *ws, strategy, nodelets, experts_per_token, capacity_factor
):
    mode = dispatch_from_strategy(
        strategy, num_experts=int(router.shape[-1]), data_axis=nodelets
    )
    mesh = sub.mesh_for(nodelets)
    # an explicit substrate mesh of a different width would silently shard
    # T/nodelets-sized capacity buffers over the wrong token stripes
    axis_size = dict(mesh.shape).get(sub.axis_name)
    if axis_size != nodelets:
        raise OpNotSupportedError(
            f"moe_dispatch needs a {nodelets}-way {sub.axis_name!r} mesh axis "
            f"(inputs.nodelets), got {axis_size}"
        )
    return _dispatch_mesh(
        x, router, *ws, mode=mode, nodelets=nodelets,
        experts_per_token=experts_per_token, capacity_factor=capacity_factor,
        mesh=mesh, axis_name=sub.axis_name,
    )


def moe_dispatch_reference(
    inputs: MoEDispatchInputs, strategy: MigratoryStrategy | None = None
) -> jax.Array:
    """Direct path, no engine: derive the mode with
    :func:`dispatch_from_strategy` and run the local dispatch — the oracle
    the service's ``moe_dispatch`` responses must be bit-identical to."""
    strategy = strategy if strategy is not None else MigratoryStrategy()
    return _dispatch_local(
        inputs.x, inputs.router, *inputs.ffn_args,
        mode=derive_mode(inputs, strategy),
        nodelets=inputs.nodelets, experts_per_token=inputs.experts_per_token,
        capacity_factor=inputs.capacity_factor,
    )


# -- traffic replay + roofline cost model --------------------------------------


_REPLAY_MEMO: "dict[int, tuple[Any, dict[str, Any]]]" = {}
_REPLAY_MEMO_MAX = 64


def _routing_replay_cached(inputs: MoEDispatchInputs) -> dict[str, Any]:
    """Cross-plan replay memo: the service rebuilds a plan per request, so
    ``plan.meta`` caching alone would rerun the O(T*k) host replay for every
    served request of the same inputs. Keyed by object identity, validated
    with a weakref so a recycled id of a collected object can never alias."""
    key = id(inputs)
    hit = _REPLAY_MEMO.get(key)
    if hit is not None and hit[0]() is inputs:
        return hit[1]
    replay = _routing_replay(inputs)
    if len(_REPLAY_MEMO) >= _REPLAY_MEMO_MAX:
        _REPLAY_MEMO.clear()
    try:
        _REPLAY_MEMO[key] = (weakref.ref(inputs), replay)
    except TypeError:
        pass  # unweakrefable inputs: still correct, just uncached
    return replay


def _routing_replay(inputs: MoEDispatchInputs) -> dict[str, Any]:
    """Host-side routing replay (strategy-independent): runs the same jax
    routing once and derives the per-mode capacity/keep statistics the
    traffic model, cost model, and metrics all share."""
    P, k = inputs.nodelets, inputs.experts_per_token
    T, D = inputs.x.shape
    E = inputs.num_experts
    t = T // P
    xs = inputs.x.reshape(P, t, D)
    _, experts = jax.vmap(
        functools.partial(_route_shard, k=k), in_axes=(0, None)
    )(xs, inputs.router)
    ef = np.asarray(experts).reshape(P, t * k)  # slot stream per source shard
    out: dict[str, Any] = {"routed_slots": T * k}
    if P > 1 and E % P == 0:
        e_local = E // P
        owner = ef // e_local
        cap_pair = _cap(inputs.capacity_factor, t * k / P)
        cap_e = _cap(inputs.capacity_factor, T * k / E)
        src = np.repeat(np.arange(P)[:, None], t * k, axis=1)
        # pair-stage keep: rank of each slot within its (src, owner) bin
        pair_rank = np.zeros_like(owner)
        for s in range(P):
            for o in range(P):
                m = owner[s] == o
                pair_rank[s, m] = np.arange(int(m.sum()))
        pair_keep = pair_rank < cap_pair
        out["push_offshard_kept"] = int((pair_keep & (owner != src)).sum())
        out["push_pair_dropped"] = int((~pair_keep).sum())
        # expert-stage keep at each owner, in the deterministic recv order
        # (src-major per owner, matching the all_to_all concat layout)
        expert_kept = 0
        for o in range(P):
            seen: dict[int, int] = {}
            for s in range(P):
                sel = np.flatnonzero(pair_keep[s] & (owner[s] == o))
                for e in ef[s][sel]:
                    r = seen.get(int(e), 0)
                    seen[int(e)] = r + 1
                    expert_kept += int(r < cap_e)
        out["push_kept"] = expert_kept
        # pull mode: every owner ranks the full global slot stream
        pull_kept = 0
        eg = ef.reshape(-1)
        counts: dict[int, int] = {}
        for e in eg:
            r = counts.get(int(e), 0)
            counts[int(e)] = r + 1
            pull_kept += int(r < cap_e)
        out["pull_kept"] = pull_kept
    cap_tp = _cap(inputs.capacity_factor, t * k / E)
    tp_kept = 0
    for s in range(P):
        counts = {}
        for e in ef[s]:
            r = counts.get(int(e), 0)
            counts[int(e)] = r + 1
            tp_kept += int(r < cap_tp)
    out["tp_kept"] = tp_kept
    return out


def moe_dispatch_traffic(
    inputs: MoEDispatchInputs, strategy: MigratoryStrategy, replay: dict[str, Any]
) -> TrafficStats:
    """The paper-lens traffic of one dispatch under ``strategy`` — exactly
    what the cost model ranks, so sweeps and rankings cross-check.

    - ``ep_push`` (S2 remote write): each off-shard kept slot is one
      remote-write packet; wire payload = token there + id + result back.
    - ``ep_pull`` (S2 migrate): every token's context is pulled by each of
      the P-1 remote owners (the all_gather), ids ride along, and every
      routed slot's result crosses back (the psum return trip).
    - ``tp`` (S1 replication): dispatch is node-local — zero traffic, the
      cost is paid in replicated expert residency instead.
    """
    P, k = inputs.nodelets, inputs.experts_per_token
    T, D = inputs.x.shape
    itemsize = jnp.dtype(inputs.x.dtype).itemsize
    mode = derive_mode(inputs, strategy)
    if mode == "tp":
        return TrafficStats(0, 0, 0)
    if mode == "ep_push":
        remote = replay["push_offshard_kept"]
        return TrafficStats(
            migrations=0,
            remote_writes=remote,
            collective_bytes=remote * (2 * D * itemsize + 4),
        )
    gather = T * (P - 1) * D * itemsize + T * k * (P - 1) * 4
    ret = T * k * (P - 1) * D * itemsize
    return TrafficStats(
        migrations=T * (P - 1), remote_writes=0, collective_bytes=gather + ret
    )


def _kept_for(replay: dict[str, Any]) -> dict[str, int]:
    """Kept (non-dropped) routed slots per dispatch mode, from one replay —
    the single source both the cost model and op metrics read."""
    return {
        "tp": replay["tp_kept"],
        "ep_push": replay.get("push_kept", 0),
        "ep_pull": replay.get("pull_kept", 0),
    }


def moe_dispatch_cost_model(inputs: MoEDispatchInputs):
    """Autotuner factory: one routing replay, then a cheap per-strategy
    estimator in report-identical traffic units. Balance penalty = dropped
    slot fraction (the §5.1 hotspot/overflow lens)."""
    replay = _routing_replay_cached(inputs)
    routed = replay["routed_slots"]
    kept_for = _kept_for(replay)
    T, D = inputs.x.shape
    itemsize = jnp.dtype(inputs.x.dtype).itemsize
    # per-stage working set of the emulation: the (T*k, D) slot stream is
    # materialized ~4x per stage chain (repeat, capacity-buffer scatter,
    # gather-back, gated combine) plus the routing logits; row-contiguous
    # scatters move whole D-vectors, so this is stream-class, not the
    # element-wise scatter path
    stage_bytes = (
        4 * T * inputs.experts_per_token * D * itemsize
        + T * inputs.num_experts * 4
    )

    def estimate(st: MigratoryStrategy) -> CostEstimate:
        traffic = moe_dispatch_traffic(inputs, st, replay)
        mode = derive_mode(inputs, st)
        dropped = routed - kept_for[mode]
        # collective dispatches per mode: push = scatter + compute + return
        # (3), pull = all-gather + return (2), tp = none (pure local compute)
        launches = {"tp": 0, "ep_push": 3, "ep_pull": 2}[mode]
        return CostEstimate(
            strategy=st,
            traffic_bytes=traffic.total_bytes,
            balance_penalty=dropped / max(routed, 1),
            detail={
                "dispatch_mode": mode,
                "migrations": traffic.migrations,
                "dropped_slots": dropped,
                "collective_launches": launches,
                "memory_bytes_per_launch": stage_bytes,
                "memory_access": "stream",
            },
            traffic=traffic,
        )

    return estimate


def moe_dispatch_grid() -> list[MigratoryStrategy]:
    """MoE dispatch reads only the S2 axis (comm -> push/pull); the grid
    pins the inert axes so the autotuner ranks 2 candidates, not 16."""
    return strategy_grid(
        replicates=(True,), layouts=(Layout.HCB,), schemes=(Scheme.PAIR,)
    )


# -- the op --------------------------------------------------------------------


class MoEDispatchOp:
    """MigratoryOp adapter: plan/traffic/bytes_moved/metrics for dispatch."""

    name = "moe_dispatch"

    def plan(
        self, inputs: MoEDispatchInputs, strategy: MigratoryStrategy,
        substrate: Substrate,
    ) -> ExecutionPlan:
        T = int(inputs.x.shape[0])
        if T % inputs.nodelets != 0:
            raise ValueError(
                f"moe_dispatch needs T % nodelets == 0, got T={T}, "
                f"nodelets={inputs.nodelets}"
            )
        inputs.validate_experts()
        kern = substrate.kernel(self.name)
        # expert weights are traced args: plan_key covers their shapes and
        # the executor threads them straight into the kernel
        args = (inputs.x, inputs.router) + inputs.ffn_args
        statics = (
            inputs.nodelets, inputs.experts_per_token, inputs.capacity_factor,
        )
        nodelets, k, cf = statics
        return ExecutionPlan(
            op=self.name,
            strategy=strategy,
            substrate=substrate.name,
            inputs=inputs,
            executor=lambda x, r, *ws: kern(
                x, r, *ws, strategy=strategy, nodelets=nodelets,
                experts_per_token=k, capacity_factor=cf,
            ),
            args=args,
            meta={"mode": derive_mode(inputs, strategy)},
            key=plan_key(self.name, substrate, strategy, args, static=statics),
        )

    def _replay(self, plan: ExecutionPlan) -> dict[str, Any]:
        if "replay" not in plan.meta:
            plan.meta["replay"] = _routing_replay_cached(plan.inputs)
        return plan.meta["replay"]

    def traffic(self, plan: ExecutionPlan) -> TrafficStats:
        return moe_dispatch_traffic(plan.inputs, plan.strategy, self._replay(plan))

    def bytes_moved(self, plan: ExecutionPlan) -> int:
        """Useful bytes of one dispatch: tokens read + combined output
        written + router weights read + expert weights read (when present)."""
        i = plan.inputs
        T, D = i.x.shape
        itemsize = jnp.dtype(i.x.dtype).itemsize
        total = 2 * T * D * itemsize + i.router.size * jnp.dtype(i.router.dtype).itemsize
        for w in i.ffn_args:
            total += w.size * jnp.dtype(w.dtype).itemsize
        return total

    def metrics(self, plan: ExecutionPlan, result: Any, seconds: float) -> dict[str, Any]:
        i = plan.inputs
        replay = self._replay(plan)
        mode = plan.meta["mode"]
        kept = _kept_for(replay)[mode]
        routed = replay["routed_slots"]
        return {
            "dispatch_mode": mode,
            "experts": i.num_experts,
            "nodelets": i.nodelets,
            "expert_ffn": i.has_experts,
            "routed_slots": routed,
            "dropped_slots": routed - kept,
            "drop_fraction": (routed - kept) / max(routed, 1),
        }


register_op(OpSpec(
    name="moe_dispatch",
    factory=MoEDispatchOp,
    inputs_type=MoEDispatchInputs,
    cost_model=moe_dispatch_cost_model,
    grid=moe_dispatch_grid,
))
