"""Engine API types: the ``MigratoryOp`` protocol, ``ExecutionPlan``, and the
unified ``RunReport`` record (DESIGN.md §1).

The paper's thesis is that one set of strategies (S1 replication, S2
migrate-vs-remote-write, S3 layout) applies uniformly to SpMV, BFS, and
graph alignment. The engine makes that uniformity structural: every
distributed op is a :class:`MigratoryOp` planned onto a
:class:`~repro.engine.substrate.Substrate`, compiled once per
shape/strategy/substrate signature (DESIGN.md §1b), and every run yields one
serializable :class:`RunReport` combining wall time, the paper's traffic
model, effective bandwidth, and compile-vs-steady-state accounting.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Protocol, runtime_checkable

import jax

from ..core.strategies import MigratoryStrategy, TrafficStats


class OpNotSupportedError(NotImplementedError):
    """Raised when a substrate cannot execute an op (e.g. BFS on pallas).

    Since the kernel registry (DESIGN.md §1e) this is *derived from registry
    absence*: ``Substrate.kernel(op_name)`` raises it when no kernel is
    registered for ``(op_name, substrate_kind)`` — at plan time, not deep in
    execution — and kernels may also raise it for runtime capability limits
    (device count, unsupported task shapes)."""


def strategy_dict(strategy: MigratoryStrategy) -> dict[str, Any]:
    """Flatten a strategy into plain-JSON form for reports."""
    return {
        "comm": strategy.comm.value,
        "replicate_x": strategy.replicate_x,
        "layout": strategy.layout.value,
        "scheme": strategy.scheme.value,
        "grain": strategy.grain,
    }


def args_signature(args: Any) -> tuple:
    """Shape/dtype (never value) signature of a plan's argument pytree.

    Two argument sets with equal signatures can share a compiled executor:
    array leaves contribute ``(shape, dtype)``, non-array leaves their repr
    (they are compile-time constants), and the treedef pins the container
    structure (including pytree aux data such as matrix shapes and bucket
    grids).
    """
    leaves, treedef = jax.tree_util.tree_flatten(args)
    sig = tuple(
        (tuple(leaf.shape), str(leaf.dtype))
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype")
        else ("pyleaf", repr(leaf))
        for leaf in leaves
    )
    return (str(treedef), sig)


def plan_key(
    op: str, substrate, strategy: MigratoryStrategy, args: Any,
    static: tuple = (),
) -> tuple:
    """The compiled-plan cache key: op name x substrate fingerprint x full
    strategy x static scalars x argument shape/dtype signature."""
    return (
        op,
        substrate.cache_fingerprint(),
        strategy.cache_key(),
        static,
        args_signature(args),
    )


@dataclasses.dataclass
class ExecutionPlan:
    """A strategy + substrate bound to concrete inputs, ready to compile.

    ``executor`` is a pure function of ``args`` (the array pytrees) — it
    closes only over compile-time statics (strategy, substrate, scalar
    parameters), all of which are pinned by ``key``, so the plan cache may
    hand the same executor to any later plan with an equal ``key``.
    ``meta`` holds static facts about the inputs (sizes, nnz, ...) plus
    anything the op caches between :meth:`MigratoryOp.traffic` and metric
    computation. ``key=None`` marks a plan as uncacheable.

    ``jit=True`` (the default) lets the compile stage wrap the executor in
    ``jax.jit`` when it enters the plan cache, so the cached artifact is one
    fused XLA executable instead of an op-by-op eager trace — ops whose
    executors do host-side work the tracer cannot see must set it False.
    """

    op: str
    strategy: MigratoryStrategy
    substrate: str
    inputs: Any
    executor: Callable[..., Any]
    args: tuple = ()
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)
    key: tuple | None = None
    jit: bool = True

    def run(self) -> Any:
        """Execute this plan's own executor on its own arguments."""
        return self.executor(*self.args)


@runtime_checkable
class MigratoryOp(Protocol):
    """A distributed operation the engine knows how to run and account for."""

    name: str

    def plan(self, inputs: Any, strategy: MigratoryStrategy, substrate) -> ExecutionPlan:
        """Bind inputs + strategy to a substrate-specific executor."""

    def traffic(self, plan: ExecutionPlan) -> TrafficStats:
        """Paper-model communication traffic for this plan."""

    def bytes_moved(self, plan: ExecutionPlan) -> int:
        """Bytes the paper's effective-bandwidth formula charges one run."""

    def metrics(self, plan: ExecutionPlan, result: Any, seconds: float) -> dict[str, Any]:
        """Op-specific derived metrics (MTEPS, recall, modeled makespan, ...)."""


@dataclasses.dataclass
class RunReport:
    """One run, one record: unifies wall time, TrafficStats, the per-op stats
    (BFS rounds / GSANA plan model), effective bandwidth, and the plan
    cache's compile accounting (``cache_hit``, ``compile_seconds``).

    ``predicted_seconds``/``model_error`` are the calibration plane's
    honesty columns (DESIGN.md §1f): the performance model's wall-seconds
    prediction for this plan and its ratio to the measurement
    (predicted / measured, 1.0 = perfect). Both stay None — and absent from
    ``to_dict`` — unless a calibrated machine file was present."""

    op: str
    strategy: dict[str, Any]
    substrate: str
    seconds: float
    traffic: TrafficStats
    bytes_moved: int
    effective_gbps: float
    cache_hit: bool = False
    compile_seconds: float = 0.0
    metrics: dict[str, Any] = dataclasses.field(default_factory=dict)
    predicted_seconds: "float | None" = None
    model_error: "float | None" = None

    def to_dict(self) -> dict[str, Any]:
        """Flat, JSON-ready form — the unified benchmark row schema.

        Op metrics may not shadow schema columns (an op metric named e.g.
        ``seconds`` would silently corrupt benchmark trajectories).
        """
        row = {
            "op": self.op,
            **{f"strategy_{k}": v for k, v in self.strategy.items()},
            "substrate": self.substrate,
            "seconds": self.seconds,
            "us_per_call": self.seconds * 1e6,
            "cache_hit": self.cache_hit,
            "compile_seconds": self.compile_seconds,
            "migrations": self.traffic.migrations,
            "remote_writes": self.traffic.remote_writes,
            "collective_bytes": self.traffic.collective_bytes,
            "traffic_bytes": self.traffic.total_bytes,
            "bytes_moved": self.bytes_moved,
            "effective_gbps": self.effective_gbps,
        }
        if self.predicted_seconds is not None:
            row["predicted_seconds"] = self.predicted_seconds
        if self.model_error is not None:
            row["model_error"] = self.model_error
        clash = sorted(set(row) & set(self.metrics))
        if clash:
            raise ValueError(
                f"op metrics {clash} collide with RunReport schema columns; "
                "rename the op metric"
            )
        row.update(self.metrics)
        return row

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), default=str)

    @classmethod
    def from_parts(
        cls,
        op: str,
        strategy: MigratoryStrategy,
        substrate: str,
        seconds: float,
        traffic: TrafficStats,
        bytes_moved: int,
        metrics: dict[str, Any] | None = None,
        cache_hit: bool = False,
        compile_seconds: float = 0.0,
        predicted_seconds: "float | None" = None,
    ) -> "RunReport":
        return cls(
            op=op,
            strategy=strategy_dict(strategy),
            substrate=substrate,
            seconds=seconds,
            traffic=traffic,
            bytes_moved=bytes_moved,
            effective_gbps=bytes_moved / max(seconds, 1e-12) / 1e9,
            cache_hit=cache_hit,
            compile_seconds=compile_seconds,
            metrics=metrics or {},
            predicted_seconds=predicted_seconds,
            model_error=(
                None if predicted_seconds is None
                else predicted_seconds / max(seconds, 1e-12)
            ),
        )
