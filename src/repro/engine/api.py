"""Engine API types: the ``MigratoryOp`` protocol, ``ExecutionPlan``, and the
unified ``RunReport`` record (DESIGN.md §1).

The paper's thesis is that one set of strategies (S1 replication, S2
migrate-vs-remote-write, S3 layout) applies uniformly to SpMV, BFS, and
graph alignment. The engine makes that uniformity structural: every
distributed op is a :class:`MigratoryOp` planned onto a
:class:`~repro.engine.substrate.Substrate`, and every run yields one
serializable :class:`RunReport` combining wall time, the paper's traffic
model, and effective bandwidth.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Protocol, runtime_checkable

from ..core.strategies import MigratoryStrategy, TrafficStats


class OpNotSupportedError(NotImplementedError):
    """Raised when a substrate cannot execute an op (e.g. BFS on pallas)."""


def strategy_dict(strategy: MigratoryStrategy) -> dict[str, Any]:
    """Flatten a strategy into plain-JSON form for reports."""
    return {
        "comm": strategy.comm.value,
        "replicate_x": strategy.replicate_x,
        "layout": strategy.layout.value,
        "scheme": strategy.scheme.value,
        "grain": strategy.grain,
    }


@dataclasses.dataclass
class ExecutionPlan:
    """A strategy + substrate bound to concrete inputs, ready to execute.

    ``run`` is a zero-arg executor returning the op's result; ``meta`` holds
    static facts about the inputs (sizes, nnz, ...) plus anything the op
    caches between :meth:`MigratoryOp.traffic` and metric computation.
    """

    op: str
    strategy: MigratoryStrategy
    substrate: str
    inputs: Any
    run: Callable[[], Any]
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)


@runtime_checkable
class MigratoryOp(Protocol):
    """A distributed operation the engine knows how to run and account for."""

    name: str

    def plan(self, inputs: Any, strategy: MigratoryStrategy, substrate) -> ExecutionPlan:
        """Bind inputs + strategy to a substrate-specific executor."""

    def traffic(self, plan: ExecutionPlan) -> TrafficStats:
        """Paper-model communication traffic for this plan."""

    def bytes_moved(self, plan: ExecutionPlan) -> int:
        """Bytes the paper's effective-bandwidth formula charges one run."""

    def metrics(self, plan: ExecutionPlan, result: Any, seconds: float) -> dict[str, Any]:
        """Op-specific derived metrics (MTEPS, recall, modeled makespan, ...)."""


@dataclasses.dataclass
class RunReport:
    """One run, one record: unifies wall time, TrafficStats, the per-op stats
    (BFS rounds / GSANA plan model), and effective bandwidth."""

    op: str
    strategy: dict[str, Any]
    substrate: str
    seconds: float
    traffic: TrafficStats
    bytes_moved: int
    effective_gbps: float
    metrics: dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict[str, Any]:
        """Flat, JSON-ready form — the unified benchmark row schema."""
        return {
            "op": self.op,
            **{f"strategy_{k}": v for k, v in self.strategy.items()},
            "substrate": self.substrate,
            "seconds": self.seconds,
            "us_per_call": self.seconds * 1e6,
            "migrations": self.traffic.migrations,
            "remote_writes": self.traffic.remote_writes,
            "collective_bytes": self.traffic.collective_bytes,
            "traffic_bytes": self.traffic.total_bytes,
            "bytes_moved": self.bytes_moved,
            "effective_gbps": self.effective_gbps,
            **self.metrics,
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), default=str)

    @classmethod
    def from_parts(
        cls,
        op: str,
        strategy: MigratoryStrategy,
        substrate: str,
        seconds: float,
        traffic: TrafficStats,
        bytes_moved: int,
        metrics: dict[str, Any] | None = None,
    ) -> "RunReport":
        return cls(
            op=op,
            strategy=strategy_dict(strategy),
            substrate=substrate,
            seconds=seconds,
            traffic=traffic,
            bytes_moved=bytes_moved,
            effective_gbps=bytes_moved / max(seconds, 1e-12) / 1e9,
            metrics=metrics or {},
        )
