"""Substrates: where a MigratoryOp's plan executes (DESIGN.md §1, §1e).

Three built-in backends, mirroring the realizations the paper compares:

- ``local``  — single-device vmap emulation with the distributed semantics
  (the correctness oracle; what the Emu sees as one node).
- ``mesh``   — ``shard_map`` over a 1-D nodelet axis (the Chick's nodelets
  as TPU shards): replication, all_gather pulls, all_to_all pushes.
- ``pallas`` — routes the compute hot loops to the Pallas kernels
  (``kernels/spmv``, ``kernels/topk_sim``) where shapes allow.

A substrate no longer implements one method per op. Its per-op entry points
are *kernels* registered against its ``substrate_kind`` in the
:mod:`~repro.engine.registry` (``@kernel("spmv", "mesh")`` below);
``Substrate.kernel(op_name)`` resolves them, and a missing registration
raises :class:`~repro.engine.api.OpNotSupportedError`. New backends
register with :func:`register_substrate` and gain every op whose kernels
they register; new ops (e.g. ``moe_dispatch``, engine/moe_op.py) register
kernels against existing kinds without touching the classes here. The old
``substrate.spmv(...)``-style methods survive as legacy shims delegating to
the registry so pre-registry call sites migrate incrementally.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax

from ..core.bfs import bfs_local, bfs_mesh
from ..core.gsana import NEG, compute_similarity, compute_similarity_mesh
from ..core.spmv import spmv_local, spmv_mesh, unstripe_vector
from ..core.strategies import MigratoryStrategy, Scheme
from .api import OpNotSupportedError
from .registry import default_registry, kernel


class Substrate:
    """Execution backend for MigratoryOps.

    Identity: ``name`` labels the instance in reports/registries;
    ``substrate_kind`` (defaults to ``name``) is the registry key kernels
    are looked up under — a subclass specializing behavior but reusing a
    parent's kernels may pin ``kind`` to the parent's.
    """

    name: str = "abstract"
    kind: "str | None" = None

    @property
    def substrate_kind(self) -> str:
        """The registry key kernels are looked up under. Explicit ``kind``
        wins; otherwise the MRO is walked for the nearest class whose own
        ``name`` has kernels registered — so a renamed subclass
        (``class FastLocal(LocalSubstrate): name = "fast_local"``) keeps
        inheriting its parent's kernels, matching the pre-registry
        subclassing contract."""
        if self.kind is not None:
            return self.kind
        kinds = set(default_registry().kernel_kinds())
        for klass in type(self).__mro__:
            own_name = klass.__dict__.get("name")
            if own_name and own_name in kinds:
                return own_name
        return self.name

    def kernel(self, op_name: str) -> Callable:
        """Resolve this backend's kernel for ``op_name`` (bound to self).
        Raises :class:`OpNotSupportedError` when no kernel is registered —
        capability *is* registry presence."""
        fn = default_registry().resolve_kernel(op_name, self.substrate_kind)
        return functools.partial(fn, self)

    def supports(self, op_name: str) -> bool:
        return default_registry().has_kernel(op_name, self.substrate_kind)

    def cache_fingerprint(self) -> tuple:
        """Hashable identity for the compiled-plan cache: two substrate
        instances with equal fingerprints are interchangeable executors."""
        return (self.name,)

    # -- legacy shims (pre-registry API; delegate to the kernel table) ---------

    def spmv(self, a, x, strategy: MigratoryStrategy) -> jax.Array:
        return self.kernel("spmv")(a, x, strategy=strategy)

    def bfs(self, g, root, strategy: MigratoryStrategy, max_rounds=None) -> jax.Array:
        return self.kernel("bfs")(g, root, strategy=strategy, max_rounds=max_rounds)

    def gsana(self, vs1, vs2, b1, b2, k: int, strategy: MigratoryStrategy):
        return self.kernel("gsana")(vs1, vs2, b1, b2, k, strategy=strategy)


class LocalSubstrate(Substrate):
    """Single-device emulation — identical semantics to the mesh paths."""

    name = "local"


class MeshSubstrate(Substrate):
    """``shard_map`` over a nodelet axis. With no explicit mesh, builds a
    1-D nodelet mesh matching the input's partition count (requires that
    many jax devices)."""

    name = "mesh"

    def __init__(self, mesh: jax.sharding.Mesh | None = None, axis_name: str = "nodelet"):
        self.mesh = mesh
        self.axis_name = axis_name

    def cache_fingerprint(self) -> tuple:
        mesh_id = None
        if self.mesh is not None:
            mesh_id = (
                tuple(self.mesh.shape.items()),
                tuple(str(d) for d in self.mesh.devices.flat),
            )
        return (self.name, self.axis_name, mesh_id)

    def mesh_for(self, p: int) -> jax.sharding.Mesh:
        """The mesh kernels run on: the explicit one, else a 1-D nodelet
        mesh of ``p`` host devices. Public so out-of-tree kernels (e.g.
        engine/moe_op.py) resolve meshes the same way the built-ins do."""
        if self.mesh is not None:
            return self.mesh
        from ..launch.mesh import make_nodelet_mesh

        if len(jax.devices()) < p:
            raise OpNotSupportedError(
                f"mesh substrate needs {p} devices for {p} nodelets, "
                f"have {len(jax.devices())} (pass an explicit mesh or use 'local')"
            )
        return make_nodelet_mesh(p)

    # pre-registry spelling, kept for out-of-tree callers
    _mesh_for = mesh_for


class PallasSubstrate(Substrate):
    """Routes hot loops to the Pallas kernels. ``interpret=True`` runs the
    kernels in interpret mode (CPU-correct); on TPU pass ``interpret=False``.
    BFS has no kernel (its hot loop is the collective pattern itself) — the
    registry simply has no ``("bfs", "pallas")`` entry."""

    name = "pallas"

    def __init__(self, interpret: bool = True):
        self.interpret = interpret

    def cache_fingerprint(self) -> tuple:
        return (self.name, self.interpret)


# -- built-in kernels ----------------------------------------------------------
# The algorithm code lives in repro.core.*; these adapters bind it to a
# backend. Registered here (not on the classes) so capability is data.


@kernel("spmv", "local")
def _spmv_local(sub: Substrate, a, x, *, strategy):
    return spmv_local(a, x, strategy)


@kernel("bfs", "local")
def _bfs_local(sub: Substrate, g, root, *, strategy, max_rounds=None):
    return bfs_local(g, root, strategy, max_rounds)


@kernel("gsana", "local")
def _gsana_local(sub: Substrate, vs1, vs2, b1, b2, k, *, strategy):
    return compute_similarity(vs1, vs2, b1, b2, k, strategy.scheme)


@kernel("spmv", "mesh")
def _spmv_mesh(sub: MeshSubstrate, a, x, *, strategy):
    return spmv_mesh(a, x, strategy, sub.mesh_for(a.P), sub.axis_name)


@kernel("bfs", "mesh")
def _bfs_mesh(sub: MeshSubstrate, g, root, *, strategy, max_rounds=None):
    return bfs_mesh(
        g, root, strategy, max_rounds, mesh=sub.mesh_for(g.P), axis_name=sub.axis_name,
    )


@kernel("gsana", "mesh")
def _gsana_mesh(sub: MeshSubstrate, vs1, vs2, b1, b2, k, *, strategy):
    # task distribution over however many devices the host mesh offers
    mesh = sub.mesh
    if mesh is None:
        from ..launch.mesh import make_nodelet_mesh

        n_dev = len(jax.devices())
        if n_dev < 2:
            raise OpNotSupportedError(
                "mesh substrate needs >1 device to distribute gsana tasks "
                "(pass an explicit mesh or use 'local')"
            )
        mesh = make_nodelet_mesh(n_dev)
    return compute_similarity_mesh(
        vs1, vs2, b1, b2, k, strategy.scheme, mesh=mesh, axis_name=sub.axis_name,
    )


@kernel("spmv", "pallas")
def _spmv_pallas(sub: PallasSubstrate, a, x, *, strategy):
    from ..kernels.spmv.ops import spmv as spmv_kernel

    x_full = x if strategy.replicate_x else unstripe_vector(x, a.shape[1])
    p, rp, k = a.cols.shape
    grain = strategy.dynamic_grain(rp)
    # nodelet planes -> one (P*R_p, K) row block; kernel grid = row chunks
    y = spmv_kernel(
        a.cols.reshape(p * rp, k), a.vals.reshape(p * rp, k), x_full,
        grain=max(1, min(grain, p * rp)), interpret=sub.interpret,
    )
    return y.reshape(p, rp)


@kernel("gsana", "pallas")
def _gsana_pallas(sub: PallasSubstrate, vs1, vs2, b1, b2, k, *, strategy):
    import jax.numpy as jnp
    import numpy as np

    from ..core.gsana import DEFAULT_VOCAB, _merge_pair_topk, _scatter_vertex_major  # noqa: PLC0415
    from ..core.gsana_data import neighbor_buckets
    from ..kernels.topk_sim.ops import topk_sim_pairs

    if strategy.scheme != Scheme.PAIR:
        raise OpNotSupportedError(
            "pallas gsana kernel implements the PAIR task shape only"
        )
    grid2 = b2.grid * b2.grid
    nb = neighbor_buckets(b2.grid)
    pair_b2 = jnp.asarray(np.repeat(np.arange(grid2), 9))
    pair_b1 = jnp.asarray(nb.reshape(-1))
    scores, u_ids = topk_sim_pairs(
        vs1, vs2, b1, b2, pair_b2, pair_b1,
        vocab=DEFAULT_VOCAB, k=min(k, b1.cap), interpret=sub.interpret,
    )
    scores = jnp.where(jnp.isfinite(scores), scores, NEG)
    cand_b, score_b = _merge_pair_topk(u_ids, scores, grid2, k)
    return _scatter_vertex_major(cand_b, score_b, b2, vs2.n, k)


# -- registry ------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], Substrate]] = {}


def register_substrate(name: str, factory: Callable[[], Substrate]) -> None:
    _REGISTRY[name] = factory


def list_substrates() -> list[str]:
    return sorted(_REGISTRY)


def get_substrate(substrate: "Substrate | str") -> Substrate:
    """Resolve a substrate instance from a name or pass an instance through."""
    if isinstance(substrate, Substrate):
        return substrate
    try:
        return _REGISTRY[substrate]()
    except KeyError:
        raise ValueError(
            f"unknown substrate {substrate!r}; registered: {list_substrates()}"
        ) from None


def substrate_for_mesh(
    mesh: jax.sharding.Mesh | None, axis_name: str = "nodelet"
) -> Substrate:
    """Legacy-shim resolution: a mesh means the mesh substrate, no mesh means
    local. The one place the old ``mesh=None`` convention is interpreted."""
    if mesh is None:
        return LocalSubstrate()
    return MeshSubstrate(mesh, axis_name)


register_substrate("local", LocalSubstrate)
register_substrate("mesh", MeshSubstrate)
register_substrate("pallas", PallasSubstrate)
