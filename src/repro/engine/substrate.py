"""Substrates: where a MigratoryOp's plan executes (DESIGN.md §1).

Three built-in backends, mirroring the realizations the paper compares:

- ``local``  — single-device vmap emulation with the distributed semantics
  (the correctness oracle; what the Emu sees as one node).
- ``mesh``   — ``shard_map`` over a 1-D nodelet axis (the Chick's nodelets
  as TPU shards): replication, all_gather pulls, all_to_all pushes.
- ``pallas`` — routes the compute hot loops to the Pallas kernels
  (``kernels/spmv``, ``kernels/topk_sim``) where shapes allow.

New backends (multi-host, CPU collectives, ...) register with
:func:`register_substrate` and immediately serve every op.
"""
from __future__ import annotations

from typing import Callable

import jax

from ..core.bfs import bfs_local, bfs_mesh
from ..core.gsana import NEG, compute_similarity, compute_similarity_mesh
from ..core.spmv import spmv_local, spmv_mesh, unstripe_vector
from ..core.strategies import MigratoryStrategy, Scheme
from .api import OpNotSupportedError


class Substrate:
    """Execution backend for MigratoryOps. Subclasses implement the ops they
    support; unimplemented ops raise :class:`OpNotSupportedError`."""

    name: str = "abstract"

    def supports(self, op_name: str) -> bool:
        return getattr(type(self), op_name, None) is not getattr(Substrate, op_name)

    def cache_fingerprint(self) -> tuple:
        """Hashable identity for the compiled-plan cache: two substrate
        instances with equal fingerprints are interchangeable executors."""
        return (self.name,)

    # -- op entry points (algorithm code lives in repro.core.*) ---------------

    def spmv(self, a, x, strategy: MigratoryStrategy) -> jax.Array:
        raise OpNotSupportedError(f"substrate {self.name!r} does not run spmv")

    def bfs(self, g, root, strategy: MigratoryStrategy, max_rounds=None) -> jax.Array:
        raise OpNotSupportedError(f"substrate {self.name!r} does not run bfs")

    def gsana(self, vs1, vs2, b1, b2, k: int, strategy: MigratoryStrategy):
        raise OpNotSupportedError(f"substrate {self.name!r} does not run gsana")


class LocalSubstrate(Substrate):
    """Single-device emulation — identical semantics to the mesh paths."""

    name = "local"

    def spmv(self, a, x, strategy):
        return spmv_local(a, x, strategy)

    def bfs(self, g, root, strategy, max_rounds=None):
        return bfs_local(g, root, strategy, max_rounds)

    def gsana(self, vs1, vs2, b1, b2, k, strategy):
        return compute_similarity(vs1, vs2, b1, b2, k, strategy.scheme)


class MeshSubstrate(Substrate):
    """``shard_map`` over a nodelet axis. With no explicit mesh, builds a
    1-D nodelet mesh matching the input's partition count (requires that
    many jax devices)."""

    name = "mesh"

    def __init__(self, mesh: jax.sharding.Mesh | None = None, axis_name: str = "nodelet"):
        self.mesh = mesh
        self.axis_name = axis_name

    def cache_fingerprint(self) -> tuple:
        mesh_id = None
        if self.mesh is not None:
            mesh_id = (
                tuple(self.mesh.shape.items()),
                tuple(str(d) for d in self.mesh.devices.flat),
            )
        return (self.name, self.axis_name, mesh_id)

    def _mesh_for(self, p: int) -> jax.sharding.Mesh:
        if self.mesh is not None:
            return self.mesh
        from ..launch.mesh import make_nodelet_mesh

        if len(jax.devices()) < p:
            raise OpNotSupportedError(
                f"mesh substrate needs {p} devices for {p} nodelets, "
                f"have {len(jax.devices())} (pass an explicit mesh or use 'local')"
            )
        return make_nodelet_mesh(p)

    def spmv(self, a, x, strategy):
        return spmv_mesh(a, x, strategy, self._mesh_for(a.P), self.axis_name)

    def bfs(self, g, root, strategy, max_rounds=None):
        return bfs_mesh(
            g, root, strategy, max_rounds,
            mesh=self._mesh_for(g.P), axis_name=self.axis_name,
        )

    def gsana(self, vs1, vs2, b1, b2, k, strategy):
        # task distribution over however many devices the host mesh offers
        mesh = self.mesh
        if mesh is None:
            from ..launch.mesh import make_nodelet_mesh

            n_dev = len(jax.devices())
            if n_dev < 2:
                raise OpNotSupportedError(
                    "mesh substrate needs >1 device to distribute gsana tasks "
                    "(pass an explicit mesh or use 'local')"
                )
            mesh = make_nodelet_mesh(n_dev)
        return compute_similarity_mesh(
            vs1, vs2, b1, b2, k, strategy.scheme, mesh=mesh, axis_name=self.axis_name,
        )


class PallasSubstrate(Substrate):
    """Routes hot loops to the Pallas kernels. ``interpret=True`` runs the
    kernels in interpret mode (CPU-correct); on TPU pass ``interpret=False``.
    BFS has no kernel (its hot loop is the collective pattern itself)."""

    name = "pallas"

    def __init__(self, interpret: bool = True):
        self.interpret = interpret

    def cache_fingerprint(self) -> tuple:
        return (self.name, self.interpret)

    def spmv(self, a, x, strategy):
        from ..kernels.spmv.ops import spmv as spmv_kernel

        x_full = x if strategy.replicate_x else unstripe_vector(x, a.shape[1])
        p, rp, k = a.cols.shape
        grain = strategy.dynamic_grain(rp)
        # nodelet planes -> one (P*R_p, K) row block; kernel grid = row chunks
        y = spmv_kernel(
            a.cols.reshape(p * rp, k), a.vals.reshape(p * rp, k), x_full,
            grain=max(1, min(grain, p * rp)), interpret=self.interpret,
        )
        return y.reshape(p, rp)

    def gsana(self, vs1, vs2, b1, b2, k, strategy):
        import jax.numpy as jnp
        import numpy as np

        from ..core.gsana import DEFAULT_VOCAB, _merge_pair_topk, _scatter_vertex_major  # noqa: PLC0415
        from ..core.gsana_data import neighbor_buckets
        from ..kernels.topk_sim.ops import topk_sim_pairs

        if strategy.scheme != Scheme.PAIR:
            raise OpNotSupportedError(
                "pallas gsana kernel implements the PAIR task shape only"
            )
        grid2 = b2.grid * b2.grid
        nb = neighbor_buckets(b2.grid)
        pair_b2 = jnp.asarray(np.repeat(np.arange(grid2), 9))
        pair_b1 = jnp.asarray(nb.reshape(-1))
        scores, u_ids = topk_sim_pairs(
            vs1, vs2, b1, b2, pair_b2, pair_b1,
            vocab=DEFAULT_VOCAB, k=min(k, b1.cap), interpret=self.interpret,
        )
        scores = jnp.where(jnp.isfinite(scores), scores, NEG)
        cand_b, score_b = _merge_pair_topk(u_ids, scores, grid2, k)
        return _scatter_vertex_major(cand_b, score_b, b2, vs2.n, k)


# -- registry ------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], Substrate]] = {}


def register_substrate(name: str, factory: Callable[[], Substrate]) -> None:
    _REGISTRY[name] = factory


def list_substrates() -> list[str]:
    return sorted(_REGISTRY)


def get_substrate(substrate: "Substrate | str") -> Substrate:
    """Resolve a substrate instance from a name or pass an instance through."""
    if isinstance(substrate, Substrate):
        return substrate
    try:
        return _REGISTRY[substrate]()
    except KeyError:
        raise ValueError(
            f"unknown substrate {substrate!r}; registered: {list_substrates()}"
        ) from None


def substrate_for_mesh(
    mesh: jax.sharding.Mesh | None, axis_name: str = "nodelet"
) -> Substrate:
    """Legacy-shim resolution: a mesh means the mesh substrate, no mesh means
    local. The one place the old ``mesh=None`` convention is interpreted."""
    if mesh is None:
        return LocalSubstrate()
    return MeshSubstrate(mesh, axis_name)


register_substrate("local", LocalSubstrate)
register_substrate("mesh", MeshSubstrate)
register_substrate("pallas", PallasSubstrate)
