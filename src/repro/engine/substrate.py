"""Substrates: where a MigratoryOp's plan executes (DESIGN.md §1, §1e).

Three built-in backends, mirroring the realizations the paper compares:

- ``local``  — single-device vmap emulation with the distributed semantics
  (the correctness oracle; what the Emu sees as one node).
- ``mesh``   — ``shard_map`` over a 1-D nodelet axis (the Chick's nodelets
  as TPU shards): replication, all_gather pulls, all_to_all pushes.
- ``pallas`` — routes the compute hot loops to the Pallas kernels
  (``kernels/spmv``, ``kernels/bfs``, ``kernels/topk_sim``) where shapes
  allow.

A substrate no longer implements one method per op. Its per-op entry points
are *kernels* registered against its ``substrate_kind`` in the
:mod:`~repro.engine.registry` (``@kernel("spmv", "mesh")`` below);
``Substrate.kernel(op_name)`` resolves them, and a missing registration
raises :class:`~repro.engine.api.OpNotSupportedError`. New backends
register with :func:`register_substrate` and gain every op whose kernels
they register; new ops (e.g. ``moe_dispatch``, engine/moe_op.py) register
kernels against existing kinds without touching the classes here. The old
``substrate.spmv(...)``-style per-op methods are gone (removed with the
:class:`~repro.engine.request.Request` redesign, DESIGN.md §1g) — resolve
kernels with ``substrate.kernel(op_name)``.
"""
from __future__ import annotations

import functools
import os
from typing import Callable

import jax

from ..core.bfs import bfs_local, bfs_mesh
from ..core.gsana import NEG, compute_similarity, compute_similarity_mesh
from ..core.spmv import spmv_local, spmv_mesh, unstripe_vector
from ..core.strategies import MigratoryStrategy, Scheme
from .api import OpNotSupportedError
from .registry import default_registry, kernel


class Substrate:
    """Execution backend for MigratoryOps.

    Identity: ``name`` labels the instance in reports/registries;
    ``substrate_kind`` (defaults to ``name``) is the registry key kernels
    are looked up under — a subclass specializing behavior but reusing a
    parent's kernels may pin ``kind`` to the parent's.

    Placement (the EngineService executor pool, DESIGN.md §1d): a substrate
    advertises how many *independent execution channels* it can drive
    (:meth:`placement_slots` — the Emu analogue is nodelets, the
    memory-channels study's is channels), a :attr:`placement_policy` for
    routing plan-key groups onto pool workers, and an optional per-slot
    *variant* (:meth:`placement_variant`) — a substrate instance whose
    executions are disjoint from other slots' (e.g. a mesh device window),
    so independent groups placed on different slots genuinely run in
    parallel instead of contending for the same devices.
    """

    name: str = "abstract"
    kind: "str | None" = None
    #: "spread": groups round-robin over pool workers and idle workers may
    #: steal queued/straggling work. "affinity": a plan-key group is pinned
    #: to one slot (its compiled executable targets that slot's devices) and
    #: is never stolen.
    placement_policy: str = "spread"
    #: False marks every plan built against this substrate uncompilable by
    #: ``jax.jit`` — its executors do host-side work the tracer cannot see
    #: (e.g. the cluster substrate's socket round trip). The planner flips
    #: ``ExecutionPlan.jit`` off so the plan cache keeps such plans eager.
    jit_plans: bool = True

    def placement_slots(self) -> int:
        """How many pool workers this substrate can keep independently busy.
        The pool sizes itself as ``min(workers, placement_slots())`` when
        asked for ``workers="auto"``."""
        return 1

    def placement_variant(self, slot: int, n_slots: int) -> "Substrate":
        """The substrate instance slot ``slot`` of ``n_slots`` should plan
        against. Default: ``self`` (all slots share one backend). Backends
        that can carve disjoint execution channels (mesh device windows)
        return a variant whose ``cache_fingerprint`` embeds the slot, so the
        slot's compiled plans are keyed — and therefore pinned — to it."""
        del slot, n_slots
        return self

    @property
    def substrate_kind(self) -> str:
        """The registry key kernels are looked up under. Explicit ``kind``
        wins; otherwise the MRO is walked for the nearest class whose own
        ``name`` has kernels registered — so a renamed subclass
        (``class FastLocal(LocalSubstrate): name = "fast_local"``) keeps
        inheriting its parent's kernels, matching the pre-registry
        subclassing contract."""
        if self.kind is not None:
            return self.kind
        kinds = set(default_registry().kernel_kinds())
        for klass in type(self).__mro__:
            own_name = klass.__dict__.get("name")
            if own_name and own_name in kinds:
                return own_name
        return self.name

    def kernel(self, op_name: str) -> Callable:
        """Resolve this backend's kernel for ``op_name`` (bound to self).
        Raises :class:`OpNotSupportedError` when no kernel is registered —
        capability *is* registry presence."""
        fn = default_registry().resolve_kernel(op_name, self.substrate_kind)
        return functools.partial(fn, self)

    def supports(self, op_name: str) -> bool:
        return default_registry().has_kernel(op_name, self.substrate_kind)

    def cache_fingerprint(self) -> tuple:
        """Hashable identity for the compiled-plan cache: two substrate
        instances with equal fingerprints are interchangeable executors."""
        return (self.name,)


class LocalSubstrate(Substrate):
    """Single-device emulation — identical semantics to the mesh paths."""

    name = "local"

    def placement_slots(self) -> int:
        # one device, many host cores: executions from different workers
        # overlap in XLA's intra-op pool, so size to the core count
        return max(1, os.cpu_count() or 1)


class MeshSubstrate(Substrate):
    """``shard_map`` over a nodelet axis. With no explicit mesh, builds a
    1-D nodelet mesh matching the input's partition count (requires that
    many jax devices).

    ``device_window`` is the executor pool's per-slot carving: a variant
    bound to a window resolves ``mesh_for(p)`` over those devices (when
    they suffice), so plans placed on different slots execute on disjoint
    devices — the paper's independent-nodelet parallelism realized as
    device-affine workers. The window is part of the cache fingerprint:
    a slot's compiled executables are keyed to its devices.
    """

    name = "mesh"
    placement_policy = "affinity"

    def __init__(
        self,
        mesh: jax.sharding.Mesh | None = None,
        axis_name: str = "nodelet",
        device_window: "tuple | None" = None,
    ):
        self.mesh = mesh
        self.axis_name = axis_name
        self.device_window = tuple(device_window) if device_window else None

    def cache_fingerprint(self) -> tuple:
        mesh_id = None
        if self.mesh is not None:
            mesh_id = (
                tuple(self.mesh.shape.items()),
                tuple(str(d) for d in self.mesh.devices.flat),
            )
        window_id = (
            tuple(str(d) for d in self.device_window) if self.device_window else None
        )
        return (self.name, self.axis_name, mesh_id, window_id)

    def placement_slots(self) -> int:
        """Independent channels = devices: an explicit mesh is one committed
        channel set; otherwise every host device is a potential window."""
        if self.mesh is not None:
            return 1
        return max(1, len(jax.devices()))

    def placement_variant(self, slot: int, n_slots: int) -> "MeshSubstrate":
        """Slot ``slot``'s device window: the ``slot``-th of ``n_slots``
        equal contiguous device blocks. With an explicit mesh (committed
        devices) or a single slot there is nothing to carve."""
        if self.mesh is not None or n_slots <= 1:
            return self
        devices = jax.devices()
        width = len(devices) // n_slots
        if width < 1:
            return self  # fewer devices than slots: all slots share everything
        lo = (slot % n_slots) * width
        return MeshSubstrate(
            None, self.axis_name, device_window=tuple(devices[lo : lo + width])
        )

    def mesh_for(self, p: int) -> jax.sharding.Mesh:
        """The mesh kernels run on: the explicit one; else the slot's device
        window when it is wide enough; else a 1-D nodelet mesh of ``p`` host
        devices. Public so out-of-tree kernels (e.g. engine/moe_op.py)
        resolve meshes the same way the built-ins do."""
        if self.mesh is not None:
            return self.mesh
        if self.device_window is not None:
            if p <= len(self.device_window):
                from ..compat import make_mesh_over

                return make_mesh_over(self.device_window[:p], (self.axis_name,))
            # the plan spans more nodelets than this slot's window: fall
            # back to the global device mesh, audibly — such plans share
            # devices across slots (no disjoint-channel parallelism) and,
            # because the window is part of the cache fingerprint, compile
            # once per slot they land on. Partition inputs to <= n_dev //
            # workers nodelets to stay inside the windows.
            import warnings

            warnings.warn(
                f"plan needs {p} nodelets but the placement window has "
                f"{len(self.device_window)} device(s); executing on the "
                "global device mesh — pool slots will NOT be disjoint for "
                "this plan",
                stacklevel=2,
            )
        from ..launch.mesh import make_nodelet_mesh

        if len(jax.devices()) < p:
            raise OpNotSupportedError(
                f"mesh substrate needs {p} devices for {p} nodelets, "
                f"have {len(jax.devices())} (pass an explicit mesh or use 'local')"
            )
        return make_nodelet_mesh(p)

    # pre-registry spelling, kept for out-of-tree callers
    _mesh_for = mesh_for


class PallasSubstrate(Substrate):
    """Routes hot loops to the Pallas kernels (``kernels/spmv``,
    ``kernels/bfs``, ``kernels/topk_sim``). ``interpret=None`` (default)
    resolves from the backend — native lowering on TPU/GPU, interpret mode
    elsewhere (:mod:`repro.kernels.runtime`); an explicit bool pins it.
    The resolved value is part of the cache fingerprint, so plans compiled
    under one mode never serve the other."""

    name = "pallas"

    def __init__(self, interpret: "bool | None" = None):
        from ..kernels.runtime import resolve_interpret

        self.interpret = resolve_interpret(interpret)

    def cache_fingerprint(self) -> tuple:
        return (self.name, self.interpret)

    def placement_slots(self) -> int:
        return max(1, os.cpu_count() or 1)


# -- built-in kernels ----------------------------------------------------------
# The algorithm code lives in repro.core.*; these adapters bind it to a
# backend. Registered here (not on the classes) so capability is data.


@kernel("spmv", "local")
def _spmv_local(sub: Substrate, a, x, *, strategy):
    return spmv_local(a, x, strategy)


@kernel("bfs", "local")
def _bfs_local(sub: Substrate, g, root, *, strategy, max_rounds=None):
    return bfs_local(g, root, strategy, max_rounds)


@kernel("gsana", "local")
def _gsana_local(sub: Substrate, vs1, vs2, b1, b2, k, *, strategy):
    return compute_similarity(vs1, vs2, b1, b2, k, strategy.scheme)


@kernel("spmv", "mesh")
def _spmv_mesh(sub: MeshSubstrate, a, x, *, strategy):
    return spmv_mesh(a, x, strategy, sub.mesh_for(a.P), sub.axis_name)


@kernel("bfs", "mesh")
def _bfs_mesh(sub: MeshSubstrate, g, root, *, strategy, max_rounds=None):
    return bfs_mesh(
        g, root, strategy, max_rounds, mesh=sub.mesh_for(g.P), axis_name=sub.axis_name,
    )


@kernel("gsana", "mesh")
def _gsana_mesh(sub: MeshSubstrate, vs1, vs2, b1, b2, k, *, strategy):
    # task distribution over however many devices the host mesh offers
    mesh = sub.mesh
    if mesh is None:
        from ..launch.mesh import make_nodelet_mesh

        n_dev = len(jax.devices())
        if n_dev < 2:
            raise OpNotSupportedError(
                "mesh substrate needs >1 device to distribute gsana tasks "
                "(pass an explicit mesh or use 'local')"
            )
        mesh = make_nodelet_mesh(n_dev)
    return compute_similarity_mesh(
        vs1, vs2, b1, b2, k, strategy.scheme, mesh=mesh, axis_name=sub.axis_name,
    )


@kernel("spmv", "pallas")
def _spmv_pallas(sub: PallasSubstrate, a, x, *, strategy):
    from ..kernels.spmv.ops import spmv as spmv_kernel

    x_full = x if strategy.replicate_x else unstripe_vector(x, a.shape[1])
    p, rp, k = a.cols.shape
    grain = strategy.dynamic_grain(rp)
    # nodelet planes -> one (P*R_p, K) row block; kernel grid = row chunks
    y = spmv_kernel(
        a.cols.reshape(p * rp, k), a.vals.reshape(p * rp, k), x_full,
        grain=max(1, min(grain, p * rp)), interpret=sub.interpret,
    )
    return y.reshape(p, rp)


@kernel("bfs", "pallas")
def _bfs_pallas(sub: PallasSubstrate, g, root, *, strategy, max_rounds=None):
    from ..kernels.bfs.ops import bfs_pallas

    # both S2 strategies share the kernel (deterministic min-merge, same
    # tree as the local oracle); the strategy contributes the grain axis
    return bfs_pallas(
        g, root, strategy, max_rounds, interpret=sub.interpret
    )


@kernel("gsana", "pallas")
def _gsana_pallas(sub: PallasSubstrate, vs1, vs2, b1, b2, k, *, strategy):
    import jax.numpy as jnp
    import numpy as np

    from ..core.gsana import DEFAULT_VOCAB, _merge_pair_topk, _scatter_vertex_major  # noqa: PLC0415
    from ..core.gsana_data import neighbor_buckets
    from ..kernels.topk_sim.ops import topk_sim_pairs

    if strategy.scheme != Scheme.PAIR:
        raise OpNotSupportedError(
            "pallas gsana kernel implements the PAIR task shape only"
        )
    grid2 = b2.grid * b2.grid
    nb = neighbor_buckets(b2.grid)
    pair_b2 = jnp.asarray(np.repeat(np.arange(grid2), 9))
    pair_b1 = jnp.asarray(nb.reshape(-1))
    scores, u_ids = topk_sim_pairs(
        vs1, vs2, b1, b2, pair_b2, pair_b1,
        vocab=DEFAULT_VOCAB, k=min(k, b1.cap), interpret=sub.interpret,
    )
    scores = jnp.where(jnp.isfinite(scores), scores, NEG)
    cand_b, score_b = _merge_pair_topk(u_ids, scores, grid2, k)
    return _scatter_vertex_major(cand_b, score_b, b2, vs2.n, k)


# -- registry ------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], Substrate]] = {}


def register_substrate(name: str, factory: Callable[[], Substrate]) -> None:
    _REGISTRY[name] = factory


def list_substrates() -> list[str]:
    return sorted(_REGISTRY)


def get_substrate(substrate: "Substrate | str") -> Substrate:
    """Resolve a substrate instance from a name or pass an instance through."""
    if isinstance(substrate, Substrate):
        return substrate
    try:
        return _REGISTRY[substrate]()
    except KeyError:
        raise ValueError(
            f"unknown substrate {substrate!r}; registered: {list_substrates()}"
        ) from None


def substrate_for_mesh(
    mesh: jax.sharding.Mesh | None, axis_name: str = "nodelet"
) -> Substrate:
    """Legacy-shim resolution: a mesh means the mesh substrate, no mesh means
    local. The one place the old ``mesh=None`` convention is interpreted."""
    if mesh is None:
        return LocalSubstrate()
    return MeshSubstrate(mesh, axis_name)


register_substrate("local", LocalSubstrate)
register_substrate("mesh", MeshSubstrate)
register_substrate("pallas", PallasSubstrate)
