"""repro — Migratory-Strategy Framework (MSF).

A production-grade JAX framework reproducing and extending
*Programming Strategies for Irregular Algorithms on the Emu Chick*
(Hein et al., 2018): replication (S1), remote writes over thread
migration (S2), and locality/load-aware data layout (S3), adapted to
multi-pod TPU SPMD execution.
"""

__version__ = "0.1.0"
