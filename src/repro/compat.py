"""jax version compatibility: one import site for APIs that moved.

The framework targets current jax (``jax.shard_map``, ``jax.make_mesh`` with
``axis_types``) but must also run on the 0.4.x line, where ``shard_map``
lives in ``jax.experimental`` (with ``check_rep`` instead of ``check_vma``)
and ``jax.sharding.AxisType`` does not exist. Every mesh/shard_map use in the
codebase goes through these two helpers.
"""
from __future__ import annotations

from typing import Any, Callable

import jax


def shard_map(
    f: Callable,
    mesh: jax.sharding.Mesh,
    in_specs: Any,
    out_specs: Any,
) -> Callable:
    """``jax.shard_map`` with replication checking off, on any jax version."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


def make_mesh_over(devices, axes: tuple[str, ...]) -> jax.sharding.Mesh:
    """A mesh over an *explicit* device list (the executor pool's per-slot
    device windows), on any jax version. ``devices`` is a flat sequence; its
    length must factor into the implied 1-D axis."""
    import numpy as np

    arr = np.asarray(devices, dtype=object)
    if hasattr(jax.sharding, "AxisType"):
        return jax.sharding.Mesh(
            arr, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.sharding.Mesh(arr, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes)
    from jax.experimental import mesh_utils  # jax < 0.4.35

    return jax.sharding.Mesh(mesh_utils.create_device_mesh(shape), axes)
