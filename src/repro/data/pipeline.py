"""Deterministic, index-based, host-sharded synthetic token pipeline.

Production properties this models:
- **index-based determinism**: batch ``i`` is a pure function of (seed, i) —
  a restarted or elastically re-meshed run replays the exact token stream
  from its checkpointed step (straggler/fault story, DESIGN.md §5);
- **host sharding**: each host materializes only its slice of the global
  batch (``host_id``/``num_hosts``), exactly like a multi-host input
  pipeline feeding ``jax.make_array_from_process_local_data``;
- **packing**: documents of random length packed into fixed-length rows with
  EOS separators (so the LM sees realistic discontinuities).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    eos_id: int = 0
    mean_doc_len: int = 256
    # zipfian unigram skew — gives the loss something learnable
    zipf_a: float = 1.3


class SyntheticTokens:
    """Infinite deterministic stream of packed LM batches."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, num_hosts: int = 1):
        assert cfg.global_batch % num_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts

    def _row(self, step: int, row: int) -> np.ndarray:
        """One packed (seq_len + 1,) row — pure function of (seed, step, row)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 0x9E3779B1 + step * 0x85EBCA77 + row) % (2**63)
        )
        out = np.empty(cfg.seq_len + 1, dtype=np.int32)
        pos = 0
        while pos < len(out):
            doc_len = max(1, int(rng.exponential(cfg.mean_doc_len)))
            n = min(doc_len, len(out) - pos)
            # zipf unigrams + a deterministic bigram structure (learnable)
            toks = rng.zipf(cfg.zipf_a, size=n) % (cfg.vocab_size - 1) + 1
            toks[1:] = np.where(
                rng.random(n - 1) < 0.5,
                (toks[:-1] * 31 + 7) % (cfg.vocab_size - 1) + 1,
                toks[1:],
            )
            out[pos : pos + n] = toks
            pos += n
            if pos < len(out):
                out[pos] = cfg.eos_id
                pos += 1
        return out

    def batch(self, step: int) -> np.ndarray:
        """Host-local slice of global batch ``step``: (local_batch, S + 1)."""
        rows = range(
            self.host_id * self.local_batch, (self.host_id + 1) * self.local_batch
        )
        return np.stack([self._row(step, r) for r in rows])

    def jax_batch(self, step: int) -> dict:
        return {"tokens": jnp.asarray(self.batch(step))}

    def global_batch_all_hosts(self, step: int) -> np.ndarray:
        """Testing helper: the full global batch (what all hosts union to)."""
        return np.stack([self._row(step, r) for r in range(self.cfg.global_batch)])
