import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input shape x mesh) cell on the production meshes, print
memory_analysis/cost_analysis, and derive the roofline terms.

Usage:
    python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all            # every cell, subprocess each
    python -m repro.launch.dryrun --all --mesh multi

Results are written to experiments/dryrun/<arch>__<shape>__<mesh>.json and
aggregated by EXPERIMENTS.md tooling.
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _apply_overrides(cfg, overrides: dict):
    import dataclasses

    typed = {}
    for k, v in overrides.items():
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            typed[k] = v in ("1", "true", "True")
        elif isinstance(cur, int):
            typed[k] = int(v)
        elif isinstance(cur, float):
            typed[k] = float(v)
        else:
            typed[k] = v
    return dataclasses.replace(cfg, **typed)


def run_cell(arch: str, shape_name: str, mesh_kind: str, overrides: dict | None = None) -> dict:
    import jax

    from ..configs import SHAPES, applicable, get_config
    from ..launch import roofline
    from ..launch.mesh import make_production_mesh
    from ..launch.steps import build_programs

    cfg = get_config(arch)
    if overrides:
        cfg = _apply_overrides(cfg, overrides)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    progs = build_programs(cfg, mesh, shape)
    with mesh:
        if shape.kind == "decode":
            params_abs, tok_abs, state_abs = progs.abstract_inputs
            lowered = progs.step.lower(params_abs, tok_abs, state_abs)
        else:
            lowered = progs.step.lower(*progs.abstract_inputs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    print(mem)
    cost = compiled.cost_analysis()
    print({k: cost.get(k) for k in ("flops", "bytes accessed")})
    hlo = compiled.as_text()
    report = roofline.analyze(hlo)
    n_chips = mesh.size
    mf = roofline.model_flops(cfg, shape.kind, shape.seq_len, shape.global_batch)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "status": "ok",
        "n_chips": n_chips,
        "seconds_lower": round(t_lower, 2), "seconds_compile": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_dev": mem.argument_size_in_bytes,
            "output_bytes_per_dev": mem.output_size_in_bytes,
            "temp_bytes_per_dev": mem.temp_size_in_bytes,
            "alias_bytes_per_dev": mem.alias_size_in_bytes,
            "peak_bytes_per_dev": (
                mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes
            ),
        },
        "xla_cost_analysis": {
            "flops_per_dev_body_once": cost.get("flops", 0.0),
            "bytes_accessed_body_once": cost.get("bytes accessed", 0.0),
        },
        "roofline": report.to_dict(),
        "model_flops_global": mf,
        "model_flops_per_dev": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / max(report.flops, 1.0),
        "hlo_bytes": len(hlo),
    }
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field override key=value (perf hillclimbing)")
    ap.add_argument("--tag", default=None, help="suffix for the output json")
    args = ap.parse_args()
    OUT_DIR.mkdir(parents=True, exist_ok=True)

    if args.all:
        from ..configs import ARCHS, SHAPES

        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        failures = []
        for arch in ARCHS:
            for shape in SHAPES:
                for mk in meshes:
                    out = OUT_DIR / f"{arch}__{shape}__{mk}.json"
                    if out.exists() and not args.force:
                        print(f"cached   {out.name}")
                        continue
                    cmd = [
                        sys.executable, "-m", "repro.launch.dryrun",
                        "--arch", arch, "--shape", shape, "--mesh", mk,
                    ]
                    t0 = time.time()
                    r = subprocess.run(cmd, capture_output=True, text=True)
                    dt = time.time() - t0
                    if r.returncode == 0 and out.exists():
                        status = json.loads(out.read_text()).get("status")
                        print(f"{status:8s} {out.name} ({dt:.0f}s)")
                    else:
                        failures.append((arch, shape, mk))
                        print(f"FAILED   {out.name} ({dt:.0f}s)")
                        print(r.stdout[-2000:])
                        print(r.stderr[-4000:])
        if failures:
            print(f"\n{len(failures)} cell(s) failed: {failures}")
            sys.exit(1)
        print("\nAll dry-run cells passed.")
        return

    assert args.arch and args.shape, "--arch and --shape required (or --all)"
    overrides = dict(kv.split("=", 1) for kv in args.override)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for mk in meshes:
        tag = f"__{args.tag}" if args.tag else ""
        out = OUT_DIR / f"{args.arch}__{args.shape}__{mk}{tag}.json"
        try:
            result = run_cell(args.arch, args.shape, mk, overrides)
        except Exception:
            traceback.print_exc()
            sys.exit(1)
        out.write_text(json.dumps(result, indent=2, default=float))
        print(f"wrote {out}")
        if result["status"] == "ok":
            r = result["roofline"]
            print(
                f"  terms: compute={r['t_compute']:.3e}s memory={r['t_memory']:.3e}s "
                f"collective={r['t_collective']:.3e}s dominant={r['dominant']}"
            )


if __name__ == "__main__":
    main()
