"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.launch.report [--mesh single]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def load(mesh: str) -> list[dict]:
    rows = []
    for p in sorted(OUT_DIR.glob(f"*__{mesh}.json")):
        rows.append(json.loads(p.read_text()))
    return rows


def fmt_bytes(b: float) -> str:
    if b >= 1e9:
        return f"{b / 1e9:.2f}GB"
    if b >= 1e6:
        return f"{b / 1e6:.1f}MB"
    return f"{b / 1e3:.0f}KB"


def roofline_table(mesh: str = "single") -> str:
    rows = load(mesh)
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "HLO flops/dev | MODEL/HLO | peak HBM/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | *skipped* "
                f"({r['reason'][:40]}…) | — | — | — |"
            )
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['t_compute']:.2e} | "
            f"{rf['t_memory']:.2e} | {rf['t_collective']:.2e} | "
            f"**{rf['dominant']}** | {rf['flops']:.2e} | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{fmt_bytes(r['memory']['peak_bytes_per_dev'])} |"
        )
    return "\n".join(lines)


def dryrun_table(mesh: str) -> str:
    rows = load(mesh)
    lines = [
        "| arch | shape | status | lower s | compile s | args/dev | temp/dev | top collective |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | skipped | — | — | — | — | — |")
            continue
        colls = r["roofline"]["collectives"]
        top = (
            f"{colls[0]['kind']}×{colls[0]['count']} ({fmt_bytes(colls[0]['wire_bytes'])})"
            if colls else "none"
        )
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['seconds_lower']} | "
            f"{r['seconds_compile']} | {fmt_bytes(r['memory']['argument_bytes_per_dev'])} | "
            f"{fmt_bytes(r['memory']['temp_bytes_per_dev'])} | {top} |"
        )
    return "\n".join(lines)


def pick_hillclimb_cells() -> list[dict]:
    """The three most interesting cells: worst useful-flops ratio, most
    collective-bound, most representative of the paper's technique (MoE EP
    dispatch = S2)."""
    rows = [r for r in load("single") if r["status"] == "ok"]
    worst_ratio = min(rows, key=lambda r: r["useful_flops_ratio"])
    most_coll = max(
        rows,
        key=lambda r: r["roofline"]["t_collective"]
        / max(max(r["roofline"]["t_compute"], r["roofline"]["t_memory"]), 1e-12),
    )
    moe = [r for r in rows if "moonshot" in r["arch"] and r["shape"] == "train_4k"]
    return [worst_ratio, most_coll] + moe[:1]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    print("## Roofline —", args.mesh)
    print(roofline_table(args.mesh))
    print()
    print("## Dry-run —", args.mesh)
    print(dryrun_table(args.mesh))
    print()
    print("## Hillclimb candidates")
    for r in pick_hillclimb_cells():
        print(
            f"- {r['arch']} x {r['shape']}: dominant={r['roofline']['dominant']} "
            f"ratio={r['useful_flops_ratio']:.2f}"
        )


if __name__ == "__main__":
    main()
