"""Roofline analysis from compiled HLO (EXPERIMENTS.md §Roofline).

Parses ``compiled.as_text()`` (post-SPMD optimized HLO) into a computation
call graph, scales while-loop bodies by their ``known_trip_count`` (XLA's
cost analysis counts a ``lax.scan`` body ONCE — verified experimentally, see
DESIGN.md §8), and derives the three per-chip roofline terms:

    compute    = dot/conv FLOPs (post-partition shapes are per-device)
    memory     = bytes touched by non-fused ops (operands + outputs)
    collective = ring-cost wire bytes per device of every collective op

The peaks come from the machine file (DESIGN.md §1f): ``analyze`` divides
by the :class:`~repro.machine.machine.Peaks` of the process-wide
:func:`~repro.machine.machine.default_machine` (or an explicit ``machine=``
profile). The bundled default carries the former hardcoded TPU-v5e-like
constants (197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link), so uncalibrated
output is unchanged; after ``python -m repro.machine.microbench`` the
roofline speaks this host's sustained rates.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

from ..machine.machine import DTYPE_BYTES as _DTYPE_BYTES
from ..machine.machine import MachineProfile, default_machine

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _parse_shapes(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    """All 'dtype[dims]' occurrences in a type string (tuples expanded)."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(x) for x in dims.split(",") if x) if dims else ()
        out.append((dt, shape))
    return out


def _nbytes(dt: str, shape: tuple[int, ...]) -> int:
    n = _DTYPE_BYTES[dt]
    for s in shape:
        n *= s
    return n


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    out_types: list  # [(dtype, shape)]
    operands: list[str]
    attrs: str


@dataclasses.dataclass
class CollectiveRecord:
    kind: str
    bytes_in: int  # per-device operand bytes (one execution)
    group_size: int
    count: int  # executions per step
    wire_bytes: float  # ring-cost bytes on the wire per device, total


@dataclasses.dataclass
class RooflineReport:
    flops: float  # per device per step
    bytes_hbm: float
    bytes_collective: float  # wire bytes per device
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    collectives: list  # top CollectiveRecords (dicts)
    collective_counts: dict  # kind -> wire bytes

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class HloModule:
    """Parsed optimized-HLO module with execution-count propagation."""

    def __init__(self, text: str):
        self.comps: dict[str, list[Op]] = {}
        self.shape_of: dict[str, tuple[str, tuple[int, ...]]] = {}
        self.entry = None
        self._parse(text)
        self.counts = self._propagate_counts()

    # -- parsing ---------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line or line.startswith("//"):
                continue
            # computation header: `%name (params...) -> type {` — params may
            # nest parens (tuple types), so match greedily and exclude op
            # lines (which contain " = ")
            header = None
            if line.endswith("{") and " = " not in line:
                header = re.match(
                    r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$", line
                )
            if header:
                cur = header.group(2)
                self.comps[cur] = []
                if header.group(1):
                    self.entry = cur
                continue
            if line.strip() == "}":
                continue
            if cur is None:
                continue
            m = _OP_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            rhs = re.sub(r"/\*.*?\*/", "", rhs).strip()  # strip /*index=N*/ comments
            parsed = self._split_rhs(rhs)
            if parsed is None:
                continue
            type_str, kind, args, attrs = parsed
            operands = re.findall(r"%([\w\.\-]+)", args)
            out_types = _parse_shapes(type_str)
            self.shape_of[name] = out_types[0] if out_types else ("f32", ())
            self.comps[cur].append(Op(name, kind, out_types, operands, attrs))
        if self.entry is None and self.comps:
            self.entry = next(iter(self.comps))

    @staticmethod
    def _split_rhs(rhs: str):
        """'TYPE kind(args), attrs' -> (type, kind, args, attrs).

        TYPE may be a tuple type with nested parens (huge for scan carries),
        so it is consumed with explicit paren balancing, not a regex.
        """
        if rhs.startswith("("):
            depth = 0
            end = -1
            for i, ch in enumerate(rhs):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            if end < 0:
                return None
            type_str = rhs[: end + 1]
            rest = rhs[end + 1 :]
        else:
            tm = re.match(r"^[\w\[\],\.]+(\{[^}]*\})?", rhs)
            if not tm:
                return None
            type_str = tm.group(0)
            rest = rhs[tm.end() :]
        km = re.match(r"\s*([\w\-]+)\((.*)$", rest)
        if not km:
            return None
        kind, tail = km.group(1), km.group(2)
        depth = 1
        args = []
        for i, ch in enumerate(tail):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return type_str, kind, "".join(args), tail[i + 1 :]
            args.append(ch)
        return type_str, kind, "".join(args), ""

    # -- execution counts --------------------------------------------------
    def _propagate_counts(self) -> dict[str, float]:
        counts: dict[str, float] = defaultdict(float)
        fused: set[str] = set()
        counts[self.entry] = 1.0
        # iterate to fixed point (call graph is a DAG; few passes suffice)
        for _ in range(12):
            changed = False
            for comp, c in list(counts.items()):
                for op in self.comps.get(comp, []):
                    trip = 1.0
                    if op.kind == "while":
                        tm = re.search(r'known_trip_count[^\d]*(\d+)', op.attrs)
                        trip = float(tm.group(1)) if tm else 1.0
                        for key in ("body=", "condition="):
                            bm = re.search(key + r"%?([\w\.\-]+)", op.attrs)
                            if bm:
                                tgt = bm.group(1)
                                newc = c * trip
                                if counts.get(tgt, 0) < newc:
                                    counts[tgt] = newc
                                    changed = True
                        continue
                    for key, is_fused in (
                        ("calls=", True), ("to_apply=", True),
                        ("branch_computations=", False),
                    ):
                        am = re.search(key + r"\{?%?([\w\.\-]+)", op.attrs)
                        if am:
                            tgt = am.group(1)
                            if is_fused and op.kind == "fusion":
                                fused.add(tgt)
                            if counts.get(tgt, 0) < c:
                                counts[tgt] = c
                                changed = True
            if not changed:
                break
        self.fused = fused
        return counts

    # -- cost extraction ---------------------------------------------------
    def _dot_flops(self, op: Op) -> float:
        out_elems = 1
        for dt, shape in op.out_types[:1]:
            for s in shape:
                out_elems *= s
        lhs = op.operands[0] if op.operands else None
        cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
        contract = 1
        if lhs and lhs in self.shape_of and cdims:
            shape = self.shape_of[lhs][1]
            for d in cdims.group(1).split(","):
                if d:
                    contract *= shape[int(d)] if int(d) < len(shape) else 1
        return 2.0 * out_elems * contract

    def flops(self) -> float:
        total = 0.0
        for comp, ops in self.comps.items():
            c = self.counts.get(comp, 0.0)
            if c == 0:
                continue
            for op in ops:
                if op.kind == "dot":
                    total += c * self._dot_flops(op)
                elif op.kind == "convolution":
                    out_elems = 1
                    for s in op.out_types[0][1]:
                        out_elems *= s
                    ksize = 1
                    if len(op.operands) > 1 and op.operands[1] in self.shape_of:
                        for s in self.shape_of[op.operands[1]][1][:-1]:
                            ksize *= s
                    total += c * 2.0 * out_elems * ksize
        return total

    _SKIP_BYTES = {
        "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
        "after-all", "partition-id", "replica-id", "iota",
    }

    def bytes_hbm(self) -> float:
        total = 0.0
        for comp, ops in self.comps.items():
            c = self.counts.get(comp, 0.0)
            if c == 0 or comp in self.fused:
                continue  # fused internals don't touch HBM
            for op in ops:
                if op.kind in self._SKIP_BYTES:
                    continue
                b = sum(_nbytes(dt, sh) for dt, sh in op.out_types)
                for o in op.operands:
                    if o in self.shape_of:
                        dt, sh = self.shape_of[o]
                        b += _nbytes(dt, sh)
                total += c * b
        return total

    @staticmethod
    def _group_size(attrs: str) -> int:
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
        if m:
            return int(m.group(2))
        m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
        if m:
            return len(m.group(1).split(","))
        return 1

    def collectives(self) -> list[CollectiveRecord]:
        recs = []
        for comp, ops in self.comps.items():
            c = self.counts.get(comp, 0.0)
            if c == 0:
                continue
            for op in ops:
                kind = next((k for k in _COLLECTIVES if op.kind.startswith(k)), None)
                if kind is None or op.kind.endswith("-done"):
                    continue
                g = self._group_size(op.attrs)
                b_in = 0
                for o in op.operands:
                    if o in self.shape_of:
                        dt, sh = self.shape_of[o]
                        b_in += _nbytes(dt, sh)
                if b_in == 0:  # fall back to output size
                    b_in = sum(_nbytes(dt, sh) for dt, sh in op.out_types)
                if kind == "all-gather":
                    wire = (g - 1) * b_in
                elif kind == "all-reduce":
                    wire = 2 * (g - 1) / max(g, 1) * b_in
                elif kind == "reduce-scatter":
                    wire = (g - 1) / max(g, 1) * b_in
                elif kind == "all-to-all":
                    wire = (g - 1) / max(g, 1) * b_in
                else:  # collective-permute
                    wire = b_in
                recs.append(
                    CollectiveRecord(
                        kind=kind, bytes_in=b_in, group_size=g, count=int(c),
                        wire_bytes=wire * c,
                    )
                )
        return recs


def analyze(
    hlo_text: str, machine: "MachineProfile | None" = None
) -> RooflineReport:
    peaks = (machine if machine is not None else default_machine()).peaks
    mod = HloModule(hlo_text)
    flops = mod.flops()
    bts = mod.bytes_hbm()
    colls = mod.collectives()
    cbytes = sum(r.wire_bytes for r in colls)
    by_kind: dict[str, float] = defaultdict(float)
    for r in colls:
        by_kind[r.kind] += r.wire_bytes
    t_c = flops / peaks.flops
    t_m = bts / peaks.hbm_bw
    t_x = cbytes / peaks.ici_bw
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)), key=lambda t: t[1])[0]
    top = sorted(colls, key=lambda r: -r.wire_bytes)[:12]
    return RooflineReport(
        flops=flops, bytes_hbm=bts, bytes_collective=cbytes,
        t_compute=t_c, t_memory=t_m, t_collective=t_x, dominant=dom,
        collectives=[dataclasses.asdict(r) for r in top],
        collective_counts=dict(by_kind),
    )


def model_flops(cfg, kind: str, seq_len: int, global_batch: int) -> float:
    """Analytic MODEL_FLOPS for the whole step (all chips): 6·N·D train /
    2·N·D inference, plus the attention term."""
    n = cfg.active_param_count
    if kind == "train":
        tokens = seq_len * global_batch
        base = 6.0 * n * tokens
        attn = 12.0 * cfg.num_layers * cfg.num_heads * cfg.hd * seq_len * seq_len * global_batch
        if cfg.sliding_window:
            attn *= min(1.0, cfg.sliding_window / seq_len)
        if cfg.family in ("ssm", "hybrid"):
            attn = 0.0
        return base + attn
    if kind == "prefill":
        tokens = seq_len * global_batch
        attn = 4.0 * cfg.num_layers * cfg.num_heads * cfg.hd * seq_len * seq_len * global_batch
        if cfg.sliding_window:
            attn *= min(1.0, cfg.sliding_window / seq_len)
        if cfg.family in ("ssm", "hybrid"):
            attn = 0.0
        return 2.0 * n * tokens + attn
    # decode: one token against seq_len of context
    ctx_len = seq_len if not cfg.sliding_window else min(seq_len, cfg.sliding_window)
    attn = 4.0 * cfg.num_layers * cfg.num_heads * cfg.hd * ctx_len * global_batch
    if cfg.family == "ssm":
        attn = 0.0
    return 2.0 * n * global_batch + attn
