"""Batched serving drivers.

LM decode path: prefill a batch of prompts, then greedy-decode.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --reduced \
        --batch 4 --prompt-len 64 --gen 32

Irregular-op path: drive an ``EngineService`` with a mixed SpMV/BFS request
stream (autotuned strategies, shared compiled-plan cache) and print the
aggregate throughput report — the engine's production-serving smoke. All
submissions go through the unified :class:`repro.engine.Request` shape.
``--ops`` uses the batched drain; ``--ops-async`` starts the worker loop and
feeds it from a synthetic *open-loop* traffic generator (requests arrive at
``--ops-rate`` req/s with jitter, independent of service progress — the
arrival process of real serving), exercising admission control
(``--ops-admission block|reject``), QoS weighting, and the overlapped
compile/execute pipeline.

    PYTHONPATH=src python -m repro.launch.serve --ops --ops-requests 32
    PYTHONPATH=src python -m repro.launch.serve --ops-async --ops-rate 100 \
        --ops-requests 64 --ops-admission reject

MoE decode serving path (DESIGN.md §1g): continuous-batched decode of the
``serve-moe`` config through the worker-loop service with an SLO target,
cross-checked token-for-token against the single-process oracle.

    PYTHONPATH=src python -m repro.launch.serve --decode-serve \
        --serve-dispatch ep_pull --serve-slo-ms 2000

Cluster path (DESIGN.md §1h): serve the same mixed-op stream on an
N-worker multi-process cluster with a bit-parity cross-check against
single-process ``engine.run``; ``--cluster-kill-one`` SIGKILLs a worker
mid-stream to demonstrate heartbeat/EOF failover.

    PYTHONPATH=src python -m repro.launch.serve --cluster 2 [--cluster-kill-one]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, reduced_config
from ..models import Ctx, api


def _ops_workload(shapes: tuple[int, ...], seed: int):
    """The demo's rotating problem signatures (SpMV pool + one BFS graph)."""
    import numpy as np

    from ..core import partition_ell
    from ..engine import BFSInputs, SpMVInputs
    from ..sparse import edges_to_csr, erdos_renyi_edges, laplacian_2d, partition_graph

    rng = np.random.default_rng(seed)
    spmv_pool = []
    for n in shapes:
        a = laplacian_2d(n)
        x = jnp.asarray(rng.standard_normal(n * n).astype(np.float32))
        spmv_pool.append(SpMVInputs(partition_ell(a, 8), x))
    g = edges_to_csr(erdos_renyi_edges(9, 6, seed=seed), 512)
    bfs_inputs = BFSInputs(partition_graph(g, 8), 0)

    def pick(i: int):
        if i % 3 == 2:
            return "bfs", bfs_inputs
        return "spmv", spmv_pool[i % len(spmv_pool)]

    return pick


def ops_demo(n_requests: int, shapes: tuple[int, ...] = (16, 24), seed: int = 0) -> dict:
    """Serve a mixed irregular-op workload through the batched EngineService.

    Requests rotate over a few problem signatures, so each drain compiles
    once per signature and serves the rest from the plan cache.
    """
    from ..engine import EngineService, Request

    pick = _ops_workload(shapes, seed)
    svc = EngineService(autotune=True)
    for i in range(n_requests):
        op, inputs = pick(i)
        svc.submit(Request(op, inputs))
    responses = svc.drain()
    report = svc.throughput_report()
    stats = svc.stats()
    print(f"served {len(responses)} requests in {stats.wall_seconds*1e3:.0f} ms "
          f"({stats.requests_per_second:.0f} req/s)")
    print(f"compiles: {stats.compiles} ({stats.compile_seconds*1e3:.0f} ms), "
          f"cache hits: {stats.cache_hits}, "
          f"amortization: {stats.amortization:.1f} req/compile")
    print(json.dumps(report, default=str))
    return report


def ops_demo_async(
    n_requests: int,
    rate: float = 100.0,
    admission: str = "block",
    max_queue_depth: int = 64,
    shapes: tuple[int, ...] = (16, 24),
    seed: int = 0,
    workers: "int | str" = 1,
) -> dict:
    """Open-loop async serving demo: a synthetic traffic generator submits at
    ``rate`` req/s (jittered, never waiting for responses — open loop) while
    the worker pipeline overlaps compiles with execution. BFS requests get a
    2x QoS weight, so mixed bursts schedule BFS groups first."""
    import numpy as np

    from ..engine import AdmissionError, EngineService, Request

    pick = _ops_workload(shapes, seed)
    rng = np.random.default_rng(seed)
    interval = 1.0 / rate if rate > 0 else 0.0
    svc = EngineService(
        autotune=True,
        workers=workers,
        max_queue_depth=max_queue_depth,
        admission=admission,
        qos={"bfs": 2.0},
        batch_window=0.02,
    )
    svc.start()
    futures = []
    try:
        for i in range(n_requests):
            try:
                op, inputs = pick(i)
                futures.append(svc.submit(Request(op, inputs)))
            except AdmissionError:
                pass  # open loop drops on the floor; counted in stats.rejected
            if interval:
                time.sleep(interval * (0.5 + rng.random()))  # jittered arrivals
        responses = [f.result(timeout=600) for f in futures]
    finally:
        svc.stop()
    report = svc.throughput_report()
    stats = svc.stats()
    print(f"served {len(responses)}/{n_requests} requests "
          f"({stats.rejected} rejected) in {stats.wall_seconds*1e3:.0f} ms "
          f"({stats.requests_per_second:.0f} req/s sustained)")
    print(f"compiles: {stats.compiles} ({stats.compile_seconds*1e3:.0f} ms), "
          f"cache hits: {stats.cache_hits}, "
          f"amortization: {stats.amortization:.1f} req/compile")
    print(f"overlap: {stats.overlap_seconds*1e3:.0f} ms "
          f"({stats.overlap_ratio:.0%} of compile time hidden under execution), "
          f"busy {stats.busy_seconds*1e3:.0f} / wall {stats.wall_seconds*1e3:.0f} ms, "
          f"queue hwm {stats.queue_depth_hwm}")
    if stats.workers > 1:
        print(f"pool: {stats.workers} workers, {stats.steals} steals, "
              f"occupancy {[round(o, 2) for o in stats.worker_occupancy]}")
    print(json.dumps(report, default=str))
    return report


def decode_serve_demo(
    n_seqs: int = 8,
    capacity: int = 8,
    max_new: int = 8,
    workers: "int | str" = 2,
    slo_ms: float = 5000.0,
    nodelets: int = 4,
    dispatch: str = "ep_pull",
    seed: int = 0,
) -> dict:
    """Continuous-batched MoE decode serving (DESIGN.md §1g): the ``serve-moe``
    config's expert FFNs run behind ``moe_dispatch`` transport, every decode
    step travels as one :class:`Request` through the worker-loop service with
    an SLO target, and the served tokens are cross-checked bit-for-bit against
    the single-process oracle."""
    import numpy as np

    from ..configs import get_config
    from ..core import Comm, MigratoryStrategy
    from ..engine import DecodeServer, EngineService
    from ..models.transformer import moe_decode_params

    cfg = get_config("serve-moe")
    params = moe_decode_params(cfg, jax.random.PRNGKey(seed))
    strategy = {
        "ep_pull": MigratoryStrategy(comm=Comm.MIGRATE),
        "ep_push": MigratoryStrategy(comm=Comm.REMOTE_WRITE),
    }.get(dispatch)
    nod = 1 if dispatch == "tp" else nodelets
    rng = np.random.default_rng(seed)
    prompts = [
        rng.integers(1, cfg.vocab_size, size=int(rng.integers(2, 6))).tolist()
        for _ in range(n_seqs)
    ]

    def drive(server):
        # staggered joins: half the sequences arrive while others are decoding
        for i, prompt in enumerate(prompts):
            server.add(prompt, max_new_tokens=max_new)
            if i % 2:
                server.step()
        server.run_until_drained()
        return dict(server.results)

    svc = EngineService(workers=workers, slo_target_seconds=slo_ms / 1e3)
    svc.start()
    try:
        served = drive(DecodeServer(
            cfg, params, capacity=capacity, max_len=32, nodelets=nod,
            strategy=strategy, service=svc,
        ))
    finally:
        svc.stop()
    stats = svc.stats()
    oracle = drive(DecodeServer(
        cfg, params, capacity=capacity, max_len=32, nodelets=nod,
        strategy=strategy, oracle=True,
    ))
    parity = served == oracle
    print(f"served {len(served)} sequences (dispatch={dispatch}, nodelets={nod}), "
          f"oracle parity: {parity}")
    print(f"latency p50/p99: {stats.total_p50*1e3:.1f}/{stats.total_p99*1e3:.1f} ms; "
          f"SLO {slo_ms:.0f} ms -> {stats.slo_violations}/{stats.slo_checked} violations "
          f"(attainment {stats.slo_attainment})")
    report = {**svc.throughput_report(), "oracle_parity": parity}
    print(json.dumps(report, default=str))
    return report


def cluster_demo(
    n_workers: int,
    n_requests: int = 24,
    shapes: tuple[int, ...] = (16, 24),
    seed: int = 0,
    kill_one: bool = False,
) -> dict:
    """Serve the mixed irregular-op stream on a multi-process cluster
    (DESIGN.md §1h) and cross-check every response bit-for-bit against
    single-process ``engine.run``. ``kill_one=True`` SIGKILLs one worker
    mid-stream to demonstrate failover: every future still terminates and
    parity still holds (in-flight requests are retried once on a
    survivor)."""
    import numpy as np

    from ..cluster import launch_cluster
    from ..engine import Request, run

    pick = _ops_workload(shapes, seed)
    requests = [Request(*pick(i)) for i in range(n_requests)]
    t_start = time.perf_counter()
    with launch_cluster(n_workers) as cluster:
        t_up = time.perf_counter() - t_start
        t0 = time.perf_counter()
        futures = [cluster.submit(r) for r in requests]
        if kill_one and n_workers > 1:
            victim = cluster.coordinator.healthy_workers()[0].worker_id
            print(f"SIGKILLing worker {victim} mid-stream ...")
            cluster.kill_worker(victim)
        responses = [f.result() for f in futures]  # every future terminates
        wall = time.perf_counter() - t0
        mismatches = 0
        for request, response in zip(requests, responses):
            oracle, _ = run(request, iters=1, warmup=0)
            if not np.array_equal(np.asarray(response.result), np.asarray(oracle)):
                mismatches += 1
        stats = cluster.stats()
    per_worker = {
        w["worker_id"]: w["served"] for w in stats["workers"]
    }
    print(f"cluster up ({n_workers} workers) in {t_up:.1f}s; served "
          f"{len(responses)} requests in {wall*1e3:.0f} ms "
          f"({len(responses)/max(wall, 1e-9):.0f} req/s)")
    print(f"per-worker served: {per_worker}, retries: {stats['retries']}, "
          f"failovers: {stats['failovers']}, mismatches: {mismatches}")
    report = {
        "n_workers": n_workers,
        "requests": len(responses),
        "wall_seconds": wall,
        "mismatches": mismatches,
        "cluster": stats,
    }
    print(json.dumps(report, default=str))
    if mismatches:
        raise SystemExit(f"{mismatches} responses diverged from engine.run")
    return report


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--ops", action="store_true",
                    help="serve an irregular-op stream via EngineService (batched drain)")
    ap.add_argument("--ops-async", action="store_true",
                    help="serve an open-loop irregular-op stream via the async worker loop")
    ap.add_argument("--ops-requests", type=int, default=24)
    ap.add_argument("--ops-rate", type=float, default=100.0,
                    help="open-loop arrival rate (req/s) for --ops-async")
    ap.add_argument("--ops-admission", choices=("block", "reject"), default="block",
                    help="admission policy when the async queue is full")
    ap.add_argument("--ops-workers", default="1",
                    help="executor-pool width for --ops-async (int or 'auto')")
    ap.add_argument("--decode-serve", action="store_true",
                    help="continuous-batched MoE decode serving with SLO stats")
    ap.add_argument("--serve-seqs", type=int, default=8)
    ap.add_argument("--serve-dispatch", choices=("ep_pull", "ep_push", "tp"),
                    default="ep_pull")
    ap.add_argument("--serve-nodelets", type=int, default=4)
    ap.add_argument("--serve-slo-ms", type=float, default=5000.0,
                    help="per-request SLO target in ms for --decode-serve")
    ap.add_argument("--cluster", type=int, default=0, metavar="N",
                    help="serve the mixed-op stream on an N-worker localhost "
                         "cluster (multi-process, DESIGN.md §1h) with "
                         "bit-parity cross-check against engine.run")
    ap.add_argument("--cluster-kill-one", action="store_true",
                    help="with --cluster: SIGKILL one worker mid-stream to "
                         "demonstrate failover")
    args = ap.parse_args(argv)

    if args.cluster:
        cluster_demo(args.cluster, n_requests=args.ops_requests,
                     kill_one=args.cluster_kill_one)
        return
    if args.decode_serve:
        workers = args.ops_workers if args.ops_workers == "auto" else int(args.ops_workers)
        decode_serve_demo(args.serve_seqs, dispatch=args.serve_dispatch,
                          nodelets=args.serve_nodelets, slo_ms=args.serve_slo_ms,
                          workers=max(2, workers) if workers != "auto" else workers)
        return
    if args.ops_async:
        workers = args.ops_workers if args.ops_workers == "auto" else int(args.ops_workers)
        ops_demo_async(args.ops_requests, rate=args.ops_rate,
                       admission=args.ops_admission, workers=workers)
        return
    if args.ops:
        ops_demo(args.ops_requests)
        return

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    ctx = Ctx(cfg=cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 1, cfg.vocab_size)
    batch = {}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_frames, cfg.d_model), jnp.float32
        )
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (args.batch, cfg.num_patches, cfg.d_model), jnp.float32
        )

    max_len = args.prompt_len + args.gen + (cfg.num_patches or 0)
    prefill = jax.jit(
        lambda p, toks: api.prefill(ctx, p, toks, max_len=max_len, batch=batch)
    )
    decode = jax.jit(lambda p, tok, st: api.decode_step(ctx, p, tok, st))

    t0 = time.perf_counter()
    logits, state = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        logits, state = api_decode(decode, params, tok, state)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill: {args.batch * args.prompt_len / t_prefill:.0f} tok/s ({t_prefill*1e3:.0f} ms)")
    print(f"decode:  {args.batch * (args.gen - 1) / max(t_decode, 1e-9):.0f} tok/s")
    print("sample token ids:", gen[0, :16].tolist())


def api_decode(decode_fn, params, tok, state):
    return decode_fn(params, tok, state)


if __name__ == "__main__":
    main()
