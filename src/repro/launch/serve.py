"""Batched serving driver: prefill a batch of prompts, then greedy-decode.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --reduced \
        --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, reduced_config
from ..models import Ctx, api


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    ctx = Ctx(cfg=cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 1, cfg.vocab_size)
    batch = {}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_frames, cfg.d_model), jnp.float32
        )
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (args.batch, cfg.num_patches, cfg.d_model), jnp.float32
        )

    max_len = args.prompt_len + args.gen + (cfg.num_patches or 0)
    prefill = jax.jit(
        lambda p, toks: api.prefill(ctx, p, toks, max_len=max_len, batch=batch)
    )
    decode = jax.jit(lambda p, tok, st: api.decode_step(ctx, p, tok, st))

    t0 = time.perf_counter()
    logits, state = prefill(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        logits, state = api_decode(decode, params, tok, state)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill: {args.batch * args.prompt_len / t_prefill:.0f} tok/s ({t_prefill*1e3:.0f} ms)")
    print(f"decode:  {args.batch * (args.gen - 1) / max(t_decode, 1e-9):.0f} tok/s")
    print("sample token ids:", gen[0, :16].tolist())


def api_decode(decode_fn, params, tok, state):
    return decode_fn(params, tok, state)


if __name__ == "__main__":
    main()
