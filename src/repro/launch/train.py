"""End-to-end training driver with fault-tolerant supervision.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --reduced \
        --steps 100 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

On the CPU container this trains the reduced config on the host mesh; on a
real TPU fleet the same driver runs the full config on the production mesh
(--production). --fail-at N demonstrates checkpoint/restart recovery.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from ..configs import get_config, reduced_config
from ..data import DataConfig, SyntheticTokens
from ..models import Ctx, api
from ..optim import AdamWConfig
from ..runtime import SupervisorConfig, run_supervised, straggler_report


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--production", action="store_true",
                    help="use the production mesh (requires real devices)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--d-model", type=int, default=None,
                    help="override width (e.g. ~100M model)")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--vocab", type=int, default=None)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    upd = {}
    if args.d_model:
        upd["d_model"] = args.d_model
        upd["d_ff"] = args.d_model * 3
        upd["num_heads"] = max(2, args.d_model // 64)
        upd["num_kv_heads"] = max(1, args.d_model // 128)
        upd["head_dim"] = 64
    if args.layers:
        upd["num_layers"] = args.layers
    if args.vocab:
        upd["vocab_size"] = args.vocab
    if upd:
        cfg = dataclasses.replace(cfg, **upd)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps, warmup_steps=args.steps // 10)

    data = SyntheticTokens(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch)
    )
    ctx = Ctx(cfg=cfg)

    def build():
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        opt = api.init_opt(cfg, params, opt_cfg)
        n_params = sum(x.size for x in jax.tree.leaves(params))
        print(f"arch={cfg.name} params={n_params/1e6:.1f}M")

        def step_fn(params, opt_state, batch):
            return api.train_step(ctx, params, opt_state, batch, opt_cfg)

        return params, opt, jax.jit(step_fn, donate_argnums=(0, 1))

    def data_for_step(step: int) -> dict:
        batch = data.jax_batch(step)
        if cfg.family == "encdec":
            key = jax.random.PRNGKey(step)
            batch["frames"] = jax.random.normal(
                key, (args.batch, cfg.encoder_frames, cfg.d_model), jnp.float32
            )
        if cfg.family == "vlm":
            key = jax.random.PRNGKey(step)
            batch["patches"] = jax.random.normal(
                key, (args.batch, cfg.num_patches, cfg.d_model), jnp.float32
            )
        return batch

    sup = SupervisorConfig(
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        total_steps=args.steps,
    )
    result = run_supervised(
        sup, build=build, data_for_step=data_for_step, fail_at=args.fail_at
    )
    first = sum(result.losses[:5]) / max(len(result.losses[:5]), 1)
    last = sum(result.losses[-5:]) / max(len(result.losses[-5:]), 1)
    print(
        f"done: steps={result.final_step + 1} restarts={result.restarts} "
        f"loss {first:.3f} -> {last:.3f}"
    )
    print("stragglers:", straggler_report(result.step_times))


if __name__ == "__main__":
    main()
