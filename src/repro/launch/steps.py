"""Builds sharded, jit-ready train/serve steps for an (arch x shape x mesh)
cell: resolves logical param/cache specs to NamedShardings and wires the
donation/jit boundaries. Used by dryrun.py, train.py, and serve.py."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import api
from ..models.config import ModelConfig
from ..models.layers import Ctx
from ..models.sharding import Rules, make_rules
from ..optim import AdamWConfig


def _resolve(rules: Rules, logical) -> P:
    """Logical axis tuple -> PartitionSpec (tuples are spec leaves)."""
    if logical is None:
        return P()
    if isinstance(logical, tuple):
        return rules.spec(*logical)
    return rules.spec(logical)


def _is_spec_leaf(x) -> bool:
    """A logical-spec leaf is None or a plain tuple of axis names — NOT a
    NamedTuple container (e.g. KVCaches of specs)."""
    if x is None:
        return True
    return (
        isinstance(x, tuple)
        and not hasattr(x, "_fields")
        and all(e is None or isinstance(e, str) for e in x)
    )


def _tree_specs(rules: Rules, logical_tree) -> Any:
    return jax.tree.map(
        lambda leaf: _resolve(rules, leaf), logical_tree, is_leaf=_is_spec_leaf
    )


def _shardings(mesh: Mesh, spec_tree) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


@dataclasses.dataclass
class CellPrograms:
    """Jit-wrapped (not yet lowered) programs + shardings for one cell."""

    ctx: Ctx
    rules: Rules
    param_sharding: Any
    batch_sharding: Any | None = None
    opt_sharding: Any | None = None
    state_sharding: Any | None = None
    step: Any = None  # the jit function
    abstract_inputs: Any = None  # kwargs for .lower()


def _batch_specs(cfg: ModelConfig, rules: Rules, batch_tree) -> Any:
    def spec(leaf):
        nd = len(leaf.shape)
        return rules.spec("batch", *([None] * (nd - 1)))

    return jax.tree.map(spec, batch_tree)


# per-arch microbatch counts: gradient accumulation for cells whose
# activations exceed HBM at the full per-device batch (see EXPERIMENTS.md)
MICROBATCHES = {"mixtral-8x22b": 4, "zamba2-2.7b": 2}


def build_train_programs(
    cfg: ModelConfig, mesh: Mesh, shape, opt_cfg: AdamWConfig | None = None,
    microbatches: int | None = None,
) -> CellPrograms:
    opt_cfg = opt_cfg or AdamWConfig()
    mb = microbatches or MICROBATCHES.get(cfg.name, 1)
    rules = make_rules(
        mesh, num_experts=cfg.num_experts, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, vocab_size=cfg.vocab_size, seq_shard=True,
    )
    ctx = Ctx(cfg=cfg, mesh=mesh, rules=rules)
    pspec = _tree_specs(rules, api.param_specs(cfg))
    psh = _shardings(mesh, pspec)

    params_abs = api.abstract_params(cfg)
    opt_abs = jax.eval_shape(lambda: api.init_opt(cfg, params_abs, opt_cfg))
    # mu/nu/ef mirror params; step is replicated
    opt_sh = jax.tree.map(lambda _: None, opt_abs)
    from ..optim import AdamWState

    opt_sh = AdamWState(
        step=NamedSharding(mesh, P()),
        mu=psh, nu=psh,
        ef_residual=psh if opt_cfg.compress_grads else None,
    )
    batch_abs = api.input_specs(cfg, "train", shape.seq_len, shape.global_batch)
    bsh = _shardings(mesh, _batch_specs(cfg, rules, batch_abs))

    def step(params, opt_state, batch):
        return api.train_step(ctx, params, opt_state, batch, opt_cfg, microbatches=mb)

    fn = jax.jit(
        step,
        in_shardings=(psh, opt_sh, bsh),
        out_shardings=(psh, opt_sh, None),
        donate_argnums=(0, 1),
    )
    return CellPrograms(
        ctx=ctx, rules=rules, param_sharding=psh, batch_sharding=bsh,
        opt_sharding=opt_sh, step=fn,
        abstract_inputs=(params_abs, opt_abs, batch_abs),
    )


def build_prefill_programs(cfg: ModelConfig, mesh: Mesh, shape) -> CellPrograms:
    rules = make_rules(
        mesh, num_experts=cfg.num_experts, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, vocab_size=cfg.vocab_size, seq_shard=True,
    )
    ctx = Ctx(cfg=cfg, mesh=mesh, rules=rules)
    psh = _shardings(mesh, _tree_specs(rules, api.param_specs(cfg)))
    params_abs = api.abstract_params(cfg)
    batch_abs = api.input_specs(cfg, "prefill", shape.seq_len, shape.global_batch)
    bsh = _shardings(mesh, _batch_specs(cfg, rules, batch_abs))
    state_sh = _shardings(mesh, _tree_specs(rules, api.decode_state_specs(cfg)))

    def prefill_step(params, batch):
        tokens = batch["tokens"]
        return api.prefill(ctx, params, tokens, max_len=shape.seq_len, batch=batch)

    fn = jax.jit(
        prefill_step,
        in_shardings=(psh, bsh),
        out_shardings=(None, state_sh),
    )
    return CellPrograms(
        ctx=ctx, rules=rules, param_sharding=psh, batch_sharding=bsh,
        state_sharding=state_sh, step=fn, abstract_inputs=(params_abs, batch_abs),
    )


def build_decode_programs(cfg: ModelConfig, mesh: Mesh, shape) -> CellPrograms:
    long_ctx = shape.global_batch < mesh.shape["data"]  # batch can't fill DP
    rules = make_rules(
        mesh, num_experts=cfg.num_experts, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, vocab_size=cfg.vocab_size,
        long_context=long_ctx,
    )
    if long_ctx:
        rules = dataclasses.replace(rules, batch=None)  # replicate tiny batch
    ctx = Ctx(cfg=cfg, mesh=mesh, rules=rules)
    psh = _shardings(mesh, _tree_specs(rules, api.param_specs(cfg)))
    params_abs = api.abstract_params(cfg)
    inputs = api.input_specs(cfg, "decode", shape.seq_len, shape.global_batch)
    state_sh = _shardings(mesh, _tree_specs(rules, api.decode_state_specs(cfg)))
    tok_sh = NamedSharding(mesh, rules.spec("batch", None))

    def decode(params, token, state):
        return api.decode_step(ctx, params, token, state)

    fn = jax.jit(
        decode,
        in_shardings=(psh, tok_sh, state_sh),
        out_shardings=(None, state_sh),
        donate_argnums=(2,),
    )
    return CellPrograms(
        ctx=ctx, rules=rules, param_sharding=psh, state_sharding=state_sh,
        step=fn, abstract_inputs=(params_abs, inputs["token"], inputs["state"]),
    )


def build_programs(cfg: ModelConfig, mesh: Mesh, shape) -> CellPrograms:
    if shape.kind == "train":
        return build_train_programs(cfg, mesh, shape)
    if shape.kind == "prefill":
        return build_prefill_programs(cfg, mesh, shape)
    return build_decode_programs(cfg, mesh, shape)
