"""Production mesh builders (multi-pod dry-run spec).

Functions, not module-level constants: importing this module never touches
jax device state. All builders go through :mod:`repro.compat` so they work
on both current jax and the 0.4.x line.
"""
from __future__ import annotations

import jax

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Whatever this host offers, as a 1-D 'data' mesh (smoke/e2e runs)."""
    return make_mesh((len(jax.devices()),), ("data",))


def make_nodelet_mesh(p: int = 8) -> jax.sharding.Mesh:
    """Emu-like mesh for the core irregular algorithms: one axis of nodelets
    (8 = one Chick node, 64 = the 8-node Chick)."""
    return make_mesh((p,), ("nodelet",))
