"""Architecture registry: --arch <id> -> ModelConfig, plus reduced smoke
configs (same family, tiny dims) for CPU tests."""
from __future__ import annotations

import dataclasses

from ..models.config import ModelConfig
from . import (
    glm4_9b, llama3_2_3b, mistral_nemo_12b, mixtral_8x22b, moonshot_v1_16b_a3b,
    phi3_vision_4_2b, qwen2_7b, rwkv6_3b, serve_moe, whisper_small,
    zamba2_2_7b,
)

ARCHS = {
    "qwen2-7b": qwen2_7b.config,
    "llama3.2-3b": llama3_2_3b.config,
    "mistral-nemo-12b": mistral_nemo_12b.config,
    "glm4-9b": glm4_9b.config,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b.config,
    "mixtral-8x22b": mixtral_8x22b.config,
    "rwkv6-3b": rwkv6_3b.config,
    "whisper-small": whisper_small.config,
    "zamba2-2.7b": zamba2_2_7b.config,
    "phi-3-vision-4.2b": phi3_vision_4_2b.config,
}

# auxiliary configs: resolvable by name but outside the assigned-arch sweep
# registry (ARCHS drives the benchmark matrix; these drive demos/serving)
AUX_CONFIGS = {
    "serve-moe": serve_moe.config,
}


def get_config(arch: str) -> ModelConfig:
    if arch in ARCHS:
        return ARCHS[arch]()
    if arch in AUX_CONFIGS:
        return AUX_CONFIGS[arch]()
    raise KeyError(
        f"unknown arch {arch!r}; choose from "
        f"{sorted([*ARCHS, *AUX_CONFIGS])}"
    )


def reduced_config(arch: str, dtype: str = "float32") -> ModelConfig:
    """Tiny same-family config for CPU smoke tests: few layers, small width,
    few experts, tiny vocab — structure preserved."""
    cfg = get_config(arch)
    hd = 32
    heads = 4
    kv = max(1, min(cfg.num_kv_heads * heads // cfg.num_heads, heads))
    upd: dict = dict(
        num_layers=2, d_model=128, num_heads=heads, num_kv_heads=kv,
        d_ff=256, vocab_size=512, head_dim=hd, dtype=dtype, remat=False,
        ssm_chunk=16,
    )
    if cfg.family == "ssm":  # rwkv: d_model must be a multiple of 64
        upd.update(num_heads=2, num_kv_heads=2, head_dim=64)
    if cfg.family == "hybrid":
        upd.update(num_layers=4, shared_attn_period=2, ssm_state=16, head_dim=32)
    if cfg.is_moe:
        upd.update(num_experts=4, experts_per_token=2, moe_d_ff=64)
    if cfg.family == "encdec":
        upd.update(encoder_layers=2, encoder_frames=16)
    if cfg.family == "vlm":
        upd.update(num_patches=8)
    if cfg.sliding_window:
        upd.update(sliding_window=16)
    return dataclasses.replace(cfg, **upd)
