from .registry import ARCHS, get_config, reduced_config
from .shapes import SHAPES, ShapeSpec, applicable, cells
