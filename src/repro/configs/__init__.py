from .registry import ARCHS, AUX_CONFIGS, get_config, reduced_config
from .shapes import SHAPES, ShapeSpec, applicable, cells
