"""zamba2-2.7b [hybrid]: Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; hf]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid", num_layers=54, d_model=2560,
        num_heads=32, num_kv_heads=32, d_ff=10240, vocab_size=32000,
        head_dim=80, ssm_state=64, ssm_chunk=256, shared_attn_period=6,
    )
