"""rwkv6-3b [ssm]: Finch — data-dependent decay, attention-free
[arXiv:2404.05892; hf]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", family="ssm", num_layers=32, d_model=2560,
        num_heads=40, num_kv_heads=40, d_ff=8960, vocab_size=65536,
        head_dim=64, ssm_chunk=128,
    )
