"""whisper-small [audio]: enc-dec, conv frontend STUB (precomputed frame
embeddings) [arXiv:2212.04356; unverified]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="encdec", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=51865,
        head_dim=64, norm="layernorm", act="gelu", pos_emb="sinusoidal",
        encoder_layers=12, encoder_frames=1500,
    )
