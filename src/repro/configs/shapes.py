"""Assigned input shapes (arch x shape = the 40 dry-run cells)."""
from __future__ import annotations

import dataclasses

from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention: run for SSM/hybrid/SWA archs,
    skip for pure full-attention archs (documented in DESIGN.md §7)."""
    if shape.name == "long_500k":
        sub_quadratic = cfg.family in ("ssm", "hybrid") or cfg.sliding_window
        if not sub_quadratic:
            return False, "pure full-attention arch: O(S) KV per token at 500k"
    return True, ""


def cells(archs: dict) -> list[tuple[str, str]]:
    """All 40 (arch, shape) cells in a stable order."""
    return [(a, s) for a in archs for s in SHAPES]
