"""serve-moe [moe]: compact single-block MoE decode-serving config — the
parameterization behind the engine's ``moe_decode`` op and the serving
walkthrough (DESIGN.md §1g). Dimensions small enough to serve on CPU in
tests and demos; float32 + no remat so served decode is bit-comparable to
the single-process oracle. 8 experts top-2 over up to 8 nodelets (ep
modes need experts % nodelets == 0)."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="serve-moe", family="moe", num_layers=1, d_model=32,
        num_heads=1, num_kv_heads=1, d_ff=64, vocab_size=256, head_dim=32,
        num_experts=8, experts_per_token=2, moe_d_ff=48,
        capacity_factor=1.5, dtype="float32", remat=False,
    )
