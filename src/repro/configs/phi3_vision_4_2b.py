"""phi-3-vision-4.2b [vlm]: phi3-mini backbone + CLIP patch STUB
[hf:microsoft/Phi-3-vision-128k-instruct; hf]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b", family="vlm", num_layers=32, d_model=3072,
        num_heads=32, num_kv_heads=32, d_ff=8192, vocab_size=32064,
        head_dim=96, rope_theta=1e4, num_patches=576,
    )
