"""glm4-9b [dense]: RoPE (partial rotary), GQA kv=2 [hf:THUDM/glm-4-9b; hf]."""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b", family="dense", num_layers=40, d_model=4096,
        num_heads=32, num_kv_heads=2, d_ff=13696, vocab_size=151552,
        head_dim=128, qkv_bias=True, rope_theta=1e4, rope_fraction=0.5,
    )
