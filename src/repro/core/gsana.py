"""GSANA parallel similarity computation (paper §3.3, results §5.3).

Schemes (Alg. 3-5): ``ALL`` spawns one task per non-empty bucket B ∈ QT2 and
compares its vertices against all neighbor buckets B' ∈ QT1.Neig(B);
``PAIR`` spawns one task per ⟨B, B'⟩ pair (finer grain, better balance, more
merge work). Both compute identical top-k results.

Layouts (§3.3.2): ``BLK`` partitions vertices by ID and buckets round-robin
(placement-oblivious); ``HCB`` sorts buckets in Hilbert order and assigns
contiguous runs to nodelets with an edge-balancing pass, co-locating each
vertex (and its metadata) with its bucket.

On TPU the compute is a vmap over tasks; the scheme/layout choice drives the
*placement and traffic model* (modeled makespan + migrations, the paper's
§5.3 metrics) which benchmarks report next to measured wall time.

Similarity σ(u, v) (paper §5.3): degree Δ, vertex type τ, adjacent vertex
types τ_V, adjacent edge types τ_E, vertex attributes C_V — the last three
compare neighborhoods via sorted fixed-width arrays.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .gsana_data import Buckets, VertexSet, neighbor_buckets
from .hilbert import hilbert_order_of_buckets
from .strategies import Layout, Scheme, TrafficStats

NEG = -jnp.inf


# -- σ: the five similarity metrics -------------------------------------------


def _hist(a: jax.Array, vocab: int) -> jax.Array:
    """(..., K) sorted padded (-1) ids -> (..., vocab) multiset histogram.

    TPU-native reformulation (DESIGN.md §2): the Emu walks sorted arrays with
    fine-grained reads; the TPU turns the multiset into a dense histogram
    (one-hot reduce, VPU-aligned) so the intersection becomes an elementwise
    min + reduce.
    """
    oh = jax.nn.one_hot(jnp.where(a >= 0, a, vocab), vocab + 1, dtype=jnp.float32)
    return oh.sum(axis=-2)[..., :vocab]


def _overlap(a: jax.Array, b: jax.Array, vocab: int) -> jax.Array:
    """Multiset overlap |a ∩ b| / max(|a|, |b|) of sorted padded arrays.

    a: (A, Ka), b: (B, Kb) -> (A, B).
    """
    ha = _hist(a, vocab)  # (A, T)
    hb = _hist(b, vocab)  # (B, T)
    inter = jnp.minimum(ha[:, None, :], hb[None, :, :]).sum(-1)
    na = (a >= 0).sum(-1).astype(jnp.float32)
    nb = (b >= 0).sum(-1).astype(jnp.float32)
    denom = jnp.maximum(jnp.maximum(na[:, None], nb[None, :]), 1.0)
    return inter / denom


# vocab sizes (n_types, n_etypes, n_attr_vocab) for the histogram overlap;
# must cover the generator's vocabularies (gsana_data defaults: 8, 6, 64).
DEFAULT_VOCAB = (16, 16, 64)


def similarity_block(
    vs2: VertexSet, vs1: VertexSet, v_idx: jax.Array, u_idx: jax.Array,
    vocab: tuple[int, int, int] = DEFAULT_VOCAB,
) -> jax.Array:
    """σ for all pairs (v ∈ v_idx from G2) x (u ∈ u_idx from G1).

    v_idx: (A,) int32 (-1 pad), u_idx: (B,) int32 (-1 pad) -> (A, B) scores,
    -inf on padded slots.
    """
    vi = jnp.maximum(v_idx, 0)
    ui = jnp.maximum(u_idx, 0)
    dv = vs2.deg[vi].astype(jnp.float32)
    du = vs1.deg[ui].astype(jnp.float32)
    s_deg = 1.0 / (1.0 + jnp.abs(dv[:, None] - du[None, :]))  # Δ
    s_typ = (vs2.vtype[vi][:, None] == vs1.vtype[ui][None, :]).astype(jnp.float32)  # τ
    s_nt = _overlap(vs2.ntypes[vi], vs1.ntypes[ui], vocab[0])  # τ_V
    s_et = _overlap(vs2.etypes[vi], vs1.etypes[ui], vocab[1])  # τ_E
    s_at = _overlap(vs2.attrs[vi], vs1.attrs[ui], vocab[2])  # C_V
    score = 0.2 * (s_deg + s_typ + s_nt + s_et + s_at)
    valid = (v_idx >= 0)[:, None] & (u_idx >= 0)[None, :]
    return jnp.where(valid, score, NEG)


# -- parallel similarity computation (ALL / PAIR) ------------------------------
#
# Per-task closures are shared by the local (vmap over all tasks) and mesh
# (shard_map over per-nodelet task slices) substrates, so both produce
# bit-identical numbers — only the execution placement differs.


def _all_task(vs1: VertexSet, vs2: VertexSet, b1: Buckets, b2: Buckets, nb, k: int):
    """One ALL task (Alg. 3+4): bucket B ∈ QT2 vs all its neighbor buckets."""
    cap1 = b1.cap

    def task(bid):
        v_idx = b2.vid[bid]  # (cap2,)
        nbs = nb[bid]  # (9,)
        u_idx = jnp.where(nbs[:, None] >= 0, b1.vid[jnp.maximum(nbs, 0)], -1)
        u_idx = u_idx.reshape(9 * cap1)
        s = similarity_block(vs2, vs1, v_idx, u_idx)  # (cap2, 9*cap1)
        sc, loc = jax.lax.top_k(s, k)
        return jnp.where(sc > NEG, u_idx[loc], -1), sc

    return task


def _pair_task(vs1: VertexSet, vs2: VertexSet, b1: Buckets, b2: Buckets, nb, kk: int):
    """One PAIR task (Alg. 3+5): a single ⟨B, B'⟩ bucket pair."""

    def task(bid, j):
        v_idx = b2.vid[bid]
        nbs = nb[bid, j]
        u_idx = jnp.where(nbs >= 0, b1.vid[jnp.maximum(nbs, 0)], -1)
        s = similarity_block(vs2, vs1, v_idx, u_idx)  # (cap2, cap1)
        sc, loc = jax.lax.top_k(s, kk)
        return jnp.where(sc > NEG, u_idx[loc], -1), sc

    return task


def _merge_pair_topk(cands, scores, grid2: int, k: int):
    """Alg. 5's Merge: per-pair top-k lists -> per-bucket top-k."""
    kk = scores.shape[-1]
    cands = cands.reshape(grid2, 9, -1, kk).transpose(0, 2, 1, 3).reshape(grid2, -1, 9 * kk)
    scores = scores.reshape(grid2, 9, -1, kk).transpose(0, 2, 1, 3).reshape(grid2, -1, 9 * kk)
    sc, loc = jax.lax.top_k(scores, k)  # merge
    cand = jnp.take_along_axis(cands, loc, axis=-1)
    return jnp.where(sc > NEG, cand, -1), sc


@partial(jax.jit, static_argnames=("k",))
def compute_similarity_all(
    vs1: VertexSet, vs2: VertexSet, b1: Buckets, b2: Buckets, nb: jax.Array, k: int
):
    """ALL scheme: one task per bucket B ∈ QT2.

    Returns (cand (G², cap, k) global u ids, score (G², cap, k)).
    """
    task = _all_task(vs1, vs2, b1, b2, nb, k)
    return jax.vmap(task)(jnp.arange(b2.grid * b2.grid))


@partial(jax.jit, static_argnames=("k",))
def compute_similarity_pair(
    vs1: VertexSet, vs2: VertexSet, b1: Buckets, b2: Buckets, nb: jax.Array, k: int
):
    """PAIR scheme: one task per ⟨B, B'⟩ bucket pair + merge. Same results
    as ALL."""
    kk = min(k, b1.cap)  # per-pair priority-list width (Alg. 5)
    task = _pair_task(vs1, vs2, b1, b2, nb, kk)
    grid2 = b2.grid * b2.grid
    bids = jnp.repeat(jnp.arange(grid2), 9)
    js = jnp.tile(jnp.arange(9), grid2)
    cands, scores = jax.vmap(task)(bids, js)  # (G²*9, cap2, kk)
    return _merge_pair_topk(cands, scores, grid2, k)


def _scatter_vertex_major(cand_b, score_b, b2: Buckets, n2: int, k: int):
    """Bucket-major (G², cap, k) results -> per-vertex (n2, k) arrays."""
    vid = b2.vid.reshape(-1)
    ok = vid >= 0
    cand = jnp.zeros((n2, k), dtype=jnp.int32).at[jnp.where(ok, vid, 0)].set(
        jnp.where(ok[:, None], cand_b.reshape(-1, k), 0), mode="drop"
    )
    score = jnp.full((n2, k), NEG).at[jnp.where(ok, vid, 0)].set(
        jnp.where(ok[:, None], score_b.reshape(-1, k), NEG), mode="drop"
    )
    return cand, score


def compute_similarity(
    vs1: VertexSet, vs2: VertexSet, b1: Buckets, b2: Buckets, k: int = 4,
    scheme: Scheme = Scheme.PAIR,
):
    """``local`` substrate: top-k alignment candidates for every v ∈ V2.
    Returns per-vertex arrays (n2, k) cand / score."""
    nb = jnp.asarray(neighbor_buckets(b2.grid))
    if scheme == Scheme.ALL:
        cand_b, score_b = compute_similarity_all(vs1, vs2, b1, b2, nb, k)
    else:
        cand_b, score_b = compute_similarity_pair(vs1, vs2, b1, b2, nb, k)
    return _scatter_vertex_major(cand_b, score_b, b2, vs2.n, k)


def compute_similarity_mesh(
    vs1: VertexSet, vs2: VertexSet, b1: Buckets, b2: Buckets, k: int = 4,
    scheme: Scheme = Scheme.PAIR, *, mesh: jax.sharding.Mesh,
    axis_name: str = "nodelet",
):
    """``mesh`` substrate: the same task set sharded over ``axis_name``.

    Bucket metadata is replicated (the shared QT plane); each nodelet runs
    its slice of the task list — compute moves to tasks, which is why the
    scheme/layout choice shows up in the *traffic model*, not in collectives.
    Tasks are padded to a multiple of the axis size with repeats of task 0
    (sliced off afterwards). Results are bit-identical to the local substrate.
    """
    from jax.sharding import PartitionSpec as P_

    from ..compat import shard_map
    from .util import round_up

    nb = jnp.asarray(neighbor_buckets(b2.grid))
    p = mesh.shape[axis_name]
    grid2 = b2.grid * b2.grid
    if scheme == Scheme.ALL:
        task = _all_task(vs1, vs2, b1, b2, nb, k)
        n_tasks = round_up(grid2, p)
        ids = jnp.minimum(jnp.arange(n_tasks, dtype=jnp.int32), grid2 - 1)
        f = shard_map(
            lambda s: jax.vmap(task)(s), mesh, in_specs=P_(axis_name),
            out_specs=P_(axis_name),
        )
        cand_b, score_b = f(ids)
        cand_b, score_b = cand_b[:grid2], score_b[:grid2]
    else:
        kk = min(k, b1.cap)
        task = _pair_task(vs1, vs2, b1, b2, nb, kk)
        n_pairs = grid2 * 9
        pad = round_up(n_pairs, p) - n_pairs
        bids = jnp.pad(jnp.repeat(jnp.arange(grid2), 9), (0, pad))
        js = jnp.pad(jnp.tile(jnp.arange(9), grid2), (0, pad))
        f = shard_map(
            lambda b, j: jax.vmap(task)(b, j), mesh,
            in_specs=(P_(axis_name), P_(axis_name)), out_specs=P_(axis_name),
        )
        cands, scores = f(bids, js)
        cand_b, score_b = _merge_pair_topk(cands[:n_pairs], scores[:n_pairs], grid2, k)
    return _scatter_vertex_major(cand_b, score_b, b2, vs2.n, k)


def recall_at_k(cand: jax.Array, pi: np.ndarray) -> float:
    """Fraction of v ∈ V2 whose ground-truth partner is among its candidates."""
    truth = np.empty(len(pi), dtype=np.int64)  # truth[v2] = v1
    truth[pi] = np.arange(len(pi))
    hits = (np.asarray(cand) == truth[:, None]).any(axis=1)
    return float(hits.mean())


# -- layouts (BLK / HCB) and the placement/traffic model ----------------------


@dataclasses.dataclass(frozen=True)
class Placement:
    bucket_owner: np.ndarray  # (G²,) nodelet of each bucket (shared plane)
    vertex_owner1: np.ndarray  # (n1,)
    vertex_owner2: np.ndarray  # (n2,)


def layout_blk(b1: Buckets, b2: Buckets, n1: int, n2: int, p: int) -> Placement:
    """BLK: vertices by ID blocks, buckets round-robin — placement-oblivious."""
    grid2 = b1.grid * b1.grid
    return Placement(
        bucket_owner=np.arange(grid2) % p,
        vertex_owner1=(np.arange(n1) * p) // max(n1, 1),
        vertex_owner2=(np.arange(n2) * p) // max(n2, 1),
    )


def layout_hcb(b1: Buckets, b2: Buckets, p: int) -> Placement:
    """HCB: buckets in Hilbert order, contiguous runs per nodelet, balanced by
    estimated comparison load (the paper's edges-per-nodelet balancing)."""
    grid = b1.grid
    ranks = hilbert_order_of_buckets(grid)  # bucket -> hilbert rank
    order = np.argsort(ranks)  # rank -> bucket id
    nb = neighbor_buckets(grid)
    c1 = np.asarray(b1.count, dtype=np.int64)
    c2 = np.asarray(b2.count, dtype=np.int64)
    load = np.zeros(grid * grid, dtype=np.int64)
    for b in range(grid * grid):
        ns = nb[b]
        load[b] = c2[b] * c1[ns[ns >= 0]].sum()
    # greedy prefix split of the Hilbert sequence into p balanced segments
    total = load[order].sum()
    target = max(total / p, 1)
    owner = np.zeros(grid * grid, dtype=np.int64)
    acc, seg = 0, 0
    for rank_pos, b in enumerate(order):
        owner[b] = seg
        acc += load[b]
        if acc >= target * (seg + 1) and seg < p - 1:
            seg += 1
    vid1 = np.asarray(b1.vid)
    vid2 = np.asarray(b2.vid)
    n1 = int(vid1.max()) + 1 if (vid1 >= 0).any() else 0
    n2 = int(vid2.max()) + 1 if (vid2 >= 0).any() else 0
    vo1 = np.zeros(n1, dtype=np.int64)
    vo2 = np.zeros(n2, dtype=np.int64)
    for b in range(grid * grid):
        vs = vid1[b][vid1[b] >= 0]
        vo1[vs] = owner[b]
        vs = vid2[b][vid2[b] >= 0]
        vo2[vs] = owner[b]
    return Placement(bucket_owner=owner, vertex_owner1=vo1, vertex_owner2=vo2)


@dataclasses.dataclass
class PlanStats:
    """Modeled execution statistics for a (layout x scheme) configuration."""

    total_comparisons: int
    makespan: float  # modeled parallel time (comparison units)
    speedup_model: float  # total / makespan
    traffic: TrafficStats
    rw_total: int  # paper's Σ RW(σ(u,v)) read/write volume (words)


def rw_sigma(deg_u: np.ndarray, deg_v: np.ndarray, ka_u: np.ndarray, ka_v: np.ndarray):
    """Paper §5.3: RW(σ) = RW(τ)+RW(Δ)+RW(τ_V)+RW(τ_E)+RW(C_V)
    = 4 + 4 + (|N(u)|+|N(v)|+2) + (|N(u)|+|N(v)|+2) + (|A(u)|+|A(v)|+2)."""
    return 8 + 2 * (deg_u + deg_v + 2) + (ka_u + ka_v + 2)


def plan_stats(
    vs1: VertexSet, vs2: VertexSet, b1: Buckets, b2: Buckets,
    placement: Placement, scheme: Scheme, p: int, threads_per_nodelet: int = 64,
    migration_penalty: float = 0.3,
) -> PlanStats:
    """Replay the task schedule in numpy with the paper's cost model.

    Task cost = comparisons (+ penalty per remote-side read); tasks run on the
    owner nodelet of their QT2 bucket; within a nodelet, tasks are spread
    LPT-greedily over its worker threads. Makespan = max worker finish time.
    """
    grid = b2.grid
    nb = neighbor_buckets(grid)
    c1 = np.asarray(b1.count, dtype=np.int64)
    c2 = np.asarray(b2.count, dtype=np.int64)
    deg1 = np.asarray(vs1.deg, dtype=np.int64)
    deg2 = np.asarray(vs2.deg, dtype=np.int64)
    na1 = (np.asarray(vs1.attrs) >= 0).sum(axis=1)
    na2 = (np.asarray(vs2.attrs) >= 0).sum(axis=1)
    vid1 = np.asarray(b1.vid)
    vid2 = np.asarray(b2.vid)

    tasks: list[tuple[int, float]] = []  # (nodelet, cost)
    migrations = 0
    rw_total = 0
    total_cmp = 0
    for b in range(grid * grid):
        if c2[b] == 0:
            continue
        home = int(placement.bucket_owner[b])
        v_ids = vid2[b][vid2[b] >= 0]
        v_remote = (placement.vertex_owner2[v_ids] != home).sum()
        pair_costs = []
        for bp in nb[b]:
            if bp < 0 or c1[bp] == 0:
                continue
            u_ids = vid1[bp][vid1[bp] >= 0]
            cmp_count = len(v_ids) * len(u_ids)
            total_cmp += cmp_count
            rw = rw_sigma(
                deg1[u_ids][None, :], deg2[v_ids][:, None],
                na1[u_ids][None, :], na2[v_ids][:, None],
            ).sum()
            rw_total += int(rw)
            u_remote = (placement.vertex_owner1[u_ids] != home).sum()
            # each comparison touching a remote-side vertex migrates there+back
            mig = len(v_ids) * int(u_remote) + int(v_remote) * len(u_ids)
            migrations += mig
            cost = cmp_count + migration_penalty * mig
            pair_costs.append(cost)
        if not pair_costs:
            continue
        if scheme == Scheme.ALL:
            tasks.append((home, float(sum(pair_costs))))
        else:
            tasks.extend((home, float(cs)) for cs in pair_costs)

    # LPT within each nodelet's thread pool
    finish = np.zeros((p, threads_per_nodelet))
    for home, cost in sorted(tasks, key=lambda t: -t[1]):
        w = int(np.argmin(finish[home]))
        finish[home, w] += cost
    makespan = float(finish.max()) if tasks else 0.0
    total_cost = float(sum(c for _, c in tasks))
    return PlanStats(
        total_comparisons=total_cmp,
        makespan=max(makespan, 1e-9),
        speedup_model=total_cost / max(makespan, 1e-9),
        traffic=TrafficStats(migrations=int(migrations)),
        rw_total=int(rw_total),
    )


def gsana_rw_bytes(
    vs1: VertexSet, vs2: VertexSet, b1: Buckets, b2: Buckets,
    word_bytes: int = 8,
) -> int:
    """Paper §5.3 useful-work volume: Σ_tasks (|B| + |B||B'| + ΣΣ RW(σ)) × sizeof(u)."""
    grid = b2.grid
    nb = neighbor_buckets(grid)
    c1 = np.asarray(b1.count, dtype=np.int64)
    c2 = np.asarray(b2.count, dtype=np.int64)
    deg1 = np.asarray(vs1.deg, dtype=np.int64)
    deg2 = np.asarray(vs2.deg, dtype=np.int64)
    na1 = (np.asarray(vs1.attrs) >= 0).sum(axis=1)
    na2 = (np.asarray(vs2.attrs) >= 0).sum(axis=1)
    vid1 = np.asarray(b1.vid)
    vid2 = np.asarray(b2.vid)
    words = 0
    for b in range(grid * grid):
        if c2[b] == 0:
            continue
        v_ids = vid2[b][vid2[b] >= 0]
        for bp in nb[b]:
            if bp < 0 or c1[bp] == 0:
                continue
            u_ids = vid1[bp][vid1[bp] >= 0]
            rw = rw_sigma(
                deg1[u_ids][None, :], deg2[v_ids][:, None],
                na1[u_ids][None, :], na2[v_ids][:, None],
            ).sum()
            words += int(c2[b]) + int(c2[b]) * int(c1[bp]) + int(rw)
    return words * word_bytes


def gsana_effective_bw(
    vs1: VertexSet, vs2: VertexSet, b1: Buckets, b2: Buckets, seconds: float,
    word_bytes: int = 8,
) -> float:
    """Paper §5.3 bandwidth: the RW-model volume over wall time."""
    return gsana_rw_bytes(vs1, vs2, b1, b2, word_bytes) / max(seconds, 1e-12)
