"""The paper's primary contribution: the three Emu programming strategies
(S1 replication, S2 remote writes, S3 locality layout) as composable,
strategy-configurable distributed operators."""
from .strategies import (
    CONTEXT_BYTES,
    WRITE_PACKET_BYTES,
    Comm,
    Layout,
    MigratoryStrategy,
    Scheme,
    TrafficStats,
)
from .spmv import (
    PartitionedELL,
    effective_bandwidth,
    gather_result,
    partition_ell,
    spmv,
    spmv_traffic,
    stripe_vector,
    unstripe_vector,
)
from .bfs import (
    BFSRunStats,
    bfs,
    bfs_effective_bandwidth,
    bfs_traffic,
    teps,
    validate_parents,
)
from .gsana import (
    Placement,
    PlanStats,
    compute_similarity,
    gsana_effective_bw,
    layout_blk,
    layout_hcb,
    plan_stats,
    recall_at_k,
)
from .gsana_data import (
    Buckets,
    VertexSet,
    bucketize,
    generate_alignment_pair,
    neighbor_buckets,
    pick_grid,
)
from .hilbert import d_to_xy, hilbert_order_of_buckets, xy_to_d
