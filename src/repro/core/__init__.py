"""The paper's primary contribution: the three Emu programming strategies
(S1 replication, S2 remote writes, S3 locality layout) as composable,
strategy-configurable distributed operators."""
from .strategies import (
    CONTEXT_BYTES,
    WRITE_PACKET_BYTES,
    Comm,
    Layout,
    MigratoryStrategy,
    Scheme,
    TrafficStats,
    strategy_grid,
)
from .cost import CostEstimate, cost_model_for
from .util import ceil_div, round_up
from .spmv import (
    PartitionedELL,
    effective_bandwidth,
    gather_result,
    partition_ell,
    spmv,
    spmv_bytes_moved,
    spmv_local,
    spmv_mesh,
    spmv_traffic,
    stripe_vector,
    unstripe_vector,
)
from .bfs import (
    BFSRunStats,
    bfs,
    bfs_bytes_moved,
    bfs_effective_bandwidth,
    bfs_local,
    bfs_mesh,
    bfs_traffic,
    teps,
    validate_parents,
)
from .gsana import (
    Placement,
    PlanStats,
    compute_similarity,
    compute_similarity_mesh,
    gsana_effective_bw,
    gsana_rw_bytes,
    layout_blk,
    layout_hcb,
    plan_stats,
    recall_at_k,
)
from .gsana_data import (
    Buckets,
    VertexSet,
    bucketize,
    generate_alignment_pair,
    neighbor_buckets,
    pick_grid,
)
from .hilbert import d_to_xy, hilbert_order_of_buckets, xy_to_d
