"""The paper's three programming strategies as a first-class config.

S1 ``replicate_x``  — replicate read-hot dense operands (paper §5.1)
S2 ``comm``         — ``migrate`` (pull/gather, Alg. 1) vs ``remote_write``
                      (push/scatter with commutative merge, Alg. 2)
S3 ``layout``       — ``blk`` (ID-blocked) vs ``hcb`` (Hilbert-curve bucket)
                      placement (paper §3.3.2)
``grain``           — rows/work-items per task; ``None`` = dynamic grain
                      (paper Fig. 4's lesson)

Every distributed op in the framework (core SpMV/BFS/GSANA, and the LM
stack's MoE dispatch + embedding) accepts a :class:`MigratoryStrategy`.
"""
from __future__ import annotations

import dataclasses
import enum


class Comm(str, enum.Enum):
    MIGRATE = "migrate"  # pull: move the reader to the data (Emu) / gather (TPU)
    REMOTE_WRITE = "remote_write"  # push: one-sided writes + local commit phase


class Layout(str, enum.Enum):
    BLK = "blk"  # block/striped by id, placement-oblivious
    HCB = "hcb"  # Hilbert-curve-based locality + load-balanced placement


class Scheme(str, enum.Enum):
    """GSANA task granularity (paper §3.3.1)."""

    ALL = "all"  # one task per bucket (coarse, imbalance-prone)
    PAIR = "pair"  # one task per bucket pair (fine, balanced)


@dataclasses.dataclass(frozen=True)
class MigratoryStrategy:
    comm: Comm = Comm.REMOTE_WRITE
    replicate_x: bool = True
    layout: Layout = Layout.HCB
    scheme: Scheme = Scheme.PAIR
    grain: int | None = None  # None => dynamic grain

    def dynamic_grain(self, n_rows: int, target_tasks: int = 512) -> int:
        """Paper Fig. 4: fixed grain 16 does not scale; pick grain so the
        task count saturates (but does not swamp) the machine."""
        if self.grain is not None:
            return self.grain
        return max(1, n_rows // target_tasks)

    def cache_key(self) -> tuple:
        """Hashable identity of the strategy — part of the compiled-plan
        cache key (engine/cache.py): two runs share an executor only if every
        strategy axis matches."""
        return (self.comm.value, self.replicate_x, self.layout.value,
                self.scheme.value, self.grain)


def strategy_grid(
    comms: tuple[Comm, ...] = (Comm.MIGRATE, Comm.REMOTE_WRITE),
    replicates: tuple[bool, ...] = (True, False),
    layouts: tuple[Layout, ...] = (Layout.BLK, Layout.HCB),
    schemes: tuple[Scheme, ...] = (Scheme.ALL, Scheme.PAIR),
    grains: tuple[int | None, ...] = (None,),
) -> list[MigratoryStrategy]:
    """The full S1 x S2 x S3 x grain candidate cross product, in a
    deterministic order (the autotuner's search space)."""
    return [
        MigratoryStrategy(comm=c, replicate_x=r, layout=l, scheme=s, grain=g)
        for c in comms for r in replicates for l in layouts for s in schemes
        for g in grains
    ]


# -- traffic model ------------------------------------------------------------
# The Emu cost model used by benchmarks to report the paper's metrics on
# non-Emu hardware: a migration moves a thread context (<200 B, §2); a remote
# write is a small packet (§5.2 "smaller size of remote write packets").
CONTEXT_BYTES = 200
WRITE_PACKET_BYTES = 16


@dataclasses.dataclass
class TrafficStats:
    """Modeled communication traffic (the paper's migration-count lens)."""

    migrations: int = 0
    remote_writes: int = 0
    collective_bytes: int = 0  # TPU-side: bytes moved by collectives

    @property
    def migration_bytes(self) -> int:
        return self.migrations * CONTEXT_BYTES

    @property
    def remote_write_bytes(self) -> int:
        return self.remote_writes * WRITE_PACKET_BYTES

    @property
    def total_bytes(self) -> int:
        return self.migration_bytes + self.remote_write_bytes + self.collective_bytes

    def __add__(self, o: "TrafficStats") -> "TrafficStats":
        return TrafficStats(
            self.migrations + o.migrations,
            self.remote_writes + o.remote_writes,
            self.collective_bytes + o.collective_bytes,
        )
