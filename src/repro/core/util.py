"""Small shared integer helpers for padding/partitioning arithmetic."""
from __future__ import annotations


def ceil_div(a: int, b: int) -> int:
    """ceil(a / b) for non-negative ints (b > 0)."""
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    """Smallest multiple of ``b`` that is >= ``a`` (b > 0)."""
    return ceil_div(a, b) * b
