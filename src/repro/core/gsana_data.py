"""GSANA alignment-problem substrate: vertex metadata, 2-D placement,
quadtree-leaf (grid) bucketization, and a DBLP-like pair generator.

Paper §3.3: GSANA places vertices on a 2-D plane from global structure; we
generate pairs with a latent ground-truth placement (corresponding vertices
land near each other, as GSANA's structural embedding achieves on DBLP).
Vertex metadata (types / neighbor types / edge types / attributes) is stored
in **sorted fixed-width arrays** — exactly the paper's "metadata of a vertex's
neighborhood in sorted arrays" regularization, padded with -1 for the TPU.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class VertexSet:
    """One graph's vertices + metadata used by the similarity function σ."""

    pos: jax.Array  # (n, 2) float32 in [0,1)^2
    deg: jax.Array  # (n,) int32
    vtype: jax.Array  # (n,) int32
    ntypes: jax.Array  # (n, Kn) int32 sorted asc, -1 pad — adjacent vertex types
    etypes: jax.Array  # (n, Ke) int32 sorted asc, -1 pad — adjacent edge types
    attrs: jax.Array  # (n, Ka) int32 sorted asc, -1 pad — vertex attributes

    def tree_flatten(self):
        return (self.pos, self.deg, self.vtype, self.ntypes, self.etypes, self.attrs), None

    @classmethod
    def tree_unflatten(cls, _, leaves):
        return cls(*leaves)

    @property
    def n(self) -> int:
        return self.pos.shape[0]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Buckets:
    """Grid bucketization (uniform-depth quadtree leaves, DESIGN.md §3)."""

    vid: jax.Array  # (grid*grid, cap) int32 vertex ids, -1 pad
    count: jax.Array  # (grid*grid,) int32
    grid: int  # static, power of two

    def tree_flatten(self):
        return (self.vid, self.count), self.grid

    @classmethod
    def tree_unflatten(cls, grid, leaves):
        return cls(*leaves, grid=grid)

    @property
    def cap(self) -> int:
        return self.vid.shape[1]


def _pad_sorted(rows: list[np.ndarray], width: int) -> np.ndarray:
    out = np.full((len(rows), width), -1, dtype=np.int32)
    for i, r in enumerate(rows):
        r = np.sort(np.asarray(r, dtype=np.int32))[:width]
        out[i, : len(r)] = r
    return out


def _metadata_from_edges(
    n: int, edges: np.ndarray, vtype: np.ndarray, etype: np.ndarray,
    attrs_list: list[np.ndarray], kn: int, ke: int, ka: int,
) -> dict[str, np.ndarray]:
    nbr: list[list[int]] = [[] for _ in range(n)]
    nbe: list[list[int]] = [[] for _ in range(n)]
    for (u, v), t in zip(edges, etype):
        nbr[u].append(vtype[v])
        nbr[v].append(vtype[u])
        nbe[u].append(t)
        nbe[v].append(t)
    deg = np.array([len(x) for x in nbr], dtype=np.int32)
    return dict(
        deg=deg,
        ntypes=_pad_sorted([np.array(x) for x in nbr], kn),
        etypes=_pad_sorted([np.array(x) for x in nbe], ke),
        attrs=_pad_sorted(attrs_list, ka),
    )


def generate_alignment_pair(
    n: int,
    avg_deg: float = 6.0,
    n_types: int = 8,
    n_etypes: int = 6,
    n_attr_vocab: int = 64,
    kn: int = 16,
    ke: int = 16,
    ka: int = 8,
    drop_frac: float = 0.1,
    pos_noise: float = 0.01,
    seed: int = 0,
) -> tuple[VertexSet, VertexSet, np.ndarray]:
    """DBLP-like pair: graph2 is a perturbed relabeling of graph1.

    Returns (vs1, vs2, pi) with ground truth pi: V1 -> V2 ids.
    """
    rng = np.random.default_rng(seed)
    m = int(n * avg_deg / 2)
    e1 = rng.integers(0, n, size=(m, 2), dtype=np.int64)
    e1 = e1[e1[:, 0] != e1[:, 1]]
    vtype1 = rng.integers(0, n_types, size=n).astype(np.int32)
    etype1 = rng.integers(0, n_etypes, size=len(e1)).astype(np.int32)
    attr_counts = rng.integers(1, ka + 1, size=n)
    attrs1 = [rng.choice(n_attr_vocab, size=c, replace=False) for c in attr_counts]

    # latent placement: corresponding vertices land close on the plane
    pos_true = rng.random((n, 2)).astype(np.float32)
    pos1 = np.clip(pos_true + rng.normal(0, pos_noise, (n, 2)).astype(np.float32), 0, 0.999)

    # graph2: relabel + perturb edges, keep types/attrs (metadata preserved)
    pi = rng.permutation(n).astype(np.int64)
    keep = rng.random(len(e1)) >= drop_frac
    e2 = pi[e1[keep]]
    extra = rng.integers(0, n, size=(int(len(e1) * drop_frac), 2), dtype=np.int64)
    extra = extra[extra[:, 0] != extra[:, 1]]
    e2 = np.concatenate([e2, extra], axis=0)
    etype2 = np.concatenate(
        [etype1[keep], rng.integers(0, n_etypes, size=len(extra)).astype(np.int32)]
    )
    vtype2 = np.empty(n, dtype=np.int32)
    vtype2[pi] = vtype1
    attrs2: list[np.ndarray] = [None] * n  # type: ignore
    for u in range(n):
        attrs2[pi[u]] = attrs1[u]
    pos2 = np.empty((n, 2), dtype=np.float32)
    pos2[pi] = np.clip(pos_true + rng.normal(0, pos_noise, (n, 2)).astype(np.float32), 0, 0.999)

    md1 = _metadata_from_edges(n, e1, vtype1, etype1, attrs1, kn, ke, ka)
    md2 = _metadata_from_edges(n, e2, vtype2, etype2, attrs2, kn, ke, ka)
    vs1 = VertexSet(
        pos=jnp.asarray(pos1), deg=jnp.asarray(md1["deg"]), vtype=jnp.asarray(vtype1),
        ntypes=jnp.asarray(md1["ntypes"]), etypes=jnp.asarray(md1["etypes"]),
        attrs=jnp.asarray(md1["attrs"]),
    )
    vs2 = VertexSet(
        pos=jnp.asarray(pos2), deg=jnp.asarray(md2["deg"]), vtype=jnp.asarray(vtype2),
        ntypes=jnp.asarray(md2["ntypes"]), etypes=jnp.asarray(md2["etypes"]),
        attrs=jnp.asarray(md2["attrs"]),
    )
    return vs1, vs2, pi


def bucketize(vs: VertexSet, grid: int, cap: int | None = None) -> Buckets:
    """Assign vertices to grid x grid buckets by 2-D position; pad to cap."""
    pos = np.asarray(vs.pos)
    bx = np.minimum((pos[:, 0] * grid).astype(np.int64), grid - 1)
    by = np.minimum((pos[:, 1] * grid).astype(np.int64), grid - 1)
    b = by * grid + bx
    order = np.argsort(b, kind="stable")
    counts = np.bincount(b, minlength=grid * grid)
    if cap is None:
        cap = max(1, int(counts.max()))
    if counts.max() > cap:
        raise ValueError(f"bucket overflow: max load {counts.max()} > cap {cap}; raise grid")
    vid = np.full((grid * grid, cap), -1, dtype=np.int32)
    offs = np.zeros(grid * grid, dtype=np.int64)
    for v in order:
        bb = b[v]
        vid[bb, offs[bb]] = v
        offs[bb] += 1
    return Buckets(vid=jnp.asarray(vid), count=jnp.asarray(counts.astype(np.int32)), grid=grid)


def pick_grid(n: int, target_bucket: int) -> int:
    """Power-of-two grid so the average bucket holds ~target_bucket vertices
    (paper Table 4 pairs |V| with a bucket size |B|)."""
    g = 1
    while (n / (g * g)) > target_bucket:
        g *= 2
    return max(g, 2)


def neighbor_buckets(grid: int) -> np.ndarray:
    """(grid*grid, 9) neighbor bucket ids (3x3 window, -1 outside) — the
    quadtree-neighbor task structure of Fig. 3."""
    ids = np.arange(grid * grid)
    bx, by = ids % grid, ids // grid
    out = np.full((grid * grid, 9), -1, dtype=np.int32)
    j = 0
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            xx, yy = bx + dx, by + dy
            ok = (xx >= 0) & (xx < grid) & (yy >= 0) & (yy < grid)
            out[ok, j] = (yy * grid + xx)[ok]
            j += 1
    return out
