"""Distributed SpMV with the paper's replication strategy (S1, §3.1/§5.1).

Layout (paper Fig. 2): the row array is striped across ``P`` logical nodelets
(row ``r`` on nodelet ``r % P``); each row's nonzeros live with their row
(jagged arrays -> padded ELL planes per nodelet, see DESIGN.md §2). The input
vector ``x`` is either

- **replicated** on every nodelet (paper's winning strategy): zero per-element
  communication after a one-time broadcast, or
- **striped** (``x[j]`` on nodelet ``j % P``): every nonzero whose column
  lives remotely triggers a thread migration on the Emu == an ``all_gather``
  pull on TPU (the ``migrate`` realization of remote gets).

``grain`` = rows per task (paper Fig. 4): the local path executes row chunks
of ``grain`` rows with ``lax.map`` (sequential across chunks, vector within),
the Pallas kernel uses it as rows-per-program, and the distributed path uses
it as the rows-per-shard block factor.

This module holds the *algorithm* (one function per substrate:
:func:`spmv_local`, :func:`spmv_mesh`); substrate selection lives in
:mod:`repro.engine` (DESIGN.md §1). :func:`spmv` is a deprecated shim.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..sparse.csr import CSR
from .strategies import MigratoryStrategy, TrafficStats
from .util import ceil_div, round_up


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PartitionedELL:
    """Per-nodelet padded ELL planes. Global row r <-> (p=r%P, slot=r//P)."""

    cols: jax.Array  # (P, R_p, K) int32 global col ids, -1 pad
    vals: jax.Array  # (P, R_p, K)
    shape: tuple[int, int]

    def tree_flatten(self):
        return (self.cols, self.vals), self.shape

    @classmethod
    def tree_unflatten(cls, shape, leaves):
        return cls(*leaves, shape=shape)

    @property
    def P(self) -> int:
        return self.cols.shape[0]

    @property
    def rows_per_nodelet(self) -> int:
        return self.cols.shape[1]

    @property
    def k(self) -> int:
        return self.cols.shape[2]


def partition_ell(a: CSR, p: int, k: int | None = None, pad_rows_to: int = 1) -> PartitionedELL:
    """Stripe a CSR matrix's rows over ``p`` nodelets as padded ELL planes."""
    indptr = np.asarray(a.indptr)
    indices = np.asarray(a.indices)
    data = np.asarray(a.data)
    n = a.n_rows
    lens = indptr[1:] - indptr[:-1]
    kmax = int(lens.max()) if n else 1
    k = k or max(kmax, 1)
    if kmax > k:
        raise ValueError(f"max row degree {kmax} > k={k}; use split_long_rows first")
    rp = round_up(ceil_div(n, p), pad_rows_to)
    cols = np.full((p, rp, k), -1, dtype=np.int32)
    vals = np.zeros((p, rp, k), dtype=data.dtype)
    for r in range(n):
        s, e = indptr[r], indptr[r + 1]
        cols[r % p, r // p, : e - s] = indices[s:e]
        vals[r % p, r // p, : e - s] = data[s:e]
    return PartitionedELL(cols=jnp.asarray(cols), vals=jnp.asarray(vals), shape=a.shape)


def stripe_vector(x: jax.Array, p: int) -> jax.Array:
    """(N,) -> (P, N_p) striped layout, x[j] at (j % p, j // p). Pads with 0."""
    n = x.shape[0]
    npp = ceil_div(n, p)
    xp = jnp.pad(x, (0, npp * p - n))
    return xp.reshape(npp, p).T


def unstripe_vector(xs: jax.Array, n: int) -> jax.Array:
    p, npp = xs.shape
    return xs.T.reshape(p * npp)[:n]


def _rows_kernel(cols, vals, x_full):
    """Compute one chunk of rows: masked gather + reduce. cols/vals (..., K)."""
    mask = cols >= 0
    xg = jnp.take(x_full, jnp.maximum(cols, 0), axis=0)
    return jnp.sum(jnp.where(mask, vals * xg, 0), axis=-1)


@partial(jax.jit, static_argnames=("grain",))
def _spmv_local(a: PartitionedELL, x_full: jax.Array, grain: int) -> jax.Array:
    """Single-device semantics path: vmap over nodelets, lax.map over row
    chunks of ``grain`` rows (the task structure the Emu sees)."""
    P, rp, k = a.cols.shape
    g = max(1, min(grain, rp))
    n_chunks = ceil_div(rp, g)
    pad = n_chunks * g - rp
    cols = jnp.pad(a.cols, ((0, 0), (0, pad), (0, 0)), constant_values=-1)
    vals = jnp.pad(a.vals, ((0, 0), (0, pad), (0, 0)))
    cols = cols.reshape(P, n_chunks, g, k)
    vals = vals.reshape(P, n_chunks, g, k)

    def per_nodelet(c, v):
        return jax.lax.map(lambda cv: _rows_kernel(cv[0], cv[1], x_full), (c, v))

    y = jax.vmap(per_nodelet)(cols, vals)  # (P, n_chunks, g)
    return y.reshape(P, n_chunks * g)[:, :rp]


def spmv_local(
    a: PartitionedELL, x: jax.Array, strategy: MigratoryStrategy
) -> jax.Array:
    """``local`` substrate: single-device vmap emulation with the distributed
    path's semantics. ``x``: full (N,) if ``strategy.replicate_x`` else
    striped (P, N_p). Returns y in striped (P, R_p) layout."""
    grain = strategy.dynamic_grain(a.rows_per_nodelet)
    x_full = x if strategy.replicate_x else unstripe_vector(x, a.shape[1])
    return _spmv_local(a, x_full, grain)


def spmv_mesh(
    a: PartitionedELL,
    x: jax.Array,
    strategy: MigratoryStrategy,
    mesh: jax.sharding.Mesh,
    axis_name: str = "nodelet",
) -> jax.Array:
    """``mesh`` substrate: nodelet planes sharded over ``axis_name``. The
    non-replicated path pulls ``x`` with an ``all_gather`` (the migrate
    analogue). Same input/output conventions as :func:`spmv_local`."""
    from ..compat import shard_map
    from jax.sharding import PartitionSpec as P_

    n = a.shape[1]

    if strategy.replicate_x:

        def body(cols_p, vals_p, x_rep):
            # x already local everywhere: pure local compute (paper's S1 win)
            return _rows_kernel(cols_p[0], vals_p[0], x_rep)[None]

        in_specs = (P_(axis_name), P_(axis_name), P_())
    else:

        def body(cols_p, vals_p, x_striped):
            # migrate/pull: gather the striped vector (thread-migration analogue)
            xg = jax.lax.all_gather(x_striped, axis_name)  # (P, 1, N_p)
            x_full = unstripe_vector(xg[:, 0, :], n)
            return _rows_kernel(cols_p[0], vals_p[0], x_full)[None]

        in_specs = (P_(axis_name), P_(axis_name), P_(axis_name))

    f = shard_map(body, mesh, in_specs=in_specs, out_specs=P_(axis_name))
    return f(a.cols, a.vals, x)


def spmv(
    a: PartitionedELL,
    x: jax.Array,
    strategy: MigratoryStrategy,
    *,
    mesh: jax.sharding.Mesh | None = None,
    axis_name: str = "nodelet",
) -> jax.Array:
    """Deprecated shim — use ``repro.engine.run(SpMVOp(), ...)`` instead.

    Kept so pre-engine call sites keep working: forwards to the engine's
    substrate resolution (``local`` without a mesh, ``mesh`` with one).
    """
    from ..engine.substrate import substrate_for_mesh

    return substrate_for_mesh(mesh, axis_name).kernel("spmv")(
        a, x, strategy=strategy
    )


def gather_result(y_striped: jax.Array, n: int) -> jax.Array:
    """(P, R_p) striped result -> global (N,) row order."""
    return unstripe_vector(y_striped, n)


def spmv_traffic(a: PartitionedELL, strategy: MigratoryStrategy) -> TrafficStats:
    """Paper-model traffic: striped x costs one migration per nonzero whose
    column owner differs from the row's nodelet; replication costs none."""
    cols = np.asarray(a.cols)
    P = a.P
    if strategy.replicate_x:
        return TrafficStats(migrations=0, remote_writes=0)
    p_idx = np.arange(P)[:, None, None]
    remote = (cols >= 0) & ((cols % P) != p_idx)
    return TrafficStats(migrations=int(remote.sum()), remote_writes=0)


def spmv_bytes_moved(a: PartitionedELL, n: int, dtype_bytes: int = 4) -> int:
    """Bytes the paper's §5.1 bandwidth formula charges one SpMV with:
    sizeof(A) (true nonzeros: value + column index) + sizeof(x) + sizeof(y).
    """
    nnz = int((np.asarray(a.cols) >= 0).sum())
    return nnz * (dtype_bytes + 4) + (n + a.shape[0]) * dtype_bytes


def effective_bandwidth(a: PartitionedELL, n: int, seconds: float, dtype_bytes: int = 4) -> float:
    """Paper §5.1 metric: (sizeof(A) + sizeof(x) + sizeof(y)) / time."""
    return spmv_bytes_moved(a, n, dtype_bytes) / max(seconds, 1e-12)
