"""Analytic strategy cost model: the paper's traffic units from shapes alone.

Rolinger & Krieger (1812.05955) show the right sparse optimization is
workload-dependent; this module systematizes the paper's §5 per-workload
analysis so the engine can *rank* the S1 x S2 x S3 x grain grid without
executing anything. Costs are expressed in the same units the engine's
RunReports carry — ``TrafficStats.total_bytes`` under the Emu model
(CONTEXT_BYTES per migration, WRITE_PACKET_BYTES per remote write) — so an
exhaustive measured sweep and the analytic ranking are directly
cross-checkable (tests/test_autotune.py pins this).

Each ``*_cost_model`` factory precomputes the shared structure statistics
once (nnz ownership, the BFS edge replay, the GSANA placements) and returns
a cheap per-strategy estimator, so ranking a 32-candidate grid costs one
pass over the inputs, not 32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

from .strategies import (
    CONTEXT_BYTES,
    WRITE_PACKET_BYTES,
    Comm,
    Layout,
    MigratoryStrategy,
    TrafficStats,
)
from .util import ceil_div

# dynamic_grain's task-count target: the machine-saturation point the grain
# tie-break scores distance from (paper Fig. 4)
GRAIN_TARGET_TASKS = 512


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """One candidate strategy's modeled cost.

    ``traffic_bytes`` is the primary key and matches the engine's reported
    ``report.traffic.total_bytes`` exactly; ``balance_penalty`` breaks ties
    among traffic-equal candidates (modeled makespan for GSANA, grain/task
    mismatch for SpMV, 0 where the axis is inert).

    ``traffic`` is the same cost split by class (migrations / remote writes
    / collective bytes) — the calibration plane's perf model charges each
    class a different alpha-beta rate, so the split matters even though
    ``traffic_bytes`` collapses it. ``predicted_seconds`` is attached by
    :class:`~repro.machine.perfmodel.PerformanceModel` when a calibrated
    machine file is present; it stays None (and ranking stays bit-identical
    to the traffic units) otherwise. ``detail["collective_launches"]``
    counts how many collective dispatches the strategy issues (BFS pays one
    per round), feeding the alpha term.

    ``detail["substrate_memory"]`` maps a substrate kind to that backend's
    *own* per-launch working set + access class when its kernel executes a
    different memory shape than the generic path — the Pallas kernels
    replicate x into every grid program's VMEM (SpMV) and min-merge a
    dense partial per program (BFS), so their sweeps depend on the grain
    axis (``block_rows``). The perf model prefers the targeted declaration
    over the generic one, which is what makes predicted seconds rank block
    sizes.
    """

    strategy: MigratoryStrategy
    traffic_bytes: int
    balance_penalty: float
    detail: dict[str, Any] = dataclasses.field(default_factory=dict)
    traffic: "TrafficStats | None" = None
    predicted_seconds: "float | None" = None

    def rank_key(self) -> tuple:
        return (
            self.traffic_bytes,
            self.balance_penalty,
            str(self.strategy.cache_key()),  # deterministic final tie-break
        )


CostModel = Callable[[MigratoryStrategy], CostEstimate]


def spmv_cost_model(inputs) -> CostModel:
    """S1 + grain model (paper §5.1): striping x costs one migration per
    nonzero whose column lives on a different nodelet; replication costs
    none. Grain is scored by task-count distance from the dynamic-grain
    saturation target."""
    a = inputs.a
    cols = np.asarray(a.cols)
    p = a.P
    p_idx = np.arange(p)[:, None, None]
    remote_nnz = int(((cols >= 0) & ((cols % p) != p_idx)).sum())
    rp = a.rows_per_nodelet
    n_cols = a.shape[1]
    # what one launch streams: the *padded* ELL slab (vals f32 + cols i32,
    # padding included — skewed matrices execute their padding) plus x
    # gathered and y written; random reads dominate, so this is charged at
    # the machine file's gather rate
    sweep_bytes = cols.size * 8 + 2 * 4 * p * rp

    def estimate(st: MigratoryStrategy) -> CostEstimate:
        migrations = 0 if st.replicate_x else remote_nnz
        grain = st.dynamic_grain(rp, target_tasks=GRAIN_TARGET_TASKS)
        tasks = ceil_div(rp, max(1, min(grain, rp))) * p
        target = min(GRAIN_TARGET_TASKS, rp) * p
        balance = abs(tasks - target) / max(target, 1)
        # the pallas kernel's launch shape (mirrors _spmv_pallas: planes
        # flattened to p*rp rows, block_rows = grain): every grid program
        # replicates x (S1 in VMEM), so small blocks multiply the x sweep
        block = max(1, min(grain, p * rp))
        programs = ceil_div(p * rp, block)
        pallas_bytes = sweep_bytes + programs * n_cols * 4
        return CostEstimate(
            strategy=st,
            traffic_bytes=migrations * CONTEXT_BYTES,
            balance_penalty=balance,
            detail={
                "migrations": migrations, "tasks": tasks, "grain": grain,
                "collective_launches": 1,
                "memory_bytes_per_launch": sweep_bytes,
                "memory_access": "gather",
                "substrate_memory": {
                    "pallas": {
                        "bytes_per_launch": pallas_bytes,
                        "access": "gather",
                        "programs": programs,
                    },
                },
            },
            traffic=TrafficStats(migrations=migrations),
        )

    return estimate


def bfs_cost_model(inputs) -> CostModel:
    """S2 model (paper §5.2): one numpy edge replay yields the remote-edge
    count; migrate charges 2 context moves per remote edge (the §7
    ping-pong), remote write one small packet."""
    from .bfs import bfs_traffic

    stats = bfs_traffic(inputs.g, inputs.root, MigratoryStrategy(comm=Comm.MIGRATE))
    remote_edges = stats.traffic.migrations // 2
    # per-round dense working set: level-synchronous kernels scatter-min
    # over the full padded adjacency every round — index + read + write per
    # (N_pad, K) slot, charged at the machine file's *scatter* rate (the
    # serialized read-modify-write path, not the triad), times rounds
    p, vp, k = inputs.g.adj.shape
    sweep_bytes = 12 * p * vp * k
    n_pad = p * vp

    def estimate(st: MigratoryStrategy) -> CostEstimate:
        if st.comm == Comm.MIGRATE:
            split = TrafficStats(migrations=2 * remote_edges)
        else:
            split = TrafficStats(remote_writes=remote_edges)
        # the pallas round kernel's launch shape (mirrors bfs_pallas:
        # block_rows = grain over the global adjacency): each grid program
        # builds and min-merges a dense (N_pad,) partial, so small blocks
        # multiply the accumulator sweep — the per-block-aggregation cost
        block = max(1, min(st.dynamic_grain(n_pad), n_pad))
        programs = ceil_div(n_pad, block)
        pallas_bytes = sweep_bytes + programs * n_pad * 8
        return CostEstimate(
            strategy=st,
            traffic_bytes=split.total_bytes,
            balance_penalty=0.0,
            detail={
                "remote_edges": remote_edges,
                "edges_traversed": stats.edges_traversed,
                "rounds": stats.rounds,
                # one collective dispatch per frontier round — the alpha
                # term is what separates migrate from remote-write on
                # latency-bound rounds
                "collective_launches": stats.rounds,
                "memory_bytes_per_launch": sweep_bytes,
                "memory_access": "scatter",
                "substrate_memory": {
                    "pallas": {
                        "bytes_per_launch": pallas_bytes,
                        "access": "scatter",
                        "programs": programs,
                    },
                },
            },
            traffic=split,
        )

    return estimate


def gsana_cost_model(inputs) -> CostModel:
    """S3 model (paper §5.3): replay the task schedule per (layout, scheme)
    with the paper's placement/traffic model; migrations drive traffic,
    modeled makespan breaks the ALL-vs-PAIR tie (schemes share traffic)."""
    from .gsana import DEFAULT_VOCAB, layout_blk, layout_hcb, plan_stats

    # one σ comparison materializes the (A, B, T) histogram-minimum
    # intermediates over the three overlap vocabularies (T = Σ DEFAULT_VOCAB
    # f32 lanes, ~2 passes each: broadcast-min write + reduce read) — dense
    # sequential work, charged at the machine file's stream rate
    cmp_bytes = 2 * 4 * sum(DEFAULT_VOCAB)

    placements = {
        Layout.BLK: layout_blk(
            inputs.b1, inputs.b2, inputs.vs1.n, inputs.vs2.n, inputs.nodelets
        ),
        Layout.HCB: layout_hcb(inputs.b1, inputs.b2, inputs.nodelets),
    }
    memo: dict[tuple, Any] = {}

    def estimate(st: MigratoryStrategy) -> CostEstimate:
        key = (st.layout, st.scheme)
        if key not in memo:
            memo[key] = plan_stats(
                inputs.vs1, inputs.vs2, inputs.b1, inputs.b2,
                placements[st.layout], st.scheme, inputs.nodelets,
                threads_per_nodelet=inputs.threads_per_nodelet,
                migration_penalty=inputs.migration_penalty,
            )
        ps = memo[key]
        return CostEstimate(
            strategy=st,
            traffic_bytes=ps.traffic.total_bytes,
            balance_penalty=ps.makespan,
            detail={
                "migrations": ps.traffic.migrations,
                "model_makespan": ps.makespan,
                "model_speedup": ps.speedup_model,
                "collective_launches": 1,
                "memory_bytes_per_launch": ps.total_comparisons * cmp_bytes,
                "memory_access": "stream",
            },
            traffic=ps.traffic,
        )

    return estimate


COST_MODELS: dict[str, Callable[[Any], CostModel]] = {
    "spmv": spmv_cost_model,
    "bfs": bfs_cost_model,
    "gsana": gsana_cost_model,
}


def register_cost_model(op_name: str, factory: Callable[[Any], CostModel]) -> None:
    """Install an op's analytic cost-model factory so ``cost_model_for``
    serves it. The engine's kernel registry calls this when an
    :class:`~repro.engine.registry.OpSpec` carries a ``cost_model`` — new
    ops (e.g. ``moe_dispatch``) become autotunable without editing this
    module. Re-registering the same op replaces the factory."""
    COST_MODELS[op_name] = factory


def cost_model_for(op_name: str, inputs) -> CostModel:
    """Build the per-strategy estimator for one op's concrete inputs."""
    try:
        factory = COST_MODELS[op_name]
    except KeyError:
        raise ValueError(
            f"no cost model for op {op_name!r}; known: {sorted(COST_MODELS)}"
        ) from None
    return factory(inputs)
