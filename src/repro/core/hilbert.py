"""Vectorized 2-D Hilbert curve order (paper §3.3.2 HCB layout).

``xy_to_d`` maps integer grid coordinates on a 2^order x 2^order grid to the
Hilbert distance; used to linearize quadtree buckets so that spatially
adjacent buckets (whose vertices get compared) land on the same shard.
"""
from __future__ import annotations

import numpy as np


def xy_to_d(order: int, x: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Hilbert distance of (x, y) on a 2^order grid. Vectorized int64."""
    x = np.asarray(x, dtype=np.int64).copy()
    y = np.asarray(y, dtype=np.int64).copy()
    rx = np.zeros_like(x)
    ry = np.zeros_like(y)
    d = np.zeros_like(x)
    s = np.int64(1) << (order - 1)
    while s > 0:
        rx = ((x & s) > 0).astype(np.int64)
        ry = ((y & s) > 0).astype(np.int64)
        d += s * s * ((3 * rx) ^ ry)
        # rotate
        swap = ry == 0
        flip = swap & (rx == 1)
        x_f, y_f = x.copy(), y.copy()
        x = np.where(flip, s - 1 - x_f, x_f)
        y = np.where(flip, s - 1 - y_f, y_f)
        x2, y2 = x.copy(), y.copy()
        x = np.where(swap, y2, x2)
        y = np.where(swap, x2, y2)
        s >>= 1
    return d


def d_to_xy(order: int, d: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Inverse of :func:`xy_to_d` (scalar loop-free, vectorized)."""
    d = np.asarray(d, dtype=np.int64)
    t = d.copy()
    x = np.zeros_like(d)
    y = np.zeros_like(d)
    s = np.int64(1)
    while s < (np.int64(1) << order):
        rx = 1 & (t // 2)
        ry = 1 & (t ^ rx)
        # rotate
        swap = ry == 0
        flip = swap & (rx == 1)
        x_f, y_f = x.copy(), y.copy()
        x = np.where(flip, s - 1 - x_f, x_f)
        y = np.where(flip, s - 1 - y_f, y_f)
        x2, y2 = x.copy(), y.copy()
        x = np.where(swap, y2, x2)
        y = np.where(swap, x2, y2)
        x += s * rx
        y += s * ry
        t //= 4
        s *= 2
    return x, y


def hilbert_order_of_buckets(grid: int) -> np.ndarray:
    """Permutation: bucket (row-major id) -> Hilbert rank, for a grid x grid
    bucket decomposition. ``grid`` must be a power of two."""
    order = int(np.log2(grid))
    assert (1 << order) == grid, "grid must be a power of two"
    ids = np.arange(grid * grid)
    bx, by = ids % grid, ids // grid
    d = xy_to_d(order, bx, by)
    return np.argsort(np.argsort(d))  # rank of each bucket
