"""Graph500 BFS: migrating threads (Alg. 1) vs remote writes (Alg. 2).

Paper §3.2: the migrate version reads ``P[d]`` remotely (a thread migration
per traversed edge) and CASes; the remote-write version blindly pushes the
proposed parent into a shadow array ``nP`` (small one-sided packets, later
writes overwrite earlier ones) and commits in a local scan — two phases, no
atomics. We keep Alg. 2's two-phase structure exactly, replacing the
nondeterministic overwrite with a deterministic ``min`` merge (any proposed
parent is a valid BFS parent; see DESIGN.md §10).

TPU realization (DESIGN.md §2):
- ``migrate``  = pull: per round, ``all_gather`` the parent array to every
  shard (and all_gather the per-shard proposal partials back) — data moves to
  compute, twice.
- ``remote_write`` = push: each shard computes a dense proposal partial for
  the whole vertex space from purely local state and pushes it with a
  reduce-scatter(min) (implemented as all_to_all + local min); the owner
  commits locally. ~P× less traffic per round, no parent pull.

Both strategies produce identical parent trees (level-synchronous min-merge);
they differ in communication structure — which is the paper's point.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..sparse.graph import PartitionedGraph
from .strategies import Comm, MigratoryStrategy, TrafficStats

UNVISITED = jnp.iinfo(jnp.int32).max  # internal sentinel (min-merge friendly)


def _adj_global(g: PartitionedGraph) -> jax.Array:
    """(P, V_p, K) nodelet-major -> (N_pad, K) global-vertex-major view."""
    p, vp, k = g.adj.shape
    return jnp.transpose(g.adj, (1, 0, 2)).reshape(vp * p, k)


def _expand_dense(adj: jax.Array, frontier: jax.Array, n_pad: int) -> jax.Array:
    """One frontier expansion: dense proposal array nP (N_pad,) via min-scatter.

    For every frontier vertex s and neighbor d: propose parent s for d.
    Invalid slots scatter UNVISITED (a no-op for min).
    """
    n, k = adj.shape
    src = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, k))
    valid = frontier[:, None] & (adj >= 0)
    dst = jnp.where(valid, adj, 0)
    prop = jnp.where(valid, src, UNVISITED)
    return jnp.full((n_pad,), UNVISITED, dtype=jnp.int32).at[dst.reshape(-1)].min(
        prop.reshape(-1), mode="drop"
    )


@partial(jax.jit, static_argnames=("max_rounds",))
def _bfs_local(adj: jax.Array, root: jax.Array, max_rounds: int) -> jax.Array:
    """Level-synchronous BFS on a single device (semantics oracle for both
    strategies — Alg. 1 and Alg. 2 compute the same tree here)."""
    n = adj.shape[0]
    parents0 = jnp.full((n,), UNVISITED, dtype=jnp.int32).at[root].set(root)
    frontier0 = jnp.zeros((n,), dtype=bool).at[root].set(True)

    def cond(state):
        _, frontier, it = state
        return jnp.logical_and(frontier.any(), it < max_rounds)

    def body(state):
        parents, frontier, it = state
        nP = _expand_dense(adj, frontier, n)
        newly = (parents == UNVISITED) & (nP != UNVISITED)
        parents = jnp.where(newly, nP, parents)
        return parents, newly, it + 1

    parents, _, _ = jax.lax.while_loop(cond, body, (parents0, frontier0, 0))
    return parents


def _finalize_parents(g: PartitionedGraph, parents: jax.Array) -> jax.Array:
    """Trim padding and map the internal UNVISITED sentinel to -1."""
    parents = parents[: g.n_vertices]
    return jnp.where(parents == UNVISITED, -1, parents)


def bfs_local(
    g: PartitionedGraph,
    root: int,
    strategy: MigratoryStrategy | None = None,
    max_rounds: int | None = None,
) -> jax.Array:
    """``local`` substrate: the single-device semantics oracle (both S2
    strategies compute the same tree here). (n_vertices,) int32, -1 unreached.
    """
    del strategy  # both comm strategies share the local oracle
    max_rounds = max_rounds or g.P * g.v_per_nodelet
    return _finalize_parents(g, _bfs_local(_adj_global(g), jnp.int32(root), max_rounds))


def bfs_mesh(
    g: PartitionedGraph,
    root: int,
    strategy: MigratoryStrategy | None = None,
    max_rounds: int | None = None,
    *,
    mesh: jax.sharding.Mesh,
    axis_name: str = "nodelet",
) -> jax.Array:
    """``mesh`` substrate: the strategy-specific distributed implementation
    over ``axis_name`` (Alg. 1 pull vs Alg. 2 push)."""
    strategy = strategy or MigratoryStrategy()
    max_rounds = max_rounds or g.P * g.v_per_nodelet
    return _finalize_parents(
        g, _bfs_distributed(g, root, strategy, mesh, axis_name, max_rounds)
    )


def bfs(
    g: PartitionedGraph,
    root: int,
    strategy: MigratoryStrategy | None = None,
    *,
    mesh: jax.sharding.Mesh | None = None,
    axis_name: str = "nodelet",
    max_rounds: int | None = None,
) -> jax.Array:
    """Deprecated shim — use ``repro.engine.run(BFSOp(), ...)`` instead.

    Kept so pre-engine call sites keep working: forwards to the engine's
    substrate resolution (``local`` without a mesh, ``mesh`` with one).
    """
    from ..engine.substrate import substrate_for_mesh

    return substrate_for_mesh(mesh, axis_name).kernel("bfs")(
        g, root, strategy=strategy or MigratoryStrategy(), max_rounds=max_rounds
    )


def _bfs_distributed(g, root, strategy, mesh, axis_name, max_rounds):
    """Distributed BFS over the nodelet mesh axis.

    State per shard: its slice of the (vertex-major) parent/frontier arrays.
    Vertex-major layout: global vertex v -> shard v // V_p, slot v % V_p
    (block distribution over the padded global order).
    """
    from jax.sharding import PartitionSpec as P_

    p, vp, k = g.adj.shape
    n_pad = p * vp
    vs = n_pad // p  # vertices per shard (block)
    adj_g = _adj_global(g)  # (N_pad, K) -> sharded on rows
    push = strategy.comm == Comm.REMOTE_WRITE

    def body(adj_s):  # adj_s: (vs, K) local adjacency rows
        shard = jax.lax.axis_index(axis_name)
        lo = shard * vs
        vids = lo + jnp.arange(vs, dtype=jnp.int32)
        parents0 = jnp.where(vids == root, jnp.int32(root), UNVISITED)
        frontier0 = vids == root

        def cond(state):
            _, _, it, alive = state
            return jnp.logical_and(alive, it < max_rounds)

        def round_body(state):
            parents, frontier, it, _ = state
            if push:
                # Alg. 2: blind dense push from local state only.
                src = lo + jnp.broadcast_to(
                    jnp.arange(vs, dtype=jnp.int32)[:, None], (vs, k)
                )
                valid = frontier[:, None] & (adj_s >= 0)
                dst = jnp.where(valid, adj_s, 0)
                prop = jnp.where(valid, src, UNVISITED)
                partial_nP = (
                    jnp.full((n_pad,), UNVISITED, dtype=jnp.int32)
                    .at[dst.reshape(-1)]
                    .min(prop.reshape(-1), mode="drop")
                )
                # reduce-scatter(min) == all_to_all + local min: the remote write
                blocks = partial_nP.reshape(p, vs)
                recv = jax.lax.all_to_all(blocks, axis_name, 0, 0, tiled=True)
                nP = jnp.min(recv.reshape(p, vs), axis=0)
            else:
                # Alg. 1: pull everything — gather parents (the per-edge read
                # of P[d] that migrates the thread), expand with the visited
                # filter, gather everyone's partials back (migrate analogue).
                par_full = jax.lax.all_gather(parents, axis_name, tiled=True)
                src = lo + jnp.broadcast_to(
                    jnp.arange(vs, dtype=jnp.int32)[:, None], (vs, k)
                )
                valid = frontier[:, None] & (adj_s >= 0)
                dst = jnp.where(valid, adj_s, 0)
                # the remote read: P[d] == UNVISITED check before the CAS
                valid = valid & (par_full[dst] == UNVISITED)
                prop = jnp.where(valid, src, UNVISITED)
                nP_partial = (
                    jnp.full((n_pad,), UNVISITED, dtype=jnp.int32)
                    .at[dst.reshape(-1)]
                    .min(prop.reshape(-1), mode="drop")
                )
                # claims still must reach the owner: second gather + min
                all_parts = jax.lax.all_gather(nP_partial, axis_name)  # (P, N_pad)
                nP_full = jnp.min(all_parts, axis=0)
                nP = jax.lax.dynamic_slice(nP_full, (lo,), (vs,))
            newly = (parents == UNVISITED) & (nP != UNVISITED)
            parents = jnp.where(newly, nP, parents)
            alive = jax.lax.psum(newly.sum(), axis_name) > 0
            return parents, newly, it + 1, alive

        parents, _, _, _ = jax.lax.while_loop(
            cond, round_body, (parents0, frontier0, 0, jnp.bool_(True))
        )
        return parents

    from ..compat import shard_map

    f = shard_map(body, mesh, in_specs=(P_(axis_name),), out_specs=P_(axis_name))
    return f(adj_g)


# -- paper-model traffic accounting (numpy simulator) -------------------------


@dataclasses.dataclass
class BFSRunStats:
    rounds: int
    edges_traversed: int
    traffic: TrafficStats


def bfs_traffic(g: PartitionedGraph, root: int, strategy: MigratoryStrategy) -> BFSRunStats:
    """Replay BFS in numpy, counting the paper's traffic units.

    migrate (Alg. 1): one thread migration per traversed edge whose
    destination lives on a remote nodelet (read of P[d] moves the thread
    there), plus the hop back ("ping-pong", §7) — counted as 2 migrations.
    remote_write (Alg. 2): one small packet per traversed edge with a remote
    destination; no migrations.
    """
    p, vp, k = g.adj.shape
    adj = np.transpose(np.asarray(g.adj), (1, 0, 2)).reshape(vp * p, k)
    n = g.n_vertices
    owner = np.arange(vp * p) % p  # striped ownership (paper layout)
    parents = np.full(vp * p, -1, dtype=np.int64)
    parents[root] = root
    frontier = np.zeros(vp * p, dtype=bool)
    frontier[root] = True
    migrations = remote_writes = edges = rounds = 0
    while frontier.any():
        rounds += 1
        srcs = np.nonzero(frontier)[0]
        nbrs = adj[srcs]  # (f, K)
        valid = nbrs >= 0
        dst = nbrs[valid]
        src = np.repeat(srcs, valid.sum(axis=1))
        edges += len(dst)
        remote = owner[dst] != owner[src]
        if strategy.comm == Comm.MIGRATE:
            migrations += int(2 * remote.sum())
        else:
            remote_writes += int(remote.sum())
        nP = np.full(vp * p, np.iinfo(np.int64).max)
        np.minimum.at(nP, dst, src)
        newly = (parents == -1) & (nP != np.iinfo(np.int64).max)
        parents[newly] = nP[newly]
        frontier = newly
    return BFSRunStats(
        rounds=rounds,
        edges_traversed=edges,
        traffic=TrafficStats(migrations=migrations, remote_writes=remote_writes),
    )


def teps(n_edges_traversed: int, seconds: float) -> float:
    return n_edges_traversed / max(seconds, 1e-12)


def bfs_bytes_moved(n_edges: int) -> int:
    """Paper §5.2 unit of useful work: every traversed edge reads+writes one
    8-byte word (2 * 8 bytes per edge)."""
    return n_edges * 2 * 8


def bfs_effective_bandwidth(scale: int, seconds: float, edge_factor: int = 16) -> float:
    """Paper §5.2: BW = 16 * 2^scale * 2 * 8 / time = TEPS * 16."""
    return bfs_bytes_moved(edge_factor * (1 << scale)) / max(seconds, 1e-12)


def validate_parents(g: PartitionedGraph, root: int, parents: np.ndarray) -> bool:
    """Graph500-style validation: parent edges exist, root ok, levels consistent."""
    p, vp, k = g.adj.shape
    adj = np.transpose(np.asarray(g.adj), (1, 0, 2)).reshape(vp * p, k)
    n = g.n_vertices
    parents = np.asarray(parents[:n])
    if parents[root] != root:
        return False
    # compute levels by following parents (bounded by n)
    level = np.full(n, -1, dtype=np.int64)
    level[root] = 0
    reached = np.nonzero(parents >= 0)[0]
    for v in reached:
        if v == root:
            continue
        # parent edge must exist in the graph
        if v not in adj[parents[v]][adj[parents[v]] >= 0]:
            return False
    # level consistency via BFS from root on the parent tree
    children: dict[int, list[int]] = {}
    for v in reached:
        if v != root:
            children.setdefault(int(parents[v]), []).append(int(v))
    stack = [(int(root), 0)]
    seen = 0
    while stack:
        u, lu = stack.pop()
        if lu > n:
            return False
        seen += 1
        for c in children.get(u, ()):
            stack.append((c, lu + 1))
    return seen == len(reached)
