"""Quickstart: the paper's three strategies in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Comm, MigratoryStrategy, Scheme, bfs, bfs_traffic, bucketize,
    compute_similarity, gather_result, generate_alignment_pair, layout_blk,
    layout_hcb, partition_ell, pick_grid, plan_stats, recall_at_k, spmv,
    spmv_traffic, stripe_vector,
)
from repro.sparse import edges_to_csr, erdos_renyi_edges, laplacian_2d, partition_graph

P = 8  # logical nodelets (one Emu Chick node)

# --- S1: SpMV — to replicate or not (paper §5.1) ---------------------------
a = laplacian_2d(32)  # 1024 x 1024 five-point stencil
x = jnp.asarray(np.random.default_rng(0).standard_normal(1024).astype(np.float32))
pe = partition_ell(a, P)

y_rep = gather_result(spmv(pe, x, MigratoryStrategy(replicate_x=True)), 1024)
y_str = gather_result(
    spmv(pe, stripe_vector(x, P), MigratoryStrategy(replicate_x=False)), 1024
)
assert np.allclose(np.asarray(y_rep), np.asarray(y_str), atol=1e-4)
print("S1 SpMV: replicated-x migrations =",
      spmv_traffic(pe, MigratoryStrategy(replicate_x=True)).migrations,
      "| striped-x migrations =",
      spmv_traffic(pe, MigratoryStrategy(replicate_x=False)).migrations)

# --- S2: BFS — remote writes beat migrating threads (paper §5.2) -----------
g = partition_graph(edges_to_csr(erdos_renyi_edges(12, 8), 1 << 12), P)
parents = bfs(g, root=0)
mig = bfs_traffic(g, 0, MigratoryStrategy(comm=Comm.MIGRATE))
push = bfs_traffic(g, 0, MigratoryStrategy(comm=Comm.REMOTE_WRITE))
print(f"S2 BFS: reached {int((np.asarray(parents) >= 0).sum())}/{1 << 12} vertices; "
      f"traffic migrate={mig.traffic.total_bytes / 1e6:.2f}MB "
      f"remote_write={push.traffic.total_bytes / 1e6:.2f}MB "
      f"({mig.traffic.total_bytes / push.traffic.total_bytes:.0f}x less)")

# --- S3: GSANA — Hilbert layout + PAIR granularity (paper §5.3) -------------
vs1, vs2, pi = generate_alignment_pair(1024, seed=1)
grid = pick_grid(1024, 32)
cap = max(bucketize(vs1, grid).cap, bucketize(vs2, grid).cap)
b1, b2 = bucketize(vs1, grid, cap=cap), bucketize(vs2, grid, cap=cap)
cand, score = compute_similarity(vs1, vs2, b1, b2, k=4, scheme=Scheme.PAIR)
blk = plan_stats(vs1, vs2, b1, b2, layout_blk(b1, b2, 1024, 1024, P), Scheme.PAIR, P)
hcb = plan_stats(vs1, vs2, b1, b2, layout_hcb(b1, b2, P), Scheme.PAIR, P)
print(f"S3 GSANA: recall@4={recall_at_k(cand, pi):.3f}; migrations "
      f"BLK={blk.traffic.migrations} -> HCB={hcb.traffic.migrations} "
      f"({100 * (1 - hcb.traffic.migrations / blk.traffic.migrations):.0f}% fewer)")
